"""Docs link checker for CI.

Verifies that (a) every relative markdown link in README.md and
docs/*.md points at a file or directory that exists (anchors and
external http(s)/mailto links are skipped), and (b) every path-shaped
row of the README "Repo map" table resolves.  Exits non-zero listing
each dead link so the lint job fails loudly instead of shipping
stale docs.

Usage: python tools/check_docs.py  (from the repo root or anywhere)
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
REPO_MAP_ROW_RE = re.compile(r"^\|\s*`([^`]+)`")


def _iter_md_files():
    yield ROOT / "README.md"
    docs = ROOT / "docs"
    if docs.is_dir():
        yield from sorted(docs.glob("*.md"))


def check_links(md: Path) -> list[str]:
    """Return one error string per unresolvable relative link in *md*."""
    errors = []
    for lineno, line in enumerate(md.read_text().splitlines(), 1):
        for target in LINK_RE.findall(line):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            if not (md.parent / path).exists():
                rel = md.relative_to(ROOT)
                errors.append(f"{rel}:{lineno}: dead link -> {target}")
    return errors


def check_repo_map(readme: Path) -> list[str]:
    """Return errors for README repo-map rows whose paths don't exist."""
    errors = []
    in_map = False
    for lineno, line in enumerate(readme.read_text().splitlines(), 1):
        if line.startswith("## "):
            in_map = line.strip() == "## Repo map"
            continue
        if not in_map:
            continue
        m = REPO_MAP_ROW_RE.match(line)
        if not m:
            continue
        path = m.group(1).rstrip("/")
        if not (ROOT / path).exists():
            errors.append(f"README.md:{lineno}: repo-map path missing -> {path}")
    return errors


def main() -> int:
    """Run both checks; print failures and return a process exit code."""
    errors = []
    for md in _iter_md_files():
        errors += check_links(md)
    errors += check_repo_map(ROOT / "README.md")
    if errors:
        print("\n".join(errors))
        print(f"\nFAIL: {len(errors)} dead doc link(s)/path(s)")
        return 1
    print("OK: all doc links and repo-map paths resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
