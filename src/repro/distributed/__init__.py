from repro.distributed.sharding import (  # noqa: F401
    ParamDef,
    ShardingRules,
    default_rules,
    init_params,
    logical_to_spec,
    param_shardings,
    param_specs,
    tree_size_bytes,
)
