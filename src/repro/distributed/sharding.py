"""Logical-axis sharding: the single place that decides how tensors map
onto the production mesh.

Modules declare parameters as :class:`ParamDef` schemas with *logical*
axis names ("embed", "heads", "ff", "experts", ...).  ``ShardingRules``
translate logical names to mesh axes; the same schema therefore serves
1-device smoke tests and the 512-chip multi-pod dry-run unchanged.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Declarative parameter: shape + logical axes + initializer."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | scaled | embed
    scale: Optional[float] = None
    dtype: Any = None  # filled from ModelConfig.param_dtype if None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis name -> mesh axis (or tuple of mesh axes, or None)."""

    rules: Tuple[Tuple[str, Any], ...]

    def get(self, name: Optional[str]):
        if name is None:
            return None
        for k, v in self.rules:
            if k == name:
                return v
        return None


def default_rules(*, fsdp: bool = True, sequence_parallel: bool = False,
                  multi_pod: bool = False, shard_kv_seq: bool = False,
                  fold_axis: Optional[str] = None) -> ShardingRules:
    """Production rules for the (pod, data, model) mesh.

    - batch over ("pod","data") — DP across pods and the data axis.
    - TP dims (heads/ff/vocab/experts) over "model".
    - fsdp shards the 'embed' dim of weights over "data" (+"pod") — ZeRO-3.
    """
    dp: Any = ("pod", "data") if multi_pod else "data"
    weight_dp = dp if fsdp else None
    r = [
        ("batch", dp),
        ("vocab", "model"),
        ("heads", "model"),
        ("kv_heads", "model"),
        ("ff", "model"),
        ("experts", dp),
        ("expert_embed", None),
        ("expert_ff", "model"),
        ("embed", weight_dp),
        ("embed_act", None),   # activations' d_model dim stays unsharded
        ("seq", "model" if sequence_parallel else None),
        ("attn_seq", None),    # q's seq dim inside attention (cells.py may
                               # map it to "model" when heads don't divide TP)
        ("logits_seq", None),  # logits' seq dim (vocab claims "model")
        ("kv_seq", dp if shard_kv_seq else None),
        ("head_dim", None),
        ("state", None),
        ("layers", None),
        ("fold", fold_axis),
        ("qk_lora", None),
        ("inner", "model"),    # mamba/rwkv expanded inner dim
        ("rows", dp),          # causal-data rows (DML engine); inside the
                               # moments engine each row block is
                               # re-constrained on this axis
        ("row_block", None),   # the block index of core.moments blocked
                               # ("whole"-strategy) partials — sequential
                               # reduction order, never sharded
        ("replicate", dp),     # bootstrap/tuning replicate axis
                               # (repro.inference ShardMapExecutor)
    ]
    return ShardingRules(rules=tuple(r))


def logical_to_spec(axes: Sequence[Optional[str]], rules: ShardingRules,
                    mesh: Optional[Mesh] = None) -> P:
    """Translate logical axes to a PartitionSpec, dropping mesh axes that
    do not exist on ``mesh`` (lets one rule set serve all mesh shapes).
    A mesh axis may appear only once in a spec; later logical axes that
    map to an already-used mesh axis fall back to replicated (e.g. under
    sequence parallelism 'seq' claims "model" before 'vocab' would)."""
    names = set(mesh.axis_names) if mesh is not None else None
    used = set()

    def ok(ax):
        return (names is None or ax in names) and ax not in used

    out = []
    for a in axes:
        m = rules.get(a)
        if m is None:
            out.append(None)
        elif isinstance(m, (tuple, list)):
            kept = tuple(x for x in m if ok(x))
            used.update(kept)
            out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
        else:
            if ok(m):
                used.add(m)
                out.append(m)
            else:
                out.append(None)
    return P(*out)


# ---------------------------------------------------------------------------
# Schema traversal
# ---------------------------------------------------------------------------

def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _map_schema(fn: Callable[[str, ParamDef], Any], schema, path: str = ""):
    if _is_def(schema):
        return fn(path, schema)
    if isinstance(schema, Mapping):
        return {k: _map_schema(fn, v, f"{path}/{k}") for k, v in schema.items()}
    raise TypeError(f"bad schema node at {path}: {type(schema)}")


def _path_key(key: jax.Array, path: str) -> jax.Array:
    h = int.from_bytes(hashlib.sha256(path.encode()).digest()[:4], "big")
    return jax.random.fold_in(key, h)


def init_params(key: jax.Array, schema, param_dtype=jnp.float32):
    """Materialize a schema into a pytree of initialized arrays."""

    def make(path: str, d: ParamDef):
        dtype = d.dtype or param_dtype
        k = _path_key(key, path)
        if d.init == "zeros":
            return jnp.zeros(d.shape, dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, dtype)
        fan_in = d.shape[0] if len(d.shape) else 1
        if d.init == "embed":
            scale = d.scale if d.scale is not None else 0.02
        elif d.init == "scaled":
            scale = (d.scale if d.scale is not None else 1.0) / max(1.0, fan_in) ** 0.5
        else:
            scale = d.scale if d.scale is not None else 0.02
        return (jax.random.normal(k, d.shape, jnp.float32) * scale).astype(dtype)

    return _map_schema(make, schema)


def param_specs(schema, rules: ShardingRules, mesh: Optional[Mesh] = None):
    """Pytree of PartitionSpecs mirroring the schema."""
    return _map_schema(lambda _, d: logical_to_spec(d.axes, rules, mesh), schema)


def param_shardings(schema, rules: ShardingRules, mesh: Mesh):
    return _map_schema(
        lambda _, d: NamedSharding(mesh, logical_to_spec(d.axes, rules, mesh)),
        schema)


def abstract_params(schema, param_dtype=jnp.float32):
    """ShapeDtypeStructs for the schema (dry-run: no allocation)."""
    return _map_schema(
        lambda _, d: jax.ShapeDtypeStruct(d.shape, d.dtype or param_dtype),
        schema)


def tree_size_bytes(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for x in leaves:
        total += x.size * x.dtype.itemsize
    return int(total)


def mesh_context(mesh: Mesh):
    """``jax.set_mesh(mesh)`` where available (jax >= 0.6), else the
    nearest equivalent on older jax (``jax.sharding.use_mesh`` /
    ``use_abstract_mesh``, falling back to the bare mesh context).
    Lowering with explicit in_shardings is correct under all of them;
    only activation ``constrain``s need the abstract mesh populated."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    return mesh


def _active_mesh():
    """The mesh the current trace sees: the abstract mesh on jax >= 0.6
    (installed by ``jax.set_mesh``), the thread-resources physical mesh
    (installed by the bare ``with mesh:`` context) on older jax.
    Returns None when no mesh is active."""
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        return get_abstract()
    try:
        from jax._src.mesh import thread_resources
        return thread_resources.env.physical_mesh
    except Exception:
        return None


def constrain(x: jax.Array, axes: Sequence[Optional[str]],
              rules: Optional[ShardingRules]) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op when rules are
    None (smoke tests) or outside a ``jax.set_mesh`` scope.

    NOTE: on jax >= 0.6 the mesh must be installed with
    ``jax.set_mesh(mesh)`` — there the bare ``with mesh:`` context does
    NOT populate the abstract mesh and silently disables every
    activation constraint (this cost 10x memory in the first dry-run;
    see EXPERIMENTS.md §Perf, iteration 0).  Use
    ``sharding.mesh_context(mesh)`` to get the right scope on any jax
    version."""
    if rules is None:
        return x
    mesh = _active_mesh()
    if mesh is None or mesh.empty:
        return x
    spec = logical_to_spec(axes, rules, mesh if mesh.axis_names else None)
    return jax.lax.with_sharding_constraint(x, spec)
