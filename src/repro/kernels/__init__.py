# Pallas TPU kernels (validated with interpret=True on CPU).
# Each kernel directory ships kernel.py (pl.pallas_call + BlockSpec),
# ops.py (jit'd dispatch wrapper) and ref.py (pure-jnp oracle).
#
# Hot spots covered (see DESIGN.md §6):
#   flash_attention/  tiled online-softmax attention (prefill/train)
#   residual_gram/    fused residualize->Gram for the DML final stage
#   ssm_scan/         chunked gated-linear-attention scan (mamba2/rwkv6)
