"""repro.kernels — Pallas TPU kernels (interpret-mode certified on CPU).

Each kernel directory ships ``kernel.py`` (the ``pl.pallas_call`` +
BlockSpec), ``ops.py`` (a jit'd dispatch wrapper with backend
selection), and ``ref.py`` (a pure-jnp oracle for tests).  The causal
workload's hot spot is the fused segment-Gram family (``seg_gram``),
reached from estimation code via
``CausalConfig.row_block_strategy="pallas"``: one kernel streams
``(block_n, p)`` tiles HBM→VMEM, runs the per-row builder in
registers, and accumulates per-segment augmented Grams — with a
``pallas → chunked → whole`` fallback ladder (counter-instrumented on
``repro.obs.metrics.default_registry``) for forms without a fused
builder.  ``flash_attention`` and ``ssm_scan`` serve the LM-backbone
nuisances.
"""
# Each kernel directory ships kernel.py (pl.pallas_call + BlockSpec),
# ops.py (jit'd dispatch wrapper) and ref.py (pure-jnp oracle).
#
# Hot spots covered (see DESIGN.md §6):
#   flash_attention/  tiled online-softmax attention (prefill/train)
#   residual_gram/    fused residualize->Gram for the DML final stage
#   ssm_scan/         chunked gated-linear-attention scan (mamba2/rwkv6)
