"""Jit'd dispatch wrapper for the fused residual-Gram kernel.

Used by repro.core.final_stage: local (per-shard) moments are computed
here, then psum'd over the data axis — the distributed normal equations
of the DML final stage.  The kernel path routes through the unified
segment-Gram kernel (repro.kernels.seg_gram), whose wrapper zero-pads
the row tail (exact no-op) — no n % block_n divisibility requirement —
and auto-detects interpret mode off-TPU.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax

from repro.kernels.residual_gram import kernel as _kernel
from repro.kernels.residual_gram import ref as _ref


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


@functools.partial(jax.jit, static_argnames=("backend", "block_n"))
def residual_gram(
    y: jax.Array,
    t: jax.Array,
    my: jax.Array,
    mt: jax.Array,
    phi: jax.Array,
    *,
    backend: str = "",
    block_n: int = 512,
) -> Tuple[jax.Array, jax.Array]:
    """Fused residualize->moments. Returns (G (p,p), b (p,)), fp32."""
    be = backend or default_backend()
    if be == "ref":
        return _ref.residual_gram_ref(y, t, my, mt, phi)
    return _kernel.residual_gram_pallas(
        y,
        t,
        my,
        mt,
        phi,
        block_n=block_n,
        interpret=True if be == "interpret" else None,
    )
