"""Jit'd dispatch wrapper for the fused residual-Gram kernel.

Used by repro.core.final_stage: local (per-shard) moments are computed
here, then psum'd over the data axis — the distributed normal equations
of the DML final stage.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.residual_gram import kernel as _kernel
from repro.kernels.residual_gram import ref as _ref


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


@functools.partial(jax.jit, static_argnames=("backend", "block_n"))
def residual_gram(y: jax.Array, t: jax.Array, my: jax.Array, mt: jax.Array,
                  phi: jax.Array, *, backend: str = "", block_n: int = 512
                  ) -> Tuple[jax.Array, jax.Array]:
    """Fused residualize->moments. Returns (G (p,p), b (p,)), fp32."""
    be = backend or default_backend()
    if be == "ref":
        return _ref.residual_gram_ref(y, t, my, mt, phi)
    n, p = phi.shape
    bn = min(block_n, n)
    pad_n = (-n) % bn
    pad_p = (-p) % 128 if be == "pallas" else 0
    if pad_n or pad_p:
        # zero rows/cols contribute exactly zero to G and b
        y = jnp.pad(y, (0, pad_n))
        t = jnp.pad(t, (0, pad_n))
        my = jnp.pad(my, (0, pad_n))
        mt = jnp.pad(mt, (0, pad_n))
        phi = jnp.pad(phi, ((0, pad_n), (0, pad_p)))
    g, b = _kernel.residual_gram_pallas(
        y, t, my, mt, phi, block_n=bn, interpret=(be == "interpret"))
    if pad_p:
        g, b = g[:p, :p], b[:p]
    return g, b
