"""Pallas TPU kernel: fused residualize -> Gram accumulation.

The DML final stage at industrial scale (paper §5.3: n = 1M rows,
p ≈ 500 covariate features) is bandwidth-bound: the naive path writes the
residual vectors and the (n,p) Z matrix back to HBM before the Gram
matmul reads them again.  This kernel streams (block_n, p) tiles of phi
through VMEM once, forms residuals and Z in registers, and accumulates
G += Z^T Z and b += Z^T ry into VMEM-resident accumulators — a single
HBM pass over the data.

Grid: (n / block_n,) — sequential; outputs use a constant block index so
they stay pinned in VMEM across iterations (accumulation pattern).

VMEM working set (fp32): phi tile block_n*p + G p*p + ~3*block_n.
block_n=512, p=512: 512*512*4 * 2 = 2 MiB << 16 MiB.  p is rounded to a
multiple of 128 by the wrapper (zero-padded features are exact no-ops in
G and b).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rg_kernel(y_ref, t_ref, my_ref, mt_ref, phi_ref, g_ref, b_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        b_ref[...] = jnp.zeros_like(b_ref)

    ry = (y_ref[...] - my_ref[...]).astype(jnp.float32)  # (bn, 1)
    rt = (t_ref[...] - mt_ref[...]).astype(jnp.float32)  # (bn, 1)
    z = rt * phi_ref[...].astype(jnp.float32)            # (bn, p)
    g_ref[...] += z.T @ z
    b_ref[...] += z.T @ ry


def residual_gram_pallas(y: jax.Array, t: jax.Array, my: jax.Array,
                         mt: jax.Array, phi: jax.Array, *,
                         block_n: int = 512, interpret: bool = True
                         ) -> Tuple[jax.Array, jax.Array]:
    """y,t,my,mt: (n,); phi: (n,p). Returns (G (p,p), b (p,)) in fp32."""
    n, p = phi.shape
    block_n = min(block_n, n)
    assert n % block_n == 0, (n, block_n)

    col = lambda x: x.reshape(n, 1)
    g, b = pl.pallas_call(
        _rg_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_n, p), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((p, p), lambda i: (0, 0)),
            pl.BlockSpec((p, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, p), jnp.float32),
            jax.ShapeDtypeStruct((p, 1), jnp.float32),
        ],
        interpret=interpret,
    )(col(y), col(t), col(my), col(mt), phi)
    return g, b[:, 0]
