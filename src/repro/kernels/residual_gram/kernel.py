"""Fused residualize -> Gram for the DML final stage — now a thin
wrapper over the unified segment-Gram kernel (repro.kernels.seg_gram),
which generalizes this form to fold/IV/segment-masked Grams.  One
fused implementation; this module keeps the historical entry point.

The augmented Gram M = [rt*phi | ry] comes out of one rolled pass over
(block_n, p) tiles (residuals and Z form in registers, accumulators
stay VMEM-resident); (G, b) are slices of it.

Padding contract (no divisibility requirement): the row tail is
zero-padded inside the kernel wrapper — all-zero rows produce all-zero
M rows, contributing exactly 0.0 to G and b (tested bitwise in
tests/test_kernels_seg_gram.py).

``interpret=None`` auto-detects the platform: compiled mosaic on TPU,
interpret mode elsewhere.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.kernels.seg_gram import kernel as sg_kernel
from repro.kernels.seg_gram import ref as sg_ref


def residual_gram_pallas(
    y: jax.Array,
    t: jax.Array,
    my: jax.Array,
    mt: jax.Array,
    phi: jax.Array,
    *,
    block_n: int = 512,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """y,t,my,mt: (n,); phi: (n,p). Returns (G (p,p), b (p,)) in fp32."""
    p = phi.shape[1]
    col = lambda x: x.astype(jax.numpy.float32).reshape(-1, 1)  # noqa: E731
    gaug = sg_kernel.seg_gram_pallas(
        sg_ref.build_residual,
        [col(y), col(t), col(my), col(mt), phi.astype(jax.numpy.float32)],
        block_n=block_n,
        interpret=interpret,
    )
    return gaug[:p, :p], gaug[:p, p]
