from repro.kernels.residual_gram.ops import residual_gram  # noqa: F401
