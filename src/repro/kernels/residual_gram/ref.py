"""Pure-jnp oracle: fused residualize -> Gram moments for the DML final
stage (Neyman-orthogonal normal equations).

Given outcomes y, treatments t, cross-fit nuisance predictions my, mt and
CATE features phi:
    ry = y - my                       (outcome residual)
    rt = t - mt                       (treatment residual)
    Z  = rt[:, None] * phi            (n, p)
    G  = Z^T Z                        (p, p)
    b  = Z^T ry                       (p,)
theta = G^{-1} b  solves  min_theta  sum_i (ry_i - <theta, phi_i> rt_i)^2,
whose FOC is the orthogonal moment  E[(ry - theta(x) rt) rt phi(x)] = 0.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def residual_gram_ref(
    y: jax.Array, t: jax.Array, my: jax.Array, mt: jax.Array, phi: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    ry = (y - my).astype(jnp.float32)
    rt = (t - mt).astype(jnp.float32)
    z = rt[:, None] * phi.astype(jnp.float32)
    gram = z.T @ z
    vec = z.T @ ry
    return gram, vec
