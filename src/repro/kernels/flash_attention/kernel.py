"""Pallas TPU kernel: tiled online-softmax (flash) attention, GQA-aware.

Grid: (B, H, Sq/block_q, Sk/block_k) — the key axis is minor/sequential;
running max / normalizer / output accumulator live in VMEM scratch across
key iterations (flash-attention-2 schedule).

VMEM working set (fp32 accumulators, bf16 tiles):
  q tile        block_q * D
  k,v tiles     2 * block_k * D
  acc scratch   block_q * D   (f32)
  m,l scratch   2 * block_q   (f32)
  scores        block_q * block_k
With block_q=block_k=128 and D=128: ~0.7 MiB << 16 MiB VMEM; block sizes
are multiples of 128 to keep the MXU contraction dims aligned.

Causal handling: blocks entirely above the diagonal skip the matmul
(pl.when) — per-element masking only on the diagonal blocks.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s, *, scale,
               causal, softcap, block_q, block_k, n_k):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    q_start = iq * block_q
    k_start = ik * block_k

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, D)
        s = (q @ k.T) * scale  # (bq, bk)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        if causal:
            qi = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            ki = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(qi >= ki, s, NEG_INF)
        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc[...] = acc[...] * alpha + p @ v
        m_s[...] = m_new

    if causal:
        # skip key blocks strictly above the causal diagonal
        pl.when(q_start + block_q - 1 >= k_start)(_compute)
    else:
        _compute()

    @pl.when(ik == n_k - 1)
    def _finalize():
        o_ref[0, 0] = (acc[...] / jnp.maximum(l_s[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, softcap: float = 0.0,
                           scale: Optional[float] = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True) -> jax.Array:
    """q: (B,H,Sq,D); k,v: (B,KV,Sk,D). Returns (B,H,Sq,D)."""
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    n_k = Sk // block_k
    sc = scale if scale is not None else 1.0 / (D ** 0.5)

    kernel = functools.partial(
        _fa_kernel, scale=sc, causal=causal, softcap=softcap,
        block_q=block_q, block_k=block_k, n_k=n_k)

    return pl.pallas_call(
        kernel,
        grid=(B, H, Sq // block_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
