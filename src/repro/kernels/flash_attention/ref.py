"""Pure-jnp oracle for flash attention (GQA-aware, causal, softcap)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, softcap: float = 0.0,
                  scale: Optional[float] = None) -> jax.Array:
    """q: (B,H,Sq,D); k,v: (B,KV,Sk,D); H % KV == 0. Returns (B,H,Sq,D)."""
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    sc = scale if scale is not None else 1.0 / (D ** 0.5)
    qg = q.reshape(B, KV, G, Sq, D).astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, k.astype(jnp.float32)) * sc
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, D).astype(q.dtype)
