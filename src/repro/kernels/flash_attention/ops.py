"""Jit'd dispatch wrapper for flash attention.

Model code passes (B,S,H,D) layout; this wrapper transposes to the
kernel's (B,H,S,D) layout and picks a backend:
  "ref"       dense jnp oracle (CPU / dry-run path — same FLOP count)
  "pallas"    compiled Pallas TPU kernel (production)
  "interpret" Pallas body interpreted on CPU (tests)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.flash_attention import kernel as _kernel
from repro.kernels.flash_attention import ref as _ref


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


@functools.partial(jax.jit, static_argnames=("causal", "softcap", "scale",
                                             "backend", "block_q", "block_k"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, softcap: float = 0.0,
                    scale: Optional[float] = None, backend: str = "",
                    block_q: int = 128, block_k: int = 128) -> jax.Array:
    """q: (B,Sq,H,D); k,v: (B,Sk,KV,D). Returns (B,Sq,H,D)."""
    be = backend or default_backend()
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if be == "ref":
        o = _ref.attention_ref(qt, kt, vt, causal=causal, softcap=softcap,
                               scale=scale)
    else:
        o = _kernel.flash_attention_pallas(
            qt, kt, vt, causal=causal, softcap=softcap, scale=scale,
            block_q=block_q, block_k=block_k, interpret=(be == "interpret"))
    return o.transpose(0, 2, 1, 3)
