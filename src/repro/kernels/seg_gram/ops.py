"""Dispatch wrapper for the fused segment-Gram kernel family.

``repro.core.moments`` routes ``row_block_strategy="pallas"`` here.
Three lowerings of the same builder vocabulary (ref.py):

  "pallas"    the Pallas kernel (kernel.py): compiled mosaic on TPU,
              interpret mode elsewhere — ONE fused HBM pass.
  "interpret" the Pallas kernel forced into interpret mode — the CPU
              certification target (same block decomposition and
              accumulation order as the compiled kernel).
  "scatter"   pure-XLA fast lowering for hosts without a mosaic
              compiler: one segment is the fused augmented matmul
              ``(w*L)^T R``; many segments scatter per-row outer
              products with ``jax.ops.segment_sum`` — measured ~2x
              over the one-hot einsum at sweep shapes on CPU, because
              the (n, S) mask never materializes.
  "ref"       the one-hot einsum oracle (ref.py).

``default_backend()`` picks "pallas" on TPU and "scatter" elsewhere;
``force_backend("interpret")`` pins the kernel path for parity tests
(the conformance suite certifies chunked = pallas estimator-wide).

Contract: all lowerings share the padding rules of the moments engine
(zero data rows, seg = -1 — ``segment_sum`` drops negative ids exactly
as the one-hot maps them to a zero row — and w = 0), so padded rows
are exact no-ops.  Counts/n_eff are computed OUTSIDE the kernels from
the same plain sums in every mode (the ``fold_weighted_gram``
precedent: strategy-independent by construction).
"""

from __future__ import annotations

import contextlib
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.seg_gram import kernel as _kernel
from repro.kernels.seg_gram import ref as _ref

Array = jax.Array
_F32 = jnp.float32

_FORCED: List[str] = []


def default_backend() -> str:
    if _FORCED:
        return _FORCED[-1]
    return "pallas" if jax.default_backend() == "tpu" else "scatter"


@contextlib.contextmanager
def force_backend(name: str):
    """Pin the lowering for the dynamic extent (tests: "interpret"
    certifies the kernel path on CPU, "ref" the einsum oracle)."""
    _FORCED.append(name)
    try:
        yield
    finally:
        _FORCED.pop()


def _col(x: Array) -> Array:
    x = x.astype(_F32)
    return x[:, None] if x.ndim == 1 else x


def _active_data_mesh():
    """Trace-time DataMesh probe (same sys.modules trick as
    core.moments: no runtime-layer import unless a mesh can exist)."""
    import sys

    rd = sys.modules.get("repro.runtime.distributed")
    return None if rd is None else rd.current_data_mesh()


def _scatter_dist(builder, arrays, seg, w, n_segments, row_block, init, dm):
    """Row-sharded blocked scatter lowering: per-block partials (the
    same ``segment_sum`` / augmented-matmul graphs as ``_scatter``'s
    scan body) evaluate shard-locally over the data mesh, then an
    ordered left fold combines them in global block order
    (runtime.distributed.dist_reduce).  Deterministic; parity with the
    single-host lowerings is tolerance-grade like every pallas-strategy
    path (per-block matmul partials reassociate the row reduction)."""
    from repro.runtime.distributed import dist_reduce

    r = int(row_block)
    sids = None if seg is None else seg[:, 0]
    bcast = {i: a for i, a in enumerate(arrays) if a.shape[0] == 1}
    row_arrays = [a for i, a in enumerate(arrays) if i not in bcast]
    qL, qR = jax.eval_shape(
        builder,
        *[
            jax.ShapeDtypeStruct(
                (a.shape[0] if a.shape[0] == 1 else r,) + a.shape[1:],
                a.dtype,
            )
            for a in arrays
        ],
    )
    qL, qR = qL.shape[1], qR.shape[1]

    def block(*blks):
        it = iter(blks)
        full = [bcast[i] if i in bcast else next(it) for i in range(len(arrays))]
        sb = next(it) if sids is not None else None
        wb = next(it) if w is not None else None
        L, R = builder(*full)
        Lw = L if wb is None else L * wb
        if sb is None:
            return Lw.T @ R
        outer = (Lw[:, :, None] * R[:, None, :]).reshape(L.shape[0], -1)
        return jax.ops.segment_sum(outer, sb, num_segments=n_segments)

    dist_arrays = list(row_arrays)
    pad_values = [0] * len(row_arrays)
    if sids is not None:
        dist_arrays.append(sids)
        pad_values.append(-1)
    if w is not None:
        dist_arrays.append(w)
        pad_values.append(0)
    acc0 = init
    if init is not None and sids is not None:
        acc0 = init.reshape(n_segments, qL * qR)
    G = dist_reduce(block, dist_arrays, row_block=r, dm=dm,
                    pad_values=pad_values, init=acc0)
    return G if sids is None else G.reshape(n_segments, qL, qR)


def _scatter(builder, arrays, seg, w, n_segments, row_block,
             init=None) -> Array:
    n = max(a.shape[0] for a in arrays)
    if n_segments == 1:
        L, R = builder(*arrays)
        Lw = L if w is None else L * w
        G = Lw.T @ R
        return G if init is None else init + G
    sids = seg[:, 0]
    r = int(row_block or 0)
    if r <= 0 or r >= n:
        L, R = builder(*arrays)
        Lw = L if w is None else L * w
        outer = (Lw[:, :, None] * R[:, None, :]).reshape(n, -1)
        G = jax.ops.segment_sum(outer, sids, num_segments=n_segments)
        G = G.reshape(n_segments, L.shape[1], R.shape[1])
        return G if init is None else init + G
    # blocked scan: bounded O(r * qL*qR) temporaries at industrial n
    pad = (-n) % r
    if pad:
        arrays = [
            a if a.shape[0] == 1 else jnp.pad(a, ((0, pad), (0, 0)))
            for a in arrays
        ]
        sids = jnp.pad(sids, (0, pad), constant_values=-1)
        if w is not None:
            w = jnp.pad(w, ((0, pad), (0, 0)))
    nb = (n + pad) // r

    def _slc(a, i):
        if a.shape[0] == 1:
            return a
        return lax.dynamic_slice_in_dim(a, i * r, r, axis=0)

    qL, qR = jax.eval_shape(
        builder,
        *[
            jax.ShapeDtypeStruct(
                (a.shape[0] if a.shape[0] == 1 else r,) + a.shape[1:],
                a.dtype,
            )
            for a in arrays
        ],
    )
    qL, qR = qL.shape[1], qR.shape[1]

    def step(acc, i):
        L, R = builder(*[_slc(a, i) for a in arrays])
        Lw = L if w is None else L * _slc(w, i)
        outer = (Lw[:, :, None] * R[:, None, :]).reshape(r, qL * qR)
        sb = lax.dynamic_slice_in_dim(sids, i * r, r, axis=0)
        return (
            acc + jax.ops.segment_sum(outer, sb, num_segments=n_segments),
            None,
        )

    # init seeds the left fold (repro.store's incremental ingest): the
    # scan replays the same addition sequence a one-shot pass over the
    # concatenated rows would, so within-backend ingest stays bitwise
    # when every prior ingest ended on a row_block boundary.
    acc0 = (jnp.zeros((n_segments, qL * qR), _F32) if init is None
            else init.reshape(n_segments, qL * qR))
    G, _ = lax.scan(step, acc0, jnp.arange(nb, dtype=jnp.int32))
    return G.reshape(n_segments, qL, qR)


def seg_reduce(
    builder,
    arrays: Sequence[Array],
    *,
    seg: Optional[Array] = None,
    w: Optional[Array] = None,
    n_segments: int = 1,
    row_block: int = 0,
    backend: str = "",
    init: Optional[Array] = None,
) -> Array:
    """The one entry point: dispatch ``G[s] = sum w_n L_n (x) R_n`` to
    the selected lowering.  ``row_block`` sets the kernel block size
    (and bounds the scatter lowering's temporaries).

    ``init`` seeds the accumulator (incremental ingest): the blocked
    scatter lowering threads it as the scan seed — bitwise the one-shot
    pass over concatenated rows at aligned boundaries — while the
    kernel/ref/whole-array lowerings add it to their result (delta-add:
    correct, tolerance-equal to one-shot)."""
    be = backend or default_backend()
    arrays = [a.astype(_F32) for a in arrays]
    if w is not None:
        w = _col(w)
    if seg is not None:
        seg = seg.astype(jnp.int32)
        seg = seg[:, None] if seg.ndim == 1 else seg
    n = max(a.shape[0] for a in arrays)
    if be != "ref" and 0 < row_block < n:
        dm = _active_data_mesh()
        if dm is not None:
            # an active data mesh overrides the single-host lowerings
            # on the blocked path ("ref" stays the unsharded oracle)
            return _scatter_dist(
                builder, arrays, seg, w, n_segments, row_block, init, dm
            )
    if be == "ref":
        G = _ref.seg_gram_ref(
            builder, arrays, seg=seg, w=w, n_segments=n_segments
        )
        return G if init is None else init + G
    if be == "scatter":
        return _scatter(
            builder, arrays, seg, w, n_segments, row_block, init=init
        )
    if be not in ("pallas", "interpret"):
        raise ValueError(f"unknown seg_gram backend {be!r}")
    interpret = True if be == "interpret" else None
    bn = row_block if 0 < row_block else 512
    G = _kernel.seg_gram_pallas(
        builder,
        arrays,
        seg=seg,
        w=w,
        n_segments=n_segments,
        block_n=bn,
        interpret=interpret,
    )
    return G if init is None else init + G


def segment_counts(
    seg: Array, n_segments: int, *, w: Optional[Array] = None
) -> Array:
    """Per-segment row counts (or weight sums) — a plain O(n) sum,
    computed identically in every backend so counts stay
    strategy-independent (exact integers match the one-hot column
    sums of the chunked reference bitwise)."""
    ones = jnp.ones((seg.shape[0],), _F32) if w is None else w.astype(_F32)
    return jax.ops.segment_sum(
        ones, seg.astype(jnp.int32), num_segments=n_segments
    )


# ---------------------------------------------------------------------------
# Moment-form API mirroring repro.core.moments (the strategy="pallas"
# targets).  All return fp32; n_eff/counts ride alongside like the
# moments signatures they replace.
# ---------------------------------------------------------------------------


def design_gram(
    D: Array, *, w: Optional[Array] = None, row_block: int = 0, backend: str = ""
) -> Array:
    """(q, q) weighted Gram over a pre-assembled design."""
    return seg_reduce(
        _ref.build_design, [D], w=w, row_block=row_block, backend=backend
    )


def fold_design_gram(
    D: Array,
    folds: Array,
    k: int,
    *,
    row_block: int = 0,
    backend: str = "",
) -> Tuple[Array, Array]:
    """(k, q, q) fold-segmented Gram + per-fold counts."""
    G = seg_reduce(
        _ref.build_design,
        [D],
        seg=folds,
        n_segments=k,
        row_block=row_block,
        backend=backend,
    )
    return G, segment_counts(folds, k)


def fold_weighted_design_gram(
    D: Array, Wk: Array, *, row_block: int = 0, backend: str = ""
) -> Array:
    """(k, q, q) dense-weight fold Gram ``G[k] = Σ_n Wk[k, n] d_n d_nᵀ``
    — the ``ni,kn,nj->kij`` form fused as one kernel pass (the kron
    builder widens L to k·q columns; n_eff stays outside, computed as a
    plain strategy-independent sum by moments.fold_weighted_gram)."""
    k, q = Wk.shape[0], D.shape[1]
    G = seg_reduce(
        _ref.build_fold_weighted,
        [Wk.T, D],
        row_block=row_block,
        backend=backend,
    )
    return G.reshape(k, q, q)


def gram_and_vec(
    D: Array, wg: Array, v: Array, *, row_block: int = 0, backend: str = ""
) -> Tuple[Array, Array]:
    """((q, q) Gram with weights wg, (q,) cross-moment with weights v)
    in one fused pass — the logistic Newton step's two-weight form,
    read off the augmented L = [wg·d | v]."""
    q = D.shape[1]
    Gaug = seg_reduce(
        _ref.build_gram_and_vec,
        [D, _col(wg), _col(v)],
        row_block=row_block,
        backend=backend,
    )
    return Gaug[:q], Gaug[q]


def residual_gram(
    y: Array,
    t: Array,
    my: Array,
    mt: Array,
    phi: Array,
    *,
    w: Optional[Array] = None,
    row_block: int = 0,
    backend: str = "",
) -> Tuple[Array, Array]:
    """(G (p, p), b (p,)) of the orthogonal moment, read off the fused
    augmented Gram M = [rt*phi | ry]."""
    p = phi.shape[1]
    Gaug = seg_reduce(
        _ref.build_residual,
        [_col(y), _col(t), _col(my), _col(mt), phi],
        w=w,
        row_block=row_block,
        backend=backend,
    )
    return Gaug[:p, :p], Gaug[:p, p]


def residual_weighted_gram(
    ry: Array,
    rt: Array,
    phi: Array,
    w: Array,
    *,
    row_block: int = 0,
    backend: str = "",
) -> Tuple[Array, Array]:
    """Weighted augmented residual Gram (inference.numerics form)."""
    Gaug = seg_reduce(
        _ref.build_residual_direct,
        [_col(ry), _col(rt), phi],
        w=w,
        row_block=row_block,
        backend=backend,
    )
    return Gaug, w.astype(_F32).sum()


def iv_gram(
    ry: Array,
    rt: Array,
    rz: Array,
    phi: Array,
    w: Array,
    *,
    row_block: int = 0,
    backend: str = "",
) -> Tuple[Array, Array]:
    """((2p+1, 2p+1) instrumented augmented Gram, n_eff)."""
    Gaug = seg_reduce(
        _ref.build_iv,
        [_col(ry), _col(rt), _col(rz), phi],
        w=w,
        row_block=row_block,
        backend=backend,
    )
    return Gaug, w.astype(_F32).sum()


def fold_iv_gram(
    ry: Array,
    rt: Array,
    rz: Array,
    phi: Array,
    folds: Array,
    k: int,
    *,
    row_block: int = 0,
    backend: str = "",
) -> Tuple[Array, Array]:
    """((k, 2p+1, 2p+1) fold-segmented instrumented Gram, counts)."""
    G = seg_reduce(
        _ref.build_iv,
        [_col(ry), _col(rt), _col(rz), phi],
        seg=folds,
        n_segments=k,
        row_block=row_block,
        backend=backend,
    )
    return G, segment_counts(folds, k)


def residual_meat(
    y: Array,
    t: Array,
    my: Array,
    mt: Array,
    phi: Array,
    theta: Array,
    *,
    w: Optional[Array] = None,
    row_block: int = 0,
    backend: str = "",
) -> Array:
    """(p, p) HC0 meat at theta; the (w*e)^2 weighting happens inside
    the builder (w scales e BEFORE squaring, matching moments)."""
    arrays = [_col(y), _col(t), _col(my), _col(mt), phi, theta.reshape(1, -1)]
    if w is not None:
        arrays.append(_col(w))
    return seg_reduce(
        _ref.build_residual_meat, arrays, row_block=row_block, backend=backend
    )


def iv_meat(
    ry: Array,
    rt: Array,
    rz: Array,
    phi: Array,
    theta: Array,
    *,
    w: Optional[Array] = None,
    row_block: int = 0,
    backend: str = "",
) -> Array:
    """(p, p) HC0 meat of the instrumented moment at theta."""
    arrays = [_col(ry), _col(rt), _col(rz), phi, theta.reshape(1, -1)]
    if w is not None:
        arrays.append(_col(w))
    return seg_reduce(
        _ref.build_iv_meat, arrays, row_block=row_block, backend=backend
    )


def segment_outer(
    U: Array,
    V: Array,
    seg: Array,
    n_segments: int,
    *,
    w: Optional[Array] = None,
    row_block: int = 0,
    backend: str = "",
    init: Optional[Array] = None,
) -> Array:
    """(S, qU, qV) segmented outer-product sums — the sweep's per-step
    gradient shape (one-hot einsum 'ns,ni,nj->sij', fused).  ``init``
    seeds the accumulator (see ``seg_reduce``)."""
    return seg_reduce(
        _ref.build_pair,
        [_col(U), _col(V)],
        seg=seg,
        w=w,
        n_segments=n_segments,
        row_block=row_block,
        backend=backend,
        init=init,
    )
