"""Builders + pure-jnp oracle for the fused segment-Gram family.

Every moment form in ``repro.core.moments`` is an instance of ONE shape:

    G[s] = sum_{n: seg_n = s}  w_n * L_n (x) R_n

where the per-row factors (L, R) are assembled from raw inputs by a
*builder* — residualize, multiply by phi, append the target column —
and ``seg`` is a segment/fold id (one segment means a plain Gram).  The
builders below are plain jnp functions over 2-D fp32 blocks, so the
SAME builder body is traced inside the Pallas kernel (registers), the
XLA scatter lowering, and this one-hot einsum oracle: the three
backends differ only in how the segmented sum is realized.

Builder contract: inputs are 2-D arrays — row-shaped ``(rows, d)`` or
broadcast ``(1, d)`` (e.g. theta) — and the output pair (L, R) is
row-linear in the data, with all-zero input rows mapping to all-zero
L/R rows (that is what makes zero-padding the row tail an exact no-op
in every accumulator).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
Pair = Tuple[Array, Array]


def build_pair(U: Array, V: Array) -> Pair:
    """Plain segmented outer product: L = U, R = V."""
    return U, V


def build_design(D: Array) -> Pair:
    """Symmetric Gram over a pre-assembled design ``[X | 1? | y?]``."""
    return D, D


def build_residual(y: Array, t: Array, my: Array, mt: Array, phi: Array) -> Pair:
    """DML final stage: M = [(t - mt) * phi | (y - my)], G = M^T M."""
    ry = y - my
    rt = t - mt
    M = jnp.concatenate([rt * phi, ry], axis=1)
    return M, M


def build_residual_direct(ry: Array, rt: Array, phi: Array) -> Pair:
    """Residuals already formed (inference.numerics): M = [rt*phi | ry]."""
    M = jnp.concatenate([rt * phi, ry], axis=1)
    return M, M


def build_iv(ry: Array, rt: Array, rz: Array, phi: Array) -> Pair:
    """Instrumented augmented Gram: M = [rz*phi | rt*phi | ry]."""
    M = jnp.concatenate([rz * phi, rt * phi, ry], axis=1)
    return M, M


def build_fold_weighted(Wt: Array, D: Array) -> Pair:
    """Dense per-fold weight matrix (moments.fold_weighted_gram):
    L_n = Wt_n ⊗ d_n (the k per-fold weights kron the design row), so
    G = L^T R reshapes to the (k, q, q) stack Σ_n Wk[k, n] d_n d_nᵀ.
    Zero rows give zero L/R rows (both factors vanish)."""
    r = Wt.shape[0]
    L = (Wt[:, :, None] * D[:, None, :]).reshape(r, Wt.shape[1] * D.shape[1])
    return L, D


def build_gram_and_vec(D: Array, wg: Array, v: Array) -> Pair:
    """Two-weight Gram + cross-moment (moments.weighted_gram_and_vec):
    L = [wg·d | v], R = d — the top q rows of L^T R are Σ wg d dᵀ and
    the trailing row is Σ v dᵀ (the augmented form; the thin ni,n->i
    mat-vec is not chunk-stable — see core.moments)."""
    return jnp.concatenate([wg * D, v], axis=1), D


def build_residual_meat(
    y: Array,
    t: Array,
    my: Array,
    mt: Array,
    phi: Array,
    theta: Array,
    w: Optional[Array] = None,
) -> Pair:
    """HC0 meat of the orthogonal moment: m = (w *) e * z with
    z = rt*phi, e = ry - <z, theta> (theta rides as a (1, p) broadcast
    row so the residual forms in registers alongside z)."""
    ry = y - my
    rt = t - mt
    z = rt * phi
    e = ry - jnp.sum(z * theta, axis=1, keepdims=True)
    if w is not None:
        e = w * e
    m = e * z
    return m, m


def build_iv_meat(
    ry: Array,
    rt: Array,
    rz: Array,
    phi: Array,
    theta: Array,
    w: Optional[Array] = None,
) -> Pair:
    """HC0 meat of the instrumented moment: score zc = rz*phi, residual
    e = ry - <rt*phi, theta>."""
    z = rt * phi
    e = ry - jnp.sum(z * theta, axis=1, keepdims=True)
    if w is not None:
        e = w * e
    m = e * (rz * phi)
    return m, m


def seg_gram_ref(
    builder,
    arrays,
    *,
    seg: Optional[Array] = None,
    w: Optional[Array] = None,
    n_segments: int = 1,
) -> Array:
    """One-hot einsum oracle (whole-array, no blocking): the reference
    the kernel and scatter lowerings are tested against."""
    L, R = builder(*arrays)
    Lw = L if w is None else L * w
    if n_segments == 1:
        return jnp.einsum("ni,nj->ij", Lw, R)
    oh = jax.nn.one_hot(seg[:, 0], n_segments, dtype=L.dtype)
    return jnp.einsum("ns,ni,nj->sij", oh, Lw, R)
