# Fused masked/segmented augmented-Gram kernel family: ONE Pallas
# kernel (kernel.py) + an XLA scatter lowering (ops.py) + the one-hot
# einsum oracle (ref.py) behind row_block_strategy="pallas".
from repro.kernels.seg_gram import ops  # noqa: F401
