"""Pallas TPU kernel: ONE fused mask -> weight -> residualize -> Gram
pass for every segment-Gram-shaped moment in the repo.

The estimators bottom out in ``G[s] = sum_{seg_n = s} w_n L_n (x) R_n``
(repro.kernels.seg_gram.ref documents the builder vocabulary).  The
naive paths write residuals, the (n, p) moment matrix, and an (n, S)
one-hot mask back to HBM between elementwise ops and the Gram matmul;
this kernel streams (block_n, d) tiles through VMEM once per input,
runs the builder in registers, applies the segment mask and bootstrap
weight in registers, and accumulates into a VMEM-resident output:

  grid        (n / block_n,) — sequential; outputs use a constant block
              index so they stay pinned in VMEM across iterations.
  S == 1      g (qL, qR):        g += (w * L)^T R      (one MXU matmul)
  S  > 1      g (S*qL, qR):      the weighted one-hot expands L into
              T[n, s*qL + i] = oh[n, s] * L[n, i] and g += T^T R — the
              segmented sum IS the matmul, which is the layout the MXU
              wants (a 2-D (S*qL, qR) accumulator, not (S, qL, qR)).

VMEM working set (fp32): input tiles ~ block_n * sum(d_i), T tile
block_n * S*qL, accumulator S*qL * qR.  block_n=512, S*qL=768, qR=128:
512*768*4 + 768*128*4 ~ 1.9 MiB << 16 MiB.

Padding contract: the row tail is zero-padded to a multiple of block_n
with seg = -1 (matches no lane of the iota compare -> zero mask row)
and w = 0; builders map all-zero rows to all-zero L/R rows, so padded
rows contribute exactly 0.0 to every accumulator.  On the mosaic path
L/R columns are zero-padded in registers to the (8, 128) fp32 tile
(sliced off the output) — interpret mode skips the column padding.

``interpret=None`` auto-detects: compiled mosaic on TPU, interpret
elsewhere (the CPU certification mode the tests pin).
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

Array = jax.Array


def _pad_rows(a: Array, pad: int, value) -> Array:
    return jnp.pad(a, ((0, pad), (0, 0)), constant_values=value)


def _pad_cols(a: Array, pad: int) -> Array:
    if pad == 0:
        return a
    return jnp.concatenate([a, jnp.zeros((a.shape[0], pad), a.dtype)], axis=1)


def seg_gram_pallas(
    builder,
    arrays: Sequence[Array],
    *,
    seg: Optional[Array] = None,
    w: Optional[Array] = None,
    n_segments: int = 1,
    block_n: int = 512,
    interpret: Optional[bool] = None,
) -> Array:
    """Fused segmented Gram.  ``arrays``: 2-D fp32 inputs, row-shaped
    (n, d) or broadcast (1, d); ``seg``: (n, 1) int32 ids in
    [0, n_segments); ``w``: (n, 1) row weights (default ones).  Returns
    (qL, qR) when n_segments == 1, else (n_segments, qL, qR), fp32."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    S = int(n_segments)
    rows = [a for a in arrays if a.shape[0] != 1]
    n = rows[0].shape[0]
    bn = min(int(block_n), n)
    qL, qR = jax.eval_shape(
        builder,
        *[
            jax.ShapeDtypeStruct(
                (a.shape[0] if a.shape[0] == 1 else bn,) + a.shape[1:],
                a.dtype,
            )
            for a in arrays
        ],
    )
    qL, qR = qL.shape[1], qR.shape[1]
    # mosaic wants (sublane, lane) = (8, 128) fp32 output tiles; padded
    # columns are exact zeros and are sliced off below
    pad_l = 0 if interpret else (-qL) % 8
    pad_r = 0 if interpret else (-qR) % 128
    qlp, qrp = qL + pad_l, qR + pad_r

    pad = (-n) % bn
    if w is None:
        w = jnp.ones((n, 1), jnp.float32)
    if pad:
        arrays = [a if a.shape[0] == 1 else _pad_rows(a, pad, 0) for a in arrays]
        w = _pad_rows(w, pad, 0)
        if seg is not None:
            seg = _pad_rows(seg, pad, -1)
    nb = (n + pad) // bn

    def _spec(a: Array) -> pl.BlockSpec:
        if a.shape[0] == 1:
            return pl.BlockSpec((1, a.shape[1]), lambda i: (0, 0))
        return pl.BlockSpec((bn, a.shape[1]), lambda i: (i, 0))

    inputs = list(arrays) + ([seg] if S > 1 else []) + [w]

    def kern(*refs):
        *data_refs, w_ref, g_ref = refs
        if S > 1:
            *data_refs, seg_ref = data_refs
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _init():
            g_ref[...] = jnp.zeros_like(g_ref)

        L, R = builder(*[r[...] for r in data_refs])
        L = _pad_cols(L, pad_l)
        R = _pad_cols(R, pad_r)
        wb = w_ref[...]  # (bn, 1)
        if S == 1:
            g_ref[...] += (L * wb).T @ R
        else:
            ids = seg_ref[...]  # (bn, 1) int32
            iota = lax.broadcasted_iota(jnp.int32, (ids.shape[0], S), 1)
            oh = jnp.where(ids == iota, wb, 0.0)  # (bn, S)
            T = (oh[:, :, None] * L[:, None, :]).reshape(ids.shape[0], S * qlp)
            g_ref[...] += T.T @ R

    out_rows = qlp if S == 1 else S * qlp
    g = pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[_spec(a) for a in inputs],
        out_specs=pl.BlockSpec((out_rows, qrp), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((out_rows, qrp), jnp.float32),
        interpret=interpret,
    )(*inputs)
    if S == 1:
        return g[:qL, :qR]
    return g.reshape(S, qlp, qrp)[:, :qL, :qR]
