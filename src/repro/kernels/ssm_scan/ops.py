"""Jit'd dispatch wrapper for the GLA scan kernel.

backend:
  "ref"       pure-jnp chunked oracle (CPU default — fast XLA path)
  "pallas"    compiled Pallas TPU kernel (production)
  "interpret" Pallas kernel body interpreted on CPU (correctness tests)
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax

from repro.kernels.ssm_scan import ref as _ref
from repro.kernels.ssm_scan import kernel as _kernel


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


@functools.partial(jax.jit, static_argnames=("chunk", "backend"))
def gla(q: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
        u: Optional[jax.Array] = None, *, chunk: int = 64,
        backend: str = "ref") -> Tuple[jax.Array, jax.Array]:
    """Gated-linear-attention scan. See ssm_scan.ref for semantics."""
    T = q.shape[2]
    while chunk > 1 and T % chunk:
        chunk //= 2
    if backend == "pallas":
        return _kernel.gla_pallas(q, k, v, w, u, chunk=chunk, interpret=False)
    if backend == "interpret":
        return _kernel.gla_pallas(q, k, v, w, u, chunk=chunk, interpret=True)
    return _ref.gla_chunked_ref(q, k, v, w, u, chunk=chunk)


def gla_decode_step(state: jax.Array, q, k, v, w, u=None):
    """Single-token state update for serving (no kernel needed: one
    rank-1 update + readout, bandwidth-bound)."""
    return _ref.gla_step(state, q, k, v, w, u)


@functools.partial(jax.jit, static_argnames=("chunk", "backend"))
def ssd(q: jax.Array, k: jax.Array, v: jax.Array, a: jax.Array, *,
        chunk: int = 32, backend: str = "ref"):
    """Mamba2 SSD scan (B/C shared across heads, scalar per-head decay).
    q,k: (B,T,N); v: (B,H,T,P); a: (B,H,T).  See ssm_scan.ref."""
    T = q.shape[1]
    while chunk > 1 and T % chunk:
        chunk //= 2
    if backend in ("pallas", "interpret"):
        from repro.kernels.ssm_scan import kernel as _kernel
        return _kernel.ssd_pallas(q, k, v, a, chunk=chunk,
                                  interpret=(backend == "interpret"))
    return _ref.ssd_chunked_ref(q, k, v, a, chunk=chunk)


def ssd_decode_step(state, q, k, v, a):
    """Single-token SSD update (serving)."""
    return _ref.ssd_step(state, q, k, v, a)
