"""Pallas TPU kernel: chunked gated-linear-attention scan.

Grid: (B*H, T/chunk) — the chunk axis is the minor (sequential) grid
dimension, so the (Dk,Dv) running state lives in a VMEM scratch that
persists across chunk iterations (reset at chunk==0 for each new b*h).

VMEM working set per iteration (fp32):
  q,k,w tiles     3 * chunk * Dk
  v,o tiles       2 * chunk * Dv
  state scratch   Dk * Dv
  chunk matmuls   chunk^2 (scores)
With chunk=128, Dk=Dv=128: ~ 0.46 MiB — far under the ~16 MiB/core VMEM
budget; chunk and Dk/Dv are MXU-aligned multiples of (8,128) whenever the
model dims allow.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _chunk_math(q, k, v, w, state, bonus_u=None):
    """Shared intra-chunk math. All fp32. q,k,w: (C,Dk); v: (C,Dv);
    state: (Dk,Dv). Returns (o, new_state).

    Stability: the intra-chunk ``k·exp(-cum)`` factor is bounded by the
    per-step decay contract (ref.MAX_LOG_DECAY × chunk); the cross-chunk
    state flow uses ``k·exp(cum_last - cum)`` whose exponent is <= 0 —
    stable for arbitrarily strong decay.
    """
    C = q.shape[0]
    logw = jnp.log(jnp.maximum(w, 1e-22))
    cum_incl = jnp.cumsum(logw, axis=0)
    w_total = jnp.exp(cum_incl[-1])
    k_t = k * jnp.exp(-cum_incl)                          # intra-chunk pairing
    k_flow = k * jnp.exp(cum_incl[-1][None] - cum_incl)   # state flow (<=1)
    if bonus_u is None:  # mamba2 / SSD: read state post-update
        q_t = q * jnp.exp(cum_incl)
        mask = jnp.tril(jnp.ones((C, C), jnp.bool_))
    else:  # rwkv6: read pre-update state + u-weighted current token
        q_t = q * jnp.exp(cum_incl - logw)
        mask = jnp.tril(jnp.ones((C, C), jnp.bool_), k=-1)
    scores = jnp.where(mask, q_t @ k_t.T, 0.0)
    o = scores @ v + q_t @ state
    if bonus_u is not None:
        diag = jnp.sum(q * bonus_u[None, :] * k, axis=-1, keepdims=True)
        o = o + diag * v
    new_state = w_total[:, None] * state + k_flow.T @ v
    return o, new_state


def _kernel_post(q_ref, k_ref, v_ref, w_ref, o_ref, s_ref, state):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    o, new_state = _chunk_math(
        q_ref[0].astype(jnp.float32), k_ref[0].astype(jnp.float32),
        v_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        state[...], None)
    state[...] = new_state
    o_ref[0] = o.astype(o_ref.dtype)
    s_ref[0] = new_state.astype(s_ref.dtype)


def _kernel_bonus(q_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_ref, state):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    o, new_state = _chunk_math(
        q_ref[0].astype(jnp.float32), k_ref[0].astype(jnp.float32),
        v_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        state[...], u_ref[0].astype(jnp.float32))
    state[...] = new_state
    o_ref[0] = o.astype(o_ref.dtype)
    s_ref[0] = new_state.astype(s_ref.dtype)


def gla_pallas(q: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
               u: Optional[jax.Array] = None, *, chunk: int = 64,
               interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """q,k,w: (B,H,T,Dk); v: (B,H,T,Dv); u: (H,Dk) or None.
    Returns (o (B,H,T,Dv), final_state (B,H,Dk,Dv))."""
    B, H, T, Dk = q.shape
    Dv = v.shape[-1]
    assert T % chunk == 0, (T, chunk)
    n = T // chunk
    BH = B * H

    def flat(x):
        return x.reshape(BH, T, x.shape[-1])

    qf, kf, vf, wf = map(flat, (q, k, v, w))

    tile_k = pl.BlockSpec((1, chunk, Dk), lambda i, j: (i, j, 0))
    tile_v = pl.BlockSpec((1, chunk, Dv), lambda i, j: (i, j, 0))
    out_o = pl.BlockSpec((1, chunk, Dv), lambda i, j: (i, j, 0))
    out_s = pl.BlockSpec((1, Dk, Dv), lambda i, j: (i, 0, 0))

    in_specs = [tile_k, tile_k, tile_v, tile_k]
    operands = [qf, kf, vf, wf]
    body = _kernel_post
    if u is not None:
        in_specs.append(pl.BlockSpec((1, Dk), lambda i, j: (i % H, 0)))
        operands.append(u)
        body = _kernel_bonus

    o, s = pl.pallas_call(
        body,
        grid=(BH, n),
        in_specs=in_specs,
        out_specs=[out_o, out_s],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, Dv), v.dtype),
            jax.ShapeDtypeStruct((BH, Dk, Dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((Dk, Dv), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return o.reshape(B, H, T, Dv), s.reshape(B, H, Dk, Dv)


# ---------------------------------------------------------------------------
# SSD-mode kernel (Mamba2): head-shared q/k, per-head scalar decay.
# Grid: (B*H, T/chunk); q/k tiles are indexed by batch only (shared
# across the H grid rows), so HBM reads of B/C happen once per batch, not
# once per head.  The (C,C) L-matrix is built from non-positive cumsum
# differences — stable for any decay, allowing MXU-sized chunks.
# VMEM per step (fp32): q,k 2·C·N + v,o 2·C·P + L,scores 2·C² + state N·P
#   C=64, N=64, P=64: ~0.2 MiB.
# ---------------------------------------------------------------------------

def _ssd_kernel(q_ref, k_ref, v_ref, a_ref, o_ref, s_ref, state):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    q = q_ref[0].astype(jnp.float32)          # (C, N)
    k = k_ref[0].astype(jnp.float32)          # (C, N)
    v = v_ref[0].astype(jnp.float32)          # (C, P)
    a = a_ref[0].astype(jnp.float32)          # (C,)
    C = q.shape[0]

    loga = jnp.log(jnp.maximum(a, 1e-37))
    cum = jnp.cumsum(loga)
    scores = q @ k.T                          # shared-head scores
    diff = cum[:, None] - cum[None, :]
    mask = jnp.tril(jnp.ones((C, C), jnp.bool_))
    L = jnp.where(mask, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
    o = (scores * L) @ v + (q * jnp.exp(cum)[:, None]) @ state[...]
    flow = jnp.exp(cum[-1] - cum)
    new_state = jnp.exp(cum[-1]) * state[...] + (k * flow[:, None]).T @ v
    state[...] = new_state
    o_ref[0] = o.astype(o_ref.dtype)
    s_ref[0] = new_state.astype(s_ref.dtype)


def ssd_pallas(q: jax.Array, k: jax.Array, v: jax.Array, a: jax.Array, *,
               chunk: int = 64, interpret: bool = True):
    """q,k: (B,T,N); v: (B,H,T,P); a: (B,H,T).
    Returns (o (B,H,T,P), final_state (B,H,N,P))."""
    B, T, N = q.shape
    H, P = v.shape[1], v.shape[-1]
    assert T % chunk == 0, (T, chunk)
    n = T // chunk
    BH = B * H
    vf = v.reshape(BH, T, P)
    af = a.reshape(BH, T)

    o, s = pl.pallas_call(
        _ssd_kernel,
        grid=(BH, n),
        in_specs=[
            pl.BlockSpec((1, chunk, N), lambda i, j: (i // H, j, 0)),
            pl.BlockSpec((1, chunk, N), lambda i, j: (i // H, j, 0)),
            pl.BlockSpec((1, chunk, P), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, P), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, N, P), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, P), v.dtype),
            jax.ShapeDtypeStruct((BH, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(q, k, vf, af)
    return o.reshape(B, H, T, P), s.reshape(B, H, N, P)
