from repro.kernels.ssm_scan.ops import gla, gla_decode_step  # noqa: F401
