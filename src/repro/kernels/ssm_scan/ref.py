"""Pure-jnp oracle for the chunked gated-linear-attention (GLA) scan.

Covers both assigned recurrence families:
- Mamba2 / SSD ("post" mode, u=None):   S_t = diag(w_t) S_{t-1} + k_t v_t^T
                                        o_t = q_t S_t
- RWKV-6 ("bonus" mode, u given):       o_t = q_t (S_{t-1} + diag(u) k_t v_t^T)
                                        S_t = diag(w_t) S_{t-1} + k_t v_t^T

Shapes: q,k,w (B,H,T,Dk); v (B,H,T,Dv); u (H,Dk) or None.
w is the per-step multiplicative decay in (0,1].

Numerical contract (enforced by the model layers, see DESIGN.md §6):
``w >= exp(-MAX_LOG_DECAY)`` per step.  The chunked form factors the
intra-chunk pairwise decay as ``(q·exp(cum)) @ (k·exp(-cum))^T``; the
``exp(-cum)`` factor is bounded by ``exp(chunk · MAX_LOG_DECAY)``, which
with chunk=16 and MAX_LOG_DECAY=3.49 stays ~1e24 — safely inside fp32.
The cross-chunk state flow uses only non-positive exponents (stable for
any w).  A per-step decay floor of exp(-3.49)≈0.03 means a 16-step span
decays by ~1e-24 — a full state reset — so the clamp is functionally
inert while guaranteeing finite arithmetic.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# Per-step decay-rate bound: w >= exp(-MAX_LOG_DECAY).  Model layers clamp
# their decay parametrization to honor this (rwkv6 omega, mamba2 dt).
MAX_LOG_DECAY = 3.49


def gla_step(state: jax.Array, q, k, v, w, u=None):
    """Single-token recurrence (decode path). state: (..., Dk, Dv)."""
    kv = k[..., :, None] * v[..., None, :]
    if u is None:
        state = state * w[..., :, None] + kv
        o = jnp.einsum("...k,...kv->...v", q, state)
    else:
        o = jnp.einsum("...k,...kv->...v", q, state + u[..., :, None] * kv)
        state = state * w[..., :, None] + kv
    return state, o


def gla_naive(q, k, v, w, u=None, initial_state=None):
    """Token-by-token recurrence — the ground-truth oracle for tests."""
    B, H, T, Dk = q.shape
    Dv = v.shape[-1]
    s0 = (jnp.zeros((B, H, Dk, Dv), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def body(state, xs):
        qt, kt, vt, wt = xs
        state, o = gla_step(state, qt.astype(jnp.float32), kt.astype(jnp.float32),
                            vt.astype(jnp.float32), wt.astype(jnp.float32),
                            None if u is None else u.astype(jnp.float32))
        return state, o

    xs = tuple(jnp.moveaxis(a, 2, 0) for a in (q, k, v, w))
    s_final, o = jax.lax.scan(body, s0, xs)
    return jnp.moveaxis(o, 0, 2).astype(v.dtype), s_final


def gla_chunked_ref(q: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                    u: Optional[jax.Array] = None, chunk: int = 64,
                    initial_state: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """Chunked-parallel scan: intra-chunk work is dense matmul (MXU food),
    inter-chunk carries the (Dk,Dv) state.  Returns (o, final_state)."""
    B, H, T, Dk = q.shape
    Dv = v.shape[-1]
    assert T % chunk == 0, (T, chunk)
    n = T // chunk
    f32 = jnp.float32

    qc = q.reshape(B, H, n, chunk, Dk).astype(f32)
    kc = k.reshape(B, H, n, chunk, Dk).astype(f32)
    vc = v.reshape(B, H, n, chunk, Dv).astype(f32)
    wc = w.reshape(B, H, n, chunk, Dk).astype(f32)

    logw = jnp.log(jnp.maximum(wc, 1e-22))
    cum_incl = jnp.cumsum(logw, axis=-2)              # prod_{i<=t} w_i
    cum_excl = cum_incl - logw                        # prod_{i<t}  w_i
    w_total = jnp.exp(cum_incl[..., -1, :])           # (B,H,n,Dk)

    # intra-chunk pairing: bounded by the decay contract (see module doc)
    k_tilde = kc * jnp.exp(-cum_incl)
    # cross-chunk flow: exponent cum_last - cum <= 0 — stable for any w
    k_flow = kc * jnp.exp(cum_incl[..., -1:, :] - cum_incl)
    if u is None:  # post mode
        q_tilde = qc * jnp.exp(cum_incl)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    else:          # bonus mode
        q_tilde = qc * jnp.exp(cum_excl)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)

    scores = jnp.einsum("bhntk,bhnsk->bhnts", q_tilde, k_tilde)
    scores = jnp.where(mask, scores, 0.0)
    o_intra = jnp.einsum("bhnts,bhnsv->bhntv", scores, vc)
    if u is not None:
        diag = jnp.einsum("bhntk,hk,bhntk->bhnt", qc, u.astype(f32), kc)
        o_intra = o_intra + diag[..., None] * vc

    ks_v = jnp.einsum("bhnsk,bhnsv->bhnkv", k_flow, vc)  # chunk kv summary

    if initial_state is None:
        s0 = jnp.zeros((B, H, Dk, Dv), f32)
    else:
        s0 = initial_state.astype(f32)

    def body(state, xs):
        q_t, wtot, kv_sum = xs  # (B,H,chunk,Dk), (B,H,Dk), (B,H,Dk,Dv)
        o_inter = jnp.einsum("bhtk,bhkv->bhtv", q_t, state)
        state = wtot[..., :, None] * state + kv_sum
        return state, o_inter

    xs = (jnp.moveaxis(q_tilde, 2, 0), jnp.moveaxis(w_total, 2, 0),
          jnp.moveaxis(ks_v, 2, 0))
    s_final, o_inter = jax.lax.scan(body, s0, xs)
    o = o_intra + jnp.moveaxis(o_inter, 0, 2)
    return o.reshape(B, H, T, Dv).astype(v.dtype), s_final


# ---------------------------------------------------------------------------
# SSD mode (Mamba2): B/C shared across heads, per-head SCALAR decay.
#
# The generic GLA path above broadcasts q/k/w to every head — an H-fold
# (64x for zamba2) materialization of (B,H,T,N) tensors that made the
# zamba2 train cell the worst roofline fraction of the sweep (0.09%).
# The SSD structure avoids it: scores q@k^T are computed ONCE (shared),
# the per-head decay enters as the (C,C) L-matrix (exp of non-positive
# cumsum differences — unconditionally stable, so chunks can be large),
# and all per-head products are 3-operand einsums that never materialize
# head-broadcast copies.
# ---------------------------------------------------------------------------

def ssd_step(state: jax.Array, q, k, v, a):
    """Single-token SSD update. state: (B,H,N,P); q,k: (B,N); v: (B,H,P);
    a: (B,H) scalar decay."""
    kv = jnp.einsum("bn,bhp->bhnp", k, v)
    state = state * a[..., None, None] + kv
    o = jnp.einsum("bn,bhnp->bhp", q, state)
    return state, o


def ssd_naive(q, k, v, a, initial_state=None):
    """Token-by-token oracle. q,k: (B,T,N); v: (B,H,T,P); a: (B,H,T)."""
    B, T, N = q.shape
    H, P = v.shape[1], v.shape[-1]
    s0 = (jnp.zeros((B, H, N, P), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def body(state, xs):
        qt, kt, vt, at = xs
        state, o = ssd_step(state, qt.astype(jnp.float32),
                            kt.astype(jnp.float32),
                            vt.astype(jnp.float32),
                            at.astype(jnp.float32))
        return state, o

    xs = (jnp.moveaxis(q, 1, 0), jnp.moveaxis(k, 1, 0),
          jnp.moveaxis(v, 2, 0), jnp.moveaxis(a, 2, 0))
    s_final, o = jax.lax.scan(body, s0, xs)
    return jnp.moveaxis(o, 0, 2).astype(v.dtype), s_final


def ssd_chunked_ref(q, k, v, a, chunk: int = 64, initial_state=None):
    """Chunked SSD scan. q,k: (B,T,N); v: (B,H,T,P); a: (B,H,T) in (0,1].
    Returns (o (B,H,T,P), final_state (B,H,N,P))."""
    B, T, N = q.shape
    H, P = v.shape[1], v.shape[-1]
    assert T % chunk == 0, (T, chunk)
    n = T // chunk
    f32 = jnp.float32

    qc = q.reshape(B, n, chunk, N).astype(f32)
    kc = k.reshape(B, n, chunk, N).astype(f32)
    vc = v.reshape(B, H, n, chunk, P).astype(f32)
    ac = a.reshape(B, H, n, chunk).astype(f32)

    loga = jnp.log(jnp.maximum(ac, 1e-37))
    cum = jnp.cumsum(loga, axis=-1)                       # (B,H,n,C)
    a_total = jnp.exp(cum[..., -1])                       # (B,H,n)

    # shared scores, computed once for all heads
    scores = jnp.einsum("bntk,bnsk->bnts", qc, kc)        # (B,n,C,C)
    # per-head decay L-matrix: exp of NON-POSITIVE differences (stable)
    diff = cum[..., :, None] - cum[..., None, :]          # (B,H,n,C,C)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(mask, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
    o_intra = jnp.einsum("bnts,bhnts,bhnsp->bhntp", scores, L, vc)

    # chunk kv summary with end-of-chunk decay (exponent <= 0)
    flow = jnp.exp(cum[..., -1:] - cum)                   # (B,H,n,C)
    kv_sum = jnp.einsum("bnsk,bhns,bhnsp->bhnkp", kc, flow, vc)

    if initial_state is None:
        s0 = jnp.zeros((B, H, N, P), f32)
    else:
        s0 = initial_state.astype(f32)

    q_in = jnp.exp(cum)                                   # (B,H,n,C)

    def body(state, xs):
        q_t, qin, atot, kvs = xs
        o_inter = jnp.einsum("btk,bht,bhkp->bhtp", q_t, qin, state)
        state = atot[..., None, None] * state + kvs
        return state, o_inter

    xs = (jnp.moveaxis(qc, 1, 0), jnp.moveaxis(q_in, 2, 0),
          jnp.moveaxis(a_total, 2, 0), jnp.moveaxis(kv_sum, 2, 0))
    s_final, o_inter = jax.lax.scan(body, s0, xs)
    o = o_intra + jnp.moveaxis(o_inter, 0, 2)
    return o.reshape(B, H, T, P).astype(v.dtype), s_final
