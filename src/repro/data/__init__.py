from repro.data.causal_dgp import CausalData, make_causal_data  # noqa: F401
from repro.data.lm_data import lm_batch_stream, synthetic_tokens  # noqa: F401
from repro.data.pipeline import ShardedFeed  # noqa: F401
