"""Sharded host feed with double-buffered prefetch.

The Ray-object-store translation (DESIGN.md §2): instead of a shared
plasma store, each host materializes only its shard of every batch and
``jax.device_put``s it under the batch NamedSharding; a background thread
keeps ``depth`` batches in flight so host generation overlaps device
compute.  Lineage is deterministic: batch s is a pure function of
(base_key, s), so checkpoint-restart at step s replays identically.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class ShardedFeed:
    """Wraps a (step -> host batch) function into a prefetching iterator
    of device-resident, sharding-constrained batches."""

    def __init__(self, make_batch: Callable[[int], Dict[str, jax.Array]],
                 sharding: Optional[NamedSharding] = None,
                 start_step: int = 0, depth: int = 2):
        self._make_batch = make_batch
        self._sharding = sharding
        self._step = start_step
        self._depth = depth
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _place(self, batch):
        if self._sharding is None:
            return batch
        return jax.tree_util.tree_map(
            lambda x: jax.device_put(x, self._sharding), batch)

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            try:
                b = self._place(self._make_batch(step))
            except Exception as e:  # surface generation errors to consumer
                self._q.put(e)
                return
            self._q.put((step, b))
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        item = self._q.get()
        if isinstance(item, Exception):
            raise item
        step, batch = item
        self._step = step + 1
        return batch

    @property
    def step(self) -> int:
        """Next step the consumer will receive (checkpoint this)."""
        return self._step

    def close(self):
        self._stop.set()
        # drain so the worker's blocked put() releases
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5)


def batch_sharding(mesh: Mesh, multi_pod: bool = False) -> NamedSharding:
    """Batch-dim sharding over the DP axes of the production mesh."""
    dp = ("pod", "data") if multi_pod and "pod" in mesh.axis_names else "data"
    return NamedSharding(mesh, P(dp))
