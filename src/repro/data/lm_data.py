"""Synthetic token streams for LM substrate training.

The stream is a noisy affine bigram process: with probability ``1-eps``
the next token is ``(a·t + c) mod V``, else uniform.  It is (i) fully
deterministic in (key, step) — restart-safe lineage, (ii) learnable, so
the end-to-end train driver shows a real loss curve (floor ≈
eps·ln V + H(eps)), and (iii) generated on-host in O(batch) with no I/O.
"""
from __future__ import annotations

from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np

A_MULT = 5
C_ADD = 13
EPS_NOISE = 0.2


def synthetic_tokens(key: jax.Array, batch: int, seq_len: int,
                     vocab_size: int) -> jax.Array:
    """(batch, seq_len+1) int32 — one extra position to split into
    (inputs, labels) without a second sample."""
    k0, kn, ku = jax.random.split(key, 3)
    t0 = jax.random.randint(k0, (batch,), 0, vocab_size)
    noise_mask = jax.random.bernoulli(kn, EPS_NOISE, (batch, seq_len))
    uniform = jax.random.randint(ku, (batch, seq_len), 0, vocab_size)

    def step(t, xs):
        noisy, unif = xs
        nxt = jnp.where(noisy, unif, (A_MULT * t + C_ADD) % vocab_size)
        return nxt, nxt

    _, rest = jax.lax.scan(step, t0, (noise_mask.T, uniform.T))
    return jnp.concatenate([t0[:, None], rest.T], axis=1).astype(jnp.int32)


def lm_batch(key: jax.Array, batch: int, seq_len: int, vocab_size: int
             ) -> Dict[str, jax.Array]:
    toks = synthetic_tokens(key, batch, seq_len, vocab_size)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def lm_batch_stream(key: jax.Array, batch: int, seq_len: int,
                    vocab_size: int, start_step: int = 0
                    ) -> Iterator[Dict[str, jax.Array]]:
    """Deterministic (step -> batch) stream; resuming at step s replays
    the identical data a fresh run would have seen at step s."""
    step = start_step
    while True:
        yield lm_batch(jax.random.fold_in(key, step), batch, seq_len,
                       vocab_size)
        step += 1


def bigram_ce_floor(vocab_size: int) -> float:
    """Analytic CE floor of the stream (nats/token)."""
    e = EPS_NOISE
    # H = -(1-e+e/V)·ln(1-e+e/V) - (V-1)·(e/V)·ln(e/V)
    p_hit = (1 - e) + e / vocab_size
    p_other = e / vocab_size
    return float(-(p_hit * np.log(p_hit)
                   + (vocab_size - 1) * p_other * np.log(p_other)))
