"""Synthetic causal data generator — the paper's §5.3 setup.

Mirrors the dowhy ``datasets.linear_dataset`` family (the paper cites
https://github.com/py-why/dowhy/blob/main/dowhy/datasets.py): Gaussian
confounders, a logistic treatment-assignment mechanism, and a (partially)
linear outcome with known ground-truth effect — so estimator tests can
assert ATE/CATE recovery, which EconML-vs-paper comparisons rely on.

All generation is pure-functional in the PRNG key: shard s of the data is
derived by folding s into the key, so a 256-host pipeline generates its
rows independently and deterministically (checkpoint-restart replays the
same data — the SPMD translation of Ray's lineage).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CausalData:
    """One synthetic observational study with known ground truth."""

    X: jax.Array          # (n, p) confounders
    t: jax.Array          # (n,) treatment (binary 0/1 or continuous)
    y: jax.Array          # (n,) outcome
    true_ate: float       # ground-truth average treatment effect
    true_cate: jax.Array  # (n,) ground-truth theta(x_i)
    propensity: jax.Array  # (n,) P(T=1|X) (binary t only)

    @property
    def n(self) -> int:
        return self.X.shape[0]

    @property
    def p(self) -> int:
        return self.X.shape[1]


def make_causal_data(key: jax.Array, n: int, p: int, *,
                     discrete_treatment: bool = True,
                     heterogeneous: bool = False,
                     effect: float = 1.0,
                     confounding_strength: float = 1.0,
                     noise: float = 1.0,
                     n_effect_modifiers: int = 1,
                     dtype=jnp.float32) -> CausalData:
    """Partially-linear DGP:

        X ~ N(0, I_p)
        T ~ Bernoulli(sigmoid(c · <a, X>))          (binary)
        theta(x) = effect                            (homogeneous)
                 = effect · (1 + 0.5·x_0 [+ ...])    (heterogeneous)
        Y = theta(X)·T + <b, X> + eps

    The paper's §5.1 demo is exactly the heterogeneous variant with one
    effect modifier: y = (1 + .5·x0)·T + x0 + N(0,1).
    """
    kx, ka, kb, kt, ke = jax.random.split(key, 5)
    X = jax.random.normal(kx, (n, p), dtype)

    # sparse-ish confounding: first ~10 covariates drive T and Y
    live = min(p, 10)
    a = jnp.zeros((p,), dtype).at[:live].set(
        jax.random.normal(ka, (live,), dtype) / jnp.sqrt(live))
    b = jnp.zeros((p,), dtype).at[:live].set(
        jax.random.normal(kb, (live,), dtype))

    logits = confounding_strength * (X @ a)
    prop = jax.nn.sigmoid(logits)
    if discrete_treatment:
        t = jax.random.bernoulli(kt, prop).astype(dtype)
    else:
        t = logits + jax.random.normal(kt, (n,), dtype)

    if heterogeneous:
        mods = X[:, :n_effect_modifiers]
        cate = effect * (1.0 + 0.5 * mods.sum(axis=-1))
    else:
        cate = jnp.full((n,), effect, dtype)

    eps = noise * jax.random.normal(ke, (n,), dtype)
    y = cate * t + X @ b + eps
    true_ate = float(effect) if not heterogeneous else float(cate.mean())
    return CausalData(X=X, t=t, y=y, true_ate=true_ate, true_cate=cate,
                      propensity=prop)


def make_sharded_causal_data(key: jax.Array, n: int, p: int, n_shards: int,
                             shard: int, **kw) -> CausalData:
    """Rows for one host shard; the union over shards equals one global
    deterministic dataset (per-shard key lineage)."""
    assert n % n_shards == 0, (n, n_shards)
    return make_causal_data(jax.random.fold_in(key, shard), n // n_shards,
                            p, **kw)


def paper_demo_data(key: jax.Array, n: int = 100_000, p: int = 500
                    ) -> CausalData:
    """The exact §5.1 listing: y = (1 + .5·x0)·T + x0 + N(0,1),
    T ~ Bern(expit(x0)), X ~ N(0, I_500)."""
    kx, kt, ke = jax.random.split(key, 3)
    X = jax.random.normal(kx, (n, p))
    prop = jax.nn.sigmoid(X[:, 0])
    t = jax.random.bernoulli(kt, prop).astype(jnp.float32)
    cate = 1.0 + 0.5 * X[:, 0]
    y = cate * t + X[:, 0] + jax.random.normal(ke, (n,))
    return CausalData(X=X, t=t, y=y, true_ate=1.0, true_cate=cate,
                      propensity=prop)
