"""Synthetic causal data generator — the paper's §5.3 setup.

Mirrors the dowhy ``datasets.linear_dataset`` family (the paper cites
https://github.com/py-why/dowhy/blob/main/dowhy/datasets.py): Gaussian
confounders, a logistic treatment-assignment mechanism, and a (partially)
linear outcome with known ground-truth effect — so estimator tests can
assert ATE/CATE recovery, which EconML-vs-paper comparisons rely on.

All generation is pure-functional in the PRNG key: shard s of the data is
derived by folding s into the key, so a 256-host pipeline generates its
rows independently and deterministically (checkpoint-restart replays the
same data — the SPMD translation of Ray's lineage).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CausalData:
    """One synthetic observational study with known ground truth."""

    X: jax.Array          # (n, p) confounders
    t: jax.Array          # (n,) treatment (binary 0/1 or continuous)
    y: jax.Array          # (n,) outcome
    true_ate: float       # ground-truth average treatment effect
    true_cate: jax.Array  # (n,) ground-truth theta(x_i)
    propensity: jax.Array  # (n,) P(T=1|X) (binary t only)

    @property
    def n(self) -> int:
        return self.X.shape[0]

    @property
    def p(self) -> int:
        return self.X.shape[1]


def make_causal_data(key: jax.Array, n: int, p: int, *,
                     discrete_treatment: bool = True,
                     heterogeneous: bool = False,
                     effect: float = 1.0,
                     confounding_strength: float = 1.0,
                     noise: float = 1.0,
                     n_effect_modifiers: int = 1,
                     dtype=jnp.float32) -> CausalData:
    """Partially-linear DGP:

        X ~ N(0, I_p)
        T ~ Bernoulli(sigmoid(c · <a, X>))          (binary)
        theta(x) = effect                            (homogeneous)
                 = effect · (1 + 0.5·x_0 [+ ...])    (heterogeneous)
        Y = theta(X)·T + <b, X> + eps

    The paper's §5.1 demo is exactly the heterogeneous variant with one
    effect modifier: y = (1 + .5·x0)·T + x0 + N(0,1).
    """
    kx, ka, kb, kt, ke = jax.random.split(key, 5)
    X = jax.random.normal(kx, (n, p), dtype)

    # sparse-ish confounding: first ~10 covariates drive T and Y
    live = min(p, 10)
    a = jnp.zeros((p,), dtype).at[:live].set(
        jax.random.normal(ka, (live,), dtype) / jnp.sqrt(live))
    b = jnp.zeros((p,), dtype).at[:live].set(
        jax.random.normal(kb, (live,), dtype))

    logits = confounding_strength * (X @ a)
    prop = jax.nn.sigmoid(logits)
    if discrete_treatment:
        t = jax.random.bernoulli(kt, prop).astype(dtype)
    else:
        t = logits + jax.random.normal(kt, (n,), dtype)

    if heterogeneous:
        mods = X[:, :n_effect_modifiers]
        cate = effect * (1.0 + 0.5 * mods.sum(axis=-1))
    else:
        cate = jnp.full((n,), effect, dtype)

    eps = noise * jax.random.normal(ke, (n,), dtype)
    y = cate * t + X @ b + eps
    true_ate = float(effect) if not heterogeneous else float(cate.mean())
    return CausalData(X=X, t=t, y=y, true_ate=true_ate, true_cate=cate,
                      propensity=prop)


def make_sharded_causal_data(key: jax.Array, n: int, p: int, n_shards: int,
                             shard: int, **kw) -> CausalData:
    """Rows for one host shard; the union over shards equals one global
    deterministic dataset (per-shard key lineage)."""
    assert n % n_shards == 0, (n, n_shards)
    return make_causal_data(jax.random.fold_in(key, shard), n // n_shards,
                            p, **kw)


# ---------------------------------------------------------------------------
# Instrumental-variable DGPs (repro.core.iv): unobserved confounding
# breaks plain DML; a randomized instrument with known compliance
# structure identifies the LATE.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class IVData:
    """One synthetic IV study with known LATE ground truth.

    Binary-instrument design: Z ~ Bern(sigmoid(c·<a, X>)); complier
    status C ~ Bern(compliance) i.i.d. (independent of X and the
    unobserved confounder U, so LATE = E[θ(X) | C=1] = E[θ(X)]);
    compliers take T = Z, noncompliers take T = Bern(sigmoid(γ·U)) —
    always/never-takers driven by the CONFOUNDER, which is what biases
    the naive (non-IV) estimate.  Y = θ(X)·T + <b, X> + γ·U + ε.
    Exclusion holds by construction (Z never enters Y directly) and
    monotonicity holds (noncompliers ignore Z)."""

    X: jax.Array            # (n, p) observed covariates
    z: jax.Array            # (n,) instrument (binary 0/1 or continuous)
    t: jax.Array            # (n,) treatment
    y: jax.Array            # (n,) outcome
    true_late: float        # ground-truth LATE (complier effect)
    true_cate: jax.Array    # (n,) θ(x_i)
    complier: jax.Array     # (n,) complier indicator (binary designs)
    instrument_propensity: jax.Array  # (n,) P(Z=1|X)

    @property
    def n(self) -> int:
        return self.X.shape[0]

    @property
    def p(self) -> int:
        return self.X.shape[1]


def make_iv_data(key: jax.Array, n: int, p: int, *,
                 effect: float = 1.0,
                 compliance: float = 0.7,
                 heterogeneous: bool = False,
                 confounding_strength: float = 1.0,
                 instrument_strength: float = 1.0,
                 noise: float = 1.0,
                 discrete_instrument: bool = True,
                 n_effect_modifiers: int = 1,
                 dtype=jnp.float32) -> IVData:
    """Compliance IV DGP with closed-form LATE.

    discrete_instrument=True  the encouragement design documented on
                              IVData (binary Z, binary T, LATE =
                              E[θ(X)] because complier status is
                              independent of X).
    discrete_instrument=False continuous Z = <a,X> + N(0,1) and
                              continuous T = compliance·Z + γ·U + ν —
                              the partially-linear IV model whose 2SLS
                              estimand is E[θ(X)] exactly.
    """
    kx, ka, kb, kz, kc, kd, ku, ke, kt = jax.random.split(key, 9)
    X = jax.random.normal(kx, (n, p), dtype)
    live = min(p, 10)
    a = jnp.zeros((p,), dtype).at[:live].set(
        jax.random.normal(ka, (live,), dtype) / jnp.sqrt(live))
    b = jnp.zeros((p,), dtype).at[:live].set(
        jax.random.normal(kb, (live,), dtype))
    U = jax.random.normal(ku, (n,), dtype)      # unobserved confounder

    if heterogeneous:
        mods = X[:, :n_effect_modifiers]
        cate = effect * (1.0 + 0.5 * mods.sum(axis=-1))
    else:
        cate = jnp.full((n,), effect, dtype)

    if discrete_instrument:
        prop_z = jax.nn.sigmoid(instrument_strength * (X @ a))
        z = jax.random.bernoulli(kz, prop_z).astype(dtype)
        complier = jax.random.bernoulli(kc, compliance, (n,)).astype(dtype)
        d_nc = jax.random.bernoulli(
            kd, jax.nn.sigmoid(confounding_strength * U)).astype(dtype)
        t = complier * z + (1.0 - complier) * d_nc
        # C ⊥ (X, U) ⇒ LATE = E[θ(X) | C=1] = E[θ(X)]
        true_late = float(effect) if not heterogeneous else float(cate.mean())
    else:
        z = X @ a + jax.random.normal(kz, (n,), dtype)
        prop_z = jnp.zeros((n,), dtype)
        complier = jnp.ones((n,), dtype)
        t = (compliance * z + confounding_strength * U
             + jax.random.normal(kt, (n,), dtype))
        true_late = float(effect) if not heterogeneous else float(cate.mean())

    eps = noise * jax.random.normal(ke, (n,), dtype)
    y = cate * t + X @ b + confounding_strength * U + eps
    return IVData(X=X, z=z, t=t, y=y, true_late=true_late,
                  true_cate=cate, complier=complier,
                  instrument_propensity=prop_z)


def paper_demo_data(key: jax.Array, n: int = 100_000, p: int = 500
                    ) -> CausalData:
    """The exact §5.1 listing: y = (1 + .5·x0)·T + x0 + N(0,1),
    T ~ Bern(expit(x0)), X ~ N(0, I_500)."""
    kx, kt, ke = jax.random.split(key, 3)
    X = jax.random.normal(kx, (n, p))
    prop = jax.nn.sigmoid(X[:, 0])
    t = jax.random.bernoulli(kt, prop).astype(jnp.float32)
    cate = 1.0 + 0.5 * X[:, 0]
    y = cate * t + X[:, 0] + jax.random.normal(ke, (n,))
    return CausalData(X=X, t=t, y=y, true_ate=1.0, true_cate=cate,
                      propensity=prop)
