"""Mamba2 (SSD) block — the zamba2 hybrid backbone.

Maps the selective-state-space recurrence onto the shared chunked GLA
kernel (repro.kernels.ssm_scan):  q=C, k=B, v=dt*x, per-head scalar decay
a_t = exp(-exp(A_log)*dt_t) broadcast over the state dim ("post" mode).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.sharding import ParamDef, constrain
from repro.kernels.ssm_scan import ops as scan_ops
from repro.kernels.ssm_scan.ref import MAX_LOG_DECAY

MAMBA_HEADDIM = 64


def _dims(cfg: ModelConfig):
    di = cfg.ssm_expand * cfg.d_model
    heads = max(1, di // MAMBA_HEADDIM)
    hd = di // heads
    return di, heads, hd


def mamba_schema(cfg: ModelConfig):
    d, s = cfg.d_model, cfg.ssm_state
    di, heads, _ = _dims(cfg)
    proj_out = 2 * di + 2 * s + heads
    return {
        "in_proj": ParamDef((d, proj_out), ("embed", "inner"), init="scaled"),
        "conv_w": ParamDef((cfg.ssm_conv, di), (None, "inner"), init="scaled",
                           scale=1.0),
        "conv_b": ParamDef((di,), (None,), init="zeros"),
        "A_log": ParamDef((heads,), (None,), init="zeros"),
        "dt_bias": ParamDef((heads,), (None,), init="zeros"),
        "D": ParamDef((heads,), (None,), init="ones"),
        "norm": ParamDef((di,), (None,), init="ones"),
        "out_proj": ParamDef((di, d), ("inner", "embed"), init="scaled"),
    }


def _split_proj(cfg, proj):
    di, heads, _ = _dims(cfg)
    s = cfg.ssm_state
    z, xb, B, C, dt = jnp.split(proj, [di, 2 * di, 2 * di + s, 2 * di + 2 * s],
                                axis=-1)
    return z, xb, B, C, dt


def _causal_conv(xb, w, b, state=None):
    """Depthwise causal conv. xb: (B,T,di); w: (K,di). state: (B,K-1,di)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((xb.shape[0], K - 1, xb.shape[2]), xb.dtype)
    else:
        pad = state.astype(xb.dtype)
    xp = jnp.concatenate([pad, xb], axis=1)
    out = sum(xp[:, i:i + xb.shape[1]] * w[i][None, None] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else None
    return out + b[None, None], new_state


def _ssd_inputs(cfg, params, xb, B, C, dt):
    """Build SSD operands: q,k (B,T,N) HEAD-SHARED, v (B,H,T,P),
    a (B,H,T) scalar decay.  Broadcasting B/C/decay to every head (the
    old GLA mapping) materialized H-fold copies of (B,T,N) — 64x for
    zamba2 — and made its train cell the sweep's worst roofline fraction;
    the SSD-structured path keeps them shared (see ssm_scan.ref)."""
    di, heads, hd = _dims(cfg)
    Bsz, T, _ = xb.shape
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    # decay-rate bound: rate = exp(A_log)*dt clamped to MAX_LOG_DECAY per
    # step (a 16-step span then decays by ~1e-24 — a full reset), keeping
    # the chunked scan's exp factors finite (kernel contract).
    rate = jnp.minimum(jnp.exp(params["A_log"].astype(jnp.float32)) * dt,
                       MAX_LOG_DECAY)
    a = jnp.exp(-rate)  # (B,T,H)
    v = xb.reshape(Bsz, T, heads, hd) * dt[..., None].astype(xb.dtype)
    v = v.transpose(0, 2, 1, 3).astype(jnp.float32)      # (B,H,T,P)
    return (C.astype(jnp.float32), B.astype(jnp.float32), v,
            a.transpose(0, 2, 1))                        # q,k,(B,H,T)


def _gated_out(cfg, params, y, z, rules):
    di, heads, hd = _dims(cfg)
    Bsz, T = z.shape[:2]
    y = y.reshape(Bsz, T, di).astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + cfg.norm_eps) * params["norm"].astype(jnp.float32)
    out = jnp.einsum("btd,de->bte", y.astype(cfg.compute_dtype),
                     params["out_proj"].astype(cfg.compute_dtype))
    return constrain(out, ("batch", "seq", "embed_act"), rules)


def mamba_train(params, cfg: ModelConfig, x: jax.Array, rules=None) -> jax.Array:
    ct = cfg.compute_dtype
    di, heads, hd = _dims(cfg)
    proj = jnp.einsum("btd,dp->btp", x, params["in_proj"].astype(ct))
    z, xb, B, C, dt = _split_proj(cfg, proj)
    xb, _ = _causal_conv(xb, params["conv_w"].astype(ct), params["conv_b"].astype(ct))
    xb = jax.nn.silu(xb)
    q, k, v, a = _ssd_inputs(cfg, params, xb, B, C, dt)
    o, _ = scan_ops.ssd(q, k, v, a, chunk=max(cfg.ssm_chunk, 32))
    o = o.transpose(0, 2, 1, 3)  # (B,T,H,hd)
    o = o + params["D"].astype(jnp.float32)[None, None, :, None] * \
        xb.reshape(*xb.shape[:2], heads, hd).astype(jnp.float32)
    return _gated_out(cfg, params, o, z, rules)


def mamba_prefill(params, cfg: ModelConfig, x: jax.Array, rules=None
                  ) -> Tuple[jax.Array, Dict]:
    """Like mamba_train, but also returns the recurrent state after the
    last token (for serving: prefill -> decode handoff)."""
    ct = cfg.compute_dtype
    di, heads, hd = _dims(cfg)
    proj = jnp.einsum("btd,dp->btp", x, params["in_proj"].astype(ct))
    z, xb, B, C, dt = _split_proj(cfg, proj)
    xb, conv_state = _causal_conv(xb, params["conv_w"].astype(ct),
                                  params["conv_b"].astype(ct))
    xb = jax.nn.silu(xb)
    q, k, v, a = _ssd_inputs(cfg, params, xb, B, C, dt)
    o, ssm_state = scan_ops.ssd(q, k, v, a, chunk=max(cfg.ssm_chunk, 32))
    o = o.transpose(0, 2, 1, 3)
    o = o + params["D"].astype(jnp.float32)[None, None, :, None] * \
        xb.reshape(*xb.shape[:2], heads, hd).astype(jnp.float32)
    out = _gated_out(cfg, params, o, z, rules)
    return out, {"ssm": ssm_state, "conv": conv_state}


def mamba_init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    di, heads, hd = _dims(cfg)
    return {
        "ssm": jnp.zeros((batch, heads, cfg.ssm_state, hd), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype),
    }


def mamba_decode(params, cfg: ModelConfig, x: jax.Array, state: Dict,
                 rules=None) -> Tuple[jax.Array, Dict]:
    """x: (B,1,d). O(1) state update — the long_500k win for hybrids."""
    ct = cfg.compute_dtype
    di, heads, hd = _dims(cfg)
    proj = jnp.einsum("btd,dp->btp", x, params["in_proj"].astype(ct))
    z, xb, B, C, dt = _split_proj(cfg, proj)
    xb, conv_state = _causal_conv(xb, params["conv_w"].astype(ct),
                                  params["conv_b"].astype(ct), state["conv"])
    xb = jax.nn.silu(xb)
    q, k, v, a = _ssd_inputs(cfg, params, xb, B, C, dt)
    new_ssm, o = scan_ops.ssd_decode_step(
        state["ssm"], q[:, 0], k[:, 0], v[:, :, 0], a[:, :, 0])
    o = o[:, :, None].transpose(0, 2, 1, 3)  # (B,1,H,hd)
    o = o + params["D"].astype(jnp.float32)[None, None, :, None] * \
        xb.reshape(xb.shape[0], 1, heads, hd).astype(jnp.float32)
    out = _gated_out(cfg, params, o, z, rules)
    return out, {"ssm": new_ssm, "conv": conv_state}
