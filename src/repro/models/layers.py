"""Shared layers: norms, embeddings, RoPE, MLPs.

Every layer is a pair of (schema fn, apply fn).  Schemas are ParamDef
trees (see repro.distributed.sharding); apply fns are pure.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.sharding import ParamDef, constrain


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_schema(d: int):
    return {"scale": ParamDef((d,), (None,), init="ones")}


def rmsnorm(params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dtype)


def layernorm_schema(d: int):
    return {"scale": ParamDef((d,), (None,), init="ones"),
            "bias": ParamDef((d,), (None,), init="zeros")}


def layernorm(params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(dtype)


def make_norm(cfg: ModelConfig):
    if cfg.family == "audio":
        return layernorm_schema, lambda p, x: layernorm(p, x, cfg.norm_eps)
    return rmsnorm_schema, lambda p, x: rmsnorm(p, x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------

def embedding_schema(cfg: ModelConfig):
    sch = {"embedding": ParamDef((cfg.padded_vocab, cfg.d_model),
                                 ("vocab", "embed"), init="embed")}
    if not cfg.tie_embeddings:
        sch["unembed"] = ParamDef((cfg.d_model, cfg.padded_vocab),
                                  ("embed", "vocab"), init="scaled")
    if cfg.learned_pos_emb:
        sch["pos"] = ParamDef((cfg.max_position_embeddings, cfg.d_model),
                              (None, "embed"), init="embed")
    return sch


def embed_tokens(params, cfg: ModelConfig, tokens: jax.Array, rules=None,
                 pos_offset: int = 0) -> jax.Array:
    x = jnp.take(params["embedding"], tokens, axis=0).astype(cfg.compute_dtype)
    if cfg.learned_pos_emb:
        pos = params["pos"][pos_offset:pos_offset + tokens.shape[-1]]
        x = x + pos.astype(cfg.compute_dtype)
    return constrain(x, ("batch", "seq", "embed_act"), rules)


def unembed(params, cfg: ModelConfig, x: jax.Array, rules=None) -> jax.Array:
    if cfg.tie_embeddings:
        w = params["embedding"].T
    else:
        w = params["unembed"]
    logits = jnp.einsum("...d,dv->...v", x, w.astype(cfg.compute_dtype))
    if cfg.logits_softcap:
        c = cfg.logits_softcap
        logits = jnp.tanh(logits / c) * c
    if cfg.padded_vocab != cfg.vocab_size:  # exact CE: pad slots -> -inf
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    # vocab claims "model"; the seq dim of logits stays unsharded so the
    # (B,S,V) fp32 CE buffer shards over batch x vocab (memory-critical)
    return constrain(logits, ("batch", "logits_seq", "vocab"), rules)


# ---------------------------------------------------------------------------
# RoPE (full / partial fraction / interleaved GLM-style)
# ---------------------------------------------------------------------------

def rope_frequencies(cfg: ModelConfig, positions: jax.Array,
                     head_dim: Optional[int] = None):
    """Return (sin, cos) of shape positions.shape + (rot_dim/2,)."""
    hd = head_dim if head_dim is not None else cfg.head_dim
    rot = int(hd * cfg.rope_fraction)
    rot -= rot % 2
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array,
               interleaved: bool = False) -> jax.Array:
    """x: (..., heads, head_dim); sin/cos: broadcastable (..., rot/2)."""
    rot = 2 * sin.shape[-1]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    sin = sin[..., None, :]  # add head axis
    cos = cos[..., None, :]
    if interleaved:  # GLM / GPT-J pairing: (x0,x1),(x2,x3),...
        x1 = x_rot[..., 0::2]
        x2 = x_rot[..., 1::2]
        r1 = x1 * cos - x2 * sin
        r2 = x2 * cos + x1 * sin
        out = jnp.stack([r1, r2], axis=-1).reshape(x_rot.shape)
    else:  # NeoX pairing: first half / second half
        half = rot // 2
        x1, x2 = x_rot[..., :half], x_rot[..., half:]
        r1 = x1 * cos - x2 * sin
        r2 = x2 * cos + x1 * sin
        out = jnp.concatenate([r1, r2], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1) if rot < x.shape[-1] else out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_schema(cfg: ModelConfig, d_ff: Optional[int] = None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    if cfg.mlp == "swiglu":
        return {
            "wi_gate": ParamDef((d, ff), ("embed", "ff"), init="scaled"),
            "wi_up": ParamDef((d, ff), ("embed", "ff"), init="scaled"),
            "wo": ParamDef((ff, d), ("ff", "embed"), init="scaled"),
        }
    return {
        "wi": ParamDef((d, ff), ("embed", "ff"), init="scaled"),
        "wo": ParamDef((ff, d), ("ff", "embed"), init="scaled"),
    }


def mlp_apply(params, cfg: ModelConfig, x: jax.Array, rules=None) -> jax.Array:
    ct = cfg.compute_dtype
    if cfg.mlp == "swiglu":
        g = jnp.einsum("...d,df->...f", x, params["wi_gate"].astype(ct))
        u = jnp.einsum("...d,df->...f", x, params["wi_up"].astype(ct))
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, params["wi"].astype(ct)))
    h = constrain(h, ("batch", "seq", "ff"), rules)
    out = jnp.einsum("...f,fd->...d", h, params["wo"].astype(ct))
    return constrain(out, ("batch", "seq", "embed_act"), rules)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: Optional[jax.Array] = None):
    """Mean next-token CE.  logits (B,S,V) fp-any, labels (B,S) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
