"""Model facade: ``build_model(cfg)`` -> init / loss / prefill / decode.

One class serves all 10 assigned architectures.  Per-family behaviour is
delegated to :class:`repro.models.transformer.DecoderStack` (dense / moe /
hybrid / ssm) and :mod:`repro.models.encdec` (whisper).  The vlm / audio
modality frontends are stubs per the assignment: ``input_specs()`` hands
the model precomputed patch / frame embeddings.

Shape-cell semantics (matching the assignment):
  train_*    -> ``loss_fn`` (forward + CE; the launcher adds grad+optim)
  prefill_*  -> ``prefill``  (full forward, last-token logits + KV cache)
  decode_*   -> ``decode_step`` (one new token against a seq_len cache)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ParallelConfig, ShapeConfig
from repro.distributed.sharding import (ParamDef, abstract_params, constrain,
                                        init_params, param_shardings,
                                        param_specs)
from repro.models import encdec
from repro.models.layers import (embedding_schema, embed_tokens, make_norm,
                                 softmax_cross_entropy, unembed)
from repro.models.transformer import Blocks, DecoderStack, stack_schema

MTP_WEIGHT = 0.3  # deepseek-v3 MTP aux loss weight (paper uses lambda=0.3)


def _num_patches(seq_len: int) -> int:
    """vlm stub: patch positions spliced at the front of the sequence."""
    return max(1, min(256, seq_len // 4))


class Model:
    def __init__(self, cfg: ModelConfig, parallel: Optional[ParallelConfig] = None,
                 rules=None):
        self.cfg = cfg
        self.parallel = parallel or ParallelConfig()
        self.rules = rules
        self.stack = (DecoderStack(cfg, self.parallel, rules)
                      if not cfg.is_encdec else None)
        self.norm_schema, self.norm = make_norm(cfg)

    # ------------------------------------------------------------------
    # Parameters
    # ------------------------------------------------------------------
    def schema(self):
        cfg = self.cfg
        sch: Dict[str, Any] = {"embed": embedding_schema(cfg)}
        if cfg.is_encdec:
            sch["encoder"] = encdec.encoder_schema(cfg)
            sch["decoder"] = stack_schema(encdec.decoder_layer_schema(cfg),
                                          cfg.num_layers)
        else:
            sch["stack"] = self.stack.schema()
            if cfg.mtp_depth:
                b = Blocks(cfg, self.parallel, self.rules)
                d = cfg.d_model
                sch["mtp"] = {
                    "proj": ParamDef((2 * d, d), ("embed", None), init="scaled"),
                    "ln_h": self.norm_schema(d),
                    "ln_e": self.norm_schema(d),
                    "block": b.dense_schema(d_ff=cfg.dense_ff or cfg.d_ff),
                }
        sch["ln_f"] = self.norm_schema(cfg.d_model)
        return sch

    def init(self, key: jax.Array):
        return init_params(key, self.schema(), self.cfg.param_dtype)

    def abstract_params(self):
        return abstract_params(self.schema(), self.cfg.param_dtype)

    def param_specs(self, rules, mesh=None):
        return param_specs(self.schema(), rules, mesh)

    def param_shardings(self, rules, mesh):
        return param_shardings(self.schema(), rules, mesh)

    # ------------------------------------------------------------------
    # Embedding helpers
    # ------------------------------------------------------------------
    def _embed_in(self, params, batch: Dict[str, jax.Array]) -> jax.Array:
        cfg = self.cfg
        x = embed_tokens(params["embed"], cfg, batch["tokens"], self.rules)
        if cfg.family == "vlm" and "patch_embeds" in batch:
            pe = batch["patch_embeds"].astype(cfg.compute_dtype)
            x = jnp.concatenate([pe, x[:, pe.shape[1]:]], axis=1)
            x = constrain(x, ("batch", "seq", "embed_act"), self.rules)
        return x

    def _logits(self, params, h: jax.Array) -> jax.Array:
        h = self.norm(params["ln_f"], h)
        return unembed(params["embed"], self.cfg, h, self.rules)

    # ------------------------------------------------------------------
    # Training forward / loss
    # ------------------------------------------------------------------
    def forward_train(self, params, batch) -> Tuple[jax.Array, jax.Array]:
        """Returns (logits (B,S,V) fp32-softmax-ready, aux_loss scalar)."""
        cfg = self.cfg
        if cfg.is_encdec:
            enc_out = encdec.encode(params["encoder"], cfg, batch["frames"],
                                    self.rules, self.parallel)
            x = self._embed_in(params, batch)
            h = encdec.decoder_train(params["decoder"], cfg, x, enc_out,
                                     self.rules, self.parallel)
            return self._logits(params, h), jnp.float32(0.0)
        x = self._embed_in(params, batch)
        h, aux = self.stack.train_hidden(params["stack"], x)
        logits = self._logits(params, h)
        if cfg.mtp_depth:
            aux = aux + self._mtp_loss(params, batch, h)
        return logits, aux

    def _mtp_loss(self, params, batch, h: jax.Array) -> jax.Array:
        """DeepSeek-V3 multi-token prediction (depth 1): predict t+2 from
        [norm(h_t); norm(emb(t_{t+1}))] through one extra dense block."""
        cfg, p = self.cfg, params["mtp"]
        tokens, labels = batch["tokens"], batch["labels"]
        e_next = embed_tokens(params["embed"], cfg, tokens[:, 1:], self.rules)
        h_cur = h[:, :-1]
        z = jnp.concatenate([self.norm(p["ln_h"], h_cur),
                             self.norm(p["ln_e"], e_next)], axis=-1)
        z = jnp.einsum("bsd,de->bse", z, p["proj"].astype(cfg.compute_dtype))
        b = Blocks(cfg, self.parallel, self.rules)
        z, _ = b.dense_train(p["block"], z)
        logits = self._logits(params, z)  # (B, S-1, V)
        return MTP_WEIGHT * softmax_cross_entropy(logits[:, :-1],
                                                  labels[:, 2:])

    def loss_fn(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        logits, aux = self.forward_train(params, batch)
        ce = softmax_cross_entropy(logits, batch["labels"], batch.get("mask"))
        loss = ce + aux
        return loss, {"ce": ce, "aux": aux}

    # ------------------------------------------------------------------
    # Features for causal nuisance heads (the Dream11 scenario: pooled
    # event-sequence representation as the confounder embedding)
    # ------------------------------------------------------------------
    def features(self, params, batch) -> jax.Array:
        cfg = self.cfg
        if cfg.is_encdec:
            enc_out = encdec.encode(params["encoder"], cfg, batch["frames"],
                                    self.rules, self.parallel)
            x = self._embed_in(params, batch)
            h = encdec.decoder_train(params["decoder"], cfg, x, enc_out,
                                     self.rules, self.parallel)
        else:
            x = self._embed_in(params, batch)
            h, _ = self.stack.train_hidden(params["stack"], x)
        h = self.norm(params["ln_f"], h)
        return h.mean(axis=1).astype(jnp.float32)

    # ------------------------------------------------------------------
    # Serving: prefill + decode
    # ------------------------------------------------------------------
    def prefill(self, params, batch) -> Tuple[jax.Array, Any]:
        """Full forward over the prompt; returns (last-token logits, cache)."""
        cfg = self.cfg
        if cfg.is_encdec:
            enc_out = encdec.encode(params["encoder"], cfg, batch["frames"],
                                    self.rules, self.parallel)
            cross = encdec.encoder_cross_kv(params["decoder"], cfg, enc_out)
            x = self._embed_in(params, batch)
            h, self_caches = encdec.decoder_prefill(
                params["decoder"], cfg, x, cross, self.rules, self.parallel)
            cache = {"self": self_caches, "cross": cross}
        else:
            x = self._embed_in(params, batch)
            h, cache = self.stack.prefill_hidden(params["stack"], x)
        logits = self._logits(params, h[:, -1:])
        return logits, cache

    def decode_step(self, params, tokens: jax.Array, cache, pos: jax.Array
                    ) -> Tuple[jax.Array, Any]:
        """One new token. tokens: (B,1) int32; pos: () int32 — the index
        the new token is written at (cache holds positions < pos)."""
        cfg = self.cfg
        x = jnp.take(params["embed"]["embedding"], tokens,
                     axis=0).astype(cfg.compute_dtype)
        if cfg.learned_pos_emb:
            pe = jax.lax.dynamic_slice_in_dim(params["embed"]["pos"], pos, 1)
            x = x + pe.astype(cfg.compute_dtype)[None]
        x = constrain(x, ("batch", "seq", "embed_act"), self.rules)
        if cfg.is_encdec:
            h, new_self = encdec.decoder_decode(
                params["decoder"], cfg, x, cache["self"], cache["cross"],
                pos, self.rules)
            new_cache = {"self": new_self, "cross": cache["cross"]}
        else:
            h, new_cache = self.stack.decode_hidden(params["stack"], x,
                                                    cache, pos)
        logits = self._logits(params, h)
        return logits, new_cache

    # alias used by the serving driver / dry-run
    serve_step = decode_step

    def init_cache(self, batch: int, seq_len: int):
        cfg = self.cfg
        if cfg.is_encdec:
            dt = cfg.compute_dtype
            kv, hd = cfg.num_kv_heads, cfg.head_dim
            L, T = cfg.num_layers, cfg.max_source_positions
            return {
                "self": {
                    "k": jnp.zeros((L, batch, seq_len, kv, hd), dt),
                    "v": jnp.zeros((L, batch, seq_len, kv, hd), dt),
                },
                "cross": {
                    "k": jnp.zeros((L, batch, T, kv, hd), dt),
                    "v": jnp.zeros((L, batch, T, kv, hd), dt),
                },
            }
        return self.stack.init_cache(batch, seq_len)

    # ------------------------------------------------------------------
    # Input specs (dry-run: ShapeDtypeStructs, no allocation)
    # ------------------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        tok = lambda *sh: jax.ShapeDtypeStruct(sh, i32)
        act = lambda *sh: jax.ShapeDtypeStruct(sh, cfg.compute_dtype)

        def extras() -> Dict[str, Any]:
            ex: Dict[str, Any] = {}
            if cfg.family == "vlm":
                ex["patch_embeds"] = act(B, _num_patches(S), cfg.d_model)
            if cfg.is_encdec:
                ex["frames"] = act(B, cfg.max_source_positions, cfg.d_model)
            return ex

        if shape.kind == "train":
            return {"tokens": tok(B, S), "labels": tok(B, S), **extras()}
        if shape.kind == "prefill":
            return {"tokens": tok(B, S), **extras()}
        if shape.kind == "decode":
            cache = jax.eval_shape(lambda: self.init_cache(B, S))
            return {"tokens": tok(B, 1), "cache": cache,
                    "pos": jax.ShapeDtypeStruct((), i32)}
        raise ValueError(shape.kind)

    def supports_shape(self, shape: ShapeConfig) -> Tuple[bool, str]:
        """Shape-cell applicability (see DESIGN.md §Arch-applicability)."""
        cfg = self.cfg
        if shape.name == "long_500k" and not cfg.is_subquadratic:
            return False, ("full quadratic attention: long_500k requires "
                           "sub-quadratic sequence mixing (skip per spec)")
        return True, ""


def build_model(cfg: ModelConfig, parallel: Optional[ParallelConfig] = None,
                rules=None) -> Model:
    return Model(cfg, parallel, rules)
