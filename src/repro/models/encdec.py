"""Whisper-style encoder-decoder (whisper-tiny).

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, T_src, d_model) — i.e. the
output of the two strided conv layers.  Everything downstream (sinusoid/
learned positions, bidirectional encoder, causal decoder with per-layer
cross-attention) is implemented in full.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ParallelConfig
from repro.distributed.sharding import ParamDef, constrain
from repro.models import attention as attn
from repro.models.layers import layernorm, layernorm_schema, mlp_schema, mlp_apply
from repro.models.transformer import stack_schema, scan_train


# ---------------------------------------------------------------------------
# Schemas
# ---------------------------------------------------------------------------

def encoder_schema(cfg: ModelConfig):
    d = cfg.d_model
    layer = {
        "ln1": layernorm_schema(d),
        "attn": attn.gqa_schema(cfg),
        "ln2": layernorm_schema(d),
        "mlp": mlp_schema(cfg),
    }
    return {
        "pos": ParamDef((cfg.max_source_positions, d), (None, "embed"),
                        init="embed"),
        "layers": stack_schema(layer, cfg.encoder_layers),
        "ln_f": layernorm_schema(d),
    }


def decoder_layer_schema(cfg: ModelConfig):
    d = cfg.d_model
    return {
        "ln1": layernorm_schema(d),
        "self": attn.gqa_schema(cfg),
        "ln2": layernorm_schema(d),
        "cross": attn.gqa_schema(cfg),
        "ln3": layernorm_schema(d),
        "mlp": mlp_schema(cfg),
    }


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------

def encode(params, cfg: ModelConfig, frames: jax.Array, rules=None,
           parallel: ParallelConfig = None) -> jax.Array:
    """frames: (B, T_src, d_model) — post-conv-stub embeddings."""
    eps = cfg.norm_eps
    x = frames.astype(cfg.compute_dtype)
    x = x + params["pos"][: x.shape[1]].astype(cfg.compute_dtype)
    x = constrain(x, ("batch", "seq", "embed_act"), rules)

    def body(lp, h):
        a = attn.gqa_train(lp["attn"], cfg, layernorm(lp["ln1"], h, eps),
                           rules, parallel, causal=False)
        h = h + a
        h = h + mlp_apply(lp["mlp"], cfg, layernorm(lp["ln2"], h, eps), rules)
        return h, jnp.float32(0.0)

    remat = parallel.remat_policy if parallel is not None else "nothing"
    x, _ = scan_train(body, params["layers"], x, remat=remat)
    return layernorm(params["ln_f"], x, eps)


def encoder_cross_kv(params, cfg: ModelConfig, enc_out: jax.Array):
    """Precompute per-decoder-layer cross K/V from the encoder output.

    Stacked over the decoder-layer axis so scan_decode can thread them.
    ``params`` is the stacked decoder-layer tree.
    """
    def per_layer(cross_p):
        return attn.cross_kv(cross_p, cfg, enc_out)

    return jax.vmap(per_layer)(params["cross"])


# ---------------------------------------------------------------------------
# Decoder
# ---------------------------------------------------------------------------

def decoder_train(params, cfg: ModelConfig, x: jax.Array, enc_out: jax.Array,
                  rules=None, parallel: ParallelConfig = None) -> jax.Array:
    """x: (B, S, d) token embeddings (+pos); enc_out: (B, T_src, d)."""
    eps = cfg.norm_eps

    def body(lp, h):
        a = attn.gqa_train(lp["self"], cfg, layernorm(lp["ln1"], h, eps),
                           rules, parallel, causal=True)
        h = h + a
        kv = attn.cross_kv(lp["cross"], cfg, enc_out)
        c = attn.cross_attn(lp["cross"], cfg, layernorm(lp["ln2"], h, eps),
                            kv, rules)
        h = h + c
        h = h + mlp_apply(lp["mlp"], cfg, layernorm(lp["ln3"], h, eps), rules)
        return h, jnp.float32(0.0)

    remat = parallel.remat_policy if parallel is not None else "nothing"
    x, _ = scan_train(body, params, x, remat=remat)
    return x


def decoder_prefill(params, cfg: ModelConfig, x: jax.Array, cross_caches,
                    rules=None, parallel: ParallelConfig = None):
    """Returns (hidden, self_caches stacked over layers)."""
    eps = cfg.norm_eps

    def body_scan(h, xs):
        lp, ckv = xs
        a, cache = attn.gqa_prefill(lp["self"], cfg,
                                    layernorm(lp["ln1"], h, eps),
                                    rules, parallel)
        h = h + a
        c = attn.cross_attn(lp["cross"], cfg, layernorm(lp["ln2"], h, eps),
                            ckv, rules)
        h = h + c
        h = h + mlp_apply(lp["mlp"], cfg, layernorm(lp["ln3"], h, eps), rules)
        return h, cache

    x, caches = jax.lax.scan(body_scan, x, (params, cross_caches))
    return x, caches


def decoder_decode(params, cfg: ModelConfig, x: jax.Array, self_caches,
                   cross_caches, pos: jax.Array, rules=None):
    """One-token decode. x: (B,1,d). Returns (hidden, new self caches)."""
    eps = cfg.norm_eps

    def body(h, xs):
        lp, sc, ckv = xs
        a, sc2 = attn.gqa_decode(lp["self"], cfg, layernorm(lp["ln1"], h, eps),
                                 sc, pos, rules)
        h = h + a
        c = attn.cross_attn(lp["cross"], cfg, layernorm(lp["ln2"], h, eps),
                            ckv, rules)
        h = h + c
        h = h + mlp_apply(lp["mlp"], cfg, layernorm(lp["ln3"], h, eps), rules)
        return h, sc2

    x, new_caches = jax.lax.scan(body, x, (params, self_caches, cross_caches))
    return x, new_caches
