"""Decoder-only stack assembly: blocks, lax.scan over layers, remat.

Families handled here: dense GQA/MLA, MoE (arctic/deepseek segments),
zamba2 hybrid (mamba groups + weight-shared attention block), rwkv6.
Whisper's encoder-decoder lives in encdec.py.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ParallelConfig
from repro.distributed.sharding import ParamDef, constrain
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import make_norm, mlp_schema, mlp_apply


# ---------------------------------------------------------------------------
# Param stacking for lax.scan
# ---------------------------------------------------------------------------

def stack_schema(schema, n: int):
    """Add a leading 'layers' axis to every ParamDef in a layer schema."""
    def bump(d: ParamDef) -> ParamDef:
        return ParamDef((n,) + d.shape, ("layers",) + d.axes, d.init, d.scale,
                        d.dtype)
    return jax.tree_util.tree_map(bump, schema,
                                  is_leaf=lambda x: isinstance(x, ParamDef))


def _policy(name: str):
    if name == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    if name == "full_save":
        return jax.checkpoint_policies.everything_saveable
    return jax.checkpoint_policies.nothing_saveable


def scan_train(body, stacked, x, aux0=0.0, *, remat: str = "nothing"):
    """body: (layer_params, x) -> (x, aux). Scans with rematerialization."""
    def f(carry, lp):
        h, aux = carry
        h, a = body(lp, h)
        return (h, aux + a), None

    f = jax.checkpoint(f, policy=_policy(remat), prevent_cse=False)
    (x, aux), _ = jax.lax.scan(f, (x, jnp.float32(aux0)), stacked)
    return x, aux


def scan_prefill(body, stacked, x):
    """body: (lp, x) -> (x, cache_layer); caches stacked on layer axis."""
    def f(h, lp):
        h, c = body(lp, h)
        return h, c

    x, caches = jax.lax.scan(f, x, stacked)
    return x, caches


def scan_decode(body, stacked, caches, x):
    """body: (x, lp, cache) -> (x, new_cache)."""
    def f(h, xs):
        lp, c = xs
        h, c2 = body(h, lp, c)
        return h, c2

    x, new_caches = jax.lax.scan(f, x, (stacked, caches))
    return x, new_caches


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

class Blocks:
    """Per-layer block functions bound to (cfg, parallel, rules)."""

    def __init__(self, cfg: ModelConfig, parallel: ParallelConfig, rules):
        self.cfg, self.parallel, self.rules = cfg, parallel, rules
        self.norm_schema, self.norm = make_norm(cfg)

    # ---- dense transformer block (GQA or MLA attention) -------------------
    def dense_schema(self, d_ff: Optional[int] = None, use_moe: bool = False):
        cfg = self.cfg
        sch = {"ln1": self.norm_schema(cfg.d_model),
               "attn": attn.attention_schema(cfg),
               "ln2": self.norm_schema(cfg.d_model)}
        if use_moe:
            sch["moe"] = moe_mod.moe_schema(cfg)
        else:
            sch["mlp"] = mlp_schema(cfg, d_ff)
        return sch

    def _attn_train(self, p, x):
        cfg = self.cfg
        if cfg.attention == "mla":
            return attn.mla_train(p, cfg, x, self.rules, self.parallel)
        return attn.gqa_train(p, cfg, x, self.rules, self.parallel)

    def dense_train(self, p, x):
        # re-assert the residual-stream sharding at block entry: the
        # scan-of-checkpoint carry stack otherwise loses its annotation
        # in GSPMD's while-loop propagation (measured: batch replicated
        # on the (L,B,S,d) saved carries)
        x = constrain(x, ("batch", "seq", "embed_act"), self.rules)
        x = x + self._attn_train(p["attn"], self.norm(p["ln1"], x))
        if "moe" in p:
            y, aux = moe_mod.moe_apply(p["moe"], self.cfg, self.norm(p["ln2"], x),
                                       self.rules)
            return x + y, aux
        x = x + mlp_apply(p["mlp"], self.cfg, self.norm(p["ln2"], x), self.rules)
        return x, jnp.float32(0.0)

    def dense_prefill(self, p, x):
        cfg = self.cfg
        h = self.norm(p["ln1"], x)
        if cfg.attention == "mla":
            y, cache = attn.mla_train(p["attn"], cfg, h, self.rules,
                                      self.parallel, return_cache=True)
        else:
            y, cache = attn.gqa_prefill(p["attn"], cfg, h, self.rules,
                                        self.parallel)
        x = x + y
        if "moe" in p:
            y, _ = moe_mod.moe_apply(p["moe"], cfg, self.norm(p["ln2"], x),
                                     self.rules)
            x = x + y
        else:
            x = x + mlp_apply(p["mlp"], cfg, self.norm(p["ln2"], x), self.rules)
        return x, cache

    def dense_decode(self, p, x, cache, pos):
        cfg = self.cfg
        h = self.norm(p["ln1"], x)
        if cfg.attention == "mla":
            y, cache = attn.mla_decode(p["attn"], cfg, h, cache, pos, self.rules)
        else:
            y, cache = attn.gqa_decode(p["attn"], cfg, h, cache, pos, self.rules)
        x = x + y
        if "moe" in p:
            y, _ = moe_mod.moe_apply(p["moe"], cfg, self.norm(p["ln2"], x),
                                     self.rules)
            x = x + y
        else:
            x = x + mlp_apply(p["mlp"], cfg, self.norm(p["ln2"], x), self.rules)
        return x, cache

    # ---- mamba block (zamba2 backbone) -------------------------------------
    def mamba_schema(self):
        return {"ln": self.norm_schema(self.cfg.d_model),
                "mamba": ssm_mod.mamba_schema(self.cfg)}

    def mamba_train(self, p, x):
        x = constrain(x, ("batch", "seq", "embed_act"), self.rules)
        return x + ssm_mod.mamba_train(p["mamba"], self.cfg,
                                       self.norm(p["ln"], x), self.rules), \
            jnp.float32(0.0)

    def mamba_decode(self, p, x, state):
        y, state = ssm_mod.mamba_decode(p["mamba"], self.cfg,
                                        self.norm(p["ln"], x), state, self.rules)
        return x + y, state

    def mamba_prefill(self, p, x):
        y, state = ssm_mod.mamba_prefill(p["mamba"], self.cfg,
                                         self.norm(p["ln"], x), self.rules)
        return x + y, state

    # ---- rwkv block ---------------------------------------------------------
    def rwkv_schema(self):
        d = self.cfg.d_model
        return {"ln1": self.norm_schema(d),
                "tm": rwkv_mod.time_mix_schema(self.cfg),
                "ln2": self.norm_schema(d),
                "cm": rwkv_mod.channel_mix_schema(self.cfg)}

    def rwkv_train(self, p, x):
        cfg = self.cfg
        x = constrain(x, ("batch", "seq", "embed_act"), self.rules)
        x = x + rwkv_mod.time_mix_train(p["tm"], cfg, self.norm(p["ln1"], x),
                                        self.rules, chunk=cfg.ssm_chunk)
        x = x + rwkv_mod.channel_mix_train(p["cm"], cfg, self.norm(p["ln2"], x),
                                           self.rules)
        return x, jnp.float32(0.0)

    def rwkv_decode(self, p, x, state):
        cfg = self.cfg
        y, tm = rwkv_mod.time_mix_decode(p["tm"], cfg, self.norm(p["ln1"], x),
                                         state["tm"], self.rules)
        x = x + y
        y, cm = rwkv_mod.channel_mix_decode(p["cm"], cfg, self.norm(p["ln2"], x),
                                            state["cm"], self.rules)
        return x + y, {"tm": tm, "cm": cm}

    def rwkv_prefill(self, p, x):
        cfg = self.cfg
        y, tm = rwkv_mod.time_mix_prefill(p["tm"], cfg, self.norm(p["ln1"], x),
                                          self.rules, chunk=cfg.ssm_chunk)
        x = x + y
        y, cm = rwkv_mod.channel_mix_prefill(p["cm"], cfg,
                                             self.norm(p["ln2"], x), self.rules)
        return x + y, {"tm": tm, "cm": cm}


# ---------------------------------------------------------------------------
# Decoder stacks per family
# ---------------------------------------------------------------------------

class DecoderStack:
    """Hidden-state pipeline: embeddings in, hidden states out."""

    def __init__(self, cfg: ModelConfig, parallel: ParallelConfig, rules=None):
        self.cfg, self.parallel, self.rules = cfg, parallel, rules
        self.blocks = Blocks(cfg, parallel, rules)

    # -- schema ---------------------------------------------------------------
    def schema(self):
        cfg, b = self.cfg, self.blocks
        if cfg.family in ("dense", "vlm"):
            return {"layers": stack_schema(b.dense_schema(), cfg.num_layers)}
        if cfg.family == "moe":
            sch: Dict[str, Any] = {}
            if cfg.first_k_dense:
                sch["dense_layers"] = stack_schema(
                    b.dense_schema(d_ff=cfg.dense_ff or cfg.d_ff),
                    cfg.first_k_dense)
            sch["moe_layers"] = stack_schema(
                b.dense_schema(use_moe=True), cfg.num_layers - cfg.first_k_dense)
            return sch
        if cfg.family == "hybrid":
            return {
                "mamba_layers": stack_schema(b.mamba_schema(), cfg.num_layers),
                "shared_attn": b.dense_schema(),  # ONE set of weights, reused
            }
        if cfg.family == "ssm":
            return {"layers": stack_schema(b.rwkv_schema(), cfg.num_layers)}
        raise ValueError(cfg.family)

    # -- helpers ---------------------------------------------------------------
    def _groups(self):
        cfg = self.cfg
        g = cfg.shared_attn_every or cfg.num_layers
        sizes = []
        rest = cfg.num_layers
        while rest > 0:
            sizes.append(min(g, rest))
            rest -= g
        return sizes

    @staticmethod
    def _slice_stack(stacked, start, size):
        return jax.tree_util.tree_map(lambda a: a[start:start + size], stacked)

    # -- train -------------------------------------------------------------------
    def train_hidden(self, params, x) -> Tuple[jax.Array, jax.Array]:
        cfg, b = self.cfg, self.blocks
        remat = self.parallel.remat_policy
        if cfg.family in ("dense", "vlm"):
            return scan_train(b.dense_train, params["layers"], x, remat=remat)
        if cfg.family == "moe":
            aux = jnp.float32(0.0)
            if cfg.first_k_dense:
                x, aux = scan_train(b.dense_train, params["dense_layers"], x,
                                    remat=remat)
            x, aux2 = scan_train(b.dense_train, params["moe_layers"], x,
                                 remat=remat)
            return x, aux + aux2
        if cfg.family == "hybrid":
            start = 0
            for size in self._groups():
                seg = self._slice_stack(params["mamba_layers"], start, size)
                x, _ = scan_train(b.mamba_train, seg, x, remat=remat)
                x, _ = b.dense_train(params["shared_attn"], x)
                start += size
            return x, jnp.float32(0.0)
        if cfg.family == "ssm":
            return scan_train(b.rwkv_train, params["layers"], x, remat=remat)
        raise ValueError(cfg.family)

    # -- prefill -------------------------------------------------------------------
    def prefill_hidden(self, params, x):
        cfg, b = self.cfg, self.blocks
        if cfg.family in ("dense", "vlm"):
            return scan_prefill(b.dense_prefill, params["layers"], x)
        if cfg.family == "moe":
            caches = {}
            if cfg.first_k_dense:
                x, caches["dense"] = scan_prefill(b.dense_prefill,
                                                  params["dense_layers"], x)
            x, caches["moe"] = scan_prefill(b.dense_prefill,
                                            params["moe_layers"], x)
            return x, caches
        if cfg.family == "hybrid":
            mamba_states, attn_caches = [], []
            start = 0
            for size in self._groups():
                seg = self._slice_stack(params["mamba_layers"], start, size)
                x, st = scan_prefill(b.mamba_prefill, seg, x)
                mamba_states.append(st)
                x, ac = b.dense_prefill(params["shared_attn"], x)
                attn_caches.append(ac)
                start += size
            cat = lambda *xs: jnp.concatenate(xs, axis=0)
            stk = lambda *xs: jnp.stack(xs, axis=0)
            return x, {
                "mamba": jax.tree_util.tree_map(cat, *mamba_states),
                "attn": jax.tree_util.tree_map(stk, *attn_caches),
            }
        if cfg.family == "ssm":
            return scan_prefill(b.rwkv_prefill, params["layers"], x)
        raise ValueError(cfg.family)

    # -- decode -------------------------------------------------------------------
    def decode_hidden(self, params, x, caches, pos):
        cfg, b = self.cfg, self.blocks
        dec = functools.partial(b.dense_decode, pos=pos)
        body = lambda h, lp, c: dec(lp, h, c)
        if cfg.family in ("dense", "vlm"):
            return scan_decode(body, params["layers"], caches, x)
        if cfg.family == "moe":
            new = {}
            if cfg.first_k_dense:
                x, new["dense"] = scan_decode(body, params["dense_layers"],
                                              caches["dense"], x)
            x, new["moe"] = scan_decode(body, params["moe_layers"],
                                        caches["moe"], x)
            return x, new
        if cfg.family == "hybrid":
            new_m, new_a = [], []
            start = 0
            for gi, size in enumerate(self._groups()):
                seg = self._slice_stack(params["mamba_layers"], start, size)
                st = self._slice_stack(caches["mamba"], start, size)
                x, st2 = scan_decode(lambda h, lp, c: b.mamba_decode(lp, h, c),
                                     seg, st, x)
                new_m.append(st2)
                ac = jax.tree_util.tree_map(lambda a: a[gi], caches["attn"])
                x, ac2 = b.dense_decode(params["shared_attn"], x, ac, pos)
                new_a.append(ac2)
                start += size
            cat = lambda *xs: jnp.concatenate(xs, axis=0)
            stk = lambda *xs: jnp.stack(xs, axis=0)
            return x, {
                "mamba": jax.tree_util.tree_map(cat, *new_m),
                "attn": jax.tree_util.tree_map(stk, *new_a),
            }
        if cfg.family == "ssm":
            return scan_decode(lambda h, lp, c: b.rwkv_decode(lp, h, c),
                               params["layers"], caches, x)
        raise ValueError(cfg.family)

    # -- cache init -------------------------------------------------------------------
    def init_cache(self, batch: int, seq_len: int):
        cfg = self.cfg
        dt = cfg.compute_dtype
        if cfg.family in ("dense", "vlm"):
            return attn.init_cache(cfg, batch, seq_len, cfg.num_layers, dt)
        if cfg.family == "moe":
            caches = {}
            if cfg.first_k_dense:
                caches["dense"] = attn.init_cache(cfg, batch, seq_len,
                                                  cfg.first_k_dense, dt)
            caches["moe"] = attn.init_cache(
                cfg, batch, seq_len, cfg.num_layers - cfg.first_k_dense, dt)
            return caches
        if cfg.family == "hybrid":
            n_groups = len(self._groups())
            per_layer = ssm_mod.mamba_init_state(cfg, batch, dt)
            mamba = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None],
                                           (cfg.num_layers,) + a.shape).copy(),
                per_layer)
            return {"mamba": mamba,
                    "attn": attn.init_cache(cfg, batch, seq_len, n_groups, dt)}
        if cfg.family == "ssm":
            per_layer = rwkv_mod.rwkv_init_state(cfg, batch, dt)
            return jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None],
                                           (cfg.num_layers,) + a.shape).copy(),
                per_layer)
        raise ValueError(cfg.family)
