"""RWKV-6 "Finch" block (rwkv6-3b): attention-free time-mix with
data-dependent per-channel decay + squared-ReLU channel-mix.

The time-mix recurrence runs on the shared chunked GLA kernel in "bonus"
mode:  o_t = r_t (S_{t-1} + diag(u) k_t v_t^T),  S_t = diag(w_t) S_{t-1}
+ k_t v_t^T, with w_t = exp(-exp(w0 + tanh(x W_a) W_b)) per channel.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.sharding import ParamDef, constrain
from repro.kernels.ssm_scan import ops as scan_ops
from repro.kernels.ssm_scan.ref import MAX_LOG_DECAY

RWKV_HEADDIM = 64
DECAY_LORA = 64


def _heads(cfg: ModelConfig):
    h = max(1, cfg.d_model // RWKV_HEADDIM)
    return h, cfg.d_model // h


def time_mix_schema(cfg: ModelConfig):
    d = cfg.d_model
    h, hd = _heads(cfg)
    lora = min(DECAY_LORA, d)
    return {
        "mu_r": ParamDef((d,), (None,), init="zeros"),
        "mu_k": ParamDef((d,), (None,), init="zeros"),
        "mu_v": ParamDef((d,), (None,), init="zeros"),
        "mu_g": ParamDef((d,), (None,), init="zeros"),
        "mu_w": ParamDef((d,), (None,), init="zeros"),
        "wr": ParamDef((d, d), ("embed", "inner"), init="scaled"),
        "wk": ParamDef((d, d), ("embed", "inner"), init="scaled"),
        "wv": ParamDef((d, d), ("embed", "inner"), init="scaled"),
        "wg": ParamDef((d, d), ("embed", "inner"), init="scaled"),
        "wo": ParamDef((d, d), ("inner", "embed"), init="scaled"),
        "w0": ParamDef((d,), (None,), init="ones", scale=1.0),
        "w_a": ParamDef((d, lora), ("embed", None), init="scaled"),
        "w_b": ParamDef((lora, d), (None, "inner"), init="scaled", scale=0.1),
        "u": ParamDef((d,), (None,), init="zeros"),
        "ln_scale": ParamDef((d,), (None,), init="ones"),
        "ln_bias": ParamDef((d,), (None,), init="zeros"),
    }


def channel_mix_schema(cfg: ModelConfig):
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "mu_k": ParamDef((d,), (None,), init="zeros"),
        "mu_r": ParamDef((d,), (None,), init="zeros"),
        "wk": ParamDef((d, ff), ("embed", "ff"), init="scaled"),
        "wv": ParamDef((ff, d), ("ff", "embed"), init="scaled"),
        "wr": ParamDef((d, d), ("embed", "inner"), init="scaled"),
    }


def _shift(x: jax.Array, last: jax.Array = None):
    """Token shift: x_{t-1}, zeros (or carried state) at t=0."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _lerp(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype)[None, None]


def _decay(params, xw: jax.Array) -> jax.Array:
    """Data-dependent per-channel decay in (0,1).

    The raw rate exp(-(w0+lora)) is clamped to MAX_LOG_DECAY per step —
    functionally a full reset over a 16-token span — which bounds the
    chunked kernel's exp(-cumsum) factor (see ssm_scan.ref contract).
    """
    f32 = jnp.float32
    lo = jnp.tanh(xw.astype(f32) @ params["w_a"].astype(f32)) @ params["w_b"].astype(f32)
    rate = jnp.minimum(jnp.exp(-(params["w0"].astype(f32) + lo)), MAX_LOG_DECAY)
    return jnp.exp(-rate)


def _group_norm(cfg, params, o, B, T):
    h, hd = _heads(cfg)
    f32 = jnp.float32
    o = o.astype(f32)
    mu = o.mean(-1, keepdims=True)
    var = ((o - mu) ** 2).mean(-1, keepdims=True)
    o = (o - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
    o = o.reshape(B, T, h * hd)
    return o * params["ln_scale"].astype(f32) + params["ln_bias"].astype(f32)


def _tm_qkvwg(params, cfg, x, xs):
    ct = cfg.compute_dtype
    h, hd = _heads(cfg)
    B, T, d = x.shape
    proj = lambda name, mu: _lerp(x, xs, params[mu]) @ params[name].astype(ct)
    r = proj("wr", "mu_r").reshape(B, T, h, hd)
    k = proj("wk", "mu_k").reshape(B, T, h, hd)
    v = proj("wv", "mu_v").reshape(B, T, h, hd)
    g = proj("wg", "mu_g")
    w = _decay(params, _lerp(x, xs, params["mu_w"])).reshape(B, T, h, hd)
    to_bhtd = lambda t: t.transpose(0, 2, 1, 3)
    u = params["u"].astype(jnp.float32).reshape(h, hd)
    return (to_bhtd(r), to_bhtd(k), to_bhtd(v), to_bhtd(w.astype(jnp.float32)),
            u, g)


def time_mix_train(params, cfg: ModelConfig, x: jax.Array, rules=None,
                   chunk: int = 64) -> jax.Array:
    ct = cfg.compute_dtype
    B, T, d = x.shape
    r, k, v, w, u, g = _tm_qkvwg(params, cfg, x, _shift(x))
    o, _ = scan_ops.gla(r, k, v, w, u, chunk=chunk)
    o = o.transpose(0, 2, 1, 3)  # (B,T,h,hd)
    o = _group_norm(cfg, params, o, B, T)
    o = (o * jax.nn.silu(g.astype(jnp.float32))).astype(ct)
    out = o @ params["wo"].astype(ct)
    return constrain(out, ("batch", "seq", "embed_act"), rules)


def time_mix_prefill(params, cfg: ModelConfig, x: jax.Array, rules=None,
                     chunk: int = 64) -> Tuple[jax.Array, Dict]:
    """time_mix_train + final recurrent state (prefill -> decode handoff)."""
    ct = cfg.compute_dtype
    B, T, d = x.shape
    r, k, v, w, u, g = _tm_qkvwg(params, cfg, x, _shift(x))
    o, s_final = scan_ops.gla(r, k, v, w, u, chunk=chunk)
    o = o.transpose(0, 2, 1, 3)
    o = _group_norm(cfg, params, o, B, T)
    o = (o * jax.nn.silu(g.astype(jnp.float32))).astype(ct)
    out = o @ params["wo"].astype(ct)
    out = constrain(out, ("batch", "seq", "embed_act"), rules)
    return out, {"s": s_final, "x_prev": x[:, -1:]}


def time_mix_decode(params, cfg: ModelConfig, x: jax.Array, state: Dict,
                    rules=None) -> Tuple[jax.Array, Dict]:
    """x: (B,1,d); state: {"s": (B,h,hd,hd), "x_prev": (B,1,d)}."""
    ct = cfg.compute_dtype
    B = x.shape[0]
    r, k, v, w, u, g = _tm_qkvwg(params, cfg, x, state["x_prev"])
    sq = lambda t: t[:, :, 0]
    new_s, o = scan_ops.gla_decode_step(state["s"], sq(r), sq(k), sq(v), sq(w), u)
    o = o[:, None]  # (B,h,hd) -> (B,1,h,hd)
    o = _group_norm(cfg, params, o, B, 1)
    o = (o * jax.nn.silu(g.astype(jnp.float32))).astype(ct)
    out = o @ params["wo"].astype(ct)
    out = constrain(out, ("batch", "seq", "embed_act"), rules)
    return out, {"s": new_s, "x_prev": x}


def channel_mix_train(params, cfg: ModelConfig, x: jax.Array, rules=None,
                      x_prev: jax.Array = None) -> jax.Array:
    ct = cfg.compute_dtype
    xs = _shift(x, x_prev)
    k = _lerp(x, xs, params["mu_k"]) @ params["wk"].astype(ct)
    k = jnp.square(jax.nn.relu(k))
    kv = k @ params["wv"].astype(ct)
    r = jax.nn.sigmoid(_lerp(x, xs, params["mu_r"]) @ params["wr"].astype(ct))
    return constrain(r * kv, ("batch", "seq", "embed_act"), rules)


def channel_mix_decode(params, cfg: ModelConfig, x: jax.Array, state: Dict,
                       rules=None) -> Tuple[jax.Array, Dict]:
    out = channel_mix_train(params, cfg, x, rules, x_prev=state["x_prev"])
    return out, {"x_prev": x}


def channel_mix_prefill(params, cfg: ModelConfig, x: jax.Array, rules=None
                        ) -> Tuple[jax.Array, Dict]:
    out = channel_mix_train(params, cfg, x, rules)
    return out, {"x_prev": x[:, -1:]}


def rwkv_init_state(cfg: ModelConfig, batch: int, dtype):
    h, hd = _heads(cfg)
    return {
        "tm": {"s": jnp.zeros((batch, h, hd, hd), jnp.float32),
               "x_prev": jnp.zeros((batch, 1, cfg.d_model), dtype)},
        "cm": {"x_prev": jnp.zeros((batch, 1, cfg.d_model), dtype)},
    }
