"""Attention: GQA (train / prefill / decode+KV-cache) and MLA (DeepSeek).

The dense reference path is pure jnp (used on CPU and as the oracle);
when ``ParallelConfig.use_flash_attention`` is on, the train/prefill path
routes through the Pallas flash-attention kernel in repro.kernels.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.sharding import ParamDef, constrain
from repro.models.layers import apply_rope, rope_frequencies


# ---------------------------------------------------------------------------
# Schemas
# ---------------------------------------------------------------------------

def gqa_schema(cfg: ModelConfig):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "wq": ParamDef((d, h, hd), ("embed", "heads", "head_dim"), init="scaled"),
        "wk": ParamDef((d, kv, hd), ("embed", "kv_heads", "head_dim"), init="scaled"),
        "wv": ParamDef((d, kv, hd), ("embed", "kv_heads", "head_dim"), init="scaled"),
        "wo": ParamDef((h, hd, d), ("heads", "head_dim", "embed"), init="scaled"),
    }


def mla_schema(cfg: ModelConfig):
    d = cfg.d_model
    h = cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    sch: Dict[str, Any] = {
        "wkv_a": ParamDef((d, kvr + dr), ("embed", "qk_lora"), init="scaled"),
        "kv_norm": ParamDef((kvr,), (None,), init="ones"),
        "wk_b": ParamDef((kvr, h, dn), ("qk_lora", "heads", "head_dim"), init="scaled"),
        "wv_b": ParamDef((kvr, h, dv), ("qk_lora", "heads", "head_dim"), init="scaled"),
        "wo": ParamDef((h, dv, d), ("heads", "head_dim", "embed"), init="scaled"),
    }
    if qr:
        sch["wq_a"] = ParamDef((d, qr), ("embed", "qk_lora"), init="scaled")
        sch["q_norm"] = ParamDef((qr,), (None,), init="ones")
        sch["wq_b"] = ParamDef((qr, h, dn + dr), ("qk_lora", "heads", "head_dim"),
                               init="scaled")
    else:
        sch["wq"] = ParamDef((d, h, dn + dr), ("embed", "heads", "head_dim"),
                             init="scaled")
    return sch


def attention_schema(cfg: ModelConfig):
    return mla_schema(cfg) if cfg.attention == "mla" else gqa_schema(cfg)


# ---------------------------------------------------------------------------
# Dense reference attention core (GQA-aware)
# ---------------------------------------------------------------------------
#
# KV heads are broadcast by an explicit repeat (not the (KV, G) grouped
# reshape): a reshaped head dim defeats GSPMD's sharding propagation —
# it moved all 16 model-shards onto the (kv, g) factor pair and
# REPLICATED the batch dim of the (B,H,S,S) score tensor (measured:
# 32 GiB/device for granite train_4k).  With the repeat layout + the
# explicit constraint below, scores shard (batch->data, heads->model).

def _repeat_kv(x: jax.Array, heads: int) -> jax.Array:
    kv = x.shape[2]
    return x if kv == heads else jnp.repeat(x, heads // kv, axis=2)


def _sdpa_decode(q: jax.Array, k: jax.Array, v: jax.Array, *,
                 kv_mask: Optional[jax.Array], softcap: float,
                 rules) -> jax.Array:
    """Single-query attention with GROUPED heads: q (B,1,H,D) reshaped to
    (B,1,KV,G,D) so the (huge) KV cache is never materialized at H heads
    — _repeat_kv on the decode path copied the 32k cache 7x for yi
    (measured +33 GiB/step traffic).  q is tiny, so reshaping q instead
    is free; GSPMD propagation is safe here because the reshaped tensor
    is the small one."""
    B, Sq, H, Dq = q.shape
    KV, Dv = k.shape[2], v.shape[-1]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, Dq)
    scale = 1.0 / jnp.sqrt(jnp.asarray(Dq, jnp.float32))
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    if kv_mask is not None:
        scores = jnp.where(kv_mask[:, None, None, None, :], scores, -1e30)
    scores = constrain(scores, ("batch", "kv_heads", None, None, "kv_seq"),
                       rules)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, Dv).astype(q.dtype)


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
          q_offset: int = 0, kv_mask: Optional[jax.Array] = None,
          softcap: float = 0.0, rules=None) -> jax.Array:
    """q: (B,Sq,H,Dq) k/v: (B,Sk,KV,D*). Returns (B,Sq,H,Dv)."""
    B, Sq, H, Dq = q.shape
    if Sq == 1 and not causal and H != k.shape[2]:
        return _sdpa_decode(q, k, v, kv_mask=kv_mask, softcap=softcap,
                            rules=rules)
    k = _repeat_kv(k, H)
    v = _repeat_kv(v, H)
    Sk = k.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(Dq, jnp.float32))
    # bf16 inputs with fp32 ACCUMULATION (MXU-native) — casting the
    # operands instead would materialize an fp32 copy of the whole KV
    # cache on the decode path (measured: +6 GiB/chip on yi decode_32k)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    if causal:
        qi = jnp.arange(Sq) + q_offset
        ki = jnp.arange(Sk)
        mask = qi[:, None] >= ki[None, :]
        scores = jnp.where(mask[None, None], scores, -1e30)
    if kv_mask is not None:  # (B, Sk) valid positions
        scores = jnp.where(kv_mask[:, None, None, :], scores, -1e30)
    scores = constrain(scores, ("batch", "heads", "attn_seq", "kv_seq"),
                       rules)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def _chunked_attn(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
                  softcap: float = 0.0, rules=None, chunk: int = 1024
                  ) -> jax.Array:
    """Online-softmax attention scanned over key blocks with a
    flash-style custom VJP — the pure-XLA translation of the flash
    kernel's schedule, including its backward (per-block score
    RECOMPUTATION instead of saving (n_blocks, B, H, Sq, chunk) probs,
    which measured 34 GiB/device on granite train_4k).  Residuals are
    O(B·H·Sq·D): q, k, v, out and the logsumexp rows.  FLOPs ~1.3x a
    saved-probs backward; peak attention memory drops by Sk/chunk.

    softcap is not supported here (falls back to dense) — only whisper
    uses it and only at tiny seq lengths."""
    B, Sq, H, Dq = q.shape
    Sk = k.shape[1]
    if Sk % chunk != 0 or Sq == 1 or softcap:
        return _sdpa(q, k, v, causal=causal, softcap=softcap, rules=rules)
    k = _repeat_kv(k, H)
    v = _repeat_kv(v, H)
    out = _flash_xla(q.astype(jnp.float32), k.astype(jnp.float32),
                     v.astype(jnp.float32), causal, chunk, rules)
    return out.astype(q.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash_xla(q, k, v, causal, chunk, rules):
    out, _ = _flash_xla_fwd(q, k, v, causal, chunk, rules)
    return out


def _blocks(x, chunk):  # (B,S,H,D) -> (n,B,chunk,H,D)
    B, S, H, D = x.shape
    return jnp.moveaxis(x.reshape(B, S // chunk, chunk, H, D), 1, 0)


def _flash_xla_fwd(q, k, v, causal, chunk, rules):
    B, Sq, H, Dq = q.shape
    Dv = v.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(Dq, jnp.float32))
    qt = q.transpose(0, 2, 1, 3)                           # (B,H,Sq,D)
    qi = jnp.arange(Sq)

    def body(carry, xs):
        m, l, acc = carry
        kblk, vblk, start = xs
        s = jnp.einsum("bhqd,bkhd->bhqk", qt, kblk) * scale
        if causal:
            ki = start + jnp.arange(chunk)
            s = jnp.where((qi[:, None] >= ki[None, :])[None, None], s, -1e30)
        s = constrain(s, ("batch", "heads", "attn_seq", None), rules)
        m_new = jnp.maximum(m, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bhqk,bkhd->bhqd", p, vblk)
        return (m_new, l, acc), None

    m0 = jnp.full((B, H, Sq, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((B, H, Sq, 1), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, Dv), jnp.float32)
    starts = jnp.arange(k.shape[1] // chunk) * chunk
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (_blocks(k, chunk), _blocks(v, chunk), starts))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))               # (B,H,Sq,1)
    out = (acc / jnp.maximum(l, 1e-30)).transpose(0, 2, 1, 3)
    return out, (q, k, v, out, lse)


def _flash_xla_bwd(causal, chunk, rules, res, dout):
    q, k, v, out, lse = res
    B, Sq, H, Dq = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(Dq, jnp.float32))
    qt = q.transpose(0, 2, 1, 3)
    ot = out.transpose(0, 2, 1, 3).astype(jnp.float32)
    dot = dout.transpose(0, 2, 1, 3).astype(jnp.float32)
    delta = jnp.sum(ot * dot, axis=-1, keepdims=True)     # (B,H,Sq,1)
    qi = jnp.arange(Sq)

    def body(dq_acc, xs):
        kblk, vblk, start = xs
        s = jnp.einsum("bhqd,bkhd->bhqk", qt, kblk) * scale
        if causal:
            ki = start + jnp.arange(chunk)
            s = jnp.where((qi[:, None] >= ki[None, :])[None, None], s, -1e30)
        s = constrain(s, ("batch", "heads", "attn_seq", None), rules)
        p = jnp.exp(s - lse)                               # recomputed probs
        dv = jnp.einsum("bhqk,bhqd->bkhd", p, dot)
        dp = jnp.einsum("bhqd,bkhd->bhqk", dot, vblk)
        ds = p * (dp - delta) * scale
        dk = jnp.einsum("bhqk,bhqd->bkhd", ds, qt)
        dq_acc = dq_acc + jnp.einsum("bhqk,bkhd->bhqd", ds, kblk)
        return dq_acc, (dk, dv)

    dq0 = jnp.zeros((B, H, Sq, Dq), jnp.float32)
    starts = jnp.arange(k.shape[1] // chunk) * chunk
    dq, (dks, dvs) = jax.lax.scan(
        body, dq0, (_blocks(k, chunk), _blocks(v, chunk), starts))
    merge = lambda b, like: jnp.moveaxis(b, 0, 1).reshape(like.shape)
    return dq.transpose(0, 2, 1, 3), merge(dks, k), merge(dvs, v)


_flash_xla.defvjp(_flash_xla_fwd, _flash_xla_bwd)


def _maybe_flash(cfg: ModelConfig, parallel, q, k, v, *, causal,
                 rules=None) -> jax.Array:
    if parallel is not None and getattr(parallel, "use_flash_attention", False):
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k, v, causal=causal,
                                      softcap=cfg.logits_softcap)
    if parallel is not None and \
            getattr(parallel, "attention_impl", "dense") == "chunked":
        return _chunked_attn(q, k, v, causal=causal,
                             softcap=cfg.logits_softcap, rules=rules,
                             chunk=getattr(parallel, "attention_chunk", 1024))
    return _sdpa(q, k, v, causal=causal, softcap=cfg.logits_softcap,
                 rules=rules)


# ---------------------------------------------------------------------------
# GQA forward paths
# ---------------------------------------------------------------------------

def gqa_project_qkv(params, cfg: ModelConfig, x: jax.Array,
                    positions: jax.Array, rules=None):
    ct = cfg.compute_dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(ct))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(ct))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(ct))
    if cfg.use_rope:
        interleaved = cfg.rope_fraction < 1.0 and cfg.name.startswith("chatglm")
        sin, cos = rope_frequencies(cfg, positions)
        q = apply_rope(q, sin, cos, interleaved)
        k = apply_rope(k, sin, cos, interleaved)
    q = constrain(q, ("batch", "attn_seq", "heads", "head_dim"), rules)
    k = constrain(k, ("batch", None, "kv_heads", "head_dim"), rules)
    v = constrain(v, ("batch", None, "kv_heads", "head_dim"), rules)
    return q, k, v


def gqa_train(params, cfg: ModelConfig, x: jax.Array, rules=None,
              parallel=None, causal: bool = True) -> jax.Array:
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = gqa_project_qkv(params, cfg, x, positions, rules)
    out = _maybe_flash(cfg, parallel, q, k, v, causal=causal, rules=rules)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(cfg.compute_dtype))
    return constrain(out, ("batch", "seq", "embed_act"), rules)


def gqa_prefill(params, cfg: ModelConfig, x: jax.Array, rules=None,
                parallel=None) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = gqa_project_qkv(params, cfg, x, positions, rules)
    out = _maybe_flash(cfg, parallel, q, k, v, causal=True, rules=rules)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(cfg.compute_dtype))
    cache = {"k": k, "v": v}
    return constrain(out, ("batch", "seq", "embed_act"), rules), cache


def gqa_decode(params, cfg: ModelConfig, x: jax.Array, cache: Dict[str, jax.Array],
               pos: jax.Array, rules=None) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode. x: (B,1,d); cache k/v: (B,S,KV,hd); pos: scalar."""
    ct = cfg.compute_dtype
    positions = jnp.broadcast_to(pos[None, None], (x.shape[0], 1))
    q, k_new, v_new = gqa_project_qkv(params, cfg, x, positions, rules)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, pos, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, pos, axis=1)
    k = constrain(k, ("batch", "kv_seq", "kv_heads", "head_dim"), rules)
    v = constrain(v, ("batch", "kv_seq", "kv_heads", "head_dim"), rules)
    kv_mask = (jnp.arange(k.shape[1]) <= pos)[None, :]
    kv_mask = jnp.broadcast_to(kv_mask, (x.shape[0], k.shape[1]))
    out = _sdpa(q, k, v, causal=False, kv_mask=kv_mask,
                softcap=cfg.logits_softcap, rules=rules)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(ct))
    return constrain(out, ("batch", "seq", "embed_act"), rules), {"k": k, "v": v}


# ---------------------------------------------------------------------------
# MLA forward paths (DeepSeek-V3): expanded for train/prefill,
# weight-absorbed latent attention for decode (the MLA cache win).
# ---------------------------------------------------------------------------

def _mla_q(params, cfg: ModelConfig, x: jax.Array, positions):
    ct = cfg.compute_dtype
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if cfg.q_lora_rank:
        ql = jnp.einsum("bsd,dr->bsr", x, params["wq_a"].astype(ct))
        ql = _rms(ql, params["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", ql, params["wq_b"].astype(ct))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(ct))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    sin, cos = rope_frequencies(cfg, positions, head_dim=dr)
    q_rope = apply_rope(q_rope, sin, cos)
    return q_nope, q_rope


def _rms(x, scale, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), -1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def _mla_latent(params, cfg: ModelConfig, x: jax.Array, positions):
    """Compressed per-token latent: c_kv (B,S,kvr) + k_rope (B,S,dr)."""
    ct = cfg.compute_dtype
    kvr, dr = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    kv = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"].astype(ct))
    c_kv, k_rope = kv[..., :kvr], kv[..., kvr:]
    c_kv = _rms(c_kv, params["kv_norm"], cfg.norm_eps)
    sin, cos = rope_frequencies(cfg, positions, head_dim=dr)
    k_rope = apply_rope(k_rope[..., None, :], sin, cos)[..., 0, :]
    return c_kv, k_rope


def mla_train(params, cfg: ModelConfig, x: jax.Array, rules=None,
              parallel=None, return_cache: bool = False):
    ct = cfg.compute_dtype
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q_nope, q_rope = _mla_q(params, cfg, x, positions)
    c_kv, k_rope = _mla_latent(params, cfg, x, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["wk_b"].astype(ct))
    v = jnp.einsum("bsr,rhk->bshk", c_kv, params["wv_b"].astype(ct))
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                (B, S, cfg.num_heads, cfg.qk_rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    q = constrain(q, ("batch", "attn_seq", "heads", "head_dim"), rules)
    k = constrain(k, ("batch", None, "heads", "head_dim"), rules)
    out = _maybe_flash(cfg, parallel, q, k, v, causal=True, rules=rules)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(ct))
    out = constrain(out, ("batch", "seq", "embed_act"), rules)
    if return_cache:
        return out, {"c_kv": c_kv, "k_rope": k_rope}
    return out


def mla_decode(params, cfg: ModelConfig, x: jax.Array, cache, pos,
               rules=None):
    """Weight-absorbed decode: attend in the kv_lora latent space; the
    KV cache holds only (kvr + dr) floats/token — MLA's memory win."""
    ct = cfg.compute_dtype
    B = x.shape[0]
    kvr, dr, dn = cfg.kv_lora_rank, cfg.qk_rope_head_dim, cfg.qk_nope_head_dim
    positions = jnp.broadcast_to(pos[None, None], (B, 1))
    q_nope, q_rope = _mla_q(params, cfg, x, positions)  # (B,1,H,dn/dr)
    c_new, kr_new = _mla_latent(params, cfg, x, positions)
    c_kv = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_new, pos, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], kr_new, pos, axis=1)
    c_kv = constrain(c_kv, ("batch", "kv_seq", None), rules)
    k_rope = constrain(k_rope, ("batch", "kv_seq", None), rules)
    # absorb wk_b into the query:  q_lat (B,1,H,kvr)
    q_lat = jnp.einsum("bqhk,rhk->bqhr", q_nope, params["wk_b"].astype(ct))
    scale = 1.0 / jnp.sqrt(jnp.asarray(dn + dr, jnp.float32))
    # bf16 operands + fp32 accumulation: never materialize an fp32 copy
    # of the latent cache
    s_lat = jnp.einsum("bqhr,bsr->bhqs", q_lat, c_kv,
                       preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bqhk,bsk->bhqs", q_rope, k_rope,
                        preferred_element_type=jnp.float32)
    scores = (s_lat + s_rope) * scale
    scores = constrain(scores, ("batch", "heads", None, "kv_seq"), rules)
    S = c_kv.shape[1]
    mask = (jnp.arange(S) <= pos)[None, None, None, :]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqs,bsr->bqhr", probs.astype(c_kv.dtype), c_kv,
                     preferred_element_type=jnp.float32)  # latent ctx
    # absorbed value up-projection then output projection
    out = jnp.einsum("bqhr,rhk->bqhk", ctx.astype(ct), params["wv_b"].astype(ct))
    out = jnp.einsum("bqhk,hkd->bqd", out, params["wo"].astype(ct))
    return constrain(out, ("batch", "seq", "embed_act"), rules), \
        {"c_kv": c_kv, "k_rope": k_rope}


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_attn(params, cfg: ModelConfig, x: jax.Array, kv_cache, rules=None):
    """kv_cache: precomputed {"k","v"} from the encoder output."""
    ct = cfg.compute_dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(ct))
    out = _sdpa(q, kv_cache["k"], kv_cache["v"], causal=False)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(ct))
    return constrain(out, ("batch", "seq", "embed_act"), rules)


def cross_kv(params, cfg: ModelConfig, enc_out: jax.Array):
    ct = cfg.compute_dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"].astype(ct))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"].astype(ct))
    return {"k": k, "v": v}


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, n_layers: int,
               dtype=None):
    """Abstract shapes for one layer-stack's decode cache."""
    dt = dtype or cfg.compute_dtype
    if cfg.attention == "mla":
        return {
            "c_kv": jnp.zeros((n_layers, batch, seq_len, cfg.kv_lora_rank), dt),
            "k_rope": jnp.zeros((n_layers, batch, seq_len, cfg.qk_rope_head_dim), dt),
        }
    return {
        "k": jnp.zeros((n_layers, batch, seq_len, cfg.num_kv_heads, cfg.head_dim), dt),
        "v": jnp.zeros((n_layers, batch, seq_len, cfg.num_kv_heads, cfg.head_dim), dt),
    }
