"""Mixture-of-Experts: top-k router + capacity-bounded GROUPED dispatch.

Design notes (TPU adaptation):
- Dispatch uses argsort + scatter/gather (Megablocks-style) rather than the
  GShard one-hot einsum: the one-hot formulation inflates HLO FLOPs by
  O(T·E·C·d) of fake matmul work, which would poison the roofline compute
  term.  Scatter/gather costs bytes, not FLOPs — the honest accounting.
- Dispatch is GROUPED per batch row (GShard-style groups, at row
  granularity): the argsort/scatter indices are LOCAL to each row, so the
  batch dim stays sharded over "data" through the whole dispatch.  A
  global sort's data-dependent cross-shard indices force GSPMD to
  all-gather the token stream per MoE layer (measured on arctic train_4k:
  collective-bound at 414 s/step, 120+ GB of per-chip gathers).
- Expert weights are sharded over the "model" mesh axis (EP); token space
  stays on "data".  Capacity is enforced per (row, expert) —
  C = ceil(S·k·cf/E) — Switch-style dropping at row granularity.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.sharding import ParamDef, constrain


def moe_schema(cfg: ModelConfig):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    # expert dims get their own logical names: "experts" takes the TP
    # axis; "expert_ff" (not the d/embed dim!) carries the FSDP shard, so
    # each matmul's weights are LOCAL on its contraction dim — FSDP over
    # d forced a 1 GiB fp32 all-gather per matrix per layer (measured:
    # 6 x 272 GiB/chip/step on arctic train_4k)
    sch = {
        "router": ParamDef((d, E), ("embed", "experts"), init="scaled"),
        "wi_gate": ParamDef((E, d, ff), ("experts", "expert_embed", "expert_ff"),
                            init="scaled"),
        "wi_up": ParamDef((E, d, ff), ("experts", "expert_embed", "expert_ff"),
                          init="scaled"),
        "wo": ParamDef((E, ff, d), ("experts", "expert_ff", "expert_embed"),
                       init="scaled"),
    }
    if cfg.num_shared_experts:
        sf = ff * cfg.num_shared_experts
        sch["shared"] = {
            "wi_gate": ParamDef((d, sf), ("embed", "ff"), init="scaled"),
            "wi_up": ParamDef((d, sf), ("embed", "ff"), init="scaled"),
            "wo": ParamDef((sf, d), ("ff", "embed"), init="scaled"),
        }
    if cfg.dense_residual:
        sch["dense"] = {
            "wi_gate": ParamDef((d, ff), ("embed", "ff"), init="scaled"),
            "wi_up": ParamDef((d, ff), ("embed", "ff"), init="scaled"),
            "wo": ParamDef((ff, d), ("ff", "embed"), init="scaled"),
        }
    return sch


def _capacity(cfg: ModelConfig, tokens: int) -> int:
    """Per-group (= per batch row) expert capacity."""
    c = int(tokens * cfg.experts_per_token * cfg.expert_capacity_factor
            / cfg.num_experts) + 1
    if c >= 128:
        c = -(-c // 128) * 128  # MXU-aligned
    else:
        c = -(-c // 8) * 8
    return c


def _swiglu(x, wg, wu, wo, ct):
    g = jnp.einsum("ecd,edf->ecf", x, wg.astype(ct))
    u = jnp.einsum("ecd,edf->ecf", x, wu.astype(ct))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, wo.astype(ct))


def _swiglu_grouped(x, wg, wu, wo, ct):
    g = jnp.einsum("becd,edf->becf", x, wg.astype(ct))
    u = jnp.einsum("becd,edf->becf", x, wu.astype(ct))
    return jnp.einsum("becf,efd->becd", jax.nn.silu(g) * u, wo.astype(ct))


def _dense_swiglu(x, p, ct):
    g = jnp.einsum("...d,df->...f", x, p["wi_gate"].astype(ct))
    u = jnp.einsum("...d,df->...f", x, p["wi_up"].astype(ct))
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, p["wo"].astype(ct))


def router_scores(params, cfg: ModelConfig, x_flat: jax.Array):
    """Returns (gates (T,k), idx (T,k), probs (T,E)) — probs for aux loss."""
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    k = cfg.experts_per_token
    if getattr(cfg, "router_score", "softmax") == "sigmoid":  # deepseek-v3
        scores = jax.nn.sigmoid(logits)
        gates, idx = jax.lax.top_k(scores, k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
        probs = scores / jnp.maximum(scores.sum(-1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        gates, idx = jax.lax.top_k(probs, k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx, probs


def _row_dispatch(x_row: jax.Array, gates: jax.Array, idx: jax.Array,
                  E: int, C: int, ct):
    """Per-row sort/scatter. x_row: (S,d); gates/idx: (S,k).
    Returns (expert_in (E,C,d), se, st, sg, slot) — all row-local."""
    S, k = idx.shape
    e_flat = idx.reshape(-1)                               # (S*k,)
    tok_ids = jnp.repeat(jnp.arange(S, dtype=jnp.int32), k)
    g_flat = gates.reshape(-1)
    order = jnp.argsort(e_flat, stable=True)
    # gates cast to compute dtype HERE: a f32 gate in the combine multiply
    # promotes the backward scatter grads to f32 (doubles the cross-model
    # psum bytes)
    se, st, sg = e_flat[order], tok_ids[order], g_flat[order].astype(ct)
    counts = jnp.bincount(e_flat, length=E)
    start = jnp.cumsum(counts) - counts
    slot = jnp.arange(S * k, dtype=jnp.int32) - start[se]
    rows = x_row[st].astype(ct)
    expert_in = jnp.zeros((E, C, x_row.shape[-1]), ct).at[se, slot].add(
        rows, mode="drop", unique_indices=True)
    return expert_in, se, st, sg, slot


def moe_apply(params, cfg: ModelConfig, x: jax.Array, rules=None
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,d) -> (out, aux_loss)."""
    ct = cfg.compute_dtype
    B, S, d = x.shape
    k = cfg.experts_per_token
    E = cfg.num_experts
    C = _capacity(cfg, S)  # per-row capacity (grouped dispatch)

    # un-shard the seq dim up front: dispatch gathers on an SP-sharded
    # x make GSPMD emit (S*k, d)-sized fp32 all-reduces per layer
    # (measured 5 x 229 GiB/chip/step on arctic); one explicit bf16
    # all-gather of (S, d) here is ~10x cheaper, and the backward
    # becomes the matching reduce-scatter
    x = constrain(x, ("batch", None, "embed_act"), rules)
    x_flat = x.reshape(B * S, d)
    gates, idx, probs = router_scores(params, cfg, x_flat)
    gates = gates.reshape(B, S, k)
    idx = idx.reshape(B, S, k)

    # ---- grouped dispatch: indices stay row-local -> batch stays on DP
    expert_in, se, st, sg, slot = jax.vmap(
        lambda xr, g, i: _row_dispatch(xr, g, i, E, C, ct))(x, gates, idx)
    expert_in = constrain(expert_in, ("batch", "experts", None, "embed_act"),
                          rules)

    # ---- expert FFN (batched over batch x expert) --------------------------
    expert_out = _swiglu_grouped(expert_in, params["wi_gate"],
                                 params["wi_up"], params["wo"], ct)
    expert_out = constrain(expert_out,
                           ("batch", "experts", None, "embed_act"), rules)

    # ---- gather back + weighted combine (row-local again) ------------------
    def _row_combine(eo, se_r, st_r, sg_r, slot_r):
        back = eo.at[se_r, slot_r].get(mode="fill", fill_value=0.0)
        # combine in compute dtype: the cross-expert psum (over "model")
        # then moves bf16, not fp32 (half the wire bytes)
        return jnp.zeros((S, d), ct).at[st_r].add(
            back.astype(ct) * sg_r[:, None])

    out = jax.vmap(_row_combine)(expert_out, se, st, sg, slot)

    if cfg.num_shared_experts:
        out = out + _dense_swiglu(x, params["shared"], ct)
    if cfg.dense_residual:
        out = out + _dense_swiglu(x, params["dense"], ct)

    # ---- Switch-style load-balance aux loss --------------------------------
    frac = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(
        1.0 / (B * S * k))
    mean_p = probs.mean(axis=0)
    aux = E * jnp.sum(frac * mean_p) * cfg.router_aux_loss
    return constrain(out, ("batch", "seq", "embed_act"), rules), aux
