"""Lightweight task handles + DAG bookkeeping for the runtime.

Ray's core abstraction is the *future*: ``f.remote(...)`` returns an
ObjectRef immediately, dependencies between refs form a task graph, and
``ray.get`` drives the graph.  The SPMD translation keeps the shape of
that API — ``TaskRuntime.submit(...)`` returns a :class:`TaskFuture`,
futures may appear as inputs to later submissions (their results are
spliced in at execution time), and ``TaskRuntime.gather`` executes the
induced DAG in deterministic topological order — but the "cluster" under
it is the Executor backend layer (serial | vmap | shard_map), so a
*map* task's replicate axis becomes one batched program instead of B
scheduled workers.

Two task kinds:

  map    ``fn`` is mapped over the leading replicate axis of ``xs``
         through the scheduler (chunked, fault-tolerant) — the Ray task
         *pool* (one submit = B logical tasks);
  call   ``fn(*args)`` runs once on the host — the glue nodes of a
         graph (survivor selection between tuning rungs, reductions),
         Ray's plain ``@ray.remote`` function.

The graph is static once gathered: execution order is the deterministic
topological order of submission indices, so repeated gathers of the
same graph replay identically (the lineage property replicate keys
already give at the numerics level).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Sequence, Tuple

_UNSET = object()


@dataclasses.dataclass
class TaskFuture:
    """Handle for a submitted task.  Cheap, hashable by identity; holds
    its result after the owning runtime executed it."""

    task_id: int
    kind: str  # "map" | "call"
    fn: Callable[..., Any]
    xs: Any  # map tasks: pytree with replicate axis
    args: Tuple[Any, ...]
    deps: Tuple["TaskFuture", ...]
    label: str = ""
    _result: Any = _UNSET

    @property
    def done(self) -> bool:
        return self._result is not _UNSET

    def result(self) -> Any:
        if not self.done:
            raise RuntimeError(
                f"task {self.task_id} ({self.label or self.fn!r}) has not "
                "been executed — gather() it through its runtime first"
            )
        return self._result

    def _set(self, value: Any) -> None:
        self._result = value

    def __hash__(self) -> int:  # identity hash: ids are unique
        return self.task_id

    def __eq__(self, other: Any) -> bool:
        return self is other


def _iter_futures(obj: Any):
    """Yield TaskFutures reachable from ``obj`` (one level of list/tuple/
    dict nesting — the containers submissions actually use)."""
    if isinstance(obj, TaskFuture):
        yield obj
    elif isinstance(obj, (list, tuple)):
        for o in obj:
            yield from _iter_futures(o)
    elif isinstance(obj, dict):
        for o in obj.values():
            yield from _iter_futures(o)


def resolve(obj: Any) -> Any:
    """Replace every (completed) TaskFuture in ``obj`` by its result."""
    if isinstance(obj, TaskFuture):
        return obj.result()
    if isinstance(obj, (list, tuple)):
        return type(obj)(resolve(o) for o in obj)
    if isinstance(obj, dict):
        return {k: resolve(v) for k, v in obj.items()}
    return obj


class TaskGraph:
    """Submission log + topological executor.  Owned by a TaskRuntime;
    the runtime supplies the map-task execution primitive."""

    def __init__(self) -> None:
        self._counter = itertools.count()

    def submit(
        self,
        kind: str,
        fn: Callable[..., Any],
        xs: Any,
        args: Sequence[Any],
        deps: Sequence[TaskFuture] = (),
        label: str = "",
    ) -> TaskFuture:
        implicit = tuple(_iter_futures(xs)) + tuple(
            f for a in args for f in _iter_futures(a)
        )
        return TaskFuture(
            task_id=next(self._counter),
            kind=kind,
            fn=fn,
            xs=xs,
            args=tuple(args),
            deps=tuple(dict.fromkeys(implicit + tuple(deps))),
            label=label,
        )

    @staticmethod
    def order(targets: Sequence[TaskFuture]) -> Tuple[TaskFuture, ...]:
        """Deterministic topological order of every task ``targets``
        depend on (ties broken by submission id)."""
        seen: dict = {}
        out = []

        def visit(f: TaskFuture, stack: Tuple[int, ...]) -> None:
            if f.task_id in stack:
                raise ValueError(f"task graph has a cycle through task {f.task_id}")
            if f.task_id in seen:
                return
            for d in sorted(f.deps, key=lambda d: d.task_id):
                visit(d, stack + (f.task_id,))
            seen[f.task_id] = f
            out.append(f)

        for t in sorted(targets, key=lambda f: f.task_id):
            visit(t, ())
        return tuple(out)

    def execute(
        self,
        targets: Sequence[TaskFuture],
        run_map: Callable[[TaskFuture], Any],
    ) -> None:
        """Run every not-yet-done task ``targets`` depend on, in
        deterministic topological order.  ``run_map`` executes a map
        task (the runtime's chunked scheduler); call tasks run inline."""
        for fut in self.order(targets):
            if fut.done:
                continue
            if fut.kind == "map":
                fut._set(run_map(fut))
            else:
                fut._set(fut.fn(*resolve(fut.args)))
