"""Multi-process row-sharded moment reduction — the data-mesh layer.

The paper's deployment story (arXiv 2401.11932) is data parallelism
over a Ray cluster: rows live where they land, the iterative causal
steps reduce locally, and only fixed-size sufficient statistics cross
the wire.  Every estimator here already bottoms out in Gram-shaped
accumulators of at most (S·qL, qR) floats (``repro.core.moments`` /
``repro.kernels.seg_gram``), so the native reproduction is a
``shard_map`` over a ``("hosts", "devices")`` mesh: shard the row
axis, reduce per shard, combine the tiny accumulators — raw data
never moves.

Bit-identity contract
---------------------
Cross-shard float addition is non-associative, so a naive
local-fold + ``psum`` cannot match the single-process chunked
left-fold bit-for-bit.  The certified scheme sidesteps reassociation
entirely:

  ``reduction="ordered"`` (default)   the distributed path IS the
      "whole" strategy of ``blocked_reduce`` with its per-block
      ``lax.map`` sharded over the data mesh.  Rows pad to
      ``row_block``-sized blocks, the BLOCK axis shards across the
      mesh (``in_specs=P(("hosts", "devices"))``), each shard maps
      the SAME unbatched per-block graph over its local blocks, and
      ``out_specs`` reassembles the per-block partials in global
      block order.  An ordinary ``lax.scan`` left-fold OUTSIDE the
      shard_map then replays exactly the addition sequence the
      single-process "whole" strategy runs — and chunked ≡ whole is
      already structural (core.moments).  ``init`` seeds that fold,
      so ``MomentStore.ingest`` inherits its aligned-ingest bitwise
      certificate unchanged.  Extra all-padding blocks (the block
      count rounds up to a multiple of the shard count) contribute
      exactly +0.0 to every accumulator.

  ``reduction="psum"``   the wire-efficient mode: each shard
      left-folds its local partials, then one tree-order ``psum``
      combines the S accumulators.  S-1 adds cross the wire instead
      of nb partial tensors — but the addition order differs from
      the chunked path, so equality is tolerance-grade (float
      reassociation), NOT bitwise.  Use it when bandwidth matters
      more than the certificate.

Activation is context-scoped: ``use_data_mesh(dm)`` makes every
blocked moments entry point (``weighted_gram``, ``fold_gram``,
``iv_gram``, the seg_gram lowerings, store-ingest seeds) route
through ``dist_reduce`` at TRACE time.  ``TaskRuntime(data_mesh=...)``
wraps task closures in this context and extends the downgrade ladder
with a shard_map → single-host rung (runtime.scheduler).

Single-host simulation: run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for an 8-way
CPU mesh; ``launch/dist_smoke.py`` exercises the host axis with two
real ``jax.distributed`` processes (best-effort).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import _mk

Array = jax.Array

DATA_AXES: Tuple[str, str] = ("hosts", "devices")


class ShardLostError(RuntimeError):
    """A mesh shard died (or was injected dead) during a distributed
    reduction — the runtime ladder downgrades to single-host, the
    sweep engine isolates the loss to one column."""


@dataclasses.dataclass(frozen=True)
class DataMesh:
    """A row-sharding mesh: rows split across ``hosts × devices``,
    fixed-size Gram accumulators combine across it."""

    mesh: Any
    axis_names: Tuple[str, str] = DATA_AXES
    reduction: str = "ordered"  # "ordered" (bitwise) | "psum" (tolerance)

    @property
    def n_shards(self) -> int:
        s = 1
        for ax in self.axis_names:
            s *= self.mesh.shape[ax]
        return s

    @property
    def label(self) -> str:
        shape = "x".join(str(self.mesh.shape[ax]) for ax in self.axis_names)
        return f"{shape}:{self.reduction}"


def make_data_mesh(n_hosts: int = 0, n_devices: int = 0, *,
                   devices: Optional[Sequence] = None,
                   reduction: str = "ordered") -> DataMesh:
    """Build a ``("hosts", "devices")`` DataMesh.  Defaults: one host
    row per participating process (``jax.process_count()``), all local
    devices spread along the device axis.  Under a single process with
    one device this degrades to a (1, 1) mesh — same code path, no
    parallelism."""
    if reduction not in ("ordered", "psum"):
        raise ValueError(f"unknown reduction {reduction!r} "
                         "(expected ordered | psum)")
    devs = list(devices) if devices is not None else list(jax.devices())
    h = int(n_hosts) or max(1, jax.process_count())
    d = int(n_devices) or max(1, len(devs) // h)
    if len(devs) < h * d:
        raise RuntimeError(
            f"data mesh ({h}, {d}) needs {h * d} devices but only "
            f"{len(devs)} exist (set XLA_FLAGS="
            "--xla_force_host_platform_device_count=<N> before jax init)")
    mesh = _mk((h, d), DATA_AXES, devices=devs[: h * d])
    return DataMesh(mesh=mesh, reduction=reduction)


# -- context-scoped activation (thread-local: job threads must not ----------
# -- leak a mesh into each other's traces) ----------------------------------

_ACTIVE = threading.local()


def current_data_mesh() -> Optional[DataMesh]:
    """The innermost active DataMesh (None outside ``use_data_mesh``).
    Read at TRACE time by ``blocked_reduce`` / ``seg_reduce``."""
    stack = getattr(_ACTIVE, "stack", None)
    return stack[-1] if stack else None


@contextlib.contextmanager
def use_data_mesh(dm: Optional[DataMesh]):
    """Route every blocked moment reduction traced inside this context
    through ``dist_reduce`` over ``dm``.  ``None`` is a no-op (so call
    sites can pass an optional mesh unconditionally)."""
    if dm is None:
        yield None
        return
    stack = getattr(_ACTIVE, "stack", None)
    if stack is None:
        stack = _ACTIVE.stack = []
    stack.append(dm)
    try:
        yield dm
    finally:
        stack.pop()


# -- deterministic failure injection (tests: lost-shard ladder rung + -------
# -- per-column sweep isolation) --------------------------------------------

_FAIL_BUDGET = [0]


def inject_shard_failure(n: int = 1) -> None:
    """Arm the next ``n`` distributed reductions to raise
    ``ShardLostError`` at trace time — a deterministic stand-in for a
    dead worker.  The budget is global and one-shot per reduction;
    ``inject_shard_failure(0)`` disarms."""
    _FAIL_BUDGET[0] = int(n)


def _maybe_fail() -> None:
    if _FAIL_BUDGET[0] > 0:
        _FAIL_BUDGET[0] -= 1
        raise ShardLostError(
            "injected shard failure (inject_shard_failure)")


# -- shard_map compat (jax.shard_map landed post-0.4; the experimental ------
# -- import is the 0.4.x spelling) ------------------------------------------

def _smap(f, mesh, in_specs, out_specs):
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        try:
            return sm(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_vma=False)
        except TypeError:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def dist_reduce(block_fn: Callable[..., Any], arrays: Sequence[Array], *,
                row_block: int, dm: Optional[DataMesh] = None,
                pad_values: Optional[Sequence] = None,
                init: Optional[Any] = None,
                reduction: Optional[str] = None) -> Any:
    """Row-sharded ``blocked_reduce``: split ``row_block``-sized blocks
    of the leading axis across ``dm``'s mesh, evaluate ``block_fn`` per
    block per shard, combine the fixed-size accumulators.

    ``reduction="ordered"`` is bit-identical to the single-process
    chunked/whole strategies at equal ``row_block`` (module docstring);
    ``"psum"`` trades the certificate for one tree-order all-reduce.
    ``block_fn``'s contract is blocked_reduce's: row-additive, zero
    rows contribute exact zeros, ``pad_values`` pins per-array padding
    constants (e.g. -1 fold ids), ``init`` seeds the ordered fold.
    """
    dm = dm if dm is not None else current_data_mesh()
    if dm is None:
        raise ValueError("dist_reduce needs a DataMesh (pass dm= or "
                         "enter use_data_mesh)")
    _maybe_fail()
    arrays = tuple(arrays)
    n = arrays[0].shape[0]
    r = int(row_block)
    if r <= 0:
        raise ValueError("dist_reduce requires row_block > 0")
    tmap = jax.tree_util.tree_map
    S = dm.n_shards
    # block count rounds up to a multiple of the shard count so the
    # block axis splits evenly; the extra blocks are all padding and
    # contribute exactly +0.0 per the block_fn zero-row contract
    nb = -(-n // r)
    nb = -(-nb // S) * S
    pad = nb * r - n
    if pad:
        pv = pad_values or (0,) * len(arrays)
        arrays = tuple(
            jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1),
                    constant_values=v)
            for a, v in zip(arrays, pv))
    blocks = tuple(a.reshape((nb, r) + a.shape[1:]) for a in arrays)
    spec = P(dm.axis_names)
    mode = reduction or dm.reduction

    if mode == "ordered":
        def shard(*bs):
            # the SAME unbatched per-block graph as the single-process
            # "whole" strategy — lax.map, NOT vmap (core.moments)
            return lax.map(lambda xs: block_fn(*xs), bs)

        parts = _smap(shard, dm.mesh, (spec,) * len(blocks),
                      spec)(*blocks)
        acc0 = (init if init is not None
                else tmap(lambda x: jnp.zeros(x.shape[1:], x.dtype), parts))
        out, _ = lax.scan(lambda acc, g: (tmap(jnp.add, acc, g), None),
                          acc0, parts)
        return out

    if mode != "psum":
        raise ValueError(f"unknown reduction {mode!r} "
                         "(expected ordered | psum)")

    axes = dm.axis_names

    def shard(*bs):
        parts = lax.map(lambda xs: block_fn(*xs), bs)
        zero = tmap(lambda x: jnp.zeros(x.shape[1:], x.dtype), parts)
        local, _ = lax.scan(lambda acc, g: (tmap(jnp.add, acc, g), None),
                            zero, parts)
        return tmap(lambda x: lax.psum(x, axes), local)

    out = _smap(shard, dm.mesh, (spec,) * len(blocks), P())(*blocks)
    return out if init is None else tmap(jnp.add, init, out)
