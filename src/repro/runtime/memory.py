"""Memory-aware replicate batching: how many replicates fit one device.

Ray sizes task placement by declared resources; XLA has no such
declaration, but the compiled program *is* inspectable: lowering the
vmapped replicate closure at a probe batch size and parsing the
post-optimization HLO with ``launch.hlo_cost.peak_temp_bytes`` yields
the largest temporary the program materializes.  Two probes (batch 1
and batch ``PROBE_CHUNK``) fit the affine model

    peak(c) ≈ base + slope · c

— ``base`` is the replicate-independent footprint (the shared data
tensors every replicate reads), ``slope`` the per-replicate increment
(the (c, k, n) weight tensors and fold-batched Gram stacks that grow
with the batch).  The scheduler then solves for the largest chunk whose
predicted peak stays under ``CausalConfig.runtime_memory_budget``, so
``n_bootstrap=2000`` at industrial n streams in chunks instead of
OOMing the one-big-vmap path.

Probes are compile-only (no execution) and cached per (closure, input
signature), so repeated ``map`` calls with the same closure — the hot
pattern everywhere in this codebase — lower at most twice.
"""

from __future__ import annotations

import dataclasses
import weakref
from typing import Any, Optional, Tuple

import jax

from repro.launch.hlo_cost import cost_summary, peak_temp_bytes

PROBE_CHUNK = 8


@dataclasses.dataclass(frozen=True)
class MemoryModel:
    """Affine peak-memory model of one replicate chunk."""

    base: float  # replicate-independent bytes (shared data passes)
    slope: float  # incremental bytes per replicate in the batch

    def peak(self, chunk: int) -> float:
        return self.base + self.slope * max(chunk, 0)

    def max_chunk(self, budget_bytes: int, b: int) -> int:
        """Largest chunk (≤ b) whose predicted peak fits the budget.
        Never returns less than 1 — a single replicate must run even if
        it alone exceeds the budget (the serial floor)."""
        if budget_bytes <= 0 or self.peak(b) <= budget_bytes:
            return b
        if self.slope <= 0:
            return b
        c = int((budget_bytes - self.base) // self.slope)
        return max(1, min(c, b))


def _signature(xs: Any, args: Tuple[Any, ...]) -> Tuple:
    leaves = jax.tree_util.tree_leaves((xs, args))
    return tuple(
        (tuple(getattr(leaf, "shape", ())), str(getattr(leaf, "dtype", type(leaf))))
        for leaf in leaves
    )


def _element_spec(xs: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), xs
    )


def _spec(tree: Any) -> Any:
    # scalar / non-array pass-through args stay concrete: executors
    # accept them (jit bakes them in), so lowering must too
    return jax.tree_util.tree_map(
        lambda x: (
            jax.ShapeDtypeStruct(x.shape, x.dtype) if hasattr(x, "shape") else x
        ),
        tree,
    )


def _compiled_text(fn, xs: Any, args: Tuple[Any, ...], chunk: int) -> str:
    """Post-optimization HLO of the ``chunk``-replicate vmapped program
    (compile-only, no execution)."""
    elem = _element_spec(xs)
    xs_spec = jax.tree_util.tree_map(
        lambda e: jax.ShapeDtypeStruct((chunk,) + e.shape, e.dtype), elem
    )

    def batched(xs_, *a):
        return jax.vmap(lambda x_: fn(x_, *a))(xs_)

    lowered = jax.jit(batched).lower(xs_spec, *_spec(args))
    return lowered.compile().as_text()


def probe_peak_bytes(fn, xs: Any, args: Tuple[Any, ...], chunk: int) -> int:
    """Peak-temp bytes of the ``chunk``-replicate vmapped program, from
    compiled HLO (no execution)."""
    return peak_temp_bytes(_compiled_text(fn, xs, args, chunk))


# Closure -> {input signature -> MemoryModel}.  Weak keys let dead
# closures drop out, mirroring the executors' _JitCache.
_MODEL_CACHE: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def memory_model(fn, xs: Any, args: Tuple[Any, ...], b: int) -> Optional[MemoryModel]:
    """Fit (and cache) the affine peak model for ``fn`` on these input
    shapes.  Returns None when the closure cannot be lowered from specs
    alone — the scheduler then falls back to unchunked execution."""
    sig = _signature(xs, args)
    per_fn = _MODEL_CACHE.setdefault(fn, {})
    if sig in per_fn:
        return per_fn[sig]
    try:
        p1 = probe_peak_bytes(fn, xs, args, 1)
        c2 = min(max(b, 1), PROBE_CHUNK)
        if c2 <= 1:
            model = MemoryModel(base=0.0, slope=float(p1))
        else:
            p2 = probe_peak_bytes(fn, xs, args, c2)
            slope = max((p2 - p1) / (c2 - 1), 0.0)
            model = MemoryModel(base=max(p1 - slope, 0.0), slope=slope)
    except Exception:
        model = None
    per_fn[sig] = model
    return model


@dataclasses.dataclass(frozen=True)
class ChunkCost:
    """Compile-time cost truth for ONE chunk size of a mapped closure —
    what the cost audit (repro.obs.audit) joins to measured chunk
    durations.  ``peak_temp_bytes`` is the exact HLO peak at this size
    (vs the affine model's interpolation); flops/hbm_bytes are the
    trip-count-aware roofline totals of one chunk execution."""

    chunk: int
    peak_temp_bytes: float
    flops: float
    hbm_bytes: float


# Closure -> {(input signature, chunk) -> Optional[ChunkCost]}.  Same
# weak-key shape as _MODEL_CACHE: audits of a hot closure lower each
# chunk size at most once.
_COST_CACHE: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def probe_chunk_cost(
    fn, xs: Any, args: Tuple[Any, ...], chunk: int
) -> Optional[ChunkCost]:
    """Lower the ``chunk``-sized program once and read its exact peak /
    roofline costs off the compiled HLO.  Returns None when the closure
    cannot be lowered from specs alone (the audit then skips the chunk
    rather than guessing)."""
    sig = (_signature(xs, args), int(chunk))
    per_fn = _COST_CACHE.setdefault(fn, {})
    if sig in per_fn:
        return per_fn[sig]
    try:
        cs = cost_summary(_compiled_text(fn, xs, args, chunk), world=1)
        cost = ChunkCost(
            chunk=int(chunk),
            peak_temp_bytes=cs["peak_temp_bytes"],
            flops=cs["flops"],
            hbm_bytes=cs["bytes"],
        )
    except Exception:
        cost = None
    per_fn[sig] = cost
    return cost
