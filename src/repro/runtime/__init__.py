# Ray-style task-graph runtime over the Executor backends — the
# scheduler layer the paper attributes to Ray, translated to SPMD:
#   future.py     TaskFuture handles + deterministic DAG execution
#                 (submit/call/gather — Ray's ObjectRef semantics)
#   memory.py     affine peak-memory model of the lowered replicate
#                 closure (launch.hlo_cost probes) -> auto chunk sizing
#   scheduler.py  TaskRuntime: memory-aware chunked maps, per-chunk
#                 retry with backend downgrade (shard_map -> vmap ->
#                 serial, bit-identical results), nested (outer x inner)
#                 parallelism via map_product
from repro.runtime.future import TaskFuture, TaskGraph, resolve
from repro.runtime.memory import (
    ChunkCost,
    MemoryModel,
    memory_model,
    probe_chunk_cost,
    probe_peak_bytes,
)
from repro.runtime.scheduler import (
    DOWNGRADE,
    EventLog,
    RuntimeEvent,
    TaskRuntime,
    as_runtime,
)

__all__ = [
    "TaskFuture",
    "TaskGraph",
    "resolve",
    "ChunkCost",
    "MemoryModel",
    "memory_model",
    "probe_chunk_cost",
    "probe_peak_bytes",
    "DOWNGRADE",
    "EventLog",
    "RuntimeEvent",
    "TaskRuntime",
    "as_runtime",
]
