"""repro.runtime — the Ray-style task-graph runtime.

The scheduling layer the paper attributes to Ray, over the Executor
backends (``serial | vmap | shard_map``): ``TaskFuture`` handles and
deterministic DAG execution give Ray's ``ObjectRef`` semantics
(``future``), an affine peak-memory model fitted from two HLO probes
auto-sizes replicate chunks against ``runtime_memory_budget``
(``memory``), and ``TaskRuntime`` (``scheduler``) streams the chunks
with per-chunk retry down the backend-downgrade ladder — results stay
bit-identical to the no-failure run wherever the replicate-invariance
contract holds.  Bootstrap, jackknife, crossfit, tuning, refutation,
and sweep cells all dispatch through it.
"""
#   future.py     TaskFuture handles + deterministic DAG execution
#                 (submit/call/gather — Ray's ObjectRef semantics)
#   memory.py     affine peak-memory model of the lowered replicate
#                 closure (launch.hlo_cost probes) -> auto chunk sizing
#   scheduler.py  TaskRuntime: memory-aware chunked maps, per-chunk
#                 retry with backend downgrade (shard_map -> vmap ->
#                 serial, bit-identical results), nested (outer x inner)
#                 parallelism via map_product
#   distributed.py row-sharded moment reduction over a ("hosts",
#                 "devices") data mesh — ordered mode bitwise vs the
#                 single-host chunked path; TaskRuntime(data_mesh=...)
#                 adds the shard_map -> single-host ladder rung
#   jobs.py       minimal job-submission + event-stream API over
#                 sweeps: submit a SweepSpec, poll status, subscribe
#                 to per-column completion events (EventLog-backed)
from repro.runtime.distributed import (
    DataMesh,
    ShardLostError,
    current_data_mesh,
    dist_reduce,
    inject_shard_failure,
    make_data_mesh,
    use_data_mesh,
)
from repro.runtime.future import TaskFuture, TaskGraph, resolve
from repro.runtime.memory import (
    ChunkCost,
    MemoryModel,
    memory_model,
    probe_chunk_cost,
    probe_peak_bytes,
)
from repro.runtime.scheduler import (
    DOWNGRADE,
    EventLog,
    RuntimeEvent,
    TaskRuntime,
    as_runtime,
)

from repro.runtime.jobs import JobManager, SweepJob

__all__ = [
    "DataMesh",
    "ShardLostError",
    "current_data_mesh",
    "dist_reduce",
    "inject_shard_failure",
    "make_data_mesh",
    "use_data_mesh",
    "JobManager",
    "SweepJob",
    "TaskFuture",
    "TaskGraph",
    "resolve",
    "ChunkCost",
    "MemoryModel",
    "memory_model",
    "probe_chunk_cost",
    "probe_peak_bytes",
    "DOWNGRADE",
    "EventLog",
    "RuntimeEvent",
    "TaskRuntime",
    "as_runtime",
]
