"""repro.runtime — the Ray-style task-graph runtime.

The scheduling layer the paper attributes to Ray, over the Executor
backends (``serial | vmap | shard_map``): ``TaskFuture`` handles and
deterministic DAG execution give Ray's ``ObjectRef`` semantics
(``future``), an affine peak-memory model fitted from two HLO probes
auto-sizes replicate chunks against ``runtime_memory_budget``
(``memory``), and ``TaskRuntime`` (``scheduler``) streams the chunks
with per-chunk retry down the backend-downgrade ladder — results stay
bit-identical to the no-failure run wherever the replicate-invariance
contract holds.  Bootstrap, jackknife, crossfit, tuning, refutation,
and sweep cells all dispatch through it.
"""
#   future.py     TaskFuture handles + deterministic DAG execution
#                 (submit/call/gather — Ray's ObjectRef semantics)
#   memory.py     affine peak-memory model of the lowered replicate
#                 closure (launch.hlo_cost probes) -> auto chunk sizing
#   scheduler.py  TaskRuntime: memory-aware chunked maps, per-chunk
#                 retry with backend downgrade (shard_map -> vmap ->
#                 serial, bit-identical results), nested (outer x inner)
#                 parallelism via map_product
from repro.runtime.future import TaskFuture, TaskGraph, resolve
from repro.runtime.memory import (
    ChunkCost,
    MemoryModel,
    memory_model,
    probe_chunk_cost,
    probe_peak_bytes,
)
from repro.runtime.scheduler import (
    DOWNGRADE,
    EventLog,
    RuntimeEvent,
    TaskRuntime,
    as_runtime,
)

__all__ = [
    "TaskFuture",
    "TaskGraph",
    "resolve",
    "ChunkCost",
    "MemoryModel",
    "memory_model",
    "probe_chunk_cost",
    "probe_peak_bytes",
    "DOWNGRADE",
    "EventLog",
    "RuntimeEvent",
    "TaskRuntime",
    "as_runtime",
]
