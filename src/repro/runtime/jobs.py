"""Minimal job-submission + event-stream API over sweeps — the Ray
job-server shape (submit / poll / subscribe) reproduced natively.

A ``SweepJob`` runs ``repro.sweep.sweep`` on a background thread and
streams one completion event per column into an ``EventLog`` ring
buffer (the same bounded structure the scheduler uses), so a client
can poll status cheaply, subscribe to per-column completions as they
land, and fetch the final ``EffectPanel`` when the job settles.
Elasticity composes: pass ``checkpoint=`` and a failed column (lost
shard, bad cell) costs exactly that column on the next submission of
the same spec (sweep.engine resume).

Events are RuntimeEvents with action ``"column"`` (label = estimator
name, chunk_index = column index, detail = "" or the column error),
bracketed by ``"submitted"`` / ``"done"`` / ``"failed"`` markers.
With a tracer, each job runs under a ``job.sweep`` span and bumps
``jobs.*`` counters on the tracer's metrics registry.
"""
from __future__ import annotations

import itertools
import threading
import time
from typing import Any, Dict, Iterator, Optional

from repro.obs.trace import Tracer, maybe_span
from repro.runtime.scheduler import EventLog, RuntimeEvent

PENDING, RUNNING, DONE, FAILED = "pending", "running", "done", "failed"


class SweepJob:
    """Handle for one submitted sweep: status, per-column events, and
    the result panel.  Thread-safe; created by ``JobManager.submit``."""

    def __init__(self, job_id: int, spec, n_columns: int,
                 events_maxlen: int = 512):
        self.job_id = job_id
        self.spec = spec
        self.n_columns = int(n_columns)
        self.events = EventLog(maxlen=events_maxlen)
        self._cond = threading.Condition()
        self._status = PENDING
        self._columns_done = 0
        self._columns_failed = 0
        self._panel = None
        self._error: Optional[BaseException] = None
        self._thread: Optional[threading.Thread] = None

    # -- producer side (JobManager's worker thread) ---------------------
    def _emit(self, event: RuntimeEvent) -> None:
        with self._cond:
            self.events.append(event)
            self._cond.notify_all()

    def _on_column(self, index: int, col) -> None:
        err = getattr(col, "error", "") or ""
        with self._cond:
            self._columns_done += 1
            if err:
                self._columns_failed += 1
            self.events.append(
                RuntimeEvent("column", getattr(col, "estimator", ""),
                             index, "", str(err)))
            self._cond.notify_all()

    def _finish(self, panel=None, error: Optional[BaseException] = None):
        with self._cond:
            self._panel = panel
            self._error = error
            self._status = FAILED if error is not None else DONE
            self.events.append(
                RuntimeEvent(FAILED if error is not None else DONE,
                             f"job{self.job_id}", -1, "",
                             str(error) if error is not None else ""))
            self._cond.notify_all()

    # -- consumer side --------------------------------------------------
    def status(self) -> Dict[str, Any]:
        with self._cond:
            return {
                "job_id": self.job_id,
                "status": self._status,
                "columns_done": self._columns_done,
                "columns_failed": self._columns_failed,
                "n_columns": self.n_columns,
                "events_total": self.events.total,
            }

    def done(self) -> bool:
        with self._cond:
            return self._status in (DONE, FAILED)

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until the job settles (True) or ``timeout`` elapses
        (False)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while self._status not in (DONE, FAILED):
                left = (None if deadline is None
                        else deadline - time.monotonic())
                if left is not None and left <= 0:
                    return False
                self._cond.wait(left)
            return True

    def result(self, timeout: Optional[float] = None):
        """The EffectPanel (raises the job's error on FAILED)."""
        if not self.wait(timeout):
            raise TimeoutError(f"job {self.job_id} still {self._status}")
        if self._error is not None:
            raise self._error
        return self._panel

    def events_since(self, start_total: int):
        """Buffered events at/after the ``events.total`` checkpoint —
        the poll-style consumer (EventLog.since semantics)."""
        with self._cond:
            return self.events.since(start_total)

    def subscribe(self, *, poll_s: float = 0.05
                  ) -> Iterator[RuntimeEvent]:
        """Yield events in order as they land, ending when the job
        settles (the terminal done/failed event is yielded last)."""
        cursor = 0
        while True:
            with self._cond:
                batch = self.events.since(cursor)
                cursor = self.events.total
                settled = self._status in (DONE, FAILED)
                if not batch and not settled:
                    self._cond.wait(poll_s)
                    continue
            for ev in batch:
                yield ev
            if settled and cursor >= self.events.total:
                return


class JobManager:
    """Submit sweeps as background jobs; poll or subscribe for
    progress.  One manager per process is plenty — jobs are threads,
    and jax tracing is thread-safe (each job's runtime keeps its own
    jit caches via fresh closures)."""

    def __init__(self, *, tracer: Optional[Tracer] = None):
        self.tracer = tracer
        self._jobs: Dict[int, SweepJob] = {}
        self._ids = itertools.count()
        self._lock = threading.Lock()

    def submit(self, spec, *, X, y, t, segment_ids, z=None, key=None,
               block: bool = False, events_maxlen: int = 512,
               **sweep_kwargs) -> SweepJob:
        """Start ``sweep(spec, ...)`` as a job.  ``sweep_kwargs`` pass
        through (executor, data_mesh, checkpoint, resume, mode, ...);
        ``block=True`` runs inline — deterministic, for tests and
        scripted pipelines."""
        from repro.sweep import sweep  # lazy: runtime must not import sweep

        with self._lock:
            job = SweepJob(next(self._ids), spec,
                           n_columns=len(spec.columns),
                           events_maxlen=events_maxlen)
            self._jobs[job.job_id] = job
        job._emit(RuntimeEvent("submitted", f"job{job.job_id}", -1, "",
                               f"columns={job.n_columns}"))
        tr = self.tracer
        if tr is not None:
            tr.metrics.counter("jobs.submitted").inc()

        def run():
            with self._lock:
                job._status = RUNNING
            try:
                with maybe_span(tr, "job.sweep", cat="jobs",
                                job_id=job.job_id,
                                n_columns=job.n_columns):
                    panel = sweep(spec, X=X, y=y, t=t,
                                  segment_ids=segment_ids, z=z, key=key,
                                  column_callback=job._on_column,
                                  **sweep_kwargs)
            except BaseException as e:  # noqa: BLE001 — job boundary
                if tr is not None:
                    tr.metrics.counter("jobs.failed").inc()
                job._finish(error=e)
                return
            if tr is not None:
                tr.metrics.counter("jobs.done").inc()
                tr.metrics.counter("jobs.columns").inc(job.n_columns)
            job._finish(panel=panel)

        if block:
            run()
        else:
            th = threading.Thread(target=run,
                                  name=f"sweep-job-{job.job_id}",
                                  daemon=True)
            job._thread = th
            th.start()
        return job

    def get(self, job_id: int) -> SweepJob:
        with self._lock:
            return self._jobs[job_id]

    def status(self, job_id: int) -> Dict[str, Any]:
        return self.get(job_id).status()

    def jobs(self) -> Dict[int, Dict[str, Any]]:
        with self._lock:
            handles = list(self._jobs.values())
        return {j.job_id: j.status() for j in handles}
