"""The task scheduler: Ray's pool semantics over Executor backends.

``TaskRuntime`` grows PR 1's flat ``Executor.map`` into the scheduling
layer the paper attributes to Ray:

  chunked scheduling   the replicate axis is split into chunks sized by
                       the affine peak-memory model of the lowered
                       closure (runtime.memory) against a per-device
                       budget — ``n_bootstrap=2000`` streams instead of
                       OOMing one giant vmap;
  fault tolerance      each chunk retries down the backend ladder
                       (shard_map → vmap → serial) on failure, the SPMD
                       stand-in for Ray re-executing a lost task on
                       another worker.  Results stay bit-identical:
                       per-replicate numerics are batch-size-invariant
                       and serial ≡ vmap bitwise, so a downgraded chunk
                       computes the same bits the healthy backend would
                       have;
  deterministic order  chunks are dispatched and concatenated in fixed
                       replicate order, whatever backends ran them;
  nested parallelism   ``map_product`` flattens two parallel axes
                       (replicate × fold, trial × fold) into ONE
                       batched program, with the same chunked/fault-
                       tolerant machinery subdividing the product axis
                       when the budget demands — the scheduler, not the
                       caller, decides how much runs at once;
  futures              ``submit``/``call``/``gather`` (runtime.future)
                       express dependent stages — successive-halving
                       rungs, refuter panels — as a task DAG instead of
                       hand-ordered loops.

A ``TaskRuntime`` with no budget, no explicit chunk, and a healthy
backend degenerates to exactly one ``Executor.map`` call, so migrating
callers onto the runtime costs nothing on the happy path.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import weakref
from typing import Any, Callable, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.inference.executor import Executor, jit_miss_hook, make_executor
from repro.obs.audit import ChunkAudit
from repro.obs.trace import Tracer, maybe_span
from repro.runtime.future import TaskFuture, TaskGraph, resolve
from repro.runtime.memory import MemoryModel, memory_model, probe_chunk_cost

# The fault-tolerance ladder: each backend's failure falls back to the
# next-simpler one.  serial has no fallback — its failure is the task's.
DOWNGRADE: dict = {"shard_map": "vmap", "vmap": "serial", "serial": None}


@dataclasses.dataclass(frozen=True)
class RuntimeEvent:
    """One scheduling decision or recovery, for tests and reports."""

    action: str  # "chunk" | "retry" | "downgrade"
    label: str
    chunk_index: int = -1
    backend: str = ""
    detail: str = ""


class EventLog:
    """Bounded RuntimeEvent record: list-like for readers, ring-buffered
    so a long-lived runtime (thousands of ``map`` calls) cannot grow an
    unbounded host-side list.  ``total`` counts every event ever
    appended; ``since(start_total)`` recovers a suffix recorded from a
    ``total`` checkpoint even after older entries were dropped — the
    drop-safe replacement for ``events[start:]`` slicing.  The tracer is
    the durable record; this log is the cheap always-on tail."""

    def __init__(self, maxlen: int = 512):
        self._buf: "collections.deque[RuntimeEvent]" = collections.deque(
            maxlen=maxlen
        )
        self._total = 0

    def append(self, event: RuntimeEvent) -> None:
        self._buf.append(event)
        self._total += 1

    @property
    def total(self) -> int:
        return self._total

    @property
    def dropped(self) -> int:
        return self._total - len(self._buf)

    def since(self, start_total: int) -> Tuple[RuntimeEvent, ...]:
        """Events appended at or after the ``total`` checkpoint
        ``start_total`` that are still buffered."""
        skip = max(0, start_total - self.dropped)
        return tuple(self._buf)[skip:]

    def clear(self) -> None:
        self._buf.clear()
        self._total = 0

    def __len__(self) -> int:
        return len(self._buf)

    def __iter__(self) -> Iterator[RuntimeEvent]:
        return iter(tuple(self._buf))

    def __getitem__(self, ix):
        return tuple(self._buf)[ix]


def _leading_dim(xs: Any) -> int:
    leaves = jax.tree_util.tree_leaves(xs)
    if not leaves:
        raise ValueError("runtime.map needs at least one array input")
    return leaves[0].shape[0]


def _slice(xs: Any, lo: int, hi: int) -> Any:
    return jax.tree_util.tree_map(lambda x: x[lo:hi], xs)


def _empty_like_mapped(fn, xs: Any, args: Tuple[Any, ...]) -> Any:
    """Zero-replicate output: (0, ...) stacked leaves with the shapes
    and dtypes one application of ``fn`` would produce."""
    elem = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), xs
    )
    arg_spec = tuple(
        jax.tree_util.tree_map(
            lambda a: (
                jax.ShapeDtypeStruct(a.shape, a.dtype) if hasattr(a, "shape") else a
            ),
            arg,
        )
        for arg in args
    )
    out = jax.eval_shape(fn, elem, *arg_spec)
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros((0,) + tuple(s.shape), s.dtype), out
    )


class TaskRuntime:
    """Memory-aware, fault-tolerant scheduler over Executor backends.

    Parameters
    ----------
    executor       backend name (serial | vmap | shard_map) or Executor
                   instance — the *preferred* backend; failures walk the
                   DOWNGRADE ladder from there.
    memory_budget  bytes/device the batched program may peak at; 0
                   disables the memory model (one chunk).
    chunk          explicit replicate chunk size; 0 defers to the
                   memory model (CausalConfig.runtime_chunk).
    max_retries    extra attempts a chunk gets after its first failure
                   (each attempt moves one rung down the ladder).
    data_mesh      optional runtime.distributed.DataMesh: task closures
                   trace with the mesh active, so every blocked moment
                   reduction inside them row-shards across
                   ("hosts", "devices") — bitwise the single-host
                   result in "ordered" mode.  The ladder gains a
                   shard_map → single-host rung on top: a lost shard
                   (ShardLostError or any mesh failure) retries the
                   SAME chunk without the mesh, same bits.
    tracer         optional repro.obs.Tracer: spans around map / chunk /
                   DAG-node execution (block_until_ready-honest), chunk
                   latency histograms, downgrade/retry/jit-miss
                   counters, and the predicted-vs-measured cost audit
                   joining each chunk to its hlo_cost probes.  None (the
                   default) records nothing and forces nothing — the
                   same compiled programs run either way.
    events_maxlen  ring-buffer capacity of the always-on RuntimeEvent
                   tail (EventLog; the tracer is the unbounded record).
    """

    # fn -> fused (outer, inner) wrapper, weak so dead closures drop out
    # (same pattern as the executors' _JitCache: the executor keys its
    # compiled cache on the closure object, so the wrapper must be
    # stable per fn).
    _PRODUCT_FNS: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()

    def __init__(
        self,
        executor="vmap",
        *,
        memory_budget: int = 0,
        chunk: int = 0,
        max_retries: int = 2,
        mesh=None,
        rules=None,
        data_mesh=None,
        tracer: Optional[Tracer] = None,
        events_maxlen: int = 512,
    ):
        self._primary = make_executor(executor, mesh=mesh, rules=rules)
        self._mesh = mesh
        self._rules = rules
        self.data_mesh = data_mesh
        self.memory_budget = int(memory_budget)
        self.chunk = int(chunk)
        self.max_retries = int(max_retries)
        self.tracer = tracer
        self.events = EventLog(maxlen=events_maxlen)
        self._graph = TaskGraph()
        # fn -> mesh-activating wrapper, per runtime: the executor jit
        # cache keys on the closure OBJECT, so mesh and plain traces of
        # the same fn must go through distinct stable closures
        self._mesh_fns: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()

    def _emit(self, event: RuntimeEvent) -> None:
        """Record one scheduling decision: always into the bounded
        EventLog; when tracing, also as an instant marker + counter."""
        self.events.append(event)
        tr = self.tracer
        if tr is not None:
            tr.instant(
                f"runtime.event.{event.action}",
                cat="runtime",
                label=event.label,
                chunk_index=event.chunk_index,
                backend=event.backend,
                detail=event.detail,
            )
            tr.metrics.counter(f"runtime.events.{event.action}").inc()

    # -- identity -------------------------------------------------------
    @property
    def name(self) -> str:
        return self._primary.name

    # -- backend ladder -------------------------------------------------
    def _ladder(self) -> Tuple[Executor, ...]:
        chain: List[Executor] = [self._primary]
        nxt = DOWNGRADE.get(self._primary.name, "vmap")
        while nxt is not None:
            chain.append(make_executor(nxt, mesh=self._mesh, rules=self._rules))
            nxt = DOWNGRADE.get(nxt)
        # dedupe by backend name, keeping first occurrence
        seen, out = set(), []
        for exe in chain:
            if exe.name not in seen:
                seen.add(exe.name)
                out.append(exe)
        return tuple(out)

    def _mesh_variant(self, fn):
        """A stable per-(runtime, fn) closure whose trace runs with the
        data mesh active — so blocked moments inside ``fn`` row-shard
        (runtime.distributed), and the mesh trace caches separately
        from the plain one."""
        wrapped = self._mesh_fns.get(fn)
        if wrapped is None:
            fn_ref = weakref.ref(fn)
            dm = self.data_mesh

            def wrapped(*a, **kw):
                from repro.runtime.distributed import use_data_mesh

                with use_data_mesh(dm):
                    return fn_ref()(*a, **kw)

            self._mesh_fns[fn] = wrapped
        return wrapped

    def _jit_miss_scope(self, label: str):
        """While tracing, count executor jit-cache misses (fresh compiled
        wrappers) per closure under ``jit_cache_miss[...]`` counters."""
        tr = self.tracer
        if tr is None:
            return contextlib.nullcontext()

        def on_miss(fn):
            name = getattr(fn, "__name__", type(fn).__name__)
            tr.metrics.counter(f"jit_cache_miss[{label or name}]").inc()

        return jit_miss_hook(on_miss)

    def _run_chunk(
        self,
        fn,
        xs_c: Any,
        args: Tuple[Any, ...],
        label: str,
        index: int,
        model: Optional[MemoryModel] = None,
    ) -> Any:
        err: Optional[BaseException] = None
        # the attempt plan: an optional data-mesh rung on the primary
        # backend first (lost shards fall back to the SAME chunk
        # single-host, same bits), then the plain backend ladder
        plans: List[Tuple[Executor, Any, str]] = []
        if self.data_mesh is not None:
            plans.append(
                (
                    self._primary,
                    self._mesh_variant(fn),
                    f"data_mesh[{self.data_mesh.label}]:{self._primary.name}",
                )
            )
        plans.extend((exe, fn, exe.name) for exe in self._ladder())
        for attempt, (exe, run_fn, rung) in enumerate(plans):
            if attempt > self.max_retries:
                break
            if attempt:
                self._emit(
                    RuntimeEvent("downgrade", label, index, rung, str(err))
                )
            try:
                tr = self.tracer
                if tr is None:
                    return exe.map(run_fn, xs_c, *args)
                return self._run_chunk_traced(
                    tr, exe, run_fn, xs_c, args, label, index, model
                )
            except Exception as e:  # noqa: BLE001 — the ladder handles it
                err = e
                # a re-attempt is coming iff the plan has a lower rung
                # left AND the retry budget allows it — that re-attempt
                # is a distinct "retry" event carrying the trigger
                if attempt < self.max_retries and attempt + 1 < len(plans):
                    self._emit(
                        RuntimeEvent("retry", label, index, rung, str(e))
                    )
        assert err is not None
        raise err

    def _run_chunk_traced(
        self, tr, exe, fn, xs_c, args, label: str, index: int,
        model: Optional[MemoryModel],
    ) -> Any:
        """One chunk attempt under an open span: duration is
        block_until_ready-honest, latency feeds the chunk histogram,
        and — when the memory model sized this map — the chunk joins
        the predicted-vs-measured cost audit."""
        csize = _leading_dim(xs_c)
        with tr.span(
            "runtime.chunk",
            cat="runtime",
            label=label,
            chunk_index=index,
            chunk_size=csize,
            backend=exe.name,
        ) as sp:
            with self._jit_miss_scope(label):
                out = exe.map(fn, xs_c, *args)
            tr.sync(out)
        tr.metrics.counter("runtime.chunks").inc()
        tr.metrics.histogram("runtime.chunk_seconds").observe(sp.duration_s)
        if model is not None:
            cost = probe_chunk_cost(fn, xs_c, args, csize)
            if cost is not None:
                tr.audit.record(
                    ChunkAudit(
                        label=label,
                        chunk_index=index,
                        chunk_size=csize,
                        predicted_peak_bytes=model.peak(csize),
                        probed_peak_bytes=cost.peak_temp_bytes,
                        flops=cost.flops,
                        hbm_bytes=cost.hbm_bytes,
                        measured_s=sp.duration_s,
                    )
                )
        return out

    # -- chunk sizing ---------------------------------------------------
    def plan_chunk(
        self, fn, xs: Any, args: Tuple[Any, ...], b: int
    ) -> Tuple[int, Optional[MemoryModel]]:
        """(chunk size, memory model) the scheduler would use for this
        map — exposed so benches can report predicted peaks."""
        if self.chunk:
            return max(1, min(self.chunk, b)), None
        if self.memory_budget <= 0 or b <= 1:
            return b, None
        model = memory_model(fn, xs, args, b)
        if model is None:
            return b, None
        return model.max_chunk(self.memory_budget, b), model

    # -- the map primitive ----------------------------------------------
    def map(self, fn: Callable[..., Any], xs: Any, *args: Any, label: str = "") -> Any:
        """Map ``fn`` over the leading replicate axis of ``xs`` with
        chunked, fault-tolerant scheduling.  Results are ordered by
        replicate index regardless of chunking or downgrades."""
        b = _leading_dim(xs)
        if b == 0:
            return _empty_like_mapped(fn, xs, args)
        chunk, model = self.plan_chunk(fn, xs, args, b)
        tr = self.tracer
        with maybe_span(
            tr, "runtime.map", cat="runtime", label=label, b=b, chunk=chunk,
            backend=self._primary.name,
        ):
            if tr is not None and model is not None:
                tag = f"[{label}]" if label else ""
                tr.metrics.gauge(f"runtime.chunk_size{tag}").set(chunk)
                tr.metrics.gauge(f"runtime.predicted_peak_bytes{tag}").set(
                    model.peak(chunk)
                )
            if chunk >= b:
                return self._run_chunk(fn, xs, args, label, 0, model)
            self._emit(
                RuntimeEvent(
                    "chunk", label, -1, self._primary.name, f"b={b} chunk={chunk}"
                )
            )
            outs = [
                self._run_chunk(
                    fn, _slice(xs, lo, min(lo + chunk, b)), args, label, i, model
                )
                for i, lo in enumerate(range(0, b, chunk))
            ]
            return jax.tree_util.tree_map(
                lambda *ys: jnp.concatenate(ys, axis=0), *outs
            )

    # -- nested parallelism ---------------------------------------------
    def map_product(
        self,
        fn: Callable[..., Any],
        xs_outer: Any,
        xs_inner: Any,
        *args: Any,
        label: str = "",
    ) -> Any:
        """One batched program for two parallel axes: ``fn(xo, xi,
        *args)`` over the (b_outer × b_inner) product, flattened onto a
        single replicate axis so chunking/fault-tolerance subdivide the
        *product* (the scheduler's choice), then reshaped back to
        (b_outer, b_inner, ...)."""
        bo = _leading_dim(xs_outer)
        bi = _leading_dim(xs_inner)
        fused = TaskRuntime._PRODUCT_FNS.get(fn)
        if fused is None:
            # the wrapper holds only a weakref to fn: a strong capture
            # would pin the WeakKeyDictionary key alive through its own
            # value, making every entry immortal.  fn is alive for the
            # duration of any call that passes it in.
            fn_ref = weakref.ref(fn)

            def fused(pair, *a):
                return fn_ref()(pair["outer"], pair["inner"], *a)

            TaskRuntime._PRODUCT_FNS[fn] = fused
        rep = jax.tree_util.tree_map(lambda x: jnp.repeat(x, bi, axis=0), xs_outer)
        til = jax.tree_util.tree_map(
            lambda x: jnp.tile(x, (bo,) + (1,) * (x.ndim - 1)), xs_inner
        )
        flat = self.map(
            fused, {"outer": rep, "inner": til}, *args, label=label or "map_product"
        )
        return jax.tree_util.tree_map(
            lambda y: y.reshape((bo, bi) + y.shape[1:]), flat
        )

    # -- futures API -----------------------------------------------------
    def submit(
        self,
        fn: Callable[..., Any],
        xs: Any,
        *args: Any,
        deps: Sequence[TaskFuture] = (),
        label: str = "",
    ) -> TaskFuture:
        """Deferred ``map``: returns a TaskFuture immediately.  ``xs`` /
        ``args`` may contain TaskFutures — resolved when gathered."""
        return self._graph.submit("map", fn, xs, args, deps, label)

    def call(
        self,
        fn: Callable[..., Any],
        *args: Any,
        deps: Sequence[TaskFuture] = (),
        label: str = "",
    ) -> TaskFuture:
        """Deferred host call — the glue nodes between map stages
        (survivor selection, reductions)."""
        return self._graph.submit("call", fn, None, args, deps, label)

    def gather(self, futures):
        """Execute the DAG below ``futures`` (deterministic topological
        order) and return their results, preserving structure.  With a
        tracer, every executed map node gets a ``dag.task`` span (its
        chunk spans nest inside)."""
        single = isinstance(futures, TaskFuture)
        targets = [futures] if single else list(futures)

        def run_map(f: TaskFuture):
            with maybe_span(
                self.tracer, "dag.task", cat="dag",
                label=f.label or f"task{f.task_id}", task_id=f.task_id,
            ):
                return self.map(
                    f.fn, resolve(f.xs), *resolve(f.args), label=f.label
                )

        self._graph.execute(targets, run_map)
        out = [t.result() for t in targets]
        return out[0] if single else out


def as_runtime(
    executor,
    *,
    mesh=None,
    rules=None,
    data_mesh=None,
    memory_budget: int = 0,
    chunk: int = 0,
    max_retries: int = 2,
    tracer: Optional[Tracer] = None,
) -> TaskRuntime:
    """Coerce an executor name / Executor / TaskRuntime into a
    TaskRuntime — the adapter every migrated caller goes through.  A
    TaskRuntime passes through untouched (it keeps its own tracer and
    data mesh); ``tracer`` / ``data_mesh`` attach to freshly-built
    runtimes only."""
    if isinstance(executor, TaskRuntime):
        return executor
    return TaskRuntime(
        executor,
        mesh=mesh,
        rules=rules,
        data_mesh=data_mesh,
        memory_budget=memory_budget,
        chunk=chunk,
        max_retries=max_retries,
        tracer=tracer,
    )
