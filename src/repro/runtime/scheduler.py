"""The task scheduler: Ray's pool semantics over Executor backends.

``TaskRuntime`` grows PR 1's flat ``Executor.map`` into the scheduling
layer the paper attributes to Ray:

  chunked scheduling   the replicate axis is split into chunks sized by
                       the affine peak-memory model of the lowered
                       closure (runtime.memory) against a per-device
                       budget — ``n_bootstrap=2000`` streams instead of
                       OOMing one giant vmap;
  fault tolerance      each chunk retries down the backend ladder
                       (shard_map → vmap → serial) on failure, the SPMD
                       stand-in for Ray re-executing a lost task on
                       another worker.  Results stay bit-identical:
                       per-replicate numerics are batch-size-invariant
                       and serial ≡ vmap bitwise, so a downgraded chunk
                       computes the same bits the healthy backend would
                       have;
  deterministic order  chunks are dispatched and concatenated in fixed
                       replicate order, whatever backends ran them;
  nested parallelism   ``map_product`` flattens two parallel axes
                       (replicate × fold, trial × fold) into ONE
                       batched program, with the same chunked/fault-
                       tolerant machinery subdividing the product axis
                       when the budget demands — the scheduler, not the
                       caller, decides how much runs at once;
  futures              ``submit``/``call``/``gather`` (runtime.future)
                       express dependent stages — successive-halving
                       rungs, refuter panels — as a task DAG instead of
                       hand-ordered loops.

A ``TaskRuntime`` with no budget, no explicit chunk, and a healthy
backend degenerates to exactly one ``Executor.map`` call, so migrating
callers onto the runtime costs nothing on the happy path.
"""

from __future__ import annotations

import dataclasses
import weakref
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.inference.executor import Executor, make_executor
from repro.runtime.future import TaskFuture, TaskGraph, resolve
from repro.runtime.memory import MemoryModel, memory_model

# The fault-tolerance ladder: each backend's failure falls back to the
# next-simpler one.  serial has no fallback — its failure is the task's.
DOWNGRADE: dict = {"shard_map": "vmap", "vmap": "serial", "serial": None}


@dataclasses.dataclass(frozen=True)
class RuntimeEvent:
    """One scheduling decision or recovery, for tests and reports."""

    action: str  # "chunk" | "retry" | "downgrade"
    label: str
    chunk_index: int = -1
    backend: str = ""
    detail: str = ""


def _leading_dim(xs: Any) -> int:
    leaves = jax.tree_util.tree_leaves(xs)
    if not leaves:
        raise ValueError("runtime.map needs at least one array input")
    return leaves[0].shape[0]


def _slice(xs: Any, lo: int, hi: int) -> Any:
    return jax.tree_util.tree_map(lambda x: x[lo:hi], xs)


def _empty_like_mapped(fn, xs: Any, args: Tuple[Any, ...]) -> Any:
    """Zero-replicate output: (0, ...) stacked leaves with the shapes
    and dtypes one application of ``fn`` would produce."""
    elem = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), xs
    )
    arg_spec = tuple(
        jax.tree_util.tree_map(
            lambda a: (
                jax.ShapeDtypeStruct(a.shape, a.dtype) if hasattr(a, "shape") else a
            ),
            arg,
        )
        for arg in args
    )
    out = jax.eval_shape(fn, elem, *arg_spec)
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros((0,) + tuple(s.shape), s.dtype), out
    )


class TaskRuntime:
    """Memory-aware, fault-tolerant scheduler over Executor backends.

    Parameters
    ----------
    executor       backend name (serial | vmap | shard_map) or Executor
                   instance — the *preferred* backend; failures walk the
                   DOWNGRADE ladder from there.
    memory_budget  bytes/device the batched program may peak at; 0
                   disables the memory model (one chunk).
    chunk          explicit replicate chunk size; 0 defers to the
                   memory model (CausalConfig.runtime_chunk).
    max_retries    extra attempts a chunk gets after its first failure
                   (each attempt moves one rung down the ladder).
    """

    # fn -> fused (outer, inner) wrapper, weak so dead closures drop out
    # (same pattern as the executors' _JitCache: the executor keys its
    # compiled cache on the closure object, so the wrapper must be
    # stable per fn).
    _PRODUCT_FNS: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()

    def __init__(
        self,
        executor="vmap",
        *,
        memory_budget: int = 0,
        chunk: int = 0,
        max_retries: int = 2,
        mesh=None,
        rules=None,
    ):
        self._primary = make_executor(executor, mesh=mesh, rules=rules)
        self._mesh = mesh
        self._rules = rules
        self.memory_budget = int(memory_budget)
        self.chunk = int(chunk)
        self.max_retries = int(max_retries)
        self.events: List[RuntimeEvent] = []
        self._graph = TaskGraph()

    # -- identity -------------------------------------------------------
    @property
    def name(self) -> str:
        return self._primary.name

    # -- backend ladder -------------------------------------------------
    def _ladder(self) -> Tuple[Executor, ...]:
        chain: List[Executor] = [self._primary]
        nxt = DOWNGRADE.get(self._primary.name, "vmap")
        while nxt is not None:
            chain.append(make_executor(nxt, mesh=self._mesh, rules=self._rules))
            nxt = DOWNGRADE.get(nxt)
        # dedupe by backend name, keeping first occurrence
        seen, out = set(), []
        for exe in chain:
            if exe.name not in seen:
                seen.add(exe.name)
                out.append(exe)
        return tuple(out)

    def _run_chunk(
        self, fn, xs_c: Any, args: Tuple[Any, ...], label: str, index: int
    ) -> Any:
        err: Optional[BaseException] = None
        for attempt, exe in enumerate(self._ladder()):
            if attempt > self.max_retries:
                break
            if attempt:
                self.events.append(
                    RuntimeEvent("downgrade", label, index, exe.name, str(err))
                )
            try:
                return exe.map(fn, xs_c, *args)
            except Exception as e:  # noqa: BLE001 — the ladder handles it
                err = e
        assert err is not None
        raise err

    # -- chunk sizing ---------------------------------------------------
    def plan_chunk(
        self, fn, xs: Any, args: Tuple[Any, ...], b: int
    ) -> Tuple[int, Optional[MemoryModel]]:
        """(chunk size, memory model) the scheduler would use for this
        map — exposed so benches can report predicted peaks."""
        if self.chunk:
            return max(1, min(self.chunk, b)), None
        if self.memory_budget <= 0 or b <= 1:
            return b, None
        model = memory_model(fn, xs, args, b)
        if model is None:
            return b, None
        return model.max_chunk(self.memory_budget, b), model

    # -- the map primitive ----------------------------------------------
    def map(self, fn: Callable[..., Any], xs: Any, *args: Any, label: str = "") -> Any:
        """Map ``fn`` over the leading replicate axis of ``xs`` with
        chunked, fault-tolerant scheduling.  Results are ordered by
        replicate index regardless of chunking or downgrades."""
        b = _leading_dim(xs)
        if b == 0:
            return _empty_like_mapped(fn, xs, args)
        chunk, _ = self.plan_chunk(fn, xs, args, b)
        if chunk >= b:
            return self._run_chunk(fn, xs, args, label, 0)
        self.events.append(
            RuntimeEvent("chunk", label, -1, self._primary.name, f"b={b} chunk={chunk}")
        )
        outs = [
            self._run_chunk(fn, _slice(xs, lo, min(lo + chunk, b)), args, label, i)
            for i, lo in enumerate(range(0, b, chunk))
        ]
        return jax.tree_util.tree_map(lambda *ys: jnp.concatenate(ys, axis=0), *outs)

    # -- nested parallelism ---------------------------------------------
    def map_product(
        self,
        fn: Callable[..., Any],
        xs_outer: Any,
        xs_inner: Any,
        *args: Any,
        label: str = "",
    ) -> Any:
        """One batched program for two parallel axes: ``fn(xo, xi,
        *args)`` over the (b_outer × b_inner) product, flattened onto a
        single replicate axis so chunking/fault-tolerance subdivide the
        *product* (the scheduler's choice), then reshaped back to
        (b_outer, b_inner, ...)."""
        bo = _leading_dim(xs_outer)
        bi = _leading_dim(xs_inner)
        fused = TaskRuntime._PRODUCT_FNS.get(fn)
        if fused is None:
            # the wrapper holds only a weakref to fn: a strong capture
            # would pin the WeakKeyDictionary key alive through its own
            # value, making every entry immortal.  fn is alive for the
            # duration of any call that passes it in.
            fn_ref = weakref.ref(fn)

            def fused(pair, *a):
                return fn_ref()(pair["outer"], pair["inner"], *a)

            TaskRuntime._PRODUCT_FNS[fn] = fused
        rep = jax.tree_util.tree_map(lambda x: jnp.repeat(x, bi, axis=0), xs_outer)
        til = jax.tree_util.tree_map(
            lambda x: jnp.tile(x, (bo,) + (1,) * (x.ndim - 1)), xs_inner
        )
        flat = self.map(
            fused, {"outer": rep, "inner": til}, *args, label=label or "map_product"
        )
        return jax.tree_util.tree_map(
            lambda y: y.reshape((bo, bi) + y.shape[1:]), flat
        )

    # -- futures API -----------------------------------------------------
    def submit(
        self,
        fn: Callable[..., Any],
        xs: Any,
        *args: Any,
        deps: Sequence[TaskFuture] = (),
        label: str = "",
    ) -> TaskFuture:
        """Deferred ``map``: returns a TaskFuture immediately.  ``xs`` /
        ``args`` may contain TaskFutures — resolved when gathered."""
        return self._graph.submit("map", fn, xs, args, deps, label)

    def call(
        self,
        fn: Callable[..., Any],
        *args: Any,
        deps: Sequence[TaskFuture] = (),
        label: str = "",
    ) -> TaskFuture:
        """Deferred host call — the glue nodes between map stages
        (survivor selection, reductions)."""
        return self._graph.submit("call", fn, None, args, deps, label)

    def gather(self, futures):
        """Execute the DAG below ``futures`` (deterministic topological
        order) and return their results, preserving structure."""
        single = isinstance(futures, TaskFuture)
        targets = [futures] if single else list(futures)
        self._graph.execute(
            targets,
            lambda f: self.map(f.fn, resolve(f.xs), *resolve(f.args), label=f.label),
        )
        out = [t.result() for t in targets]
        return out[0] if single else out


def as_runtime(
    executor,
    *,
    mesh=None,
    rules=None,
    memory_budget: int = 0,
    chunk: int = 0,
    max_retries: int = 2,
) -> TaskRuntime:
    """Coerce an executor name / Executor / TaskRuntime into a
    TaskRuntime — the adapter every migrated caller goes through."""
    if isinstance(executor, TaskRuntime):
        return executor
    return TaskRuntime(
        executor,
        mesh=mesh,
        rules=rules,
        memory_budget=memory_budget,
        chunk=chunk,
        max_retries=max_retries,
    )
