"""Base configuration system for NEXUS-JAX.

Every architecture in ``repro.configs`` instantiates these dataclasses.
Configs are frozen (hashable) so they can be closed over by jitted
functions and used as cache keys by the dry-run machinery.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (one per assigned arch)."""

    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm

    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention ---
    attention: str = "gqa"  # gqa | mla | rwkv | none
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0  # fraction of head_dim that rotates (phi4/chatglm)
    use_rope: bool = True
    learned_pos_emb: bool = False  # whisper
    max_position_embeddings: int = 1 << 20
    logits_softcap: float = 0.0

    # --- MLA (deepseek) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 0
    qk_nope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MLP ---
    mlp: str = "swiglu"  # swiglu | gelu

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    dense_residual: bool = False  # arctic: dense FFN parallel to MoE
    first_k_dense: int = 0  # deepseek: first k layers use a dense MLP
    dense_ff: int = 0  # ff width of those dense layers (deepseek 18432)
    router_aux_loss: float = 0.001
    router_score: str = "softmax"  # softmax | sigmoid (deepseek-v3)
    expert_capacity_factor: float = 1.25
    mtp_depth: int = 0  # deepseek multi-token-prediction heads (optional)

    # --- SSM / hybrid (zamba2, rwkv6) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 128
    shared_attn_every: int = 0  # zamba2: one shared attn block every N mamba blocks

    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    max_source_positions: int = 1500

    # --- vlm (pixtral) ---
    patch_embed_dim: int = 0  # stub frontend: precomputed patch embeddings

    # --- numerics ---
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    @property
    def is_subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def padded_vocab(self) -> int:
        """Embedding tables are padded to a multiple of 256 (= TP16 x the
        128-lane VPU tile) so the vocab dim always shards over "model";
        unembed masks pad logits to -inf, keeping the CE exact."""
        return -(-self.vocab_size // 256) * 256

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def q_dim(self) -> int:
        if self.attention == "mla":
            return self.num_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
        return self.num_heads * self.head_dim

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-flops in roofline)."""
        d, L = self.d_model, self.num_layers
        n = self.vocab_size * d  # input embedding
        if not self.tie_embeddings:
            n += self.vocab_size * d
        for i in range(L):
            n += self._layer_params(i)
        if self.is_encdec:
            for _ in range(self.encoder_layers):
                n += self._enc_layer_params()
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE uses top-k experts only)."""
        if self.num_experts == 0:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        n = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for i in range(L):
            n += self._layer_params(i, active_only=True)
        return n

    # -- internals ------------------------------------------------------
    def _attn_params(self) -> int:
        d = self.d_model
        if self.attention == "mla":
            n = d * self.q_lora_rank if self.q_lora_rank else 0
            qin = self.q_lora_rank or d
            n += qin * self.num_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
            n += d * (self.kv_lora_rank + self.qk_rope_head_dim)
            n += self.kv_lora_rank * self.num_heads * (self.qk_nope_head_dim + self.v_head_dim)
            n += self.num_heads * self.v_head_dim * d
            return n
        if self.attention == "rwkv":
            # rwkv6 time-mix: r,k,v,g,o (d*d) + decay lora + token-shift mixes
            return 5 * d * d + d * 64 * 2
        nq = d * self.num_heads * self.head_dim
        nkv = 2 * d * self.num_kv_heads * self.head_dim
        no = self.num_heads * self.head_dim * d
        return nq + nkv + no

    def _mlp_params(self, ff: int) -> int:
        mult = 3 if self.mlp == "swiglu" else 2
        return mult * self.d_model * ff

    def _layer_params(self, i: int, active_only: bool = False) -> int:
        d = self.d_model
        n = 2 * d  # norms
        if self.family == "ssm":  # rwkv
            n += self._attn_params() + self._mlp_params(self.d_ff)
            return n
        if self.family == "hybrid":  # zamba2 mamba backbone
            di = self.ssm_expand * d
            n += 2 * d * di + di * self.ssm_state * 2 + di * self.ssm_conv + di
            # shared attention block amortized over layers it serves
            if self.shared_attn_every:
                shared = self._attn_params() + self._mlp_params(self.d_ff) + 2 * d
                n += shared // max(1, self.num_layers)
            return n
        n += self._attn_params()
        if self.num_experts and i >= self.first_k_dense:
            per_expert = self._mlp_params(self.d_ff)
            k = self.experts_per_token if active_only else self.num_experts
            n += per_expert * k + per_expert * self.num_shared_experts
            n += self.d_model * self.num_experts  # router
            if self.dense_residual:
                n += self._mlp_params(self.d_ff)
        else:
            n += self._mlp_params(self.dense_ff or self.d_ff)
        return n

    def _enc_layer_params(self) -> int:
        return self._attn_params() + self._mlp_params(self.d_ff) + 4 * self.d_model


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (assigned per arch)."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", "train", 4_096, 256),
    ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    ShapeConfig("decode_32k", "decode", 32_768, 128),
    ShapeConfig("long_500k", "decode", 524_288, 1),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """How the model maps onto the mesh (perf knobs live here)."""

    fsdp: bool = True  # shard the 'embed' dim of weights over the data axis
    sequence_parallel: bool = False  # shard activations' seq dim over model axis
    remat_policy: str = "nothing"  # nothing | dots | full_save
    scan_layers: bool = True
    gradient_compression: str = "none"  # none | bf16 | int8
    shard_kv_seq: bool = False  # long-context: shard KV cache seq over data
    adam_moment_dtype: Any = jnp.float32
    grad_accum_dtype: Any = jnp.float32  # bf16 halves per-microbatch
    # grad reduce-scatter bytes (MoE giants); fp32 default elsewhere
    use_flash_attention: bool = False  # pallas path (TPU); ref path on CPU
    attention_impl: str = "dense"  # dense | chunked (online-softmax scan)
    attention_chunk: int = 1024
    microbatch: int = 1  # gradient-accumulation splits of the global batch


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1_000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class CausalConfig:
    """DML estimator configuration (the paper's §5 case study)."""

    n_folds: int = 5
    nuisance_y: str = "ridge"  # ridge | mlp | backbone
    nuisance_t: str = "logistic"  # logistic | mlp | backbone
    final_stage: str = "linear"  # linear CATE: theta(x) = <beta, phi(x)>
    cate_features: int = 1  # phi(x) dims (1 => ATE-only / constant effect)
    ridge_lambda: float = 1e-3
    newton_iters: int = 16
    # --- streaming sufficient statistics (repro.core.moments) ---
    # 0 = whole-array moments (legacy einsum forms, one allocation);
    # R > 0 = lax.scan over row blocks of R — peak activation memory
    # drops from O(n·p) to O(R·p), so n can exceed a single allocation.
    # Chunked and whole evaluation of the SAME row_block are
    # bit-identical (see core/moments.py); different settings agree to
    # float reassociation only.
    row_block: int = 0
    # Blocked-evaluation strategy at row_block > 0: "chunked" streams
    # one lax.scan-sliced block at a time (bounded memory), "whole"
    # materializes every block partial at once.  The two are
    # bit-identical for equal row_block (the moments contract); the
    # knob exists so the conformance harness can assert that equality
    # at the ESTIMATOR level, and so perf work can trade memory for
    # fusion freedom without touching call sites.  "pallas" routes the
    # Gram-shaped forms through the fused mask→weight→residualize→
    # accumulate kernel family (repro.kernels.seg_gram: compiled
    # mosaic on TPU, a fused XLA scatter lowering on CPU, interpret
    # mode for certification); forms without a fused builder ladder
    # back to "chunked".  Parity with "chunked" is tolerance-certified
    # (≤1e-6 estimator-wide), not bitwise.
    row_block_strategy: str = "chunked"  # chunked | whole | pallas
    mlp_hidden: Tuple[int, ...] = (256, 256)
    mlp_steps: int = 200
    mlp_lr: float = 1e-3
    discrete_treatment: bool = True
    engine: str = "parallel"  # parallel (paper, C1) | sequential (EconML baseline)
    # --- instrumental variables (repro.core.iv: OrthoIV / DRIV) ---
    nuisance_z: str = "logistic"  # instrument model E[Z|X] (logistic | ridge | mlp)
    discrete_instrument: bool = True
    # DRIV clips the compliance denominator E[rt·rz|X] away from zero
    # (EconML's cov_clip); magnitude floor, sign-preserving.
    iv_cov_clip: float = 0.1
    # --- uncertainty quantification (repro.inference subsystem) ---
    inference: str = "bootstrap"  # bootstrap (pairs) | multiplier | jackknife | none
    n_bootstrap: int = 200        # B replicates (EconML BootstrapInference)
    alpha: float = 0.05           # CI level: 1 - alpha
    inference_executor: str = "vmap"  # serial | vmap | shard_map
    # --- task-graph runtime (repro.runtime) ---
    # Per-device peak-memory budget (bytes) for replicate batching: the
    # scheduler probes the lowered closure (launch.hlo_cost peak temps)
    # and streams the replicate axis in chunks that fit.  0 = unbounded
    # (one batched program, the legacy behavior).
    runtime_memory_budget: int = 0
    runtime_chunk: int = 0        # explicit chunk size; 0 = auto from budget
    runtime_max_retries: int = 2  # per-chunk backend-downgrade attempts
    # --- segment-parallel sweeps (repro.sweep) ---
    # Name of the cohort/segment column in the caller's frame — pure
    # provenance carried into EffectPanel summaries ("" = unsegmented);
    # the sweep engine itself takes the integer segment-id array.
    segment_key: str = ""
    # Max sweep cells batched per compiled program (the segment × config
    # axis); 0 defers to runtime_chunk / the memory model.  Bounds the
    # (cells, n) live mask/weight activations at industrial n.
    sweep_chunk: int = 0


def smoke_variant(cfg: ModelConfig, **overrides: Any) -> ModelConfig:
    """A reduced config of the same family for CPU smoke tests."""
    base = dict(
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        param_dtype=jnp.float32,
        compute_dtype=jnp.float32,
        max_position_embeddings=512,
    )
    if cfg.attention == "mla":
        base.update(q_lora_rank=32, kv_lora_rank=16, qk_rope_head_dim=8,
                    qk_nope_head_dim=8, v_head_dim=16)
    if cfg.num_experts:
        base.update(num_experts=4, experts_per_token=2,
                    num_shared_experts=min(cfg.num_shared_experts, 1),
                    first_k_dense=min(cfg.first_k_dense, 1),
                    dense_ff=128 if cfg.dense_ff else 0,
                    # no token dropping at smoke scale: keeps train/
                    # prefill/decode numerically consistent for tests
                    expert_capacity_factor=8.0)
    if cfg.family in ("ssm", "hybrid"):
        base.update(ssm_state=8, ssm_chunk=16)
    if cfg.shared_attn_every:
        base.update(shared_attn_every=1, num_layers=2)
    if cfg.is_encdec:
        base.update(encoder_layers=2, max_source_positions=64)
    base.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **base)
