"""The many-cohorts sweep as a dry-run cell: E per-segment DML fits
lowered against the production mesh — the paper's case-study workload
shape (many effect estimates per run, not one) at the §5.3 scale.

Two lowerings of the same estimation:

  mode="segmented"  the one-pass segment×fold Gram kernels
                    (repro.sweep.segmented): rows shard over every
                    chip, the (E·K, q, q) segmented Gram is the one
                    cross-chip reduction — the many-effects-cheaply
                    execution, and the cell most representative of the
                    sweep subsystem's technique;
  mode="cells"      E masked weighted single fits batched on a leading
                    cell axis (the certified-bitwise execution),
                    lowered for cross-checking the segmented cell's
                    collectives.

Like launch/dml_cell.py these lower compile-only (no device buffers):
the dry-run/roofline tooling reads cost + memory off the HLO.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import CausalConfig
from repro.core.final_stage import cate_basis

N_ROWS = 1_048_576  # the paper's "1 Million", padded to 2^20 (see dml_cell)
N_COVARIATES = 500
N_SEGMENTS = 64


def make_sweep_step(cfg: CausalConfig, n_segments: int = N_SEGMENTS,
                    mode: str = "segmented"):
    """One full E-segment sweep column as a single jittable program.
    Segment ids come in as data (host-computed, like fold assignments
    in the DML cell)."""
    if mode == "segmented":
        from repro.sweep.segmented import segmented_dml_sweep

        def sweep_fit(X, y, t, sids):
            out = segmented_dml_sweep(cfg, X, y, t, sids, n_segments,
                                      jax.random.PRNGKey(0))
            return out["theta"], out["se"]

        return sweep_fit
    if mode != "cells":
        raise ValueError(f"unknown sweep cell mode {mode!r}")

    from repro.core.registry import get_spec
    from repro.sweep.engine import column_keys
    cell = get_spec("dml").weighted_fit(cfg)

    def sweep_fit(X, y, t, sids):
        keys = column_keys(jax.random.PRNGKey(0), 0, n_segments)
        data = {"X": X, "y": y, "t": t, "phi": cate_basis(
            X, cfg.cate_features)}

        def one(key, sid):
            w = (sids == sid).astype(jnp.float32)
            return cell(key, w, data)

        out = jax.vmap(one)(keys, jnp.arange(n_segments, dtype=jnp.int32))
        return out["theta"], out["se"]

    return sweep_fit


def input_specs(n: int = N_ROWS, p: int = N_COVARIATES):
    f32, i32 = jnp.float32, jnp.int32
    return {
        "X": jax.ShapeDtypeStruct((n, p), f32),
        "y": jax.ShapeDtypeStruct((n,), f32),
        "t": jax.ShapeDtypeStruct((n,), f32),
        "sids": jax.ShapeDtypeStruct((n,), i32),
    }


def row_sharding(mesh: Mesh) -> Dict[str, NamedSharding]:
    """Rows shard over EVERY mesh axis jointly (the paper's one giant
    data axis; segments batch inside the program)."""
    axes = tuple(mesh.axis_names)
    return {
        "X": NamedSharding(mesh, P(axes, None)),
        "y": NamedSharding(mesh, P(axes)),
        "t": NamedSharding(mesh, P(axes)),
        "sids": NamedSharding(mesh, P(axes)),
    }


def lower_sweep_cell(mesh: Mesh, cfg: CausalConfig = None,
                     n: int = N_ROWS, p: int = N_COVARIATES,
                     n_segments: int = N_SEGMENTS,
                     mode: str = "segmented"):
    cfg = cfg or CausalConfig(n_folds=5, cate_features=1)
    step = make_sweep_step(cfg, n_segments, mode)
    specs = input_specs(n, p)
    sh = row_sharding(mesh)
    from repro.distributed.sharding import mesh_context
    with mesh_context(mesh):
        lowered = jax.jit(
            step,
            in_shardings=(sh["X"], sh["y"], sh["t"], sh["sids"]),
        ).lower(specs["X"], specs["y"], specs["t"], specs["sids"])
    return lowered
