"""The paper's own workload as a dry-run cell: fold-parallel DML
(5-fold ridge + logistic cross-fit, orthogonal final stage) at the §5.3
scale — n = 1M rows x p = 500 covariates — lowered against the
production mesh with rows sharded over every chip.

This is the cell "most representative of the paper's technique" for the
§Perf hillclimb: C1's K simultaneous fold-fits appear as a leading vmap
axis; the Gram/Newton reductions are the collectives.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import CausalConfig
from repro.core.crossfit import fold_weights
from repro.core.final_stage import cate_basis, fit_final_stage
from repro.core.nuisance import make_logistic, make_ridge

N_ROWS = 1_048_576  # the paper's "1 Million", padded to 2^20 so rows
# shard evenly over 256/512 chips (extra rows carry zero weight)
N_COVARIATES = 500


def make_dml_step(cfg: CausalConfig, engine: str = "parallel"):
    """One full DML fit as a single jittable program.  Fold assignment
    comes in as data (host-computed, deterministic).

    engine="parallel"      paper-faithful C1 (vmapped complement fits)
    engine="parallel_loo"  beyond-paper leave-one-out-Gram fast path
    """
    ridge = make_ridge(cfg.ridge_lambda)
    logit = make_logistic(cfg.ridge_lambda, cfg.newton_iters)

    def dml_fit(X, y, t, folds):
        k = cfg.n_folds
        key = jax.random.PRNGKey(0)
        if engine == "parallel_loo":
            from repro.core.crossfit import crossfit_parallel_loo
            my, _ = crossfit_parallel_loo(ridge, key, X, y, folds, k)
            mt, _ = crossfit_parallel_loo(logit, key, X, t, folds, k)
        else:
            W = fold_weights(folds, k)                  # (k, n)
            keys = jax.random.split(key, k)

            def fit_fold_y(kk, w):
                st = ridge.fit(ridge.init(kk, X.shape[1]), X, y, w)
                return ridge.predict(st, X)

            def fit_fold_t(kk, w):
                st = logit.fit(logit.init(kk, X.shape[1]), X, t, w)
                return logit.predict(st, X)

            preds_y = jax.vmap(fit_fold_y)(keys, W)      # (k, n) C1 axis
            preds_t = jax.vmap(fit_fold_t)(keys, W)
            my = jnp.take_along_axis(preds_y, folds[None, :], 0)[0]
            mt = jnp.take_along_axis(preds_t, folds[None, :], 0)[0]
        phi = cate_basis(X, cfg.cate_features)
        fs = fit_final_stage(y, t, my, mt, phi)
        return fs.theta, fs.cov

    return dml_fit


def input_specs(n: int = N_ROWS, p: int = N_COVARIATES):
    f32, i32 = jnp.float32, jnp.int32
    return {
        "X": jax.ShapeDtypeStruct((n, p), f32),
        "y": jax.ShapeDtypeStruct((n,), f32),
        "t": jax.ShapeDtypeStruct((n,), f32),
        "folds": jax.ShapeDtypeStruct((n,), i32),
    }


def row_sharding(mesh: Mesh) -> Dict[str, NamedSharding]:
    """Rows shard over EVERY mesh axis jointly (the paper's one giant
    data axis; folds batch inside the program)."""
    axes = tuple(mesh.axis_names)
    return {
        "X": NamedSharding(mesh, P(axes, None)),
        "y": NamedSharding(mesh, P(axes)),
        "t": NamedSharding(mesh, P(axes)),
        "folds": NamedSharding(mesh, P(axes)),
    }


def lower_dml_cell(mesh: Mesh, cfg: CausalConfig = None,
                   n: int = N_ROWS, p: int = N_COVARIATES,
                   engine: str = "parallel"):
    cfg = cfg or CausalConfig(n_folds=5, cate_features=1)
    step = make_dml_step(cfg, engine)
    specs = input_specs(n, p)
    sh = row_sharding(mesh)
    with jax.set_mesh(mesh):
        lowered = jax.jit(
            step,
            in_shardings=(sh["X"], sh["y"], sh["t"], sh["folds"]),
        ).lower(specs["X"], specs["y"], specs["t"], specs["folds"])
    return lowered
