"""The paper's own workload as a dry-run cell: fold-parallel DML
(5-fold ridge + logistic cross-fit, orthogonal final stage) at the §5.3
scale — n = 1M rows x p = 500 covariates — lowered against the
production mesh with rows sharded over every chip.

This is the cell "most representative of the paper's technique" for the
§Perf hillclimb: C1's K simultaneous fold-fits appear as a leading vmap
axis; the Gram/Newton reductions are the collectives.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import CausalConfig
from repro.core.crossfit import crossfit_parallel, crossfit_parallel_loo
from repro.core.final_stage import cate_basis, fit_final_stage
from repro.core.nuisance import make_nuisance

N_ROWS = 1_048_576  # the paper's "1 Million", padded to 2^20 so rows
# shard evenly over 256/512 chips (extra rows carry zero weight)
N_COVARIATES = 500


def make_dml_step(cfg: CausalConfig, engine: str = "parallel",
                  rules=None):
    """One full DML fit as a single jittable program, lowering the SAME
    shared estimation engine the host estimator runs (no inline
    re-implementation of cross-fitting).  Fold assignment comes in as
    data (host-computed, deterministic).

    engine="parallel"      paper-faithful C1 (vmapped complement fits)
    engine="parallel_loo"  beyond-paper leave-one-out-Gram fast path

    cfg.row_block > 0 streams every moments pass (nuisance normal
    equations, LOO fold Grams, final stage) in row blocks constrained
    on the ``rows`` mesh axis — the (k, n) complement-fit activations
    and the (n, p_phi) final-stage moment matrix never materialize.
    """
    ridge = make_nuisance(cfg.nuisance_y, "reg", cfg)
    logit = make_nuisance(cfg.nuisance_t,
                          "clf" if cfg.discrete_treatment else "reg", cfg)

    def dml_fit(X, y, t, folds):
        k = cfg.n_folds
        key = jax.random.PRNGKey(0)
        cf = (crossfit_parallel_loo if engine == "parallel_loo"
              else crossfit_parallel)
        my, _ = cf(ridge, key, X, y, folds, k, rules)
        mt, _ = cf(logit, key, X, t, folds, k, rules)
        phi = cate_basis(X, cfg.cate_features)
        fs = fit_final_stage(y, t, my, mt, phi,
                             row_block=cfg.row_block, rules=rules)
        return fs.theta, fs.cov

    return dml_fit


def input_specs(n: int = N_ROWS, p: int = N_COVARIATES):
    f32, i32 = jnp.float32, jnp.int32
    return {
        "X": jax.ShapeDtypeStruct((n, p), f32),
        "y": jax.ShapeDtypeStruct((n,), f32),
        "t": jax.ShapeDtypeStruct((n,), f32),
        "folds": jax.ShapeDtypeStruct((n,), i32),
    }


def row_sharding(mesh: Mesh) -> Dict[str, NamedSharding]:
    """Rows shard over EVERY mesh axis jointly (the paper's one giant
    data axis; folds batch inside the program)."""
    axes = tuple(mesh.axis_names)
    return {
        "X": NamedSharding(mesh, P(axes, None)),
        "y": NamedSharding(mesh, P(axes)),
        "t": NamedSharding(mesh, P(axes)),
        "folds": NamedSharding(mesh, P(axes)),
    }


def lower_dml_cell(mesh: Mesh, cfg: CausalConfig = None,
                   n: int = N_ROWS, p: int = N_COVARIATES,
                   engine: str = "parallel", rules=None):
    cfg = cfg or CausalConfig(n_folds=5, cate_features=1)
    step = make_dml_step(cfg, engine, rules)
    specs = input_specs(n, p)
    sh = row_sharding(mesh)
    from repro.distributed.sharding import mesh_context
    with mesh_context(mesh):
        lowered = jax.jit(
            step,
            in_shardings=(sh["X"], sh["y"], sh["t"], sh["folds"]),
        ).lower(specs["X"], specs["y"], specs["t"], specs["folds"])
    return lowered
