"""The paper's workloads as dry-run cells: fold-parallel DML (5-fold
ridge + logistic cross-fit, orthogonal final stage) and its
orthogonal-IV sibling (three cross-fit nuisances + the instrumented
final stage), at the §5.3 scale — n = 1M rows x p = 500 covariates —
lowered against the production mesh with rows sharded over every chip.

These are the cells "most representative of the paper's technique" for
the §Perf hillclimb: C1's K simultaneous fold-fits appear as a leading
vmap axis; the Gram/Newton reductions are the collectives.  The IV cell
lowers the SAME shared engines (crossfit_one ×3 + moments.iv_gram), so
the two estimands differ only in which moments the final stage reads.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import CausalConfig
from repro.core.crossfit import crossfit_parallel, crossfit_parallel_loo
from repro.core.final_stage import cate_basis, fit_final_stage
from repro.core.iv import fit_iv_final_stage
from repro.core.nuisance import make_nuisance

N_ROWS = 1_048_576  # the paper's "1 Million", padded to 2^20 so rows
# shard evenly over 256/512 chips (extra rows carry zero weight)
N_COVARIATES = 500


def make_dml_step(cfg: CausalConfig, engine: str = "parallel",
                  rules=None):
    """One full DML fit as a single jittable program, lowering the SAME
    shared estimation engine the host estimator runs (no inline
    re-implementation of cross-fitting).  Fold assignment comes in as
    data (host-computed, deterministic).

    engine="parallel"      paper-faithful C1 (vmapped complement fits)
    engine="parallel_loo"  beyond-paper leave-one-out-Gram fast path

    cfg.row_block > 0 streams every moments pass (nuisance normal
    equations, LOO fold Grams, final stage) in row blocks constrained
    on the ``rows`` mesh axis — the (k, n) complement-fit activations
    and the (n, p_phi) final-stage moment matrix never materialize.
    """
    ridge = make_nuisance(cfg.nuisance_y, "reg", cfg)
    logit = make_nuisance(cfg.nuisance_t,
                          "clf" if cfg.discrete_treatment else "reg", cfg)

    def dml_fit(X, y, t, folds):
        k = cfg.n_folds
        key = jax.random.PRNGKey(0)
        cf = (crossfit_parallel_loo if engine == "parallel_loo"
              else crossfit_parallel)
        my, _ = cf(ridge, key, X, y, folds, k, rules)
        mt, _ = cf(logit, key, X, t, folds, k, rules)
        phi = cate_basis(X, cfg.cate_features)
        fs = fit_final_stage(y, t, my, mt, phi,
                             row_block=cfg.row_block, rules=rules)
        return fs.theta, fs.cov

    return dml_fit


def make_iv_step(cfg: CausalConfig, engine: str = "parallel",
                 rules=None):
    """One full OrthoIV fit as a single jittable program: the same
    shared crossfit engine run for THREE nuisances (E[Y|X], E[T|X],
    E[Z|X]) plus the instrumented final stage (moments.iv_gram /
    iv_meat) — the IV workload lowered the exact way the DML cell is."""
    ridge = make_nuisance(cfg.nuisance_y, "reg", cfg)
    logit_t = make_nuisance(cfg.nuisance_t,
                            "clf" if cfg.discrete_treatment else "reg",
                            cfg)
    logit_z = make_nuisance(cfg.nuisance_z,
                            "clf" if cfg.discrete_instrument else "reg",
                            cfg)

    def iv_fit(X, y, t, z, folds):
        k = cfg.n_folds
        key = jax.random.PRNGKey(0)
        cf = (crossfit_parallel_loo if engine == "parallel_loo"
              else crossfit_parallel)
        my, _ = cf(ridge, key, X, y, folds, k, rules)
        mt, _ = cf(logit_t, key, X, t, folds, k, rules)
        mz, _ = cf(logit_z, key, X, z, folds, k, rules)
        f32 = jnp.float32
        ry = y.astype(f32) - my
        rt = t.astype(f32) - mt
        rz = z.astype(f32) - mz
        phi = cate_basis(X, cfg.cate_features)
        fs = fit_iv_final_stage(ry, rt, rz, phi,
                                row_block=cfg.row_block,
                                strategy=cfg.row_block_strategy,
                                rules=rules)
        return fs.theta, fs.cov

    return iv_fit


def input_specs(n: int = N_ROWS, p: int = N_COVARIATES,
                with_instrument: bool = False):
    f32, i32 = jnp.float32, jnp.int32
    specs = {
        "X": jax.ShapeDtypeStruct((n, p), f32),
        "y": jax.ShapeDtypeStruct((n,), f32),
        "t": jax.ShapeDtypeStruct((n,), f32),
        "folds": jax.ShapeDtypeStruct((n,), i32),
    }
    if with_instrument:
        specs["z"] = jax.ShapeDtypeStruct((n,), f32)
    return specs


def row_sharding(mesh: Mesh, with_instrument: bool = False
                 ) -> Dict[str, NamedSharding]:
    """Rows shard over EVERY mesh axis jointly (the paper's one giant
    data axis; folds batch inside the program)."""
    axes = tuple(mesh.axis_names)
    sh = {
        "X": NamedSharding(mesh, P(axes, None)),
        "y": NamedSharding(mesh, P(axes)),
        "t": NamedSharding(mesh, P(axes)),
        "folds": NamedSharding(mesh, P(axes)),
    }
    if with_instrument:
        sh["z"] = NamedSharding(mesh, P(axes))
    return sh


def lower_dml_cell(mesh: Mesh, cfg: CausalConfig = None,
                   n: int = N_ROWS, p: int = N_COVARIATES,
                   engine: str = "parallel", rules=None):
    cfg = cfg or CausalConfig(n_folds=5, cate_features=1)
    step = make_dml_step(cfg, engine, rules)
    specs = input_specs(n, p)
    sh = row_sharding(mesh)
    from repro.distributed.sharding import mesh_context
    with mesh_context(mesh):
        lowered = jax.jit(
            step,
            in_shardings=(sh["X"], sh["y"], sh["t"], sh["folds"]),
        ).lower(specs["X"], specs["y"], specs["t"], specs["folds"])
    return lowered


def lower_iv_cell(mesh: Mesh, cfg: CausalConfig = None,
                  n: int = N_ROWS, p: int = N_COVARIATES,
                  engine: str = "parallel", rules=None):
    """The OrthoIV workload against the production mesh: identical row
    sharding plus the instrument column."""
    cfg = cfg or CausalConfig(n_folds=5, cate_features=1)
    step = make_iv_step(cfg, engine, rules)
    specs = input_specs(n, p, with_instrument=True)
    sh = row_sharding(mesh, with_instrument=True)
    from repro.distributed.sharding import mesh_context
    with mesh_context(mesh):
        lowered = jax.jit(
            step,
            in_shardings=(sh["X"], sh["y"], sh["t"], sh["z"],
                          sh["folds"]),
        ).lower(specs["X"], specs["y"], specs["t"], specs["z"],
                specs["folds"])
    return lowered
