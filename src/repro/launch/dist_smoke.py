"""Two-process ``jax.distributed`` smoke test for the data mesh.

Launches N worker processes (default 2) on localhost, each with its own
forced CPU device count, initializes ``jax.distributed`` against a
local coordinator, builds a ``("hosts", "devices")`` data mesh spanning
every process, and runs one ``dist_reduce`` weighted-Gram pass in
"psum" mode, checking the result against a local numpy reference.

Best-effort by design: multi-process CPU collectives are not supported
on every jax build, so anything short of an explicit identity FAILURE
reports SKIP and exits 0 — CI treats SKIP as success-with-a-note.  The
bitwise "ordered" certificate is carried by the single-process forced-
8-device suite (tests/test_distributed_runtime.py); this script only
establishes that the same entry points run under a real multi-process
``jax.distributed`` runtime when the platform allows it.

Usage:  python -m repro.launch.dist_smoke [--nprocs 2]
"""
from __future__ import annotations

import argparse
import os
import socket
import subprocess
import sys

OK_MARKER = "DIST_SMOKE_OK"
FAIL_MARKER = "DIST_SMOKE_FAIL"


def _worker(proc: int, nprocs: int, port: int) -> int:
    import jax

    jax.distributed.initialize(
        coordinator_address=f"127.0.0.1:{port}",
        num_processes=nprocs,
        process_id=proc,
    )
    import jax.numpy as jnp
    import numpy as np

    from repro.runtime.distributed import dist_reduce, make_data_mesh

    dm = make_data_mesh(n_hosts=nprocs, reduction="psum")
    rng = np.random.default_rng(0)
    n, p = 512, 8
    X = rng.standard_normal((n, p)).astype(np.float32)
    w = rng.random(n).astype(np.float32)

    def block(xb, wb):
        return (wb[:, None] * xb).T @ xb

    got = dist_reduce(block, [jnp.asarray(X), jnp.asarray(w)],
                      row_block=64, dm=dm)
    ref = (w[:, None] * X).T @ X
    ok = bool(np.allclose(np.asarray(got), ref, rtol=1e-4, atol=1e-3))
    if proc == 0:
        print(OK_MARKER if ok else FAIL_MARKER, flush=True)
    return 0 if ok else 1


def _free_port() -> int:
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def run_smoke(nprocs: int = 2, devices_per_proc: int = 2,
              timeout: float = 120.0) -> str:
    """Spawn the workers; returns "OK", "SKIP: <why>", or "FAIL"."""
    port = _free_port()
    src = os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices_per_proc}")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (src, os.environ.get("PYTHONPATH", "")) if p)
    procs = [
        subprocess.Popen(
            [sys.executable, "-m", "repro.launch.dist_smoke",
             "--proc", str(i), "--nprocs", str(nprocs),
             "--port", str(port)],
            env=env, stdout=subprocess.PIPE,
            stderr=subprocess.STDOUT, text=True)
        for i in range(nprocs)
    ]
    outs = []
    try:
        for pr in procs:
            out, _ = pr.communicate(timeout=timeout)
            outs.append(out or "")
    except subprocess.TimeoutExpired:
        for pr in procs:
            pr.kill()
        return "SKIP: timeout (multi-process collectives unsupported?)"
    combined = "\n".join(outs)
    if FAIL_MARKER in combined:
        return "FAIL"
    if OK_MARKER in combined and all(pr.returncode == 0 for pr in procs):
        return "OK"
    tail = combined.strip().splitlines()[-1] if combined.strip() else "no output"
    return f"SKIP: workers did not converge ({tail[:120]})"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--nprocs", type=int, default=2)
    ap.add_argument("--devices-per-proc", type=int, default=2)
    ap.add_argument("--timeout", type=float, default=120.0)
    ap.add_argument("--proc", type=int, default=None, help=argparse.SUPPRESS)
    ap.add_argument("--port", type=int, default=None, help=argparse.SUPPRESS)
    args = ap.parse_args(argv)
    if args.proc is not None:
        return _worker(args.proc, args.nprocs, args.port)
    verdict = run_smoke(nprocs=args.nprocs,
                        devices_per_proc=args.devices_per_proc,
                        timeout=args.timeout)
    print(f"dist_smoke: {verdict}")
    return 1 if verdict == "FAIL" else 0


if __name__ == "__main__":
    sys.exit(main())
