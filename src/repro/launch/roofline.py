"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Three terms per (arch × shape × mesh), all PER-DEVICE (the compiled
module under SPMD is the per-device program — verified against a known
matmul in tests/test_roofline.py):

    compute    = HLO_FLOPs / PEAK_FLOPS            [s]
    memory     = HLO_bytes / HBM_BW                [s]
    collective = wire_bytes / LINK_BW              [s]

``wire_bytes`` is not in cost_analysis: we parse the compiled HLO and
sum per-op estimates with ring-algorithm factors (G = group size):

    all-reduce          2·S·(G-1)/G      (reduce-scatter + all-gather)
    all-gather          S_out·(G-1)/G
    reduce-scatter      S_out·(G-1)     (input = S_out·G)
    all-to-all          S·(G-1)/G
    collective-permute  S

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_OLD_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(sh: str) -> int:
    m = _SHAPE_RE.match(sh)
    if not m:
        return 0
    dt, dims = m.groups()
    n = _DTYPE_BYTES.get(dt, 0)
    if n == 0:
        return 0
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _result_bytes(lhs: str) -> int:
    """Bytes of an op's result type: 'f32[8,16]{...}' or a tuple."""
    lhs = lhs.strip()
    if lhs.startswith("("):
        return sum(_shape_bytes(p.strip())
                   for p in lhs[1:].split(")")[0].split(","
                   ) if "[" in p) or sum(
            _shape_bytes(s) for s in re.findall(r"\w+\[[\d,]*\]", lhs))
    return _shape_bytes(lhs)


def _group_size(line: str, world: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_OLD_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return world


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float
    by_op: Dict[str, float]
    count: int

    def top(self, k: int = 5) -> List[Tuple[str, float]]:
        return sorted(self.by_op.items(), key=lambda x: -x[1])[:k]


def parse_collectives(hlo_text: str, world: int = 256) -> CollectiveStats:
    total = 0.0
    by_op: Dict[str, float] = {}
    count = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        hit = None
        for op in _COLLECTIVES:
            if re.search(rf"\b{op}(-start)?\(", s):
                hit = op
                break
        if hit is None or f"{hit}-done" in s:
            continue
        lhs = s.split("=", 1)[0]
        # async start ops return (operand, result, ...) tuples; take the
        # largest component as the payload
        sizes = [_shape_bytes(x) for x in re.findall(r"\w+\[[\d,]*\]", lhs)]
        size = max(sizes) if sizes else 0
        g = _group_size(s, world)
        ring = (g - 1) / max(g, 1)
        if hit == "all-reduce":
            wire = 2 * size * ring
        elif hit == "reduce-scatter":
            wire = size * (g - 1)
        elif hit == "collective-permute":
            wire = size
        else:  # all-gather / all-to-all: size = output (gathered) bytes
            wire = size * ring
        total += wire
        by_op[hit] = by_op.get(hit, 0.0) + wire
        count += 1
    return CollectiveStats(wire_bytes=total, by_op=by_op, count=count)


@dataclasses.dataclass
class Roofline:
    flops: float            # per device
    hbm_bytes: float        # per device
    wire_bytes: float       # per device
    model_flops: float      # analytic 6ND/2ND (global)
    chips: int

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.wire_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def step_time(self) -> float:
        """Lower bound assuming perfect overlap: max of the three."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / (chips · HLO_FLOPs): how much compiled compute
        is 'useful' (catches remat/redundancy waste)."""
        tot = self.flops * self.chips
        return self.model_flops / tot if tot else 0.0

    @property
    def mfu_bound(self) -> float:
        """Roofline-fraction score: useful model FLOPs per chip-second at
        the step-time lower bound, vs peak."""
        t = self.step_time
        if t <= 0:
            return 0.0
        return self.model_flops / (self.chips * t) / PEAK_FLOPS

    def row(self) -> Dict[str, float]:
        return {
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck, "step_time": self.step_time,
            "useful_frac": self.useful_flops_frac,
            "mfu_bound": self.mfu_bound,
        }


def model_flops_for(cfg, shape) -> float:
    """Analytic MODEL_FLOPS for the cell (global, per step):
    train 6·N_active·D; prefill 2·N_active·D; decode 2·N_active·B."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: one token
