"""Trip-count-aware cost analysis over compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts every while-loop body ONCE —
under ``lax.scan``-over-layers that under-counts a 60-layer model by 60x
(verified: a scanned 10x matmul reports 1/10th the unrolled flops).  The
roofline needs the true per-step cost, so this module parses the
post-optimization HLO, builds the computation call graph and multiplies
loop bodies by their trip counts.

Counted per op:
  flops   dot: 2 · |out| · |contracting|;  convolution: 2 · |out| · K;
          elementwise/reduce: |out| (minor terms)
  bytes   sum(operand sizes) + |out| for HBM-level ops; fusion internals
          are free (a fusion reads its operands and writes its output
          once — the same model XLA uses)

Trip counts come from each while-condition's ``compare(counter,
constant)``; anything unparseable falls back to 1 with a warning flag.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")


def _shape_dims(sh: str) -> Tuple[int, Tuple[int, ...]]:
    m = _SHAPE_RE.match(sh)
    if not m:
        return 0, ()
    dt, dims = m.groups()
    b = _DTYPE_BYTES.get(dt, 0)
    d = tuple(int(x) for x in dims.split(",") if x)
    return b, d


def _size_bytes(sh: str) -> int:
    if sh.startswith("("):  # tuple type: sum components
        return sum(_size_bytes(p) for p in re.findall(r"\w+\[[\d,]*\]", sh))
    b, d = _shape_dims(sh)
    n = b
    for x in d:
        n *= x
    return n


def _numel(sh: str) -> int:
    _, d = _shape_dims(sh)
    n = 1
    for x in d:
        n *= x
    return n


@dataclasses.dataclass
class Op:
    name: str
    out_type: str
    opcode: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    types: Dict[str, str]  # %name -> type string


_NO_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "copy-start", "copy-done", "after-all",
             "partition-id", "replica-id", "iota"}

# fused-for-free on the TPU target (see byte-model note in _comp_cost)
_ELEMENTWISE = {
    "add", "multiply", "subtract", "divide", "maximum", "minimum",
    "exponential", "exponential-minus-one", "tanh", "log", "log-plus-one",
    "rsqrt", "sqrt", "power", "negate", "compare", "select", "and", "or",
    "not", "xor", "convert", "broadcast", "clamp", "abs", "sign", "floor",
    "ceil", "round-nearest-afz", "cosine", "sine", "logistic",
    "reduce-precision", "is-finite", "atan2", "remainder", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "map",
}
_FLOW = {"fusion", "while", "call", "conditional", "custom-call",
         "async-start", "async-done", "async-update"}


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s:
            continue
        mc = _COMP_RE.match(line) if not line.startswith(" ") else None
        if mc and ("{" in line):
            cur = Computation(mc.group(1), [], {})
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        mo = _OP_RE.match(line)
        if mo:
            name, out_type, opcode = mo.groups()
            cur.types[name] = out_type
            cur.ops.append(Op(name, out_type, opcode, s))
    return comps


def _operand_names(line: str) -> List[str]:
    # text inside the first top-level parens after the opcode
    i = line.index("(")
    depth, j = 0, i
    for j in range(i, len(line)):
        if line[j] == "(":
            depth += 1
        elif line[j] == ")":
            depth -= 1
            if depth == 0:
                break
    inner = line[i + 1:j]
    return re.findall(r"%([\w.\-]+)", inner)


def _dot_flops(op: Op, comp: Computation) -> float:
    out_n = _numel(op.out_type)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    ops = _operand_names(op.line)
    if not m or not ops:
        return 2.0 * out_n  # degenerate
    lhs_t = comp.types.get(ops[0], "")
    _, lhs_dims = _shape_dims(lhs_t)
    contract = 1
    for ix in m.group(1).split(","):
        if ix and int(ix) < len(lhs_dims):
            contract *= lhs_dims[int(ix)]
    return 2.0 * out_n * contract


def _conv_flops(op: Op, comp: Computation) -> float:
    out_n = _numel(op.out_type)
    ops = _operand_names(op.line)
    if len(ops) >= 2:
        k_n = _numel(comp.types.get(ops[1], ""))
        _, out_dims = _shape_dims(op.out_type)
        # flops = 2*|out|*(kernel elements per output channel)
        _, k_dims = _shape_dims(comp.types.get(ops[1], ""))
        per_out = k_n / max(k_dims[-1] if k_dims else 1, 1)
        return 2.0 * out_n * per_out
    return 2.0 * out_n


def _trip_count(cond: Computation) -> Optional[int]:
    const_vals: Dict[str, int] = {}
    for op in cond.ops:
        mm = re.search(r"constant\((\d+)\)", op.line)
        if op.opcode == "constant" and mm:
            const_vals[op.name] = int(mm.group(1))
    for op in cond.ops:
        if op.opcode == "compare" and "direction=LT" in op.line:
            for nm in _operand_names(op.line):
                if nm in const_vals:
                    return const_vals[nm]
    # fallback: any s32 constant in the condition
    return max(const_vals.values()) if const_vals else None


@dataclasses.dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    wire_bytes: float = 0.0          # collective bytes on the ICI wire
    coll_by_op: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_count: int = 0
    unknown_trip_counts: int = 0


_COLL_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\b")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _collective_wire(op: Op, comp: Computation, world: int
                     ) -> Tuple[str, float]:
    """Ring-algorithm wire bytes per device for one collective op."""
    m = _COLL_RE.search(op.opcode)
    kind = m.group(1)
    g = world
    mg = _GROUPS_RE.search(op.line)
    if mg:
        g = int(mg.group(2))
    ring = (g - 1) / max(g, 1)
    # async -start ops return (operands, results, ...) tuples; use the
    # largest component as the payload
    if op.out_type.startswith("("):
        sizes = [_size_bytes(s)
                 for s in re.findall(r"\w+\[[\d,]*\]", op.out_type)]
        size = max(sizes) if sizes else 0
    else:
        size = _size_bytes(op.out_type)
    if kind == "all-reduce":
        wire = 2 * size * ring
    elif kind == "reduce-scatter":
        wire = size * (g - 1)
    elif kind == "collective-permute":
        wire = size
    else:  # all-gather / all-to-all (size = gathered output)
        wire = size * ring
    return kind, wire


_SLICE_OPS = {"dynamic-slice", "slice", "gather"}


def _sliced_param_sizes(callee: Computation,
                        comps: Optional[Dict[str, Computation]] = None,
                        _memo: Optional[Dict[str, Dict[int, float]]] = None,
                        _stack: Tuple[str, ...] = ()) -> Dict[int, float]:
    """Parameter indices of ``callee`` whose ONLY consumers are slice-type
    ops, mapped to the total bytes those slices actually read.  The
    exemption propagates through nested fusion/call boundaries (XLA's
    CPU backend wraps fusions in ``parallel_*`` call computations for
    thread-level parallelism; the stack operand is still only sliced,
    one level down)."""
    comps = comps or {}
    if _memo is None:
        _memo = {}
    if callee.name in _memo:
        return _memo[callee.name]
    if callee.name in _stack:  # malformed recursion guard
        return {}
    params: Dict[str, int] = {}
    for op in callee.ops:
        if op.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", op.line)
            if m:
                params[op.name] = int(m.group(1))
    out: Dict[int, float] = {}
    consumers: Dict[str, List[Op]] = {n: [] for n in params}
    for op in callee.ops:
        if op.opcode == "parameter":
            continue
        for nm in _operand_names(op.line):
            if nm in consumers:
                consumers[nm].append(op)
    for nm, idx in params.items():
        cons = consumers[nm]
        if not cons:
            continue
        total = 0.0
        exempt = True
        for c in cons:
            if c.opcode in _SLICE_OPS and _operand_names(c.line)[0] == nm:
                total += _size_bytes(c.out_type)
                continue
            if c.opcode in ("fusion", "call"):
                m = _CALLS_RE.search(c.line)
                sub = comps.get(m.group(1)) if m else None
                if sub is not None:
                    sub_sliced = _sliced_param_sizes(
                        sub, comps, _memo, _stack + (callee.name,))
                    pos = [i for i, on in enumerate(_operand_names(c.line))
                           if on == nm]
                    if pos and all(p in sub_sliced for p in pos):
                        total += sum(sub_sliced[p] for p in pos)
                        continue
            exempt = False
            break
        if exempt:
            out[idx] = total
    _memo[callee.name] = out
    return out


def _dus_root(callee: Optional[Computation]):
    """If the fused computation's root is a dynamic-update-slice, return
    ({param indices reached only through the DUS target operand},
    update_bytes); else (set(), None)."""
    if callee is None or not callee.ops:
        return set(), None
    root = callee.ops[-1]
    if root.opcode != "dynamic-update-slice":
        return set(), None
    ops_n = _operand_names(root.line)
    if len(ops_n) < 2:
        return set(), None
    update_bytes = _size_bytes(callee.types.get(ops_n[1], ""))
    # parameter index feeding the DUS target (operand 0), possibly via a
    # bitcast chain
    target = ops_n[0]
    by_name = {op.name: op for op in callee.ops}
    seen = set()
    while target in by_name and by_name[target].opcode in ("bitcast", "copy") \
            and target not in seen:
        seen.add(target)
        target = _operand_names(by_name[target].line)[0]
    free = set()
    if target in by_name and by_name[target].opcode == "parameter":
        mm = re.search(r"parameter\((\d+)\)", by_name[target].line)
        if mm:
            free.add(int(mm.group(1)))
    return free, update_bytes


_Cost = Tuple[float, float, float, Dict[str, float], int]


def _comp_cost(comp: Computation, comps: Dict[str, Computation],
               totals: CostTotals, memo: Dict[str, _Cost], world: int,
               stack: Tuple[str, ...] = ()) -> _Cost:
    """(flops, bytes, wire, coll_by_op, coll_count) of one execution of
    ``comp`` including callees."""
    if comp.name in memo:
        return memo[comp.name]
    if comp.name in stack:  # malformed recursion guard
        return 0.0, 0.0, 0.0, {}, 0
    fl = by = wi = 0.0
    cbo: Dict[str, float] = {}
    cct = 0

    def add_coll(d: Dict[str, float], n: int, scale: float = 1.0):
        nonlocal cct
        for k, v in d.items():
            cbo[k] = cbo.get(k, 0.0) + v * scale
        cct += n

    for op in comp.ops:
        oc = op.opcode
        if oc in _NO_BYTES:
            continue
        if _COLL_RE.search(oc) and not oc.endswith("-done"):
            kind, wire = _collective_wire(op, comp, world)
            wi += wire
            add_coll({kind: wire}, 1)
            by += _size_bytes(op.out_type)
            continue
        if oc == "fusion" or oc == "call":
            m = _CALLS_RE.search(op.line)
            callee = comps.get(m.group(1)) if m else None
            if callee is not None:
                cf, _, cw, cd, cn = _comp_cost(
                    callee, comps, totals, memo, world,
                    stack + (comp.name,))
                fl += cf
                wi += cw
                add_coll(cd, cn)
            # fusion bytes: operands + output at the call site, except
            #  * operands the fused computation only SLICES (the (L, ...)
            #    stacked-params stack dynamic-sliced per layer) -> charged
            #    at slice-output size;
            #  * fusions rooted at dynamic-update-slice (in-place KV-cache
            #    writes; XLA aliases the buffer) -> charged at update
            #    size, and the updated operand itself is free (measured:
            #    the naive rule billed 2 x 232 GiB/step on yi decode for
            #    a 3.9 GiB cache written in place)
            call_args = _operand_names(op.line)
            sliced = _sliced_param_sizes(callee, comps) if callee else {}
            dus_free, dus_update = _dus_root(callee)
            if dus_update is not None:
                by += 2 * dus_update
            else:
                by += _size_bytes(op.out_type)
            for i, nm in enumerate(call_args):
                if i in dus_free:
                    continue
                if i in sliced:
                    by += sliced[i]
                else:
                    by += _size_bytes(comp.types.get(nm, ""))
            continue
        if oc == "while":
            m = _WHILE_RE.search(op.line)
            if m:
                cond_n, body_n = m.group(1), m.group(2)
                trips = None
                if cond_n in comps:
                    trips = _trip_count(comps[cond_n])
                if trips is None:
                    trips = 1
                    totals.unknown_trip_counts += 1
                if body_n in comps:
                    bf, bb, bw, bd, bn = _comp_cost(
                        comps[body_n], comps, totals, memo, world,
                        stack + (comp.name,))
                    fl += trips * bf
                    by += trips * bb
                    wi += trips * bw
                    add_coll(bd, trips * bn, float(trips))
            continue
        if oc == "conditional":
            m = _CALLS_RE.search(op.line)
            if m and m.group(1) in comps:
                cf, cb, cw, cd, cn = _comp_cost(
                    comps[m.group(1)], comps, totals, memo, world,
                    stack + (comp.name,))
                fl += cf
                by += cb
                wi += cw
                add_coll(cd, cn)
            continue
        # plain op bytes, with two deliberate modeling choices:
        #  * slicing ops read the slice, not the whole operand (a
        #    dynamic-slice of the (L,...) stacked params reads one layer;
        #    the naive rule over-counted a 40-layer scan body ~40x);
        #  * ELEMENTWISE ops are charged zero bytes — on the TPU target
        #    XLA fuses elementwise chains into their producers, while the
        #    CPU backend we compile on leaves many at top level (measured:
        #    15 copies of the same 536 MB score tensor).  Their traffic is
        #    captured at real boundaries (dots, reduces, copies, fusions).
        if oc in ("dynamic-slice", "slice", "gather"):
            by += 2 * _size_bytes(op.out_type)
        elif oc in ("dynamic-update-slice", "scatter"):
            ops_n = _operand_names(op.line)
            upd = _size_bytes(comp.types.get(ops_n[1], "")) if len(ops_n) > 1 \
                else _size_bytes(op.out_type)
            by += 2 * upd
        elif oc in _ELEMENTWISE:
            pass
        else:
            by += _size_bytes(op.out_type)
            for nm in _operand_names(op.line):
                by += _size_bytes(comp.types.get(nm, ""))
        if oc == "dot":
            fl += _dot_flops(op, comp)
        elif oc == "convolution":
            fl += _conv_flops(op, comp)
        elif oc in _ELEMENTWISE or oc == "reduce":
            fl += _numel(op.out_type)
    memo[comp.name] = (fl, by, wi, cbo, cct)
    return memo[comp.name]


def peak_temp_bytes(hlo_text: str) -> int:
    """Largest single non-parameter, non-tuple op output in the module —
    a cheap proxy for the biggest temporary XLA must materialize.  Used
    to verify memory claims of streamed programs (e.g. the chunked DML
    final stage never materializes the dense (n, p_phi) moment matrix:
    its peak temp is O(row_block · p_phi), while the whole-array path's
    is O(n · p_phi))."""
    comps = parse_hlo(hlo_text)
    peak = 0
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode in _NO_BYTES or op.out_type.startswith("("):
                continue
            peak = max(peak, _size_bytes(op.out_type))
    return peak


def cost_summary(hlo_text: str, world: int = 1) -> Dict[str, float]:
    """The one-call join the cost audit (repro.obs.audit) consumes:
    trip-count-aware roofline totals from :func:`analyze` plus the
    :func:`peak_temp_bytes` memory proxy, over one parse each.  World
    defaults to 1 — the audit runs on single-process chunk programs."""
    totals = analyze(hlo_text, world=world)
    return {
        "flops": totals.flops,
        "bytes": totals.bytes,
        "wire_bytes": totals.wire_bytes,
        "peak_temp_bytes": float(peak_temp_bytes(hlo_text)),
    }


def analyze(hlo_text: str, world: int = 256) -> CostTotals:
    comps = parse_hlo(hlo_text)
    totals = CostTotals()
    # entry computation: the one marked ENTRY, else the last
    entry = None
    for raw in hlo_text.splitlines():
        if raw.startswith("ENTRY"):
            m = re.search(r"ENTRY\s+%?([\w.\-]+)", raw)
            if m:
                entry = m.group(1)
    if entry is None or entry not in comps:
        entry = list(comps)[-1] if comps else None
    if entry is None:
        return totals
    memo: Dict[str, _Cost] = {}
    fl, by, wi, cd, cn = _comp_cost(comps[entry], comps, totals, memo, world)
    totals.flops = fl
    totals.bytes = by
    totals.wire_bytes = wi
    totals.coll_by_op = cd
    totals.coll_count = cn
    return totals
