"""End-to-end training driver.

``make_train_step`` is the single train-step factory used by BOTH the
real driver (this file's CLI, host mesh) and the multi-pod dry-run
(launch/dryrun.py, 512 placeholder devices): forward + CE, grad
accumulation over microbatches, optional gradient compression, LR
schedule, AdamW, all under pjit with the cell's sharding rules.

CLI (see examples/train_lm.py for the library-level version):

    PYTHONPATH=src python -m repro.launch.train \
        --arch granite-3-2b-smoke --steps 200 --batch 16 --seq 128
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.config import ParallelConfig, TrainConfig
from repro.configs import get_config
from repro.data.lm_data import bigram_ce_floor, lm_batch
from repro.data.pipeline import ShardedFeed, batch_sharding
from repro.launch.mesh import make_host_mesh
from repro.distributed.sharding import mesh_context, default_rules
from repro.models.model import Model, build_model
from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.compression import compress_decompress
from repro.optim.schedule import cosine_schedule


def make_train_step(model: Model, tcfg: TrainConfig):
    pcfg = model.parallel
    ct = model.cfg.compute_dtype

    def loss_fn(params, batch):
        # cast-before-gather: matrix params drop to compute dtype ONCE at
        # step start, while still sharded — every FSDP all-gather then
        # moves bf16 instead of fp32 (the model's per-use .astype becomes
        # a no-op).  Grads flow through the cast, so the optimizer still
        # accumulates into fp32 master params.  1-D params (norm scales,
        # biases) stay fp32.
        cast = jax.tree_util.tree_map(
            lambda p: p.astype(ct) if p.ndim >= 2 else p, params)
        return model.loss_fn(cast, batch)

    # PartitionSpecs for the grad accumulator: a bare jnp.zeros is
    # data-independent, so GSPMD REPLICATES it — every microbatch's
    # weight grads were then fp32-all-reduced to full size (measured:
    # 2 x 315 GiB/chip/step on arctic train_4k).  Constraining the
    # accumulator to the param sharding turns those into reduce-scatters
    # onto the FSDP shards.
    pspecs = None
    if model.rules is not None:
        from repro.distributed.sharding import param_specs
        pspecs = param_specs(model.schema(), model.rules)

    def train_step(params, opt: AdamWState, batch):
        if pcfg.microbatch > 1:
            m = pcfg.microbatch

            def resh(x):
                return x.reshape((m, x.shape[0] // m) + x.shape[1:])

            mbs = jax.tree_util.tree_map(resh, batch)
            acc_dt = pcfg.grad_accum_dtype
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            if pspecs is not None:
                zeros = jax.tree_util.tree_map(
                    lambda z, s: jax.lax.with_sharding_constraint(z, s),
                    zeros, pspecs)

            def acc(carry, mb):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                gsum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), gsum, g)
                if pspecs is not None:
                    # re-assert inside the loop body: while-carry
                    # shardings do not propagate reliably (same issue as
                    # the layer-scan residual carry)
                    gsum = jax.tree_util.tree_map(
                        lambda z, sp: jax.lax.with_sharding_constraint(z, sp),
                        gsum, pspecs)
                return (gsum, lsum + l), None

            (gsum, lsum), _ = jax.lax.scan(acc, (zeros, jnp.float32(0.0)),
                                           mbs)
            grads = jax.tree_util.tree_map(lambda g: g / m, gsum)
            loss = lsum / m
            metrics: Dict[str, jax.Array] = {"ce": loss}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)

        if pcfg.gradient_compression != "none":
            grads = jax.tree_util.tree_map(
                lambda g: compress_decompress(g, pcfg.gradient_compression),
                grads)

        lr = cosine_schedule(opt.step, peak=tcfg.learning_rate,
                             warmup=tcfg.warmup_steps, total=tcfg.total_steps)
        params, opt, om = adamw_update(grads, opt, params, lr, tcfg,
                                       pcfg.adam_moment_dtype)
        return params, opt, {"loss": loss, **metrics, **om}

    return train_step


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: AdamWState
    step: int = 0


def train_loop(model: Model, tcfg: TrainConfig, feed, *,
               manager: Optional[CheckpointManager] = None,
               ckpt_every: int = 0, log_every: int = 10,
               state: Optional[TrainState] = None,
               log=print) -> TrainState:
    if state is None:
        params = model.init(jax.random.PRNGKey(tcfg.seed))
        state = TrainState(params=params,
                           opt=adamw_init(params,
                                          model.parallel.adam_moment_dtype))
    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0, 1))
    t0 = time.time()
    for batch in feed:
        state.params, state.opt, metrics = step_fn(state.params, state.opt,
                                                   batch)
        state.step += 1
        if log_every and state.step % log_every == 0:
            loss = float(metrics["loss"])
            log(f"step {state.step:5d}  loss {loss:.4f}  "
                f"gnorm {float(metrics['grad_norm']):.3f}  "
                f"lr {float(metrics['lr']):.2e}  "
                f"{(time.time() - t0) / log_every:.3f}s/step")
            t0 = time.time()
        if manager is not None and ckpt_every and state.step % ckpt_every == 0:
            manager.save_async(state.step,
                               {"params": state.params, "opt": state.opt},
                               metric=float(metrics["loss"]))
        if state.step >= tcfg.total_steps:
            break
    if manager is not None:
        manager.wait()
    return state


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b-smoke")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    mesh = make_host_mesh()
    rules = default_rules(fsdp=False)
    pcfg = ParallelConfig(fsdp=False, microbatch=args.microbatch)
    model = build_model(cfg, pcfg, rules)
    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=args.steps // 10,
                       total_steps=args.steps)

    key = jax.random.PRNGKey(0)
    feed = ShardedFeed(
        lambda s: lm_batch(jax.random.fold_in(key, s), args.batch, args.seq,
                           cfg.vocab_size),
        sharding=batch_sharding(mesh))
    manager = (CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None)
    print(f"training {args.arch}: vocab {cfg.vocab_size}, "
          f"CE floor ≈ {bigram_ce_floor(cfg.vocab_size):.3f} nats")
    with mesh_context(mesh):
        train_loop(model, tcfg, feed, manager=manager,
                   ckpt_every=args.ckpt_every)
    feed.close()


if __name__ == "__main__":
    main()
