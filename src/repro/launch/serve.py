"""Batched serving driver (the NEXUS deployment path).

A minimal continuous-batching decode service: requests join a wave, the
wave prefills once, then decodes lock-step with per-slot stop handling.
On the production mesh this is the program the decode_* dry-run cells
lower; on the host mesh it runs for real (examples/serve_demo.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model


@dataclasses.dataclass
class Request:
    prompt: jax.Array          # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0   # 0 => greedy


@dataclasses.dataclass
class Completion:
    tokens: List[int]
    latency_s: float


class BatchServer:
    """Wave-batched decoder.  Pads a wave of requests to a common prompt
    length, prefills, then decodes; slots that hit max_new_tokens stop
    contributing (their outputs are dropped on the way out)."""

    def __init__(self, model: Model, params, *, max_seq: int = 512,
                 key: Optional[jax.Array] = None):
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.key = key if key is not None else jax.random.PRNGKey(0)
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step, donate_argnums=(2,))

    def _sample(self, logits: jax.Array, temperature: float) -> jax.Array:
        if temperature <= 0:
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        self.key, k = jax.random.split(self.key)
        return jax.random.categorical(
            k, logits[:, -1] / temperature).astype(jnp.int32)

    def serve_wave(self, requests: List[Request],
                   extras: Optional[Dict[str, Any]] = None
                   ) -> List[Completion]:
        t0 = time.time()
        B = len(requests)
        S = max(int(r.prompt.shape[0]) for r in requests)
        toks = jnp.stack([
            jnp.pad(r.prompt, (S - r.prompt.shape[0], 0))  # left-pad
            for r in requests]).astype(jnp.int32)
        batch = {"tokens": toks, **(extras or {})}

        # prefill against a cache sized for prompt + generation budget
        budget = S + max(r.max_new_tokens for r in requests)
        budget = min(budget, self.max_seq)
        logits, wave_cache = self._prefill(self.params, batch)
        cache = self.model.init_cache(B, budget)
        cache = _splice_prefill(cache, wave_cache, S)

        temp = requests[0].temperature
        out_tokens: List[List[int]] = [[] for _ in range(B)]
        nxt = self._sample(logits, temp)
        for i in range(B):
            out_tokens[i].append(int(nxt[i]))
        steps = max(r.max_new_tokens for r in requests) - 1
        for s in range(steps):
            pos = jnp.int32(S + s)
            logits, cache = self._decode(self.params, nxt[:, None], cache,
                                         pos)
            nxt = self._sample(logits, temp)
            for i in range(B):
                if len(out_tokens[i]) < requests[i].max_new_tokens:
                    out_tokens[i].append(int(nxt[i]))
        dt = time.time() - t0
        return [Completion(tokens=t, latency_s=dt) for t in out_tokens]


def _splice_prefill(full_cache, wave_cache, s: int):
    """Copy the prefill cache (seq length s) into the front of the
    generation-budget cache.  Recurrent states (ssm/rwkv) copy whole."""
    def splice(dst, src):
        if dst.shape == src.shape:
            return src
        # KV-style caches differ on the seq axis; find it and splice
        for ax in range(dst.ndim):
            if dst.shape[ax] != src.shape[ax]:
                return jax.lax.dynamic_update_slice_in_dim(
                    dst, src.astype(dst.dtype), 0, axis=ax)
        return src
    return jax.tree_util.tree_map(splice, full_cache, wave_cache)
