"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — jax locks the device count on
first backend init, and only dryrun.py is allowed to set the
512-placeholder-device XLA flag before that happens.
"""
from __future__ import annotations


import jax

SINGLE_POD = (16, 16)                  # 256 chips (v5e pod)
MULTI_POD = (2, 16, 16)                # 2 pods = 512 chips


def _mk(shape, axes, devices=None):
    axis_type = getattr(jax.sharding, "AxisType", None)
    kw = ({"axis_types": (axis_type.Auto,) * len(axes)}
          if axis_type is not None else {})
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes, devices=devices, **kw)
    # jax < 0.4.35 fallback
    import numpy as np
    devices = devices if devices is not None else jax.devices()
    return jax.sharding.Mesh(
        np.asarray(devices)[: int(np.prod(shape))].reshape(shape), axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devs)} exist; "
            "the dry-run must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before any jax import")
    return _mk(shape, axes, devices=devs[:n])


def make_host_mesh():
    """Whatever this host has — smoke tests and the CPU train driver."""
    n = len(jax.devices())
    return _mk((n, 1), ("data", "model"))


def make_causal_mesh(*, multi_pod: bool = False):
    """Flat row-parallel mesh for the DML engine (the paper's workload
    has one giant data axis; folds/trials batch inside the program)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    return mesh  # rows shard over ("data","model") jointly via the
    # "rows" logical axis (see distributed.sharding.default_rules)
