"""Elastic restart: resume a run on a DIFFERENT mesh than it was saved
from — the SPMD answer to Ray's "recover tasks from a failed machine"
(DESIGN.md §7).

Flow on pod failure:
  1. the job restarts with fewer (or more) pods -> a new mesh;
  2. ``elastic_restore`` rebuilds the state template from the model and
     re-places every checkpointed leaf under the NEW shardings (the
     checkpoint format is mesh-free, so this is just device_put);
  3. the data pipeline resumes from the checkpointed step — generation
     is a pure function of (key, step), so the replay is exact.

Straggler note: within a compiled step there are no stragglers (lock-step
SPMD); a persistently slow pod is handled by dropping it through this
path.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.models.model import Model
from repro.optim.adamw import AdamWState, adamw_init


def state_template(model: Model) -> Dict[str, Any]:
    """Abstract (ShapeDtypeStruct) train state matching train_loop's
    checkpoints."""
    params = model.abstract_params()
    opt = jax.eval_shape(
        lambda p: adamw_init(p, model.parallel.adam_moment_dtype), params)
    return {"params": params, "opt": opt}


def state_shardings(model: Model, rules, mesh) -> Dict[str, Any]:
    from jax.sharding import NamedSharding, PartitionSpec as P
    psh = model.param_shardings(rules, mesh)
    osh = AdamWState(step=NamedSharding(mesh, P()), m=psh, v=psh)
    return {"params": psh, "opt": osh}


def elastic_restore(manager: CheckpointManager, model: Model, rules, mesh,
                    *, step: Optional[int] = None
                    ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Restore the latest (or given) checkpoint onto ``mesh`` — which may
    have a different shape than the mesh that saved it."""
    template = state_template(model)
    shardings = state_shardings(model, rules, mesh)
    return manager.restore(template, step=step, shardings=shardings)


# ----------------------------------------------------------------------
# Causal path: elastic sweeps
# ----------------------------------------------------------------------
def sweep_checkpoint_manager(directory: str, spec,
                             *, keep_best: int = 1) -> CheckpointManager:
    """CheckpointManager sized for a per-column sweep checkpoint
    (step = column index): retention must cover every column plus one
    in-flight save, or early columns get pruned before the sweep ends.
    ``sweep()`` applies the same floor defensively; creating the
    manager here makes the elastic entry point one call."""
    return CheckpointManager(directory,
                             keep_latest=len(spec.columns) + 1,
                             keep_best=keep_best)


def elastic_sweep(spec, *, directory: str, data_mesh=None, **sweep_kwargs):
    """Run (or resume) a sweep with per-column checkpointing — the
    causal-path analogue of ``elastic_restore``.  A lost shard or a
    killed process costs at most the in-flight column: re-invoking with
    the same ``directory`` restores every completed column from disk
    and recomputes only the missing ones (sweep.engine's resume path,
    signature-checked per column).  ``data_mesh`` passes through to
    row-shard each column's moment passes."""
    from repro.sweep import sweep

    manager = sweep_checkpoint_manager(directory, spec)
    return sweep(spec, data_mesh=data_mesh, checkpoint=manager,
                 resume=True, **sweep_kwargs)
