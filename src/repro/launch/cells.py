"""Cell construction: one (architecture × input-shape × mesh) dry-run /
launch unit, with its sharding policy.

The policy encodes the real TP/DP decisions a production launcher makes,
all derived from divisibility against the fixed production mesh
(data=16|32, model=16):

  * heads/kv_heads shard over "model" only when divisible by TP=16;
    otherwise attention falls back to sequence-sharded q (train/prefill)
    or sequence-sharded KV cache (decode) — full-rank alternatives that
    keep per-chip attention work 1/16 without padding the architecture.
  * train params use FSDP (embed dim over the DP axes) + TP; serving
    params use pure TP (+ expert sharding over DP×TP for the MoE giants,
    whose expert tensors dominate).
  * decode caches shard batch over DP when divisible (decode_32k), else
    the cache's seq dim over DP (long_500k, batch=1).
  * sequence parallelism (residual seq over "model") is ON for train
    cells: the lax.scan layer carry is the dominant live activation and
    SP cuts it 16x.
  * MoE giants (arctic/deepseek) train with bf16 params+moments —
    recorded in EXPERIMENTS.md §Dry-run (the fp32 variants exceed v5e
    HBM at 256 chips).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import (ModelConfig, ParallelConfig, ShapeConfig,
    SHAPE_BY_NAME)
from repro.configs import get_config
from repro.distributed.sharding import ShardingRules, logical_to_spec
from repro.models.model import Model, build_model

TP = 16  # the "model" axis extent of the production mesh


def _div(a: int, b: int) -> bool:
    return a % b == 0


@dataclasses.dataclass(frozen=True)
class Cell:
    arch: str
    shape: ShapeConfig
    cfg: ModelConfig
    pcfg: ParallelConfig
    rules: ShardingRules
    multi_pod: bool

    @property
    def name(self) -> str:
        return f"{self.arch}/{self.shape.name}"

    def model(self) -> Model:
        return build_model(self.cfg, self.pcfg, self.rules)


# ---------------------------------------------------------------------------
# Per-cell parallel policy
# ---------------------------------------------------------------------------

BF16_TRAIN_ARCHS = ("arctic-480b", "deepseek-v3-671b")  # HBM-bound giants


def cell_parallel_config(cfg: ModelConfig, shape: ShapeConfig,
                         overrides: Optional[Dict[str, Any]] = None
                         ) -> Tuple[ModelConfig, ParallelConfig]:
    kw: Dict[str, Any] = {}
    if shape.kind == "train":
        kw.update(fsdp=True, sequence_parallel=True, remat_policy="nothing",
                  attention_impl="chunked")
        # per-chip activation footprint scales with B/microbatch: the MoE
        # giants need grad accumulation to fit expert dispatch buffers
        if cfg.num_experts:
            kw.update(microbatch=8)
        elif cfg.param_count() > 20e9 or cfg.family in ("hybrid",):
            kw.update(microbatch=2)
        if cfg.name in BF16_TRAIN_ARCHS:
            kw.update(adam_moment_dtype=jnp.bfloat16,
                      grad_accum_dtype=jnp.bfloat16)
            cfg = dataclasses.replace(cfg, param_dtype=jnp.bfloat16)
    else:
        kw.update(fsdp=False, sequence_parallel=False)
        # serving checkpoints are bf16 (halves weight HBM + collective)
        cfg = dataclasses.replace(cfg, param_dtype=jnp.bfloat16)
        if shape.kind == "prefill":
            kw.update(attention_impl="chunked")
    if shape.name == "long_500k":
        kw.update(shard_kv_seq=True)
    kw.update(overrides or {})
    return cfg, ParallelConfig(**kw)


def cell_rules(cfg: ModelConfig, shape: ShapeConfig, pcfg: ParallelConfig,
               *, multi_pod: bool) -> ShardingRules:
    dp: Any = ("pod", "data") if multi_pod else "data"
    dp_size = 32 if multi_pod else 16
    train = shape.kind == "train"

    heads_ok = _div(cfg.num_heads, TP) and cfg.attention in ("gqa", "mla")
    kv_ok = _div(cfg.num_kv_heads, TP) and cfg.attention == "gqa"
    if cfg.attention == "mla":
        kv_ok = False  # latent cache has no head dim; see kv_seq below
    vocab_ok = _div(cfg.padded_vocab, TP)  # always true by construction
    batch_ok = _div(shape.global_batch, dp_size)

    # decode-cache seq placement: model axis when heads can't claim it,
    # DP axes for the long-context cell (batch=1 frees them)
    kv_seq: Any = None
    if shape.kind == "decode":
        if pcfg.shard_kv_seq and _div(shape.seq_len, dp_size):
            kv_seq = dp if not batch_ok else "model"
        elif not kv_ok and _div(shape.seq_len, TP):
            kv_seq = "model"

    # attention q-seq sharding replaces head-TP when heads don't divide
    attn_seq = None
    if not heads_ok and shape.kind in ("train", "prefill") \
            and cfg.attention in ("gqa", "mla") and _div(shape.seq_len, TP):
        attn_seq = "model"

    # weight placement: train = FSDP (embed over DP) + TP; serving = pure
    # TP for archs whose TP-sharded weights fit HBM, ZeRO-style weight
    # sharding (embed over DP too, gathered per layer) for the giants.
    # Expert tensors stay EP over "model" — moving them to the DP axes
    # was tried and REFUTED (collective term unchanged: the dominant cost
    # was the global-sort dispatch, fixed in models/moe.py instead).
    # serving always shards the weights' embed dim over the DP axes too:
    # archs whose heads/kv don't divide TP would otherwise replicate
    # their attention weights 16x (measured: 24 GiB/chip fp32 on yi
    # decode); the contraction-dim sharding turns into small activation
    # all-reduces at decode shapes, not weight gathers
    embed: Any = None
    if train and pcfg.fsdp:
        embed = dp
    elif not train:
        embed = dp

    r = [
        ("batch", dp if batch_ok else None),
        ("vocab", "model" if vocab_ok else None),
        ("heads", "model" if heads_ok else None),
        ("kv_heads", "model" if kv_ok else None),
        ("ff", "model"),
        # experts: EP over DP x TP over "model" -> fully resident weights
        # (104 MB/layer/chip on arctic).  FSDP'd experts re-gather per
        # microbatch (measured 1.2+ TB/chip/step); EP moves ~0.3 GB of
        # dispatch activations per layer instead (all-to-all over data).
        ("experts", dp),
        ("expert_embed", None),
        ("expert_ff", "model"),
        ("embed", embed),
        ("embed_act", None),
        ("seq", "model" if pcfg.sequence_parallel else None),
        ("attn_seq", attn_seq),
        ("logits_seq", None),
        ("kv_seq", kv_seq),
        ("head_dim", None),
        ("state", None),
        ("layers", None),
        ("fold", None),
        ("qk_lora", None),
        ("inner", "model"),
        ("rows", dp),
    ]
    return ShardingRules(rules=tuple(r))


def make_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
              overrides: Optional[Dict[str, Any]] = None) -> Cell:
    cfg = get_config(arch)
    shape = SHAPE_BY_NAME[shape_name]
    cfg, pcfg = cell_parallel_config(cfg, shape, overrides)
    rules = cell_rules(cfg, shape, pcfg, multi_pod=multi_pod)
    return Cell(arch=arch, shape=shape, cfg=cfg, pcfg=pcfg, rules=rules,
                multi_pod=multi_pod)


# ---------------------------------------------------------------------------
# Shardings for the cell's inputs
# ---------------------------------------------------------------------------

def batch_pspecs(cell: Cell) -> Dict[str, P]:
    """PartitionSpecs mirroring Model.input_specs for train/prefill."""
    rules = cell.rules
    tok = logical_to_spec(("batch", None), rules)
    act3 = logical_to_spec(("batch", None, None), rules)
    specs = {"tokens": tok, "labels": tok,
             "patch_embeds": act3, "frames": act3}
    return specs


_CACHE_AXES = {
    # leaf name -> logical axes for (layers, batch, ...) cache leaves
    "k": ("layers", "batch", "kv_seq", "kv_heads", None),
    "v": ("layers", "batch", "kv_seq", "kv_heads", None),
    "c_kv": ("layers", "batch", "kv_seq", None),
    "k_rope": ("layers", "batch", "kv_seq", None),
    "ssm": ("layers", "batch", "inner", None, None),
    "conv": ("layers", "batch", None, "inner"),
    "s": ("layers", "batch", None, None, None),
    "x_prev": ("layers", "batch", None, None),
}


def cache_pspecs(cell: Cell, cache_shapes) -> Any:
    """PartitionSpec tree mirroring init_cache's structure.  Leaf rules
    are keyed by leaf name; whisper's cross-KV (T_src=1500, indivisible)
    stays replicated along seq."""
    rules = cell.rules

    def leaf_spec(path, leaf):
        name = None
        in_cross = False
        for pp in path:
            k = getattr(pp, "key", None)
            if k == "cross":
                in_cross = True
            if k in _CACHE_AXES:
                name = k
        axes = list(_CACHE_AXES[name])
        if in_cross:
            axes = [a if a != "kv_seq" else None for a in axes]
        # mamba ssm head dim shards over model only when divisible
        if name == "ssm" and leaf.shape[2] % TP != 0:
            axes[2] = None
        spec = logical_to_spec(tuple(axes)[: len(leaf.shape)], rules)
        return spec

    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    specs = [leaf_spec(p, l) for p, l in paths_leaves]
    return jax.tree_util.tree_unflatten(treedef, specs)


def named(mesh: Mesh, tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


def cell_input_shardings(cell: Cell, mesh: Mesh):
    """(example_args, in_shardings) for the cell's entry point."""
    model = cell.model()
    specs = model.input_specs(cell.shape)
    if cell.shape.kind in ("train", "prefill"):
        ps = batch_pspecs(cell)
        shard = {k: NamedSharding(mesh, ps[k]) for k in specs}
        return specs, shard
    # decode: {"tokens", "cache", "pos"}
    tok_spec = logical_to_spec(("batch", None), cell.rules)
    cache_sp = cache_pspecs(cell, specs["cache"])
    shard = {
        "tokens": NamedSharding(mesh, tok_spec),
        "cache": named(mesh, cache_sp),
        "pos": NamedSharding(mesh, P()),
    }
    return specs, shard
