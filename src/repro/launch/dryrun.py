import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST stay the first statements in this module
# (jax locks the platform device count at first init), which is also why
# there is no `from __future__ import annotations` here.

"""Multi-pod dry-run (deliverable e).

Lowers + compiles every (architecture × input-shape) cell against the
production mesh — single-pod (16,16)=256 chips and multi-pod
(2,16,16)=512 chips — and reports memory_analysis / cost_analysis /
collective stats per cell.  This is how the distribution config is
proven coherent without hardware: sharding mismatches, unsupported
collectives and compile-time OOMs all surface here as hard failures.

The two lines above MUST stay the first statements in this module: jax
locks the platform device count at first init, and only the dry-run is
allowed to see 512 placeholder devices (tests/benches see 1).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --json out.jsonl
"""
import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
from repro.distributed.sharding import mesh_context

from repro.config import SHAPES, TrainConfig
from repro.configs import ARCH_IDS
from repro.launch.cells import Cell, cell_input_shardings, make_cell
from repro.launch.mesh import make_production_mesh
from repro.launch import hlo_cost
from repro.launch.roofline import Roofline, model_flops_for
from repro.launch.train import make_train_step
from repro.optim.adamw import AdamWState, adamw_init


def _abstract_opt(model, params_abs) -> AdamWState:
    return jax.eval_shape(
        lambda p: adamw_init(p, model.parallel.adam_moment_dtype), params_abs)


def _opt_shardings(param_sh, mesh) -> AdamWState:
    from jax.sharding import NamedSharding, PartitionSpec as P
    return AdamWState(step=NamedSharding(mesh, P()), m=param_sh, v=param_sh)


def lower_cell(cell: Cell, mesh, tcfg: Optional[TrainConfig] = None):
    """Returns (lowered, example shapes) for the cell's entry point."""
    model = cell.model()
    params_abs = model.abstract_params()
    param_sh = model.param_shardings(cell.rules, mesh)
    inputs, input_sh = cell_input_shardings(cell, mesh)

    if cell.shape.kind == "train":
        tcfg = tcfg or TrainConfig()
        opt_abs = _abstract_opt(model, params_abs)
        opt_sh = _opt_shardings(param_sh, mesh)
        step = make_train_step(model, tcfg)
        with mesh_context(mesh):
            lowered = jax.jit(
                step,
                in_shardings=(param_sh, opt_sh, input_sh),
                out_shardings=(param_sh, opt_sh, None),
            ).lower(params_abs, opt_abs, inputs)
        return lowered

    if cell.shape.kind == "prefill":
        def prefill(params, batch):
            return model.prefill(params, batch)

        with mesh_context(mesh):
            lowered = jax.jit(
                prefill, in_shardings=(param_sh, input_sh),
            ).lower(params_abs, inputs)
        return lowered

    # decode: keep the cache sharding stable across steps
    def serve_step(params, tokens, cache, pos):
        return model.decode_step(params, tokens, cache, pos)

    with mesh_context(mesh):
        lowered = jax.jit(
            serve_step,
            in_shardings=(param_sh, input_sh["tokens"], input_sh["cache"],
                          input_sh["pos"]),
            out_shardings=(None, input_sh["cache"]),
        ).lower(params_abs, inputs["tokens"], inputs["cache"], inputs["pos"])
    return lowered


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             verbose: bool = True) -> Dict[str, Any]:
    t0 = time.time()
    cell = make_cell(arch, shape_name, multi_pod=multi_pod)
    model = cell.model()
    ok, why = model.supports_shape(cell.shape)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
    }
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    lowered = lower_cell(cell, mesh)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    # trip-count-aware cost (XLA's cost_analysis counts while bodies once;
    # see launch/hlo_cost.py) — raw XLA numbers kept alongside for audit
    hc = hlo_cost.analyze(hlo, world=rec["chips"])
    rl = Roofline(
        flops=hc.flops,
        hbm_bytes=hc.bytes,
        wire_bytes=hc.wire_bytes,
        model_flops=model_flops_for(cell.cfg, cell.shape),
        chips=rec["chips"],
    )
    mem = {}
    if ma is not None:
        mem = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
        }
        mem["peak_bytes"] = (mem["argument_bytes"] + mem["output_bytes"]
                             + mem["temp_bytes"] - mem["alias_bytes"])
    rec.update(
        status="ok",
        flops_per_chip=rl.flops,
        hbm_bytes_per_chip=rl.hbm_bytes,
        xla_flops_single_trip=float(ca.get("flops", 0.0)),
        xla_bytes_single_trip=float(ca.get("bytes accessed", 0.0)),
        unknown_trip_counts=hc.unknown_trip_counts,
        wire_bytes_per_chip=rl.wire_bytes,
        collective_count=hc.coll_count,
        collective_by_op={k: float(v) for k, v in hc.coll_by_op.items()},
        model_flops=rl.model_flops,
        t_compute=rl.t_compute, t_memory=rl.t_memory,
        t_collective=rl.t_collective,
        bottleneck=rl.bottleneck, step_time=rl.step_time,
        useful_frac=rl.useful_flops_frac, mfu_bound=rl.mfu_bound,
        memory=mem, lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
    )
    if verbose:
        print(f"[{rec['mesh']}] {arch}/{shape_name}: "
              f"bottleneck={rl.bottleneck} step>={rl.step_time*1e3:.1f}ms "
              f"mfu_bound={rl.mfu_bound:.2%} "
              f"peak_mem={mem.get('peak_bytes', 0)/2**30:.2f}GiB "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        print("  memory_analysis:", ma)
    return rec


def run_dml_cell(*, multi_pod: bool, verbose: bool = True,
                 n: int = 0, p: int = 0,
                 engine: str = "parallel") -> Dict[str, Any]:
    """The paper's own 1M x 500 fold-parallel DML fit on the mesh."""
    from repro.launch import dml_cell
    t0 = time.time()
    rec: Dict[str, Any] = {
        "arch": f"dml-crossfit-{engine}",
        "shape": f"{n or dml_cell.N_ROWS}rows",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
    }
    mesh = make_production_mesh(multi_pod=multi_pod)
    lowered = dml_cell.lower_dml_cell(
        mesh, n=n or dml_cell.N_ROWS, p=p or dml_cell.N_COVARIATES,
        engine=engine)
    compiled = lowered.compile()
    hc = hlo_cost.analyze(compiled.as_text(), world=rec["chips"])
    ma = compiled.memory_analysis()
    nn, pp = n or dml_cell.N_ROWS, p or dml_cell.N_COVARIATES
    # useful model flops: 2 nuisance Gram/Newton passes + final stage
    model_fl = 2.0 * 5 * nn * pp * pp * (1 + 16) / 4  # rough; see roofline
    rl = Roofline(flops=hc.flops, hbm_bytes=hc.bytes,
                  wire_bytes=hc.wire_bytes, model_flops=model_fl,
                  chips=rec["chips"])
    mem = {}
    if ma is not None:
        mem = {"argument_bytes": int(ma.argument_size_in_bytes),
               "temp_bytes": int(ma.temp_size_in_bytes)}
        mem["peak_bytes"] = (mem["argument_bytes"] + mem["temp_bytes"]
                             + int(ma.output_size_in_bytes))
    rec.update(status="ok", flops_per_chip=rl.flops,
               hbm_bytes_per_chip=rl.hbm_bytes,
               wire_bytes_per_chip=rl.wire_bytes,
               collective_by_op={k: float(v)
                                 for k, v in hc.coll_by_op.items()},
               collective_count=hc.coll_count,
               model_flops=model_fl, t_compute=rl.t_compute,
               t_memory=rl.t_memory, t_collective=rl.t_collective,
               bottleneck=rl.bottleneck, step_time=rl.step_time,
               useful_frac=rl.useful_flops_frac, mfu_bound=rl.mfu_bound,
               memory=mem, compile_s=round(time.time() - t0, 1))
    if verbose:
        print(f"[{rec['mesh']}] dml-crossfit/{rec['shape']}: "
              f"bottleneck={rl.bottleneck} step>={rl.step_time*1e3:.1f}ms "
              f"peak_mem={mem.get('peak_bytes', 0)/2**30:.2f}GiB")
        print("  memory_analysis:", ma)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="")
    ap.add_argument("--shape", default="")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--paper-cell", action="store_true",
                    help="lower the paper's 1Mx500 DML fit instead")
    ap.add_argument("--json", default="")
    args = ap.parse_args(argv)

    if args.paper_cell:
        out = open(args.json, "a") if args.json else None
        for mp in {"single": [False], "multi": [True],
                   "both": [False, True]}[args.mesh]:
            for engine in ("parallel", "parallel_loo"):
                rec = run_dml_cell(multi_pod=mp, engine=engine)
                if out:
                    out.write(json.dumps(rec) + "\n")
                    out.flush()
        if out:
            out.close()
        return 0

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = ([s.name for s in SHAPES] if (args.all or not args.shape)
              else [args.shape])
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    out = open(args.json, "a") if args.json else None
    failed = 0
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                try:
                    rec = run_cell(arch, shape, multi_pod=mp)
                except Exception as e:  # a sharding bug — report, keep going
                    failed += 1
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "error", "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                    print(f"[FAIL] {arch}/{shape}: {e}", file=sys.stderr)
                if out:
                    out.write(json.dumps(rec) + "\n")
                    out.flush()
    if out:
        out.close()
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
