"""Bootstrap re-estimation as ONE batched program.

EconML's ``BootstrapInference(n_bootstrap_samples=B)`` re-runs the whole
estimator B times — the most expensive iterative step the paper's Ray
translation targets.  Here each replicate is a *weighted* refit (pairs
bootstrap = multinomial row counts; multiplier/Bayesian = Exp(1) row
weights), which reuses the weighted-fit path that ``fold_weights``
already exercises for C1: replicate weights multiply the fold-complement
masks, so the (B, k, n) weight tensor turns B full re-estimations into
one stacked program dispatched by an Executor.

Replay: replicate b derives all of its randomness (resampling weights
AND fold assignment) from ``fold_in(base_key, b)`` — any replicate can
be re-run alone, bit-identically, which is the SPMD translation of Ray's
lineage-based reconstruction.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.crossfit import _oof_select, fold_ids, fold_weights
from repro.core.nuisance import Nuisance
from repro.inference.intervals import InferenceResult
from repro.inference.numerics import (logistic_fit_folds_w,
                                      predict_folds_linear,
                                      predict_folds_logistic,
                                      ridge_fit_folds_w,
                                      weighted_iv_theta, weighted_theta)

SCHEMES = ("pairs", "multiplier", "bayesian")


def bootstrap_weights(key: jax.Array, n: int, scheme: str) -> jax.Array:
    """Per-row resampling weights, mean ≈ 1.

    pairs       multinomial counts (classic resample-with-replacement);
                integer counts -> exactly batch-invariant;
    multiplier  i.i.d. Exp(1) multipliers (= Bayesian bootstrap /
                Rubin's Dirichlet weights up to normalization).
    """
    if scheme == "pairs":
        idx = jax.random.randint(key, (n,), 0, n)
        return jnp.bincount(idx, length=n).astype(jnp.float32)
    if scheme in ("multiplier", "bayesian"):
        return jax.random.exponential(key, (n,), jnp.float32)
    raise ValueError(f"unknown bootstrap scheme {scheme!r}")


def replicate_keys(key: jax.Array, n_replicates: int) -> jax.Array:
    """(B, key) stack where replicate b's key is ``fold_in(base, b)`` —
    NOT ``split(base, B)``, so replicate b is independent of B: a B=100
    run is a bit-exact prefix of a B=200 run, and any single replicate
    can be replayed alone (the lineage property)."""
    return jax.vmap(lambda b: jax.random.fold_in(key, b))(
        jnp.arange(n_replicates, dtype=jnp.uint32))


def _hyper(nuis: Nuisance, name: str, default):
    h = getattr(nuis, "hyper", None) or {}
    return h.get(name, default)


def fit_predict_folds(nuis: Nuisance, key: jax.Array, X: jax.Array,
                      target: jax.Array, Wk: jax.Array,
                      row_block: int = 0) -> jax.Array:
    """(k, n) fold-model predictions under weighted training.

    ridge/logistic take the replicate-invariant fold-batched kernels
    (serial == vmap bitwise), streamed in row blocks when the nuisance
    carries a ``row_block`` hyper (or one is passed); other nuisances
    (MLP, custom) fall back to vmapping ``nuis.fit`` over folds —
    statistically identical, but LAPACK-free bit-identity is not
    guaranteed there.
    """
    rb = row_block or int(_hyper(nuis, "row_block", 0))
    if nuis.name == "ridge":
        lam = _hyper(nuis, "lam", 1e-3)
        return predict_folds_linear(
            ridge_fit_folds_w(lam, X, target, Wk, row_block=rb), X)
    if nuis.name == "logistic":
        lam = _hyper(nuis, "lam", 1e-3)
        iters = int(_hyper(nuis, "iters", 16))
        return predict_folds_logistic(
            logistic_fit_folds_w(lam, iters, X, target, Wk,
                                 row_block=rb), X)
    k = Wk.shape[0]
    keys = jax.random.split(key, k)
    st0 = jax.vmap(nuis.init, in_axes=(0, None))(keys, X.shape[1])
    st = jax.vmap(nuis.fit, in_axes=(0, None, None, 0))(st0, X, target, Wk)
    return jax.vmap(nuis.predict, in_axes=(0, None))(st, X)


def dml_residuals_once(nuis_y: Nuisance, nuis_t: Nuisance, n_folds: int,
                       XW: jax.Array, y: jax.Array, t: jax.Array,
                       key: jax.Array, w: jax.Array, *,
                       row_block: int = 0) -> Dict[str, jax.Array]:
    """The nuisance prefix of one weighted DML re-estimation: folds
    re-derived from ``key``, both nuisances cross-fit under
    ``fold_weights * w``, returning the orthogonal residuals
    {ry, rt}.  Split out so sweep cells that differ only in final
    stage can share one nuisance pass (repro.sweep)."""
    kf, ky, kt = jax.random.split(key, 3)
    folds = fold_ids(kf, XW.shape[0], n_folds)
    Wk = fold_weights(folds, n_folds) * w[None, :]
    oof_y = _oof_select(fit_predict_folds(nuis_y, ky, XW, y, Wk,
                                          row_block), folds)
    oof_t = _oof_select(fit_predict_folds(nuis_t, kt, XW, t, Wk,
                                          row_block), folds)
    return {"ry": y.astype(jnp.float32) - oof_y,
            "rt": t.astype(jnp.float32) - oof_t}


def dml_theta_once(nuis_y: Nuisance, nuis_t: Nuisance, n_folds: int,
                   XW: jax.Array, y: jax.Array, t: jax.Array,
                   phi: jax.Array, key: jax.Array, w: jax.Array,
                   *, with_se: bool = True, row_block: int = 0
                   ) -> Dict[str, jax.Array]:
    """One full weighted DML re-estimation (the replicate closure body):
    fold keys re-derived from ``key``, nuisances cross-fit under
    ``fold_weights * w``, weighted orthogonal final stage.  Pure and
    jit/vmap-compatible."""
    r = dml_residuals_once(nuis_y, nuis_t, n_folds, XW, y, t, key, w,
                           row_block=row_block)
    theta, se = weighted_theta(r["ry"], r["rt"], phi, w, with_se=with_se,
                               row_block=row_block)
    out = {"theta": theta}
    if se is not None:
        out["se"] = se
    return out


def make_dml_replicate_fn(nuis_y: Nuisance, nuis_t: Nuisance,
                          n_folds: int, *, scheme: str = "pairs",
                          with_se: bool = True, row_block: int = 0):
    """The bootstrap replicate closure: (key, XW, y, t, phi) ->
    {theta[, se]}.  The data tensors arrive as executor pass-through
    arguments (not closure constants) so compiled programs take them as
    real inputs; build the closure ONCE and reuse it across
    executor.map calls — executors key their compiled-program caches on
    the closure object."""

    def replicate(kb, XW, y, t, phi):
        kw, kfit = jax.random.split(kb)
        w = bootstrap_weights(kw, XW.shape[0], scheme)
        return dml_theta_once(nuis_y, nuis_t, n_folds, XW, y, t, phi,
                              kfit, w, with_se=with_se,
                              row_block=row_block)

    return replicate


def dml_bootstrap(nuis_y: Nuisance, nuis_t: Nuisance, *, n_folds: int,
                  XW: jax.Array, y: jax.Array, t: jax.Array,
                  phi: jax.Array, key: jax.Array,
                  n_replicates: int = 200, scheme: str = "pairs",
                  executor="vmap", alpha: float = 0.05,
                  with_se: bool = True,
                  point: Optional[jax.Array] = None,
                  point_se: Optional[jax.Array] = None,
                  mesh=None, rules=None,
                  row_block: int = 0, memory_budget: int = 0,
                  chunk: int = 0, max_retries: int = 2) -> InferenceResult:
    """B weighted DML refits scheduled by the task runtime: the
    replicate axis streams in memory-budgeted chunks (repro.runtime),
    each chunk retrying down the backend ladder on failure — results
    are replicate-ordered and bit-identical across all of it."""
    from repro.runtime import as_runtime
    rt = as_runtime(executor, mesh=mesh, rules=rules,
                    memory_budget=memory_budget, chunk=chunk,
                    max_retries=max_retries)
    keys = replicate_keys(key, n_replicates)
    replicate = make_dml_replicate_fn(nuis_y, nuis_t, n_folds,
                                      scheme=scheme, with_se=with_se,
                                      row_block=row_block)
    out = rt.map(replicate, keys, XW, y, t, phi, label="dml_bootstrap")
    thetas = out["theta"]
    se = jnp.std(thetas, axis=0, ddof=1)
    return InferenceResult(
        method=scheme, executor=rt.name,
        point=thetas.mean(axis=0) if point is None else point,
        replicates=thetas, se=se, alpha=alpha, point_se=point_se,
        replicate_se=out.get("se"))


def iv_residuals_once(nuis_y: Nuisance, nuis_t: Nuisance,
                      nuis_z: Nuisance, n_folds: int, XW: jax.Array,
                      y: jax.Array, t: jax.Array, z: jax.Array,
                      key: jax.Array, w: jax.Array, *,
                      row_block: int = 0) -> Dict[str, jax.Array]:
    """The nuisance prefix of one weighted OrthoIV re-estimation: folds
    re-derived from ``key``, the THREE nuisances cross-fit under
    ``fold_weights * w``, returning the residual triple {ry, rt, rz}
    (shared by sweep cells that differ only in final stage)."""
    kf, ky, kt, kz = jax.random.split(key, 4)
    folds = fold_ids(kf, XW.shape[0], n_folds)
    Wk = fold_weights(folds, n_folds) * w[None, :]
    oof_y = _oof_select(fit_predict_folds(nuis_y, ky, XW, y, Wk,
                                          row_block), folds)
    oof_t = _oof_select(fit_predict_folds(nuis_t, kt, XW, t, Wk,
                                          row_block), folds)
    oof_z = _oof_select(fit_predict_folds(nuis_z, kz, XW, z, Wk,
                                          row_block), folds)
    return {"ry": y.astype(jnp.float32) - oof_y,
            "rt": t.astype(jnp.float32) - oof_t,
            "rz": z.astype(jnp.float32) - oof_z}


def iv_theta_once(nuis_y: Nuisance, nuis_t: Nuisance, nuis_z: Nuisance,
                  n_folds: int, XW: jax.Array, y: jax.Array,
                  t: jax.Array, z: jax.Array, phi: jax.Array,
                  key: jax.Array, w: jax.Array, *, with_se: bool = True,
                  row_block: int = 0) -> Dict[str, jax.Array]:
    """One full weighted OrthoIV re-estimation (the replicate closure
    body): folds re-derived from ``key``, the THREE nuisances cross-fit
    under ``fold_weights * w``, weighted instrumented final stage.
    Pure, jit/vmap-compatible, built only from the replicate-invariant
    vocabulary."""
    r = iv_residuals_once(nuis_y, nuis_t, nuis_z, n_folds, XW, y, t, z,
                          key, w, row_block=row_block)
    theta, se = weighted_iv_theta(r["ry"], r["rt"], r["rz"], phi, w,
                                  with_se=with_se, row_block=row_block)
    out = {"theta": theta}
    if se is not None:
        out["se"] = se
    return out


def iv_bootstrap(nuis_y: Nuisance, nuis_t: Nuisance, nuis_z: Nuisance,
                 *, n_folds: int, XW: jax.Array, y: jax.Array,
                 t: jax.Array, z: jax.Array, phi: jax.Array,
                 key: jax.Array, n_replicates: int = 200,
                 scheme: str = "pairs", executor="vmap",
                 alpha: float = 0.05, with_se: bool = True,
                 point: Optional[jax.Array] = None,
                 point_se: Optional[jax.Array] = None,
                 mesh=None, rules=None, row_block: int = 0,
                 memory_budget: int = 0, chunk: int = 0,
                 max_retries: int = 2) -> InferenceResult:
    """B weighted OrthoIV refits through the task runtime — the same
    chunked, fault-tolerant, replicate-ordered scheduling as
    dml_bootstrap."""
    from repro.runtime import as_runtime
    rt_ = as_runtime(executor, mesh=mesh, rules=rules,
                     memory_budget=memory_budget, chunk=chunk,
                     max_retries=max_retries)
    keys = replicate_keys(key, n_replicates)

    def replicate(kb, XW_, y_, t_, z_, phi_):
        kw, kfit = jax.random.split(kb)
        w = bootstrap_weights(kw, XW_.shape[0], scheme)
        return iv_theta_once(nuis_y, nuis_t, nuis_z, n_folds, XW_, y_,
                             t_, z_, phi_, kfit, w, with_se=with_se,
                             row_block=row_block)

    out = rt_.map(replicate, keys, XW, y, t, z, phi, label="iv_bootstrap")
    thetas = out["theta"]
    return InferenceResult(
        method=scheme, executor=rt_.name,
        point=thetas.mean(axis=0) if point is None else point,
        replicates=thetas, se=jnp.std(thetas, axis=0, ddof=1),
        alpha=alpha, point_se=point_se, replicate_se=out.get("se"))


def driv_theta_once(nuis_y: Nuisance, nuis_t: Nuisance, nuis_z: Nuisance,
                    compliance: Nuisance, n_folds: int, XW: jax.Array,
                    y: jax.Array, t: jax.Array, z: jax.Array,
                    phi: jax.Array, key: jax.Array, w: jax.Array, *,
                    cov_clip: float = 0.1, with_se: bool = True,
                    row_block: int = 0) -> Dict[str, jax.Array]:
    """One weighted DRIV re-estimation (mirrors DRIV.fit): weighted
    residual nuisances + weighted compliance fit β(x) = E[rt·rz|X],
    preliminary weighted constant OrthoIV, pseudo-outcome regression on
    phi.  Draws the LATE functional (weighted mean ψ) alongside
    theta."""
    from repro.core.iv import clip_compliance
    f32 = jnp.float32
    n = XW.shape[0]
    kf, ky, kt, kz, kb = jax.random.split(key, 5)
    folds = fold_ids(kf, n, n_folds)
    Wk = fold_weights(folds, n_folds) * w[None, :]
    oof_y = _oof_select(fit_predict_folds(nuis_y, ky, XW, y, Wk,
                                          row_block), folds)
    oof_t = _oof_select(fit_predict_folds(nuis_t, kt, XW, t, Wk,
                                          row_block), folds)
    oof_z = _oof_select(fit_predict_folds(nuis_z, kz, XW, z, Wk,
                                          row_block), folds)
    ry = y.astype(f32) - oof_y
    rt = t.astype(f32) - oof_t
    rz = z.astype(f32) - oof_z
    oof_b = _oof_select(fit_predict_folds(compliance, kb, XW, rt * rz,
                                          Wk, row_block), folds)
    beta = clip_compliance(oof_b, cov_clip)
    ones = jnp.ones((n, 1), f32)
    th_pre, _ = weighted_iv_theta(ry, rt, rz, ones, w, with_se=False,
                                  row_block=row_block)
    psi = th_pre[0] + (ry - th_pre[0] * rt) * rz / beta
    theta, se = weighted_theta(psi, jnp.ones((n,), f32), phi, w,
                               with_se=with_se, row_block=row_block)
    wf = w.astype(f32)
    ate = (wf * psi).sum() / jnp.maximum(wf.sum(), 1.0)
    out = {"theta": theta, "ate": ate}
    if se is not None:
        out["se"] = se
    return out


def driv_bootstrap(nuis_y: Nuisance, nuis_t: Nuisance, nuis_z: Nuisance,
                   compliance: Nuisance, *, n_folds: int, XW: jax.Array,
                   y: jax.Array, t: jax.Array, z: jax.Array,
                   phi: jax.Array, key: jax.Array,
                   n_replicates: int = 200, scheme: str = "pairs",
                   executor="vmap", alpha: float = 0.05,
                   cov_clip: float = 0.1, with_se: bool = True,
                   point: Optional[jax.Array] = None,
                   point_se: Optional[jax.Array] = None,
                   ate_point: Optional[float] = None,
                   mesh=None, rules=None, row_block: int = 0,
                   memory_budget: int = 0, chunk: int = 0,
                   max_retries: int = 2) -> InferenceResult:
    """B weighted DRIV refits through the task runtime; the LATE
    functional's own draws ride along (ate_interval centers on mean ψ,
    not theta[0], exactly like dr_bootstrap)."""
    from repro.runtime import as_runtime
    rt_ = as_runtime(executor, mesh=mesh, rules=rules,
                     memory_budget=memory_budget, chunk=chunk,
                     max_retries=max_retries)
    keys = replicate_keys(key, n_replicates)

    def replicate(kb, XW_, y_, t_, z_, phi_):
        kw, kfit = jax.random.split(kb)
        w = bootstrap_weights(kw, XW_.shape[0], scheme)
        return driv_theta_once(nuis_y, nuis_t, nuis_z, compliance,
                               n_folds, XW_, y_, t_, z_, phi_, kfit, w,
                               cov_clip=cov_clip, with_se=with_se,
                               row_block=row_block)

    out = rt_.map(replicate, keys, XW, y, t, z, phi,
                  label="driv_bootstrap")
    thetas = out["theta"]
    return InferenceResult(
        method=scheme, executor=rt_.name,
        point=thetas.mean(axis=0) if point is None else point,
        replicates=thetas, se=jnp.std(thetas, axis=0, ddof=1),
        alpha=alpha, point_se=point_se, replicate_se=out.get("se"),
        ate_replicates=out["ate"], ate_point=ate_point)


def dr_theta_once(outcome: Nuisance, propensity: Nuisance, n_folds: int,
                  X: jax.Array, y: jax.Array, t: jax.Array,
                  phi: jax.Array, key: jax.Array, w: jax.Array,
                  *, clip: float = 0.01, with_se: bool = True,
                  row_block: int = 0) -> Dict[str, jax.Array]:
    """One weighted AIPW re-estimation (mirrors DRLearner.fit): weighted
    arm-wise outcome fits + weighted propensity, weighted pseudo-outcome
    regression on phi.  With the constant basis theta[0] IS the weighted
    ATE."""
    kf, k0, k1, ke = jax.random.split(key, 4)
    n = X.shape[0]
    folds = fold_ids(kf, n, n_folds)
    W = fold_weights(folds, n_folds)
    tt = t.astype(jnp.float32)
    arm0 = (1.0 - tt)[None, :]
    arm1 = tt[None, :]
    wk = w[None, :]
    m0 = _oof_select(fit_predict_folds(outcome, k0, X, y,
                                       W * arm0 * wk, row_block), folds)
    m1 = _oof_select(fit_predict_folds(outcome, k1, X, y,
                                       W * arm1 * wk, row_block), folds)
    e = _oof_select(fit_predict_folds(propensity, ke, X, tt, W * wk,
                                      row_block), folds)
    e = jnp.clip(e, clip, 1.0 - clip)
    psi = (m1 - m0
           + tt * (y - m1) / e
           - (1.0 - tt) * (y - m0) / (1.0 - e))
    theta, se = weighted_theta(psi, jnp.ones((n,), jnp.float32), phi, w,
                               with_se=with_se, row_block=row_block)
    # the ATE functional itself (DRResult.ate = mean psi), weighted —
    # theta[0] only equals it for the constant basis, so draw it too
    wf = w.astype(jnp.float32)
    ate = (wf * psi).sum() / jnp.maximum(wf.sum(), 1.0)
    out = {"theta": theta, "ate": ate}
    if se is not None:
        out["se"] = se
    return out


def dr_bootstrap(outcome: Nuisance, propensity: Nuisance, *, n_folds: int,
                 X: jax.Array, y: jax.Array, t: jax.Array, phi: jax.Array,
                 key: jax.Array, n_replicates: int = 200,
                 scheme: str = "pairs", executor="vmap",
                 alpha: float = 0.05, clip: float = 0.01,
                 with_se: bool = True,
                 point: Optional[jax.Array] = None,
                 point_se: Optional[jax.Array] = None,
                 ate_point: Optional[float] = None,
                 mesh=None, rules=None,
                 row_block: int = 0, memory_budget: int = 0,
                 chunk: int = 0, max_retries: int = 2) -> InferenceResult:
    """B weighted AIPW refits through the task runtime (same chunked,
    fault-tolerant scheduling as dml_bootstrap)."""
    from repro.runtime import as_runtime
    rt = as_runtime(executor, mesh=mesh, rules=rules,
                    memory_budget=memory_budget, chunk=chunk,
                    max_retries=max_retries)
    keys = replicate_keys(key, n_replicates)

    def replicate(kb, X_, y_, t_, phi_):
        kw, kfit = jax.random.split(kb)
        w = bootstrap_weights(kw, X_.shape[0], scheme)
        return dr_theta_once(outcome, propensity, n_folds, X_, y_, t_,
                             phi_, kfit, w, clip=clip, with_se=with_se,
                             row_block=row_block)

    out = rt.map(replicate, keys, X, y, t, phi, label="dr_bootstrap")
    thetas = out["theta"]
    return InferenceResult(
        method=scheme, executor=rt.name,
        point=thetas.mean(axis=0) if point is None else point,
        replicates=thetas, se=jnp.std(thetas, axis=0, ddof=1),
        alpha=alpha, point_se=point_se, replicate_se=out.get("se"),
        ate_replicates=out["ate"], ate_point=ate_point)
