"""The Executor protocol — "how iterative steps run" as a first-class,
swappable choice (the SPMD analogue of Ray's task pool).

The paper's thesis (§5): fold fits, tuning trials, and bootstrap
replicates are embarrassingly parallel, so schedule them as concurrent
tasks instead of Python loops.  An Executor maps a fit-closure over a
leading *replicate* axis:

  serial     one compiled program per replicate, strictly in sequence —
             the EconML/Ray-less baseline every benchmark compares to;
  vmap       all replicates stacked and batched into ONE program — the
             single-host translation of Ray's task pool (paper C1/C2);
  shard_map  the replicate axis sharded over the ``data`` mesh axis via
             distributed/sharding.py rules — replicates spread across
             devices, each shard running the vmapped program locally.

``serial`` and ``vmap`` are *bit-identical* per replicate when the
closure is built from the replicate-invariant vocabulary in
``inference/numerics.py`` (tests assert this).  Closures take one pytree
argument whose leaves carry the replicate axis first (PRNG keys,
hyper-parameter values, fold weights, ...) and return a pytree of
arrays.
"""
from __future__ import annotations

import contextlib
import dataclasses
import weakref
from typing import Any, Callable, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P


@runtime_checkable
class Executor(Protocol):
    """Maps ``fn`` over the leading axis of ``xs`` (a pytree).  Extra
    ``*args`` are passed through to every call UN-mapped (replicated) —
    use them for the data tensors so they enter the compiled program as
    arguments, not as baked-in constants XLA will try to fold (a real
    compile-time cost at industrial n)."""

    name: str

    def map(self, fn: Callable[..., Any], xs: Any, *args: Any) -> Any:
        ...


def _leading_dim(xs: Any) -> int:
    leaves = jax.tree_util.tree_leaves(xs)
    if not leaves:
        raise ValueError("executor.map needs at least one array input")
    return leaves[0].shape[0]


def _index(xs: Any, i: int) -> Any:
    return jax.tree_util.tree_map(lambda x: x[i], xs)


# Observability taps (repro.obs): callables invoked with the closure on
# every _JitCache miss — a miss is a fresh jit wrapper, i.e. a compile
# the executor could not amortize.  Empty list (the default) costs one
# falsy check per miss; hooks are installed scoped via jit_miss_hook().
_JIT_MISS_HOOKS: list = []


@contextlib.contextmanager
def jit_miss_hook(cb: Callable[[Any], None]):
    """Scoped registration of a jit-cache-miss observer (the tracer's
    per-closure recompile counter)."""
    _JIT_MISS_HOOKS.append(cb)
    try:
        yield
    finally:
        _JIT_MISS_HOOKS.remove(cb)


class _JitCache:
    """Per-executor compiled-program reuse: ``map(fn, ...)`` called twice
    with the SAME closure object hits the same jit wrapper (and thus its
    compilation cache) instead of re-tracing.  Weak keys let dead
    closures drop out."""

    def __init__(self):
        self._cache = weakref.WeakKeyDictionary()

    def get(self, fn, build):
        f = self._cache.get(fn)
        if f is None:
            if _JIT_MISS_HOOKS:
                for hook in tuple(_JIT_MISS_HOOKS):
                    hook(fn)
            f = build(fn)
            self._cache[fn] = f
        return f


@dataclasses.dataclass
class SerialExecutor:
    """Python loop over replicates — one dispatch per replicate, like K
    Ray-less workers.  The runtime baseline for bench_inference."""

    name: str = "serial"
    jit: bool = True

    def __post_init__(self):
        self._jits = _JitCache()

    def map(self, fn, xs, *args):
        f = self._jits.get(fn, jax.jit) if self.jit else fn
        outs = [f(_index(xs, i), *args) for i in range(_leading_dim(xs))]
        return jax.tree_util.tree_map(lambda *ys: jnp.stack(ys), *outs)


@dataclasses.dataclass
class VmapExecutor:
    """All replicates as ONE batched program (the paper's translation of
    the Ray task pool to SPMD).

    ``microbatch`` caps how many replicates are batched per program:
    the (B, k, n, p) weighted-Gram intermediates grow linearly in the
    batch, so at industrial n a full-B program can exceed memory; chunks
    of the same compiled program keep the batching win with bounded
    footprint (bit-identity is preserved — per-replicate numerics are
    batch-size-invariant)."""

    name: str = "vmap"
    microbatch: Optional[int] = None

    def __post_init__(self):
        self._jits = _JitCache()

    def map(self, fn, xs, *args):
        def build(g):
            @jax.jit
            def batched(xs_, *a):
                return jax.vmap(lambda x_: g(x_, *a))(xs_)
            return batched

        f = self._jits.get(fn, build)
        b = _leading_dim(xs)
        c = self.microbatch
        if not c or c >= b:
            return f(xs, *args)
        outs = [f(jax.tree_util.tree_map(lambda x: x[i:i + c], xs), *args)
                for i in range(0, b, c)]
        return jax.tree_util.tree_map(
            lambda *ys: jnp.concatenate(ys, axis=0), *outs)


@dataclasses.dataclass
class ShardMapExecutor:
    """Replicate axis sharded over a mesh axis; each shard runs the
    vmapped program on its local replicates.  The replicate count is
    padded up to a multiple of the mesh axis size (padding replays
    replicate 0 and is dropped from the output)."""

    mesh: Optional[Mesh] = None
    axis: str = "data"
    name: str = "shard_map"

    def __post_init__(self):
        self._jits = _JitCache()

    def _mesh(self) -> Mesh:
        if self.mesh is not None:
            return self.mesh
        return Mesh(np.asarray(jax.devices()), (self.axis,))

    def map(self, fn, xs, *args):
        from jax.experimental.shard_map import shard_map
        mesh = self._mesh()
        size = mesh.shape[self.axis]
        b = _leading_dim(xs)
        pad = (-b) % size

        def pad_leaf(x):
            if pad == 0:
                return x
            return jnp.concatenate(
                [x, jnp.broadcast_to(x[:1], (pad,) + x.shape[1:])], axis=0)

        xs_p = jax.tree_util.tree_map(pad_leaf, xs)
        spec = P(self.axis)

        def build(g):
            @jax.jit
            def sharded(xs_, *a):
                # replicate axis sharded; pass-through args replicated
                inner = shard_map(
                    lambda x_, *aa: jax.vmap(lambda e: g(e, *aa))(x_),
                    mesh=mesh,
                    in_specs=(spec,) + tuple(
                        jax.tree_util.tree_map(lambda _: P(), aa_)
                        for aa_ in a),
                    out_specs=spec, check_rep=False)
                return inner(xs_, *a)
            return sharded

        out = self._jits.get(fn, build)(xs_p, *args)
        return jax.tree_util.tree_map(lambda y: y[:b], out)


# Default serial/vmap executors are process-wide singletons: their
# _JitCache (keyed on closure objects) is what turns "call crossfit /
# bootstrap again" into a compile-cache hit instead of a re-trace.
_DEFAULT_EXECUTORS: dict = {}


def make_executor(name, *, mesh: Optional[Mesh] = None,
                  rules=None) -> Executor:
    """Factory.  ``name`` may already be an Executor (passed through).
    For ``shard_map`` the mesh axis defaults to the one the sharding
    rules assign to the logical ``replicate`` axis (falling back to
    ``data``) — the same rule table that shards DML rows."""
    if isinstance(name, (SerialExecutor, VmapExecutor, ShardMapExecutor)):
        return name
    if not isinstance(name, str) and isinstance(name, Executor):
        return name
    if name == "serial":
        return _DEFAULT_EXECUTORS.setdefault("serial", SerialExecutor())
    if name == "vmap":
        return _DEFAULT_EXECUTORS.setdefault("vmap", VmapExecutor())
    if name == "shard_map":
        axis = "data"
        if rules is not None:
            mapped = rules.get("replicate")
            if isinstance(mapped, (tuple, list)):
                mapped = mapped[-1] if mapped else None
            if isinstance(mapped, str):
                axis = mapped
        return ShardMapExecutor(mesh=mesh, axis=axis)
    raise ValueError(f"unknown executor {name!r} "
                     "(expected serial | vmap | shard_map)")
