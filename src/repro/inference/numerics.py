"""Replicate-invariant weighted estimation kernels.

The Executor contract promises that ``serial`` and ``vmap`` backends
produce *bit-identical* per-replicate estimates.  XLA does not give that
for free: LAPACK solves (``jnp.linalg.solve``, Cholesky) and mat-vec
einsums change their reduction order when a leading batch dimension is
added, so a vmapped replicate differs from the same replicate run alone
by a few ulps.  Empirically (see tests/test_inference.py) the operations
that ARE invariant under an added batch axis:

  * gram-shaped einsums with explicit fold index: ``ni,kn,nj->kij`` and
    ``kp,np->kn`` — XLA loops the batch over the same per-matrix
    contraction (the thinner ``kn,np->kp`` is NOT safe once XLA fuses an
    elementwise producer into it, so gradients are read off augmented
    Grams instead);
  * elementwise ops, plain sums, ``fold_in``/``permutation`` PRNG;
  * Gauss-Jordan elimination written as broadcast updates (fori_loop of
    rank-1 outer products) — no LAPACK, no pivot-order ambiguity.

Every function here is built ONLY from that vocabulary.  The mat-vec
RHS of the normal equations is folded into an *augmented* Gram (append
the target as an extra column of X), so the one bad shape class —
``ni,n->i`` — never appears.  Gauss-Jordan without pivoting is safe
because every system we solve is SPD plus an explicit ridge.

These kernels double as the weighted-fit path for bootstrap replicates:
``Wk`` carries fold-complement masks multiplied by per-row bootstrap
weights, the same mechanism ``crossfit.fold_weights`` uses for C1.

The Gram-shaped reductions themselves live in the streaming moments
engine (``repro.core.moments``): this module no longer re-implements
the weighted normal equations — it supplies the deterministic solves
and the fold-batched *protocols* on top of the engine's augmented-Gram
passes.  A ``row_block`` argument streams every pass in fixed-order
row blocks (bounded memory at industrial n); at the default
``row_block=0`` the einsum forms below are byte-for-byte the legacy
whole-array ones, which is what keeps serial == vmap bit-identity.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import moments


def det_solve(A: jax.Array, b: jax.Array) -> jax.Array:
    """Deterministic (p,p) @ x = (p,) solve via Gauss-Jordan without
    pivoting.  Elementwise broadcast updates only — bit-identical under
    any number of leading vmap axes.  Requires A SPD-ish (ridge added by
    every caller)."""
    M = jnp.concatenate([A, b[:, None]], axis=1)

    def elim(i, M):
        piv = M[i] / M[i, i]
        factors = M[:, i].at[i].set(0.0)
        M = M - factors[:, None] * piv[None, :]
        return M.at[i].set(piv)

    M = jax.lax.fori_loop(0, A.shape[0], elim, M)
    return M[:, -1]


def det_inv(A: jax.Array) -> jax.Array:
    """Gauss-Jordan inverse (same invariance properties as det_solve)."""
    p = A.shape[0]
    M = jnp.concatenate([A, jnp.eye(p, dtype=A.dtype)], axis=1)

    def elim(i, M):
        piv = M[i] / M[i, i]
        factors = M[:, i].at[i].set(0.0)
        M = M - factors[:, None] * piv[None, :]
        return M.at[i].set(piv)

    M = jax.lax.fori_loop(0, p, elim, M)
    return M[:, p:]


def _aug(X: jax.Array) -> jax.Array:
    return jnp.concatenate([X, jnp.ones((X.shape[0], 1), X.dtype)], axis=1)


# ---------------------------------------------------------------------------
# Fold-batched weighted nuisance fits.  Wk is (k, n): fold-complement
# mask times per-row replicate weights.  All einsums carry the fold
# index explicitly — vmap-of-gram ("ni,n,nj->ij" under vmap) is NOT
# batch-invariant, the explicit "ni,kn,nj->kij" form is.
# ---------------------------------------------------------------------------

def ridge_fit_folds_w(lam: jax.Array, X: jax.Array, y: jax.Array,
                      Wk: jax.Array, *, row_block: int = 0,
                      rules=None) -> jax.Array:
    """Weighted per-fold ridge, one augmented fold-weighted Gram from
    the moments engine.  Returns beta (k, p+1) (intercept last,
    matching nuisance.make_ridge's column order)."""
    f32 = jnp.float32
    p = X.shape[1] + 1
    Gaug, n_eff = moments.fold_weighted_gram(X, Wk, intercept=True,
                                             append=y,
                                             row_block=row_block,
                                             rules=rules)
    n_eff = jnp.maximum(n_eff, 1.0)                             # (k,)
    A = Gaug[:, :p, :p] / n_eff[:, None, None] \
        + lam * jnp.eye(p, dtype=f32)[None]
    b = Gaug[:, :p, p] / n_eff[:, None]
    return jax.vmap(det_solve)(A, b)


def logistic_fit_folds_w(lam: jax.Array, iters: int, X: jax.Array,
                         t: jax.Array, Wk: jax.Array, *,
                         row_block: int = 0, rules=None) -> jax.Array:
    """Weighted per-fold Newton/IRLS logistic (same math as
    nuisance.make_logistic, fold axis explicit).  Returns beta (k, p+1).

    The gradient mat-vec Σ_n r_kn·Xa_n is read off an augmented Gram
    (ones column appended): the 2-operand "kn,np->kp" einsum changes
    its reduction order when XLA fuses the elementwise residual into
    it under vmap; the engine's 3-operand Gram form does not."""
    f32 = jnp.float32
    Xa = _aug(X.astype(f32))
    k, p = Wk.shape[0], Xa.shape[1]
    Wk = Wk.astype(f32)
    tt = t.astype(f32)
    n_eff = jnp.maximum(Wk.sum(axis=1), 1.0)                    # (k,)
    lam_eye = lam * jnp.eye(p, dtype=f32)
    ones = jnp.ones((Xa.shape[0],), f32)

    def newton(_, beta):                                        # beta (k, p)
        z = jnp.einsum("kp,np->kn", beta, Xa)
        mu = jax.nn.sigmoid(z)
        s = jnp.clip(mu * (1.0 - mu), 1e-6, None) * Wk
        Gr, _ = moments.fold_weighted_gram(
            Xa, Wk * (mu - tt[None, :]), append=ones,
            row_block=row_block, rules=rules)
        g = Gr[:, :p, p] / n_eff[:, None] + lam * beta
        H, _ = moments.fold_weighted_gram(X, s, intercept=True,
                                          row_block=row_block,
                                          rules=rules)
        H = H / n_eff[:, None, None] + lam_eye[None]
        return beta - jax.vmap(det_solve)(H, g)

    beta = jax.lax.fori_loop(0, iters, newton, jnp.zeros((k, p), f32))
    return beta


def predict_folds_linear(beta: jax.Array, X: jax.Array) -> jax.Array:
    """(k, p+1) coefficients -> (k, n) linear predictions."""
    return jnp.einsum("kp,np->kn", beta, _aug(X.astype(jnp.float32)))


def predict_folds_logistic(beta: jax.Array, X: jax.Array) -> jax.Array:
    return jax.nn.sigmoid(predict_folds_linear(beta, X))


# ---------------------------------------------------------------------------
# Weighted orthogonal final stage (weighted analogue of
# final_stage.fit_final_stage, replicate-invariant form).
# ---------------------------------------------------------------------------

def weighted_theta(ry: jax.Array, rt: jax.Array, phi: jax.Array,
                   w: jax.Array, *, ridge: float = 1e-8,
                   with_se: bool = True, row_block: int = 0,
                   rules=None
                   ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Solve the weighted orthogonal moment
    ``theta = argmin Σ w_i (ry_i - <theta, phi_i> rt_i)²`` and (optionally)
    its weighted HC0 sandwich stderr.  ry, rt, w: (n,); phi: (n, p_phi).

    Both the augmented Gram and the meat stream through the moments
    engine: with ``row_block > 0`` neither the (n, p_phi) moment matrix
    Z nor the residual vector materializes."""
    f32 = jnp.float32
    p = phi.shape[1]
    Gaug, n_eff = moments.residual_weighted_gram(ry, rt, phi, w,
                                                 row_block=row_block,
                                                 rules=rules)
    n_eff = jnp.maximum(n_eff, 1.0)
    A = Gaug[:p, :p] + ridge * n_eff * jnp.eye(p, dtype=f32)
    theta = det_solve(A, Gaug[:p, p])
    if not with_se:
        return theta, None
    # weighted HC0: cov = A⁻¹ (Zᵀ diag(w² e²) Z) A⁻¹ — elementwise resid
    # (no mat-vec: (Z * theta).sum over the tiny p_phi axis is invariant)
    meat = moments.residual_meat(ry, rt, jnp.zeros_like(ry),
                                 jnp.zeros_like(rt), phi, theta, w=w,
                                 row_block=row_block, rules=rules)
    Ainv = det_inv(A)
    cov = jnp.einsum("ia,ab,bj->ij", Ainv, meat, Ainv)
    se = jnp.sqrt(jnp.clip(jnp.diagonal(cov), 0.0, None))
    return theta, se


def weighted_iv_theta(ry: jax.Array, rt: jax.Array, rz: jax.Array,
                      phi: jax.Array, w: jax.Array, *,
                      ridge: float = 1e-8, with_se: bool = True,
                      row_block: int = 0, strategy: Optional[str] = None,
                      rules=None
                      ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Solve the weighted instrumented orthogonal moment
    ``Σ w_i rz_i φ_i (ry_i - <theta, φ_i> rt_i) = 0`` (the residual-on-
    residual 2SLS condition) plus its weighted HC0 sandwich stderr.
    ry, rt, rz, w: (n,); phi: (n, p_phi).

    All sufficient statistics come off ONE instrumented augmented Gram
    (``moments.iv_gram``) and one meat pass — replicate-invariant forms
    only (serial ≡ vmap bitwise, certified on the row-blocked canonical
    path by tests/test_conformance.py), and w=1 reproduces the point
    fit exactly."""
    f32 = jnp.float32
    p = phi.shape[1]
    Gaug, n_eff = moments.iv_gram(ry, rt, rz, phi, w,
                                  row_block=row_block,
                                  strategy=strategy, rules=rules)
    J, b, _, _ = moments.iv_slices(Gaug, p)
    n_eff = jnp.maximum(n_eff, 1.0)
    # J = Σ w·rz·rt·φφᵀ is symmetric (a signed-weight Gram) but not
    # PSD; with a relevant instrument its pivots are bounded away from
    # zero, which is all Gauss-Jordan needs (the weak-instrument F
    # check in core.refutation screens the degenerate case).
    A = J + ridge * n_eff * jnp.eye(p, dtype=f32)
    theta = det_solve(A, b)
    if not with_se:
        return theta, None
    meat = moments.iv_meat(ry, rt, rz, phi, theta, w=w,
                           row_block=row_block, strategy=strategy,
                           rules=rules)
    Ainv = det_inv(A)
    cov = jnp.einsum("ia,ab,bj->ij", Ainv, meat, Ainv)
    se = jnp.sqrt(jnp.clip(jnp.diagonal(cov), 0.0, None))
    return theta, se
