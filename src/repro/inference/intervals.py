"""Confidence intervals from replicate draws + the InferenceResult
container attached to estimator results.

Three interval families over the (B, p_phi) replicate matrix:

  percentile   plain empirical quantiles of the draws (EconML's
               ``BootstrapInference`` default);
  normal       point ± z_{1-α/2} · sd(draws);
  studentized  bootstrap-t: quantiles of (θ*_b - θ̂)/se*_b rescaled by
               the point estimate's influence-function stderr — second-
               order accurate when per-replicate stderrs are available.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def z_crit(alpha: float) -> float:
    """Two-sided normal critical value z_{1-α/2} (the single home for
    the magic 1.96 — analytic and replicate CIs share it)."""
    if alpha == 0.05:
        return 1.959963984540054
    return float(jax.scipy.stats.norm.ppf(1.0 - alpha / 2.0))


def percentile_interval(replicates: jax.Array, alpha: float = 0.05
                        ) -> Tuple[jax.Array, jax.Array]:
    """(B, ...) draws -> (lo, hi) empirical (α/2, 1-α/2) quantiles."""
    lo = jnp.quantile(replicates, alpha / 2.0, axis=0)
    hi = jnp.quantile(replicates, 1.0 - alpha / 2.0, axis=0)
    return lo, hi


def normal_interval(point: jax.Array, replicates: jax.Array,
                    alpha: float = 0.05) -> Tuple[jax.Array, jax.Array]:
    se = jnp.std(replicates, axis=0, ddof=1)
    z = z_crit(alpha)
    return point - z * se, point + z * se


def studentized_interval(point: jax.Array, point_se: jax.Array,
                         replicates: jax.Array, replicate_se: jax.Array,
                         alpha: float = 0.05
                         ) -> Tuple[jax.Array, jax.Array]:
    """Bootstrap-t: t*_b = (θ*_b - θ̂)/se*_b; CI is
    [θ̂ - q_{1-α/2}(t*)·se(θ̂), θ̂ - q_{α/2}(t*)·se(θ̂)]."""
    tstar = (replicates - point[None]) / jnp.maximum(replicate_se, 1e-12)
    q_lo = jnp.quantile(tstar, alpha / 2.0, axis=0)
    q_hi = jnp.quantile(tstar, 1.0 - alpha / 2.0, axis=0)
    return point - q_hi * point_se, point - q_lo * point_se


@dataclasses.dataclass(frozen=True)
class InferenceResult:
    """Uncertainty quantification for a (p_phi,) coefficient vector.

    ``replicates`` holds the B re-estimated thetas (jackknife: the k
    delete-fold thetas); ``se`` is the replicate-based stderr.  All CIs
    for derived quantities (ATE = theta[0] with the constant basis, CATE
    = phi(x)·theta) come from pushing each draw through the functional.
    """

    method: str                              # pairs|multiplier|jackknife
    executor: str                            # serial|vmap|shard_map
    point: jax.Array                         # (p_phi,)
    replicates: jax.Array                    # (B, p_phi)
    se: jax.Array                            # (p_phi,) replicate stderr
    alpha: float = 0.05
    point_se: Optional[jax.Array] = None     # (p_phi,) IF/sandwich stderr
    replicate_se: Optional[jax.Array] = None  # (B, p_phi) for bootstrap-t
    # estimators whose ATE is NOT theta[0] (DR: ATE = weighted mean of
    # the pseudo-outcome) supply the ATE functional's own draws so
    # ate_interval() centers on the quantity the result reports
    ate_replicates: Optional[jax.Array] = None  # (B,)
    ate_point: Optional[float] = None

    @property
    def n_replicates(self) -> int:
        return int(self.replicates.shape[0])

    def interval(self, alpha: Optional[float] = None,
                 kind: str = "percentile") -> Tuple[jax.Array, jax.Array]:
        a = self.alpha if alpha is None else alpha
        if self.method == "jackknife" or kind == "normal":
            # jackknife draws are k pseudo-values, far too few for
            # quantiles — always use the normal interval with its se
            z = z_crit(a)
            return self.point - z * self.se, self.point + z * self.se
        if kind == "percentile":
            return percentile_interval(self.replicates, a)
        if kind == "studentized":
            if self.replicate_se is None or self.point_se is None:
                raise ValueError("studentized CI needs per-replicate "
                                 "stderrs (with_se=True)")
            return studentized_interval(self.point, self.point_se,
                                        self.replicates, self.replicate_se,
                                        a)
        raise ValueError(f"unknown interval kind {kind!r}")

    def ate_interval(self, alpha: Optional[float] = None,
                     kind: str = "percentile") -> Tuple[float, float]:
        """CI for the ATE: theta[0] under the constant CATE basis, or
        the dedicated ATE-functional draws when the estimator supplied
        them (DR's pseudo-outcome mean)."""
        a = self.alpha if alpha is None else alpha
        if self.ate_replicates is not None:
            draws = self.ate_replicates
            if kind == "normal" or self.method == "jackknife":
                center = (float(draws.mean()) if self.ate_point is None
                          else self.ate_point)
                z = z_crit(a)
                se = float(jnp.std(draws, ddof=1))
                return center - z * se, center + z * se
            lo, hi = percentile_interval(draws, a)
            return float(lo), float(hi)
        lo, hi = self.interval(alpha, kind)
        return float(lo[0]), float(hi[0])

    # the IV family's estimand name for the same functional: theta[0]
    # under the constant basis, or the dedicated draws (DRIV's weighted
    # mean pseudo-outcome) when the estimator supplied them
    late_interval = ate_interval

    def cate_interval(self, phi: jax.Array, alpha: Optional[float] = None
                      ) -> Tuple[jax.Array, jax.Array]:
        """Pointwise CI bands for phi @ theta.  phi: (n, p_phi) ->
        ((n,), (n,)) lo/hi bands."""
        a = self.alpha if alpha is None else alpha
        draws = jnp.einsum("np,bp->bn", phi.astype(jnp.float32),
                           self.replicates)
        if self.method == "jackknife":
            z = z_crit(a)
            center = phi.astype(jnp.float32) @ self.point
            k = draws.shape[0]
            dev = jnp.sqrt(jnp.clip((k - 1.0) / k * jnp.square(
                draws - draws.mean(0, keepdims=True)).sum(0), 0.0, None))
            return center - z * dev, center + z * dev
        return percentile_interval(draws, a)
