"""Delete-fold jackknife — uncertainty almost for free.

Cross-fitting already partitions the rows into k folds and computes
out-of-fold nuisance predictions for every row.  The delete-group
jackknife re-solves only the (tiny) final stage k times, dropping one
fold of rows each time — no nuisance refits, so the marginal cost is
k extra (p_phi, p_phi) solves on top of a finished DML fit.  This is the
cheap end of the inference spectrum (bootstrap being the expensive end),
and the k delete-fold thetas go through the same Executor as bootstrap
replicates.

Variance: the delete-group jackknife estimator with k groups,

    se² = (k-1)/k · Σ_j (θ_(-j) - θ̄)²,

is a consistent estimate of the same asymptotic variance the influence-
function (HC0 sandwich) stderr targets — tests assert agreement.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.inference.executor import make_executor
from repro.inference.intervals import InferenceResult
from repro.inference.numerics import weighted_theta


def delete_fold_jackknife(y: jax.Array, t: jax.Array, oof_y: jax.Array,
                          oof_t: jax.Array, folds: jax.Array,
                          phi: jax.Array, n_folds: int, *,
                          alpha: float = 0.05, executor="vmap",
                          point=None, point_se=None,
                          mesh=None, rules=None) -> InferenceResult:
    """Jackknife over the existing fold partition.  y, t: (n,);
    oof_y/oof_t: (n,) out-of-fold nuisance predictions from the fit;
    folds: (n,) fold ids."""
    exe = make_executor(executor, mesh=mesh, rules=rules)
    ry = y.astype(jnp.float32) - oof_y
    rt = t.astype(jnp.float32) - oof_t

    def drop_fold(j, ry_, rt_, phi_, folds_):
        w = (folds_ != j).astype(jnp.float32)
        theta, _ = weighted_theta(ry_, rt_, phi_, w, with_se=False)
        return theta

    thetas = exe.map(drop_fold, jnp.arange(n_folds, dtype=jnp.int32),
                     ry, rt, phi, folds)
    theta_bar = thetas.mean(axis=0)
    center = theta_bar if point is None else point
    k = float(n_folds)
    se = jnp.sqrt(jnp.clip(
        (k - 1.0) / k * jnp.square(thetas - theta_bar[None, :]).sum(axis=0),
        0.0, None))
    return InferenceResult(method="jackknife", executor=exe.name,
                           point=center, replicates=thetas, se=se,
                           alpha=alpha, point_se=point_se)
