"""Delete-fold jackknife — uncertainty almost for free.

Cross-fitting already partitions the rows into k folds and computes
out-of-fold nuisance predictions for every row.  The delete-group
jackknife is a *pure reweighted-moments pass*: ONE fold-segmented
augmented residual Gram over the data (repro.core.moments, optionally
streamed in row blocks), after which each delete-fold estimate is the
LOO identity

    G_(-j) = G_total - G_fold_j

plus a (p_phi, p_phi) deterministic solve — no nuisance refits, no
dataset re-indexing, k tiny solves on top of a finished DML fit.  This
is the cheap end of the inference spectrum (bootstrap being the
expensive end), and the k delete-fold solves go through the same
Executor as bootstrap replicates (elementwise subtraction + the
Gauss-Jordan solve are replicate-invariant, so serial == vmap holds
bitwise here too).

Variance: the delete-group jackknife estimator with k groups,

    se² = (k-1)/k · Σ_j (θ_(-j) - θ̄)²,

is a consistent estimate of the same asymptotic variance the influence-
function (HC0 sandwich) stderr targets — tests assert agreement.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import moments
from repro.inference.intervals import InferenceResult
from repro.inference.numerics import det_solve


def delete_fold_jackknife(y: jax.Array, t: jax.Array, oof_y: jax.Array,
                          oof_t: jax.Array, folds: jax.Array,
                          phi: jax.Array, n_folds: int, *,
                          alpha: float = 0.05, executor="vmap",
                          point=None, point_se=None,
                          mesh=None, rules=None, ridge: float = 1e-8,
                          row_block: int = 0, memory_budget: int = 0,
                          chunk: int = 0,
                          max_retries: int = 2) -> InferenceResult:
    """Jackknife over the existing fold partition.  y, t: (n,);
    oof_y/oof_t: (n,) out-of-fold nuisance predictions from the fit;
    folds: (n,) fold ids.  The k delete-fold solves go through the task
    runtime like bootstrap replicates (chunking is moot at k solves,
    but the fault-tolerance ladder still applies)."""
    from repro.runtime import as_runtime
    sched = as_runtime(executor, mesh=mesh, rules=rules,
                       memory_budget=memory_budget, chunk=chunk,
                       max_retries=max_retries)
    f32 = jnp.float32
    n, p = phi.shape
    ry = y.astype(f32) - oof_y
    rt = t.astype(f32) - oof_t

    # one segmented pass: Gh[j] = Σ_{i in fold j} m_i m_iᵀ, m = [Z | ry]
    def block(ryb, rtb, phib, fb):
        Z = rtb[:, None] * phib.astype(f32)
        M = jnp.concatenate([Z, ryb[:, None]], axis=1)
        oh = jax.nn.one_hot(fb, n_folds, dtype=f32)
        return jnp.einsum("nk,ni,nj->kij", oh, M, M), oh.sum(0)

    Gh, counts = moments.blocked_reduce(
        block, (ry, rt, phi, folds), row_block=row_block, rules=rules,
        pad_values=(0, 0, 0, -1))
    G_tot = Gh.sum(0)
    n_eff = jnp.maximum(n - counts, 1.0)                     # (k,)

    def drop_fold(seg, G_tot_):
        Gd = G_tot_ - seg["G"]
        A = Gd[:p, :p] + ridge * seg["n_eff"] * jnp.eye(p, dtype=f32)
        return det_solve(A, Gd[:p, p])

    thetas = sched.map(drop_fold, {"G": Gh, "n_eff": n_eff}, G_tot,
                       label="jackknife")
    return _jackknife_result(thetas, n_folds, point, point_se, alpha,
                             sched.name)


def _jackknife_result(thetas, n_folds: int, point, point_se,
                      alpha: float, executor_name: str) -> InferenceResult:
    theta_bar = thetas.mean(axis=0)
    center = theta_bar if point is None else point
    k = float(n_folds)
    se = jnp.sqrt(jnp.clip(
        (k - 1.0) / k * jnp.square(thetas - theta_bar[None, :]).sum(axis=0),
        0.0, None))
    return InferenceResult(method="jackknife", executor=executor_name,
                           point=center, replicates=thetas, se=se,
                           alpha=alpha, point_se=point_se)


def delete_fold_jackknife_iv(y: jax.Array, t: jax.Array, z: jax.Array,
                             oof_y: jax.Array, oof_t: jax.Array,
                             oof_z: jax.Array, folds: jax.Array,
                             phi: jax.Array, n_folds: int, *,
                             alpha: float = 0.05, executor="vmap",
                             point=None, point_se=None, mesh=None,
                             rules=None, ridge: float = 1e-8,
                             row_block: int = 0, memory_budget: int = 0,
                             chunk: int = 0,
                             max_retries: int = 2) -> InferenceResult:
    """Delete-fold jackknife for the instrumented moment: ONE
    fold-segmented instrumented Gram (``moments.fold_iv_gram``,
    optionally row-blocked), then each delete-fold 2SLS estimate is the
    LOO identity ``G_(-j) = G_total - G_fold_j`` plus one (p, p)
    deterministic solve — no nuisance refits, exactly the DML
    jackknife's cost structure on the IV moment."""
    from repro.runtime import as_runtime
    sched = as_runtime(executor, mesh=mesh, rules=rules,
                       memory_budget=memory_budget, chunk=chunk,
                       max_retries=max_retries)
    f32 = jnp.float32
    n, p = phi.shape
    ry = y.astype(f32) - oof_y
    rt = t.astype(f32) - oof_t
    rz = z.astype(f32) - oof_z
    Gh, counts = moments.fold_iv_gram(ry, rt, rz, phi, folds, n_folds,
                                      row_block=row_block, rules=rules)
    G_tot = Gh.sum(0)
    n_eff = jnp.maximum(n - counts, 1.0)

    def drop_fold(seg, G_tot_):
        Gd = G_tot_ - seg["G"]
        J, b, _, _ = moments.iv_slices(Gd, p)
        A = J + ridge * seg["n_eff"] * jnp.eye(p, dtype=f32)
        return det_solve(A, b)

    thetas = sched.map(drop_fold, {"G": Gh, "n_eff": n_eff}, G_tot,
                       label="jackknife_iv")
    return _jackknife_result(thetas, n_folds, point, point_se, alpha,
                             sched.name)
