"""repro.inference — distributed bootstrap/jackknife inference.

The third paper-parallelized step class (after §5.1 cross-fitting and
§5.2 tuning): EconML's ``BootstrapInference`` runs B full
re-estimations as Ray tasks; here the B replicates are one batched
SPMD program dispatched by a pluggable ``Executor``
(``serial | vmap | shard_map``).  ``numerics`` holds the
replicate-invariant weighted fit kernels whose serial ≡ vmap bitwise
contract underwrites every batched CI; pairs and
multiplier/Bayesian bootstrap, the delete-fold jackknife (one
segmented pass + k LOO-identity solves), and
percentile/normal/studentized intervals build on them.
"""
#   executor.py   the Executor protocol + backends (the Ray-pool analogue)
#   numerics.py   replicate-invariant weighted fits (serial == vmap bitwise)
#   bootstrap.py  pairs + multiplier/Bayesian bootstrap over the executor
#   jackknife.py  delete-fold jackknife from the existing fold states
#   intervals.py  percentile / normal / studentized CIs, InferenceResult
from repro.inference.executor import (Executor, SerialExecutor,  # noqa: F401
    VmapExecutor, ShardMapExecutor, make_executor)
from repro.inference.intervals import (InferenceResult,  # noqa: F401
    percentile_interval, normal_interval, studentized_interval, z_crit)
from repro.inference.bootstrap import (bootstrap_weights,  # noqa: F401
    dml_theta_once, dml_residuals_once, dml_bootstrap, dr_bootstrap,
    dr_theta_once, iv_theta_once, iv_residuals_once, iv_bootstrap,
    driv_theta_once, driv_bootstrap)
from repro.inference.jackknife import (delete_fold_jackknife,  # noqa: F401
    delete_fold_jackknife_iv)
