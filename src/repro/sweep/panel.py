"""EffectPanel: the result container of one sweep — E × C estimates
with CIs, diagnostics, and per-cell failure status.

Per-cell validity is a first-class output, not an exception: a segment
with no rows (or a non-finite solve) flags its cells ``ok = False``
while every other cell keeps its bit-exact estimate, and a column whose
dispatch fails even after the runtime's backend-downgrade ladder is
recorded as a failed column without poisoning its neighbors.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import CausalConfig


@dataclasses.dataclass(frozen=True)
class ColumnResult:
    """One (estimator, config) column of the panel: per-segment arrays,
    or an error string when the whole column's dispatch failed."""

    estimator: str
    cfg: CausalConfig
    thetas: Optional[jax.Array] = None  # (E, p_phi)
    ates: Optional[jax.Array] = None  # (E,)
    ses: Optional[jax.Array] = None  # (E, p_phi)
    ci_lo: Optional[jax.Array] = None  # (E,) replicate ATE CI
    ci_hi: Optional[jax.Array] = None  # (E,)
    replicates: Optional[jax.Array] = None  # (E, B, p_phi)
    key_index: int = 0  # column index of the key lineage
    shared_nuisance: bool = False  # residuals reused from key_index
    events: Tuple[str, ...] = ()  # runtime chunk/downgrade events
    error: Optional[str] = None
    # store-refreshed columns only: True = every ingest of this column
    # ended on a row_block boundary (bitwise regime), False = at least
    # one misaligned ingest (tolerance regime), None = not applicable
    # (sweep columns, failed columns)
    aligned: Optional[bool] = None

    @property
    def failed(self) -> bool:
        """Whether this column errored (its cells carry no estimates)."""
        return self.error is not None

    def ok(self, counts: jax.Array) -> jax.Array:
        """(E,) per-cell validity: the column ran, the segment has rows,
        and the estimate is finite."""
        e = counts.shape[0]
        if self.failed or self.thetas is None:
            return jnp.zeros((e,), bool)
        finite = jnp.isfinite(self.thetas).all(axis=-1)
        return (counts > 0) & finite


@dataclasses.dataclass(frozen=True)
class EffectPanel:
    """E segments × C estimator-config columns of effect estimates."""

    columns: Tuple[ColumnResult, ...]
    counts: jax.Array  # (E,) rows per segment
    n_segments: int
    segment_key: str = ""

    @property
    def n_columns(self) -> int:
        """Number of estimator-config columns C."""
        return len(self.columns)

    def ok(self) -> jax.Array:
        """(E, C) per-cell validity mask."""
        return jnp.stack([c.ok(self.counts) for c in self.columns], axis=1)

    def ate_table(self) -> jax.Array:
        """(E, C) ATE/LATE point estimates; failed columns are NaN."""
        e = self.n_segments
        cols = [
            c.ates if c.ates is not None else jnp.full((e,), jnp.nan, jnp.float32)
            for c in self.columns
        ]
        return jnp.stack(cols, axis=1)

    def failures(self) -> Tuple[Tuple[int, str], ...]:
        """(column index, error) for every failed column."""
        return tuple((i, c.error) for i, c in enumerate(self.columns) if c.failed)

    def summary(self) -> str:
        """Human-readable panel overview (shape, validity, failures)."""
        ok = self.ok()
        head = f"EffectPanel: {self.n_segments} segments x {self.n_columns} columns"
        if self.segment_key:
            head += f" (segment_key={self.segment_key!r})"
        lines = [
            head,
            f"rows/segment: min {int(self.counts.min())}, "
            f"max {int(self.counts.max())}; "
            f"valid cells {int(ok.sum())}/{ok.size}",
            "-" * 60,
        ]
        table = self.ate_table()
        for j, col in enumerate(self.columns):
            if col.failed:
                lines.append(f"[{j}] {col.estimator}: FAILED ({col.error})")
                continue
            ates = table[:, j]
            good = ok[:, j]
            denom = jnp.maximum(good.sum(), 1)
            mean = float(jnp.where(good, ates, 0.0).sum() / denom)
            tag = " (shared nuisances)" if col.shared_nuisance else ""
            if col.aligned is False:
                tag += " (misaligned ingest: tolerance regime)"
            lines.append(
                f"[{j}] {col.estimator} p_phi={col.cfg.cate_features}: "
                f"mean ATE {mean:+.4f} over {int(good.sum())} segments{tag}"
            )
        return "\n".join(lines)
