# Segment-parallel sweeps: the paper's many-cohorts workload (estimate
# E effects × C estimator-configs as batched programs, not a loop).
#   spec.py       SweepSpec — the (segments × estimator-configs) grid
#   engine.py     sweep() / serial_loop(): masked weighted cells
#                 through the task runtime (bitwise ≡ the loop of
#                 single fits at canonical shapes), shared-nuisance
#                 reuse, (cell × replicate) CIs via map_product
#   segmented.py  the one-pass segment×fold-Gram fast path (DML family)
#   panel.py      EffectPanel — thetas, CIs, diagnostics, per-cell
#                 failure status
from repro.sweep.spec import SweepSpec, segment_counts  # noqa: F401
from repro.sweep.panel import ColumnResult, EffectPanel  # noqa: F401
from repro.sweep.engine import column_keys, serial_loop, sweep  # noqa: F401
from repro.sweep.segmented import (  # noqa: F401
    segmented_dml_sweep,
    segmented_supported,
)
