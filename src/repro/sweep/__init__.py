"""repro.sweep — segment-parallel sweeps: the many-cohorts workload.

The paper's case study is not one estimation but many (per user
segment / treatment cohort / config variant) fanned out on Ray; here
the (E segments × C estimator-configs) grid of a ``SweepSpec`` runs
as batched programs.  Cells mode treats every cell as a masked
weighted single fit (bitwise ≡ a Python loop of single fits at
canonical row-blocked shapes), shared-nuisance reuse collapses
columns that differ only in final stage onto one residual pass, and
segmented mode solves all E·K fold-complement normal equations from
ONE combined segment×fold Gram pass (DML family).  Results land in an
``EffectPanel`` with per-cell validity instead of exceptions; the
persistent, incrementally refreshed variant of this panel lives in
``repro.store``.
"""
#   spec.py       SweepSpec — the (segments × estimator-configs) grid
#   engine.py     sweep() / serial_loop(): masked weighted cells
#                 through the task runtime (bitwise ≡ the loop of
#                 single fits at canonical shapes), shared-nuisance
#                 reuse, (cell × replicate) CIs via map_product
#   segmented.py  the one-pass segment×fold-Gram fast path (DML family)
#   panel.py      EffectPanel — thetas, CIs, diagnostics, per-cell
#                 failure status
from repro.sweep.spec import SweepSpec, segment_counts  # noqa: F401
from repro.sweep.panel import ColumnResult, EffectPanel  # noqa: F401
from repro.sweep.engine import column_keys, serial_loop, sweep  # noqa: F401
from repro.sweep.segmented import (  # noqa: F401
    segmented_dml_sweep,
    segmented_supported,
)
