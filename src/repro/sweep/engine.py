"""The sweep engine: estimate E segments × C estimator-configs as
batched programs instead of a Python loop.

Execution model
---------------
Each cell of the grid is a *masked weighted single fit*: the segment
mask enters the estimator exactly where bootstrap resampling weights do
(``w`` of the registry's ``weighted_fit`` closures), so per-segment
sufficient statistics stream through ``core.moments`` — no per-segment
data copies are ever gathered.  Cells are built from the same
replicate-invariant closure family the bootstrap replicates run, so the
certified serial ≡ vmap bit-identity contract transfers verbatim: at
the canonical row-blocked shapes the panel is BITWISE identical to a
Python loop of the same single fits (``serial_loop``, asserted by
tests/test_sweep.py).

Scheduling
----------
The (segment × config) cell axis dispatches through the task runtime
(``runtime.map``), inheriting memory-aware chunking
(``CausalConfig.sweep_chunk`` / ``runtime_chunk`` / the HLO-probed
budget) and the per-chunk backend-downgrade ladder.  Replicate CIs add
the bootstrap axis through ``runtime.map_product`` — (cell × replicate)
flattened onto ONE batched program, subdivided by the same scheduler.

Cost sharing
------------
Two layers of reuse on top of the cell grid:

  * columns that differ only in final stage (same
    ``registry.nuisance_signature``) share one residual pass per
    segment (``spec.residual_fit`` / ``spec.final_fit``);
  * ``mode="segmented"`` (DML family) collapses the per-cell fold Grams
    into ONE segment×fold-segmented pass over the data via the
    leave-one-out identity — the many-effects-cheaply execution, ~10x
    over the loop at E=64 (see repro.sweep.segmented).

Fault isolation
---------------
A failing column (bad config, nuisance build error, dispatch failure
past the downgrade ladder) is recorded on its ``ColumnResult.error``;
every other column keeps its estimates.  Zero-row segments yield
flagged (``ok = False``) finite cells, never a crash.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import CausalConfig
from repro.core.estimator import resolve_scheme
from repro.core.final_stage import cate_basis
from repro.core.registry import EstimatorSpec, get_spec, nuisance_signature
from repro.obs.trace import maybe_span
from repro.sweep.panel import ColumnResult, EffectPanel
from repro.sweep.spec import SweepSpec, segment_counts

_BOOT_SCHEMES = ("bootstrap", "multiplier", "bayesian")


def column_keys(key: jax.Array, col_index: int, n_segments: int) -> jax.Array:
    """Per-cell fit keys: fold_in(fold_in(base, column), segment) — any
    single cell can be replayed alone, bit-identically (the lineage
    property bootstrap replicates already carry)."""
    ck = jax.random.fold_in(key, col_index)
    return jax.vmap(lambda s: jax.random.fold_in(ck, s))(
        jnp.arange(n_segments, dtype=jnp.uint32)
    )


def _segment_mask(sids: jax.Array, sid) -> jax.Array:
    return (sids == sid).astype(jnp.float32)


def _runtime(cfg: CausalConfig, executor, tracer=None, data_mesh=None):
    from repro.runtime import as_runtime

    return as_runtime(
        executor if executor is not None else cfg.inference_executor,
        memory_budget=cfg.runtime_memory_budget,
        chunk=cfg.sweep_chunk or cfg.runtime_chunk,
        max_retries=cfg.runtime_max_retries,
        data_mesh=data_mesh,
        tracer=tracer,
    )


def _make_masked_cell(cell):
    def _masked_cell(xs, d):
        w = _segment_mask(d["sids"], xs["sid"])
        return cell(xs["key"], w, d)

    return _masked_cell


def _make_masked_resid(resid_fn):
    def _masked_resid(xs, d):
        w = _segment_mask(d["sids"], xs["sid"])
        return resid_fn(xs["key"], w, d)

    return _masked_resid


def _make_masked_final(final_fn):
    def _masked_final(xs, d):
        w = _segment_mask(d["sids"], xs["sid"])
        return final_fn(xs["resid"], w, d)

    return _masked_final


def _make_replicate_cell(cell, scheme: str):
    from repro.inference.bootstrap import bootstrap_weights

    def _rep_cell(xo, kb, d):
        # per-(cell, replicate) randomness: the replicate key folds in
        # the segment id, then splits into (resample, fit) keys
        kcell = jax.random.fold_in(kb, xo["sid"].astype(jnp.uint32))
        kw, kfit = jax.random.split(kcell)
        w = _segment_mask(d["sids"], xo["sid"]) * bootstrap_weights(
            kw, d["sids"].shape[0], scheme
        )
        out = cell(kfit, w, d)
        return {"theta": out["theta"], "ate": out["ate"]}

    return _rep_cell


def _column_data(base_data: Dict[str, Any], cfg: CausalConfig) -> Dict[str, Any]:
    d = dict(base_data)
    d["phi"] = cate_basis(base_data["X"], cfg.cate_features)
    return d


def _column_ci(cell, cfg: CausalConfig, rt, xs, data, key, col_index: int):
    """(cell × replicate) bootstrap draws through map_product: the two
    parallel axes flatten onto one replicate axis, chunked and
    downgraded by the scheduler like any other replicate program."""
    from repro.inference.bootstrap import replicate_keys

    # non-resampling methods (jackknife) have no per-cell replicate
    # program; they substitute the pairs bootstrap, and the column's
    # events carry a "ci:<scheme>" tag so the substitution is visible
    method = cfg.inference if cfg.inference in _BOOT_SCHEMES else "bootstrap"
    scheme = resolve_scheme(method)
    ci_key = jax.random.fold_in(jax.random.fold_in(key, col_index), 0x0B00)
    bkeys = replicate_keys(ci_key, cfg.n_bootstrap)
    rep_cell = _make_replicate_cell(cell, scheme)
    draws = rt.map_product(rep_cell, xs, bkeys, data, label="sweep:ci")
    a = cfg.alpha
    return dict(
        ci_lo=jnp.quantile(draws["ate"], a / 2.0, axis=1),
        ci_hi=jnp.quantile(draws["ate"], 1.0 - a / 2.0, axis=1),
        replicates=draws["theta"],
        ci_scheme=scheme,
    )


def _events(rt, start_total: int = 0) -> Tuple[str, ...]:
    # EventLog.since is drop-safe: start_total is an events.total
    # checkpoint, valid even if the ring dropped older entries
    return tuple(f"{e.action}:{e.backend}" for e in rt.events.since(start_total))


def _want_ci(cfg: CausalConfig, with_ci: Optional[bool]) -> bool:
    if with_ci is not None:
        return bool(with_ci) and cfg.n_bootstrap > 0
    return cfg.inference not in ("none", "") and cfg.n_bootstrap > 0


# -- elastic per-column checkpoints (repro.checkpoint) ----------------------

_CKPT_SCHEMA = "sweep-column-v1"
_CKPT_ARRAYS = ("thetas", "ates", "ses", "ci_lo", "ci_hi", "replicates")


def _column_signature(name: str, cfg: CausalConfig, n_segments: int) -> str:
    """Provenance key a resumed column must match: same estimator, same
    frozen config (repr is stable for the dataclass), same grid height."""
    import hashlib

    return hashlib.sha1(
        f"{name}|{cfg!r}|{n_segments}".encode()
    ).hexdigest()[:16]


def _save_column(mgr, idx: int, col: ColumnResult, n_segments: int) -> None:
    """One checkpoint step per column (step = column index): the present
    result arrays + provenance meta.  Failed columns save too (the
    attempt is on record) but never restore — a resume recomputes them,
    which is the whole point: a lost shard costs ONE column."""
    state = {
        k: getattr(col, k)
        for k in _CKPT_ARRAYS
        if getattr(col, k) is not None
    }
    extra = {
        "schema": _CKPT_SCHEMA,
        "signature": _column_signature(col.estimator, col.cfg, n_segments),
        "estimator": col.estimator,
        "key_index": int(col.key_index),
        "shared_nuisance": bool(col.shared_nuisance),
        "events": list(col.events),
        "error": col.error,
        "aligned": col.aligned,
    }
    mgr.save(idx, state, extra=extra)


def _restore_column(
    mgr, idx: int, name: str, cfg: CausalConfig, n_segments: int
) -> Optional[ColumnResult]:
    """The saved ColumnResult for step ``idx``, or None when it is
    missing, provenance-mismatched (spec changed under the checkpoint
    dir), or errored (failed columns recompute on resume)."""
    if not mgr.has_step(idx):
        return None
    arrays, meta = mgr.load(step=idx)
    extra = meta.get("extra") or {}
    if extra.get("schema") != _CKPT_SCHEMA:
        return None
    if extra.get("signature") != _column_signature(name, cfg, n_segments):
        return None
    if extra.get("error"):
        return None
    kw = {k: jnp.asarray(arrays[k]) for k in _CKPT_ARRAYS if k in arrays}
    return ColumnResult(
        estimator=name,
        cfg=cfg,
        key_index=int(extra.get("key_index", idx)),
        shared_nuisance=bool(extra.get("shared_nuisance", False)),
        events=tuple(extra.get("events") or ()) + ("restored",),
        aligned=extra.get("aligned"),
        **kw,
    )


def _run_column(
    rspec: EstimatorSpec,
    cfg: CausalConfig,
    col_index: int,
    base_data,
    n_segments: int,
    key,
    executor,
    with_ci: Optional[bool],
    tracer=None,
    data_mesh=None,
) -> ColumnResult:
    """One column as E masked single-fit cells through the runtime."""
    cell = rspec.weighted_fit(cfg)
    data = _column_data(base_data, cfg)
    xs = {
        "key": column_keys(key, col_index, n_segments),
        "sid": jnp.arange(n_segments, dtype=jnp.int32),
    }
    rt = _runtime(cfg, executor, tracer, data_mesh)
    with maybe_span(
        rt.tracer, f"sweep.column[{col_index}]", cat="sweep",
        estimator=rspec.name, segments=n_segments,
    ):
        out = rt.map(_make_masked_cell(cell), xs, data, label=f"sweep:{rspec.name}")
        extra: Dict[str, Any] = {}
        if _want_ci(cfg, with_ci):
            extra = _column_ci(cell, cfg, rt, xs, data, key, col_index)
    ci_tag = ()
    if "ci_scheme" in extra:
        ci_tag = (f"ci:{extra['ci_scheme']}",)
    return ColumnResult(
        estimator=rspec.name,
        cfg=cfg,
        thetas=out["theta"],
        ates=out["ate"],
        ses=out.get("se"),
        ci_lo=extra.get("ci_lo"),
        ci_hi=extra.get("ci_hi"),
        replicates=extra.get("replicates"),
        key_index=col_index,
        events=_events(rt) + ci_tag,
    )


def _run_shared_group(
    rspec: EstimatorSpec,
    members: List[Tuple[int, CausalConfig]],
    base_data,
    n_segments: int,
    key,
    executor,
    with_ci: Optional[bool],
    tracer=None,
    data_mesh=None,
) -> List[Tuple[int, ColumnResult]]:
    """Columns differing only in final stage: ONE residual pass per
    segment (keyed on the first member's lineage), then a cheap
    final-stage map per column."""
    first_idx, cfg0 = members[0]
    resid_fn = rspec.residual_fit(cfg0)
    keys = column_keys(key, first_idx, n_segments)
    sid = jnp.arange(n_segments, dtype=jnp.int32)
    rt = _runtime(cfg0, executor, tracer, data_mesh)
    # the shared residual pass is group-fatal by design (every member
    # consumes it); everything after is isolated per member
    with maybe_span(
        rt.tracer, f"sweep.group:{rspec.name}", cat="sweep",
        members=len(members), segments=n_segments,
    ):
        resids = rt.map(
            _make_masked_resid(resid_fn),
            {"key": keys, "sid": sid},
            dict(base_data),
            label=f"sweep:{rspec.name}:resid",
        )
    results = []
    for col_index, cfg in members:
        ev_start = rt.events.total
        try:
            col = _shared_member_column(
                rspec, cfg, first_idx, col_index, base_data, resids,
                keys, sid, rt, key, with_ci, ev_start
            )
        except Exception as err:  # noqa: BLE001 — one member must not
            # discard its siblings' already-computed columns
            col = ColumnResult(
                estimator=rspec.name, cfg=cfg, key_index=first_idx,
                shared_nuisance=col_index != first_idx, error=str(err)
            )
        results.append((col_index, col))
    return results


def _shared_member_column(
    rspec: EstimatorSpec,
    cfg: CausalConfig,
    first_idx: int,
    col_index: int,
    base_data,
    resids,
    keys,
    sid,
    rt,
    key,
    with_ci: Optional[bool],
    ev_start: int,
) -> ColumnResult:
    data = _column_data(base_data, cfg)
    with maybe_span(
        rt.tracer, f"sweep.column[{col_index}]", cat="sweep",
        estimator=rspec.name, shared_nuisance=col_index != first_idx,
    ):
        out = rt.map(
            _make_masked_final(rspec.final_fit(cfg)),
            {"sid": sid, "resid": resids},
            data,
            label=f"sweep:{rspec.name}:final",
        )
        extra: Dict[str, Any] = {}
        if _want_ci(cfg, with_ci):
            # replicate refits reweight the nuisances, so CIs cannot
            # reuse the shared residuals — they run the full cell
            cell = rspec.weighted_fit(cfg)
            xs = {"key": keys, "sid": sid}
            extra = _column_ci(cell, cfg, rt, xs, data, key, first_idx)
    ci_tag = ()
    if "ci_scheme" in extra:
        ci_tag = (f"ci:{extra['ci_scheme']}",)
    return ColumnResult(
        estimator=rspec.name,
        cfg=cfg,
        thetas=out["theta"],
        ates=out["ate"],
        ses=out.get("se"),
        ci_lo=extra.get("ci_lo"),
        ci_hi=extra.get("ci_hi"),
        replicates=extra.get("replicates"),
        key_index=first_idx,
        shared_nuisance=col_index != first_idx,
        events=_events(rt, ev_start) + ci_tag,
    )


def _segmented_or_cells(
    rspec: EstimatorSpec,
    cfg: CausalConfig,
    col_index: int,
    base_data,
    n_segments: int,
    key,
    executor,
    with_ci: Optional[bool],
    tracer=None,
    data_mesh=None,
) -> ColumnResult:
    """mode="segmented" dispatch: the one-pass kernels where they apply,
    the plain cell path otherwise.  The segmented fast path stays
    single-host (its module-level jits would cache a mesh trace across
    unrelated sweeps); data_mesh applies to the cells fallback only."""
    from repro.sweep.segmented import segmented_column, segmented_supported

    if not segmented_supported(rspec, cfg):
        return _run_column(
            rspec, cfg, col_index, base_data, n_segments, key, executor,
            with_ci, tracer, data_mesh,
        )
    with maybe_span(
        tracer, f"sweep.column[{col_index}]", cat="sweep",
        estimator=rspec.name, segmented=True,
    ) as sp:
        out = segmented_column(
            cfg, base_data, n_segments, jax.random.fold_in(key, col_index)
        )
        if tracer is not None and sp is not None:
            tracer.sync(out)
    return ColumnResult(
        estimator=rspec.name,
        cfg=cfg,
        thetas=out["theta"],
        ates=out["ate"],
        ses=out.get("se"),
        key_index=col_index,
        events=("segmented",),
    )


def sweep(
    spec: SweepSpec,
    *,
    X: jax.Array,
    y: jax.Array,
    t: jax.Array,
    segment_ids: jax.Array,
    z: Optional[jax.Array] = None,
    key: Optional[jax.Array] = None,
    executor=None,
    mode: str = "cells",
    reuse: bool = True,
    with_ci: Optional[bool] = None,
    tracer=None,
    data_mesh=None,
    checkpoint=None,
    resume: bool = True,
    column_callback=None,
) -> EffectPanel:
    """Run the (segments × estimator-configs) grid as batched programs.

    mode="cells"      every cell is a masked weighted single fit —
                      bitwise identical to ``serial_loop`` at the
                      canonical row-blocked shapes (the default, and
                      the contract tests certify).
    mode="segmented"  DML-family columns collapse onto the one-pass
                      segment×fold Gram kernels (repro.sweep.segmented,
                      ~10x at E=64); unsupported columns fall back to
                      cells.
    reuse=True        columns sharing a nuisance signature share one
                      residual pass (cells mode).
    with_ci           None = per column from cfg.inference; True/False
                      forces replicate CIs on/off.  CIs are resampling
                      draws: a non-resampling cfg.inference (jackknife)
                      substitutes the pairs bootstrap, tagged
                      "ci:pairs" in the column's events.
    tracer            optional repro.obs.Tracer: every column (and
                      shared-nuisance group) opens a labelled span, and
                      the runtimes under it inherit the tracer — chunk
                      spans, metrics, and the cost audit nest inside.
                      None (the default) changes nothing.
    data_mesh         optional runtime.distributed.DataMesh: column
                      cells row-shard across ("hosts", "devices"), with
                      the shard_map → single-host ladder rung catching
                      lost shards — bitwise the single-host panel in
                      "ordered" mode (cells path; the segmented fast
                      path stays single-host).
    checkpoint        optional repro.checkpoint.CheckpointManager: each
                      column saves as checkpoint step = column index the
                      moment it settles (success OR error), so a killed
                      job — or a shard loss that exhausted the ladder —
                      costs at most the in-flight column on the next
                      run.  ``keep_latest`` is raised to cover the grid.
    resume            with ``checkpoint``: restore provenance-matching
                      completed columns (tagged "restored" in their
                      events) and recompute only missing/failed ones.
    column_callback   ``f(index, ColumnResult)`` called as each column
                      settles (including restored ones) — the event
                      stream hook of runtime.jobs.
    """
    if mode not in ("cells", "segmented"):
        raise ValueError(f"unknown sweep mode {mode!r} (cells | segmented)")
    key = key if key is not None else jax.random.PRNGKey(0)
    sids = segment_ids.astype(jnp.int32)
    n_seg = spec.n_segments
    base_data: Dict[str, Any] = {"X": X, "y": y, "t": t, "sids": sids}
    if z is not None:
        base_data["z"] = z
    counts = segment_counts(sids, n_seg)

    results: Dict[int, ColumnResult] = {}

    if checkpoint is not None:
        # retention must cover one step per column or early columns
        # would be pruned before the sweep finishes
        checkpoint.keep_latest = max(
            checkpoint.keep_latest, len(spec.columns) + 1
        )

    def record(idx: int, col: ColumnResult, *, save: bool = True) -> None:
        results[idx] = col
        if save and checkpoint is not None:
            _save_column(checkpoint, idx, col, n_seg)
        if column_callback is not None:
            column_callback(idx, col)

    restored: set = set()
    if checkpoint is not None and resume:
        for idx, (name, cfg) in enumerate(spec.columns):
            col = _restore_column(checkpoint, idx, name, cfg, n_seg)
            if col is not None:
                restored.add(idx)
                record(idx, col, save=False)

    # -- group columns: (estimator, nuisance signature) -----------------
    groups: Dict[Any, List[Tuple[int, CausalConfig]]] = {}
    order: List[Any] = []
    for idx, (name, cfg) in enumerate(spec.columns):
        if idx in restored:
            continue
        gk = (name, nuisance_signature(cfg))
        if gk not in groups:
            groups[gk] = []
            order.append(gk)
        groups[gk].append((idx, cfg))

    for gk in order:
        name = gk[0]
        members = groups[gk]
        try:
            rspec = get_spec(name)
            if rspec.weighted_fit is None:
                raise ValueError(f"estimator {name!r} has no weighted fit")
            if rspec.needs_instrument and z is None:
                raise ValueError(f"estimator {name!r} needs an instrument z")
        except Exception as err:  # noqa: BLE001 — isolated per column
            for idx, cfg in members:
                record(idx, ColumnResult(
                    estimator=name, cfg=cfg, key_index=idx, error=str(err)
                ))
            continue

        if mode == "segmented":
            for idx, cfg in members:
                try:
                    record(idx, _segmented_or_cells(
                        rspec, cfg, idx, base_data, n_seg, key, executor,
                        with_ci, tracer, data_mesh,
                    ))
                except Exception as err:  # noqa: BLE001
                    record(idx, ColumnResult(
                        estimator=name, cfg=cfg, key_index=idx, error=str(err)
                    ))
            continue

        shareable = (
            reuse
            and len(members) > 1
            and rspec.residual_fit is not None
            and rspec.final_fit is not None
        )
        try:
            if shareable:
                for idx, col in _run_shared_group(
                    rspec, members, base_data, n_seg, key, executor,
                    with_ci, tracer, data_mesh,
                ):
                    record(idx, col)
            else:
                for idx, cfg in members:
                    record(idx, _run_column(
                        rspec, cfg, idx, base_data, n_seg, key, executor,
                        with_ci, tracer, data_mesh,
                    ))
        except Exception as err:  # noqa: BLE001 — one column/group must
            # not poison the panel; the runtime ladder already retried
            for idx, cfg in members:
                if idx not in results:
                    record(idx, ColumnResult(
                        estimator=name, cfg=cfg, key_index=idx, error=str(err)
                    ))

    columns = tuple(results[i] for i in range(len(spec.columns)))
    return EffectPanel(
        columns=columns,
        counts=counts,
        n_segments=n_seg,
        segment_key=spec.segment_key,
    )


def serial_loop(
    estimator: str,
    cfg: CausalConfig,
    *,
    X: jax.Array,
    y: jax.Array,
    t: jax.Array,
    segment_ids: jax.Array,
    n_segments: int,
    z: Optional[jax.Array] = None,
    key: Optional[jax.Array] = None,
    col_index: int = 0,
) -> Dict[str, jax.Array]:
    """The reference baseline: a Python loop of masked single-estimator
    fits — one compiled program dispatched per cell, no cross-cell
    batching — with exactly the key lineage ``sweep()`` gives column
    ``col_index``.  The panel's cells mode is certified bitwise
    identical to this loop at the canonical row-blocked shapes; it is
    also the serial side of benchmarks/bench_sweep.py."""
    from repro.inference.executor import make_executor

    key = key if key is not None else jax.random.PRNGKey(0)
    rspec = get_spec(estimator)
    cell = rspec.weighted_fit(cfg)
    base_data: Dict[str, Any] = {
        "X": X,
        "y": y,
        "t": t,
        "sids": segment_ids.astype(jnp.int32),
    }
    if z is not None:
        base_data["z"] = z
    data = _column_data(base_data, cfg)
    xs = {
        "key": column_keys(key, col_index, n_segments),
        "sid": jnp.arange(n_segments, dtype=jnp.int32),
    }
    return make_executor("serial").map(_make_masked_cell(cell), xs, data)
