"""SweepSpec: the (segments × estimator-configs) grid of one sweep.

The paper's case study — and the industrial workloads it stands in for
(Netflix's "estimate many effects cheaply", Amazon's DML-at-scale
batches) — is not one estimation but E × C of them: every user segment
/ treatment cohort crossed with every estimator-config variant.  A
``SweepSpec`` names that grid; ``repro.sweep.engine.sweep`` executes it
as batched programs instead of a Python loop.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp

from repro.config import CausalConfig


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """One sweep's grid.

    n_segments   E: cells run per segment id in [0, E) (ids come in as
                 a per-row integer array at ``sweep()`` time — segments
                 with no rows produce flagged, not crashing, cells).
    columns      the estimator-config axis: (registry name, config)
                 pairs.  Columns may mix estimator families.
    segment_key  provenance only — the name of the cohort column in the
                 caller's frame (CausalConfig.segment_key); the engine
                 itself consumes the integer id array.
    """

    n_segments: int
    columns: Tuple[Tuple[str, CausalConfig], ...]
    segment_key: str = ""

    def __post_init__(self):
        if self.n_segments < 1:
            raise ValueError(f"n_segments must be >= 1, got {self.n_segments}")
        if not self.columns:
            raise ValueError("a sweep needs at least one (estimator, config) column")

    @classmethod
    def grid(
        cls,
        n_segments: int,
        estimators: Tuple[str, ...] = ("dml",),
        configs: Tuple[CausalConfig, ...] = (CausalConfig(),),
        segment_key: str = "",
    ) -> "SweepSpec":
        """The full outer product: every estimator × every config."""
        cols = tuple((e, c) for e in estimators for c in configs)
        key = segment_key
        if not key:
            key = next((c.segment_key for c in configs if c.segment_key), "")
        return cls(n_segments=n_segments, columns=cols, segment_key=key)

    @property
    def n_cells(self) -> int:
        """Total grid size E x C."""
        return self.n_segments * len(self.columns)


def segment_counts(segment_ids, n_segments: int):
    """(E,) rows per segment — the zero-row diagnostic every panel
    carries."""
    return jnp.bincount(segment_ids, length=n_segments)
