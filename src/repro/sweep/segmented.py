"""The segmented DML fast path: all E segments' cross-fit estimates
from ONE segment×fold-segmented pass over the data.

A masked sweep cell re-reads every row per cell — E cells touch E·n
rows.  But each row belongs to exactly one (segment, fold) pair, so one
``moments.fold_gram`` pass over the combined id ``segment·K + fold``
yields every per-(segment, fold) held-out Gram at once, and the
leave-one-out identity (the repo's ``parallel_loo`` trick, here
generalized over segments)

    G_complement[s, j] = (Σ_j' Gh[s, j']) - Gh[s, j]

turns them into all E·K fold-complement normal equations with NO
second data pass.  Ridge nuisances stay EXACT; the logistic treatment
nuisance uses the Böhning-Lindsay fixed majorizer (H0 = Gram/4 + λI
factored once per (s, j), then matvec-cheap MM steps — the same
substitution ``crossfit_parallel_loo`` makes), converging to the same
optimum as Newton.  The orthogonal final stage and its HC0 meat are
per-segment one-hot Grams over the residuals.

Everything streams through ``core.moments`` (``fold_gram`` honors
``cfg.row_block``), so no per-segment data copy and no (E, n) weight
tensor ever materializes.  ``cfg.row_block_strategy="pallas"`` swaps
the one-hot einsums (the fold Grams, the MM gradient terms, the
per-segment final stage) for the fused segment-Gram kernels of
``repro.kernels.seg_gram`` — the (n, E·k) masks never materialize at
all, which is the measured CPU/TPU win on the MM hot loop.  This is the "software that estimates many
effects cheaply" execution (Wong 2020): benchmarks/bench_sweep.py
measures ~10x over the serial loop at E=64 on CPU.

Contract: a *different execution* of the same estimator, not the same
bits — like ``engine="parallel_loo"`` vs ``"parallel"``, it shares one
fold assignment across cells and swaps Newton for MM, so tests assert
tolerance-equality against gathered per-segment references, while the
bitwise panel ≡ loop contract stays on the default cells mode.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config import CausalConfig
from repro.core import moments
from repro.core.crossfit import fold_ids
from repro.core.final_stage import cate_basis
from repro.core.registry import EstimatorSpec
from repro.inference.numerics import det_inv, det_solve

_F32 = jnp.float32


def segmented_supported(rspec: EstimatorSpec, cfg: CausalConfig) -> bool:
    """The one-pass kernels cover the linear-nuisance DML family."""
    if cfg.discrete_treatment:
        t_kind_ok = cfg.nuisance_t == "logistic"
    else:
        # continuous T is ridge-fit here; a logistic nuisance_t would
        # silently become a different estimator than cells mode
        t_kind_ok = cfg.nuisance_t == "ridge"
    return rspec.name.startswith("dml") and cfg.nuisance_y == "ridge" and t_kind_ok


def _aug(X: jax.Array) -> jax.Array:
    return jnp.concatenate([X, jnp.ones((X.shape[0], 1), X.dtype)], axis=1)


def _segment_fold_ridge(X, target, comb, n_segments, k, lam, row_block, strategy):
    """EXACT per-(segment, fold-complement) ridge via the LOO identity:
    one fold_gram pass over the combined segment×fold id (the target
    rides as an appended design column), then E·K tiny solves."""
    q = X.shape[1] + 1
    Gh, counts = moments.fold_gram(
        X,
        comb,
        n_segments * k,
        intercept=True,
        append=target,
        row_block=row_block,
        strategy=strategy,
    )
    Gh = Gh.reshape(n_segments, k, q + 1, q + 1)
    counts = counts.reshape(n_segments, k)
    Gseg = Gh.sum(axis=1)
    A_aug = Gseg[:, None] - Gh  # complement Grams
    n_eff = jnp.maximum(counts.sum(1, keepdims=True) - counts, 1.0)
    A = A_aug[..., :q, :q] / n_eff[..., None, None] + lam * jnp.eye(q, dtype=_F32)
    b = A_aug[..., :q, q] / n_eff[..., None]
    beta = jax.vmap(jax.vmap(det_solve))(A, b)  # (E, k, q)
    return beta, n_eff


def _segment_fold_logistic(
    Xa, tt, sids, folds, comb, n_segments, k, lam, iters, row_block, strategy
):
    """Per-(segment, fold-complement) logistic via the Böhning-Lindsay
    fixed majorizer: H0 factored from one segmented Gram pass, then
    ``iters`` MM steps of segment-gathered matvecs (each step reads the
    data once — no per-cell Gram rebuilds)."""
    q = Xa.shape[1]
    GhX, counts = moments.fold_gram(
        Xa, comb, n_segments * k, row_block=row_block, strategy=strategy
    )
    GhX = GhX.reshape(n_segments, k, q, q)
    counts = counts.reshape(n_segments, k)
    GsegX = GhX.sum(axis=1)
    n_eff = jnp.maximum(counts.sum(1, keepdims=True) - counts, 1.0)
    H0 = (GsegX[:, None] - GhX) / (4.0 * n_eff[..., None, None]) + lam * jnp.eye(
        q, dtype=_F32
    )
    if strategy == "pallas":
        # the fused segment-outer kernels replace the one-hot einsums:
        # neither the (n, E) nor the (n, E·k) mask ever materializes.
        # In-loop calls run whole-array (row_block=0): the transient
        # (n, k·q) outer is SMALLER than the (n, E·k) one-hot it
        # replaces, and the MM loop is the measured sweep hot spot.
        from repro.kernels.seg_gram import ops as sg_ops

        def grad_terms(r, rr):
            t1 = sg_ops.segment_outer(r, Xa, sids, n_segments)
            t2 = sg_ops.segment_outer(rr[:, None], Xa, comb, n_segments * k)
            return t1, t2.reshape(n_segments, k, q)

    else:
        oh_seg = jax.nn.one_hot(sids, n_segments, dtype=_F32)  # (n, E)
        oh_comb = jax.nn.one_hot(comb, n_segments * k, dtype=_F32)  # (n, E·k)

        def grad_terms(r, rr):
            t1 = jnp.einsum("ns,nk,np->skp", oh_seg, r, Xa)
            t2 = jnp.einsum("nc,n,np->cp", oh_comb, rr, Xa)
            return t1, t2.reshape(n_segments, k, q)

    def _step(_, beta):  # beta: (E, k, q)
        bs = beta[sids]  # (n, k, q)
        mu = jax.nn.sigmoid(jnp.einsum("np,nkp->nk", Xa, bs))
        r = mu - tt[:, None]  # (n, k)
        # held-in sums per segment minus own-fold sums = complement
        rr = jnp.take_along_axis(r, folds[:, None], axis=1)[:, 0]
        t1, t2 = grad_terms(r, rr)
        g = (t1 - t2) / n_eff[..., None] + lam * beta
        return beta - jax.vmap(jax.vmap(det_solve))(H0, g)

    return jax.lax.fori_loop(0, iters, _step, jnp.zeros((n_segments, k, q), _F32))


def _segment_final_stage(
    ry, rt, phi, sids, n_segments, ridge=1e-8, row_block=0, strategy=None
):
    """Per-segment orthogonal final stage + HC0 sandwich, all E
    segments from segment-Grams over the residuals (one data pass:
    one-hot einsums by default, the fused seg_gram kernels under
    strategy="pallas")."""
    pf = phi.shape[1]
    z = rt[:, None] * phi
    m = jnp.concatenate([z, ry[:, None]], axis=1)
    if strategy == "pallas":
        from repro.kernels.seg_gram import ops as sg_ops

        gaug = sg_ops.segment_outer(m, m, sids, n_segments, row_block=row_block)
        nseg = jnp.maximum(sg_ops.segment_counts(sids, n_segments), 1.0)
    else:
        oh_seg = jax.nn.one_hot(sids, n_segments, dtype=_F32)
        gaug = jnp.einsum("ns,ni,nj->sij", oh_seg, m, m)  # (E, pf+1, pf+1)
        nseg = jnp.maximum(oh_seg.sum(0), 1.0)
    a = gaug[:, :pf, :pf] + ridge * nseg[:, None, None] * jnp.eye(pf, dtype=_F32)
    theta = jax.vmap(det_solve)(a, gaug[:, :pf, pf])
    e = ry - (z * theta[sids]).sum(axis=1)
    me = e[:, None] * z
    if strategy == "pallas":
        meat = sg_ops.segment_outer(me, me, sids, n_segments, row_block=row_block)
    else:
        meat = jnp.einsum("ns,ni,nj->sij", oh_seg, me, me)
    ainv = jax.vmap(det_inv)(a)
    cov = jnp.einsum("sia,sab,sbj->sij", ainv, meat, ainv)
    se = jnp.sqrt(jnp.clip(jnp.diagonal(cov, axis1=1, axis2=2), 0.0, None))
    return theta, se


def segmented_dml_sweep(
    cfg: CausalConfig,
    X: jax.Array,
    y: jax.Array,
    t: jax.Array,
    sids: jax.Array,
    n_segments: int,
    key: jax.Array,
) -> Dict[str, jax.Array]:
    """All E per-segment DML fits from one segmented pass: shared fold
    assignment, LOO-identity ridge + MM logistic nuisances, per-segment
    final stage.  Returns {"theta" (E, p), "se" (E, p), "ate" (E,)}."""
    n = X.shape[0]
    k = cfg.n_folds
    lam = cfg.ridge_lambda
    rb, st = cfg.row_block, cfg.row_block_strategy
    folds = fold_ids(key, n, k)
    comb = sids * k + folds  # (n,) in [0, E·k)

    beta_y, _ = _segment_fold_ridge(X, y, comb, n_segments, k, lam, rb, st)
    xa = _aug(X.astype(_F32))
    tt = t.astype(_F32)
    mm_iters = 2 * cfg.newton_iters  # MM trades per-step cost for steps
    if cfg.discrete_treatment:
        beta_t = _segment_fold_logistic(
            xa, tt, sids, folds, comb, n_segments, k, lam, mm_iters, rb, st
        )
        mt = jax.nn.sigmoid(jnp.einsum("np,np->n", xa, beta_t[sids, folds]))
    else:
        beta_t, _ = _segment_fold_ridge(X, t, comb, n_segments, k, lam, rb, st)
        mt = jnp.einsum("np,np->n", xa, beta_t[sids, folds])

    # out-of-fold predictions: each row read once by its own
    # (segment, fold) model — a gather, not an (E, n) prediction matrix
    my = jnp.einsum("np,np->n", xa, beta_y[sids, folds])
    ry = y.astype(_F32) - my
    rt = tt - mt
    phi = cate_basis(X, cfg.cate_features)
    theta, se = _segment_final_stage(
        ry, rt, phi, sids, n_segments, row_block=rb, strategy=st
    )
    return {"theta": theta, "se": se, "ate": theta[:, 0]}


_JITTED: Dict[Any, Any] = {}


def segmented_column(
    cfg: CausalConfig,
    base_data: Dict[str, Any],
    n_segments: int,
    key: jax.Array,
) -> Dict[str, jax.Array]:
    """Engine adapter: jit the segmented sweep per (config, E) so
    repeated sweeps hit the compile cache."""
    ck = (cfg, n_segments)
    fn = _JITTED.get(ck)
    if fn is None:
        fn = jax.jit(
            lambda X, y, t, sids, key_: segmented_dml_sweep(
                cfg, X, y, t, sids, n_segments, key_
            )
        )
        _JITTED[ck] = fn
    return fn(base_data["X"], base_data["y"], base_data["t"], base_data["sids"], key)
