from repro.checkpoint.manager import CheckpointManager, restore_tree  # noqa: F401
