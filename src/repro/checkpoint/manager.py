"""Atomic, asynchronous, elastic checkpointing.

Fault-tolerance story (DESIGN.md §7 — the SPMD translation of Ray's
lineage-based recovery):

  * **Atomic**: state is written to ``<dir>/tmp.<step>`` and renamed to
    ``<dir>/step_<step>`` only after a full fsync'd write — a crash mid-
    save never corrupts the latest checkpoint.
  * **Async**: ``save_async`` snapshots device arrays to host memory
    (``jax.device_get``) and hands the serialization to a background
    thread, so the training loop resumes immediately (the copy is the
    only on-critical-path cost).
  * **Elastic**: ``restore`` takes the *target* shardings — restoring a
    512-chip checkpoint onto 256 chips (dead pod dropped) or vice versa
    is just ``device_put`` under the new NamedSharding; nothing in the
    format encodes the mesh.
  * **Retention**: keeps the newest ``keep_latest`` checkpoints plus the
    ``keep_best`` lowest-metric ones.

Format: one ``arrays.npz`` holding leaves keyed by their pytree path +
``meta.json`` (step, metric, user metadata).  Restore matches leaves to
a caller-provided abstract template by path, so optimizer/model refactors
fail loudly instead of silently misloading.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def flatten_with_paths(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {_path_str(path): leaf for path, leaf in flat}


def restore_tree(template, arrays: Dict[str, np.ndarray], *,
                 shardings=None):
    """Rebuild ``template``'s structure from path-keyed arrays; place
    under ``shardings`` (same structure) if given — the elastic re-mesh."""
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    sh_leaves = (jax.tree_util.tree_leaves(shardings)
                 if shardings is not None else [None] * len(paths_leaves))
    out = []
    for (path, tmpl), sh in zip(paths_leaves, sh_leaves):
        key = _path_str(path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"shape mismatch at {key}: "
                             f"ckpt {arr.shape} vs template {tmpl.shape}")
        arr = arr.astype(tmpl.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else
                   jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    def __init__(self, directory: str, *, keep_latest: int = 2,
                 keep_best: int = 1):
        self.dir = directory
        self.keep_latest = keep_latest
        self.keep_best = keep_best
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    # Save
    # ------------------------------------------------------------------
    def save(self, step: int, state, *, metric: Optional[float] = None,
             extra: Optional[Dict[str, Any]] = None):
        """Blocking save (used by save_async's worker)."""
        host = {k: np.asarray(jax.device_get(v))
                for k, v in flatten_with_paths(state).items()}
        tmp = os.path.join(self.dir, f"tmp.{step}.{os.getpid()}")
        os.makedirs(tmp, exist_ok=True)
        try:
            with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
                np.savez(f, **host)
                f.flush()
                os.fsync(f.fileno())
            meta = {"step": int(step), "metric": metric,
                    "time": time.time(), "extra": extra or {}}
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
                f.flush()
                os.fsync(f.fileno())
            final = os.path.join(self.dir, f"step_{step:08d}")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # the atomic commit
        finally:
            if os.path.exists(tmp):
                shutil.rmtree(tmp, ignore_errors=True)
        self._retain()

    def save_async(self, step: int, state, *, metric: Optional[float] = None,
                   extra: Optional[Dict[str, Any]] = None):
        """Snapshot to host now; serialize in the background."""
        self.wait()  # one in-flight save at a time
        host_state = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), state)

        def work():
            try:
                self.save(step, host_state, metric=metric, extra=extra)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ------------------------------------------------------------------
    # Restore
    # ------------------------------------------------------------------
    def _steps(self) -> List[Tuple[int, str]]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append((int(name.split("_")[1]), os.path.join(self.dir, name)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self._steps()
        return steps[-1][0] if steps else None

    def has_step(self, step: int) -> bool:
        return any(s == step for s, _ in self._steps())

    def load(self, *, step: Optional[int] = None
             ) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
        """(path-keyed host arrays, meta) WITHOUT a template — for
        callers whose leaf set varies per step (the sweep engine's
        per-column checkpoints: a column with no CIs saves fewer
        arrays).  ``restore`` remains the exact-template contract."""
        steps = dict(self._steps())
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        step = step if step is not None else max(steps)
        path = steps[step]
        with np.load(os.path.join(path, "arrays.npz")) as z:
            arrays = {k: z[k] for k in z.files}
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        return arrays, meta

    def restore(self, template, *, step: Optional[int] = None,
                shardings=None) -> Tuple[Any, Dict[str, Any]]:
        """Returns (state, meta).  ``shardings`` may target ANY mesh —
        this is the elastic-restart path."""
        arrays, meta = self.load(step=step)
        return restore_tree(template, arrays, shardings=shardings), meta

    # ------------------------------------------------------------------
    # Retention
    # ------------------------------------------------------------------
    def _retain(self):
        steps = self._steps()
        if len(steps) <= self.keep_latest:
            return
        # newest keep_latest always survive
        protected = {s for s, _ in steps[-self.keep_latest:]}
        # plus the keep_best best-metric ones
        scored = []
        for s, p in steps:
            try:
                with open(os.path.join(p, "meta.json")) as f:
                    m = json.load(f).get("metric")
                if m is not None:
                    scored.append((m, s))
            except OSError:
                pass
        for _, s in sorted(scored)[: self.keep_best]:
            protected.add(s)
        for s, p in steps:
            if s not in protected:
                shutil.rmtree(p, ignore_errors=True)
