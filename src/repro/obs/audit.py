"""The predicted-vs-measured cost audit: close the loop on the memory
model that sizes chunks.

``runtime.memory`` fits an affine peak-bytes model from two compile-only
probes (c=1 and c=8) and the scheduler trusts the interpolation to pick
chunk sizes — but until now nothing ever checked the model against the
chunks that actually ran.  The audit joins every traced chunk to two
ground truths:

  peak_ratio   affine-model predicted peak bytes at the chunk's actual
               size vs the exact ``hlo_cost.peak_temp_bytes`` of the
               compiled program AT that size — how good the two-probe
               interpolation is where the scheduler used it (1.0 =
               perfect; the acceptance bar is *finite*, the report makes
               drift visible);
  time_ratio   measured wall-clock (span duration, ``block_until_ready``
               honest) vs the roofline lower bound
               max(FLOPs/peak_flops, bytes/hbm_bw) from the same
               compiled HLO — the fraction-of-roofline lens the serving
               layer's latency SLOs will inherit.

Hardware constants default to ``launch.roofline``'s TPU-v5e model;
pass CPU-calibrated numbers for host-only runs (the ratios stay
comparable across PRs either way — same constants, same shapes).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.launch.roofline import HBM_BW, PEAK_FLOPS

_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class ChunkAudit:
    """One traced chunk joined to its compile-time cost predictions."""

    label: str
    chunk_index: int
    chunk_size: int
    predicted_peak_bytes: float  # affine memory model at chunk_size
    probed_peak_bytes: float  # exact HLO peak at chunk_size
    flops: float  # hlo_cost.analyze roofline FLOPs
    hbm_bytes: float  # hlo_cost.analyze HBM traffic
    measured_s: float  # span duration (block_until_ready honest)

    @property
    def peak_ratio(self) -> float:
        """Affine-predicted / HLO-measured peak bytes (finite, > 0)."""
        return max(self.predicted_peak_bytes, _EPS) / max(
            self.probed_peak_bytes, _EPS
        )

    def roofline_s(self, peak_flops: float = PEAK_FLOPS, hbm_bw: float = HBM_BW):
        """Roofline lower bound for one execution of the chunk program."""
        return max(self.flops / peak_flops, self.hbm_bytes / hbm_bw)

    def time_ratio(self, peak_flops: float = PEAK_FLOPS, hbm_bw: float = HBM_BW):
        """Measured / roofline seconds (>= ~1 when the model is sane)."""
        return max(self.measured_s, _EPS) / max(
            self.roofline_s(peak_flops, hbm_bw), _EPS
        )


class CostAudit:
    """Accumulates :class:`ChunkAudit` rows across a traced run and
    renders them as a table / bench-JSON summary."""

    def __init__(self, peak_flops: float = PEAK_FLOPS, hbm_bw: float = HBM_BW):
        self.peak_flops = float(peak_flops)
        self.hbm_bw = float(hbm_bw)
        self.rows: List[ChunkAudit] = []

    def record(self, row: ChunkAudit) -> None:
        self.rows.append(row)

    def __len__(self) -> int:
        return len(self.rows)

    def as_dicts(self) -> List[Dict]:
        return [
            {
                "label": r.label,
                "chunk_index": r.chunk_index,
                "chunk_size": r.chunk_size,
                "predicted_peak_bytes": r.predicted_peak_bytes,
                "probed_peak_bytes": r.probed_peak_bytes,
                "peak_ratio": r.peak_ratio,
                "flops": r.flops,
                "hbm_bytes": r.hbm_bytes,
                "measured_s": r.measured_s,
                "roofline_s": r.roofline_s(self.peak_flops, self.hbm_bw),
                "time_ratio": r.time_ratio(self.peak_flops, self.hbm_bw),
            }
            for r in self.rows
        ]

    def summary(self) -> Dict:
        """Rollup for BENCH_results.json's ``obs.audit`` section."""
        if not self.rows:
            return {"n_chunks": 0}
        pr = [r.peak_ratio for r in self.rows]
        tr = [r.time_ratio(self.peak_flops, self.hbm_bw) for r in self.rows]
        return {
            "n_chunks": len(self.rows),
            "labels": sorted({r.label for r in self.rows}),
            "peak_ratio_min": min(pr),
            "peak_ratio_max": max(pr),
            "peak_ratio_mean": sum(pr) / len(pr),
            "time_ratio_min": min(tr),
            "time_ratio_max": max(tr),
        }

    def table(self) -> str:
        """Human-readable audit: one line per chunk, predicted vs
        measured side by side."""
        head = (
            f"{'label':<24} {'#':>3} {'size':>5} {'pred_peak':>10} "
            f"{'hlo_peak':>10} {'ratio':>6} {'meas_ms':>8} {'time_x':>9}"
        )
        lines = [head, "-" * len(head)]
        for r in self.rows:
            lines.append(
                f"{r.label[:24]:<24} {r.chunk_index:>3} {r.chunk_size:>5} "
                f"{r.predicted_peak_bytes:>10.0f} {r.probed_peak_bytes:>10.0f} "
                f"{r.peak_ratio:>6.2f} {r.measured_s * 1e3:>8.2f} "
                f"{r.time_ratio(self.peak_flops, self.hbm_bw):>9.1f}"
            )
        return "\n".join(lines)
