"""Zero-dependency metrics registry: counters, gauges, histograms.

The runtime makes scheduling decisions (chunks dispatched, ladder
downgrades, retries, jit-cache misses) and the memory model makes
predictions (peak bytes, chosen chunk size) that previously vanished
into an ad-hoc event list.  This registry gives each of them a durable,
snapshot-able home:

  Counter    monotone occurrence counts ("runtime.chunks",
             "runtime.downgrades", "jit_cache_miss[<closure>]");
  Gauge      last-written values ("runtime.predicted_peak_bytes[label]",
             "runtime.chunk_size[label]");
  Histogram  bounded-reservoir distributions ("runtime.chunk_seconds")
             with exact count/sum/min/max and reservoir percentiles —
             the substrate the serving layer's p50/p99 SLOs will read.

Everything is plain host-side Python: no jax values are held (callers
convert), so a registry never extends a tracer's lifetime to device
buffers and never perturbs compilation.
"""

from __future__ import annotations

import math
import random
from typing import Dict, List, Optional


class Counter:
    """Monotone event counter."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-written value (None until first set)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value: Optional[float] = None

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Value distribution with exact count/sum/min/max and percentiles
    from a bounded reservoir (Algorithm-R uniform sample of ``cap``
    observations — bounded for runtime-lifetime safety).

    The reservoir is a *uniform* sample over the whole observation
    stream, not a prefix: once full, observation ``i`` replaces a
    random slot with probability ``cap / i``, so the percentiles of a
    long-running server track the live distribution instead of
    freezing on warm-up latencies.  Sampling is host-side and
    deterministic per instance (seeded ``random.Random``); count / sum
    / min / max stay exact regardless."""

    __slots__ = ("count", "total", "lo", "hi", "cap", "_values", "_rng")

    def __init__(self, cap: int = 4096, seed: int = 0) -> None:
        self.count = 0
        self.total = 0.0
        self.lo = math.inf
        self.hi = -math.inf
        self.cap = int(cap)
        self._values: List[float] = []
        self._rng = random.Random(seed)

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.lo = min(self.lo, v)
        self.hi = max(self.hi, v)
        if len(self._values) < self.cap:
            self._values.append(v)
        else:
            # Algorithm R: keep each of the count observations seen so
            # far in the reservoir with equal probability cap/count
            j = self._rng.randrange(self.count)
            if j < self.cap:
                self._values[j] = v

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Reservoir percentile, q in [0, 1] (nearest-rank)."""
        if not self._values:
            return 0.0
        vs = sorted(self._values)
        rank = min(int(q * len(vs)), len(vs) - 1)
        return vs[max(rank, 0)]

    def summary(self) -> Dict[str, float]:
        if not self.count:
            return {"count": 0, "sum": 0.0}
        return {
            "count": self.count,
            "sum": self.total,
            "mean": self.mean,
            "min": self.lo,
            "max": self.hi,
            "p50": self.percentile(0.50),
            "p99": self.percentile(0.99),
        }


class MetricsRegistry:
    """Named get-or-create store for the three instrument kinds, with
    one JSON-friendly ``snapshot()`` for bench reports and tests.

    Most call sites thread an explicit registry (a ``Tracer`` owns
    one); ``default_registry()`` below serves the few places with no
    tracer in scope — e.g. the moments engine's fallback-ladder
    counter, which fires at *trace time* inside ``jit`` and therefore
    cannot take a per-call handle."""

    def __init__(self) -> None:
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge()
        return g

    def histogram(self, name: str, cap: int = 4096, seed: int = 0) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(cap=cap, seed=seed)
        return h

    def snapshot(self) -> Dict[str, Dict]:
        """Point-in-time view: {"counters": {...}, "gauges": {...},
        "histograms": {name: summary dict}} — plain scalars only."""
        return {
            "counters": {k: c.value for k, c in sorted(self._counters.items())},
            "gauges": {k: g.value for k, g in sorted(self._gauges.items())},
            "histograms": {
                k: h.summary() for k, h in sorted(self._histograms.items())
            },
        }


# ---------------------------------------------------------------------------
# Process-wide default registry.
# ---------------------------------------------------------------------------

_DEFAULT: Optional[MetricsRegistry] = None


def default_registry() -> MetricsRegistry:
    """The process-wide fallback registry (created on first use).

    Used by instrumentation that runs where no tracer/registry handle
    can be threaded — notably ``core.moments.blocked_reduce`` counting
    ``seg_gram.fallback[<form>]`` when ``strategy="pallas"`` ladders
    down to "chunked" for a form without a fused builder.  Counts are
    trace-time events: a jit-cached call does not re-trace and so does
    not re-count (the counter answers "which forms still lack a fused
    lowering?", not "how many rows took it").
    """
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = MetricsRegistry()
    return _DEFAULT


def reset_default_registry() -> None:
    """Drop the process-wide registry (a fresh one is created on next
    use).  Tests reset between cases so same-name counters can never
    couple test order; long-lived processes can reset after shipping a
    snapshot.  Holders of an old ``default_registry()`` handle keep
    writing to the detached instance — callers that want the live one
    re-call ``default_registry()`` (as all in-tree call sites do).

    The serving layer does NOT live here: every ``EffectServer`` owns a
    per-server ``MetricsRegistry`` so two servers in one process never
    share a latency histogram.
    """
    global _DEFAULT
    _DEFAULT = None
