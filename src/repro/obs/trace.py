"""Hierarchical host-side span tracer with Chrome-trace export.

The paper's Ray case study is an observability argument: it justifies
the parallelization by *measuring* estimation times.  This tracer is
the measuring instrument for our runtime — spans open around
``TaskRuntime.map`` / per-chunk dispatches / gathered DAG nodes, sweep
columns, and crossfit targets, nest by call structure (a host-side
stack), and close with ``jax.block_until_ready`` on the produced value
so durations measure executed work, not dispatch latency.

Exports:

  chrome_trace()       Chrome trace-event JSON ("X" complete events,
                       "i" instants for RuntimeEvents) — load the file
                       in Perfetto (https://ui.perfetto.dev) or
                       chrome://tracing;
  render()             indented text tree with durations, for terminals
                       and bench logs;
  rollup()             per-span-name {count, total_s, max_s} — the
                       ``obs.spans`` section of BENCH_results.json.

A ``Tracer`` owns its :class:`~repro.obs.metrics.MetricsRegistry` and
:class:`~repro.obs.audit.CostAudit` so integrations thread ONE object.
``tracer=None`` everywhere means: no spans, no syncs, no probe
lowerings — the traced and untraced paths run the same compiled
programs (bit-identity contracts hold by construction).
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import time
from typing import Any, Dict, Iterator, List, Optional

import jax

from repro.obs.audit import CostAudit
from repro.obs.metrics import MetricsRegistry


def _jsonable(v: Any) -> Any:
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


@dataclasses.dataclass
class Span:
    """One traced interval (or instant, when ``end_ns == start_ns``)."""

    span_id: int
    name: str
    cat: str
    start_ns: int
    end_ns: int = -1  # -1 while open
    parent_id: int = -1
    depth: int = 0
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    instant: bool = False

    @property
    def open(self) -> bool:
        return self.end_ns < 0

    @property
    def duration_s(self) -> float:
        if self.open:
            return 0.0
        return max(self.end_ns - self.start_ns, 0) / 1e9


class Tracer:
    """Span stack + completed-span log + metrics + cost audit.

    ``sync=True`` (default) forces ``jax.block_until_ready`` at
    :meth:`sync` call sites so span durations are honest; set False to
    trace pure scheduling overhead without forcing device work.
    """

    def __init__(self, *, sync: bool = True, clock=time.perf_counter_ns):
        self._clock = clock
        self.sync_enabled = bool(sync)
        self.spans: List[Span] = []  # in open order; closed in place
        self._stack: List[Span] = []
        self._next_id = 0
        self.metrics = MetricsRegistry()
        self.audit = CostAudit()

    # -- recording ------------------------------------------------------
    @contextlib.contextmanager
    def span(self, name: str, cat: str = "runtime", **attrs) -> Iterator[Span]:
        """Open a nested span; yields it so callers can attach attrs."""
        parent = self._stack[-1] if self._stack else None
        s = Span(
            span_id=self._next_id,
            name=name,
            cat=cat,
            start_ns=self._clock(),
            parent_id=parent.span_id if parent else -1,
            depth=len(self._stack),
            attrs={k: _jsonable(v) for k, v in attrs.items()},
        )
        self._next_id += 1
        self.spans.append(s)
        self._stack.append(s)
        try:
            yield s
        finally:
            self._stack.pop()
            s.end_ns = self._clock()

    def instant(self, name: str, cat: str = "event", **attrs) -> Span:
        """Zero-duration marker (RuntimeEvents: retry, downgrade, ...)."""
        parent = self._stack[-1] if self._stack else None
        now = self._clock()
        s = Span(
            span_id=self._next_id,
            name=name,
            cat=cat,
            start_ns=now,
            end_ns=now,
            parent_id=parent.span_id if parent else -1,
            depth=len(self._stack),
            attrs={k: _jsonable(v) for k, v in attrs.items()},
            instant=True,
        )
        self._next_id += 1
        self.spans.append(s)
        return s

    def sync(self, value: Any) -> Any:
        """``block_until_ready`` inside an open span so its duration
        covers the device work that produced ``value``."""
        if self.sync_enabled:
            try:
                jax.block_until_ready(value)
            except Exception:  # noqa: BLE001 — non-jax values pass through
                pass
        return value

    # -- export ---------------------------------------------------------
    def chrome_trace(self) -> Dict[str, Any]:
        """Chrome trace-event JSON (dict; ``json.dump`` it).  Timestamps
        are microseconds relative to the first span, complete spans are
        ph="X", instants ph="i" — the schema Perfetto ingests."""
        t0 = min((s.start_ns for s in self.spans), default=0)
        events: List[Dict[str, Any]] = []
        for s in self.spans:
            base = {
                "name": s.name,
                "cat": s.cat,
                "ts": (s.start_ns - t0) / 1e3,
                "pid": 1,
                "tid": 1,
                "args": dict(s.attrs),
            }
            if s.instant:
                events.append({**base, "ph": "i", "s": "t"})
            else:
                end = s.end_ns if not s.open else s.start_ns
                events.append(
                    {**base, "ph": "X", "dur": max(end - s.start_ns, 0) / 1e3}
                )
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f, indent=1)
        return path

    def render(self) -> str:
        """Indented text tree (spans in open order, depth-indented)."""
        lines = []
        for s in self.spans:
            pad = "  " * s.depth
            if s.instant:
                lines.append(f"{pad}! {s.name} {s.attrs or ''}".rstrip())
            else:
                lines.append(
                    f"{pad}{s.name} [{s.cat}] {s.duration_s * 1e3:.2f}ms"
                    + (f" {s.attrs}" if s.attrs else "")
                )
        return "\n".join(lines)

    def rollup(self) -> Dict[str, Dict[str, float]]:
        """Per-name duration rollup over completed non-instant spans."""
        out: Dict[str, Dict[str, float]] = {}
        for s in self.spans:
            if s.instant or s.open:
                continue
            r = out.setdefault(s.name, {"count": 0, "total_s": 0.0, "max_s": 0.0})
            r["count"] += 1
            r["total_s"] += s.duration_s
            r["max_s"] = max(r["max_s"], s.duration_s)
        return out

    def span_names(self) -> List[str]:
        return [s.name for s in self.spans]


@contextlib.contextmanager
def maybe_span(tracer: Optional[Tracer], name: str, cat: str = "runtime", **attrs):
    """``tracer.span(...)`` when tracing, a free no-op otherwise — the
    one-liner integrations use so ``tracer=None`` stays zero-cost."""
    if tracer is None:
        yield None
    else:
        with tracer.span(name, cat=cat, **attrs) as s:
            yield s
