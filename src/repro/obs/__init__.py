"""repro.obs — zero-dependency observability.

The paper's argument is ultimately a measurement argument; this
package makes the same measurements first-class: a hierarchical span
tracer with ``block_until_ready``-honest durations and
Chrome-trace/Perfetto export (``trace``), a metrics registry of
counters / gauges / bounded-reservoir histograms with a plain-JSON
snapshot (``metrics``), and a predicted-vs-measured cost audit
joining traced chunks to the affine memory model and HLO roofline
probes (``audit``).  Thread ONE ``Tracer`` through
``TaskRuntime(tracer=...)``, ``sweep(tracer=...)``, or
``MomentStore(tracer=...)``; ``tracer=None`` (the default everywhere)
records nothing and lowers nothing, so traced and untraced runs
execute the same compiled programs.
"""
#   trace.py    hierarchical span tracer (block_until_ready-honest
#               durations), Chrome trace-event / Perfetto export,
#               text tree, per-name rollups
#   metrics.py  counters / gauges / histograms with a snapshot API
#   audit.py    predicted-vs-measured cost audit joining traced chunks
#               to the affine memory model and hlo_cost roofline
# Thread ONE Tracer through TaskRuntime(tracer=...), sweep(tracer=...),
# and crossfit (via a traced runtime); tracer=None everywhere is the
# zero-overhead default.
from repro.obs.audit import ChunkAudit, CostAudit
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_registry,
    reset_default_registry,
)
from repro.obs.trace import Span, Tracer, maybe_span

__all__ = [
    "ChunkAudit",
    "CostAudit",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Span",
    "Tracer",
    "default_registry",
    "maybe_span",
    "reset_default_registry",
]
