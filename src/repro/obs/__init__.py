# repro.obs — zero-dependency observability for the task runtime:
#   trace.py    hierarchical span tracer (block_until_ready-honest
#               durations), Chrome trace-event / Perfetto export,
#               text tree, per-name rollups
#   metrics.py  counters / gauges / histograms with a snapshot API
#   audit.py    predicted-vs-measured cost audit joining traced chunks
#               to the affine memory model and hlo_cost roofline
# Thread ONE Tracer through TaskRuntime(tracer=...), sweep(tracer=...),
# and crossfit (via a traced runtime); tracer=None everywhere is the
# zero-overhead default.
from repro.obs.audit import ChunkAudit, CostAudit
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.trace import Span, Tracer, maybe_span

__all__ = [
    "ChunkAudit",
    "CostAudit",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Span",
    "Tracer",
    "maybe_span",
]
