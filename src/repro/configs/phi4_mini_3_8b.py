"""phi4-mini-3.8b — dense, partial RoPE, SwiGLU, GQA [arXiv:2412.08905]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200_064,
    attention="gqa",
    mlp="swiglu",
    rope_theta=10_000.0,
    rope_fraction=0.75,  # partial_rotary_factor
    tie_embeddings=True,
)
