"""The orthogonal-IV case study: OrthoIV / DRIV on the compliance DGP
(repro.data.causal_dgp.make_iv_data).

The paper's catalogue parallelizes EconML's IV family (OrthoIV / DMLIV
/ DRIV) with the same Ray-task machinery as DML; this config is the
paper-faithful estimator settings for that workload on the SPMD
translation — identical scales to the DML sweep (configs.dml_synthetic)
so Fig.-6-style comparisons line up column-for-column.
"""
from repro.config import CausalConfig

# Paper-faithful IV estimator settings: 5-fold cross-fitting of the
# nuisance triple (ridge E[Y|X], logistic E[T|X], logistic E[Z|X]),
# constant CATE basis -> the LATE, bootstrap CIs through the runtime.
IV_CAUSAL = CausalConfig(
    n_folds=5,
    nuisance_y="ridge",
    nuisance_t="logistic",
    nuisance_z="logistic",
    final_stage="linear",
    cate_features=1,          # constant effect -> LATE (Wald on residuals)
    discrete_treatment=True,
    discrete_instrument=True,
    iv_cov_clip=0.1,          # DRIV compliance-denominator floor
    engine="parallel",
)

# Figure-6 sweep sizes (shared with the DML case study)
SCALES = (10_000, 100_000, 1_000_000)
N_COVARIATES = 500

# Compliance rate of the synthetic encouragement design: 70% compliers
# gives a strong-but-not-trivial first stage (F >> 10 at these n).
COMPLIANCE = 0.7
