"""rwkv6-3b — "Finch": attention-free time-mix with data-dependent decay
[arXiv:2404.05892; hf:RWKV/rwkv-6-world-3b]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,   # 2560 / 64 per-head channels
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    attention="rwkv",
    mlp="gelu",  # unused: rwkv channel-mix replaces the MLP
    use_rope=False,
    ssm_chunk=16,  # stability bound: chunk * MAX_LOG_DECAY must stay in fp32 exp range
)
