"""Architecture registry: ``get_config("<arch-id>")`` -> ModelConfig.

One module per assigned architecture (exact public-literature configs)
plus the paper's own synthetic-DML study config (``dml_synthetic``).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.config import ModelConfig, smoke_variant

ARCH_IDS: List[str] = [
    "yi-34b",
    "granite-3-2b",
    "phi4-mini-3.8b",
    "chatglm3-6b",
    "pixtral-12b",
    "zamba2-1.2b",
    "arctic-480b",
    "deepseek-v3-671b",
    "whisper-tiny",
    "rwkv6-3b",
]

_MODULES: Dict[str, str] = {
    "yi-34b": "yi_34b",
    "granite-3-2b": "granite_3_2b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "chatglm3-6b": "chatglm3_6b",
    "pixtral-12b": "pixtral_12b",
    "zamba2-1.2b": "zamba2_1_2b",
    "arctic-480b": "arctic_480b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "whisper-tiny": "whisper_tiny",
    "rwkv6-3b": "rwkv6_3b",
}


def get_config(arch: str) -> ModelConfig:
    name = arch[:-len("-smoke")] if arch.endswith("-smoke") else arch
    if name not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    cfg: ModelConfig = mod.CONFIG
    if arch.endswith("-smoke"):
        return smoke_variant(cfg)
    return cfg


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
