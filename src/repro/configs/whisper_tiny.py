"""whisper-tiny — encoder-decoder; conv/mel frontend STUBBED (input_specs
provides precomputed frame embeddings) [arXiv:2212.04356]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,  # decoder layers
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    attention="gqa",
    mlp="gelu",
    use_rope=False,
    learned_pos_emb=True,
    max_position_embeddings=32_768,  # stretched past whisper's 448 so the
    # assigned decode_32k cell is well-defined (noted in DESIGN.md)
    encoder_layers=4,
    max_source_positions=1500,
    tie_embeddings=True,
)
