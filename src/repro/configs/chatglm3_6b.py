"""chatglm3-6b — dense, 2d (interleaved, half-dim) RoPE, GQA kv=2
[arXiv:2406.12793; hf:THUDM/chatglm3-6b]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=65024,
    attention="gqa",
    mlp="swiglu",
    rope_theta=10_000.0,
    rope_fraction=0.5,  # GLM rotates half of head_dim, interleaved pairs
)
