"""The many-cohorts sweep case study: E per-segment effect estimates
per run (repro.sweep) on the synthetic DGP.

The paper's workload is many estimations fanned out on Ray — per user
segment, treatment cohort, and config variant (the shape Netflix's
Computational Causal Inference agenda and Amazon's DML-at-scale
pipeline both batch).  This preset pins the paper-faithful estimator
settings for that grid; ``examples/sweep_demo.py`` and
``benchmarks/bench_sweep.py`` consume it.
"""
from repro.config import CausalConfig

# Per-cell estimator settings: DML with 5-fold cross-fitting, constant
# CATE basis -> one ATE per segment, cells chunked through the runtime
# in blocks of 16 so the (cells, n) live weights stay bounded at
# industrial n.  segment_key names the cohort column in the caller's
# frame (provenance carried into EffectPanel summaries).
SWEEP = CausalConfig(
    n_folds=5,
    nuisance_y="ridge",
    nuisance_t="logistic",
    final_stage="linear",
    cate_features=1,
    discrete_treatment=True,
    engine="parallel",
    inference="none",          # point sweep; flip to "bootstrap" for CIs
    segment_key="segment",
    sweep_chunk=16,
)

# The bench grid: E=64 segments (bench_sweep's acceptance shape) at
# CPU-friendly rows; --full raises rows toward the paper's scales.
N_SEGMENTS = 64
SCALES = (16_384, 65_536, 1_048_576)
N_COVARIATES = 50
