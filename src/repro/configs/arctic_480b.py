"""arctic-480b — MoE 128 experts top-2 with a dense residual MLP in
parallel (dense-MoE hybrid) [hf:Snowflake/snowflake-arctic-base]."""
from repro.config import ModelConfig
import jax.numpy as jnp

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    attention="gqa",
    mlp="swiglu",
    num_experts=128,
    experts_per_token=2,
    dense_residual=True,
    expert_capacity_factor=1.25,
    # 480B fp32 optimizer state exceeds v5e HBM; bf16 moments (see DESIGN)
    param_dtype=jnp.float32,
)
