"""zamba2-1.2b — hybrid: Mamba2 backbone + one weight-shared attention
block applied every 6 mamba blocks [arXiv:2411.15242; hf:Zyphra]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,  # shared attention block is MHA
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    attention="gqa",
    mlp="swiglu",
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_chunk=16,  # stability bound: chunk * MAX_LOG_DECAY must stay in fp32 exp range
    shared_attn_every=6,
)
