"""deepseek-v3-671b — MLA attention, 1 shared + 256 routed experts top-8,
sigmoid router, first-3-dense, optional MTP [arXiv:2412.19437]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=2048,  # routed expert intermediate size
    vocab_size=129_280,
    attention="mla",
    mlp="swiglu",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_head_dim=64,
    qk_nope_head_dim=128,
    v_head_dim=128,
    num_experts=256,
    experts_per_token=8,
    num_shared_experts=1,
    first_k_dense=3,
    dense_ff=18432,
    router_score="sigmoid",
    mtp_depth=0,  # MTP head available via flag; off for shape cells
)
