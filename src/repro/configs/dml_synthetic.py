"""The paper's own case study (§5.3): DML vs DML_Ray on synthetic data.

Scales match Figure 6: {10k, 100k, 1M} rows x ~500 covariates, binary
treatment, dowhy-style partially-linear DGP, 5-fold cross-fitting.
"""
from repro.config import CausalConfig

# Paper-faithful estimator settings (EconML defaults modulo the nuisance
# family swap documented in DESIGN.md §2/§9).
CAUSAL = CausalConfig(
    n_folds=5,
    nuisance_y="ridge",
    nuisance_t="logistic",
    final_stage="linear",
    cate_features=1,       # constant effect -> ATE (paper's demo)
    discrete_treatment=True,
    engine="parallel",
)

# Figure-6 sweep sizes
SCALES = (10_000, 100_000, 1_000_000)
N_COVARIATES = 500
