"""pixtral-12b — VLM: pixtral-ViT frontend (STUB: input_specs provides
patch embeddings) + mistral-nemo decoder backbone
[hf:mistralai/Pixtral-12B-2409]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,  # explicit head_dim (nemo): 32*128 = 4096 != d_model
    d_ff=14336,
    vocab_size=131_072,
    attention="gqa",
    mlp="swiglu",
    rope_theta=1_000_000_000.0,
    patch_embed_dim=1024,  # pixtral ViT hidden size (stubbed frontend)
)
