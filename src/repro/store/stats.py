"""Ingest-side sufficient statistics of the effect store.

The store's unit of state is the per-(segment, fold) cell.  Each cell
holds two Gram-additive accumulators over the nuisance design
``dn = [X | 1 | t | y]`` (``[... | z]`` for the instrumented family):

  ng      (cells, qd, qd)   ``Σ_n dn_n dn_nᵀ`` — the nuisance fold
          Gram.  Its fold-complement (the leave-one-out identity) is
          every cross-fit ridge normal equation at once.
  vg      (cells, pf·qd, pf·qd)   ``Σ_n v_n v_nᵀ`` with
          ``v = φ(x) ⊗ dn`` — the degree-4 moment tensor.  Every
          final-stage statistic (G, b, Σrz·rt·φφᵀ, Σe² …) is a
          *contraction* of vg with per-cell residual coefficient
          vectors (a residual is linear in dn: ``ry = c_yᵀ dn`` with
          ``c_y = [-β_y | 1 at the y column]``), so refresh never
          re-reads a row.
  counts  (cells,)   exact integer row counts (f32 sums of integers
          are order-independent below 2²⁴).

Ingest folds a new row block into all three with ONE
``moments.blocked_reduce`` pass over only the new rows, seeded with the
standing accumulators (``init=``).  Because the seeded left-fold
replays exactly the addition sequence a one-shot pass over the
concatenated rows would run, incremental ingest is **bitwise** the
full rebuild whenever every earlier ingest ended on a ``row_block``
boundary — the store's fixed-order block-fold contract.
``strategy="pallas"`` routes through the fused segment-outer kernels
instead (bitwise on the scatter lowering, delta-add tolerance on the
compiled kernel).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import moments

Array = jax.Array
_F32 = jnp.float32

State = Dict[str, Array]


@dataclasses.dataclass(frozen=True)
class ColumnLayout:
    """Static shape metadata of one store column's accumulators."""

    p: int    # X feature width
    pf: int   # CATE basis width (cate_basis column count)
    k: int    # cross-fit folds
    iv: bool  # instrumented design (z column present)

    @property
    def q(self) -> int:
        """Augmented nuisance design width [X | 1]."""
        return self.p + 1

    @property
    def it(self) -> int:
        """Column index of t inside dn."""
        return self.q

    @property
    def iy(self) -> int:
        """Column index of y inside dn."""
        return self.q + 1

    @property
    def iz(self) -> int:
        """Column index of z inside dn (instrumented layouts only)."""
        return self.q + 2

    @property
    def qd(self) -> int:
        """Full dn width."""
        return self.q + (3 if self.iv else 2)

    @property
    def pv(self) -> int:
        """Width of the Khatri-Rao row ``v = φ ⊗ dn``."""
        return self.pf * self.qd


def init_state(layout: ColumnLayout, n_cells: int) -> State:
    """Zero accumulators for ``n_cells = n_segments · k`` cells."""
    return {
        "ng": jnp.zeros((n_cells, layout.qd, layout.qd), _F32),
        "vg": jnp.zeros((n_cells, layout.pv, layout.pv), _F32),
        "counts": jnp.zeros((n_cells,), _F32),
    }


def _dn(layout: ColumnLayout, X: Array, t: Array, y: Array,
        z: Optional[Array]) -> Array:
    n = X.shape[0]
    cols = [
        X.astype(_F32),
        jnp.ones((n, 1), _F32),
        t.astype(_F32).reshape(n, 1),
        y.astype(_F32).reshape(n, 1),
    ]
    if layout.iv:
        cols.append(z.astype(_F32).reshape(n, 1))
    return jnp.concatenate(cols, axis=1)


def _vrow(layout: ColumnLayout, phi: Array, dn: Array) -> Array:
    v = phi.astype(_F32)[:, :, None] * dn[:, None, :]
    return v.reshape(dn.shape[0], layout.pv)


def ingest_cells(layout: ColumnLayout, state: State, X: Array, t: Array,
                 y: Array, z: Optional[Array], phi: Array, comb: Array,
                 n_cells: int, *, row_block: int = 0,
                 strategy: Optional[str] = None) -> State:
    """Fold a row block into the standing cell accumulators.

    ``comb`` is the combined cell id ``segment·k + fold`` per row.  One
    blocked pass over ONLY the new rows; history is never re-touched.
    """
    if strategy == "pallas":
        from repro.kernels.seg_gram import ops as sg_ops

        dn = _dn(layout, X, t, y, z)
        v = _vrow(layout, phi, dn)
        return {
            "ng": sg_ops.segment_outer(dn, dn, comb, n_cells,
                                       row_block=row_block,
                                       init=state["ng"]),
            "vg": sg_ops.segment_outer(v, v, comb, n_cells,
                                       row_block=row_block,
                                       init=state["vg"]),
            "counts": state["counts"] + sg_ops.segment_counts(comb, n_cells),
        }

    def _block(Xb, tb, yb, *rest):
        if layout.iv:
            zb, phib, cb = rest
        else:
            (phib, cb), zb = rest, None
        dn = _dn(layout, Xb, tb, yb, zb)
        v = _vrow(layout, phib, dn)
        oh = jax.nn.one_hot(cb, n_cells, dtype=_F32)
        return {
            "ng": jnp.einsum("nc,ni,nj->cij", oh, dn, dn),
            "vg": jnp.einsum("nc,ni,nj->cij", oh, v, v),
            "counts": oh.sum(0),
        }

    arrays = (X, t, y) + ((z,) if layout.iv else ()) + (phi, comb)
    pad_values = (0,) * (len(arrays) - 1) + (-1,)
    return moments.blocked_reduce(_block, arrays, row_block=row_block,
                                  strategy=strategy, pad_values=pad_values,
                                  init=state, form="store_ingest")
