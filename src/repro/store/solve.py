"""Refresh-side solves: cell accumulators → per-segment effects.

Everything here is O(p³)-per-cell linear algebra on the store's
sufficient statistics — no data pass:

  1. Cross-fit ridge nuisances come from the fold-complement of the
     nuisance Gram (the leave-one-out identity of
     ``sweep.segmented._segment_fold_ridge``, same scaling: complement
     Gram / n_eff + λI).
  2. Residuals are linear forms of the design, ``r = cᵀ dn`` with
     coefficient vectors like ``c_y = [-β_y | 1 at the y column]``, so
     every final-stage moment is a contraction of the degree-4 tensor
     ``vg`` with two coefficient vectors:

        G   = Σ rt²·φφᵀ      = ⟨vg, c_t ⊗ c_t⟩
        b   = Σ rt·ry·φ      = ⟨vg, c_t ⊗ c_y⟩  (φ₀ ≡ 1 carries ry)
        J   = Σ rz·rt·φφᵀ    = ⟨vg, c_z ⊗ c_t⟩  (instrumented family)
        Σe² = Σry² - 2θᵀb + θᵀGθ

  3. Solve/invert with the deterministic Gauss-Jordan kernels and the
     exact ridge scaling of the segmented sweep (``+ 1e-8·n_seg·I``).

Standard errors are the **homoskedastic** sandwich ``σ²·A⁻¹ G A⁻¹``
(σ² = Σe²/n_seg): the HC0 meat ``Σe²·zzᵀ`` is degree-6 in the design
and is NOT a contraction of any stored moment — computing it would
need a data pass, which is exactly what refresh must not do.  See
docs/ARCHITECTURE.md for the contract table entry.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.inference.numerics import det_inv, det_solve
from repro.store.stats import ColumnLayout, State

Array = jax.Array
_F32 = jnp.float32


def _coef(beta: Array, col: int, qd: int, q: int) -> Array:
    """Residual coefficient vector in dn coordinates: r = cᵀ dn."""
    c = jnp.zeros(beta.shape[:-1] + (qd,), beta.dtype)
    c = c.at[..., :q].set(-beta)
    return c.at[..., col].set(1.0)


def refresh_column(layout: ColumnLayout, state: State, n_segments: int, *,
                   ridge_lambda: float, ridge_final: float = 1e-8
                   ) -> Dict[str, Array]:
    """Re-solve one column: {"theta" (E, pf), "se" (E, pf), "ate" (E,)}.

    Zero-row cells stay finite (n_eff/n_seg floored at 1, ridge keeps
    every solve well-posed); ``EffectPanel.ok`` flags them via counts.
    """
    lo = layout
    E, k, q, qd, pf = n_segments, lo.k, lo.q, lo.qd, lo.pf
    ng = state["ng"].reshape(E, k, qd, qd)
    counts = state["counts"].reshape(E, k)

    # fold-complement ridge nuisances (LOO identity, segmented scaling)
    Gseg = ng.sum(axis=1)
    A_aug = Gseg[:, None] - ng
    n_eff = jnp.maximum(counts.sum(1, keepdims=True) - counts, 1.0)
    A = (A_aug[..., :q, :q] / n_eff[..., None, None]
         + ridge_lambda * jnp.eye(q, dtype=_F32))
    solve2 = jax.vmap(jax.vmap(det_solve))

    def _beta_for(col):
        return solve2(A, A_aug[..., :q, col] / n_eff[..., None])

    cy = _coef(_beta_for(lo.iy), lo.iy, qd, q)
    ct = _coef(_beta_for(lo.it), lo.it, qd, q)

    # final-stage statistics as contractions of the degree-4 tensor
    V6 = state["vg"].reshape(E, k, pf, qd, pf, qd)

    def _quad(ca, cb):
        return jnp.einsum("skaibj,ski,skj->sab", V6, ca, cb)

    def _qvec(ca, cb):
        return jnp.einsum("skaij,ski,skj->sa", V6[:, :, :, :, 0, :], ca, cb)

    def _qscl(ca, cb):
        return jnp.einsum("skij,ski,skj->s", V6[:, :, 0, :, 0, :], ca, cb)

    nseg = jnp.maximum(counts.sum(axis=1), 1.0)
    eye = jnp.eye(pf, dtype=_F32)
    Gtt = _quad(ct, ct)          # Σ rt²·φφᵀ per segment
    bty = _qvec(ct, cy)          # Σ rt·ry·φ
    syy = _qscl(cy, cy)          # Σ ry²

    if lo.iv:
        cz = _coef(_beta_for(lo.iz), lo.iz, qd, q)
        a = _quad(cz, ct) + ridge_final * nseg[:, None, None] * eye
        theta = jax.vmap(det_solve)(a, _qvec(cz, cy))
        meat_base = _quad(cz, cz)   # Σ rz²·φφᵀ — the instrument score Gram
    else:
        a = Gtt + ridge_final * nseg[:, None, None] * eye
        theta = jax.vmap(det_solve)(a, bty)
        meat_base = Gtt

    sse = syy - 2.0 * (theta * bty).sum(-1) + jnp.einsum(
        "sa,sab,sb->s", theta, Gtt, theta)
    sigma2 = jnp.clip(sse, 0.0, None) / nseg
    ainv = jax.vmap(det_inv)(a)
    cov = jnp.einsum("sia,sab,sbj->sij", ainv,
                     sigma2[:, None, None] * meat_base, ainv)
    se = jnp.sqrt(jnp.clip(jnp.diagonal(cov, axis1=1, axis2=2), 0.0, None))
    return {"theta": theta, "se": se, "ate": theta[:, 0]}
