"""repro.store — the persistent, incrementally-updatable effect store.

Every estimator in this repo bottoms out in Gram-additive sufficient
statistics; this package makes that additivity operational for the
daily-refresh workload.  A ``MomentStore`` keeps per-(segment, fold)
nuisance and final-stage moment accumulators for every column of a
``SweepSpec``; ``ingest`` folds each newly arrived row block into them
with one fused/blocked pass over only the new rows (history is never
re-read), and ``refresh`` re-solves thetas/SEs in O(p³) per cell and
emits a fresh ``EffectPanel``.  At canonical row-blocked shapes the
incremental path is *bitwise identical* to a full refit on the
concatenated data (the fixed-order block-fold contract), and versioned
snapshots ride through ``repro.checkpoint`` for hot-swap/rollback.
Coverage is gated by ``store_supported`` (all-ridge DML and OrthoIV
families); unsupported columns fault-isolate as failed panel columns.
"""

from repro.store.stats import ColumnLayout
from repro.store.store import MomentStore, store_supported

__all__ = ["ColumnLayout", "MomentStore", "store_supported"]
