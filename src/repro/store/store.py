"""MomentStore: the persistent, incrementally-updatable effect store.

Lifecycle::

    store = MomentStore(spec, n_features=p, key=key)
    store.ingest(X=day0.X, y=day0.y, t=day0.t, segment_ids=sids0)
    panel_v1 = store.refresh()            # EffectPanel, O(p³) per cell
    store.save(manager)                   # versioned snapshot (v1)
    store.ingest(X=day1.X, ...)           # one pass over ONLY new rows
    panel_v2 = store.refresh()
    store.restore(manager, step=1)        # rollback / hot-swap

Contracts (certified by tests/test_store.py):

  * **Bitwise ingest invariance** — at canonical row-blocked shapes
    (``cfg.row_block = R > 0``, every ingest except the last a
    multiple of R), any partition of the rows into ingest blocks
    yields bit-identical accumulators AND a bit-identical refreshed
    panel to the single-ingest full rebuild.  This follows from the
    fixed-order block-fold of ``moments.blocked_reduce`` seeded with
    the standing accumulator (``init=``) plus the index-keyed fold
    assignment below.  Misaligned ingests and ``row_block = 0`` remain
    correct but only tolerance-equal; alignment is tracked PER COLUMN
    (``store.column_aligned``, plus each refreshed ``ColumnResult``'s
    ``aligned`` flag — one misaligned ingest into one column never
    downgrades a neighbor's reported regime), with ``store.aligned``
    as the all-columns rollup.
  * **Streaming-stable folds** — a row's fold is
    ``randint(fold_in(column_key, global_row_index), k)``: it depends
    only on the row's global arrival index, never on rows that arrive
    later (``crossfit.fold_ids``'s balanced permutation depends on
    total n and would reshuffle history on every ingest).
  * **Coverage gate** — ``store_supported`` admits the all-ridge
    continuous-treatment DML and OrthoIV families, whose estimates are
    exact functionals of the stored moments.  Unsupported columns are
    fault-isolated: they land as failed ``ColumnResult``s with the
    gate's reason, never an exception.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import CausalConfig
from repro.core.final_stage import cate_basis
from repro.core.registry import EstimatorSpec, get_spec
from repro.obs.trace import maybe_span
from repro.store import stats as store_stats
from repro.store.solve import refresh_column
from repro.store.stats import ColumnLayout
from repro.sweep.panel import ColumnResult, EffectPanel
from repro.sweep.spec import SweepSpec

Array = jax.Array
_F32 = jnp.float32


def store_supported(rspec: EstimatorSpec, cfg: CausalConfig
                    ) -> Tuple[bool, str]:
    """Gate: can this column be refreshed exactly from stored moments?

    Returns ``(ok, reason)``.  Admitted: the DML family and the
    OrthoIV family with all-ridge nuisances and continuous treatment —
    every statistic they need is a contraction of the store's Gram
    accumulators.  Excluded: logistic nuisances (per-iteration data
    passes), DRLearner/DRIV/metalearners (per-row pseudo-outcomes and
    clipped propensities are not Gram-additive).
    """
    if rspec.name.startswith("dml") or rspec.name.startswith("orthoiv"):
        iv = rspec.needs_instrument
        if cfg.discrete_treatment:
            return False, (f"store: {rspec.name} with discrete_treatment "
                           "needs a logistic propensity (per-iteration "
                           "data passes); use discrete_treatment=False "
                           "with nuisance_t='ridge'")
        for field, kind in (("nuisance_y", cfg.nuisance_y),
                            ("nuisance_t", cfg.nuisance_t)) + (
                                (("nuisance_z", cfg.nuisance_z),) if iv
                                else ()):
            if kind != "ridge":
                return False, (f"store: {rspec.name} requires "
                               f"{field}='ridge' (got {kind!r}) — only "
                               "ridge normal equations are exact "
                               "functionals of the stored Grams")
        return True, ""
    return False, (f"store: {rspec.name} builds per-row pseudo-outcomes/"
                   "propensities (not Gram-additive); supported families: "
                   "dml*, orthoiv* with all-ridge nuisances")


def _basis_width(p: int, n_features: int) -> int:
    """Width of ``cate_basis(X, n_features)`` for X with p columns."""
    return 1 if n_features <= 1 else 1 + min(n_features - 1, p)


@dataclasses.dataclass
class _Column:
    name: str
    cfg: CausalConfig
    rspec: EstimatorSpec
    layout: Optional[ColumnLayout]
    state: Optional[store_stats.State]
    error: Optional[str]
    aligned: bool = True  # per-column: no misaligned ingest yet


class MomentStore:
    """Per-(segment, fold) sufficient-statistics store over a SweepSpec.

    ``n_features`` fixes the X width up front so every accumulator (and
    the checkpoint template) exists before the first row arrives.
    ``key`` roots the fold-assignment lineage (column i uses
    ``fold_in(key, i)``, mirroring the sweep's ``column_keys``).
    ``data_mesh`` (runtime.distributed.DataMesh) row-shards each
    ingest pass across ("hosts", "devices"): the sharded blocked
    reduction seeds the SAME ordered left fold, so aligned-ingest
    bitwise invariance carries over unchanged in "ordered" mode.
    """

    def __init__(self, spec: SweepSpec, n_features: int,
                 key: Optional[Array] = None, *, tracer=None,
                 data_mesh=None):
        self.spec = spec
        self.n_features = int(n_features)
        self.key = key if key is not None else jax.random.PRNGKey(0)
        self.tracer = tracer
        self.data_mesh = data_mesh
        self.n_total = 0
        self.n_ingests = 0
        self.version = 0
        self.seg_counts = jnp.zeros((spec.n_segments,), _F32)
        self._cols: List[_Column] = []
        self._jit_cache: Dict[Any, Any] = {}
        for name, cfg in spec.columns:
            rspec = get_spec(name)
            ok, reason = store_supported(rspec, cfg)
            if not ok:
                self._cols.append(_Column(name, cfg, rspec, None, None,
                                          reason))
                continue
            layout = ColumnLayout(
                p=self.n_features,
                pf=_basis_width(self.n_features, cfg.cate_features),
                k=cfg.n_folds,
                iv=rspec.needs_instrument,
            )
            state = store_stats.init_state(layout,
                                           spec.n_segments * layout.k)
            self._cols.append(_Column(name, cfg, rspec, layout, state,
                                      None))

    # ------------------------------------------------------------------
    # Alignment regime (per column — one misaligned ingest into one
    # column must not downgrade its neighbors' reported regime)
    # ------------------------------------------------------------------
    @property
    def column_aligned(self) -> Tuple[Optional[bool], ...]:
        """Per-column alignment: True = every ingest of that column
        ended on its ``row_block`` boundary (bitwise-ingest regime),
        False = tolerance regime, None = unsupported column."""
        return tuple(None if c.layout is None else c.aligned
                     for c in self._cols)

    @property
    def aligned(self) -> bool:
        """Store-wide rollup: every supported column still bitwise."""
        return all(c.aligned for c in self._cols if c.layout is not None)

    # ------------------------------------------------------------------
    # Fold lineage
    # ------------------------------------------------------------------
    def column_key(self, col_index: int) -> Array:
        """The fold-assignment key of column ``col_index``."""
        return jax.random.fold_in(self.key, col_index)

    def fold_assignment(self, col_index: int, start: int, n: int) -> Array:
        """Folds of global rows [start, start+n) for one column —
        index-keyed, so a row's fold never depends on later arrivals."""
        col = self._cols[col_index]
        if col.layout is None:
            raise ValueError(col.error)
        return _row_folds(self.column_key(col_index), start, n,
                          col.layout.k)

    # ------------------------------------------------------------------
    # Ingest
    # ------------------------------------------------------------------
    def ingest(self, *, X: Array, y: Array, t: Array, segment_ids: Array,
               z: Optional[Array] = None) -> "MomentStore":
        """Fold a new row block into every supported column's cells.

        One fused/blocked pass per column over ONLY the new rows.
        Empty blocks are exact no-ops on the accumulators (the version
        still advances).  Returns ``self``.
        """
        n = int(X.shape[0])
        if X.ndim != 2 or X.shape[1] != self.n_features:
            raise ValueError(f"store: X must be (n, {self.n_features}), "
                             f"got {X.shape}")
        needs_z = any(c.layout is not None and c.layout.iv
                      for c in self._cols)
        if needs_z and z is None:
            raise ValueError("store: spec has instrumented columns; "
                             "ingest requires z")
        with maybe_span(self.tracer, "store.ingest", cat="store",
                        rows=n, version=self.version + 1):
            if n:
                for i, col in enumerate(self._cols):
                    if col.layout is None:
                        continue
                    rb = col.cfg.row_block
                    if rb > 0 and self.n_total % rb != 0:
                        # prior ingests broke THIS column's block
                        # alignment: still correct, but its bitwise
                        # contract degrades to tolerance from here on
                        # (columns with a different row_block keep
                        # their own regime)
                        col.aligned = False
                    fn = self._ingest_fn(i)
                    args = (col.state, X, t, y, segment_ids,
                            jnp.uint32(self.n_total),
                            self.column_key(i))
                    col.state = fn(*args, z) if col.layout.iv else fn(*args)
                self.seg_counts = self.seg_counts + _seg_counts(
                    segment_ids, self.spec.n_segments)
                self.n_total += n
            self.version += 1
            self.n_ingests += 1
        if self.tracer is not None:
            m = self.tracer.metrics
            m.counter("store.ingests").inc()
            m.counter("store.ingest.rows").inc(n)
            m.gauge("store.version").set(self.version)
        return self

    def _ingest_fn(self, col_index: int):
        col = self._cols[col_index]
        cfg, layout = col.cfg, col.layout
        ck = ("ingest", cfg, self.spec.n_segments, layout)
        fn = self._jit_cache.get(ck)
        if fn is not None:
            return fn
        n_cells = self.spec.n_segments * layout.k
        dm = self.data_mesh

        def _run(state, X, t, y, sids, start, col_key, z=None):
            folds = _row_folds(col_key, start, X.shape[0], layout.k)
            comb = sids.astype(jnp.int32) * layout.k + folds
            phi = cate_basis(X, cfg.cate_features)
            if dm is not None:
                # Activate at trace time: blocked_reduce inside
                # ingest_cells routes each moment pass through
                # dist_reduce on the row mesh.  The per-instance
                # _jit_cache keeps mesh/plain traces separate.
                from repro.runtime.distributed import use_data_mesh

                with use_data_mesh(dm):
                    return store_stats.ingest_cells(
                        layout, state, X, t, y, z, phi, comb, n_cells,
                        row_block=cfg.row_block,
                        strategy=cfg.row_block_strategy)
            return store_stats.ingest_cells(
                layout, state, X, t, y, z, phi, comb, n_cells,
                row_block=cfg.row_block, strategy=cfg.row_block_strategy)

        fn = jax.jit(_run)
        self._jit_cache[ck] = fn
        return fn

    # ------------------------------------------------------------------
    # Refresh
    # ------------------------------------------------------------------
    def refresh(self) -> EffectPanel:
        """Re-solve every column from its accumulators (no data pass)
        and emit the refreshed ``EffectPanel``."""
        with maybe_span(self.tracer, "store.refresh", cat="store",
                        version=self.version, n_total=self.n_total):
            columns = []
            tag = (f"store:v{self.version}",)
            for i, col in enumerate(self._cols):
                if col.layout is None:
                    columns.append(ColumnResult(
                        estimator=col.name, cfg=col.cfg, key_index=i,
                        error=col.error))
                    continue
                out = self._refresh_fn(i)(col.state)
                columns.append(ColumnResult(
                    estimator=col.name, cfg=col.cfg,
                    thetas=out["theta"], ates=out["ate"], ses=out["se"],
                    key_index=i, events=tag, aligned=col.aligned))
            panel = EffectPanel(columns=tuple(columns),
                                counts=self.seg_counts,
                                n_segments=self.spec.n_segments,
                                segment_key=self.spec.segment_key)
        if self.tracer is not None:
            self.tracer.metrics.counter("store.refreshes").inc()
        return panel

    def _refresh_fn(self, col_index: int):
        col = self._cols[col_index]
        cfg, layout = col.cfg, col.layout
        ck = ("refresh", cfg, self.spec.n_segments, layout)
        fn = self._jit_cache.get(ck)
        if fn is None:
            fn = jax.jit(lambda state: refresh_column(
                layout, state, self.spec.n_segments,
                ridge_lambda=cfg.ridge_lambda))
            self._jit_cache[ck] = fn
        return fn

    # ------------------------------------------------------------------
    # Versioned snapshots (checkpoint/)
    # ------------------------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        """The checkpointable pytree: segment counts + per-supported-
        column accumulators (keyed by column index)."""
        d: Dict[str, Any] = {"seg_counts": self.seg_counts}
        for i, col in enumerate(self._cols):
            if col.state is not None:
                d[f"col{i}"] = col.state
        return d

    def _meta(self) -> Dict[str, Any]:
        return {
            "n_total": self.n_total,
            "n_ingests": self.n_ingests,
            "aligned": self.aligned,
            "column_aligned": list(self.column_aligned),
            "n_features": self.n_features,
            "n_segments": self.spec.n_segments,
            "segment_key": self.spec.segment_key,
            "columns": [c.name for c in self._cols],
        }

    def save(self, manager, *, metric: Optional[float] = None) -> int:
        """Snapshot the store at its current version through a
        ``checkpoint.CheckpointManager`` (atomic tmp+rename).  Returns
        the step (= version) written."""
        manager.save(self.version, self.state_dict(), metric=metric,
                     extra=self._meta())
        return self.version

    def restore(self, manager, *, step: Optional[int] = None
                ) -> "MomentStore":
        """Hot-swap/rollback: replace the accumulators with snapshot
        ``step`` (latest if None).  Spec provenance is checked so a
        checkpoint from a different column set fails loudly."""
        state, meta = manager.restore(self.state_dict(), step=step)
        extra = meta.get("extra", {})
        want = [c.name for c in self._cols]
        if extra.get("columns") != want:
            raise ValueError(
                f"store: checkpoint columns {extra.get('columns')} do not "
                f"match this spec's {want}")
        if extra.get("n_features") != self.n_features:
            raise ValueError(
                f"store: checkpoint n_features {extra.get('n_features')} "
                f"!= {self.n_features}")
        self.seg_counts = state["seg_counts"]
        for i, col in enumerate(self._cols):
            if col.state is not None:
                col.state = state[f"col{i}"]
        self.version = int(meta["step"])
        self.n_total = int(extra.get("n_total", 0))
        self.n_ingests = int(extra.get("n_ingests", 0))
        # per-column flags when present; older snapshots carried only
        # the store-wide bool, which broadcasts conservatively
        col_aligned = extra.get(
            "column_aligned",
            [bool(extra.get("aligned", True))] * len(self._cols))
        for col, flag in zip(self._cols, col_aligned):
            if col.layout is not None:
                col.aligned = bool(flag)
        return self


def _row_folds(col_key: Array, start, n: int, k: int) -> Array:
    idx = jnp.asarray(start, jnp.uint32) + jnp.arange(n, dtype=jnp.uint32)
    keys = jax.vmap(lambda i: jax.random.fold_in(col_key, i))(idx)
    return jax.vmap(
        lambda kk: jax.random.randint(kk, (), 0, k))(keys).astype(jnp.int32)


def _seg_counts(sids: Array, n_segments: int) -> Array:
    return jax.ops.segment_sum(jnp.ones((sids.shape[0],), _F32),
                               sids.astype(jnp.int32),
                               num_segments=n_segments)
