"""ServingPanel: the immutable scoring artifact of one panel version.

A server never scores against a live ``EffectPanel`` — it scores
against a *prepared* snapshot of one estimator column: the per-segment
effect coefficients, their standard errors, and the per-segment
validity mask, stamped with the version they came from.  Preparing the
artifact once (gather, dtype-fix, ok-mask materialization) keeps the
hot path free of host-side panel plumbing, and making it immutable is
what makes hot-swap atomic: installing a new version is one reference
assignment, and every in-flight wave keeps the reference it captured.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array
_F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class ServingPanel:
    """One servable panel version: column ``column`` of an EffectPanel.

    thetas / ses are (E, pf) per-segment effect coefficients and their
    standard errors; ``ok`` is the (E,) per-segment validity mask
    (zero-row or non-finite cells serve flagged responses, never NaN).
    ``aligned`` carries the store column's ingest regime (None for
    sweep-fitted panels); ``version`` is the store/checkpoint version
    the estimates came from.
    """

    thetas: Array  # (E, pf)
    ses: Array  # (E, pf)
    ok: Array  # (E,) bool
    n_features: int  # expected request feature width p
    cate_features: int  # pf of phi(x) (1 => constant effect)
    version: int = 0
    column: str = ""  # estimator name, provenance only
    aligned: Optional[bool] = None

    @property
    def n_segments(self) -> int:
        """Number of segments E this panel serves."""
        return int(self.thetas.shape[0])

    @classmethod
    def from_effect_panel(
        cls,
        panel,
        *,
        n_features: int,
        column: int = 0,
        version: int = 0,
    ) -> "ServingPanel":
        """Prepare column ``column`` of ``panel`` for serving.

        Fails loudly on a failed column — a server must not silently
        serve a column that carries no estimates.
        """
        col = panel.columns[column]
        if col.failed or col.thetas is None:
            raise ValueError(
                f"serve: column {column} ({col.estimator!r}) failed and "
                f"carries no estimates: {col.error}"
            )
        thetas = jnp.asarray(col.thetas, _F32)
        if col.ses is not None:
            ses = jnp.asarray(col.ses, _F32)
        else:
            ses = jnp.zeros_like(thetas)
        return cls(
            thetas=thetas,
            ses=ses,
            ok=col.ok(panel.counts),
            n_features=int(n_features),
            cate_features=int(thetas.shape[1]),
            version=int(version),
            column=col.estimator,
            aligned=col.aligned,
        )


def panel_from_checkpoint(
    manager,
    spec,
    n_features: int,
    *,
    key=None,
    column: int = 0,
    step: Optional[int] = None,
    store=None,
    tracer=None,
) -> ServingPanel:
    """Load a servable panel version from a ``MomentStore`` snapshot.

    Builds a store shell for ``spec`` (or reuses ``store`` — a warm
    shell keeps its refresh jit cache, which is what makes a periodic
    hot-swap loop recompile-free), restores snapshot ``step`` (latest
    if None) through ``repro.checkpoint`` — inheriting the store's
    provenance checks, so a snapshot from a different column set or
    feature width fails loudly — then refreshes and prepares column
    ``column``.  This is the ingest → refresh → serve hot-swap edge:
    the PR-8 daily-ingest loop writes versions, the server pulls them.
    """
    from repro.store import MomentStore

    if store is None:
        store = MomentStore(spec, n_features=n_features, key=key, tracer=tracer)
    store.restore(manager, step=step)
    return ServingPanel.from_effect_panel(
        store.refresh(),
        n_features=n_features,
        column=column,
        version=store.version,
    )
