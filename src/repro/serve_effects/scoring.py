"""The jitted scoring kernel: ``phi(x) · thetas[segment]`` per row.

Structure is everything here:

  * The batch scorer is ``vmap`` of a single-row scorer.  Every
    statistic of row i (basis build, theta gather, effect dot product,
    SE band) involves ONLY row i, so batching cannot change any row's
    bits — which is what certifies (a) padded slots as no-ops and
    (b) wave-batched scoring ≡ per-request unbatched scoring, bitwise
    (tests/test_serve_effects.py, at the canonical wave shapes).
  * Padded slots follow the ``seg_gram`` convention: ``sid = -1``.
    An out-of-range segment id scores against clamped index 0 but is
    masked ``ok = False`` and zeroed on the way out, exactly like the
    kernel's ``seg=-1/w=0`` rows.
  * Failed panel cells (``ok[sid] = False`` — zero-row segments,
    non-finite solves) return a *flagged* response: ``ok = False`` and
    zeroed effect/CI fields, never NaN — NaN thetas are masked out by
    the same ``where``.

CI bands are analytic from the stored per-coefficient SEs under the
diagonal approximation ``se(phi·theta)² ≈ Σ_a phi_a² se_a²`` (the
panel stores SEs, not full covariance; for ``pf = 1`` — the common
ATE-per-segment panel — this is exact).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

Array = jax.Array
_F32 = jnp.float32


def _row_phi(x: Array, pf: int) -> Array:
    """phi of ONE row: [1] or [1, x_0..x_{pf-2}] — cate_basis, unbatched."""
    one = jnp.ones((1,), _F32)
    if pf <= 1:
        return one
    return jnp.concatenate([one, x[: pf - 1].astype(_F32)])


def _score_row(
    thetas: Array,
    ses: Array,
    ok: Array,
    x: Array,
    sid: Array,
    z: Array,
) -> Dict[str, Array]:
    """Score one request against one panel version (all scalars out)."""
    n_segments = thetas.shape[0]
    valid = (sid >= 0) & (sid < n_segments)
    s = jnp.clip(sid, 0, n_segments - 1)
    phi = _row_phi(x, thetas.shape[1])
    cate = (phi * thetas[s]).sum()
    band = jnp.sqrt(jnp.clip((phi * phi * ses[s] * ses[s]).sum(), 0.0, None))
    good = valid & ok[s] & jnp.isfinite(cate)
    zero = jnp.zeros((), _F32)
    return {
        "cate": jnp.where(good, cate, zero),
        "lo": jnp.where(good, cate - z * band, zero),
        "hi": jnp.where(good, cate + z * band, zero),
        "se": jnp.where(good, band, zero),
        "ok": good,
    }


def score_rows(
    thetas: Array,
    ses: Array,
    ok: Array,
    X: Array,
    sids: Array,
    z: Array,
) -> Dict[str, Array]:
    """Score a wave: X (W, p), sids (W,) int32 (-1 = padded slot).

    A ``vmap`` of the row scorer — see the module docstring for why
    that shape is the certification.  Returns (W,) arrays.
    """
    fn = jax.vmap(_score_row, in_axes=(None, None, None, 0, 0, None))
    return fn(thetas, ses, ok, X, sids, z)


# jit caches on shapes: one compile per (wave size, panel shape) pair —
# the server's fixed wave-size ladder makes that a small closed set,
# and hot-swapping to a same-shape refreshed panel reuses the compile.
_score_rows_jit = jax.jit(score_rows)
_score_row_jit = jax.jit(_score_row)


def score_batch(panel, X: Array, sids: Array, z: float) -> Dict[str, Array]:
    """Jitted wave entry point used by the server: panel is a
    ``ServingPanel``; z the CI critical value."""
    return _score_rows_jit(
        panel.thetas,
        panel.ses,
        panel.ok,
        jnp.asarray(X, _F32),
        jnp.asarray(sids, jnp.int32),
        jnp.asarray(z, _F32),
    )


def score_single(panel, x: Array, segment_id: int, z: float) -> Dict[str, Array]:
    """Unbatched reference scorer: ONE request, no wave, no padding —
    the bitwise yardstick batched serving is certified against."""
    return _score_row_jit(
        panel.thetas,
        panel.ses,
        panel.ok,
        jnp.asarray(x, _F32),
        jnp.asarray(segment_id, jnp.int32),
        jnp.asarray(z, _F32),
    )
