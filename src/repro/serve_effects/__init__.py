"""repro.serve_effects — the online effect-serving layer.

The estimation side of the repo (sweep / store) fits ONCE into an
``EffectPanel``; an industrial deployment then has to *serve* those
effects to product traffic — per-user CATE/uplift lookups at high QPS
(the Netflix "Computational Causal Inference" framing: effect-serving
is a first-class production workload, not an afterthought of fitting).

Three pieces:

  ``ServingPanel``  the immutable scoring artifact of one panel
                    version — per-segment thetas/SEs/validity gathered
                    out of an ``EffectPanel`` (from ``repro.sweep`` or
                    ``MomentStore.refresh()``), loadable from a
                    ``repro.checkpoint`` snapshot with the store's
                    provenance checks (``panel_from_checkpoint``);
  ``scoring``       the jitted wave scorer — ``phi(x) · thetas[sid]``
                    per row with analytic CI bands from the stored
                    SEs; batching is a ``vmap`` of the row scorer, so
                    padded slots are certified no-ops and batched
                    scoring is bitwise the unbatched row score;
  ``EffectServer``  the admission queue + continuous wave batching +
                    versioned hot-swap server: requests coalesce into
                    a small fixed ladder of jit shapes (pad-and-mask,
                    ``sid = -1`` padding like ``seg_gram``'s
                    ``seg=-1`` rows), every wave scores against
                    exactly ONE panel version, ``swap``/``rollback``
                    exchange refreshed versions between waves, and a
                    per-server ``MetricsRegistry`` (never the process
                    global) carries the p50/p99 latency, wave, and
                    occupancy histograms.

See README "Serving" and docs/ARCHITECTURE.md for the store → serve
dataflow; ``benchmarks/bench_serve.py`` gates latency/throughput in CI.
"""

from repro.serve_effects.panel import ServingPanel, panel_from_checkpoint
from repro.serve_effects.scoring import score_rows, score_single
from repro.serve_effects.server import (
    EffectServer,
    QueueFull,
    Request,
    Response,
    Ticket,
)

__all__ = [
    "EffectServer",
    "QueueFull",
    "Request",
    "Response",
    "ServingPanel",
    "Ticket",
    "panel_from_checkpoint",
    "score_rows",
    "score_single",
]
