"""EffectServer: admission queue + continuous wave batching + hot-swap.

The serving loop mirrors ``launch/serve.py``'s ``BatchServer`` wave
pattern, translated to effect scoring:

  * Requests enter a bounded admission queue (``submit``; a full queue
    raises ``QueueFull`` — backpressure is explicit, never silent
    drops).
  * ``step()`` drains one *wave*: up to ``max(wave_sizes)`` requests,
    padded to the smallest configured wave size that fits.  The wave
    ladder is the whole anti-recompile story — every wave hits one of
    ``len(wave_sizes)`` jit shapes, so steady-state serving runs zero
    compiles regardless of traffic shape.  Padded slots carry
    ``sid = -1`` and are certified no-ops (scoring is a vmap of a row
    scorer; see ``scoring``).
  * Each wave captures ONE ``ServingPanel`` reference at entry: a
    ``swap()`` arriving mid-queue affects the *next* wave, so no
    request is ever scored against a mix of versions and no in-flight
    wave is dropped.  ``swap`` keeps the outgoing version on a history
    stack; ``rollback()`` re-installs it — the rollback path of the
    store's versioned snapshots, one reference assignment away.
  * Observability is per-server: a ``MetricsRegistry`` owned by the
    server (NEVER ``obs.metrics.default_registry()`` — two servers in
    one process must not share a latency histogram) records
    request-latency / wave-latency / batch-occupancy histograms and
    queue/version gauges, and an optional ``Tracer`` wraps every wave
    in a ``serve.wave`` span.

The loop is synchronous and single-threaded by design (drive it with
``step()``/``drain()``/``score()``): determinism is a test contract
here, and the paper's serving analogue is wave-at-a-time anyway.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.inference.intervals import z_crit
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import maybe_span
from repro.serve_effects.panel import ServingPanel
from repro.serve_effects.scoring import score_batch


class QueueFull(RuntimeError):
    """Raised by ``submit`` when the admission queue is at capacity."""


@dataclasses.dataclass(frozen=True)
class Request:
    """One scoring request: a feature row and its segment id."""

    x: np.ndarray  # (p,) features
    segment_id: int


@dataclasses.dataclass
class Response:
    """One scored effect: point estimate, CI band, validity, lineage."""

    cate: float
    lo: float
    hi: float
    se: float
    ok: bool  # False => flagged (failed cell / bad segment id)
    version: int  # the ONE panel version this request scored on
    latency_s: float  # submit -> response, block_until_ready-honest


@dataclasses.dataclass
class Ticket:
    """Queue handle returned by ``submit``; ``response`` fills on the
    wave that serves it."""

    request: Request
    submitted_at: float
    response: Optional[Response] = None

    @property
    def done(self) -> bool:
        """Whether the owning wave has completed."""
        return self.response is not None


class EffectServer:
    """Wave-batched CATE/uplift scorer over versioned ServingPanels."""

    def __init__(
        self,
        panel: ServingPanel,
        *,
        wave_sizes: Sequence[int] = (8, 64),
        max_queue: int = 1024,
        alpha: float = 0.05,
        registry: Optional[MetricsRegistry] = None,
        tracer=None,
    ):
        if not wave_sizes or any(w < 1 for w in wave_sizes):
            raise ValueError(f"serve: bad wave_sizes {wave_sizes!r}")
        self._panel = panel
        self._history: List[ServingPanel] = []
        self.wave_sizes: Tuple[int, ...] = tuple(sorted(set(wave_sizes)))
        self.max_queue = int(max_queue)
        self.alpha = float(alpha)
        self._z = z_crit(alpha)
        self._queue: Deque[Ticket] = deque()
        self.metrics = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer

    # ------------------------------------------------------------------
    # Panel versions
    # ------------------------------------------------------------------
    @property
    def panel(self) -> ServingPanel:
        """The panel version the NEXT wave will score against."""
        return self._panel

    @property
    def version(self) -> int:
        """Version id of the currently installed panel."""
        return self._panel.version

    def swap(self, panel: ServingPanel) -> None:
        """Atomically install a refreshed panel version.

        One reference assignment between waves: queued requests score
        against the new version from the next ``step()`` on, the wave
        in flight (if ``swap`` is called from a tracer callback or
        another thread) keeps the reference it captured, and the
        outgoing version lands on the rollback stack.
        """
        self._history.append(self._panel)
        self._panel = panel
        self.metrics.counter("serve.swaps").inc()
        self.metrics.gauge("serve.panel_version").set(panel.version)

    def rollback(self) -> ServingPanel:
        """Re-install the previous panel version (raises when there is
        no history); returns the version rolled back TO."""
        if not self._history:
            raise RuntimeError("serve: no panel version to roll back to")
        self._panel = self._history.pop()
        self.metrics.counter("serve.rollbacks").inc()
        self.metrics.gauge("serve.panel_version").set(self._panel.version)
        return self._panel

    # ------------------------------------------------------------------
    # Admission
    # ------------------------------------------------------------------
    @property
    def queue_depth(self) -> int:
        """Requests admitted but not yet served."""
        return len(self._queue)

    def submit(self, x, segment_id: int) -> Ticket:
        """Admit one request; raises ``QueueFull`` at capacity."""
        if len(self._queue) >= self.max_queue:
            self.metrics.counter("serve.rejected").inc()
            raise QueueFull(f"serve: admission queue at capacity ({self.max_queue})")
        x = np.asarray(x, np.float32)
        if x.shape != (self._panel.n_features,):
            raise ValueError(
                f"serve: request x must be ({self._panel.n_features},), got {x.shape}"
            )
        ticket = Ticket(
            Request(x=x, segment_id=int(segment_id)),
            submitted_at=time.perf_counter(),
        )
        self._queue.append(ticket)
        self.metrics.counter("serve.requests").inc()
        self.metrics.gauge("serve.queue_depth").set(len(self._queue))
        return ticket

    # ------------------------------------------------------------------
    # The wave loop
    # ------------------------------------------------------------------
    def _wave_shape(self, n: int) -> int:
        """Smallest configured wave size that fits n requests."""
        for w in self.wave_sizes:
            if n <= w:
                return w
        return self.wave_sizes[-1]

    def step(self) -> List[Ticket]:
        """Serve one wave; empty queue is a free no-op.

        Pops up to ``max(wave_sizes)`` requests, pads to the chosen jit
        shape, scores them against the panel version captured at wave
        entry, and fills each ticket's ``Response``.
        """
        if not self._queue:
            return []
        panel = self._panel  # ONE version for this whole wave
        cap = self.wave_sizes[-1]
        wave = [self._queue.popleft() for _ in range(min(len(self._queue), cap))]
        n = len(wave)
        w = self._wave_shape(n)
        with maybe_span(
            self.tracer,
            "serve.wave",
            cat="serve",
            wave_size=w,
            fill=n,
            version=panel.version,
        ):
            t0 = time.perf_counter()
            X = np.zeros((w, panel.n_features), np.float32)
            sids = np.full((w,), -1, np.int32)  # seg_gram's pad id
            for i, t in enumerate(wave):
                X[i] = t.request.x
                sids[i] = t.request.segment_id
            out = score_batch(panel, X, sids, self._z)
            out = {k: np.asarray(v) for k, v in jax.block_until_ready(out).items()}
            t1 = time.perf_counter()
        for i, t in enumerate(wave):
            lat = t1 - t.submitted_at
            t.response = Response(
                cate=float(out["cate"][i]),
                lo=float(out["lo"][i]),
                hi=float(out["hi"][i]),
                se=float(out["se"][i]),
                ok=bool(out["ok"][i]),
                version=panel.version,
                latency_s=lat,
            )
            self.metrics.histogram("serve.request_seconds").observe(lat)
        m = self.metrics
        m.counter("serve.waves").inc()
        m.counter("serve.scored").inc(n)
        m.histogram("serve.wave_seconds").observe(t1 - t0)
        m.histogram("serve.batch_occupancy").observe(n / w)
        m.gauge("serve.queue_depth").set(len(self._queue))
        return wave

    def drain(self) -> List[Ticket]:
        """Run waves until the queue is empty; returns served tickets."""
        served: List[Ticket] = []
        while self._queue:
            served.extend(self.step())
        return served

    def score(self, X, segment_ids) -> List[Response]:
        """Synchronous burst convenience: submit every row of ``X``
        through the admission queue (draining whenever it fills) and
        return the responses in request order."""
        X = np.asarray(X, np.float32)
        sids = np.asarray(segment_ids)
        tickets: List[Ticket] = []
        for i in range(X.shape[0]):
            if len(self._queue) >= self.max_queue:
                self.drain()
            tickets.append(self.submit(X[i], int(sids[i])))
        self.drain()
        return [t.response for t in tickets]

    # ------------------------------------------------------------------
    # Observability
    # ------------------------------------------------------------------
    def snapshot(self) -> Dict[str, Dict]:
        """The server's metrics snapshot (plain JSON scalars)."""
        return self.metrics.snapshot()
