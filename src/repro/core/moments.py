"""Streaming sufficient-statistics engine — the single estimation
substrate shared by nuisance fits, the orthogonal final stage, and
replicate inference.

Every estimator in this codebase bottoms out in weighted Gram-shaped
moments: ridge/logistic normal equations, the leave-one-out fold Grams
of cross-fitting, the Neyman-orthogonal final stage, and the
reweighted refits of bootstrap/jackknife inference.  Wong's
*Computational Causal Inference* argues that condensing estimation to
such sufficient statistics is the path to industrial scale; More et
al. (2409.02332) stream DML in row chunks.  This module is both ideas
as one API: compute ``Σ_n w_n · d_n d_nᵀ`` (and friends) over a row
design ``d`` with a *fixed block decomposition* and two evaluation
strategies.

Memory model
------------
  row_block = 0   one whole-array block — the legacy einsum forms
                  verbatim (fastest when (n, q) activations fit in a
                  single allocation; the default).
  row_block = R   rows are zero-padded to a multiple of R and reduced
                  block-by-block in FIXED left-to-right order:

      strategy "whole"    every block partial materializes at once
                          (an unbatched per-block lax.map + an ordered
                          fold) — peak memory ~ O(n·q + B·q²);
      strategy "chunked"  ``lax.scan`` streams one dynamic-sliced
                          block at a time, each block constrained on
                          the ``rows`` mesh axis — peak memory
                          ~ O(R·q + q²).  n is no longer bounded by a
                          single dense allocation: the actual
                          "industrial scale" claim.
      strategy "pallas"   the fused mask→weight→residualize→accumulate
                          kernel (repro.kernels.seg_gram): one HBM
                          pass per form — compiled mosaic on TPU, a
                          fused XLA scatter/matmul lowering elsewhere,
                          interpret mode for certification.  Every
                          dense-weight form now has a fused builder
                          (``fold_weighted_gram`` via the kron
                          builder, ``weighted_gram_and_vec`` via the
                          augmented two-weight builder); a residual
                          pallas→chunked fallback rung remains for
                          not-yet-fused future forms and is counted per
                          form on obs metrics.  Parity with "chunked"
                          is tolerance-certified (≤1e-6
                          estimator-wide, conformance suite), not
                          bitwise.

Bit-identity contract
---------------------
For equal ``row_block`` the two strategies are bit-identical *by
construction* (tests/test_moments.py asserts exact equality):

  * identical block decomposition and zero-row padding (padded rows
    carry zero weight / zero design entries, which contribute exactly
    0.0 to every accumulator);
  * identical per-block einsum forms — the augmented-Gram vocabulary
    of ``repro.inference.numerics``: cross-moments are read off
    appended design columns, NEVER the thin ``ni,n->i`` shape class,
    whose reduction XLA reassociates under fusion (measured: the thin
    form breaks chunked-vs-whole equality, the augmented form does
    not);
  * identical left-fold reduction order over blocks (a ``lax.scan``
    accumulation in both strategies).

Different ``row_block`` values commute only up to float reassociation;
estimator-level invariance across settings is asserted with tight
tolerances, not bitwise.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import constrain

Array = jax.Array


def resolve_row_block(n: int, row_block: Optional[int]) -> int:
    """0 means "one whole-array block" (legacy forms); any R >= n
    collapses to the same thing."""
    r = int(row_block or 0)
    return 0 if r <= 0 or r >= n else r


def _seg_ops():
    """The fused-kernel dispatch (lazy: kernels are optional at import
    time for forms that never take the pallas strategy)."""
    from repro.kernels.seg_gram import ops as sg_ops
    return sg_ops


def _use_pallas(n: int, row_block: int, strategy: Optional[str]) -> bool:
    """strategy="pallas" engages on the blocked path (row_block > 0),
    mirroring the chunked/whole semantics; row_block=0 keeps the legacy
    whole-array forms byte-for-byte."""
    return strategy == "pallas" and resolve_row_block(n, row_block) > 0


def _active_data_mesh():
    """The trace-time DataMesh, if ``repro.runtime.distributed`` has
    been imported AND a ``use_data_mesh`` context is active.  The
    sys.modules probe keeps core.moments free of any runtime-layer
    import: a mesh can only be active if the module that activates it
    is already loaded."""
    import sys
    rd = sys.modules.get("repro.runtime.distributed")
    return None if rd is None else rd.current_data_mesh()


def design(X: Array, *, intercept: bool = False,
           append: Optional[Array] = None) -> Array:
    """Assemble the per-(block-)row design ``[X | 1? | append?]`` in
    fp32.  ``append`` (a target / residual column) is how cross-moments
    ride inside a Gram — the replicate-invariant trick from
    repro.inference.numerics."""
    f32 = jnp.float32
    cols = [X.astype(f32)]
    if intercept:
        cols.append(jnp.ones((X.shape[0], 1), f32))
    if append is not None:
        a = append.astype(f32)
        cols.append(a[:, None] if a.ndim == 1 else a)
    return cols[0] if len(cols) == 1 else jnp.concatenate(cols, axis=1)


def blocked_reduce(block_fn: Callable[..., Any], arrays: Sequence[Array],
                   *, row_block: int = 0, strategy: Optional[str] = None,
                   rules=None, pad_values: Optional[Sequence] = None,
                   init: Optional[Any] = None, form: str = "") -> Any:
    """Reduce ``block_fn`` over row blocks of the leading axis.

    ``block_fn(*blocks) -> pytree`` must be row-additive AND must map
    zero-padded rows to exactly-zero contributions (every Gram-shaped
    form here does: padded rows carry zero weights / zero one-hot rows
    / zero design entries).  ``pad_values`` overrides the per-array
    padding constant (e.g. -1 for integer fold ids so their one-hot is
    the zero row).

    row_block == 0 evaluates ``block_fn`` once on the whole arrays —
    the legacy path, byte-for-byte.  Otherwise the same fixed
    decomposition is reduced left-to-right either all-at-once
    ("whole") or streamed ("chunked"); see the module docstring for
    the bit-identity contract.

    ``init`` seeds the left-fold accumulator (same pytree structure as
    ``block_fn``'s output) instead of zeros — the incremental-refresh
    hook of ``repro.store``: folding new rows on top of a standing
    accumulator replays the EXACT addition sequence a one-shot pass
    over the concatenated rows would run, **provided every earlier
    ingest ended on a ``row_block`` boundary** (otherwise the block
    decomposition shifts and identity holds only up to float
    reassociation).  On the ``row_block == 0`` path ``init`` is added
    to the whole-array result — correct, but only tolerance-equal to a
    one-shot pass.

    ``form`` labels the moment form for the fallback-ladder counter:
    when ``strategy="pallas"`` reaches this function (no fused
    seg_gram builder for the form), the downgrade to "chunked" is
    counted on ``obs.metrics.default_registry()`` as
    ``seg_gram.fallback[<form>]`` — a trace-time event (jit-cached
    calls do not re-count).
    """
    arrays = tuple(arrays)
    n = arrays[0].shape[0]
    tmap = jax.tree_util.tree_map
    r = resolve_row_block(n, row_block)
    if r == 0:
        out = block_fn(*arrays)
        return out if init is None else tmap(jnp.add, init, out)
    strategy = strategy or "chunked"
    if strategy == "pallas":
        # the fallback ladder (pallas → chunked → whole): forms without
        # a fused seg_gram builder stream chunked — same bits as the
        # reference the pallas forms are certified against.  Counted so
        # the remaining fusion gap stays observable (ROADMAP item).
        from repro.obs.metrics import default_registry
        default_registry().counter(
            f"seg_gram.fallback[{form or 'unlabeled'}]").inc()
        strategy = "chunked"
    dm = _active_data_mesh()
    if dm is not None:
        # row-sharded reduction over the active data mesh: the block
        # axis splits across ("hosts", "devices") and the ordered mode
        # replays this function's exact left-fold addition sequence —
        # bitwise the chunked/whole result (runtime.distributed)
        from repro.runtime.distributed import dist_reduce
        return dist_reduce(block_fn, arrays, row_block=r, dm=dm,
                           pad_values=pad_values, init=init)
    pad = (-n) % r
    if pad:
        pv = pad_values or (0,) * len(arrays)
        arrays = tuple(
            jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1),
                    constant_values=v)
            for a, v in zip(arrays, pv))
    nb = (n + pad) // r
    if strategy == "whole":
        blocks = tuple(
            constrain(a.reshape((nb, r) + a.shape[1:]),
                      ("row_block", "rows") + (None,) * (a.ndim - 1), rules)
            for a in arrays)
        # lax.map, NOT vmap: each block partial comes from the SAME
        # unbatched per-block graph the chunked strategy traces, so
        # chunked ≡ whole is structural — a vmapped block program's
        # einsums can retile under batching (measured: the p=1 meat
        # with no weight operand), which would break the contract
        # data-dependently.  All partials still materialize at once,
        # which is this strategy's memory signature.
        parts = lax.map(lambda bs: block_fn(*bs), blocks)
        acc0 = (tmap(lambda x: jnp.zeros(x.shape[1:], x.dtype), parts)
                if init is None else init)
        out, _ = lax.scan(lambda acc, g: (tmap(jnp.add, acc, g), None),
                          acc0, parts)
        return out
    if strategy != "chunked":
        raise ValueError(f"unknown strategy {strategy!r} "
                         "(expected whole | chunked | pallas)")

    def step(acc, i):
        blks = tuple(
            constrain(lax.dynamic_slice_in_dim(a, i * r, r, axis=0),
                      ("rows",) + (None,) * (a.ndim - 1), rules)
            for a in arrays)
        return tmap(jnp.add, acc, block_fn(*blks)), None

    if init is None:
        shapes = jax.eval_shape(
            block_fn, *[jax.ShapeDtypeStruct((r,) + a.shape[1:], a.dtype)
                        for a in arrays])
        acc0 = tmap(lambda s: jnp.zeros(s.shape, s.dtype), shapes)
    else:
        acc0 = init
    out, _ = lax.scan(step, acc0, jnp.arange(nb, dtype=jnp.int32))
    return out


# ---------------------------------------------------------------------------
# Weighted moments (ridge / logistic normal equations, HC0 meats).
# ---------------------------------------------------------------------------

def weighted_gram(X: Array, w: Array, *, intercept: bool = False,
                  append: Optional[Array] = None, row_block: int = 0,
                  strategy: Optional[str] = None, rules=None
                  ) -> Tuple[Array, Array]:
    """``G = Σ_n w_n d_n d_nᵀ`` over ``d = [X | 1? | append?]`` plus
    ``n_eff = Σ_n w_n`` from the same blocked reduction.  With
    ``append=y``, the cross-moment ``Σ w·d·y`` is ``G[:, -1]``."""
    if _use_pallas(X.shape[0], row_block, strategy):
        D = design(X, intercept=intercept, append=append)
        G = _seg_ops().design_gram(D, w=w, row_block=row_block)
        return G, w.astype(jnp.float32).sum()
    if append is None:
        def block(Xb, wb):
            D = design(Xb, intercept=intercept)
            ws = wb.astype(jnp.float32)
            return jnp.einsum("ni,n,nj->ij", D, ws, D), ws.sum()
        return blocked_reduce(block, (X, w), row_block=row_block,
                              strategy=strategy, rules=rules,
                              form="weighted_gram")

    def block(Xb, ab, wb):
        D = design(Xb, intercept=intercept, append=ab)
        ws = wb.astype(jnp.float32)
        return jnp.einsum("ni,n,nj->ij", D, ws, D), ws.sum()

    return blocked_reduce(block, (X, append, w), row_block=row_block,
                          strategy=strategy, rules=rules,
                          form="weighted_gram")


def weighted_gram_and_vec(X: Array, wg: Array, v: Array, *,
                          intercept: bool = False, row_block: int = 0,
                          strategy: Optional[str] = None, rules=None
                          ) -> Tuple[Array, Array, Array]:
    """One blocked pass returning ``(G = Σ wg_n d_n d_nᵀ,
    u = Σ v_n d_n, n_eff = Σ wg_n)`` — Gram and cross-moment with
    *different* row weights sharing a single read of X (the logistic
    Newton step: Hessian weights s, gradient residuals r).

    Two regimes for the cross-moment:

      row_block = 0  the thin ``ni,n->i`` mat-vec — the legacy form,
                     byte-for-byte, and half the FLOPs of a second
                     Gram (this is the benchmarked hot path: 16 Newton
                     iterations per logistic fit);
      row_block > 0  ``Σ v_n da_n`` read off the trailing all-ones
                     column of a SECOND v-weighted Gram over
                     ``da = [d | 1]``.  The thin mat-vec compiles to
                     DIFFERENT reduction tilings inside the chunked
                     scan body vs the whole lax.map body (measured:
                     x_learner's blocked propensity fit), so only the
                     augmented-Gram form keeps chunked ≡ whole exact
                     on the blocked path.

    Neither form is certified batch-invariant under an executor's
    replicate vmap — replicate closures read gradients off augmented
    Grams in inference.numerics instead."""
    if _use_pallas(X.shape[0], row_block, strategy):
        D = design(X, intercept=intercept)
        G, u = _seg_ops().gram_and_vec(D, wg, v, row_block=row_block)
        # n_eff through the same blocked left fold as the chunked path
        # (a whole-array sum reassociates) — bitwise, like fold_gram's
        # counts: plain sums stay strategy-independent.
        n_eff = blocked_reduce(lambda wb: wb.astype(jnp.float32).sum(),
                               (wg,), row_block=row_block)
        return G, u, n_eff
    if resolve_row_block(X.shape[0], row_block) == 0:
        D = design(X, intercept=intercept)
        ws = wg.astype(jnp.float32)
        return (jnp.einsum("ni,n,nj->ij", D, ws, D),
                jnp.einsum("ni,n->i", D, v.astype(jnp.float32)),
                ws.sum())

    def block(Xb, wb, vb):
        D = design(Xb, intercept=intercept)
        Da = D if intercept else design(Xb, intercept=True)
        ws = wb.astype(jnp.float32)
        Gv = jnp.einsum("ni,n,nj->ij", Da, vb.astype(jnp.float32), Da)
        return (jnp.einsum("ni,n,nj->ij", D, ws, D),
                Gv[: D.shape[1], -1],
                ws.sum())

    return blocked_reduce(block, (X, wg, v), row_block=row_block,
                          strategy=strategy, rules=rules,
                          form="weighted_gram_and_vec")


# ---------------------------------------------------------------------------
# Fold-segmented moments (the leave-one-out identity of cross-fitting:
# Xᵀdiag(w_k)X = G_total - G_heldout_k needs one segmented pass).
# ---------------------------------------------------------------------------

def fold_gram(X: Array, folds: Array, k: int, *, intercept: bool = False,
              append: Optional[Array] = None, row_block: int = 0,
              strategy: Optional[str] = None, rules=None
              ) -> Tuple[Array, Array]:
    """One-pass fold-segmented Gram: ``Gh[k] = Σ_{n in fold k} d_n d_nᵀ``
    (k, q, q) plus per-fold row counts (k,).  Integer fold ids are
    padded with -1 so padded rows one-hot to the zero row."""
    if _use_pallas(X.shape[0], row_block, strategy):
        D = design(X, intercept=intercept, append=append)
        return _seg_ops().fold_design_gram(D, folds, k,
                                           row_block=row_block)

    def block(Xb, fb, *rest):
        D = design(Xb, intercept=intercept,
                   append=rest[0] if rest else None)
        oh = jax.nn.one_hot(fb, k, dtype=jnp.float32)
        return jnp.einsum("nk,ni,nj->kij", oh, D, D), oh.sum(0)

    arrays = (X, folds) + (() if append is None else (append,))
    pad_values = (0, -1) + (() if append is None else (0,))
    return blocked_reduce(block, arrays, row_block=row_block,
                          strategy=strategy, rules=rules,
                          pad_values=pad_values, form="fold_gram")


def fold_weighted_gram(X: Array, Wk: Array, *, intercept: bool = False,
                       append: Optional[Array] = None, row_block: int = 0,
                       strategy: Optional[str] = None, rules=None
                       ) -> Tuple[Array, Array]:
    """``G[k] = Σ_n Wk[k,n] d_n d_nᵀ`` (k, q, q) plus per-fold
    ``n_eff = Σ_n Wk[k,n]`` — the replicate-invariant
    ``ni,kn,nj->kij`` form of repro.inference.numerics, blocked.  At
    row_block=0 this IS the legacy whole-array einsum, bitwise."""
    f32 = jnp.float32
    r = resolve_row_block(X.shape[0], row_block)
    # n_eff is an O(n·k) plain sum — computed whole-array in EVERY mode
    # so it is strategy-independent by construction (slicing the
    # transposed Wk operand per block reassociates its reduction)
    n_eff = Wk.astype(f32).sum(axis=1)
    if r == 0:
        D = design(X, intercept=intercept, append=append)
        return jnp.einsum("ni,kn,nj->kij", D, Wk.astype(f32), D), n_eff
    if strategy == "pallas":
        D = design(X, intercept=intercept, append=append)
        return _seg_ops().fold_weighted_design_gram(D, Wk, row_block=r), n_eff

    def block(Xb, Wb, *rest):
        D = design(Xb, intercept=intercept,
                   append=rest[0] if rest else None)
        return jnp.einsum("ni,kn,nj->kij", D, Wb.astype(f32).T, D)

    arrays = (X, Wk.T) + (() if append is None else (append,))
    G = blocked_reduce(block, arrays, row_block=r, strategy=strategy,
                       rules=rules, form="fold_weighted_gram")
    return G, n_eff


# ---------------------------------------------------------------------------
# Residual moments (the DML final stage): Z = (t - mt) ⊙ phi,
# G = ZᵀZ, b = Zᵀ(y - my), meat = Σ e²·z zᵀ.
# ---------------------------------------------------------------------------

def residual_moments(y: Array, t: Array, my: Array, mt: Array, phi: Array,
                     *, row_block: int = 0, strategy: Optional[str] = None,
                     rules=None, backend: str = ""
                     ) -> Tuple[Array, Array]:
    """(G (p,p), b (p,)) of the orthogonal moment, fp32.  row_block=0
    delegates to the fused ``residual_gram`` kernel dispatch (Pallas on
    TPU, jnp oracle elsewhere) — today's whole-array path, bitwise.
    Blocked evaluation streams row blocks; with a Pallas-capable
    backend each block takes the fused kernel (one HBM pass per block),
    otherwise the augmented ``M = [Z | ry]`` Gram form (the thin
    ``Zᵀry`` mat-vec is not chunked-stable; the augmented column is)."""
    from repro.kernels.residual_gram import ops as rg_ops
    n, p = phi.shape
    r = resolve_row_block(n, row_block)
    if r == 0:
        return rg_ops.residual_gram(y, t, my, mt, phi, backend=backend)
    if strategy == "pallas":
        return _seg_ops().residual_gram(y, t, my, mt, phi, row_block=r)
    if backend in ("pallas", "interpret"):
        def block(yb, tb, myb, mtb, phib):
            return rg_ops.residual_gram(yb, tb, myb, mtb, phib,
                                        backend=backend,
                                        block_n=min(512, r))
    else:
        def block(yb, tb, myb, mtb, phib):
            ry = (yb - myb).astype(jnp.float32)
            rt = (tb - mtb).astype(jnp.float32)
            z = rt[:, None] * phib.astype(jnp.float32)
            M = jnp.concatenate([z, ry[:, None]], axis=1)
            Gaug = M.T @ M
            return Gaug[:p, :p], Gaug[:p, p]

    return blocked_reduce(block, (y, t, my, mt, phi), row_block=r,
                          strategy=strategy, rules=rules,
                          form="residual_moments")


def residual_weighted_gram(ry: Array, rt: Array, phi: Array, w: Array,
                           *, row_block: int = 0,
                           strategy: Optional[str] = None, rules=None
                           ) -> Tuple[Array, Array]:
    """Weighted augmented residual Gram ``Σ_n w_n m_n m_nᵀ`` with
    ``m = [rt·phi | ry]`` plus ``n_eff = Σ w`` — the replicate-invariant
    weighted-final-stage moment (inference.numerics.weighted_theta).
    Z is formed per block: on the blocked path the dense (n, p) moment
    matrix never materializes."""
    f32 = jnp.float32
    if _use_pallas(ry.shape[0], row_block, strategy):
        return _seg_ops().residual_weighted_gram(ry, rt, phi, w,
                                                 row_block=row_block)

    def block(ryb, rtb, phib, wb):
        Z = rtb.astype(f32)[:, None] * phib.astype(f32)
        M = jnp.concatenate([Z, ryb.astype(f32)[:, None]], axis=1)
        ws = wb.astype(f32)
        return jnp.einsum("ni,n,nj->ij", M, ws, M), ws.sum()

    return blocked_reduce(block, (ry, rt, phi, w), row_block=row_block,
                          strategy=strategy, rules=rules,
                          form="residual_weighted_gram")


def _meat_gram(score: Array, e: Array, p: int) -> Array:
    """``Σ_n e_n² s_n s_nᵀ`` in the batch-invariant form for this p.

    XLA's tiling of the n-contraction is shape-dependent: with a
    COMPUTED weight (e² is a fused elementwise producer, unlike the
    plain-input weights of the Gram kernels above) the 3-operand
    ``ni,n,nj->ij`` einsum tends to keep its reduction order under an
    added vmap axis at p = 1, while folding e into the score and
    contracting ``mᵀm`` keeps it at p ≥ 2 (measured on CPU XLA).
    Dispatch on the static width picks the stabler form per regime; the
    serial ≡ vmap CONTRACT is certified on the row-blocked path, where
    the scan barrier makes it shape-robust (tests/test_conformance.py
    pins it there)."""
    if p >= 2:
        m = e[:, None] * score
        return jnp.einsum("ni,nj->ij", m, m)
    return jnp.einsum("ni,n,nj->ij", score, jnp.square(e), score)


def residual_meat(y: Array, t: Array, my: Array, mt: Array, phi: Array,
                  theta: Array, *, w: Optional[Array] = None,
                  row_block: int = 0, strategy: Optional[str] = None,
                  rules=None) -> Array:
    """HC0 meat ``Σ_n (w_n e_n)² z_n z_nᵀ`` with ``e = ry - <z, theta>``
    streamed per block — the dense (n, p) moment matrix ``z`` and the
    residual vector never materialize on the blocked path.  The inner
    product uses the small-axis ``(z * theta).sum(-1)`` form (replicate-
    and chunk-invariant); the contraction takes the width-dispatched
    batch-invariant form (see ``_meat_gram``)."""
    p = phi.shape[1]
    if _use_pallas(phi.shape[0], row_block, strategy):
        return _seg_ops().residual_meat(y, t, my, mt, phi, theta, w=w,
                                        row_block=row_block)

    def block(yb, tb, myb, mtb, phib, *rest):
        ry = (yb - myb).astype(jnp.float32)
        rt = (tb - mtb).astype(jnp.float32)
        z = rt[:, None] * phib.astype(jnp.float32)
        e = ry - (z * theta[None, :]).sum(axis=1)
        if rest:
            e = rest[0].astype(jnp.float32) * e
        return _meat_gram(z, e, p)

    arrays = (y, t, my, mt, phi) + (() if w is None else (w,))
    return blocked_reduce(block, arrays, row_block=row_block,
                          strategy=strategy, rules=rules,
                          form="residual_meat")


# ---------------------------------------------------------------------------
# Instrumented moments (the orthogonal-IV family, repro.core.iv):
# M = [rz ⊙ phi | rt ⊙ phi | ry], G = Σ w · m mᵀ.  Every 2SLS-shaped
# sufficient statistic is a slice of this ONE augmented Gram:
#   J    = G[:p, p:2p]   Σ w·rz·rt·φφᵀ   (the residual-on-residual
#                                          instrument moment)
#   b    = G[:p, 2p]     Σ w·rz·ry·φ     (instrumented cross-moment)
#   Szz  = G[:p, :p]     Σ w·rz²·φφᵀ     (instrument strength)
#   Stt  = G[p:2p, p:2p] Σ w·rt²·φφᵀ
#   bty  = G[p:2p, 2p]   Σ w·rt·ry·φ     (the OLS cross-moment, free)
# Like every form in this module, cross-moments ride as appended
# columns of the blocked Gram — bit-identical chunked vs whole.
# ---------------------------------------------------------------------------

def iv_gram(ry: Array, rt: Array, rz: Array, phi: Array, w: Array, *,
            row_block: int = 0, strategy: Optional[str] = None,
            rules=None) -> Tuple[Array, Array]:
    """Weighted instrumented augmented Gram ``Σ_n w_n m_n m_nᵀ`` with
    ``m = [rz·phi | rt·phi | ry]`` ((2p+1, 2p+1)) plus ``n_eff = Σ w``.
    Point fits pass w = 1; bootstrap replicates their resampling
    weights — both take the same einsum form, so a w=1 replicate is
    bitwise the point fit."""
    f32 = jnp.float32
    if _use_pallas(phi.shape[0], row_block, strategy):
        return _seg_ops().iv_gram(ry, rt, rz, phi, w, row_block=row_block)

    def block(ryb, rtb, rzb, phib, wb):
        ph = phib.astype(f32)
        M = jnp.concatenate(
            [rzb.astype(f32)[:, None] * ph,
             rtb.astype(f32)[:, None] * ph,
             ryb.astype(f32)[:, None]], axis=1)
        ws = wb.astype(f32)
        return jnp.einsum("ni,n,nj->ij", M, ws, M), ws.sum()

    return blocked_reduce(block, (ry, rt, rz, phi, w),
                          row_block=row_block, strategy=strategy,
                          rules=rules, form="iv_gram")


def iv_slices(Gaug: Array, p: int) -> Tuple[Array, Array, Array, Array]:
    """(J, b, Szz, Stt) read off an ``iv_gram`` result (see the section
    comment above for the algebra)."""
    return (Gaug[:p, p:2 * p], Gaug[:p, 2 * p],
            Gaug[:p, :p], Gaug[p:2 * p, p:2 * p])


def iv_meat(ry: Array, rt: Array, rz: Array, phi: Array, theta: Array,
            *, w: Optional[Array] = None, row_block: int = 0,
            strategy: Optional[str] = None, rules=None) -> Array:
    """HC0 meat of the instrumented moment: ``Σ_n (w_n e_n)² zc_n zc_nᵀ``
    with score ``zc = rz·phi`` and residual ``e = ry - <rt·phi, theta>``,
    streamed per block (neither the (n, p) score matrix nor the residual
    vector materializes on the blocked path).  The inner product uses
    the small-axis ``(z * theta).sum(-1)`` form and the contraction the
    width-dispatched batch-invariant form, matching ``residual_meat``."""
    f32 = jnp.float32
    p = phi.shape[1]
    if _use_pallas(phi.shape[0], row_block, strategy):
        return _seg_ops().iv_meat(ry, rt, rz, phi, theta, w=w,
                                  row_block=row_block)

    def block(ryb, rtb, rzb, phib, *rest):
        ph = phib.astype(f32)
        z = rtb.astype(f32)[:, None] * ph
        e = ryb.astype(f32) - (z * theta[None, :]).sum(axis=1)
        if rest:
            e = rest[0].astype(f32) * e
        if p >= 2:
            m = e[:, None] * (rzb.astype(f32)[:, None] * ph)
            return jnp.einsum("ni,nj->ij", m, m)
        # p = 1: the meat is the plain sum Σ (e·rz·φ)² — elementwise
        # square + sum, the one contraction-free member of the
        # invariant vocabulary.  (The 3-operand einsum that is stable
        # for residual_meat's score here picks up an extra fused
        # producer and loses batch invariance — measured, and pinned by
        # tests/test_conformance.py.)
        m = e * (rzb.astype(f32)[:, None] * ph)[:, 0]
        return jnp.square(m).sum().reshape(1, 1)

    arrays = (ry, rt, rz, phi) + (() if w is None else (w,))
    return blocked_reduce(block, arrays, row_block=row_block,
                          strategy=strategy, rules=rules,
                          form="iv_meat")


def fold_iv_gram(ry: Array, rt: Array, rz: Array, phi: Array,
                 folds: Array, k: int, *, row_block: int = 0,
                 strategy: Optional[str] = None, rules=None
                 ) -> Tuple[Array, Array]:
    """Fold-segmented instrumented Gram ``Gh[j] = Σ_{n in fold j}
    m_n m_nᵀ`` ((k, 2p+1, 2p+1)) plus per-fold row counts — the
    delete-fold jackknife's one pass (LOO identity:
    ``G_(-j) = Σ_j Gh - Gh[j]``).  Padded fold ids are -1 so they
    one-hot to the zero row."""
    f32 = jnp.float32
    if _use_pallas(phi.shape[0], row_block, strategy):
        return _seg_ops().fold_iv_gram(ry, rt, rz, phi, folds, k,
                                       row_block=row_block)

    def block(ryb, rtb, rzb, phib, fb):
        ph = phib.astype(f32)
        M = jnp.concatenate(
            [rzb.astype(f32)[:, None] * ph,
             rtb.astype(f32)[:, None] * ph,
             ryb.astype(f32)[:, None]], axis=1)
        oh = jax.nn.one_hot(fb, k, dtype=f32)
        return jnp.einsum("nk,ni,nj->kij", oh, M, M), oh.sum(0)

    return blocked_reduce(block, (ry, rt, rz, phi, folds),
                          row_block=row_block, strategy=strategy,
                          rules=rules, pad_values=(0, 0, 0, 0, -1),
                          form="fold_iv_gram")
