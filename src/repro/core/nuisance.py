"""Nuisance model zoo for Double-ML (m_y = E[Y|X], m_t = E[T|X]).

Every model is a triple of pure functions (init / fit / predict) with a
*sample-weight* argument, which is the key to the paper's C1 translation:
the K out-of-fold fits become ONE batched program by vmapping fit over a
leading fold axis whose per-fold weights mask the held-out fold.  Each
row of X is then read once and used by K-1 fits — strictly less data
movement than Ray's K independent tasks re-reading the dataset.

The zoo is MXU-native (DESIGN.md §2, §9): closed-form ridge, Newton
logistic, MLPs, and pooled LM-backbone features with a linear head —
replacing EconML's RandomForest defaults, which do not map to systolic
arrays.  The DML estimator is agnostic to the nuisance family as long as
it is consistent; tests verify the same ATE recovery.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import CausalConfig
from repro.core import moments
from repro.optim.adamw import adamw_init, adamw_update
from repro.config import TrainConfig


@dataclasses.dataclass(frozen=True, eq=False)
class Nuisance:
    """Pure-function model bundle.  All fns are jit/vmap-compatible.
    Identity-hashed (eq=False) so executor-facing closure caches can
    key on the instance (``hyper`` holds an unhashable dict).

    init(key, p)            -> state
    fit(state, X, y, w)     -> state      (w: (n,) sample weights)
    predict(state, X)       -> (n,)       (E[y|X] or P(t=1|X))

    ``hyper`` exposes the scalar hyper-parameters baked into the
    closures (lam, newton iters, ...) so repro.inference can rebuild the
    same fit on its replicate-invariant fold-batched kernels.
    """

    name: str
    task: str  # "reg" | "clf"
    init: Callable[[jax.Array, int], Any]
    fit: Callable[[Any, jax.Array, jax.Array, jax.Array], Any]
    predict: Callable[[Any, jax.Array], jax.Array]
    hyper: Optional[Dict[str, Any]] = None


def _aug(X: jax.Array) -> jax.Array:
    """Append the intercept column."""
    return jnp.concatenate(
        [X, jnp.ones((X.shape[0], 1), X.dtype)], axis=1)


# ---------------------------------------------------------------------------
# Ridge regression (closed form — one Gram + solve)
# ---------------------------------------------------------------------------

def make_ridge(lam: float = 1e-3, row_block: int = 0,
               strategy: Optional[str] = None) -> Nuisance:
    def init(key, p):
        return {"beta": jnp.zeros((p + 1,), jnp.float32),
                "lam": jnp.asarray(lam, jnp.float32)}

    def fit(state, X, y, w):
        # weighted normal equations as ONE augmented sufficient-
        # statistics pass (repro.core.moments): the target rides as an
        # appended design column, so G and the cross-moment b come out
        # of the same (optionally row-blocked) Gram reduction.
        q = X.shape[1] + 1
        Gaug, n_eff = moments.weighted_gram(X, w, intercept=True,
                                            append=y, row_block=row_block,
                                            strategy=strategy)
        n_eff = jnp.maximum(n_eff, 1.0)
        A = Gaug[:q, :q] / n_eff \
            + state["lam"] * jnp.eye(q, dtype=jnp.float32)
        beta = jnp.linalg.solve(A, Gaug[:q, q] / n_eff)
        return {**state, "beta": beta}

    def predict(state, X):
        return _aug(X.astype(jnp.float32)) @ state["beta"]

    return Nuisance("ridge", "reg", init, fit, predict,
                    hyper={"lam": lam, "row_block": row_block,
                           "strategy": strategy})


# ---------------------------------------------------------------------------
# Logistic regression via Newton/IRLS (fixed iteration count -> jit-able)
# ---------------------------------------------------------------------------

def make_logistic(lam: float = 1e-3, iters: int = 16,
                  row_block: int = 0,
                  strategy: Optional[str] = None) -> Nuisance:
    def init(key, p):
        return {"beta": jnp.zeros((p + 1,), jnp.float32),
                "lam": jnp.asarray(lam, jnp.float32)}

    def fit(state, X, y, w):
        Xf = X.astype(jnp.float32)
        ws = w.astype(jnp.float32)
        yt = y.astype(jnp.float32)
        q = X.shape[1] + 1
        n_eff = jnp.maximum(ws.sum(), 1.0)
        lam_eye = state["lam"] * jnp.eye(q, dtype=jnp.float32)

        def newton(_, beta):
            z = Xf @ beta[:-1] + beta[-1]
            mu = jax.nn.sigmoid(z)
            s = jnp.clip(mu * (1 - mu), 1e-6, None) * ws
            # Hessian + gradient in ONE weighted-moments pass over X
            H, g_raw, _ = moments.weighted_gram_and_vec(
                Xf, s, ws * (mu - yt), intercept=True,
                row_block=row_block, strategy=strategy)
            g = g_raw / n_eff + state["lam"] * beta
            return beta - jnp.linalg.solve(H / n_eff + lam_eye, g)

        beta = jax.lax.fori_loop(0, iters, newton, state["beta"])
        return {**state, "beta": beta}

    def predict(state, X):
        return jax.nn.sigmoid(_aug(X.astype(jnp.float32)) @ state["beta"])

    return Nuisance("logistic", "clf", init, fit, predict,
                    hyper={"lam": lam, "iters": iters,
                           "row_block": row_block, "strategy": strategy})


# ---------------------------------------------------------------------------
# MLP (full-batch AdamW for a fixed step count, lax.scan -> one program)
# ---------------------------------------------------------------------------

def _mlp_init(key, sizes) -> Dict[str, Any]:
    params = {}
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        kw, key = jax.random.split(key)
        params[f"w{i}"] = jax.random.normal(kw, (a, b), jnp.float32) / jnp.sqrt(a)
        params[f"b{i}"] = jnp.zeros((b,), jnp.float32)
    return params


def _mlp_forward(params, X, n_layers) -> jax.Array:
    h = X.astype(jnp.float32)
    for i in range(n_layers):
        h = h @ params[f"w{i}"] + params[f"b{i}"]
        if i < n_layers - 1:
            h = jax.nn.gelu(h)
    return h[..., 0]


def make_mlp(task: str, hidden: Tuple[int, ...] = (256, 256),
             steps: int = 200, lr: float = 1e-3, wd: float = 1e-4) -> Nuisance:
    tcfg = TrainConfig(learning_rate=lr, weight_decay=wd, grad_clip=1.0)
    n_layers = len(hidden) + 1

    def init(key, p):
        sizes = (p,) + tuple(hidden) + (1,)
        params = _mlp_init(key, sizes)
        return {"params": params, "opt": adamw_init(params)}

    def loss_fn(params, X, y, w):
        out = _mlp_forward(params, X, n_layers)
        if task == "clf":
            per = jnp.maximum(out, 0) - out * y + jnp.log1p(jnp.exp(-jnp.abs(out)))
        else:
            per = 0.5 * jnp.square(out - y)
        return jnp.sum(per * w) / jnp.maximum(w.sum(), 1.0)

    def fit(state, X, y, w):
        Xf = X.astype(jnp.float32)
        yf = y.astype(jnp.float32)
        wf = w.astype(jnp.float32)
        # an "lr" state leaf overrides the baked-in rate, so tuning can
        # sweep lr as DATA on one compiled program (core.tuning maps
        # trials through the executor without re-tracing per trial)
        lr_t = jnp.asarray(state.get("lr", lr), jnp.float32)

        def step(carry, _):
            params, opt = carry
            g = jax.grad(loss_fn)(params, Xf, yf, wf)
            params, opt, _ = adamw_update(g, opt, params, lr_t, tcfg)
            return (params, opt), None

        (params, opt), _ = jax.lax.scan(step, (state["params"], state["opt"]),
                                        None, length=steps)
        return {"params": params, "opt": opt}

    def predict(state, X):
        out = _mlp_forward(state["params"], X, n_layers)
        return jax.nn.sigmoid(out) if task == "clf" else out

    return Nuisance(f"mlp_{task}", task, init, fit, predict,
                    hyper={"hidden": hidden, "steps": steps, "lr": lr})


# ---------------------------------------------------------------------------
# LM-backbone features (the Dream11 scenario: event-sequence confounders)
# ---------------------------------------------------------------------------

def backbone_features(model, params, tokens: jax.Array,
                      batch_size: int = 0, extras: Optional[Dict] = None
                      ) -> jax.Array:
    """Pooled (n, d_model) features from a repro Model over user event
    sequences.  The backbone is frozen; nuisance heads (ridge/logistic)
    are cross-fit on top — so C1/C2 apply to all 10 assigned archs."""
    extras = extras or {}
    if not batch_size or tokens.shape[0] <= batch_size:
        return model.features(params, {"tokens": tokens, **extras})
    chunks = []
    for i in range(0, tokens.shape[0], batch_size):
        sl = {k: v[i:i + batch_size] for k, v in extras.items()}
        chunks.append(model.features(
            params, {"tokens": tokens[i:i + batch_size], **sl}))
    return jnp.concatenate(chunks, axis=0)


# ---------------------------------------------------------------------------
# Factory
# ---------------------------------------------------------------------------

def make_nuisance(kind: str, task: str, cfg: CausalConfig) -> Nuisance:
    rb, st = cfg.row_block, cfg.row_block_strategy
    if kind == "ridge":
        return make_ridge(cfg.ridge_lambda, row_block=rb, strategy=st)
    if kind == "logistic":
        return make_logistic(cfg.ridge_lambda, cfg.newton_iters,
                             row_block=rb, strategy=st)
    if kind == "mlp":
        return make_mlp(task, cfg.mlp_hidden, cfg.mlp_steps, cfg.mlp_lr)
    if kind == "backbone":
        # heads over precomputed backbone features; same linear math
        return (make_logistic(cfg.ridge_lambda, cfg.newton_iters,
                              row_block=rb, strategy=st)
                if task == "clf" else make_ridge(cfg.ridge_lambda,
                                                 row_block=rb,
                                                 strategy=st))
    raise ValueError(f"unknown nuisance kind {kind!r}")


# ---------------------------------------------------------------------------
# Fold-batched fast paths (beyond-paper optimization, EXPERIMENTS §Perf):
# the leave-one-out Gram identity
#
#       Xᵀ diag(w_k) X  =  G_total - G_heldout_k
#
# turns the K complement-weighted Grams of cross-fitting into ONE pass
# over X (a fold-segmented Gram) plus O(K p²) combination — the paper's
# C1 runs K tasks that each re-read the data; this removes even the
# single batched re-read per fold.  For logistic, the Newton/IRLS
# Hessians (16 X-passes) are replaced by the Böhning-Lindsay fixed
# majorizer H0 = XᵀX/4 + λI (factored once per fold via the same
# identity); iterations then cost two matvecs each.  Ridge stays EXACT;
# logistic converges monotonically to the same optimum (MM guarantee).
# ---------------------------------------------------------------------------

def _fold_grams(Xa: jax.Array, folds: jax.Array, k: int,
                row_block: int = 0, strategy: Optional[str] = None):
    """One-pass fold-segmented Gram: returns (G_heldout (k,p,p),
    G_total (p,p)).  Delegates to the moments engine (row_block > 0
    streams the pass in fixed-order row blocks)."""
    Gh, _ = moments.fold_gram(Xa, folds, k, row_block=row_block,
                              strategy=strategy)
    return Gh, Gh.sum(0)


def ridge_fit_folds(lam: float, X: jax.Array, y: jax.Array,
                    folds: jax.Array, k: int, row_block: int = 0,
                    strategy: Optional[str] = None):
    """EXACT per-fold ridge via the LOO identity; one X pass.  The
    target rides as an appended design column of the segmented Gram,
    so the per-fold cross-moments come out of the same reduction."""
    f32 = jnp.float32
    n, p = X.shape[0], X.shape[1] + 1
    Gh_aug, counts = moments.fold_gram(X, folds, k, intercept=True,
                                       append=y, row_block=row_block,
                                       strategy=strategy)
    G_aug = Gh_aug.sum(0)
    Gh, G = Gh_aug[:, :p, :p], G_aug[:p, :p]
    bh, b_tot = Gh_aug[:, :p, p], G_aug[:p, p]
    n_eff = jnp.maximum(n - counts, 1.0)[:, None, None]
    A = (G[None] - Gh) / n_eff + lam * jnp.eye(p, dtype=f32)[None]
    rhs = (b_tot[None] - bh) / n_eff[..., 0]
    beta = jnp.linalg.solve(A, rhs[..., None])[..., 0]      # (k, p)
    return {"beta": beta, "lam": jnp.full((k,), lam, f32)}


def logistic_fit_folds(lam: float, iters: int, X: jax.Array, t: jax.Array,
                       folds: jax.Array, k: int, row_block: int = 0,
                       strategy: Optional[str] = None):
    """Per-fold logistic via fixed-Hessian majorization (Böhning-Lindsay):
    H0_k = Xᵀdiag(w_k)X/4 + λI factored ONCE (LOO identity via one
    moments pass), then ``iters`` MM steps of two matvecs each."""
    f32 = jnp.float32
    Xa = _aug(X.astype(f32))
    n, p = Xa.shape
    Gh, G = _fold_grams(Xa, folds, k, row_block=row_block,
                        strategy=strategy)
    onehot = jax.nn.one_hot(folds, k, dtype=f32)            # (n, k)
    w = 1.0 - onehot                                        # train weights
    counts = onehot.sum(0)
    n_eff = jnp.maximum(n - counts, 1.0)
    H0 = (G[None] - Gh) / (4.0 * n_eff[:, None, None]) \
        + lam * jnp.eye(p, dtype=f32)[None]
    lu = jax.scipy.linalg.lu_factor(H0)
    tt = t.astype(f32)

    def step(_, beta):                                      # beta: (k, p)
        z = Xa @ beta.T                                     # (n, k)
        mu = jax.nn.sigmoid(z)
        r = w * (mu - tt[:, None])                          # (n, k)
        g = (r.T @ Xa) / n_eff[:, None] + lam * beta        # (k, p)
        delta = jax.vmap(jax.scipy.linalg.lu_solve)(lu, g[..., None])
        return beta - delta[..., 0]

    beta = jax.lax.fori_loop(0, iters, step, jnp.zeros((k, p), f32))
    return {"beta": beta, "lam": jnp.full((k,), lam, f32)}
