"""Doubly-Robust (AIPW) learner — the DR baseline the paper cites
(§2.2, Foster & Syrgkanis 2019) built on the same fold-parallel
cross-fitting engine as DML.

Pseudo-outcome (binary treatment):

    ψ_i = m1(x_i) - m0(x_i)
        + t_i (y_i - m1(x_i)) / e(x_i)
        - (1 - t_i)(y_i - m0(x_i)) / (1 - e(x_i))

with cross-fit outcome models m_t(x) = E[Y|X,T=t] and propensity
e(x) = P(T=1|X).  ATE = mean(ψ); CATE = regress ψ on phi(x).
Consistent if EITHER the outcome models or the propensity is consistent
(double robustness).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import CausalConfig
from repro.core.crossfit import fold_ids, fold_weights, _oof_select
from repro.core.final_stage import cate_basis
from repro.core.nuisance import Nuisance, make_logistic, make_ridge


@dataclasses.dataclass(frozen=True)
class DRResult:
    ate: float
    stderr: float
    theta: jax.Array          # CATE coefficients on phi(x)
    pseudo: jax.Array         # (n,) AIPW pseudo-outcomes

    def cate(self, X: jax.Array, n_features: int) -> jax.Array:
        return cate_basis(X, n_features) @ self.theta

    def conf_int(self, z: float = 1.96):
        return self.ate - z * self.stderr, self.ate + z * self.stderr


class DRLearner:
    """fit(y, t, X) with 3 cross-fit nuisances (m0, m1, e)."""

    def __init__(self, cfg: CausalConfig,
                 outcome: Optional[Nuisance] = None,
                 propensity: Optional[Nuisance] = None,
                 clip: float = 0.01):
        self.cfg = cfg
        self.outcome = outcome or make_ridge(cfg.ridge_lambda)
        self.propensity = propensity or make_logistic(cfg.ridge_lambda,
                                                      cfg.newton_iters)
        self.clip = clip

    def _crossfit_outcome_arm(self, key, X, y, t, folds, arm: int):
        """Cross-fit E[Y|X, T=arm]: train weights select the complement
        AND the arm."""
        k = self.cfg.n_folds
        W = fold_weights(folds, k)
        arm_mask = (t == arm).astype(jnp.float32)[None, :]
        keys = jax.random.split(key, k)
        states0 = jax.vmap(self.outcome.init, in_axes=(0, None))(
            keys, X.shape[1])
        states = jax.vmap(self.outcome.fit, in_axes=(0, None, None, 0))(
            states0, X, y, W * arm_mask)
        preds = jax.vmap(self.outcome.predict, in_axes=(0, None))(states, X)
        return _oof_select(preds, folds)

    def fit(self, y: jax.Array, t: jax.Array, X: jax.Array,
            key: Optional[jax.Array] = None) -> DRResult:
        key = key if key is not None else jax.random.PRNGKey(0)
        kf, k0, k1, ke = jax.random.split(key, 4)
        n = X.shape[0]
        k = self.cfg.n_folds
        folds = fold_ids(kf, n, k)
        tt = t.astype(jnp.float32)

        m0 = self._crossfit_outcome_arm(k0, X, y, tt, folds, 0)
        m1 = self._crossfit_outcome_arm(k1, X, y, tt, folds, 1)

        W = fold_weights(folds, k)
        keys = jax.random.split(ke, k)
        st0 = jax.vmap(self.propensity.init, in_axes=(0, None))(
            keys, X.shape[1])
        st = jax.vmap(self.propensity.fit, in_axes=(0, None, None, 0))(
            st0, X, tt, W)
        e = _oof_select(jax.vmap(self.propensity.predict,
                                 in_axes=(0, None))(st, X), folds)
        e = jnp.clip(e, self.clip, 1.0 - self.clip)

        psi = (m1 - m0
               + tt * (y - m1) / e
               - (1.0 - tt) * (y - m0) / (1.0 - e))
        ate = float(psi.mean())
        se = float(psi.std(ddof=1) / jnp.sqrt(n))

        phi = cate_basis(X, self.cfg.cate_features)
        G = phi.T @ phi + 1e-8 * n * jnp.eye(phi.shape[1])
        theta = jnp.linalg.solve(G, phi.T @ psi)
        return DRResult(ate=ate, stderr=se, theta=theta, pseudo=psi)
