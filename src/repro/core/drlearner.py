"""Doubly-Robust (AIPW) learner — the DR baseline the paper cites
(§2.2, Foster & Syrgkanis 2019) built on the same fold-parallel
cross-fitting engine as DML.

Pseudo-outcome (binary treatment):

    ψ_i = m1(x_i) - m0(x_i)
        + t_i (y_i - m1(x_i)) / e(x_i)
        - (1 - t_i)(y_i - m0(x_i)) / (1 - e(x_i))

with cross-fit outcome models m_t(x) = E[Y|X,T=t] and propensity
e(x) = P(T=1|X).  ATE = mean(ψ); CATE = regress ψ on phi(x).
Consistent if EITHER the outcome models or the propensity is consistent
(double robustness).

Interval/caching plumbing comes from ``repro.core.estimator``
(PseudoOutcomeEffectResult); this module keeps only the AIPW program
and its bootstrap dispatch.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import CausalConfig
from repro.core import moments
from repro.core.crossfit import fold_ids, fold_weights, _oof_select
from repro.core.estimator import (PseudoOutcomeEffectResult,
                                  inf_cache_field, resolve_scheme)
from repro.core.final_stage import cate_basis
from repro.core.nuisance import Nuisance, make_logistic, make_ridge


@dataclasses.dataclass(frozen=True)
class DRResult(PseudoOutcomeEffectResult):
    ate: float
    stderr: float
    theta: jax.Array          # CATE coefficients on phi(x)
    pseudo: jax.Array         # (n,) AIPW pseudo-outcomes
    cfg: Optional[CausalConfig] = None
    fit_ctx: Optional[Dict[str, Any]] = None
    _inf_cache: Dict[Any, Any] = inf_cache_field()

    estimator_name = "DRLearner"

    def _resolve_method(self, method):
        # DR has no fold-state shortcut; a delete-fold jackknife would
        # silently be a different estimator, so substitute the bootstrap
        return "bootstrap" if method == "jackknife" else method

    def _replicate_inference(self, method, n_boot, exe, alpha):
        """Bootstrap the whole AIPW pipeline (nuisances + pseudo-outcome
        regression) as one runtime-scheduled program (the ATE
        functional's own draws ride along)."""
        from repro.inference import dr_bootstrap
        cfg = self._config()
        ctx = self.fit_ctx
        return dr_bootstrap(
            ctx["outcome"], ctx["propensity"], n_folds=cfg.n_folds,
            X=ctx["X"], y=ctx["y"], t=ctx["t"], phi=ctx["phi"],
            key=jax.random.fold_in(ctx["key"], 0x0b00), alpha=alpha,
            n_replicates=n_boot, scheme=resolve_scheme(method),
            executor=exe, clip=ctx["clip"], point=self.theta,
            ate_point=self.ate, row_block=cfg.row_block,
            **self._runtime_kwargs())


class DRLearner:
    """fit(y, t, X) with 3 cross-fit nuisances (m0, m1, e)."""

    def __init__(self, cfg: CausalConfig,
                 outcome: Optional[Nuisance] = None,
                 propensity: Optional[Nuisance] = None,
                 clip: float = 0.01):
        self.cfg = cfg
        self.outcome = outcome or make_ridge(
            cfg.ridge_lambda, row_block=cfg.row_block,
            strategy=cfg.row_block_strategy)
        self.propensity = propensity or make_logistic(
            cfg.ridge_lambda, cfg.newton_iters, row_block=cfg.row_block,
            strategy=cfg.row_block_strategy)
        self.clip = clip

    def _crossfit_outcome_arm(self, key, X, y, t, folds, arm: int):
        """Cross-fit E[Y|X, T=arm]: train weights select the complement
        AND the arm."""
        k = self.cfg.n_folds
        W = fold_weights(folds, k)
        arm_mask = (t == arm).astype(jnp.float32)[None, :]
        keys = jax.random.split(key, k)
        states0 = jax.vmap(self.outcome.init, in_axes=(0, None))(
            keys, X.shape[1])
        states = jax.vmap(self.outcome.fit, in_axes=(0, None, None, 0))(
            states0, X, y, W * arm_mask)
        preds = jax.vmap(self.outcome.predict, in_axes=(0, None))(states, X)
        return _oof_select(preds, folds)

    def fit(self, y: jax.Array, t: jax.Array, X: jax.Array,
            key: Optional[jax.Array] = None) -> DRResult:
        key = key if key is not None else jax.random.PRNGKey(0)
        kf, k0, k1, ke = jax.random.split(key, 4)
        n = X.shape[0]
        k = self.cfg.n_folds
        folds = fold_ids(kf, n, k)
        tt = t.astype(jnp.float32)

        m0 = self._crossfit_outcome_arm(k0, X, y, tt, folds, 0)
        m1 = self._crossfit_outcome_arm(k1, X, y, tt, folds, 1)

        W = fold_weights(folds, k)
        keys = jax.random.split(ke, k)
        st0 = jax.vmap(self.propensity.init, in_axes=(0, None))(
            keys, X.shape[1])
        st = jax.vmap(self.propensity.fit, in_axes=(0, None, None, 0))(
            st0, X, tt, W)
        e = _oof_select(jax.vmap(self.propensity.predict,
                                 in_axes=(0, None))(st, X), folds)
        e = jnp.clip(e, self.clip, 1.0 - self.clip)

        psi = (m1 - m0
               + tt * (y - m1) / e
               - (1.0 - tt) * (y - m0) / (1.0 - e))
        ate = float(psi.mean())
        se = float(psi.std(ddof=1) / jnp.sqrt(n))

        # pseudo-outcome regression as one (optionally row-blocked)
        # augmented-moments pass: psi rides as the appended column
        phi = cate_basis(X, self.cfg.cate_features)
        q = phi.shape[1]
        Gaug, _ = moments.weighted_gram(phi, jnp.ones((n,), jnp.float32),
                                        append=psi,
                                        row_block=self.cfg.row_block,
                                        strategy=self.cfg.row_block_strategy)
        G = Gaug[:q, :q] + 1e-8 * n * jnp.eye(q)
        theta = jnp.linalg.solve(G, Gaug[:q, q])
        ctx = {"X": X, "y": y, "t": t, "phi": phi, "key": key,
               "outcome": self.outcome, "propensity": self.propensity,
               "clip": self.clip}
        return DRResult(ate=ate, stderr=se, theta=theta, pseudo=psi,
                        cfg=self.cfg, fit_ctx=ctx)
