"""Distributed hyper-parameter tuning — the paper's §5.2 contribution (C2).

Ray Tune's trial pool becomes a *population axis*: trials share one
compiled graph and differ only in scalar hyper-parameters, so the whole
(trial × fold) grid is a single double-vmapped program — the entire
sweep is one batched matmul stream on the MXU instead of T·K scheduled
tasks.  For budgeted search, ``successive_halving`` implements the
ASHA-style rung schedule on top (per-rung survivor sets are plain
arrays, so a preempted sweep resumes from the last rung — DESIGN §7).

The replicate axis (trials for the grid, folds inside a halving rung)
is dispatched through ``repro.inference.executor`` — the same pluggable
Executor that schedules §5.1 fold fits and bootstrap replicates — so
"how iterative steps run" is one swappable choice across all three
paper-parallelized step classes: ``vmap`` (default) batches the sweep
into one program, ``serial`` is the Ray-less loop baseline, and
``shard_map`` spreads the axis over the device mesh.

Scores are out-of-fold (cross-validated) losses: MSE for regression,
log-loss for classification — the same objective Ray Tune's scikit-learn
wrappers report.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.config import CausalConfig
from repro.core.crossfit import fold_ids, fold_weights, _oof_select
from repro.core.nuisance import Nuisance, make_mlp, make_logistic, make_ridge
from repro.inference.executor import make_executor


def _oof_score(preds_kn: jax.Array, folds: jax.Array, target: jax.Array,
               task: str) -> jax.Array:
    oof = _oof_select(preds_kn, folds)
    if task == "clf":
        p = jnp.clip(oof, 1e-6, 1 - 1e-6)
        yt = target.astype(jnp.float32)
        return -(yt * jnp.log(p) + (1 - yt) * jnp.log(1 - p)).mean()
    return jnp.square(oof - target.astype(jnp.float32)).mean()


@dataclasses.dataclass(frozen=True)
class TuneResult:
    best_index: int
    best_value: float
    best_score: float
    scores: jax.Array     # (T,) per-trial OOF scores
    values: jax.Array     # (T,) the swept hyper-parameter values


# ---------------------------------------------------------------------------
# Grid search over penalty strength (ridge / logistic): one program for
# the full (T trials × K folds) grid.
# ---------------------------------------------------------------------------

def tune_penalty(task: str, lams: jax.Array, X: jax.Array, target: jax.Array,
                 *, n_folds: int = 5, key: Optional[jax.Array] = None,
                 newton_iters: int = 16, executor="vmap") -> TuneResult:
    key = key if key is not None else jax.random.PRNGKey(0)
    folds = fold_ids(key, X.shape[0], n_folds)
    W = fold_weights(folds, n_folds)
    make = make_logistic if task == "clf" else make_ridge
    proto = make(1.0) if task == "reg" else make(1.0, newton_iters)
    exe = make_executor(executor)

    # (T, K, n) predictions: the trial axis is the C2 population axis,
    # dispatched through the executor (vmap => one double-batched
    # program, exactly Ray Tune's trial pool as SPMD); folds stay
    # vmapped inside each trial.  Data tensors ride as pass-through
    # executor args (compiled-program inputs, not baked constants).
    def trial(lam, X_, target_, W_, folds_):
        st0 = proto.init(key, X_.shape[1])

        def one_fold(w):
            st = proto.fit({**st0, "lam": lam}, X_, target_, w)
            return proto.predict(st, X_)

        preds = jax.vmap(one_fold)(W_)                      # (K, n)
        return _oof_score(preds, folds_, target_, task)

    scores = exe.map(trial, lams, X, target, W, folds)
    best = int(jnp.argmin(scores))
    return TuneResult(best_index=best, best_value=float(lams[best]),
                      best_score=float(scores[best]), scores=scores,
                      values=lams)


# ---------------------------------------------------------------------------
# Successive halving (ASHA-style) for iterative models (MLP nuisances):
# rung r trains the survivors for base_steps * eta^r steps.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HalvingResult:
    best_lr: float
    history: Tuple[Dict, ...]   # per-rung survivor sets + scores


@functools.lru_cache(maxsize=None)
def _halving_trial_fn(task: str, hidden: Tuple[int, ...], steps: int):
    """Stable per-(task, hidden, steps) trial closure.  lr enters as
    mapped DATA (an ``lr`` state leaf overrides make_mlp's baked rate),
    so one rung is ONE executor.map over the trial axis and the
    executor's _JitCache gets the SAME closure object on every call —
    the old per-rung lambda re-traced every rung (and every trial)."""
    nz = make_mlp(task, hidden=hidden, steps=steps)

    def trial(lr, X, target, W, folds, st0):
        def one_fold(w):
            st = nz.fit({**st0, "lr": lr}, X, target, w)
            return nz.predict(st, X)

        preds = jax.vmap(one_fold)(W)                       # (K, n)
        return _oof_score(preds, folds, target, task)

    return trial


def successive_halving(task: str, lrs: jax.Array, X: jax.Array,
                       target: jax.Array, *, n_folds: int = 3,
                       base_steps: int = 25, eta: int = 2, rungs: int = 3,
                       hidden: Tuple[int, ...] = (64,),
                       key: Optional[jax.Array] = None,
                       executor="vmap") -> HalvingResult:
    key = key if key is not None else jax.random.PRNGKey(0)
    folds = fold_ids(key, X.shape[0], n_folds)
    W = fold_weights(folds, n_folds)
    survivors = jnp.arange(lrs.shape[0])
    history = []
    steps = base_steps
    exe = make_executor(executor)
    # init is lr-independent: one state serves every trial and rung
    st0 = make_mlp(task, hidden=hidden, steps=base_steps).init(
        key, X.shape[1])
    for rung in range(rungs):
        cur = lrs[survivors]
        # the trial axis goes through the executor (C2's population
        # axis): the whole rung is one dispatched map over lr values;
        # only a change of ``steps`` (the static scan length) can ever
        # force a new trace, and the closure cache is keyed on it.
        trial = _halving_trial_fn(task, tuple(hidden), steps)
        scores = exe.map(trial, cur, X, target, W, folds, st0)
        order = jnp.argsort(scores)
        keep = max(1, len(survivors) // eta)
        history.append({"rung": rung, "steps": steps,
                        "lrs": cur.tolist(),
                        "scores": [float(s) for s in scores],
                        "kept": [float(cur[i]) for i in order[:keep]]})
        survivors = survivors[order[:keep]]
        steps *= eta
        if len(survivors) == 1:
            break
    return HalvingResult(best_lr=float(lrs[survivors[0]]),
                         history=tuple(history))


def tuned_nuisances(cfg: CausalConfig, X, y, t, key) -> Tuple[Nuisance, Nuisance]:
    """Convenience: grid-tune both penalty nuisances, return the winners
    (what the paper's §5.2 listing does with tune_grid_search_*)."""
    lams = jnp.asarray([1e-4, 1e-3, 1e-2, 1e-1], jnp.float32)
    ky, kt = jax.random.split(key)
    ry = tune_penalty("reg", lams, X, y, n_folds=cfg.n_folds, key=ky)
    rt = tune_penalty("clf" if cfg.discrete_treatment else "reg",
                      lams, X, t, n_folds=cfg.n_folds, key=kt,
                      newton_iters=cfg.newton_iters)
    ny = make_ridge(ry.best_value)
    nt = (make_logistic(rt.best_value, cfg.newton_iters)
          if cfg.discrete_treatment else make_ridge(rt.best_value))
    return ny, nt
