"""Distributed hyper-parameter tuning — the paper's §5.2 contribution (C2).

Ray Tune's trial pool becomes a *population axis*: trials share one
compiled graph and differ only in scalar hyper-parameters, so the whole
(trial × fold) grid is a single double-vmapped program — the entire
sweep is one batched matmul stream on the MXU instead of T·K scheduled
tasks.  For budgeted search, ``successive_halving`` implements the
ASHA-style rung schedule on top (per-rung survivor sets are plain
arrays, so a preempted sweep resumes from the last rung — DESIGN §7).

The (trial × fold) grid is dispatched through ``repro.runtime`` — the
same task scheduler that runs §5.1 fold fits and bootstrap replicates —
so "how iterative steps run" is one swappable choice across all three
paper-parallelized step classes: ``vmap`` (default) batches the sweep
into one program, ``serial`` is the Ray-less loop baseline,
``shard_map`` spreads the axis over the device mesh, and a TaskRuntime
with a memory budget streams it in chunks.  ``successive_halving``'s
rung schedule is a dependent task graph on the runtime's futures.

Scores are out-of-fold (cross-validated) losses: MSE for regression,
log-loss for classification — the same objective Ray Tune's scikit-learn
wrappers report.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import CausalConfig
from repro.core.crossfit import fold_ids, fold_weights, _oof_select
from repro.core.nuisance import Nuisance, make_mlp, make_logistic, make_ridge
from repro.runtime import TaskFuture, as_runtime


def _oof_score(preds_kn: jax.Array, folds: jax.Array, target: jax.Array,
               task: str) -> jax.Array:
    oof = _oof_select(preds_kn, folds)
    if task == "clf":
        p = jnp.clip(oof, 1e-6, 1 - 1e-6)
        yt = target.astype(jnp.float32)
        return -(yt * jnp.log(p) + (1 - yt) * jnp.log(1 - p)).mean()
    return jnp.square(oof - target.astype(jnp.float32)).mean()


@dataclasses.dataclass(frozen=True)
class TuneResult:
    best_index: int
    best_value: float
    best_score: float
    scores: jax.Array     # (T,) per-trial OOF scores
    values: jax.Array     # (T,) the swept hyper-parameter values


# ---------------------------------------------------------------------------
# Grid search over penalty strength (ridge / logistic): one program for
# the full (T trials × K folds) grid.
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _penalty_cell_fn(task: str, newton_iters: int):
    """Stable per-(task, iters) closure for ONE (trial, fold) cell of
    the grid — the unit the scheduler's nested parallelism batches.
    Returns the cell's *summed held-out loss* (a scalar), so the mapped
    output is (T, K) — never a (T, K, n) prediction tensor — and the
    fold-weight matrix rides as ONE shared pass-through arg indexed by
    fold id instead of being tiled T times.  Cached so repeated tune
    calls hand the runtime the same object (compiled-program caches are
    keyed on it)."""
    make = make_logistic if task == "clf" else make_ridge
    proto = make(1.0) if task != "clf" else make(1.0, newton_iters)

    def cell(lam, j, X, target, W, folds, st0):
        st = proto.fit({**st0, "lam": lam}, X, target, W[j])
        pred = proto.predict(st, X)
        yt = target.astype(jnp.float32)
        if task == "clf":
            p = jnp.clip(pred, 1e-6, 1 - 1e-6)
            loss = -(yt * jnp.log(p) + (1 - yt) * jnp.log(1 - p))
        else:
            loss = jnp.square(pred - yt)
        mask = (folds == j).astype(jnp.float32)   # this cell's held-out rows
        return (mask * loss).sum()

    return proto, cell


def tune_penalty(task: str, lams: jax.Array, X: jax.Array, target: jax.Array,
                 *, n_folds: int = 5, key: Optional[jax.Array] = None,
                 newton_iters: int = 16, executor="vmap") -> TuneResult:
    key = key if key is not None else jax.random.PRNGKey(0)
    folds = fold_ids(key, X.shape[0], n_folds)
    W = fold_weights(folds, n_folds)
    proto, cell = _penalty_cell_fn(task, newton_iters)
    rt = as_runtime(executor)

    # the (trial × fold) grid is ONE batched program chosen by the
    # scheduler (runtime.map_product flattens the product onto a single
    # replicate axis — Ray Tune's trial pool AND the fold pool as one
    # SPMD dispatch, chunked if a budget demands).  Mapped inputs are
    # scalars (lam, fold id); data tensors ride as pass-through args
    # (compiled-program inputs, not baked constants); init is
    # lam-independent so one st0 serves the whole grid.  Summing the
    # (T, K) per-fold partial losses reproduces the OOF score: every
    # row's loss enters exactly once, under its held-out fold's model.
    st0 = proto.init(key, X.shape[1])
    cells = rt.map_product(cell, lams, jnp.arange(n_folds), X, target,
                           W, folds, st0, label="tune_penalty")
    scores = cells.sum(axis=1) / X.shape[0]                    # (T,)
    best = int(jnp.argmin(scores))
    return TuneResult(best_index=best, best_value=float(lams[best]),
                      best_score=float(scores[best]), scores=scores,
                      values=lams)


# ---------------------------------------------------------------------------
# Successive halving (ASHA-style) for iterative models (MLP nuisances):
# rung r trains the survivors for base_steps * eta^r steps.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HalvingResult:
    best_lr: float
    history: Tuple[Dict, ...]   # per-rung survivor sets + scores


@functools.lru_cache(maxsize=None)
def _halving_trial_fn(task: str, hidden: Tuple[int, ...], steps: int):
    """Stable per-(task, hidden, steps) trial closure.  lr enters as
    mapped DATA (an ``lr`` state leaf overrides make_mlp's baked rate),
    so one rung is ONE executor.map over the trial axis and the
    executor's _JitCache gets the SAME closure object on every call —
    the old per-rung lambda re-traced every rung (and every trial)."""
    nz = make_mlp(task, hidden=hidden, steps=steps)

    def trial(lr, X, target, W, folds, st0):
        def one_fold(w):
            st = nz.fit({**st0, "lr": lr}, X, target, w)
            return nz.predict(st, X)

        preds = jax.vmap(one_fold)(W)                       # (K, n)
        return _oof_score(preds, folds, target, task)

    return trial


def successive_halving(task: str, lrs: jax.Array, X: jax.Array,
                       target: jax.Array, *, n_folds: int = 3,
                       base_steps: int = 25, eta: int = 2, rungs: int = 3,
                       hidden: Tuple[int, ...] = (64,),
                       key: Optional[jax.Array] = None,
                       executor="vmap") -> HalvingResult:
    """ASHA-style rung schedule expressed as a *dependent task graph*:
    rung r's map task scores the survivors, a host call task selects
    the top 1/eta, and rung r+1's map task consumes that future — the
    whole schedule is submitted up front (survivor-set SIZES are
    deterministic, so the graph is static) and one ``gather`` drives
    it in topological order.  This is Ray Tune's ASHA dependency
    structure on the runtime's futures instead of a hand-ordered
    loop."""
    key = key if key is not None else jax.random.PRNGKey(0)
    folds = fold_ids(key, X.shape[0], n_folds)
    W = fold_weights(folds, n_folds)
    history: list = []
    steps = base_steps
    rt = as_runtime(executor)
    # init is lr-independent: one state serves every trial and rung
    st0 = make_mlp(task, hidden=hidden, steps=base_steps).init(
        key, X.shape[1])

    def _select(rung: int, steps_: int, keep: int):
        def select(cur, scores):
            order = jnp.argsort(scores)
            history.append({"rung": rung, "steps": steps_,
                            "lrs": cur.tolist(),
                            "scores": [float(s) for s in scores],
                            "kept": [float(cur[i]) for i in order[:keep]]})
            return cur[order[:keep]]
        return select

    cur: Any = lrs                      # plain array, then futures
    n_live = int(lrs.shape[0])
    for rung in range(rungs):
        # one map task per rung over the surviving lr values; only a
        # change of ``steps`` (the static scan length) can ever force a
        # new trace, and the closure cache is keyed on it.
        trial = _halving_trial_fn(task, tuple(hidden), steps)
        scores = rt.submit(trial, cur, X, target, W, folds, st0,
                           label=f"halving_rung{rung}")
        keep = max(1, n_live // eta)
        cur = rt.call(_select(rung, steps, keep), cur, scores,
                      label=f"halving_select{rung}")
        n_live = keep
        steps *= eta
        if n_live == 1:
            break
    # rungs <= 0 builds no graph: cur is still the plain lrs array
    final = rt.gather(cur) if isinstance(cur, TaskFuture) else cur
    return HalvingResult(best_lr=float(final[0]), history=tuple(history))


def tuned_nuisances(cfg: CausalConfig, X, y, t, key) -> Tuple[Nuisance, Nuisance]:
    """Convenience: grid-tune both penalty nuisances, return the winners
    (what the paper's §5.2 listing does with tune_grid_search_*)."""
    lams = jnp.asarray([1e-4, 1e-3, 1e-2, 1e-1], jnp.float32)
    ky, kt = jax.random.split(key)
    ry = tune_penalty("reg", lams, X, y, n_folds=cfg.n_folds, key=ky)
    rt = tune_penalty("clf" if cfg.discrete_treatment else "reg",
                      lams, X, t, n_folds=cfg.n_folds, key=kt,
                      newton_iters=cfg.newton_iters)
    return (_tuned_winner(cfg, "reg", ry),
            _tuned_winner(cfg, "clf" if cfg.discrete_treatment else "reg",
                          rt))


def _tuned_winner(cfg: CausalConfig, task: str, res: TuneResult
                  ) -> Nuisance:
    """Build the winning nuisance with the cfg's streaming-memory
    settings threaded through — tuned winners honor the same row_block
    contract cfg-built nuisances do."""
    if task == "clf":
        return make_logistic(res.best_value, cfg.newton_iters,
                             row_block=cfg.row_block,
                             strategy=cfg.row_block_strategy)
    return make_ridge(res.best_value, row_block=cfg.row_block,
                      strategy=cfg.row_block_strategy)


def tuned_iv_nuisances(cfg: CausalConfig, X, y, t, z, key,
                       executor="vmap"
                       ) -> Tuple[Nuisance, Nuisance, Nuisance]:
    """Grid-tune the orthogonal-IV nuisance triple (E[Y|X], E[T|X],
    E[Z|X]).  Each penalty sweep is one (trial × fold) ``map_product``
    grid through the task runtime — three flattened-product programs,
    not 3·T·K scheduled tasks."""
    lams = jnp.asarray([1e-4, 1e-3, 1e-2, 1e-1], jnp.float32)
    ky, kt, kz = jax.random.split(key, 3)
    ry = tune_penalty("reg", lams, X, y, n_folds=cfg.n_folds, key=ky,
                      executor=executor)
    t_task = "clf" if cfg.discrete_treatment else "reg"
    z_task = "clf" if cfg.discrete_instrument else "reg"
    rt = tune_penalty(t_task, lams, X, t, n_folds=cfg.n_folds, key=kt,
                      newton_iters=cfg.newton_iters, executor=executor)
    rz = tune_penalty(z_task, lams, X, z, n_folds=cfg.n_folds, key=kz,
                      newton_iters=cfg.newton_iters, executor=executor)
    return (_tuned_winner(cfg, "reg", ry), _tuned_winner(cfg, t_task, rt),
            _tuned_winner(cfg, z_task, rz))
