"""Distributed hyper-parameter tuning — the paper's §5.2 contribution (C2).

Ray Tune's trial pool becomes a *population axis*: trials share one
compiled graph and differ only in scalar hyper-parameters, so the whole
(trial × fold) grid is a single double-vmapped program — the entire
sweep is one batched matmul stream on the MXU instead of T·K scheduled
tasks.  For budgeted search, ``successive_halving`` implements the
ASHA-style rung schedule on top (per-rung survivor sets are plain
arrays, so a preempted sweep resumes from the last rung — DESIGN §7).

Scores are out-of-fold (cross-validated) losses: MSE for regression,
log-loss for classification — the same objective Ray Tune's scikit-learn
wrappers report.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.config import CausalConfig
from repro.core.crossfit import fold_ids, fold_weights, _oof_select
from repro.core.nuisance import Nuisance, make_mlp, make_logistic, make_ridge


def _oof_score(preds_kn: jax.Array, folds: jax.Array, target: jax.Array,
               task: str) -> jax.Array:
    oof = _oof_select(preds_kn, folds)
    if task == "clf":
        p = jnp.clip(oof, 1e-6, 1 - 1e-6)
        yt = target.astype(jnp.float32)
        return -(yt * jnp.log(p) + (1 - yt) * jnp.log(1 - p)).mean()
    return jnp.square(oof - target.astype(jnp.float32)).mean()


@dataclasses.dataclass(frozen=True)
class TuneResult:
    best_index: int
    best_value: float
    best_score: float
    scores: jax.Array     # (T,) per-trial OOF scores
    values: jax.Array     # (T,) the swept hyper-parameter values


# ---------------------------------------------------------------------------
# Grid search over penalty strength (ridge / logistic): one program for
# the full (T trials × K folds) grid.
# ---------------------------------------------------------------------------

def tune_penalty(task: str, lams: jax.Array, X: jax.Array, target: jax.Array,
                 *, n_folds: int = 5, key: Optional[jax.Array] = None,
                 newton_iters: int = 16) -> TuneResult:
    key = key if key is not None else jax.random.PRNGKey(0)
    folds = fold_ids(key, X.shape[0], n_folds)
    W = fold_weights(folds, n_folds)
    make = make_logistic if task == "clf" else make_ridge
    proto = make(1.0) if task == "reg" else make(1.0, newton_iters)

    def fit_one(lam, w):
        st = proto.init(key, X.shape[1])
        st = {**st, "lam": lam}
        st = proto.fit(st, X, target, w)
        return proto.predict(st, X)

    # (T, K, n) predictions in one program: vmap over trials of vmap
    # over folds — the C2 population axis.
    preds = jax.vmap(lambda lam: jax.vmap(lambda w: fit_one(lam, w))(W))(lams)
    scores = jax.vmap(lambda p: _oof_score(p, folds, target, task))(preds)
    best = int(jnp.argmin(scores))
    return TuneResult(best_index=best, best_value=float(lams[best]),
                      best_score=float(scores[best]), scores=scores,
                      values=lams)


# ---------------------------------------------------------------------------
# Successive halving (ASHA-style) for iterative models (MLP nuisances):
# rung r trains the survivors for base_steps * eta^r steps.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HalvingResult:
    best_lr: float
    history: Tuple[Dict, ...]   # per-rung survivor sets + scores


def successive_halving(task: str, lrs: jax.Array, X: jax.Array,
                       target: jax.Array, *, n_folds: int = 3,
                       base_steps: int = 25, eta: int = 2, rungs: int = 3,
                       hidden: Tuple[int, ...] = (64,),
                       key: Optional[jax.Array] = None) -> HalvingResult:
    key = key if key is not None else jax.random.PRNGKey(0)
    folds = fold_ids(key, X.shape[0], n_folds)
    W = fold_weights(folds, n_folds)
    survivors = jnp.arange(lrs.shape[0])
    history = []
    steps = base_steps
    for rung in range(rungs):
        cur = lrs[survivors]
        # lr is a python closure of make_mlp (it parameterizes the jitted
        # scan), so trials within a rung are a python loop of fits whose
        # FOLD axis is vmapped — rung sizes shrink geometrically, so the
        # loop is short; fold concurrency is where the batching pays.
        scores = []
        for lr in cur.tolist():
            nz = make_mlp(task, hidden=hidden, steps=steps, lr=lr)
            st0 = nz.init(key, X.shape[1])
            preds = jax.vmap(lambda w: nz.predict(nz.fit(st0, X, target, w),
                                                  X))(W)
            scores.append(_oof_score(preds, folds, target, task))
        scores = jnp.stack(scores)
        order = jnp.argsort(scores)
        keep = max(1, len(survivors) // eta)
        history.append({"rung": rung, "steps": steps,
                        "lrs": cur.tolist(),
                        "scores": [float(s) for s in scores],
                        "kept": [float(cur[i]) for i in order[:keep]]})
        survivors = survivors[order[:keep]]
        steps *= eta
        if len(survivors) == 1:
            break
    return HalvingResult(best_lr=float(lrs[survivors[0]]),
                         history=tuple(history))


def tuned_nuisances(cfg: CausalConfig, X, y, t, key) -> Tuple[Nuisance, Nuisance]:
    """Convenience: grid-tune both penalty nuisances, return the winners
    (what the paper's §5.2 listing does with tune_grid_search_*)."""
    lams = jnp.asarray([1e-4, 1e-3, 1e-2, 1e-1], jnp.float32)
    ky, kt = jax.random.split(key)
    ry = tune_penalty("reg", lams, X, y, n_folds=cfg.n_folds, key=ky)
    rt = tune_penalty("clf" if cfg.discrete_treatment else "reg",
                      lams, X, t, n_folds=cfg.n_folds, key=kt,
                      newton_iters=cfg.newton_iters)
    ny = make_ridge(ry.best_value)
    nt = (make_logistic(rt.best_value, cfg.newton_iters)
          if cfg.discrete_treatment else make_ridge(rt.best_value))
    return ny, nt
