"""Orthogonal final stage: the Neyman-orthogonal moment solved as
(distributed) normal equations on residuals.

    ry = y - m_y(X),  rt = t - m_t(X),  Z = rt ⊙ phi(X)
    theta = argmin  Σ (ry - <theta, phi>·rt)²   ⇒   (ZᵀZ)θ = Zᵀry

All sufficient statistics come from the streaming moments engine
(repro.core.moments).  Two memory regimes:

  row_block = 0   whole-array: the fused Pallas ``residual_gram``
                  kernel (HBM→VMEM, one pass) computes G/b, and the
                  HC0 meat is a dense einsum over the materialized
                  (n, p_phi) moment matrix Z — fastest when Z fits.
  row_block = R   chunked: a ``lax.scan`` over row blocks streams BOTH
                  passes (G/b, then the meat at the solved theta), so
                  the dense Z and the residual vector never
                  materialize — peak temporaries are O(R·p_phi), which
                  is what lets n exceed a single-allocation budget
                  (paper §5.3 "industrial scale").  Each block is
                  constrained on the ``rows`` mesh axis; the (p,p)
                  moments are the only thing reduced — the same shape
                  as Ray's driver-side aggregation but executed as one
                  psum.  ``strategy="pallas"`` keeps the same two-pass
                  structure but takes each pass through the fused
                  seg_gram kernel (one HBM pass per moment; the
                  measured CPU lowering closes the chunked-vs-whole
                  runtime gap at n=100k — benchmarks/bench_final_stage).

Inference: heteroskedasticity-robust (HC0) sandwich covariance, matching
EconML's ``StatsModelsLinearRegression`` final stage.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import moments
from repro.kernels.residual_gram import ops as rg_ops


def cate_basis(X: jax.Array, n_features: int) -> jax.Array:
    """phi(x): [1] (ATE / constant effect) or [1, x_0..x_{m-1}]."""
    n = X.shape[0]
    ones = jnp.ones((n, 1), jnp.float32)
    if n_features <= 1:
        return ones
    return jnp.concatenate([ones, X[:, : n_features - 1].astype(jnp.float32)],
                           axis=1)


@dataclasses.dataclass(frozen=True)
class FinalStageResult:
    theta: jax.Array       # (p_phi,)
    cov: jax.Array         # (p_phi, p_phi) HC0 sandwich
    gram: jax.Array        # (p_phi, p_phi) ZᵀZ / n
    n: int

    @property
    def stderr(self) -> jax.Array:
        return jnp.sqrt(jnp.diag(self.cov))


def fit_final_stage(y: jax.Array, t: jax.Array, my: jax.Array,
                    mt: jax.Array, phi: jax.Array, *,
                    ridge: float = 1e-8, backend: str = "",
                    row_block: int = 0, strategy: Optional[str] = None,
                    rules=None) -> FinalStageResult:
    """Solve the orthogonal moment.  y,t,my,mt: (n,); phi: (n, p_phi).

    ``row_block > 0`` streams every moment in fixed-order row blocks
    (see module docstring); chunked and "whole" blocked evaluation of
    the same row_block are bit-identical by construction."""
    n, p = phi.shape
    r = moments.resolve_row_block(n, row_block)
    if r > 0:
        G, b = moments.residual_moments(y, t, my, mt, phi, row_block=r,
                                        strategy=strategy, rules=rules,
                                        backend=backend)
        A = G + ridge * n * jnp.eye(p, dtype=jnp.float32)
        theta = jnp.linalg.solve(A, b)
        meat = moments.residual_meat(y, t, my, mt, phi, theta,
                                     row_block=r, strategy=strategy,
                                     rules=rules)
        Ainv = jnp.linalg.inv(A)
        cov = Ainv @ meat @ Ainv
        return FinalStageResult(theta=theta, cov=cov, gram=G / n, n=n)

    G, b = rg_ops.residual_gram(y, t, my, mt, phi, backend=backend)
    A = G + ridge * n * jnp.eye(p, dtype=jnp.float32)
    theta = jnp.linalg.solve(A, b)

    # HC0 sandwich: cov = G⁻¹ (Zᵀ diag(e²) Z) G⁻¹
    ry = (y - my).astype(jnp.float32)
    rt = (t - mt).astype(jnp.float32)
    z = rt[:, None] * phi.astype(jnp.float32)
    e = ry - z @ theta
    meat = jnp.einsum("ni,n,nj->ij", z, jnp.square(e), z)
    Ainv = jnp.linalg.inv(A)
    cov = Ainv @ meat @ Ainv
    return FinalStageResult(theta=theta, cov=cov, gram=G / n, n=n)
