"""Orthogonal final stage: the Neyman-orthogonal moment solved as
(distributed) normal equations on residuals.

    ry = y - m_y(X),  rt = t - m_t(X),  Z = rt ⊙ phi(X)
    theta = argmin  Σ (ry - <theta, phi>·rt)²   ⇒   (ZᵀZ)θ = Zᵀry

At the paper's scale (n=1M, p≈500) the moments are the bandwidth hot
spot; the fused Pallas ``residual_gram`` kernel streams each row once
(HBM→VMEM) and accumulates G/b in VMEM.  Rows are sharded over the
``data`` mesh axis; the (p,p) moments are the only thing reduced — the
same shape as Ray's driver-side aggregation but executed as one psum.

Inference: heteroskedasticity-robust (HC0) sandwich covariance, matching
EconML's ``StatsModelsLinearRegression`` final stage.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.residual_gram import ops as rg_ops


def cate_basis(X: jax.Array, n_features: int) -> jax.Array:
    """phi(x): [1] (ATE / constant effect) or [1, x_0..x_{m-1}]."""
    n = X.shape[0]
    ones = jnp.ones((n, 1), jnp.float32)
    if n_features <= 1:
        return ones
    return jnp.concatenate([ones, X[:, : n_features - 1].astype(jnp.float32)],
                           axis=1)


@dataclasses.dataclass(frozen=True)
class FinalStageResult:
    theta: jax.Array       # (p_phi,)
    cov: jax.Array         # (p_phi, p_phi) HC0 sandwich
    gram: jax.Array        # (p_phi, p_phi) ZᵀZ / n
    n: int

    @property
    def stderr(self) -> jax.Array:
        return jnp.sqrt(jnp.diag(self.cov))


def fit_final_stage(y: jax.Array, t: jax.Array, my: jax.Array,
                    mt: jax.Array, phi: jax.Array, *,
                    ridge: float = 1e-8, backend: str = ""
                    ) -> FinalStageResult:
    """Solve the orthogonal moment.  y,t,my,mt: (n,); phi: (n, p_phi)."""
    n, p = phi.shape
    G, b = rg_ops.residual_gram(y, t, my, mt, phi, backend=backend)
    A = G + ridge * n * jnp.eye(p, dtype=jnp.float32)
    theta = jnp.linalg.solve(A, b)

    # HC0 sandwich: cov = G⁻¹ (Zᵀ diag(e²) Z) G⁻¹
    ry = (y - my).astype(jnp.float32)
    rt = (t - mt).astype(jnp.float32)
    z = rt[:, None] * phi.astype(jnp.float32)
    e = ry - z @ theta
    meat = jnp.einsum("ni,n,nj->ij", z, jnp.square(e), z)
    Ainv = jnp.linalg.inv(A)
    cov = Ainv @ meat @ Ainv
    return FinalStageResult(theta=theta, cov=cov, gram=G / n, n=n)
