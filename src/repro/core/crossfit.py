"""Distributed cross-fitting — the paper's §5.1 contribution (C1).

EconML runs the K out-of-fold nuisance fits as a sequential loop (or
joblib threads); the paper's DML_Ray turns each fold into a Ray task.
On a TPU pod the equivalent concurrency is *SPMD batching*: the K fits
are stacked on a leading fold axis and batched into one compiled
program — every fold trains simultaneously, sharing each row's bandwidth
(fold masks select the complement), with GSPMD sharding rows over the
``data`` mesh axis.

"How the K fold fits run" is dispatched through the same ``Executor``
protocol (repro.inference.executor) that schedules tuning trials and
bootstrap replicates — ONE swappable knob for every paper-parallelized
step class.  ``engine="parallel"`` maps the fold axis through the
``vmap`` executor (the Ray-task-pool translation); ``"sequential"``
maps it through ``serial`` — the EconML-style baseline for
benchmarks/bench_crossfit (paper Fig. 6) — with no bespoke Python loop
of its own.

Determinism: fold assignment and per-fold init keys derive from one base
key — the lineage that makes checkpoint-restart replay exact (DESIGN §7).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core.nuisance import Nuisance
from repro.distributed.sharding import constrain


def fold_ids(key: jax.Array, n: int, k: int) -> jax.Array:
    """Balanced random fold assignment in [0, k)."""
    base = jnp.arange(n, dtype=jnp.int32) % k
    return jax.random.permutation(key, base)


def fold_weights(folds: jax.Array, k: int) -> jax.Array:
    """(k, n) training weights: w[j, i] = 1.0 iff sample i is OUTSIDE
    fold j (cross-fitting trains on the complement)."""
    return (folds[None, :] != jnp.arange(k, dtype=folds.dtype)[:, None]
            ).astype(jnp.float32)


def _oof_select(preds_kn: jax.Array, folds: jax.Array) -> jax.Array:
    """preds_kn: (k, n) predictions of every fold-model on every row.
    Row i keeps the prediction of model folds[i] — its held-out model."""
    return jnp.take_along_axis(preds_kn, folds[None, :], axis=0)[0]


@functools.lru_cache(maxsize=128)
def _fold_fit_fn(nuis: Nuisance):
    """The per-fold fit closure mapped by the Executor.  Cached per
    Nuisance so repeated crossfit calls hand the SAME closure object to
    the executor — its compiled-program cache is keyed on it (a fresh
    lambda per call would re-trace every fit)."""

    def fold_fit(xs, X, target):
        st = nuis.fit(nuis.init(xs["key"], X.shape[1]), X, target,
                      xs["w"])
        return nuis.predict(st, X), st

    return fold_fit


def _crossfit_engine(nuis: Nuisance, keys: jax.Array, X: jax.Array,
                     target: jax.Array, folds: jax.Array, k: int,
                     rules, executor) -> Tuple[jax.Array, Any]:
    """The shared fold-fit dispatch: the fold axis (init keys + fold-
    complement weights) maps through the task runtime, so fold fits,
    tuning trials, and bootstrap replicates all run through one "how
    iterative steps run" knob — with the runtime's chunking and
    backend-downgrade ladder available to the fold axis too (pass a
    TaskRuntime as ``executor`` to set a budget, or one carrying a
    repro.obs Tracer to get labelled crossfit spans with the fold-fit
    chunk spans nested inside)."""
    from repro.obs.trace import maybe_span
    from repro.runtime import as_runtime
    rt = as_runtime(executor, rules=rules)
    W = fold_weights(folds, k)                      # (k, n)
    label = f"crossfit:{nuis.name}"
    with maybe_span(rt.tracer, label, cat="crossfit", k=k,
                    n=int(X.shape[0]), backend=rt.name):
        preds, states = rt.map(_fold_fit_fn(nuis), {"key": keys, "w": W},
                               X, target, label=label)
        if rt.tracer is not None:
            rt.tracer.sync((preds, states))
    preds = constrain(preds, ("fold", "batch"), rules)
    return _oof_select(preds, folds), states


def crossfit_parallel(nuis: Nuisance, key: jax.Array, X: jax.Array,
                      target: jax.Array, folds: jax.Array, k: int,
                      rules=None, executor="vmap") -> Tuple[jax.Array, Any]:
    """C1: all K fold-fits in ONE batched program (the Ray-tasks
    translation).  Returns (out-of-fold predictions (n,), states)."""
    keys = jax.random.split(key, k)
    return _crossfit_engine(nuis, keys, X, target, folds, k, rules,
                            executor)


def crossfit_parallel_loo(nuis: Nuisance, key: jax.Array, X: jax.Array,
                          target: jax.Array, folds: jax.Array, k: int,
                          rules=None, mm_iters: int = 32):
    """C1+ (beyond-paper, EXPERIMENTS §Perf): the leave-one-out Gram
    identity collapses the K complement fits to ONE fold-segmented
    moments pass over X (row-blocked when the nuisance carries a
    ``row_block`` hyper).  Exact for ridge; fixed-majorizer MM for
    logistic (same optimum).  Falls back to the vmap engine for
    non-linear nuisances."""
    from repro.core.nuisance import logistic_fit_folds, ridge_fit_folds
    p = X.shape[1]
    lam = (nuis.init(key, p)["lam"]
           if nuis.name in ("ridge", "logistic") else 0.0)
    rb = (nuis.hyper or {}).get("row_block", 0)
    st = (nuis.hyper or {}).get("strategy", None)
    if nuis.name == "ridge":
        states = ridge_fit_folds(lam, X, target, folds, k, row_block=rb,
                                 strategy=st)
    elif nuis.name == "logistic":
        states = logistic_fit_folds(lam, mm_iters, X, target, folds, k,
                                    row_block=rb, strategy=st)
    else:
        return crossfit_parallel(nuis, key, X, target, folds, k, rules)
    preds = jax.vmap(nuis.predict, in_axes=(0, None))(states, X)
    preds = constrain(preds, ("fold", "batch"), rules)
    return _oof_select(preds, folds), states


def crossfit_sequential(nuis: Nuisance, key: jax.Array, X: jax.Array,
                        target: jax.Array, folds: jax.Array, k: int
                        ) -> Tuple[jax.Array, Any]:
    """EconML-style baseline: one fit per fold, strictly in sequence —
    the ``serial`` Executor (one compiled program per fold, like K
    Ray-less workers); the bespoke Python loop this function used to
    carry is gone.  Per-fold init keys keep the legacy
    ``fold_in(key, j)`` lineage."""
    keys = jax.vmap(lambda j: jax.random.fold_in(key, j))(
        jnp.arange(k, dtype=jnp.uint32))
    return _crossfit_engine(nuis, keys, X, target, folds, k, None,
                            "serial")


@dataclasses.dataclass(frozen=True)
class CrossfitResult:
    oof_y: jax.Array      # (n,) out-of-fold E[Y|X]
    oof_t: jax.Array      # (n,) out-of-fold E[T|X] (propensity if binary)
    folds: jax.Array      # (n,) fold assignment
    states_y: Any
    states_t: Any


def crossfit_one(nuis: Nuisance, key: jax.Array, X: jax.Array,
                 target: jax.Array, folds: jax.Array, k: int,
                 engine: str = "parallel", rules=None
                 ) -> Tuple[jax.Array, Any]:
    """Engine dispatch for ONE cross-fit target over a fixed fold
    assignment — the unit `crossfit` composes twice and the IV
    estimators (three nuisances: E[Y|X], E[T|X], E[Z|X]) compose three
    or four times.  engine: "parallel" (paper C1) maps the fold axis
    through ``vmap``; "sequential" through ``serial``; "parallel_loo"
    takes the one-pass LOO-Gram fast path; any other executor name or
    Executor/TaskRuntime instance maps the fold axis directly."""
    if engine == "parallel_loo":
        return crossfit_parallel_loo(nuis, key, X, target, folds, k, rules)
    if engine == "sequential":
        return crossfit_sequential(nuis, key, X, target, folds, k)
    exe = "vmap" if engine == "parallel" else engine
    return crossfit_parallel(nuis, key, X, target, folds, k, rules,
                             executor=exe)


def crossfit(nuis_y: Nuisance, nuis_t: Nuisance, key: jax.Array,
             X: jax.Array, y: jax.Array, t: jax.Array, k: int,
             engine: str = "parallel", rules=None) -> CrossfitResult:
    """Cross-fit both nuisances.  engine: "parallel" (paper) dispatches
    the 2·K fits through the ``vmap`` Executor; "sequential" (EconML
    baseline) through ``serial``; "parallel_loo" takes the one-pass
    LOO-Gram fast path.  Any other executor name (e.g. "shard_map") or
    Executor instance maps the fold axis directly."""
    kf, ky, kt = jax.random.split(key, 3)
    folds = fold_ids(kf, X.shape[0], k)
    oof_y, st_y = crossfit_one(nuis_y, ky, X, y, folds, k, engine, rules)
    oof_t, st_t = crossfit_one(nuis_t, kt, X, t, folds, k, engine, rules)
    return CrossfitResult(oof_y=oof_y, oof_t=oof_t, folds=folds,
                          states_y=st_y, states_t=st_t)
