"""Distributed cross-fitting — the paper's §5.1 contribution (C1).

EconML runs the K out-of-fold nuisance fits as a sequential loop (or
joblib threads); the paper's DML_Ray turns each fold into a Ray task.
On a TPU pod the equivalent concurrency is *SPMD batching*: the K fits
are stacked on a leading fold axis and vmapped into one compiled
program — every fold trains simultaneously, sharing each row's bandwidth
(fold masks select the complement), with GSPMD sharding rows over the
``data`` mesh axis.  ``crossfit_sequential`` keeps the EconML-style loop
as the runtime baseline for benchmarks/bench_crossfit (paper Fig. 6).

Determinism: fold assignment and per-fold init keys derive from one base
key — the lineage that makes checkpoint-restart replay exact (DESIGN §7).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.nuisance import Nuisance
from repro.distributed.sharding import constrain


def fold_ids(key: jax.Array, n: int, k: int) -> jax.Array:
    """Balanced random fold assignment in [0, k)."""
    base = jnp.arange(n, dtype=jnp.int32) % k
    return jax.random.permutation(key, base)


def fold_weights(folds: jax.Array, k: int) -> jax.Array:
    """(k, n) training weights: w[j, i] = 1.0 iff sample i is OUTSIDE
    fold j (cross-fitting trains on the complement)."""
    return (folds[None, :] != jnp.arange(k, dtype=folds.dtype)[:, None]
            ).astype(jnp.float32)


def _oof_select(preds_kn: jax.Array, folds: jax.Array) -> jax.Array:
    """preds_kn: (k, n) predictions of every fold-model on every row.
    Row i keeps the prediction of model folds[i] — its held-out model."""
    return jnp.take_along_axis(preds_kn, folds[None, :], axis=0)[0]


def crossfit_parallel(nuis: Nuisance, key: jax.Array, X: jax.Array,
                      target: jax.Array, folds: jax.Array, k: int,
                      rules=None) -> Tuple[jax.Array, Any]:
    """C1: all K fold-fits in ONE batched program (the Ray-tasks
    translation).  Returns (out-of-fold predictions (n,), states)."""
    p = X.shape[1]
    keys = jax.random.split(key, k)
    states0 = jax.vmap(nuis.init, in_axes=(0, None))(keys, p)
    W = fold_weights(folds, k)                      # (k, n)
    states = jax.vmap(nuis.fit, in_axes=(0, None, None, 0))(
        states0, X, target, W)
    preds = jax.vmap(nuis.predict, in_axes=(0, None))(states, X)  # (k, n)
    preds = constrain(preds, ("fold", "batch"), rules)
    return _oof_select(preds, folds), states


def crossfit_parallel_loo(nuis: Nuisance, key: jax.Array, X: jax.Array,
                          target: jax.Array, folds: jax.Array, k: int,
                          rules=None, mm_iters: int = 32):
    """C1+ (beyond-paper, EXPERIMENTS §Perf): the leave-one-out Gram
    identity collapses the K complement fits to ONE pass over X.  Exact
    for ridge; fixed-majorizer MM for logistic (same optimum).  Falls
    back to the vmap engine for non-linear nuisances."""
    from repro.core.nuisance import logistic_fit_folds, ridge_fit_folds
    p = X.shape[1]
    lam = (nuis.init(key, p)["lam"]
           if nuis.name in ("ridge", "logistic") else 0.0)
    if nuis.name == "ridge":
        states = ridge_fit_folds(lam, X, target, folds, k)
    elif nuis.name == "logistic":
        states = logistic_fit_folds(lam, mm_iters, X, target, folds, k)
    else:
        return crossfit_parallel(nuis, key, X, target, folds, k, rules)
    preds = jax.vmap(nuis.predict, in_axes=(0, None))(states, X)
    preds = constrain(preds, ("fold", "batch"), rules)
    return _oof_select(preds, folds), states


def crossfit_sequential(nuis: Nuisance, key: jax.Array, X: jax.Array,
                        target: jax.Array, folds: jax.Array, k: int
                        ) -> Tuple[jax.Array, list]:
    """EconML-style baseline: one fit per fold, strictly in sequence
    (each fold is its own compiled program, like one Ray-less worker)."""
    n = X.shape[0]
    W = fold_weights(folds, k)
    oof = jnp.zeros((n,), jnp.float32)
    states = []
    fit = jax.jit(nuis.fit)
    predict = jax.jit(nuis.predict)
    for j in range(k):
        st = fit(nuis.init(jax.random.fold_in(key, j), X.shape[1]),
                 X, target, W[j])
        pj = predict(st, X)
        oof = jnp.where(folds == j, pj, oof)
        states.append(st)
    return oof, states


@dataclasses.dataclass(frozen=True)
class CrossfitResult:
    oof_y: jax.Array      # (n,) out-of-fold E[Y|X]
    oof_t: jax.Array      # (n,) out-of-fold E[T|X] (propensity if binary)
    folds: jax.Array      # (n,) fold assignment
    states_y: Any
    states_t: Any


def crossfit(nuis_y: Nuisance, nuis_t: Nuisance, key: jax.Array,
             X: jax.Array, y: jax.Array, t: jax.Array, k: int,
             engine: str = "parallel", rules=None) -> CrossfitResult:
    """Cross-fit both nuisances.  engine: "parallel" (paper) runs the
    2·K fits concurrently; "sequential" (EconML baseline) loops."""
    kf, ky, kt = jax.random.split(key, 3)
    folds = fold_ids(kf, X.shape[0], k)
    if engine == "parallel":
        oof_y, st_y = crossfit_parallel(nuis_y, ky, X, y, folds, k, rules)
        oof_t, st_t = crossfit_parallel(nuis_t, kt, X, t, folds, k, rules)
    elif engine == "parallel_loo":
        oof_y, st_y = crossfit_parallel_loo(nuis_y, ky, X, y, folds, k, rules)
        oof_t, st_t = crossfit_parallel_loo(nuis_t, kt, X, t, folds, k, rules)
    elif engine == "sequential":
        oof_y, st_y = crossfit_sequential(nuis_y, ky, X, y, folds, k)
        oof_t, st_t = crossfit_sequential(nuis_t, kt, X, t, folds, k)
    else:
        raise ValueError(engine)
    return CrossfitResult(oof_y=oof_y, oof_t=oof_t, folds=folds,
                          states_y=st_y, states_t=st_t)
