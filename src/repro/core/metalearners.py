"""Metalearners (Künzel et al. 2019) — the S/T/X baselines the paper
cites in §2.2, built on the nuisance zoo so the same fold/population
batching applies.

  S-learner: one model of E[Y | X, T];  τ(x) = f(x,1) - f(x,0)
  T-learner: per-arm models;            τ(x) = m1(x) - m0(x)
  X-learner: imputed per-arm effects blended by the propensity
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.nuisance import Nuisance, make_logistic, make_ridge


@dataclasses.dataclass(frozen=True)
class MetaResult:
    ate: float
    cate: jax.Array  # (n,)


def _fit_predict(nuis: Nuisance, key, X, y, w, X_eval):
    st = nuis.fit(nuis.init(key, X.shape[1]), X, y, w)
    return nuis.predict(st, X_eval)


def s_learner(y, t, X, *, nuisance: Optional[Nuisance] = None,
              key=None) -> MetaResult:
    key = key if key is not None else jax.random.PRNGKey(0)
    nuis = nuisance or make_ridge(1e-3)
    tt = t.astype(jnp.float32)[:, None]
    Xt = jnp.concatenate([X, tt, X * tt], axis=1)  # treatment interactions
    ones = jnp.ones((X.shape[0],), jnp.float32)
    st = nuis.fit(nuis.init(key, Xt.shape[1]), Xt, y, ones)
    X1 = jnp.concatenate([X, jnp.ones_like(tt), X], axis=1)
    X0 = jnp.concatenate([X, jnp.zeros_like(tt), jnp.zeros_like(X)], axis=1)
    cate = nuis.predict(st, X1) - nuis.predict(st, X0)
    return MetaResult(ate=float(cate.mean()), cate=cate)


def t_learner(y, t, X, *, nuisance: Optional[Nuisance] = None,
              key=None) -> MetaResult:
    key = key if key is not None else jax.random.PRNGKey(0)
    nuis = nuisance or make_ridge(1e-3)
    k0, k1 = jax.random.split(key)
    tt = t.astype(jnp.float32)
    m1 = _fit_predict(nuis, k1, X, y, tt, X)
    m0 = _fit_predict(nuis, k0, X, y, 1.0 - tt, X)
    cate = m1 - m0
    return MetaResult(ate=float(cate.mean()), cate=cate)


def x_learner(y, t, X, *, nuisance: Optional[Nuisance] = None,
              propensity: Optional[Nuisance] = None, key=None,
              clip: float = 0.01) -> MetaResult:
    key = key if key is not None else jax.random.PRNGKey(0)
    nuis = nuisance or make_ridge(1e-3)
    prop = propensity or make_logistic(1e-3)
    k0, k1, k2, k3, ke = jax.random.split(key, 5)
    tt = t.astype(jnp.float32)

    # stage 1: per-arm outcome models
    m1 = _fit_predict(nuis, k1, X, y, tt, X)
    m0 = _fit_predict(nuis, k0, X, y, 1.0 - tt, X)

    # stage 2: imputed individual effects, learned per arm
    d_treated = y - m0          # valid on treated rows
    d_control = m1 - y          # valid on control rows
    tau1 = _fit_predict(nuis, k2, X, d_treated, tt, X)
    tau0 = _fit_predict(nuis, k3, X, d_control, 1.0 - tt, X)

    # stage 3: propensity-weighted blend
    ones = jnp.ones((X.shape[0],), jnp.float32)
    e = jnp.clip(_fit_predict(prop, ke, X, tt, ones, X), clip, 1 - clip)
    cate = e * tau0 + (1.0 - e) * tau1
    return MetaResult(ate=float(cate.mean()), cate=cate)
