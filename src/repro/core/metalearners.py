"""Metalearners (Künzel et al. 2019) — the S/T/X baselines the paper
cites in §2.2, built on the nuisance zoo so the same fold/population
batching applies.

  S-learner: one model of E[Y | X, T];  τ(x) = f(x,1) - f(x,0)
  T-learner: per-arm models;            τ(x) = m1(x) - m0(x)
  X-learner: imputed per-arm effects blended by the propensity

Every learner body is a *weighted* core ``(key, y, t, X, w) -> (ate,
cate)``: the public fits run it at w = 1, bootstrap replicates
(``meta_bootstrap``) at resampling weights, and the sweep subsystem
(repro.sweep) at per-segment masks — one program shape for all three.
Ridge/logistic stages route through the replicate-invariant kernels of
``repro.inference.numerics`` (a singleton fold axis), so metalearner
replicates and sweep cells hold the same serial ≡ vmap bit-identity
contract as every other estimator; custom nuisances fall back to
``nuis.fit`` (statistically identical, bit-identity not guaranteed).

Fits return ``MetaResult`` (an ``EffectResult``): metalearners now
carry ``ate_interval`` / ``inference`` like the rest of the catalogue.
Their CATE is not linear in a phi basis, so only the ATE functional has
replicate intervals.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import CausalConfig
from repro.core.estimator import EffectResult, inf_cache_field, resolve_scheme
from repro.core.nuisance import Nuisance, make_logistic, make_ridge


def _hyper(nuis: Nuisance, name: str, default):
    h = getattr(nuis, "hyper", None) or {}
    return h.get(name, default)


def _wfit_predict(nuis: Nuisance, key, X, target, w):
    """Weighted single fit -> predict callable.  ridge/logistic take the
    replicate-invariant fold-batched kernels with a singleton fold axis
    (serial == vmap bitwise — what lets sweep cells and bootstrap
    replicates batch); other nuisances fall back to ``nuis.fit``."""
    from repro.inference.numerics import (logistic_fit_folds_w,
                                          predict_folds_linear,
                                          predict_folds_logistic,
                                          ridge_fit_folds_w)
    rb = int(_hyper(nuis, "row_block", 0))
    if nuis.name == "ridge":
        beta = ridge_fit_folds_w(_hyper(nuis, "lam", 1e-3), X, target,
                                 w[None, :], row_block=rb)
        return lambda Xe: predict_folds_linear(beta, Xe)[0]
    if nuis.name == "logistic":
        beta = logistic_fit_folds_w(_hyper(nuis, "lam", 1e-3),
                                    int(_hyper(nuis, "iters", 16)),
                                    X, target, w[None, :], row_block=rb)
        return lambda Xe: predict_folds_logistic(beta, Xe)[0]
    st = nuis.fit(nuis.init(key, X.shape[1]), X, target, w)
    return lambda Xe: nuis.predict(st, Xe)


def _wmean(x, w):
    wf = w.astype(jnp.float32)
    return (wf * x).sum() / jnp.maximum(wf.sum(), 1.0)


# ---------------------------------------------------------------------------
# Weighted learner cores: (key, y, t, X, w) -> (ate, cate).
# ---------------------------------------------------------------------------

def _s_core(nuis, key, y, t, X, w):
    tt = t.astype(jnp.float32)[:, None]
    Xt = jnp.concatenate([X, tt, X * tt], axis=1)  # treatment interactions
    predict = _wfit_predict(nuis, key, Xt, y, w)
    X1 = jnp.concatenate([X, jnp.ones_like(tt), X], axis=1)
    X0 = jnp.concatenate([X, jnp.zeros_like(tt), jnp.zeros_like(X)], axis=1)
    cate = predict(X1) - predict(X0)
    return _wmean(cate, w), cate


def _t_core(nuis, key, y, t, X, w):
    k0, k1 = jax.random.split(key)
    tt = t.astype(jnp.float32)
    m1 = _wfit_predict(nuis, k1, X, y, w * tt)(X)
    m0 = _wfit_predict(nuis, k0, X, y, w * (1.0 - tt))(X)
    cate = m1 - m0
    return _wmean(cate, w), cate


def _x_core(nuis, prop, key, y, t, X, w, clip):
    k0, k1, k2, k3, ke = jax.random.split(key, 5)
    tt = t.astype(jnp.float32)

    # stage 1: per-arm outcome models
    m1 = _wfit_predict(nuis, k1, X, y, w * tt)(X)
    m0 = _wfit_predict(nuis, k0, X, y, w * (1.0 - tt))(X)

    # stage 2: imputed individual effects, learned per arm
    d_treated = y - m0          # valid on treated rows
    d_control = m1 - y          # valid on control rows
    tau1 = _wfit_predict(nuis, k2, X, d_treated, w * tt)(X)
    tau0 = _wfit_predict(nuis, k3, X, d_control, w * (1.0 - tt))(X)

    # stage 3: propensity-weighted blend
    e = jnp.clip(_wfit_predict(prop, ke, X, tt, w)(X), clip, 1 - clip)
    cate = e * tau0 + (1.0 - e) * tau1
    return _wmean(cate, w), cate


def make_meta_core(learner: str, cfg: Optional[CausalConfig] = None,
                   nuisance: Optional[Nuisance] = None,
                   propensity: Optional[Nuisance] = None,
                   clip: float = 0.01) -> Callable:
    """Build one learner's weighted core ``(key, y, t, X, w) -> (ate,
    cate)`` with nuisances defaulted from the CausalConfig (row_block /
    strategy thread through the nuisance hypers) — the unit the sweep
    subsystem masks per segment and ``meta_bootstrap`` reweights per
    replicate."""
    cfg = cfg or CausalConfig()
    nuis = nuisance or make_ridge(cfg.ridge_lambda, row_block=cfg.row_block,
                                  strategy=cfg.row_block_strategy)
    if learner == "s":
        return lambda key, y, t, X, w: _s_core(nuis, key, y, t, X, w)
    if learner == "t":
        return lambda key, y, t, X, w: _t_core(nuis, key, y, t, X, w)
    if learner == "x":
        prop = propensity or make_logistic(cfg.ridge_lambda,
                                           cfg.newton_iters,
                                           row_block=cfg.row_block,
                                           strategy=cfg.row_block_strategy)
        return lambda key, y, t, X, w: _x_core(nuis, prop, key, y, t, X,
                                               w, clip)
    raise ValueError(f"unknown metalearner {learner!r} (expected s|t|x)")


# ---------------------------------------------------------------------------
# Replicate inference: B weighted learner refits as one batched program.
# ---------------------------------------------------------------------------

def meta_bootstrap(core: Callable, *, y: jax.Array, t: jax.Array,
                   X: jax.Array, key: jax.Array, n_replicates: int = 200,
                   scheme: str = "pairs", executor="vmap",
                   alpha: float = 0.05, ate_point: Optional[float] = None,
                   mesh=None, rules=None, memory_budget: int = 0,
                   chunk: int = 0, max_retries: int = 2):
    """B weighted metalearner refits through the task runtime (chunked,
    fault-tolerant, replicate-ordered — same scheduling as
    dml_bootstrap).  Only the ATE functional's draws are kept:
    metalearner CATEs are not phi-linear, so there is no (B, p_phi)
    coefficient matrix to quantile."""
    from repro.inference import InferenceResult
    from repro.inference.bootstrap import bootstrap_weights, replicate_keys
    from repro.runtime import as_runtime
    rt = as_runtime(executor, mesh=mesh, rules=rules,
                    memory_budget=memory_budget, chunk=chunk,
                    max_retries=max_retries)
    keys = replicate_keys(key, n_replicates)

    def replicate(kb, y_, t_, X_):
        kw, kfit = jax.random.split(kb)
        w = bootstrap_weights(kw, X_.shape[0], scheme)
        ate, _ = core(kfit, y_, t_, X_, w)
        return {"ate": ate}

    out = rt.map(replicate, keys, y, t, X, label="meta_bootstrap")
    draws = out["ate"][:, None]                       # (B, 1)
    point = (jnp.asarray([draws.mean()]) if ate_point is None
             else jnp.asarray([ate_point], jnp.float32))
    return InferenceResult(
        method=scheme, executor=rt.name, point=point, replicates=draws,
        se=jnp.std(draws, axis=0, ddof=1), alpha=alpha,
        ate_replicates=out["ate"], ate_point=ate_point)


@dataclasses.dataclass(frozen=True)
class MetaResult(EffectResult):
    ate: float
    cate: jax.Array  # (n,) pointwise CATE at the training rows
    learner: str = ""
    cfg: Optional[CausalConfig] = None
    fit_ctx: Optional[Dict[str, Any]] = None
    _inf_cache: Dict[Any, Any] = inf_cache_field()

    estimator_name = "metalearner"

    def _resolve_method(self, method):
        # no fold states to jackknife: substitute the bootstrap
        return "bootstrap" if method == "jackknife" else method

    def _replicate_inference(self, method, n_boot, exe, alpha):
        ctx = self.fit_ctx
        cfg = self._config()
        return meta_bootstrap(
            ctx["core"], y=ctx["y"], t=ctx["t"], X=ctx["X"],
            key=jax.random.fold_in(ctx["key"], 0x0b00), alpha=alpha,
            n_replicates=n_boot, scheme=resolve_scheme(method),
            executor=exe, ate_point=self.ate, **self._runtime_kwargs())

    def cate_interval(self, X, alpha=None):
        raise ValueError(
            "metalearner CATEs are not linear in a phi basis; only the "
            "ATE functional carries replicate intervals (ate_interval)")

    def summary(self) -> str:
        name = self.learner or self.estimator_name
        lines = [f"{name}_learner result", "-" * 46,
                 f"ATE = {self.ate:+.4f} (n = {self.cate.shape[0]})"]
        cfg = self._config()
        # only quote a CI that was already computed: summary() must not
        # silently dispatch cfg.n_bootstrap learner refits (the other
        # estimators' summaries are analytic-only for the same reason)
        if self._inf_cache:
            res = next(iter(self._inf_cache.values()))
            lo, hi = res.ate_interval(cfg.alpha)
            lines.append(f"bootstrap {100 * (1 - cfg.alpha):.0f}% CI "
                         f"[{lo:+.4f}, {hi:+.4f}]")
        return "\n".join(lines)


def _meta_fit(learner: str, y, t, X, nuisance, propensity, key, cfg,
              clip: float = 0.01) -> MetaResult:
    key = key if key is not None else jax.random.PRNGKey(0)
    core = make_meta_core(learner, cfg, nuisance, propensity, clip)
    ones = jnp.ones((X.shape[0],), jnp.float32)
    ate, cate = core(key, y, t, X, ones)
    ctx = {"core": core, "y": y, "t": t, "X": X, "key": key}
    return MetaResult(ate=float(ate), cate=cate, learner=learner, cfg=cfg,
                      fit_ctx=ctx)


def s_learner(y, t, X, *, nuisance: Optional[Nuisance] = None,
              key=None, cfg: Optional[CausalConfig] = None) -> MetaResult:
    return _meta_fit("s", y, t, X, nuisance, None, key, cfg)


def t_learner(y, t, X, *, nuisance: Optional[Nuisance] = None,
              key=None, cfg: Optional[CausalConfig] = None) -> MetaResult:
    return _meta_fit("t", y, t, X, nuisance, None, key, cfg)


def x_learner(y, t, X, *, nuisance: Optional[Nuisance] = None,
              propensity: Optional[Nuisance] = None, key=None,
              cfg: Optional[CausalConfig] = None,
              clip: float = 0.01) -> MetaResult:
    return _meta_fit("x", y, t, X, nuisance, propensity, key, cfg, clip)
