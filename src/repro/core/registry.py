"""The estimator registry: ONE source of truth for every estimator in
the catalogue (DML, DRLearner, the S/T/X metalearners, OrthoIV, DRIV).

Each estimator registers an ``EstimatorSpec``; three consumers read the
registry instead of keeping private copies:

  * tests/test_conformance.py runs the cross-estimator certification
    suite (serial ≡ vmap bootstrap bit-identity at canonical shapes,
    chunked ≡ whole exact equality, row_block invariance, config
    round-trip, truth recovery) over SPECS;
  * repro.sweep builds its segment-parallel cells from
    ``spec.weighted_fit`` (a pure masked/weighted single fit — the same
    closure family the bootstrap replicates run, so a segment mask is
    just another weight vector) and, where available,
    ``spec.residual_fit``/``spec.final_fit`` for shared-nuisance reuse
    across cells that differ only in final stage;
  * benchmarks (bench_sweep) loop the same cells serially as the
    baseline the batched panel is compared against.

This module used to live in tests/conformance.py; it was promoted so
src code can consume it.  Adding an estimator = appending one spec; the
whole certification suite and the sweep subsystem apply automatically.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import CausalConfig
from repro.core.dml import DML
from repro.core.drlearner import DRLearner
from repro.core.estimator import fit_adapter
from repro.core.iv import DRIV, OrthoIV
from repro.core.metalearners import (make_meta_core, s_learner, t_learner,
                                     x_learner)
from repro.core.nuisance import make_logistic, make_nuisance, make_ridge
from repro.data.causal_dgp import make_causal_data, make_iv_data

# Non-divisible on purpose: n % ROW_BLOCK != 0, so the zero-row padding
# of the blocked decomposition is exercised by every chunked≡whole
# assertion.
N_CONF = 1100
ROW_BLOCK = 256
EFFECT = 1.2


@dataclasses.dataclass(frozen=True)
class EstimatorSpec:
    """One estimator's registration with the conformance suite AND the
    sweep subsystem.

    fit(data, cfg, key)   -> pytree of jnp arrays (the full estimate)
    point(tree)           -> float ATE/LATE read off that pytree
    boot(data, cfg, key, executor, B) -> InferenceResult
    boot_cfg              the canonical bit-identity config for the
                          serial ≡ vmap check (None -> skip)
    rb_tol                |theta(rb=0) - theta(rb=R)| tolerance for the
                          cross-setting invariance check
    weighted_fit(cfg)     -> cell(key, w, data) -> {"theta", "ate", ...}
                          the pure weighted single fit the sweep masks
                          per segment (w = segment mask — the same
                          closure family bootstrap replicates run, so
                          every certified bit-identity contract
                          transfers to sweep cells)
    residual_fit(cfg)     -> resid(key, w, data) -> residual pytree —
                          the nuisance prefix of weighted_fit, shared
                          across sweep cells that differ only in final
                          stage (None -> no reuse path)
    final_fit(cfg)        -> final(resid, w, data) -> {"theta", ...} —
                          the final-stage suffix consuming residual_fit
    needs_instrument      whether ``data`` must carry a ``z`` column
    """

    name: str
    make_data: Callable[[jax.Array], Any]
    fit: Callable[[Any, CausalConfig, jax.Array], Any]
    point: Callable[[Any], float]
    truth: Callable[[Any], float]
    base_cfg: CausalConfig
    boot: Optional[Callable[..., Any]] = None
    boot_cfg: Optional[CausalConfig] = None
    truth_tol: float = 0.25
    rb_tol: float = 2e-3
    weighted_fit: Optional[Callable[[CausalConfig], Callable]] = None
    residual_fit: Optional[Callable[[CausalConfig], Callable]] = None
    final_fit: Optional[Callable[[CausalConfig], Callable]] = None
    needs_instrument: bool = False


def _conf_data(key):
    return make_causal_data(key, N_CONF, 6, effect=EFFECT)


def _conf_iv_data(key):
    return make_iv_data(key, N_CONF, 6, effect=EFFECT, compliance=0.75)


def _boot_via_inference(fit):
    """Estimators whose result exposes .inference(): one adapter."""

    def boot(data, cfg, key, executor, n_replicates):
        res = fit(data, cfg, key)
        return res.inference(executor=executor,
                             n_bootstrap=n_replicates)

    return boot


def nuisance_signature(cfg: CausalConfig) -> tuple:
    """The config fields that determine the nuisance stage — sweep cells
    whose configs agree on this tuple (differing only in final-stage
    fields like cate_features) can share one residual pass."""
    return (cfg.n_folds, cfg.nuisance_y, cfg.nuisance_t, cfg.nuisance_z,
            cfg.discrete_treatment, cfg.discrete_instrument,
            cfg.ridge_lambda, cfg.newton_iters, cfg.row_block,
            cfg.row_block_strategy, cfg.mlp_hidden, cfg.mlp_steps,
            cfg.mlp_lr, cfg.iv_cov_clip)


# -- DML --------------------------------------------------------------------

_fit_dml = fit_adapter(DML, "y", "t", "X")


def _dml_nuisances(cfg):
    t_task = "clf" if cfg.discrete_treatment else "reg"
    return (make_nuisance(cfg.nuisance_y, "reg", cfg),
            make_nuisance(cfg.nuisance_t, t_task, cfg))


def _dml_weighted_fit(cfg):
    from repro.inference.bootstrap import dml_theta_once
    ny, nt = _dml_nuisances(cfg)

    def cell(key, w, data):
        out = dml_theta_once(ny, nt, cfg.n_folds, data["X"], data["y"],
                             data["t"], data["phi"], key, w,
                             with_se=True, row_block=cfg.row_block)
        out["ate"] = out["theta"][0]
        return out

    return cell


def _dml_residual_fit(cfg):
    from repro.inference.bootstrap import dml_residuals_once
    ny, nt = _dml_nuisances(cfg)

    def resid(key, w, data):
        return dml_residuals_once(ny, nt, cfg.n_folds, data["X"],
                                  data["y"], data["t"], key, w,
                                  row_block=cfg.row_block)

    return resid


def _dml_final_fit(cfg):
    from repro.inference.numerics import weighted_theta

    def final(resid, w, data):
        theta, se = weighted_theta(resid["ry"], resid["rt"], data["phi"],
                                   w, with_se=True,
                                   row_block=cfg.row_block)
        return {"theta": theta, "se": se, "ate": theta[0]}

    return final


# -- DRLearner --------------------------------------------------------------

_fit_dr = fit_adapter(DRLearner, "y", "t", "X")


def _dr_weighted_fit(cfg):
    from repro.inference.bootstrap import dr_theta_once
    outcome = make_ridge(cfg.ridge_lambda, row_block=cfg.row_block,
                         strategy=cfg.row_block_strategy)
    propensity = make_logistic(cfg.ridge_lambda, cfg.newton_iters,
                               row_block=cfg.row_block,
                               strategy=cfg.row_block_strategy)

    def cell(key, w, data):
        return dr_theta_once(outcome, propensity, cfg.n_folds, data["X"],
                             data["y"], data["t"], data["phi"], key, w,
                             with_se=True, row_block=cfg.row_block)

    return cell


# -- metalearners (weighted cores from repro.core.metalearners; the
#    cfg threads row_block/strategy through the nuisance hypers) ------------

def _fit_meta(learner_fn):
    def fit(data, cfg, key):
        return learner_fn(data.y, data.t, data.X, key=key, cfg=cfg)

    return fit


def _meta_weighted_fit(learner: str):
    def build(cfg):
        core = make_meta_core(learner, cfg)

        def cell(key, w, data):
            ate, _ = core(key, data["y"], data["t"], data["X"], w)
            return {"theta": ate[None], "ate": ate}

        return cell

    return build


# -- orthogonal-IV family ---------------------------------------------------

_fit_orthoiv = fit_adapter(OrthoIV, "y", "t", "z", "X")

_fit_driv = fit_adapter(DRIV, "y", "t", "z", "X")


def _iv_nuisances(cfg):
    est = OrthoIV(cfg)
    return est.nuis_y, est.nuis_t, est.nuis_z


def _orthoiv_weighted_fit(cfg):
    from repro.inference.bootstrap import iv_theta_once
    ny, nt, nz = _iv_nuisances(cfg)

    def cell(key, w, data):
        out = iv_theta_once(ny, nt, nz, cfg.n_folds, data["X"],
                            data["y"], data["t"], data["z"],
                            data["phi"], key, w, with_se=True,
                            row_block=cfg.row_block)
        out["ate"] = out["theta"][0]
        return out

    return cell


def _orthoiv_residual_fit(cfg):
    from repro.inference.bootstrap import iv_residuals_once
    ny, nt, nz = _iv_nuisances(cfg)

    def resid(key, w, data):
        return iv_residuals_once(ny, nt, nz, cfg.n_folds, data["X"],
                                 data["y"], data["t"], data["z"], key,
                                 w, row_block=cfg.row_block)

    return resid


def _orthoiv_final_fit(cfg):
    from repro.inference.numerics import weighted_iv_theta

    def final(resid, w, data):
        theta, se = weighted_iv_theta(resid["ry"], resid["rt"],
                                      resid["rz"], data["phi"], w,
                                      with_se=True,
                                      row_block=cfg.row_block)
        return {"theta": theta, "se": se, "ate": theta[0]}

    return final


def _driv_weighted_fit(cfg):
    from repro.inference.bootstrap import driv_theta_once
    ny, nt, nz = _iv_nuisances(cfg)
    compliance = make_ridge(cfg.ridge_lambda, row_block=cfg.row_block,
                            strategy=cfg.row_block_strategy)

    def cell(key, w, data):
        return driv_theta_once(ny, nt, nz, compliance, cfg.n_folds,
                               data["X"], data["y"], data["t"],
                               data["z"], data["phi"], key, w,
                               cov_clip=cfg.iv_cov_clip, with_se=True,
                               row_block=cfg.row_block)

    return cell


_CFG = CausalConfig(n_folds=3, inference="none")
_CFG_BOOT_RB = CausalConfig(n_folds=3, n_bootstrap=4,
                            row_block=ROW_BLOCK)

SPECS = (
    EstimatorSpec(
        name="dml",
        make_data=_conf_data,
        fit=_fit_dml,
        point=lambda r: r.ate,
        truth=lambda d: d.true_ate,
        base_cfg=_CFG,
        boot=_boot_via_inference(_fit_dml),
        # the uniform conformance contract certifies the row-blocked
        # path (its lax.scan is a fusion barrier, so the invariant
        # einsum vocabulary survives batching at any shape); the
        # legacy whole-array p_phi=1 contract stays pinned at its
        # PR-1 canonical shape in tests/test_inference.py
        boot_cfg=_CFG_BOOT_RB,
        weighted_fit=_dml_weighted_fit,
        residual_fit=_dml_residual_fit,
        final_fit=_dml_final_fit,
    ),
    EstimatorSpec(
        name="dml_p2_rb",
        make_data=_conf_data,
        fit=_fit_dml,
        point=lambda r: r.ate,
        truth=lambda d: d.true_ate,
        base_cfg=dataclasses.replace(_CFG, cate_features=2),
        boot=_boot_via_inference(_fit_dml),
        # wider bases hold bit-identity on the row-blocked path only
        boot_cfg=dataclasses.replace(_CFG_BOOT_RB, cate_features=2),
        truth_tol=0.4,   # theta[0] is the x=0 effect under this basis
        weighted_fit=_dml_weighted_fit,
        residual_fit=_dml_residual_fit,
        final_fit=_dml_final_fit,
    ),
    EstimatorSpec(
        name="dml_loo",
        make_data=_conf_data,
        fit=_fit_dml,
        point=lambda r: r.ate,
        truth=lambda d: d.true_ate,
        base_cfg=dataclasses.replace(_CFG, engine="parallel_loo"),
        weighted_fit=_dml_weighted_fit,
        residual_fit=_dml_residual_fit,
        final_fit=_dml_final_fit,
    ),
    EstimatorSpec(
        name="drlearner",
        make_data=_conf_data,
        fit=_fit_dr,
        point=lambda r: r.ate,
        truth=lambda d: d.true_ate,
        base_cfg=_CFG,
        boot=_boot_via_inference(_fit_dr),
        boot_cfg=_CFG_BOOT_RB,
        weighted_fit=_dr_weighted_fit,
    ),
    EstimatorSpec(
        name="s_learner",
        make_data=_conf_data,
        fit=_fit_meta(s_learner),
        point=lambda r: r.ate,
        truth=lambda d: d.true_ate,
        base_cfg=_CFG,
        boot=_boot_via_inference(_fit_meta(s_learner)),
        boot_cfg=_CFG_BOOT_RB,
        weighted_fit=_meta_weighted_fit("s"),
    ),
    EstimatorSpec(
        name="t_learner",
        make_data=_conf_data,
        fit=_fit_meta(t_learner),
        point=lambda r: r.ate,
        truth=lambda d: d.true_ate,
        base_cfg=_CFG,
        boot=_boot_via_inference(_fit_meta(t_learner)),
        boot_cfg=_CFG_BOOT_RB,
        weighted_fit=_meta_weighted_fit("t"),
    ),
    EstimatorSpec(
        name="x_learner",
        make_data=_conf_data,
        fit=_fit_meta(x_learner),
        point=lambda r: r.ate,
        truth=lambda d: d.true_ate,
        base_cfg=_CFG,
        boot=_boot_via_inference(_fit_meta(x_learner)),
        boot_cfg=_CFG_BOOT_RB,
        weighted_fit=_meta_weighted_fit("x"),
    ),
    EstimatorSpec(
        name="orthoiv",
        make_data=_conf_iv_data,
        fit=_fit_orthoiv,
        point=lambda r: r.late,
        truth=lambda d: d.true_late,
        base_cfg=_CFG,
        boot=_boot_via_inference(_fit_orthoiv),
        boot_cfg=_CFG_BOOT_RB,
        truth_tol=0.35,  # IV variance at n=1100 is honest-to-goodness wide
        weighted_fit=_orthoiv_weighted_fit,
        residual_fit=_orthoiv_residual_fit,
        final_fit=_orthoiv_final_fit,
        needs_instrument=True,
    ),
    EstimatorSpec(
        name="orthoiv_p2_rb",
        make_data=_conf_iv_data,
        fit=_fit_orthoiv,
        point=lambda r: r.late,
        truth=lambda d: d.true_late,
        base_cfg=dataclasses.replace(_CFG, cate_features=2),
        boot=_boot_via_inference(_fit_orthoiv),
        boot_cfg=dataclasses.replace(_CFG_BOOT_RB, cate_features=2),
        truth_tol=0.5,
        weighted_fit=_orthoiv_weighted_fit,
        residual_fit=_orthoiv_residual_fit,
        final_fit=_orthoiv_final_fit,
        needs_instrument=True,
    ),
    EstimatorSpec(
        name="driv",
        make_data=_conf_iv_data,
        fit=_fit_driv,
        point=lambda r: r.late,
        truth=lambda d: d.true_late,
        base_cfg=_CFG,
        boot=_boot_via_inference(_fit_driv),
        boot_cfg=_CFG_BOOT_RB,
        truth_tol=0.35,
        weighted_fit=_driv_weighted_fit,
        needs_instrument=True,
    ),
)

SPEC_IDS = tuple(s.name for s in SPECS)

REGISTRY: Dict[str, EstimatorSpec] = {s.name: s for s in SPECS}


def get_spec(name: str) -> EstimatorSpec:
    """Registry lookup by estimator name (the sweep subsystem's entry
    point)."""
    try:
        return REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown estimator {name!r}; registered: {sorted(REGISTRY)}"
        ) from None


def _to_tree(obj):
    """Recursively open dataclass results into plain dicts (skipping
    caches, configs and fit contexts) so tree_leaves reaches every
    nested array — results are NOT registered pytrees."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {f.name: _to_tree(getattr(obj, f.name))
                for f in dataclasses.fields(obj)
                if not f.name.startswith("_")
                and f.name not in ("cfg", "fit_ctx")}
    return obj


def tree_arrays(tree) -> tuple:
    """The floating jnp-array leaves of an estimator result, for
    exact-equality comparison across execution strategies."""
    return tuple(leaf for leaf in jax.tree_util.tree_leaves(_to_tree(tree))
                 if isinstance(leaf, (jax.Array, jnp.ndarray))
                 and jnp.issubdtype(leaf.dtype, jnp.floating))
