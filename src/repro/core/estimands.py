"""Estimand summaries + orthogonality/overlap diagnostics (the NEXUS
'integrated validation' features, paper §4)."""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Diagnostics:
    resid_y_mean: float      # E[ry] ≈ 0 if m_y unbiased
    resid_t_mean: float      # E[rt] ≈ 0 if m_t unbiased
    resid_corr: float        # corr(ry, rt) pre-final-stage
    ortho_moment: float      # |E[(ry - θ·rt)·rt]| ≈ 0 (Neyman orthogonality)
    min_propensity: float    # overlap (assumption 3)
    max_propensity: float
    nuisance_r2_y: float     # 1 - Var(ry)/Var(y)
    nuisance_auc_proxy: float  # mean |mt - 0.5|·2 (separation proxy)

    def rows(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def compute_diagnostics(y, t, my, mt, theta_at_x, rt_clip: float = 1e-9
                        ) -> Diagnostics:
    f32 = jnp.float32
    ry = (y - my).astype(f32)
    rt = (t - mt).astype(f32)
    e = ry - theta_at_x.astype(f32) * rt
    corr = jnp.corrcoef(jnp.stack([ry, rt]))[0, 1]
    var_y = jnp.maximum(jnp.var(y.astype(f32)), rt_clip)
    return Diagnostics(
        resid_y_mean=float(ry.mean()),
        resid_t_mean=float(rt.mean()),
        resid_corr=float(corr),
        ortho_moment=float(jnp.abs((e * rt).mean())),
        min_propensity=float(mt.min()),
        max_propensity=float(mt.max()),
        nuisance_r2_y=float(1.0 - jnp.var(ry) / var_y),
        nuisance_auc_proxy=float((jnp.abs(mt - 0.5) * 2).mean()),
    )


def ate_from_cate(cate: jax.Array) -> float:
    return float(cate.mean())


def att_from_cate(cate: jax.Array, t: jax.Array) -> float:
    tw = t.astype(jnp.float32)
    return float((cate * tw).sum() / jnp.maximum(tw.sum(), 1.0))
