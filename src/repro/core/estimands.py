"""Estimand summaries + orthogonality/overlap diagnostics (the NEXUS
'integrated validation' features, paper §4)."""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Diagnostics:
    resid_y_mean: float      # E[ry] ≈ 0 if m_y unbiased
    resid_t_mean: float      # E[rt] ≈ 0 if m_t unbiased
    resid_corr: float        # corr(ry, rt) pre-final-stage
    ortho_moment: float      # |E[(ry - θ·rt)·rt]| ≈ 0 (Neyman orthogonality)
    min_propensity: float    # overlap (assumption 3)
    max_propensity: float
    nuisance_r2_y: float     # 1 - Var(ry)/Var(y)
    nuisance_auc_proxy: float  # mean |mt - 0.5|·2 (separation proxy)

    def rows(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def compute_diagnostics(y, t, my, mt, theta_at_x, rt_clip: float = 1e-9
                        ) -> Diagnostics:
    f32 = jnp.float32
    ry = (y - my).astype(f32)
    rt = (t - mt).astype(f32)
    e = ry - theta_at_x.astype(f32) * rt
    corr = jnp.corrcoef(jnp.stack([ry, rt]))[0, 1]
    var_y = jnp.maximum(jnp.var(y.astype(f32)), rt_clip)
    return Diagnostics(
        resid_y_mean=float(ry.mean()),
        resid_t_mean=float(rt.mean()),
        resid_corr=float(corr),
        ortho_moment=float(jnp.abs((e * rt).mean())),
        min_propensity=float(mt.min()),
        max_propensity=float(mt.max()),
        nuisance_r2_y=float(1.0 - jnp.var(ry) / var_y),
        nuisance_auc_proxy=float((jnp.abs(mt - 0.5) * 2).mean()),
    )


@dataclasses.dataclass(frozen=True)
class IVDiagnostics:
    """Instrument-side health checks for the orthogonal-IV family, on
    top of the shared residual diagnostics."""

    first_stage_f: float     # heteroskedasticity-robust first-stage F
    instrument_corr: float   # corr(rz, rt): the identifying covariance
    resid_z_mean: float      # E[rz] ≈ 0 if m_z unbiased
    ortho_moment: float      # |E[(ry - θᵀφ·rt)·rz]| ≈ 0 (the IV moment)
    min_instrument_propensity: float   # overlap of E[Z|X]
    max_instrument_propensity: float
    weak_instrument: bool    # F below the Stock-Yogo rule-of-thumb 10

    def rows(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def first_stage_f(rt: jax.Array, rz: jax.Array) -> float:
    """Robust first-stage F: the squared t-statistic of pi in
    ``rt = pi·rz + u`` with HC0 variance — the standard
    weak-instrument screen (F < 10 ⇒ weak, Stock & Yogo)."""
    f32 = jnp.float32
    rtf, rzf = rt.astype(f32), rz.astype(f32)
    szz = jnp.maximum((rzf * rzf).sum(), 1e-12)
    pi = (rzf * rtf).sum() / szz
    u = rtf - pi * rzf
    var_pi = (rzf * rzf * u * u).sum() / (szz * szz)
    return float(pi * pi / jnp.maximum(var_pi, 1e-30))


def compute_iv_diagnostics(t, z, mt, mz, e=None, *,
                           f_threshold: float = 10.0) -> IVDiagnostics:
    """``e`` is the final-stage residual ``ry - θᵀφ·rt`` (omit for the
    pre-fit view)."""
    f32 = jnp.float32
    rt = (t - mt).astype(f32)
    rz = (z - mz).astype(f32)
    f_stat = first_stage_f(rt, rz)
    corr = jnp.corrcoef(jnp.stack([rz, rt]))[0, 1]
    ortho = float(jnp.abs((e.astype(f32) * rz).mean())) if e is not None \
        else float("nan")
    return IVDiagnostics(
        first_stage_f=f_stat,
        instrument_corr=float(corr),
        resid_z_mean=float(rz.mean()),
        ortho_moment=ortho,
        min_instrument_propensity=float(mz.min()),
        max_instrument_propensity=float(mz.max()),
        weak_instrument=bool(f_stat < f_threshold),
    )


def ate_from_cate(cate: jax.Array) -> float:
    return float(cate.mean())


def att_from_cate(cate: jax.Array, t: jax.Array) -> float:
    tw = t.astype(jnp.float32)
    return float((cate * tw).sum() / jnp.maximum(tw.sum(), 1.0))
