"""Refutation tests — NEXUS's 'integrated validation' (paper §4), the
dowhy-style robustness checks re-run through the fold-parallel engine:

  placebo_treatment      permuted T  -> estimate should collapse to ~0
  random_common_cause    X + noise covariate -> estimate should be stable
  data_subset            random half of rows -> estimate should be stable

Each refuter is R independent re-fits — iterative steps of a causal
algorithm, i.e. exactly the concurrency class the paper parallelizes;
here each re-fit reuses the one-program crossfit engine.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import CausalConfig
from repro.core.dml import DML


@dataclasses.dataclass(frozen=True)
class RefutationReport:
    name: str
    original_ate: float
    refuted_ates: Tuple[float, ...]
    expectation: str  # "zero" | "stable"

    @property
    def mean(self) -> float:
        return float(jnp.mean(jnp.asarray(self.refuted_ates)))

    @property
    def passed(self) -> bool:
        m = jnp.asarray(self.refuted_ates)
        if self.expectation == "zero":
            # placebo effects should be ~0 relative to the real effect
            return bool(jnp.abs(m.mean()) < 0.25 * abs(self.original_ate)
                        + 3 * m.std() + 1e-6)
        rel = jnp.abs(m.mean() - self.original_ate) / max(
            abs(self.original_ate), 1e-9)
        return bool(rel < 0.25)

    def row(self) -> str:
        return (f"{self.name:>22}: original={self.original_ate:+.4f} "
                f"refuted_mean={self.mean:+.4f} "
                f"[{'PASS' if self.passed else 'FAIL'}]")


def placebo_treatment(est: DML, y, t, X, *, original_ate: float,
                      n_reps: int = 3, key=None) -> RefutationReport:
    key = key if key is not None else jax.random.PRNGKey(7)
    ates = []
    for r in range(n_reps):
        kr = jax.random.fold_in(key, r)
        t_fake = jax.random.permutation(kr, t)
        ates.append(est.fit(y, t_fake, X, key=kr).ate)
    return RefutationReport("placebo_treatment", original_ate,
                            tuple(ates), "zero")


def random_common_cause(est: DML, y, t, X, *, original_ate: float,
                        n_reps: int = 3, key=None) -> RefutationReport:
    key = key if key is not None else jax.random.PRNGKey(8)
    ates = []
    for r in range(n_reps):
        kr = jax.random.fold_in(key, r)
        extra = jax.random.normal(kr, (X.shape[0], 1), X.dtype)
        ates.append(est.fit(y, t, jnp.concatenate([X, extra], 1), key=kr).ate)
    return RefutationReport("random_common_cause", original_ate,
                            tuple(ates), "stable")


def data_subset(est: DML, y, t, X, *, original_ate: float,
                frac: float = 0.5, n_reps: int = 3, key=None
                ) -> RefutationReport:
    key = key if key is not None else jax.random.PRNGKey(9)
    n = X.shape[0]
    m = int(n * frac)
    ates = []
    for r in range(n_reps):
        kr = jax.random.fold_in(key, r)
        idx = jax.random.permutation(kr, n)[:m]
        ates.append(est.fit(y[idx], t[idx], X[idx], key=kr).ate)
    return RefutationReport("data_subset", original_ate, tuple(ates),
                            "stable")


def run_all(cfg: CausalConfig, y, t, X, *, key=None
            ) -> Tuple[RefutationReport, ...]:
    key = key if key is not None else jax.random.PRNGKey(0)
    est = DML(cfg)
    base = est.fit(y, t, X, key=key)
    a0 = base.ate
    return (
        placebo_treatment(est, y, t, X, original_ate=a0, key=key),
        random_common_cause(est, y, t, X, original_ate=a0, key=key),
        data_subset(est, y, t, X, original_ate=a0, key=key),
    )
