"""Refutation tests — NEXUS's 'integrated validation' (paper §4), the
dowhy-style robustness checks re-run through the fold-parallel engine:

  placebo_treatment      permuted T  -> estimate should collapse to ~0
  random_common_cause    X + noise covariate -> estimate should be stable
  data_subset            random half of rows -> estimate should be stable

Each refuter is R independent re-fits — iterative steps of a causal
algorithm, i.e. exactly the concurrency class the paper parallelizes
(§5.1 fold fits, §5.2 tuning trials, and these replicates).  The R
re-fits are dispatched through ``repro.inference.executor`` — the same
pluggable Executor that runs bootstrap replicates — so by default they
execute as ONE vmapped program instead of a Python loop (pass
``executor="serial"`` for the loop baseline; per-replicate estimates are
bit-identical across the two).  Each replicate derives its permutation /
noise / subset mask AND its fold assignment from ``fold_in(key, r)``,
the lineage that makes any single replicate exactly replayable.

``data_subset`` keeps rows in place and zeroes their training + moment
weights (the weighted-fit path bootstrap replicates use), which is
estimation-equivalent to physically dropping the rows but keeps every
replicate the same shape — the requirement for batching them into one
program.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config import CausalConfig
from repro.core.dml import DML
from repro.core.final_stage import cate_basis
from repro.inference.bootstrap import dml_theta_once, replicate_keys
from repro.runtime import as_runtime


@dataclasses.dataclass(frozen=True)
class RefutationReport:
    name: str
    original_ate: float
    refuted_ates: Tuple[float, ...]
    expectation: str  # "zero" | "stable"

    @property
    def mean(self) -> float:
        return float(jnp.mean(jnp.asarray(self.refuted_ates)))

    @property
    def passed(self) -> bool:
        m = jnp.asarray(self.refuted_ates)
        if self.expectation == "zero":
            # placebo effects should be ~0 relative to the real effect
            return bool(jnp.abs(m.mean()) < 0.25 * abs(self.original_ate)
                        + 3 * m.std() + 1e-6)
        rel = jnp.abs(m.mean() - self.original_ate) / max(
            abs(self.original_ate), 1e-9)
        return bool(rel < 0.25)

    def row(self) -> str:
        return (f"{self.name:>22}: original={self.original_ate:+.4f} "
                f"refuted_mean={self.mean:+.4f} "
                f"[{'PASS' if self.passed else 'FAIL'}]")


def _run_replicates(est, fn, key, n_reps: int, executor, *arrays,
                    label: str = "refute") -> Tuple[float, ...]:
    """Dispatch ``n_reps`` refit replicates through the task runtime and
    extract the leading (ATE) coefficient of each — shared by the DML
    refuters (y, t, X, phi) and the IV refuters (y, t, z, X, phi)."""
    rt = as_runtime(executor, rules=est.rules)
    thetas = rt.map(fn, replicate_keys(key, n_reps), *arrays,
                    label=label)["theta"]
    return tuple(float(a) for a in thetas[:, 0])


def placebo_treatment(est: DML, y, t, X, *, original_ate: float,
                      n_reps: int = 3, key=None,
                      executor="vmap") -> RefutationReport:
    key = key if key is not None else jax.random.PRNGKey(7)
    phi = cate_basis(X, est.cfg.cate_features)

    def refit(kr, y_, t_, X_, phi_):
        t_fake = jax.random.permutation(kr, t_)
        ones = jnp.ones((X_.shape[0],), jnp.float32)
        return dml_theta_once(est.nuis_y, est.nuis_t, est.cfg.n_folds,
                              X_, y_, t_fake, phi_, kr, ones,
                              with_se=False)

    ates = _run_replicates(est, refit, key, n_reps, executor, y, t, X, phi)
    return RefutationReport("placebo_treatment", original_ate, ates, "zero")


def random_common_cause(est: DML, y, t, X, *, original_ate: float,
                        n_reps: int = 3, key=None,
                        executor="vmap") -> RefutationReport:
    key = key if key is not None else jax.random.PRNGKey(8)
    phi = cate_basis(X, est.cfg.cate_features)

    def refit(kr, y_, t_, X_, phi_):
        n = X_.shape[0]
        extra = jax.random.normal(kr, (n, 1), X_.dtype)
        Xr = jnp.concatenate([X_, extra], axis=1)
        ones = jnp.ones((n,), jnp.float32)
        return dml_theta_once(est.nuis_y, est.nuis_t, est.cfg.n_folds,
                              Xr, y_, t_, phi_, kr, ones, with_se=False)

    ates = _run_replicates(est, refit, key, n_reps, executor, y, t, X, phi)
    return RefutationReport("random_common_cause", original_ate, ates,
                            "stable")


def data_subset(est: DML, y, t, X, *, original_ate: float,
                frac: float = 0.5, n_reps: int = 3, key=None,
                executor="vmap") -> RefutationReport:
    key = key if key is not None else jax.random.PRNGKey(9)
    m = int(X.shape[0] * frac)
    phi = cate_basis(X, est.cfg.cate_features)

    def refit(kr, y_, t_, X_, phi_):
        # weight-out (1-frac) of the rows instead of slicing them away:
        # identical moments, static shapes (batchable)
        n = X_.shape[0]
        w = (jax.random.permutation(kr, jnp.arange(n)) < m
             ).astype(jnp.float32)
        return dml_theta_once(est.nuis_y, est.nuis_t, est.cfg.n_folds,
                              X_, y_, t_, phi_, kr, w, with_se=False)

    ates = _run_replicates(est, refit, key, n_reps, executor, y, t, X, phi)
    return RefutationReport("data_subset", original_ate, ates, "stable")


# ---------------------------------------------------------------------------
# Instrument-side refuters (repro.core.iv).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WeakInstrumentReport:
    """First-stage F screen (Stock-Yogo rule of thumb: F < 10 ⇒ weak
    instrument ⇒ 2SLS point estimates and CIs are unreliable)."""

    f_stat: float
    threshold: float
    instrument_corr: float

    @property
    def passed(self) -> bool:
        return self.f_stat >= self.threshold

    def row(self) -> str:
        return (f"{'weak_instrument':>22}: F={self.f_stat:.1f} "
                f"(threshold {self.threshold:.0f}) corr(rz,rt)="
                f"{self.instrument_corr:+.3f} "
                f"[{'PASS' if self.passed else 'FAIL'}]")


def weak_instrument(res, *, threshold: float = 10.0
                    ) -> WeakInstrumentReport:
    """Screen a fitted OrthoIV/DRIV result's first stage: the robust F
    of ``rt ~ rz`` recomputed from the result's out-of-fold residuals
    (repro.core.estimands.first_stage_f)."""
    from repro.core.estimands import first_stage_f
    cf = res.fit_ctx
    if cf is None or not hasattr(res, "crossfit"):
        # DRIVResult (no stored crossfit) or a context-free result:
        # the fit-time diagnostics already carry the same F
        d = res.diagnostics
        return WeakInstrumentReport(f_stat=d.first_stage_f,
                                    threshold=threshold,
                                    instrument_corr=d.instrument_corr)
    rt_res = cf.t - res.crossfit.oof_t
    rz_res = cf.z - res.crossfit.oof_z
    f = first_stage_f(rt_res, rz_res)
    corr = float(jnp.corrcoef(jnp.stack(
        [jnp.asarray(rz_res, jnp.float32),
         jnp.asarray(rt_res, jnp.float32)]))[0, 1])
    return WeakInstrumentReport(f_stat=f, threshold=threshold,
                                instrument_corr=corr)


def placebo_instrument(est, y, t, z, X, *, original_ate: float,
                       n_reps: int = 3, key=None,
                       executor="vmap") -> RefutationReport:
    """Permute Z: a scrambled instrument carries no first-stage signal,
    so the 2SLS numerator AND denominator collapse toward 0/0 — the
    replicate estimates should scatter around zero effect with no
    systematic drift toward the original.  Each replicate is one
    weighted OrthoIV refit through the task runtime (the same
    replicate-closure machinery as the bootstrap)."""
    from repro.inference.bootstrap import iv_theta_once
    key = key if key is not None else jax.random.PRNGKey(17)
    phi = cate_basis(X, est.cfg.cate_features)

    def refit(kr, y_, t_, z_, X_, phi_):
        z_fake = jax.random.permutation(kr, z_)
        ones = jnp.ones((X_.shape[0],), jnp.float32)
        return iv_theta_once(est.nuis_y, est.nuis_t, est.nuis_z,
                             est.cfg.n_folds, X_, y_, t_, z_fake, phi_,
                             kr, ones, with_se=False)

    ates = _run_replicates(est, refit, key, n_reps, executor, y, t, z,
                           X, phi, label="placebo_instrument")
    return RefutationReport("placebo_instrument", original_ate, ates,
                            "zero")


def run_all(cfg: CausalConfig, y, t, X, *, key=None, executor="vmap"
            ) -> Tuple[RefutationReport, ...]:
    """The refuter panel on ONE shared task runtime (configured from
    cfg.runtime_*): the three refuters are independent branches of a
    task graph gathered together, each branch's replicate map going
    through the same chunked, fault-tolerant scheduler."""
    key = key if key is not None else jax.random.PRNGKey(0)
    est = DML(cfg)
    base = est.fit(y, t, X, key=key)
    a0 = base.ate
    rt = as_runtime(executor, rules=est.rules,
                    memory_budget=cfg.runtime_memory_budget,
                    chunk=cfg.runtime_chunk,
                    max_retries=cfg.runtime_max_retries)
    p = rt.call(lambda: placebo_treatment(
        est, y, t, X, original_ate=a0, key=key, executor=rt),
        label="placebo_treatment")
    r = rt.call(lambda: random_common_cause(
        est, y, t, X, original_ate=a0, key=key, executor=rt),
        label="random_common_cause")
    d = rt.call(lambda: data_subset(
        est, y, t, X, original_ate=a0, key=key, executor=rt),
        label="data_subset")
    return tuple(rt.gather([p, r, d]))
