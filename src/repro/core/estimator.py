"""The shared estimator base layer: one copy of the fit -> inference
plumbing every estimator result used to carry privately.

Before this module, DMLResult / DRResult / OrthoIVResult / DRIVResult
each held a near-identical ~80-line block: resolve the inference method
and replicate count from the CausalConfig, cache InferenceResults by
(method, B, executor), fall back to analytic CIs when inference is
disabled, and project replicate draws through the ATE / CATE
functionals.  ``EffectResult`` owns all of that once; estimators plug in
only the genuinely estimator-specific piece — how to run one batch of
replicate re-estimations (``_replicate_inference``) — plus optional
analytic fallbacks.

Two concrete flavors cover the catalogue:

  SandwichEffectResult       theta + HC0 covariance (DML, OrthoIV):
                             analytic per-coefficient CIs come free from
                             the sandwich; ``ate`` is theta[0] under the
                             constant basis.
  PseudoOutcomeEffectResult  scalar ATE = mean pseudo-outcome plus a
                             theta projection of the pseudo-outcome on
                             phi (DRLearner, DRIV): analytic ATE CI from
                             the pseudo-outcome se; CATE bands require
                             replicate inference.

Metalearner results subclass ``EffectResult`` directly (their CATE is
not linear in a phi basis, so only the ATE functional carries
intervals).  ``CausalEstimator`` is the facade protocol the registry
(repro.core.registry) and the sweep subsystem (repro.sweep) consume.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp

from repro.config import CausalConfig
from repro.core.final_stage import cate_basis


def resolve_scheme(method: str) -> str:
    """Inference-method name -> bootstrap weight scheme ("bootstrap" is
    the user-facing name for the pairs scheme)."""
    return "pairs" if method == "bootstrap" else method


def inf_cache_field() -> Any:
    """The per-result InferenceResult cache field (excluded from repr
    and equality so frozen results stay hashable value objects)."""
    return dataclasses.field(default_factory=dict, repr=False, compare=False)


@runtime_checkable
class CausalEstimator(Protocol):
    """Every estimator facade: construct with a CausalConfig, ``fit``
    returns an EffectResult.  (Positional data arguments differ by
    family — DML takes (y, t, X), the IV family (y, t, z, X) — which is
    why the registry carries per-estimator fit adapters.)"""

    cfg: CausalConfig

    def fit(self, *args: Any, **kwargs: Any) -> "EffectResult":
        ...


class EffectResult:
    """Mixin owning the shared fit -> inference plumbing.

    Subclass dataclasses provide the fields ``cfg`` (CausalConfig or
    None), ``fit_ctx`` (replay context, None disables replicate
    inference) and ``_inf_cache`` (via ``inf_cache_field()``), plus the
    hook ``_replicate_inference`` that runs one batch of replicate
    re-estimations through the task runtime.
    """

    estimator_name = "effect"

    # -- config / runtime plumbing --------------------------------------
    def _config(self) -> CausalConfig:
        return self.cfg or CausalConfig()

    def _runtime_kwargs(self) -> Dict[str, Any]:
        """The task-runtime knobs every replicate dispatch threads
        through (memory-budgeted chunking + the downgrade ladder)."""
        cfg = self._config()
        return dict(
            memory_budget=cfg.runtime_memory_budget,
            chunk=cfg.runtime_chunk,
            max_retries=cfg.runtime_max_retries,
        )

    # -- estimator-specific hooks ---------------------------------------
    def _resolve_method(self, method: str) -> str:
        """Map/refuse inference methods the estimator cannot serve
        (e.g. DR has no fold-state jackknife shortcut)."""
        return method

    def _replicate_inference(
        self, method: str, n_boot: int, executor: Any, alpha: float
    ):
        raise NotImplementedError

    def _analytic_ate_interval(self, alpha: float) -> Tuple[float, float]:
        raise ValueError(
            f"{type(self).__name__} has no analytic ATE interval; set "
            "cfg.inference or call .inference(method=...) explicitly"
        )

    def _analytic_cate_interval(
        self, phi: jax.Array, alpha: float
    ) -> Tuple[jax.Array, jax.Array]:
        raise ValueError(
            f"cate_interval needs replicate inference ({type(self).__name__} "
            "has no coefficient covariance); set cfg.inference or call "
            ".inference(method=...) explicitly"
        )

    def _summary_extra(self) -> Tuple[str, ...]:
        """Diagnostics lines appended to ``summary()``."""
        return ()

    # -- uncertainty quantification (repro.inference) -------------------
    def inference(
        self,
        *,
        method: Optional[str] = None,
        n_bootstrap: Optional[int] = None,
        executor: Optional[str] = None,
        alpha: Optional[float] = None,
    ):
        """Replicate-based inference, computed lazily and cached.  The B
        re-estimations run as ONE program through the configured
        Executor / task runtime; ``method`` overrides cfg.inference
        (bootstrap | multiplier | jackknife).  The replicates are
        alpha-independent, so alpha is NOT part of the cache key — a new
        level re-quantiles the stored draws."""
        if self.fit_ctx is None:
            raise ValueError(
                "result carries no fit context; re-fit through the "
                "estimator facade to enable replicate inference"
            )
        cfg = self._config()
        method = method or cfg.inference
        if method in ("none", ""):
            raise ValueError("cfg.inference='none'; pass method= to force")
        method = self._resolve_method(method)
        n_boot = n_bootstrap or cfg.n_bootstrap
        exe = executor or cfg.inference_executor
        a = cfg.alpha if alpha is None else alpha
        cache_key = (method, n_boot, exe)
        if cache_key in self._inf_cache:
            return self._inf_cache[cache_key]
        res = self._replicate_inference(method, n_boot, exe, a)
        self._inf_cache[cache_key] = res
        return res

    def ate_interval(
        self, alpha: Optional[float] = None, kind: str = "percentile"
    ) -> Tuple[float, float]:
        """(lo, hi) CI for the ATE functional from cfg.n_bootstrap
        replicate re-estimations; falls back to the estimator's analytic
        interval when cfg.inference == 'none'."""
        cfg = self._config()
        a = cfg.alpha if alpha is None else alpha
        if self.fit_ctx is None or cfg.inference in ("none", ""):
            return self._analytic_ate_interval(a)
        return self.inference(alpha=a).ate_interval(a, kind)

    # the IV family's name for the same functional
    late_interval = ate_interval

    def cate_interval(
        self, X: jax.Array, alpha: Optional[float] = None
    ) -> Tuple[jax.Array, jax.Array]:
        """Pointwise (lo, hi) bands for theta(x) = <phi(x), theta>."""
        cfg = self._config()
        a = cfg.alpha if alpha is None else alpha
        phi = cate_basis(X, cfg.cate_features)
        if self.fit_ctx is None or cfg.inference in ("none", ""):
            return self._analytic_cate_interval(phi, a)
        return self.inference(alpha=a).cate_interval(phi, a)

    def summary(self) -> str:
        raise NotImplementedError


class SandwichEffectResult(EffectResult):
    """theta + HC0 sandwich covariance (subclass dataclasses provide
    ``theta`` (p_phi,) and ``cov`` (p_phi, p_phi))."""

    @property
    def ate(self) -> float:
        """With phi = [1, x...], theta[0] is the effect at x = 0; for
        the constant basis it IS the ATE (the IV family reads the same
        coefficient as the LATE).  For heterogeneous bases use
        ``cate(X).mean()``."""
        return float(self.theta[0])

    late = ate

    @property
    def stderr(self) -> jax.Array:
        return jnp.sqrt(jnp.diag(self.cov))

    def cate(self, X: jax.Array) -> jax.Array:
        phi = cate_basis(X, self._config().cate_features)
        return phi @ self.theta

    def ate_of(self, X: jax.Array) -> float:
        return float(self.cate(X).mean())

    def conf_int(self, alpha: float = 0.05) -> Tuple[jax.Array, jax.Array]:
        from repro.inference.intervals import z_crit

        se = self.stderr
        z = z_crit(alpha)
        return self.theta - z * se, self.theta + z * se

    def _analytic_ate_interval(self, alpha: float) -> Tuple[float, float]:
        lo, hi = self.conf_int(alpha)
        return float(lo[0]), float(hi[0])

    def _analytic_cate_interval(
        self, phi: jax.Array, alpha: float
    ) -> Tuple[jax.Array, jax.Array]:
        from repro.inference.intervals import z_crit

        z = z_crit(alpha)
        se = jnp.sqrt(
            jnp.clip(jnp.einsum("ni,ij,nj->n", phi, self.cov, phi), 0.0, None)
        )
        c = phi @ self.theta
        return c - z * se, c + z * se

    def summary(self) -> str:
        lo, hi = self.conf_int()
        lines = [
            f"{self.estimator_name} result",
            "-" * 46,
            f"{'coef':>4} {'point':>10} {'stderr':>10} {'ci_lo':>9} {'ci_hi':>9}",
        ]
        for i in range(self.theta.shape[0]):
            lines.append(
                f"θ[{i}] {float(self.theta[i]):>10.4f} "
                f"{float(self.stderr[i]):>10.4f} "
                f"{float(lo[i]):>9.4f} {float(hi[i]):>9.4f}"
            )
        extra = self._summary_extra()
        if extra:
            lines.append("-" * 46)
            lines.extend(extra)
        return "\n".join(lines)


class PseudoOutcomeEffectResult(EffectResult):
    """Scalar ATE = mean pseudo-outcome + a theta projection on phi
    (subclass dataclasses provide ``ate``, ``stderr`` (floats) and
    ``theta`` (p_phi,))."""

    def cate(self, X: jax.Array, n_features: Optional[int] = None) -> jax.Array:
        nf = n_features if n_features is not None else self._config().cate_features
        return cate_basis(X, nf) @ self.theta

    def conf_int(self, alpha: float = 0.05) -> Tuple[float, float]:
        from repro.inference.intervals import z_crit

        z = z_crit(alpha)
        return self.ate - z * self.stderr, self.ate + z * self.stderr

    def _analytic_ate_interval(self, alpha: float) -> Tuple[float, float]:
        return self.conf_int(alpha)

    def summary(self) -> str:
        lo, hi = self.conf_int()
        lines = [
            f"{self.estimator_name} result",
            "-" * 46,
            f"ATE = {self.ate:+.4f} (se {self.stderr:.4f}), "
            f"95% CI [{lo:+.4f}, {hi:+.4f}]",
        ]
        extra = self._summary_extra()
        if extra:
            lines.extend(extra)
        return "\n".join(lines)


def fit_adapter(
    estimator_cls: Callable[[CausalConfig], Any], *fields: str
) -> Callable[..., Any]:
    """Uniform (data, cfg, key) -> EffectResult adapter the registry and
    sweep layers use: pulls ``fields`` off the data object and calls
    ``estimator_cls(cfg).fit(*columns, key=key)``."""

    def fit(data: Any, cfg: CausalConfig, key: jax.Array) -> Any:
        cols = [getattr(data, f) for f in fields]
        return estimator_cls(cfg).fit(*cols, key=key)

    return fit
