"""Double/Debiased ML estimator (Chernozhukov et al. 2018) — the
algorithm the paper scales.  ``DML(engine="parallel")`` is the paper's
DML_Ray translated to SPMD; ``engine="sequential"`` is the EconML
baseline it benchmarks against (both produce identical estimates up to
fold-init PRNG; tests assert the equivalence).

Usage (mirrors the paper's §5.1 listing):

    est = DML(CausalConfig(n_folds=5, nuisance_y="ridge",
                           nuisance_t="logistic", engine="parallel"))
    res = est.fit(y, t, X=X, key=jax.random.PRNGKey(0))
    res.ate, res.stderr, res.cate(X_new)
    res.ate_interval()            # B=cfg.n_bootstrap replicates, one
    res.cate_interval(X_new)      # vmapped program (repro.inference)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import CausalConfig
from repro.core.crossfit import CrossfitResult, crossfit
from repro.core.estimands import Diagnostics, compute_diagnostics
from repro.core.final_stage import FinalStageResult, cate_basis, fit_final_stage
from repro.core.nuisance import Nuisance, make_nuisance


@dataclasses.dataclass(frozen=True)
class FitContext:
    """Everything needed to re-run the estimation as one batched program
    (bootstrap replicates re-derive folds from ``key`` for exact replay)."""

    y: jax.Array
    t: jax.Array
    XW: jax.Array     # nuisance covariates (X ++ W)
    phi: jax.Array    # (n, p_phi) CATE basis
    key: jax.Array
    nuis_y: Nuisance
    nuis_t: Nuisance
    rules: Any = None


@dataclasses.dataclass(frozen=True)
class DMLResult:
    theta: jax.Array             # (p_phi,) final-stage coefficients
    cov: jax.Array               # (p_phi, p_phi)
    cfg: CausalConfig
    crossfit: CrossfitResult
    final: FinalStageResult
    diagnostics: Diagnostics
    fit_ctx: Optional[FitContext] = None
    _inf_cache: Dict[Any, Any] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    @property
    def ate(self) -> float:
        """With phi = [1, x...], theta[0] is the effect at x = 0; for the
        constant basis it IS the ATE.  For heterogeneous bases use
        ``cate(X).mean()``."""
        return float(self.theta[0])

    @property
    def stderr(self) -> jax.Array:
        return jnp.sqrt(jnp.diag(self.cov))

    def cate(self, X: jax.Array) -> jax.Array:
        phi = cate_basis(X, self.cfg.cate_features)
        return phi @ self.theta

    def ate_of(self, X: jax.Array) -> float:
        return float(self.cate(X).mean())

    def conf_int(self, alpha: float = 0.05):
        from repro.inference.intervals import z_crit
        se = self.stderr
        z = z_crit(alpha)
        return self.theta - z * se, self.theta + z * se

    # -- uncertainty quantification (repro.inference) -------------------
    def inference(self, *, method: Optional[str] = None,
                  n_bootstrap: Optional[int] = None,
                  executor: Optional[str] = None,
                  alpha: Optional[float] = None):
        """Replicate-based inference, computed lazily and cached.  The B
        re-estimations run as ONE program through the configured
        Executor (cfg.inference_executor); ``method`` overrides
        cfg.inference (bootstrap | multiplier | jackknife).  The
        replicates are alpha-independent, so alpha is NOT part of the
        cache key — a new level re-quantiles the stored draws."""
        from repro.inference import (delete_fold_jackknife, dml_bootstrap)
        if self.fit_ctx is None:
            raise ValueError("result carries no fit context; re-fit with "
                             "DML.fit to enable replicate inference")
        method = method or self.cfg.inference
        if method in ("none", ""):
            raise ValueError("cfg.inference='none'; pass method= to force")
        n_boot = n_bootstrap or self.cfg.n_bootstrap
        exe = executor or self.cfg.inference_executor
        a = self.cfg.alpha if alpha is None else alpha
        cache_key = (method, n_boot, exe)
        if cache_key in self._inf_cache:
            return self._inf_cache[cache_key]
        ctx = self.fit_ctx
        rt_kw = dict(memory_budget=self.cfg.runtime_memory_budget,
                     chunk=self.cfg.runtime_chunk,
                     max_retries=self.cfg.runtime_max_retries)
        if method == "jackknife":
            cf = self.crossfit
            res = delete_fold_jackknife(
                ctx.y, ctx.t, cf.oof_y, cf.oof_t, cf.folds, ctx.phi,
                self.cfg.n_folds, alpha=a, executor=exe,
                point=self.theta, point_se=self.stderr, rules=ctx.rules,
                row_block=self.cfg.row_block, **rt_kw)
        else:
            scheme = "pairs" if method == "bootstrap" else method
            res = dml_bootstrap(
                ctx.nuis_y, ctx.nuis_t, n_folds=self.cfg.n_folds,
                XW=ctx.XW, y=ctx.y, t=ctx.t, phi=ctx.phi,
                key=jax.random.fold_in(ctx.key, 0x0b00), alpha=a,
                n_replicates=n_boot, scheme=scheme, executor=exe,
                point=self.theta, point_se=self.stderr, rules=ctx.rules,
                row_block=self.cfg.row_block, **rt_kw)
        self._inf_cache[cache_key] = res
        return res

    def ate_interval(self, alpha: Optional[float] = None,
                     kind: str = "percentile") -> Tuple[float, float]:
        """(lo, hi) CI for the ATE (theta[0] under the constant basis)
        from cfg.n_bootstrap replicate re-estimations.  Falls back to
        the analytic sandwich CI when cfg.inference == 'none'."""
        a = self.cfg.alpha if alpha is None else alpha
        if self.cfg.inference in ("none", "") or self.fit_ctx is None:
            lo, hi = self.conf_int(a)
            return float(lo[0]), float(hi[0])
        return self.inference(alpha=a).ate_interval(a, kind)

    def cate_interval(self, X: jax.Array, alpha: Optional[float] = None
                      ) -> Tuple[jax.Array, jax.Array]:
        """Pointwise (lo, hi) bands for theta(x) = <phi(x), theta>."""
        from repro.inference.intervals import z_crit
        a = self.cfg.alpha if alpha is None else alpha
        phi = cate_basis(X, self.cfg.cate_features)
        if self.cfg.inference in ("none", "") or self.fit_ctx is None:
            z = z_crit(a)
            se = jnp.sqrt(jnp.clip(jnp.einsum(
                "ni,ij,nj->n", phi, self.cov, phi), 0.0, None))
            c = phi @ self.theta
            return c - z * se, c + z * se
        return self.inference(alpha=a).cate_interval(phi, a)

    def summary(self) -> str:
        lo, hi = self.conf_int()
        lines = ["DML result", "-" * 46,
                 f"{'coef':>4} {'point':>10} {'stderr':>10} "
                 f"{'ci_lo':>9} {'ci_hi':>9}"]
        for i in range(self.theta.shape[0]):
            lines.append(f"θ[{i}] {float(self.theta[i]):>10.4f} "
                         f"{float(self.stderr[i]):>10.4f} "
                         f"{float(lo[i]):>9.4f} {float(hi[i]):>9.4f}")
        d = self.diagnostics
        lines += ["-" * 46,
                  f"ortho-moment |E[e·rt]| = {d.ortho_moment:.2e}",
                  f"overlap: propensity in [{d.min_propensity:.3f}, "
                  f"{d.max_propensity:.3f}]",
                  f"nuisance R²(y) = {d.nuisance_r2_y:.3f}"]
        return "\n".join(lines)


class DML:
    """The estimator facade.  Nuisances default from the CausalConfig;
    pass explicit ``Nuisance`` objects to override (e.g. tuned models
    from repro.core.tuning, or backbone-feature heads)."""

    def __init__(self, cfg: CausalConfig,
                 nuisance_y: Optional[Nuisance] = None,
                 nuisance_t: Optional[Nuisance] = None,
                 rules=None):
        self.cfg = cfg
        t_task = "clf" if cfg.discrete_treatment else "reg"
        self.nuis_y = nuisance_y or make_nuisance(cfg.nuisance_y, "reg", cfg)
        self.nuis_t = nuisance_t or make_nuisance(cfg.nuisance_t, t_task, cfg)
        self.rules = rules

    def fit(self, y: jax.Array, t: jax.Array, X: jax.Array,
            W: Optional[jax.Array] = None,
            key: Optional[jax.Array] = None) -> DMLResult:
        """y, t: (n,); X: (n, p) effect-relevant covariates; W: optional
        extra controls (concatenated for nuisance fitting only, exactly
        EconML's X/W split)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        XW = X if W is None else jnp.concatenate([X, W], axis=1)
        cf = crossfit(self.nuis_y, self.nuis_t, key, XW, y, t,
                      self.cfg.n_folds, self.cfg.engine, self.rules)
        phi = cate_basis(X, self.cfg.cate_features)
        fs = fit_final_stage(y, t, cf.oof_y, cf.oof_t, phi,
                             row_block=self.cfg.row_block,
                             strategy=self.cfg.row_block_strategy,
                             rules=self.rules)
        theta_at_x = phi @ fs.theta
        diag = compute_diagnostics(y, t, cf.oof_y, cf.oof_t, theta_at_x)
        ctx = FitContext(y=y, t=t, XW=XW, phi=phi, key=key,
                         nuis_y=self.nuis_y, nuis_t=self.nuis_t,
                         rules=self.rules)
        return DMLResult(theta=fs.theta, cov=fs.cov, cfg=self.cfg,
                         crossfit=cf, final=fs, diagnostics=diag,
                         fit_ctx=ctx)
