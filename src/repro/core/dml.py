"""Double/Debiased ML estimator (Chernozhukov et al. 2018) — the
algorithm the paper scales.  ``DML(engine="parallel")`` is the paper's
DML_Ray translated to SPMD; ``engine="sequential"`` is the EconML
baseline it benchmarks against (both produce identical estimates up to
fold-init PRNG; tests assert the equivalence).

Usage (mirrors the paper's §5.1 listing):

    est = DML(CausalConfig(n_folds=5, nuisance_y="ridge",
                           nuisance_t="logistic", engine="parallel"))
    res = est.fit(y, t, X=X, key=jax.random.PRNGKey(0))
    res.ate, res.stderr, res.cate(X_new)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import CausalConfig
from repro.core.crossfit import CrossfitResult, crossfit
from repro.core.estimands import Diagnostics, compute_diagnostics
from repro.core.final_stage import FinalStageResult, cate_basis, fit_final_stage
from repro.core.nuisance import Nuisance, make_nuisance


@dataclasses.dataclass(frozen=True)
class DMLResult:
    theta: jax.Array             # (p_phi,) final-stage coefficients
    cov: jax.Array               # (p_phi, p_phi)
    cfg: CausalConfig
    crossfit: CrossfitResult
    final: FinalStageResult
    diagnostics: Diagnostics

    @property
    def ate(self) -> float:
        """With phi = [1, x...], theta[0] is the effect at x = 0; for the
        constant basis it IS the ATE.  For heterogeneous bases use
        ``cate(X).mean()``."""
        return float(self.theta[0])

    @property
    def stderr(self) -> jax.Array:
        return jnp.sqrt(jnp.diag(self.cov))

    def cate(self, X: jax.Array) -> jax.Array:
        phi = cate_basis(X, self.cfg.cate_features)
        return phi @ self.theta

    def ate_of(self, X: jax.Array) -> float:
        return float(self.cate(X).mean())

    def conf_int(self, alpha: float = 0.05):
        z = 1.959963984540054 if alpha == 0.05 else \
            float(jax.scipy.stats.norm.ppf(1 - alpha / 2))
        se = self.stderr
        return self.theta - z * se, self.theta + z * se

    def summary(self) -> str:
        lo, hi = self.conf_int()
        lines = ["DML result", "-" * 46,
                 f"{'coef':>4} {'point':>10} {'stderr':>10} "
                 f"{'ci_lo':>9} {'ci_hi':>9}"]
        for i in range(self.theta.shape[0]):
            lines.append(f"θ[{i}] {float(self.theta[i]):>10.4f} "
                         f"{float(self.stderr[i]):>10.4f} "
                         f"{float(lo[i]):>9.4f} {float(hi[i]):>9.4f}")
        d = self.diagnostics
        lines += ["-" * 46,
                  f"ortho-moment |E[e·rt]| = {d.ortho_moment:.2e}",
                  f"overlap: propensity in [{d.min_propensity:.3f}, "
                  f"{d.max_propensity:.3f}]",
                  f"nuisance R²(y) = {d.nuisance_r2_y:.3f}"]
        return "\n".join(lines)


class DML:
    """The estimator facade.  Nuisances default from the CausalConfig;
    pass explicit ``Nuisance`` objects to override (e.g. tuned models
    from repro.core.tuning, or backbone-feature heads)."""

    def __init__(self, cfg: CausalConfig,
                 nuisance_y: Optional[Nuisance] = None,
                 nuisance_t: Optional[Nuisance] = None,
                 rules=None):
        self.cfg = cfg
        t_task = "clf" if cfg.discrete_treatment else "reg"
        self.nuis_y = nuisance_y or make_nuisance(cfg.nuisance_y, "reg", cfg)
        self.nuis_t = nuisance_t or make_nuisance(cfg.nuisance_t, t_task, cfg)
        self.rules = rules

    def fit(self, y: jax.Array, t: jax.Array, X: jax.Array,
            W: Optional[jax.Array] = None,
            key: Optional[jax.Array] = None) -> DMLResult:
        """y, t: (n,); X: (n, p) effect-relevant covariates; W: optional
        extra controls (concatenated for nuisance fitting only, exactly
        EconML's X/W split)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        XW = X if W is None else jnp.concatenate([X, W], axis=1)
        cf = crossfit(self.nuis_y, self.nuis_t, key, XW, y, t,
                      self.cfg.n_folds, self.cfg.engine, self.rules)
        phi = cate_basis(X, self.cfg.cate_features)
        fs = fit_final_stage(y, t, cf.oof_y, cf.oof_t, phi)
        theta_at_x = phi @ fs.theta
        diag = compute_diagnostics(y, t, cf.oof_y, cf.oof_t, theta_at_x)
        return DMLResult(theta=fs.theta, cov=fs.cov, cfg=self.cfg,
                         crossfit=cf, final=fs, diagnostics=diag)
