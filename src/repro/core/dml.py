"""Double/Debiased ML estimator (Chernozhukov et al. 2018) — the
algorithm the paper scales.  ``DML(engine="parallel")`` is the paper's
DML_Ray translated to SPMD; ``engine="sequential"`` is the EconML
baseline it benchmarks against (both produce identical estimates up to
fold-init PRNG; tests assert the equivalence).

Usage (mirrors the paper's §5.1 listing):

    est = DML(CausalConfig(n_folds=5, nuisance_y="ridge",
                           nuisance_t="logistic", engine="parallel"))
    res = est.fit(y, t, X=X, key=jax.random.PRNGKey(0))
    res.ate, res.stderr, res.cate(X_new)
    res.ate_interval()            # B=cfg.n_bootstrap replicates, one
    res.cate_interval(X_new)      # vmapped program (repro.inference)

The fit -> inference plumbing (interval methods, replicate caching,
analytic fallbacks) lives in the shared base layer
``repro.core.estimator``; this module supplies only the DML-specific
pieces: the fit program and the replicate-inference dispatch.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import CausalConfig
from repro.core.crossfit import CrossfitResult, crossfit
from repro.core.estimands import Diagnostics, compute_diagnostics
from repro.core.estimator import (SandwichEffectResult, inf_cache_field,
                                  resolve_scheme)
from repro.core.final_stage import FinalStageResult, cate_basis, fit_final_stage
from repro.core.nuisance import Nuisance, make_nuisance


@dataclasses.dataclass(frozen=True)
class FitContext:
    """Everything needed to re-run the estimation as one batched program
    (bootstrap replicates re-derive folds from ``key`` for exact replay)."""

    y: jax.Array
    t: jax.Array
    XW: jax.Array     # nuisance covariates (X ++ W)
    phi: jax.Array    # (n, p_phi) CATE basis
    key: jax.Array
    nuis_y: Nuisance
    nuis_t: Nuisance
    rules: Any = None


@dataclasses.dataclass(frozen=True)
class DMLResult(SandwichEffectResult):
    theta: jax.Array             # (p_phi,) final-stage coefficients
    cov: jax.Array               # (p_phi, p_phi)
    cfg: CausalConfig
    crossfit: CrossfitResult
    final: FinalStageResult
    diagnostics: Diagnostics
    fit_ctx: Optional[FitContext] = None
    _inf_cache: Dict[Any, Any] = inf_cache_field()

    estimator_name = "DML"

    def _replicate_inference(self, method, n_boot, exe, alpha):
        """Replicate re-estimation through the task runtime: delete-fold
        jackknife off the existing fold states, or B weighted refits
        (pairs/multiplier bootstrap) as one batched program."""
        from repro.inference import delete_fold_jackknife, dml_bootstrap
        ctx = self.fit_ctx
        rt_kw = self._runtime_kwargs()
        if method == "jackknife":
            cf = self.crossfit
            return delete_fold_jackknife(
                ctx.y, ctx.t, cf.oof_y, cf.oof_t, cf.folds, ctx.phi,
                self.cfg.n_folds, alpha=alpha, executor=exe,
                point=self.theta, point_se=self.stderr, rules=ctx.rules,
                row_block=self.cfg.row_block, **rt_kw)
        return dml_bootstrap(
            ctx.nuis_y, ctx.nuis_t, n_folds=self.cfg.n_folds,
            XW=ctx.XW, y=ctx.y, t=ctx.t, phi=ctx.phi,
            key=jax.random.fold_in(ctx.key, 0x0b00), alpha=alpha,
            n_replicates=n_boot, scheme=resolve_scheme(method),
            executor=exe, point=self.theta, point_se=self.stderr,
            rules=ctx.rules, row_block=self.cfg.row_block, **rt_kw)

    def _summary_extra(self):
        d = self.diagnostics
        return (f"ortho-moment |E[e·rt]| = {d.ortho_moment:.2e}",
                f"overlap: propensity in [{d.min_propensity:.3f}, "
                f"{d.max_propensity:.3f}]",
                f"nuisance R²(y) = {d.nuisance_r2_y:.3f}")


class DML:
    """The estimator facade.  Nuisances default from the CausalConfig;
    pass explicit ``Nuisance`` objects to override (e.g. tuned models
    from repro.core.tuning, or backbone-feature heads)."""

    def __init__(self, cfg: CausalConfig,
                 nuisance_y: Optional[Nuisance] = None,
                 nuisance_t: Optional[Nuisance] = None,
                 rules=None):
        self.cfg = cfg
        t_task = "clf" if cfg.discrete_treatment else "reg"
        self.nuis_y = nuisance_y or make_nuisance(cfg.nuisance_y, "reg", cfg)
        self.nuis_t = nuisance_t or make_nuisance(cfg.nuisance_t, t_task, cfg)
        self.rules = rules

    def fit(self, y: jax.Array, t: jax.Array, X: jax.Array,
            W: Optional[jax.Array] = None,
            key: Optional[jax.Array] = None) -> DMLResult:
        """y, t: (n,); X: (n, p) effect-relevant covariates; W: optional
        extra controls (concatenated for nuisance fitting only, exactly
        EconML's X/W split)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        XW = X if W is None else jnp.concatenate([X, W], axis=1)
        cf = crossfit(self.nuis_y, self.nuis_t, key, XW, y, t,
                      self.cfg.n_folds, self.cfg.engine, self.rules)
        phi = cate_basis(X, self.cfg.cate_features)
        fs = fit_final_stage(y, t, cf.oof_y, cf.oof_t, phi,
                             row_block=self.cfg.row_block,
                             strategy=self.cfg.row_block_strategy,
                             rules=self.rules)
        theta_at_x = phi @ fs.theta
        diag = compute_diagnostics(y, t, cf.oof_y, cf.oof_t, theta_at_x)
        ctx = FitContext(y=y, t=t, XW=XW, phi=phi, key=key,
                         nuis_y=self.nuis_y, nuis_t=self.nuis_t,
                         rules=self.rules)
        return DMLResult(theta=fs.theta, cov=fs.cov, cfg=self.cfg,
                         crossfit=cf, final=fs, diagnostics=diag,
                         fit_ctx=ctx)
