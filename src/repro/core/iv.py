"""Orthogonal instrumental-variable estimators — the paper's remaining
EconML workload (OrthoIV / DMLIV / DRIV are the estimators its case
study parallelizes alongside DML and DRLearner).

Two estimators on the SAME substrate every other estimand uses
(streaming moments engine + crossfit engine + task runtime):

  OrthoIV   partially-linear IV: cross-fit m_y = E[Y|X], m_t = E[T|X],
            m_z = E[Z|X]; solve the residual-on-residual 2SLS moment

                E[ rz · φ(x) · (ry - <θ, φ(x)>·rt) ] = 0
                ⇒  (Σ rz·rt·φφᵀ) θ = Σ rz·ry·φ

            via ONE instrumented augmented Gram (moments.iv_gram, the
            M = [rz·φ | rt·φ | ry] form — bit-identical chunked vs
            whole).  With the constant basis θ is the classic Wald /
            2SLS ratio of residual covariances; under binary-Z
            compliance designs it targets the LATE.

  DRIV      doubly-robust IV CATE (Syrgkanis et al. 2019; EconML's
            DRIV): one more cross-fit nuisance β(x) = E[rt·rz|X] (the
            conditional compliance covariance), a preliminary constant
            OrthoIV estimate θ_pre, and the pseudo-outcome

                ψ = θ_pre + (ry - θ_pre·rt) · rz / clip(β(x))

            regressed on φ(x).  Consistent if either the residual
            nuisances or the preliminary estimate is good; mean ψ is
            the LATE functional with its own bootstrap draws.

Inference mirrors DML: analytic HC0 sandwich CIs for free, replicate
inference (pairs/multiplier bootstrap, delete-fold jackknife) routed
through ``repro.runtime`` chunked scheduling, every replicate closure
built from the replicate-invariant vocabulary so serial ≡ vmap holds
bitwise per replicate.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import CausalConfig
from repro.core import moments
from repro.core.crossfit import crossfit_one, fold_ids
from repro.core.estimands import IVDiagnostics, compute_iv_diagnostics
from repro.core.estimator import (PseudoOutcomeEffectResult,
                                  SandwichEffectResult, inf_cache_field,
                                  resolve_scheme)
from repro.core.final_stage import cate_basis
from repro.core.nuisance import Nuisance, make_nuisance, make_ridge
from repro.inference.numerics import det_inv, det_solve


# ---------------------------------------------------------------------------
# Three-nuisance cross-fitting (shared folds, shared engine dispatch).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class IVCrossfitResult:
    oof_y: jax.Array      # (n,) out-of-fold E[Y|X]
    oof_t: jax.Array      # (n,) out-of-fold E[T|X]
    oof_z: jax.Array      # (n,) out-of-fold E[Z|X]
    folds: jax.Array      # (n,) fold assignment
    states_y: Any
    states_t: Any
    states_z: Any


def iv_crossfit(nuis_y: Nuisance, nuis_t: Nuisance, nuis_z: Nuisance,
                key: jax.Array, X: jax.Array, y: jax.Array, t: jax.Array,
                z: jax.Array, k: int, engine: str = "parallel",
                rules=None) -> IVCrossfitResult:
    """Cross-fit the three IV nuisances over ONE fold assignment — three
    ``crossfit_one`` dispatches through whichever engine cfg selects
    (parallel / sequential / parallel_loo / an Executor instance)."""
    kf, ky, kt, kz = jax.random.split(key, 4)
    folds = fold_ids(kf, X.shape[0], k)
    oof_y, st_y = crossfit_one(nuis_y, ky, X, y, folds, k, engine, rules)
    oof_t, st_t = crossfit_one(nuis_t, kt, X, t, folds, k, engine, rules)
    oof_z, st_z = crossfit_one(nuis_z, kz, X, z, folds, k, engine, rules)
    return IVCrossfitResult(oof_y=oof_y, oof_t=oof_t, oof_z=oof_z,
                            folds=folds, states_y=st_y, states_t=st_t,
                            states_z=st_z)


# ---------------------------------------------------------------------------
# Instrumented final stage.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class IVFinalStageResult:
    theta: jax.Array       # (p_phi,)
    cov: jax.Array         # (p_phi, p_phi) HC0 sandwich
    j_gram: jax.Array      # (p_phi, p_phi) Σ rz·rt·φφᵀ / n
    n: int

    @property
    def stderr(self) -> jax.Array:
        return jnp.sqrt(jnp.diag(self.cov))


def fit_iv_final_stage(ry: jax.Array, rt: jax.Array, rz: jax.Array,
                       phi: jax.Array, *, w: Optional[jax.Array] = None,
                       ridge: float = 1e-8, row_block: int = 0,
                       strategy: Optional[str] = None, rules=None
                       ) -> IVFinalStageResult:
    """Solve the instrumented orthogonal moment Jθ = b with HC0
    sandwich covariance — all statistics off one ``iv_gram`` pass plus
    one meat pass, streamed in fixed-order row blocks when
    ``row_block > 0``.  Deterministic Gauss-Jordan solves (no LAPACK),
    so the point fit is bitwise the w=1 replicate."""
    n, p = phi.shape
    f32 = jnp.float32
    ws = jnp.ones((n,), f32) if w is None else w.astype(f32)
    Gaug, n_eff = moments.iv_gram(ry, rt, rz, phi, ws,
                                  row_block=row_block, strategy=strategy,
                                  rules=rules)
    J, b, _, _ = moments.iv_slices(Gaug, p)
    n_eff = jnp.maximum(n_eff, 1.0)
    A = J + ridge * n_eff * jnp.eye(p, dtype=f32)
    theta = det_solve(A, b)
    meat = moments.iv_meat(ry, rt, rz, phi, theta, w=w,
                           row_block=row_block, strategy=strategy,
                           rules=rules)
    Ainv = det_inv(A)
    cov = jnp.einsum("ia,ab,bj->ij", Ainv, meat, Ainv)
    return IVFinalStageResult(theta=theta, cov=cov, j_gram=J / n, n=n)


# ---------------------------------------------------------------------------
# OrthoIV — the partially-linear IV estimator facade.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class IVFitContext:
    """Replay context for replicate inference (bootstrap replicates
    re-derive folds from ``key``, exactly like DML's FitContext)."""

    y: jax.Array
    t: jax.Array
    z: jax.Array
    XW: jax.Array     # nuisance covariates (X ++ W)
    phi: jax.Array    # (n, p_phi) CATE basis
    key: jax.Array
    nuis_y: Nuisance
    nuis_t: Nuisance
    nuis_z: Nuisance
    compliance: Optional[Nuisance] = None   # DRIV's β(x) model
    rules: Any = None


@dataclasses.dataclass(frozen=True)
class OrthoIVResult(SandwichEffectResult):
    theta: jax.Array             # (p_phi,) final-stage coefficients
    cov: jax.Array               # (p_phi, p_phi)
    cfg: CausalConfig
    crossfit: IVCrossfitResult
    final: IVFinalStageResult
    diagnostics: IVDiagnostics
    fit_ctx: Optional[IVFitContext] = None
    _inf_cache: Dict[Any, Any] = inf_cache_field()

    estimator_name = "OrthoIV"

    def _replicate_inference(self, method, n_boot, exe, alpha):
        """Replicate inference through the task runtime: delete-fold
        jackknife off ONE segmented instrumented-Gram pass, or B
        weighted 2SLS refits as one batched program."""
        from repro.inference import iv_bootstrap
        from repro.inference.jackknife import delete_fold_jackknife_iv
        ctx = self.fit_ctx
        rt_kw = self._runtime_kwargs()
        if method == "jackknife":
            cf = self.crossfit
            return delete_fold_jackknife_iv(
                ctx.y, ctx.t, ctx.z, cf.oof_y, cf.oof_t, cf.oof_z,
                cf.folds, ctx.phi, self.cfg.n_folds, alpha=alpha,
                executor=exe, point=self.theta, point_se=self.stderr,
                rules=ctx.rules, row_block=self.cfg.row_block, **rt_kw)
        return iv_bootstrap(
            ctx.nuis_y, ctx.nuis_t, ctx.nuis_z,
            n_folds=self.cfg.n_folds, XW=ctx.XW, y=ctx.y, t=ctx.t,
            z=ctx.z, phi=ctx.phi,
            key=jax.random.fold_in(ctx.key, 0x1b00), alpha=alpha,
            n_replicates=n_boot, scheme=resolve_scheme(method),
            executor=exe, point=self.theta, point_se=self.stderr,
            rules=ctx.rules, row_block=self.cfg.row_block, **rt_kw)

    def _summary_extra(self):
        d = self.diagnostics
        flag = "WEAK" if d.weak_instrument else "ok"
        return (f"IV-moment |E[e·rz]| = {d.ortho_moment:.2e}",
                f"first-stage F = {d.first_stage_f:.1f} [{flag}]",
                f"corr(rz, rt) = {d.instrument_corr:+.3f}",
                f"instrument overlap: E[Z|X] in "
                f"[{d.min_instrument_propensity:.3f}, "
                f"{d.max_instrument_propensity:.3f}]")


class OrthoIV:
    """Partially-linear IV via the residual-on-residual 2SLS moment.
    Nuisances default from the CausalConfig (``nuisance_z`` selects the
    instrument model: logistic for a binary instrument, ridge/mlp
    otherwise); pass explicit ``Nuisance`` objects to override (tuned
    models from repro.core.tuning)."""

    def __init__(self, cfg: CausalConfig,
                 nuisance_y: Optional[Nuisance] = None,
                 nuisance_t: Optional[Nuisance] = None,
                 nuisance_z: Optional[Nuisance] = None,
                 rules=None):
        self.cfg = cfg
        t_task = "clf" if cfg.discrete_treatment else "reg"
        z_task = "clf" if cfg.discrete_instrument else "reg"
        z_kind = cfg.nuisance_z if cfg.discrete_instrument else (
            "ridge" if cfg.nuisance_z == "logistic" else cfg.nuisance_z)
        self.nuis_y = nuisance_y or make_nuisance(cfg.nuisance_y, "reg", cfg)
        self.nuis_t = nuisance_t or make_nuisance(cfg.nuisance_t, t_task, cfg)
        self.nuis_z = nuisance_z or make_nuisance(z_kind, z_task, cfg)
        self.rules = rules

    def fit(self, y: jax.Array, t: jax.Array, z: jax.Array,
            X: jax.Array, W: Optional[jax.Array] = None,
            key: Optional[jax.Array] = None) -> OrthoIVResult:
        """y, t, z: (n,); X: (n, p) effect covariates; W: optional extra
        controls (nuisance fitting only, EconML's X/W split)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        XW = X if W is None else jnp.concatenate([X, W], axis=1)
        cf = iv_crossfit(self.nuis_y, self.nuis_t, self.nuis_z, key, XW,
                         y, t, z, self.cfg.n_folds, self.cfg.engine,
                         self.rules)
        f32 = jnp.float32
        ry = y.astype(f32) - cf.oof_y
        rt = t.astype(f32) - cf.oof_t
        rz = z.astype(f32) - cf.oof_z
        phi = cate_basis(X, self.cfg.cate_features)
        fs = fit_iv_final_stage(ry, rt, rz, phi,
                                row_block=self.cfg.row_block,
                                strategy=self.cfg.row_block_strategy,
                                rules=self.rules)
        e = ry - (rt[:, None] * phi * fs.theta[None, :]).sum(axis=1)
        diag = compute_iv_diagnostics(t, z, cf.oof_t, cf.oof_z, e)
        ctx = IVFitContext(y=y, t=t, z=z, XW=XW, phi=phi, key=key,
                           nuis_y=self.nuis_y, nuis_t=self.nuis_t,
                           nuis_z=self.nuis_z, rules=self.rules)
        return OrthoIVResult(theta=fs.theta, cov=fs.cov, cfg=self.cfg,
                             crossfit=cf, final=fs, diagnostics=diag,
                             fit_ctx=ctx)


# ---------------------------------------------------------------------------
# DRIV — doubly-robust IV CATE.
# ---------------------------------------------------------------------------

def clip_compliance(beta: jax.Array, clip: float) -> jax.Array:
    """Sign-preserving magnitude floor on the compliance denominator
    β(x) = E[rt·rz|X] (EconML's cov_clip): zero crossings clamp to
    +clip."""
    return jnp.where(beta >= 0, jnp.maximum(beta, clip),
                     jnp.minimum(beta, -clip))


@dataclasses.dataclass(frozen=True)
class DRIVResult(PseudoOutcomeEffectResult):
    ate: float                # mean pseudo-outcome: the LATE functional
    stderr: float
    theta: jax.Array          # CATE coefficients on phi(x)
    pseudo: jax.Array         # (n,) DRIV pseudo-outcomes
    theta_pre: float          # the preliminary constant OrthoIV estimate
    diagnostics: IVDiagnostics
    cfg: Optional[CausalConfig] = None
    fit_ctx: Optional[IVFitContext] = None
    _inf_cache: Dict[Any, Any] = inf_cache_field()

    estimator_name = "DRIV"

    late = property(lambda self: self.ate)

    def _resolve_method(self, method):
        if method == "jackknife":
            # unlike OrthoIV, the DRIV pipeline has no LOO-identity
            # shortcut (the pseudo-outcome depends on every fold's
            # nuisances); silently substituting a bootstrap would make
            # jackknife-vs-jackknife comparisons lie
            raise ValueError(
                "DRIV has no delete-fold jackknife; use "
                "method='bootstrap'|'multiplier', or OrthoIV for a "
                "jackknife over the instrumented moment")
        return method

    def _replicate_inference(self, method, n_boot, exe, alpha):
        """Bootstrap the whole DRIV pipeline (nuisances, compliance,
        preliminary estimate, pseudo-outcome regression) as one
        runtime-scheduled program (the LATE functional's own draws ride
        along)."""
        from repro.inference import driv_bootstrap
        cfg = self._config()
        ctx = self.fit_ctx
        return driv_bootstrap(
            ctx.nuis_y, ctx.nuis_t, ctx.nuis_z, ctx.compliance,
            n_folds=cfg.n_folds, XW=ctx.XW, y=ctx.y, t=ctx.t, z=ctx.z,
            phi=ctx.phi, key=jax.random.fold_in(ctx.key, 0x1b00),
            alpha=alpha, n_replicates=n_boot,
            scheme=resolve_scheme(method), executor=exe,
            cov_clip=cfg.iv_cov_clip, point=self.theta,
            ate_point=self.ate, rules=ctx.rules,
            row_block=cfg.row_block, **self._runtime_kwargs())


class DRIV:
    """fit(y, t, z, X): 4 cross-fit nuisances (m_y, m_t, m_z, β) + the
    doubly-robust pseudo-outcome regression."""

    def __init__(self, cfg: CausalConfig,
                 nuisance_y: Optional[Nuisance] = None,
                 nuisance_t: Optional[Nuisance] = None,
                 nuisance_z: Optional[Nuisance] = None,
                 compliance: Optional[Nuisance] = None,
                 rules=None):
        self.cfg = cfg
        base = OrthoIV(cfg, nuisance_y, nuisance_t, nuisance_z, rules)
        self.nuis_y, self.nuis_t, self.nuis_z = (base.nuis_y, base.nuis_t,
                                                 base.nuis_z)
        # β(x) = E[rt·rz|X] is a regression whatever Z/T are
        self.compliance = compliance or make_ridge(
            cfg.ridge_lambda, row_block=cfg.row_block,
            strategy=cfg.row_block_strategy)
        self.rules = rules

    def fit(self, y: jax.Array, t: jax.Array, z: jax.Array,
            X: jax.Array, W: Optional[jax.Array] = None,
            key: Optional[jax.Array] = None) -> DRIVResult:
        cfg = self.cfg
        key = key if key is not None else jax.random.PRNGKey(0)
        XW = X if W is None else jnp.concatenate([X, W], axis=1)
        n = X.shape[0]
        f32 = jnp.float32
        cf = iv_crossfit(self.nuis_y, self.nuis_t, self.nuis_z, key, XW,
                         y, t, z, cfg.n_folds, cfg.engine, self.rules)
        ry = y.astype(f32) - cf.oof_y
        rt = t.astype(f32) - cf.oof_t
        rz = z.astype(f32) - cf.oof_z

        # compliance nuisance on the SAME folds: β(x) = E[rt·rz | X]
        kb = jax.random.fold_in(key, 0xbe7a)
        oof_b, _ = crossfit_one(self.compliance, kb, XW, rt * rz,
                                cf.folds, cfg.n_folds, cfg.engine,
                                self.rules)
        beta = clip_compliance(oof_b, cfg.iv_cov_clip)

        # preliminary constant OrthoIV estimate (same moment, phi = 1)
        ones = jnp.ones((n, 1), f32)
        pre = fit_iv_final_stage(ry, rt, rz, ones,
                                 row_block=cfg.row_block,
                                 strategy=cfg.row_block_strategy,
                                 rules=self.rules)
        theta_pre = pre.theta[0]

        psi = theta_pre + (ry - theta_pre * rt) * rz / beta
        ate = float(psi.mean())
        se = float(psi.std(ddof=1) / jnp.sqrt(n))

        # pseudo-outcome regression: one augmented-moments pass
        phi = cate_basis(X, cfg.cate_features)
        q = phi.shape[1]
        Gaug, _ = moments.weighted_gram(phi, jnp.ones((n,), f32),
                                        append=psi,
                                        row_block=cfg.row_block,
                                        strategy=cfg.row_block_strategy)
        G = Gaug[:q, :q] + 1e-8 * n * jnp.eye(q)
        theta = det_solve(G, Gaug[:q, q])

        # the orthogonality diagnostic checks the moment that was
        # actually zeroed — the preliminary 2SLS solve's residual (the
        # pseudo-outcome-regression theta is a projection of ψ, not a
        # solution of E[e·rz·φ] = 0)
        e = ry - theta_pre * rt
        diag = compute_iv_diagnostics(t, z, cf.oof_t, cf.oof_z, e)
        ctx = IVFitContext(y=y, t=t, z=z, XW=XW, phi=phi, key=key,
                           nuis_y=self.nuis_y, nuis_t=self.nuis_t,
                           nuis_z=self.nuis_z, compliance=self.compliance,
                           rules=self.rules)
        return DRIVResult(ate=ate, stderr=se, theta=theta, pseudo=psi,
                          theta_pre=float(theta_pre), diagnostics=diag,
                          cfg=cfg, fit_ctx=ctx)
