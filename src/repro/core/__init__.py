"""repro.core — the estimation substrate: distributed Double-ML.

The paper's primary contribution, translated from Ray task pools to
batched SPMD programs.  Everything bottoms out in the streaming
sufficient-statistics engine (``moments``); on top of it sit the
shared estimator base layer (``estimator``), fold-parallel
cross-fitting (``crossfit``, paper C1), population-axis tuning
(``tuning``, C2), the DML / DR / metalearner / orthogonal-IV
estimator facades, the refutation suite, and the registry
(``registry``) that tests, benchmarks, ``repro.sweep``, and
``repro.store`` all consume as the single source of truth.
Uncertainty quantification lives in ``repro.inference``; segment
panels in ``repro.sweep``; incremental refresh in ``repro.store``.
"""
#   moments.py      streaming sufficient-statistics engine (the single
#                   estimation substrate: whole-array or row-chunked,
#                   bit-identical by construction)
#   estimator.py    the shared estimator base layer (EffectResult: one
#                   copy of the fit -> inference plumbing)
#   registry.py     the estimator registry (one source of truth for
#                   tests, benchmarks, and repro.sweep)
#   crossfit.py     C1 fold-parallel cross-fitting (+ sequential baseline)
#   tuning.py       C2 population-axis hyper-parameter search
#   dml.py          the estimator facade (DML / DML_Ray translation)
#   nuisance.py     MXU-native nuisance zoo (ridge/logistic/MLP/backbone)
#   final_stage.py  orthogonal moment via the fused residual_gram kernel
#   iv.py           orthogonal-IV family (OrthoIV / DRIV) on the same
#                   moments + crossfit + runtime substrate
#   metalearners.py S/T/X learners as weighted cores (EffectResult fits)
#   refutation.py   NEXUS validation suite (placebo / RCC / subset /
#                   weak-instrument F screen)
#   estimands.py    ATE/ATT/CATE summaries + diagnostics
# Uncertainty quantification (bootstrap/jackknife CIs) lives in
# repro.inference; tuning + refutation replicate loops dispatch through
# its Executor.  Segment-parallel many-cohorts estimation lives in
# repro.sweep (it consumes the registry).
from repro.core import moments  # noqa: F401
from repro.core.estimator import (CausalEstimator, EffectResult,  # noqa: F401
    PseudoOutcomeEffectResult, SandwichEffectResult)
from repro.core.dml import DML, DMLResult  # noqa: F401
from repro.core.crossfit import (crossfit, crossfit_parallel,  # noqa: F401
    crossfit_parallel_loo, crossfit_sequential)
from repro.core.nuisance import Nuisance, make_nuisance, make_ridge, make_logistic, make_mlp  # noqa: F401
from repro.core.final_stage import cate_basis, fit_final_stage  # noqa: F401
from repro.core.drlearner import DRLearner  # noqa: F401
from repro.core.metalearners import (MetaResult, meta_bootstrap,  # noqa: F401
    make_meta_core, s_learner, t_learner, x_learner)
# iv last: it pulls repro.inference.numerics, whose package __init__
# imports the core submodules above (all satisfied from sys.modules by
# this point — no cycle)
from repro.core.iv import DRIV, OrthoIV  # noqa: F401
# the registry imports the estimator facades above, so it comes last
from repro.core.registry import (REGISTRY, EstimatorSpec,  # noqa: F401
    get_spec)
