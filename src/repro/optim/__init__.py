from repro.optim.adamw import AdamWState, adamw_init, adamw_update  # noqa: F401
from repro.optim.schedule import cosine_schedule, linear_schedule  # noqa: F401
from repro.optim.compression import (compress_decompress,  # noqa: F401
                                     compressed_psum_mean, ErrorFeedback)
