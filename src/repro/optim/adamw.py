"""AdamW with decoupled weight decay, global-norm clipping and
configurable moment dtype (bf16 moments halve optimizer HBM — required to
fit the 480B/671B MoE cells on v5e, see EXPERIMENTS.md §Dry-run)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


@dataclasses.dataclass
class AdamWState:
    step: jax.Array     # () int32
    m: Any              # pytree like params
    v: Any              # pytree like params

    def tree_flatten(self):
        return (self.step, self.m, self.v), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    AdamWState, AdamWState.tree_flatten, AdamWState.tree_unflatten)


def adamw_init(params, moment_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree_util.tree_map(zeros, params),
                      v=jax.tree_util.tree_map(zeros, params))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(grads, state: AdamWState, params, lr: jax.Array,
                 cfg: TrainConfig, moment_dtype=jnp.float32
                 ) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    """One decoupled-weight-decay Adam step.  Math in fp32 regardless of
    param/moment dtypes; returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        mhat = m32 / c1
        vhat = v32 / c2
        p32 = p.astype(jnp.float32)
        delta = mhat / (jnp.sqrt(vhat) + 1e-8) + cfg.weight_decay * p32
        return ((p32 - lr * delta).astype(p.dtype),
                m32.astype(moment_dtype), v32.astype(moment_dtype))

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics
