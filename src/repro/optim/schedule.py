"""LR schedules (pure functions of the step, jit-safe)."""
from __future__ import annotations

import jax.numpy as jnp


def linear_schedule(step, *, peak: float, warmup: int, total: int,
                    floor: float = 0.0):
    s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = peak * s / max(warmup, 1)
    frac = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    decay = peak + (floor - peak) * frac
    return jnp.where(s < warmup, warm, decay)


def cosine_schedule(step, *, peak: float, warmup: int, total: int,
                    floor_frac: float = 0.1):
    s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = peak * s / max(warmup, 1)
    frac = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    floor = peak * floor_frac
    decay = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(s < warmup, warm, decay)
