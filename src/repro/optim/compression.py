"""Gradient compression for data-parallel reductions (+ error feedback).

Distributed-optimization trick for the fold/trial engines (where we own
the reduction via shard_map) and the manual-DP trainer: gradients are
quantized to bf16 or int8 (per-tensor absmax scale) before the psum and
dequantized after, halving/quartering DP collective bytes.  The residual
(g - dequant(quant(g))) is carried as error feedback so the compression
bias vanishes over steps (Karimireddy et al., 2019 — EF-SGD).

Under the pure-pjit path GSPMD owns the all-reduce and this module is
bypassed (documented in DESIGN.md §5); the roofline's collective term is
measured for both variants in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class ErrorFeedback:
    residual: Any  # pytree like grads (fp32)


def ef_init(grads_like) -> ErrorFeedback:
    return ErrorFeedback(residual=jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


jax.tree_util.register_pytree_node(
    ErrorFeedback,
    lambda ef: ((ef.residual,), None),
    lambda aux, ch: ErrorFeedback(residual=ch[0]))


def _quant_one(g: jax.Array, method: str) -> Tuple[jax.Array, jax.Array]:
    """Returns (payload, scale). Payload is what crosses the wire."""
    g32 = g.astype(jnp.float32)
    if method == "bf16":
        return g32.astype(jnp.bfloat16), jnp.ones((), jnp.float32)
    if method == "int8":
        absmax = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12)
        scale = absmax / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        return q, scale
    raise ValueError(method)


def _dequant_one(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_decompress(g: jax.Array, method: str) -> jax.Array:
    """Round-trip a tensor through the compressed representation (what a
    receiver reconstructs). Identity for method == 'none'."""
    if method == "none":
        return g.astype(jnp.float32)
    q, s = _quant_one(g, method)
    return _dequant_one(q, s)


def compressed_psum_mean(grads, axis_name: str, method: str = "none",
                         ef: Optional[ErrorFeedback] = None
                         ) -> Tuple[Any, Optional[ErrorFeedback]]:
    """Mean-reduce ``grads`` over ``axis_name`` inside shard_map/vmap,
    quantizing the payload.  With error feedback, the local residual is
    added before quantization and the new residual carried forward.

    int8 note: scales are per-tensor-per-shard; we psum the dequantized
    payload (the wire format is the int8 tensor + one fp32 scalar, which
    is what the collective-bytes accounting in §Roofline counts)."""
    n = jax.lax.psum(1, axis_name)

    def one(g, r):
        g32 = g.astype(jnp.float32) / n
        if ef is not None:
            g32 = g32 + r
        if method == "none":
            out = jax.lax.psum(g32, axis_name)
            return out, jnp.zeros_like(g32)
        q, s = _quant_one(g32, method)
        sent = _dequant_one(q, s)
        new_r = g32 - sent  # error feedback residual (stays local)
        out = jax.lax.psum(sent.astype(jnp.float32)
                           if method == "int8" else sent, axis_name)
        return out.astype(jnp.float32), new_r

    res = ef.residual if ef is not None else jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(res)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    reduced = treedef.unflatten([o[0] for o in outs])
    new_ef = ErrorFeedback(residual=treedef.unflatten([o[1] for o in outs]))
    return reduced, (new_ef if ef is not None else None)
