"""Data substrate: DGP determinism + ground truth, LM stream lineage,
prefetching feed ordering."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.causal_dgp import (make_causal_data,
                                   make_sharded_causal_data)
from repro.data.lm_data import (bigram_ce_floor, lm_batch_stream,
    synthetic_tokens)
from repro.data.pipeline import ShardedFeed


def test_dgp_deterministic(key):
    d1 = make_causal_data(key, 500, 8)
    d2 = make_causal_data(key, 500, 8)
    np.testing.assert_array_equal(np.asarray(d1.X), np.asarray(d2.X))
    np.testing.assert_array_equal(np.asarray(d1.y), np.asarray(d2.y))


def test_dgp_ground_truth_consistent(key):
    d = make_causal_data(key, 50_000, 10, effect=2.0, heterogeneous=True)
    assert d.true_ate == pytest.approx(float(d.true_cate.mean()))
    # overlap: propensities bounded away from {0,1}
    assert 0.001 < float(d.propensity.min())
    assert float(d.propensity.max()) < 0.999
    # naive difference-in-means is confounded (differs from truth)
    t = d.t
    naive = float((d.y * t).sum() / t.sum()
                  - (d.y * (1 - t)).sum() / (1 - t).sum())
    assert abs(naive - d.true_ate) > 0.05


def test_sharded_dgp_unions(key):
    shards = [make_sharded_causal_data(key, 100, 4, 4, s) for s in range(4)]
    assert all(s.X.shape == (25, 4) for s in shards)
    # shards differ (independent folds of the key)
    assert not np.allclose(np.asarray(shards[0].X), np.asarray(shards[1].X))


def test_lm_stream_lineage(key):
    s1 = lm_batch_stream(key, 2, 16, 97, start_step=0)
    a = [next(s1) for _ in range(3)]
    s2 = lm_batch_stream(key, 2, 16, 97, start_step=2)
    b = next(s2)
    np.testing.assert_array_equal(np.asarray(a[2]["tokens"]),
                                  np.asarray(b["tokens"]))


def test_lm_tokens_learnable_structure(key):
    toks = synthetic_tokens(key, 4, 256, 97)
    nxt = (5 * toks[:, :-1] + 13) % 97
    frac = float((toks[:, 1:] == nxt).mean())
    assert 0.7 < frac < 0.9  # ~1-eps of transitions follow the bigram map
    assert 0 < bigram_ce_floor(97) < np.log(97)


def test_sharded_feed_order_and_close(key):
    feed = ShardedFeed(lambda s: {"x": jnp.full((2,), s)}, depth=2)
    got = [int(next(feed)["x"][0]) for _ in range(5)]
    assert got == [0, 1, 2, 3, 4]
    assert feed.step == 5
    feed.close()


def test_sharded_feed_propagates_errors():
    def boom(s):
        if s == 1:
            raise ValueError("generator failed")
        return {"x": jnp.zeros(())}

    feed = ShardedFeed(boom, depth=1)
    next(feed)
    with pytest.raises(ValueError, match="generator failed"):
        next(feed)
        next(feed)
    feed.close()
