"""Cross-fitting engine invariants (paper C1)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.crossfit import (crossfit_parallel, crossfit_sequential,
                                 fold_ids, fold_weights, _oof_select)
from repro.core.nuisance import Nuisance, make_ridge


def test_fold_ids_balanced(key):
    folds = fold_ids(key, 1000, 5)
    counts = np.bincount(np.asarray(folds), minlength=5)
    assert counts.min() == counts.max() == 200


def test_fold_weights_complement(key):
    folds = fold_ids(key, 100, 4)
    W = fold_weights(folds, 4)
    assert W.shape == (4, 100)
    # each sample is excluded from exactly ONE fold-model's training set
    np.testing.assert_array_equal(np.asarray(W.sum(0)), 3.0 * np.ones(100))
    for j in range(4):
        np.testing.assert_array_equal(np.asarray(W[j] == 0.0),
                                      np.asarray(folds == j))


def test_oof_is_truly_out_of_fold(key):
    """A 'memorizing' nuisance proves row i's prediction cannot come from
    a model that saw row i."""
    n, k = 60, 3
    folds = fold_ids(key, n, k)

    def fit(state, X, y, w):
        return {"seen": w}  # remember exactly which rows were trained on

    def predict(state, X):
        return state["seen"]  # 'prediction' = did I train on this row?

    memorizer = Nuisance("mem", "reg", lambda key, p: {}, fit, predict)
    X = jnp.zeros((n, 2))
    y = jnp.zeros((n,))
    oof, _ = crossfit_parallel(memorizer, key, X, y, folds, k)
    # every row must be predicted by the model that did NOT train on it
    np.testing.assert_array_equal(np.asarray(oof), np.zeros(n))


def test_parallel_equals_sequential_predictions(key):
    n, p, k = 500, 8, 5
    ks = jax.random.split(key, 3)
    X = jax.random.normal(ks[0], (n, p))
    y = X @ jax.random.normal(ks[1], (p,)) + 0.1 * jax.random.normal(
        ks[2], (n,))
    folds = fold_ids(key, n, k)
    ridge = make_ridge(1e-3)
    oof_p, _ = crossfit_parallel(ridge, key, X, y, folds, k)
    oof_s, _ = crossfit_sequential(ridge, key, X, y, folds, k)
    np.testing.assert_allclose(oof_p, oof_s, rtol=1e-5, atol=1e-5)


def test_oof_select(key):
    preds = jnp.arange(12, dtype=jnp.float32).reshape(3, 4)  # (k=3, n=4)
    folds = jnp.asarray([2, 0, 1, 0], jnp.int32)
    out = _oof_select(preds, folds)
    np.testing.assert_array_equal(np.asarray(out), [8., 1., 6., 3.])
