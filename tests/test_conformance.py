"""The cross-estimator conformance suite: ONE parametrized
certification run over every estimator in the promoted registry
(repro.core.registry: DML, DRLearner, S/T/X metalearners, OrthoIV,
DRIV).

Checks per estimator: serial ≡ vmap bootstrap bit-identity at the
estimator's canonical shape, chunked ≡ whole blocked-evaluation
EXACT equality (non-divisible n), row_block cross-setting invariance,
config round-trip, and loose truth recovery.  Plus the kernel-level
batch-invariance pins for the meat forms whose stability is
shape-dispatched (core/moments._meat_gram and the iv_meat p=1 branch).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import CausalConfig
from repro.core.registry import ROW_BLOCK, SPEC_IDS, SPECS, tree_arrays

_FIT_KEY = jax.random.PRNGKey(0)
_DATA_KEY = jax.random.PRNGKey(42)
_data_cache = {}


def _data(spec):
    if spec.make_data not in _data_cache:
        _data_cache[spec.make_data] = spec.make_data(_DATA_KEY)
    return _data_cache[spec.make_data]


def _assert_trees_equal(a, b, msg=""):
    la, lb = tree_arrays(a), tree_arrays(b)
    assert len(la) == len(lb), msg
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=msg)


@pytest.mark.parametrize("spec", SPECS, ids=SPEC_IDS)
def test_chunked_equals_whole_bitwise(spec):
    """Blocked evaluation strategy is an execution detail: for the SAME
    row_block (non-divisible into n, so the zero-padding is exercised)
    the streamed and all-at-once evaluations must agree EXACTLY, all
    the way out to the estimator's public result arrays."""
    data = _data(spec)
    cfg_c = dataclasses.replace(spec.base_cfg, row_block=ROW_BLOCK,
                                row_block_strategy="chunked")
    cfg_w = dataclasses.replace(spec.base_cfg, row_block=ROW_BLOCK,
                                row_block_strategy="whole")
    r_c = spec.fit(data, cfg_c, _FIT_KEY)
    r_w = spec.fit(data, cfg_w, _FIT_KEY)
    _assert_trees_equal(r_c, r_w, f"{spec.name}: chunked != whole")


@pytest.mark.parametrize("backend", ["scatter", "interpret"])
@pytest.mark.parametrize("spec", SPECS, ids=SPEC_IDS)
def test_pallas_strategy_parity(spec, backend):
    """row_block_strategy="pallas" is tolerance-certified against the
    chunked reference for EVERY registry estimator: the fused seg_gram
    lowerings (XLA scatter on CPU, the Pallas kernel in interpret mode
    — the same kernel logic mosaic compiles on TPU) reassociate the
    Gram sums, so the contract is <= 1e-6 on the point estimate, not
    bitwise.  Non-divisible ROW_BLOCK exercises the padding path."""
    from repro.kernels.seg_gram import ops as sg_ops
    data = _data(spec)
    cfg_c = dataclasses.replace(spec.base_cfg, row_block=ROW_BLOCK,
                                row_block_strategy="chunked")
    cfg_p = dataclasses.replace(spec.base_cfg, row_block=ROW_BLOCK,
                                row_block_strategy="pallas")
    r_c = spec.fit(data, cfg_c, _FIT_KEY)
    with sg_ops.force_backend(backend):
        r_p = spec.fit(data, cfg_p, _FIT_KEY)
    np.testing.assert_allclose(spec.point(r_c), spec.point(r_p),
                               rtol=1e-6, atol=1e-6,
                               err_msg=f"{spec.name}[{backend}]")
    if hasattr(r_c, "theta"):
        np.testing.assert_allclose(np.asarray(r_c.theta),
                                   np.asarray(r_p.theta),
                                   rtol=1e-6, atol=1e-6,
                                   err_msg=f"{spec.name}[{backend}]")


@pytest.mark.parametrize("spec", SPECS, ids=SPEC_IDS)
def test_row_block_invariance(spec):
    """Different row_block settings commute only up to float
    reassociation — the estimate must be invariant to tolerance."""
    data = _data(spec)
    r0 = spec.fit(data, spec.base_cfg, _FIT_KEY)
    rb = spec.fit(data, dataclasses.replace(spec.base_cfg,
                                            row_block=ROW_BLOCK),
                  _FIT_KEY)
    assert abs(spec.point(r0) - spec.point(rb)) < spec.rb_tol, spec.name
    if hasattr(r0, "theta"):
        np.testing.assert_allclose(np.asarray(r0.theta),
                                   np.asarray(rb.theta),
                                   rtol=2e-3, atol=2e-4,
                                   err_msg=spec.name)


@pytest.mark.parametrize(
    "spec", [s for s in SPECS if s.boot is not None],
    ids=[s.name for s in SPECS if s.boot is not None])
def test_serial_vmap_bit_identity(spec):
    """The executor contract: per-replicate estimates from the loop
    baseline and the batched program are IDENTICAL at the estimator's
    canonical bit-identity shape — not just close."""
    data = _data(spec)
    r_ser = spec.boot(data, spec.boot_cfg, _FIT_KEY, "serial", 4)
    r_vec = spec.boot(data, spec.boot_cfg, _FIT_KEY, "vmap", 4)
    np.testing.assert_array_equal(np.asarray(r_ser.replicates),
                                  np.asarray(r_vec.replicates),
                                  err_msg=spec.name)
    for attr in ("replicate_se", "ate_replicates"):
        a, b = getattr(r_ser, attr), getattr(r_vec, attr)
        assert (a is None) == (b is None), (spec.name, attr)
        if a is not None:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"{spec.name}.{attr}")


@pytest.mark.parametrize("spec", SPECS, ids=SPEC_IDS)
def test_config_round_trip(spec):
    """asdict -> CausalConfig(**d) is the identity, and the round-
    tripped config drives a bit-identical fit.  The sweep fields
    (segment_key / sweep_chunk) ride along with non-default values so
    the round trip covers them."""
    cfg = dataclasses.replace(spec.base_cfg, segment_key="cohort",
                              sweep_chunk=8)
    cfg2 = CausalConfig(**dataclasses.asdict(cfg))
    assert cfg2 == cfg
    assert (cfg2.segment_key, cfg2.sweep_chunk) == ("cohort", 8)
    data = _data(spec)
    _assert_trees_equal(spec.fit(data, cfg, _FIT_KEY),
                        spec.fit(data, cfg2, _FIT_KEY),
                        f"{spec.name}: config round-trip changed bits")


@pytest.mark.parametrize("spec", SPECS, ids=SPEC_IDS)
def test_truth_recovery(spec):
    """Loose sanity floor: every estimator lands near its DGP's known
    estimand (tight statistical assertions live in the per-estimator
    modules and tests/test_oracle_properties.py)."""
    data = _data(spec)
    res = spec.fit(data, spec.base_cfg, _FIT_KEY)
    err = abs(spec.point(res) - spec.truth(data))
    assert err < spec.truth_tol, (spec.name, spec.point(res),
                                  spec.truth(data))


_META_IDS = ("s_learner", "t_learner", "x_learner")


@pytest.mark.parametrize("spec",
                         [s for s in SPECS if s.name in _META_IDS],
                         ids=list(_META_IDS))
def test_metalearner_ate_interval(spec):
    """Metalearner fits return EffectResult objects (shared engine
    layer), so they carry replicate ate_intervals like every other
    estimator — B weighted learner refits as one batched program."""
    data = _data(spec)
    cfg = dataclasses.replace(spec.base_cfg, inference="bootstrap",
                              n_bootstrap=8)
    res = spec.fit(data, cfg, _FIT_KEY)
    lo, hi = res.ate_interval()
    assert np.isfinite(lo) and np.isfinite(hi) and lo < hi
    assert lo - 0.3 < spec.truth(data) < hi + 0.3, spec.name
    # the metalearner CATE is not phi-linear: bands must refuse loudly
    with pytest.raises(ValueError):
        res.cate_interval(data.X)


# ---------------------------------------------------------------------------
# Kernel-level pins: the meat contractions whose batch invariance is
# shape-dispatched (XLA retiles computed-weight contractions
# differently per width — core/moments._meat_gram documents the
# measured regimes; this is the regression guard for that dispatch).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p", [1, 2, 3])
@pytest.mark.parametrize("kernel", ["residual", "iv"])
def test_meat_kernels_batch_invariant(kernel, p):
    """serial ≡ vmap for the meat kernels on the ROW-BLOCKED path (the
    canonical bit-identity contract: the scan barrier keeps the
    computed-weight contraction from refusing under batching; the
    whole-array forms are batch-invariant only at specific shapes —
    the p_phi = 1 legacy anchor lives in test_inference.py)."""
    from repro.core import moments
    from repro.inference import make_executor
    key = jax.random.PRNGKey(3)
    n = 1100
    ks = jax.random.split(key, 5)
    ry = jax.random.normal(ks[0], (n,))
    rt = jax.random.normal(ks[1], (n,))
    rz = jax.random.normal(ks[2], (n,))
    phi = jax.random.normal(ks[3], (n, p))
    W = jax.random.exponential(ks[4], (4, n))
    theta = jnp.arange(1.0, p + 1)
    if kernel == "residual":
        def fn(w):
            return moments.residual_meat(
                ry, rt, jnp.zeros_like(ry), jnp.zeros_like(rt), phi,
                theta, w=w, row_block=ROW_BLOCK)
    else:
        def fn(w):
            return moments.iv_meat(ry, rt, rz, phi, theta, w=w,
                                   row_block=ROW_BLOCK)
    ser = make_executor("serial").map(fn, W)
    vec = make_executor("vmap").map(fn, W)
    np.testing.assert_array_equal(np.asarray(ser), np.asarray(vec))
    # and the blocked strategies agree exactly (non-divisible n)
    kw = dict(w=W[0], row_block=ROW_BLOCK)
    if kernel == "residual":
        a = moments.residual_meat(ry, rt, jnp.zeros_like(ry),
                                  jnp.zeros_like(rt), phi, theta,
                                  strategy="chunked", **kw)
        b = moments.residual_meat(ry, rt, jnp.zeros_like(ry),
                                  jnp.zeros_like(rt), phi, theta,
                                  strategy="whole", **kw)
    else:
        a = moments.iv_meat(ry, rt, rz, phi, theta, strategy="chunked",
                            **kw)
        b = moments.iv_meat(ry, rt, rz, phi, theta, strategy="whole",
                            **kw)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_iv_gram_slices_consistent():
    """iv_gram's slice map must reproduce the direct einsum forms."""
    from repro.core import moments
    key = jax.random.PRNGKey(5)
    n, p = 777, 2
    ks = jax.random.split(key, 5)
    ry = jax.random.normal(ks[0], (n,))
    rt = jax.random.normal(ks[1], (n,))
    rz = jax.random.normal(ks[2], (n,))
    phi = jax.random.normal(ks[3], (n, p))
    w = jax.random.exponential(ks[4], (n,))
    Gaug, n_eff = moments.iv_gram(ry, rt, rz, phi, w)
    J, b, Szz, Stt = moments.iv_slices(Gaug, p)
    np.testing.assert_allclose(
        np.asarray(J),
        np.einsum("n,ni,nj->ij", np.asarray(w * rz * rt),
                  np.asarray(phi), np.asarray(phi)), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(b),
        np.einsum("n,ni->i", np.asarray(w * rz * ry), np.asarray(phi)),
        rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(Szz),
        np.einsum("n,ni,nj->ij", np.asarray(w * rz * rz),
                  np.asarray(phi), np.asarray(phi)), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(Stt),
        np.einsum("n,ni,nj->ij", np.asarray(w * rt * rt),
                  np.asarray(phi), np.asarray(phi)), rtol=1e-5)
    assert float(n_eff) == pytest.approx(float(w.sum()))
    # chunked ≡ whole, non-divisible n
    a = moments.iv_gram(ry, rt, rz, phi, w, row_block=ROW_BLOCK,
                        strategy="chunked")
    bb = moments.iv_gram(ry, rt, rz, phi, w, row_block=ROW_BLOCK,
                         strategy="whole")
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(bb[0]))
