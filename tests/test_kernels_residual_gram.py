"""Fused residual->Gram kernel vs the jnp oracle (the DML final-stage
hot spot), including the wrapper's padding paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.residual_gram import ops as rg_ops
from repro.kernels.residual_gram import ref as rg_ref


def _mk(key, n, p):
    ks = jax.random.split(key, 5)
    y = jax.random.normal(ks[0], (n,))
    t = jax.random.bernoulli(ks[1], 0.5, (n,)).astype(jnp.float32)
    my = jax.random.normal(ks[2], (n,)) * 0.1
    mt = jax.random.uniform(ks[3], (n,), minval=0.1, maxval=0.9)
    phi = jax.random.normal(ks[4], (n, p))
    return y, t, my, mt, phi


@pytest.mark.parametrize("n,p,block_n", [
    (512, 8, 128), (1024, 32, 256), (256, 1, 64), (768, 17, 256),
])
def test_kernel_matches_ref(key, n, p, block_n):
    y, t, my, mt, phi = _mk(key, n, p)
    g_ref, b_ref = rg_ref.residual_gram_ref(y, t, my, mt, phi)
    g, b = rg_ops.residual_gram(y, t, my, mt, phi, backend="interpret",
                                block_n=block_n)
    np.testing.assert_allclose(g, g_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(b, b_ref, rtol=1e-4, atol=1e-4)


def test_padding_is_exact(key):
    """n not divisible by block_n and p not multiple of 128: the wrapper
    zero-pads; zero rows/cols are exact no-ops in G and b."""
    y, t, my, mt, phi = _mk(key, 700, 9)
    g_ref, b_ref = rg_ref.residual_gram_ref(y, t, my, mt, phi)
    g, b = rg_ops.residual_gram(y, t, my, mt, phi, backend="interpret",
                                block_n=256)
    np.testing.assert_allclose(g, g_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(b, b_ref, rtol=1e-4, atol=1e-4)


def test_theta_solution_recovers_effect(key):
    """End-to-end sanity: G^{-1} b on clean residuals recovers theta."""
    n = 4096
    ks = jax.random.split(key, 3)
    rt = jax.random.normal(ks[0], (n,))
    x0 = jax.random.normal(ks[1], (n,))
    phi = jnp.stack([jnp.ones(n), x0], axis=1)
    theta_true = jnp.asarray([1.5, -0.5])
    ry = (phi @ theta_true) * rt + 0.01 * jax.random.normal(ks[2], (n,))
    g, b = rg_ops.residual_gram(jnp.zeros(n), jnp.zeros(n), -ry, -rt, phi,
                                backend="ref")
    theta = jnp.linalg.solve(g, b)
    np.testing.assert_allclose(theta, theta_true, atol=0.02)
