"""Baseline estimators the paper cites in §2.2 (DR learner,
S/T/X metalearners): all recover the ATE on the standard DGP, and the
doubly-robust property holds under a broken outcome model."""
import jax
import jax.numpy as jnp
import pytest

from repro.config import CausalConfig
from repro.core.drlearner import DRLearner
from repro.core.metalearners import s_learner, t_learner, x_learner
from repro.core.nuisance import make_ridge
from repro.data.causal_dgp import make_causal_data

N, P, EFFECT = 8000, 15, 1.5


@pytest.fixture(scope="module")
def data():
    return make_causal_data(jax.random.PRNGKey(33), N, P, effect=EFFECT)


def test_dr_learner_recovers_ate(data, key):
    cfg = CausalConfig(n_folds=4)
    res = DRLearner(cfg).fit(data.y, data.t, data.X, key=key)
    assert abs(res.ate - EFFECT) < 3 * res.stderr + 0.05
    lo, hi = res.conf_int()
    assert lo < EFFECT < hi or abs(res.ate - EFFECT) < 0.08


def test_dr_learner_double_robustness(data, key):
    """Garbage outcome model (lambda -> inf shrinks m to ~0) but a good
    propensity: AIPW stays consistent."""
    cfg = CausalConfig(n_folds=4)
    broken = make_ridge(lam=1e6)
    res = DRLearner(cfg, outcome=broken).fit(data.y, data.t, data.X,
                                             key=key)
    assert abs(res.ate - EFFECT) < 0.15


def test_dr_cate_heterogeneous(key):
    data = make_causal_data(jax.random.PRNGKey(5), N, P,
                            heterogeneous=True, effect=1.0)
    cfg = CausalConfig(n_folds=4, cate_features=2)
    res = DRLearner(cfg).fit(data.y, data.t, data.X, key=key)
    cate = res.cate(data.X, 2)
    rmse = float(jnp.sqrt(jnp.mean((cate - data.true_cate) ** 2)))
    assert rmse < 0.2


@pytest.mark.parametrize("learner", [s_learner, t_learner, x_learner])
def test_metalearners_recover_ate(data, key, learner):
    res = learner(data.y, data.t, data.X, key=key)
    assert abs(res.ate - EFFECT) < 0.12, learner.__name__
    assert res.cate.shape == (N,)


def test_estimator_agreement(data, key):
    """DML, DR and T-learner agree on the homogeneous-effect DGP."""
    from repro.core.dml import DML
    cfg = CausalConfig(n_folds=4)
    dml = DML(cfg).fit(data.y, data.t, data.X, key=key)
    dr = DRLearner(cfg).fit(data.y, data.t, data.X, key=key)
    tl = t_learner(data.y, data.t, data.X, key=key)
    assert abs(dml.ate - dr.ate) < 0.1
    assert abs(dml.ate - tl.ate) < 0.1
