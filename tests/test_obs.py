"""repro.obs: span tracer (Chrome-trace export schema, nesting,
rollups), metrics registry, predicted-vs-measured cost audit, the
EventLog ring buffer, and the two integration contracts — the traced
span tree covers runtime chunks / sweep columns / crossfit targets, and
``tracer=None`` changes nothing (bit-identity, no recompiles)."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import CausalConfig
from repro.core.crossfit import crossfit
from repro.core.nuisance import make_ridge
from repro.data.causal_dgp import make_causal_data
from repro.inference.executor import jit_miss_hook
from repro.obs import (ChunkAudit, CostAudit, Histogram, MetricsRegistry,
                       Tracer, maybe_span)
from repro.runtime import EventLog, RuntimeEvent, TaskRuntime, memory_model
from repro.sweep import SweepSpec, sweep

_XS = jnp.arange(14, dtype=jnp.float32).reshape(7, 2)
_C = jnp.float32(1.0)


def _double(x, c):
    return {"y": x * 2.0 + c, "s": x.sum()}


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------

def test_counter_gauge_histogram():
    reg = MetricsRegistry()
    reg.counter("a").inc()
    reg.counter("a").inc(4)
    reg.gauge("g").set(2.5)
    for v in [1.0, 2.0, 3.0, 4.0]:
        reg.histogram("h").observe(v)
    snap = reg.snapshot()
    assert snap["counters"]["a"] == 5
    assert snap["gauges"]["g"] == 2.5
    h = snap["histograms"]["h"]
    assert h["count"] == 4 and h["sum"] == 10.0
    assert h["min"] == 1.0 and h["max"] == 4.0
    assert h["mean"] == pytest.approx(2.5)


def test_histogram_percentiles_and_reservoir_cap():
    h = Histogram(cap=10)
    for v in range(100):
        h.observe(float(v))
    # exact stats survive past the reservoir cap
    assert h.count == 100 and h.hi == 99.0 and h.lo == 0.0
    assert len(h._values) == 10  # bounded
    # the reservoir is a sample of the stream, not a warm-up prefix
    assert all(0.0 <= v <= 99.0 for v in h._values)
    assert h.percentile(0.0) <= h.percentile(0.5) <= h.percentile(1.0)
    assert Histogram().summary() == {"count": 0, "sum": 0.0}


def test_histogram_reservoir_tracks_shifted_distribution():
    # the long-running-server regression: latencies shift AFTER the
    # reservoir fills; percentiles must follow the live distribution
    # instead of freezing on the first `cap` (warm-up) observations
    cap = 64
    h = Histogram(cap=cap)
    for _ in range(cap):
        h.observe(1.0)           # warm-up regime fills the reservoir
    assert h.percentile(0.5) == 1.0
    for _ in range(20 * cap):
        h.observe(10.0)          # steady-state regime, post-cap
    assert h.percentile(0.5) == 10.0   # p50 follows the shift
    assert h.percentile(0.99) == 10.0
    # exact aggregates never degrade to the sample
    assert h.count == 21 * cap
    assert h.total == cap * 1.0 + 20 * cap * 10.0
    assert h.lo == 1.0 and h.hi == 10.0
    assert len(h._values) == cap


def test_histogram_reservoir_deterministic_seed():
    def fill(seed):
        h = Histogram(cap=8, seed=seed)
        for v in range(1000):
            h.observe(float(v))
        return list(h._values)

    assert fill(0) == fill(0)        # seeded Algorithm R replays
    assert fill(0) != fill(1)


def test_reset_default_registry_decouples_tests():
    from repro.obs.metrics import default_registry, reset_default_registry

    default_registry().counter("coupling.probe").inc(3)
    assert default_registry().snapshot()["counters"]["coupling.probe"] == 3
    reset_default_registry()
    fresh = default_registry()
    assert "coupling.probe" not in fresh.snapshot()["counters"]
    assert default_registry() is fresh  # stable until the next reset


def test_registry_get_or_create_is_stable():
    reg = MetricsRegistry()
    assert reg.counter("x") is reg.counter("x")
    assert reg.gauge("x") is reg.gauge("x")
    assert reg.histogram("x") is reg.histogram("x")


# ---------------------------------------------------------------------------
# Tracer: spans, nesting, export
# ---------------------------------------------------------------------------

def test_span_nesting_and_rollup():
    tr = Tracer()
    with tr.span("outer", cat="test", tag="a") as so:
        with tr.span("inner"):
            tr.instant("mark", detail="x")
        with tr.span("inner"):
            pass
    assert so.depth == 0 and not so.open
    inners = [s for s in tr.spans if s.name == "inner"]
    assert all(s.parent_id == so.span_id and s.depth == 1 for s in inners)
    mark = next(s for s in tr.spans if s.name == "mark")
    assert mark.instant and mark.depth == 2 and mark.duration_s == 0.0
    roll = tr.rollup()
    assert roll["inner"]["count"] == 2
    assert "mark" not in roll  # instants don't roll up
    assert roll["outer"]["total_s"] >= roll["inner"]["total_s"]
    text = tr.render()
    assert "outer" in text and "  inner" in text and "! mark" in text


def test_chrome_trace_schema(tmp_path):
    tr = Tracer()
    with tr.span("work", cat="runtime", label="L", size=jnp.int32(3)):
        tr.instant("event")
    path = tr.write_chrome_trace(str(tmp_path / "trace.json"))
    doc = json.loads(open(path).read())  # round-trips as strict JSON
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert len(evs) == 2
    for e in evs:
        assert {"name", "cat", "ts", "pid", "tid", "ph", "args"} <= set(e)
        assert e["ph"] in ("X", "i")
        assert e["ts"] >= 0.0
        # args must be JSON scalars (jax values are stringified)
        for v in e["args"].values():
            assert isinstance(v, (str, int, float, bool, type(None)))
    x = next(e for e in evs if e["ph"] == "X")
    assert x["dur"] >= 0.0 and x["name"] == "work"
    i = next(e for e in evs if e["ph"] == "i")
    assert i["s"] == "t" and "dur" not in i


def test_maybe_span_none_is_noop():
    with maybe_span(None, "anything") as s:
        assert s is None
    tr = Tracer()
    with maybe_span(tr, "real", cat="c", k=1) as s:
        assert s is not None and s.name == "real"
    assert tr.span_names() == ["real"]


# ---------------------------------------------------------------------------
# Cost audit
# ---------------------------------------------------------------------------

def test_audit_ratios_finite_even_on_zero_inputs():
    row = ChunkAudit(label="z", chunk_index=0, chunk_size=1,
                     predicted_peak_bytes=0.0, probed_peak_bytes=0.0,
                     flops=0.0, hbm_bytes=0.0, measured_s=0.0)
    assert np.isfinite(row.peak_ratio)
    assert np.isfinite(row.time_ratio())


def test_audit_summary_and_table():
    audit = CostAudit()
    assert audit.summary() == {"n_chunks": 0}
    audit.record(ChunkAudit(label="boot", chunk_index=0, chunk_size=4,
                            predicted_peak_bytes=1000.0,
                            probed_peak_bytes=800.0, flops=1e9,
                            hbm_bytes=1e6, measured_s=0.01))
    s = audit.summary()
    assert s["n_chunks"] == 1 and s["labels"] == ["boot"]
    assert s["peak_ratio_min"] == pytest.approx(1.25)
    assert np.isfinite(s["time_ratio_min"])
    assert "boot" in audit.table()
    d = audit.as_dicts()[0]
    assert np.isfinite(d["peak_ratio"]) and np.isfinite(d["time_ratio"])


# ---------------------------------------------------------------------------
# EventLog ring buffer (satellite: bounded events growth)
# ---------------------------------------------------------------------------

def _ev(i):
    return RuntimeEvent("chunk", f"e{i}", i)


def test_eventlog_ring_bounds_growth():
    log = EventLog(maxlen=4)
    for i in range(10):
        log.append(_ev(i))
    assert len(log) == 4 and log.total == 10 and log.dropped == 6
    assert [e.label for e in log] == ["e6", "e7", "e8", "e9"]
    assert log[0].label == "e6" and log[-1].label == "e9"
    assert [e.label for e in log[1:3]] == ["e7", "e8"]


def test_eventlog_since_is_drop_safe():
    log = EventLog(maxlen=4)
    for i in range(3):
        log.append(_ev(i))
    start = log.total  # checkpoint at 3
    for i in range(3, 10):
        log.append(_ev(i))  # events 0..5 dropped by now
    # the suffix since the checkpoint that is STILL buffered
    assert [e.label for e in log.since(start)] == ["e6", "e7", "e8", "e9"]
    assert log.since(log.total) == ()
    log.clear()
    assert len(log) == 0 and log.total == 0


def test_runtime_events_are_bounded():
    rt = TaskRuntime("vmap", chunk=1, events_maxlen=3)
    rt.map(_double, _XS, _C)  # 7 chunks -> 1 "chunk" event per map + ...
    for _ in range(5):
        rt.map(_double, _XS, _C)
    assert len(rt.events) <= 3
    assert rt.events.total == 6  # one "chunk" decision per chunked map


# ---------------------------------------------------------------------------
# Traced runtime: span tree, audit join, metrics
# ---------------------------------------------------------------------------

def _outer(v, base):
    return jnp.tanh(v[:, None] * v[None, :] + base).sum()


@pytest.fixture(scope="module")
def traced_budget_run():
    m = 64
    xs = jnp.ones((16, m), jnp.float32)
    base = jnp.zeros((m, m), jnp.float32)
    model = memory_model(_outer, xs, (base,), 16)
    assert model is not None
    tr = Tracer()
    rt = TaskRuntime("vmap", memory_budget=int(model.base + 4 * model.slope),
                     tracer=tr)
    out = rt.map(_outer, xs, base, label="probe")
    ref = TaskRuntime("vmap").map(_outer, xs, base)
    return tr, out, ref


def test_traced_map_is_bitwise_identical(traced_budget_run):
    _, out, ref = traced_budget_run
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_traced_map_span_tree(traced_budget_run):
    tr, _, _ = traced_budget_run
    names = tr.span_names()
    assert "runtime.map" in names
    chunks = [s for s in tr.spans if s.name == "runtime.chunk"]
    assert len(chunks) >= 2  # the budget forced chunking
    mp = next(s for s in tr.spans if s.name == "runtime.map")
    assert all(s.parent_id == mp.span_id for s in chunks)
    assert all(s.attrs["label"] == "probe" for s in chunks)
    sizes = sum(s.attrs["chunk_size"] for s in chunks)
    assert sizes == 16  # chunks cover the replicate axis exactly


def test_traced_map_audit_rows_finite(traced_budget_run):
    tr, _, _ = traced_budget_run
    assert len(tr.audit) >= 2  # every budget-sized chunk audited
    for d in tr.audit.as_dicts():
        assert np.isfinite(d["peak_ratio"]) and d["peak_ratio"] > 0
        assert np.isfinite(d["time_ratio"]) and d["time_ratio"] > 0
        assert d["probed_peak_bytes"] > 0
    # the affine model interpolates the HLO peak well where it was used
    s = tr.audit.summary()
    assert 0.5 <= s["peak_ratio_min"] and s["peak_ratio_max"] <= 2.0


def test_traced_map_metrics(traced_budget_run):
    tr, _, _ = traced_budget_run
    snap = tr.metrics.snapshot()
    n_chunks = len([s for s in tr.spans if s.name == "runtime.chunk"])
    assert snap["counters"]["runtime.chunks"] == n_chunks
    assert snap["counters"]["runtime.events.chunk"] == 1
    assert snap["histograms"]["runtime.chunk_seconds"]["count"] == n_chunks
    assert snap["gauges"]["runtime.chunk_size[probe]"] >= 1
    assert snap["gauges"]["runtime.predicted_peak_bytes[probe]"] > 0


def test_traced_chrome_trace_serializes(traced_budget_run):
    tr, _, _ = traced_budget_run
    doc = json.loads(json.dumps(tr.chrome_trace()))
    assert all(e["ph"] in ("X", "i") for e in doc["traceEvents"])


def test_untraced_runtime_reuses_compiled_programs():
    """tracer=None must add no jit recompiles: a fresh untraced runtime
    mapping a closure the executor already compiled (by a TRACED run at
    the same shapes) hits the cache — zero misses."""
    def fn(x, c):
        return x * 3.0 + c

    TaskRuntime("vmap", chunk=3, tracer=Tracer()).map(fn, _XS, _C)
    misses = []
    with jit_miss_hook(misses.append):
        out = TaskRuntime("vmap", chunk=3).map(fn, _XS, _C)
    assert misses == []
    ref = TaskRuntime("vmap", chunk=3).map(fn, _XS, _C)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_dag_gather_spans():
    tr = Tracer()
    rt = TaskRuntime("vmap", tracer=tr)
    a = rt.submit(_double, _XS, _C, label="stage_a")
    b = rt.submit(_double, rt.call(lambda o: o["y"][:3], a), _C, label="stage_b")
    rt.gather(b)
    dag = [s for s in tr.spans if s.name == "dag.task"]
    assert {s.attrs["label"] for s in dag} == {"stage_a", "stage_b"}
    # each dag.task span wraps its runtime.map span
    for s in tr.spans:
        if s.name == "runtime.map":
            assert tr.spans[s.parent_id].name == "dag.task"


# ---------------------------------------------------------------------------
# Integration: sweep columns + crossfit targets in ONE span tree
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sweep_and_crossfit_span_coverage():
    key = jax.random.PRNGKey(0)
    d = make_causal_data(key, 400, 4, effect=1.0)
    tr = Tracer()

    crossfit(make_ridge(), make_ridge(), jax.random.PRNGKey(1),
             d.X, d.y, d.t, 3, engine=TaskRuntime("vmap", tracer=tr))

    sids = jax.random.randint(key, (400,), 0, 2)
    cfg = CausalConfig(n_folds=2, inference="none")
    spec = SweepSpec(n_segments=2, columns=(("dml", cfg),))
    sweep(spec, X=d.X, y=d.y, t=d.t, segment_ids=sids,
          key=jax.random.PRNGKey(2), executor="vmap", tracer=tr)

    names = tr.span_names()
    assert any(n.startswith("crossfit:") for n in names)
    assert any(n.startswith("sweep.column[") for n in names)
    assert "runtime.map" in names
    cf = next(s for s in tr.spans if s.name.startswith("crossfit:"))
    kids = [s for s in tr.spans if s.parent_id == cf.span_id]
    assert any(s.name == "runtime.map" for s in kids)  # nesting holds
    # the whole tree exports as valid Chrome-trace JSON
    doc = json.loads(json.dumps(tr.chrome_trace()))
    assert len(doc["traceEvents"]) == len(tr.spans)
