"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.crossfit import fold_ids, fold_weights
from repro.distributed.sharding import ShardingRules, logical_to_spec
from repro.kernels.ssm_scan import ref as gla_ref
from repro.models import attention as attn_mod
from repro.optim.compression import compress_decompress

SETTINGS = dict(max_examples=20, deadline=None)


@settings(**SETTINGS)
@given(n=st.integers(10, 500), k=st.integers(2, 8), seed=st.integers(0, 99))
def test_fold_partition_invariants(n, k, seed):
    folds = fold_ids(jax.random.PRNGKey(seed), n, k)
    W = fold_weights(folds, k)
    f = np.asarray(folds)
    assert f.min() >= 0 and f.max() < k
    # balanced within 1
    counts = np.bincount(f, minlength=k)
    assert counts.max() - counts.min() <= 1
    # every sample trains k-1 models and is held out of exactly 1
    np.testing.assert_array_equal(np.asarray(W.sum(0)), (k - 1.0))


@settings(**SETTINGS)
@given(b=st.integers(1, 2), h=st.integers(1, 3),
       nchunks=st.integers(1, 4), dk=st.sampled_from([4, 8, 16]),
       dv=st.sampled_from([4, 8]), mode=st.sampled_from(["post", "bonus"]),
       seed=st.integers(0, 999))
def test_gla_chunked_equals_naive(b, h, nchunks, dk, dv, mode, seed):
    t = 16 * nchunks
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, h, t, dk))
    k = jax.random.normal(ks[1], (b, h, t, dk))
    v = jax.random.normal(ks[2], (b, h, t, dv))
    w = 0.05 + 0.95 * jax.random.uniform(ks[3], (b, h, t, dk))
    u = None if mode == "post" else jax.random.normal(ks[4], (h, dk))
    o1, s1 = gla_ref.gla_chunked_ref(q, k, v, w, u, chunk=16)
    o2, s2 = gla_ref.gla_naive(q, k, v, w, u)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=5e-4, atol=5e-4)


@settings(**SETTINGS)
@given(sq=st.sampled_from([32, 64]), h=st.integers(1, 4),
       kv_ratio=st.sampled_from([1, 2, 4]), d=st.sampled_from([8, 16]),
       causal=st.booleans(), seed=st.integers(0, 999))
def test_chunked_attention_equals_dense(sq, h, kv_ratio, d, causal, seed):
    heads = h * kv_ratio
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, sq, heads, d))
    k = jax.random.normal(ks[1], (1, sq, h, d))
    v = jax.random.normal(ks[2], (1, sq, h, d))
    dense = attn_mod._sdpa(q, k, v, causal=causal)
    chunked = attn_mod._chunked_attn(q, k, v, causal=causal, chunk=16)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked),
                               rtol=5e-5, atol=5e-5)


@settings(**SETTINGS)
@given(scale=st.floats(1e-4, 1e4), n=st.sampled_from([16, 257]),
       method=st.sampled_from(["bf16", "int8"]), seed=st.integers(0, 99))
def test_compression_relative_error_bounded(scale, n, method, seed):
    g = scale * jax.random.normal(jax.random.PRNGKey(seed), (n,))
    rec = compress_decompress(g, method)
    num = float(jnp.linalg.norm(rec - g))
    den = float(jnp.linalg.norm(g)) + 1e-30
    assert num / den < 0.03


@settings(**SETTINGS)
@given(seed=st.integers(0, 99), frac=st.sampled_from([0.5, 1.0]))
def test_rope_preserves_norm_and_relativity(seed, frac):
    """RoPE is an orthogonal per-position rotation: norms preserved, and
    <rope(q,m), rope(k,n)> depends only on (m - n)."""
    from repro.config import ModelConfig
    from repro.models.layers import apply_rope, rope_frequencies
    cfg = ModelConfig(name="t", family="dense", num_layers=1, d_model=32,
                      num_heads=1, num_kv_heads=1, head_dim=16, d_ff=32,
                      vocab_size=64, rope_fraction=frac)
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (1, 4, 1, 16))
    positions = jnp.arange(4)[None, :]
    sin, cos = rope_frequencies(cfg, positions)
    q_r = apply_rope(q, sin, cos)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(q), axis=-1),
        np.linalg.norm(np.asarray(q_r), axis=-1), rtol=1e-5)
    # relativity: shift both positions by a constant -> same dot product
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 4, 1, 16))
    k_r = apply_rope(k, sin, cos)
    dots1 = np.einsum("bshd,bthd->bst", np.asarray(q_r), np.asarray(k_r))
    sin2, cos2 = rope_frequencies(cfg, positions + 5)
    q_r2 = apply_rope(q, sin2, cos2)
    k_r2 = apply_rope(k, sin2, cos2)
    dots2 = np.einsum("bshd,bthd->bst", np.asarray(q_r2), np.asarray(k_r2))
    np.testing.assert_allclose(dots1, dots2, rtol=1e-3, atol=1e-4)


@settings(**SETTINGS)
@given(seed=st.integers(0, 999))
def test_spec_never_reuses_mesh_axis(seed):
    """logical_to_spec must never emit a PartitionSpec using one mesh
    axis twice (GSPMD rejects it)."""
    rng = np.random.RandomState(seed)
    names = ["batch", "seq", "vocab", "heads", "ff", "embed"]
    mesh_axes = ["data", "model", None]
    rules = ShardingRules(rules=tuple(
        (n, mesh_axes[rng.randint(3)]) for n in names))
    axes = tuple(names[rng.randint(len(names))]
                 for _ in range(rng.randint(1, 5)))
    spec = logical_to_spec(axes, rules)
    flat = [a for p in spec for a in
            (p if isinstance(p, tuple) else (p,)) if a]
    assert len(flat) == len(set(flat)), (axes, spec)
