"""Per-architecture smoke tests (deliverable f): every assigned arch at a
reduced config runs one forward/train step on CPU with finite outputs and
correct shapes, plus the strongest serving-correctness check we have:
prefill + decode reproduces the train-path logits position by position.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.model import build_model

# full-zoo / serving loops: the long tier (PR CI runs -m 'not slow')
pytestmark = pytest.mark.slow

B, S = 2, 32


def _batch(cfg, key):
    tk = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tk, "labels": jnp.roll(tk, -1, axis=1)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = 0.1 * jax.random.normal(
            key, (B, 8, cfg.d_model), cfg.compute_dtype)
    if cfg.is_encdec:
        batch["frames"] = 0.1 * jax.random.normal(
            key, (B, cfg.max_source_positions, cfg.d_model),
            cfg.compute_dtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_shapes_and_finite(arch, key):
    cfg = get_config(arch + "-smoke")
    model = build_model(cfg)
    params = model.init(key)
    batch = _batch(cfg, key)
    logits, aux = model.forward_train(params, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert jnp.isfinite(logits).all(), arch
    loss, metrics = model.loss_fn(params, batch)
    assert jnp.isfinite(loss), arch
    # one gradient step must be finite too
    grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    for leaf in jax.tree_util.tree_leaves(grads):
        assert jnp.isfinite(leaf).all(), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_train_logits(arch, key):
    """Serving correctness: teacher-forced decode logits == train-path
    logits at every generated position."""
    cfg = get_config(arch + "-smoke")
    model = build_model(cfg)
    params = model.init(key)
    batch = _batch(cfg, key)
    full_logits, _ = model.forward_train(params, batch)

    split = S // 2
    pre = {k: (v[:, :split] if k in ("tokens", "labels") else v)
           for k, v in batch.items() if k != "labels"}
    logits, cache = model.prefill(params, pre)
    np.testing.assert_allclose(
        np.asarray(logits[:, -1], np.float32),
        np.asarray(full_logits[:, split - 1], np.float32),
        rtol=2e-3, atol=2e-3)

    # grow every seq-carrying cache leaf to S and continue teacher-forced
    # (recurrent ssm/rwkv states are same-shape and pass through)
    big = model.init_cache(B, S)
    def splice(dst, src):
        if dst.shape == src.shape:
            return src.astype(dst.dtype)
        ax = [i for i in range(dst.ndim) if dst.shape[i] != src.shape[i]][0]
        return jax.lax.dynamic_update_slice_in_dim(
            dst, src.astype(dst.dtype), 0, axis=ax)
    cache = jax.tree_util.tree_map(splice, big, cache)

    for pos in range(split, S):
        tok = batch["tokens"][:, pos][:, None]
        logits, cache = model.decode_step(params, tok, cache,
                                          jnp.int32(pos))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32),
            np.asarray(full_logits[:, pos], np.float32),
            rtol=5e-3, atol=5e-3)


def test_padded_vocab_ce_is_exact(key):
    """Pad logits are masked to -inf: CE over padded vocab == CE over the
    unpadded slice."""
    cfg = get_config("granite-3-2b-smoke")
    cfg = dataclasses.replace(cfg, vocab_size=250)  # padded_vocab = 256
    model = build_model(cfg)
    params = model.init(key)
    batch = _batch(cfg, key)
    logits, _ = model.forward_train(params, batch)
    assert logits.shape[-1] == 256
    assert float(logits[..., 250:].max()) < -1e29
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    assert float(probs[..., 250:].sum()) < 1e-6


def test_mtp_head_runs(key):
    """DeepSeek MTP flag: extra head trains and adds a finite aux loss."""
    cfg = dataclasses.replace(get_config("deepseek-v3-671b-smoke"),
                              mtp_depth=1)
    model = build_model(cfg)
    params = model.init(key)
    batch = _batch(cfg, key)
    loss, metrics = model.loss_fn(params, batch)
    assert jnp.isfinite(loss)
    assert float(metrics["aux"]) != 0.0  # MTP CE contributes


def test_features_pool_shape(key):
    cfg = get_config("rwkv6-3b-smoke")
    model = build_model(cfg)
    params = model.init(key)
    feats = model.features(params, {"tokens": _batch(cfg, key)["tokens"]})
    assert feats.shape == (B, cfg.d_model)
    assert jnp.isfinite(feats).all()
