"""repro.runtime: futures/DAG semantics, memory-aware chunked
scheduling, fault-tolerant backend downgrade (bitwise-deterministic),
and nested parallelism — plus the executor/runtime edge cases: zero-
length replicate axis, chunk sizes that don't divide B, and retry-
downgrade runs that must be bit-identical to the no-failure run."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import CausalConfig
from repro.core.dml import DML
from repro.data.causal_dgp import make_causal_data
from repro.inference.bootstrap import (dml_bootstrap,
                                       make_dml_replicate_fn,
                                       replicate_keys)
from repro.inference.executor import VmapExecutor
from repro.runtime import (DOWNGRADE, MemoryModel, TaskRuntime, as_runtime,
                           memory_model)


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


def _double(x, c):
    return {"y": x * 2.0 + c, "s": x.sum()}


_XS = jnp.arange(14, dtype=jnp.float32).reshape(7, 2)
_C = jnp.float32(1.0)


# ---------------------------------------------------------------------------
# Futures / task graph
# ---------------------------------------------------------------------------

def test_submit_gather_chain():
    rt = TaskRuntime("vmap")
    a = rt.submit(_double, _XS, _C, label="a")
    b = rt.call(lambda o: o["y"][:3], a, label="slice")
    c = rt.submit(_double, b, jnp.float32(0.0), label="c")
    out = rt.gather(c)
    np.testing.assert_array_equal(
        np.asarray(out["y"]), np.asarray((_XS[:3] * 2 + 1) * 2))


def test_gather_many_preserves_structure():
    rt = TaskRuntime("vmap")
    a = rt.submit(_double, _XS, _C)
    b = rt.call(lambda o: float(o["s"].sum()), a)
    ra, rb = rt.gather([a, b])
    assert ra["y"].shape == (7, 2)
    assert rb == pytest.approx(float(_XS.sum()))  # Σ per-replicate sums


def test_result_before_gather_raises():
    rt = TaskRuntime("vmap")
    a = rt.submit(_double, _XS, _C)
    with pytest.raises(RuntimeError, match="gather"):
        a.result()


def test_cycle_detection():
    rt = TaskRuntime("vmap")
    a = rt.call(lambda v: v, 1)
    b = rt.call(lambda v: v, a)
    a.deps = (b,)  # forge a cycle
    with pytest.raises(ValueError, match="cycle"):
        rt.gather(b)


def test_gather_is_idempotent():
    rt = TaskRuntime("vmap")
    calls = []
    a = rt.call(lambda: calls.append(1) or 42)
    assert rt.gather(a) == 42
    assert rt.gather(a) == 42
    assert len(calls) == 1  # executed once, replayed from the handle


# ---------------------------------------------------------------------------
# Chunked scheduling
# ---------------------------------------------------------------------------

def test_chunk_not_dividing_axis_is_bitwise():
    full = TaskRuntime("vmap").map(_double, _XS, _C)
    for chunk in (1, 2, 3, 5, 7, 100):
        out = TaskRuntime("vmap", chunk=chunk).map(_double, _XS, _C)
        np.testing.assert_array_equal(np.asarray(full["y"]),
                                      np.asarray(out["y"]))
        np.testing.assert_array_equal(np.asarray(full["s"]),
                                      np.asarray(out["s"]))


def test_zero_length_replicate_axis():
    out = TaskRuntime("vmap").map(_double, _XS[:0], _C)
    assert out["y"].shape == (0, 2)
    assert out["s"].shape == (0,)
    assert out["y"].dtype == jnp.float32


def test_zero_length_axis_serial_backend():
    out = TaskRuntime("serial").map(_double, _XS[:0], _C)
    assert out["y"].shape == (0, 2)


def test_scalar_passthrough_args_survive_budget_and_empty_axis():
    """Executors accept python-scalar pass-through args (jit bakes them
    in); the memory probe and the zero-replicate path must too."""
    full = TaskRuntime("vmap").map(_double, _XS, 0.5)
    budgeted = TaskRuntime("vmap", memory_budget=1 << 20)
    out = budgeted.map(_double, _XS, 0.5)
    np.testing.assert_array_equal(np.asarray(full["y"]), np.asarray(out["y"]))
    empty = TaskRuntime("vmap").map(_double, _XS[:0], 0.5)
    assert empty["y"].shape == (0, 2)


def test_memory_model_and_budget_chunking():
    # closure with a per-replicate (m, m) temp: slope ~ m*m*4 bytes
    m = 64

    def outer(v, base):
        # tanh blocks XLA's algebraic simplifier from collapsing the
        # (m, m) outer-product temp the test is sizing
        return jnp.tanh(v[:, None] * v[None, :] + base).sum()

    xs = jnp.ones((16, m), jnp.float32)
    base = jnp.zeros((m, m), jnp.float32)
    model = memory_model(outer, xs, (base,), 16)
    assert model is not None
    per_rep = m * m * 4
    assert model.slope >= per_rep  # at least the outer-product temp
    # budget for ~4 replicates must chunk below 16 and still be exact
    budget = int(model.base + 4 * model.slope)
    rt = TaskRuntime("vmap", memory_budget=budget)
    chunk, _ = rt.plan_chunk(outer, xs, (base,), 16)
    assert 1 <= chunk <= 4
    out = rt.map(outer, xs, base)
    ref = TaskRuntime("vmap").map(outer, xs, base)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert any(e.action == "chunk" for e in rt.events)


def test_max_chunk_floors_at_one():
    model = MemoryModel(base=0.0, slope=1000.0)
    assert model.max_chunk(1, 8) == 1  # one replicate must always run


def test_explicit_chunk_overrides_budget():
    rt = TaskRuntime("vmap", memory_budget=1, chunk=5)
    chunk, model = rt.plan_chunk(_double, _XS, (_C,), 7)
    assert chunk == 5 and model is None


# ---------------------------------------------------------------------------
# Fault tolerance: retry with backend downgrade
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FailingExecutor(VmapExecutor):
    """Backend that dies on its first ``fail_first`` map calls — the
    stand-in for a lost Ray worker."""

    name: str = "failing"
    fail_first: int = 10 ** 9
    calls: int = 0

    def map(self, fn, xs, *args):
        self.calls += 1
        if self.calls <= self.fail_first:
            raise RuntimeError("synthetic worker loss")
        return super().map(fn, xs, *args)


def test_downgrade_result_bitwise_equals_healthy_run():
    healthy = TaskRuntime("vmap", chunk=3).map(_double, _XS, _C)
    rt = TaskRuntime(FailingExecutor(), chunk=3)
    out = rt.map(_double, _XS, _C)
    np.testing.assert_array_equal(np.asarray(healthy["y"]),
                                  np.asarray(out["y"]))
    downs = [e for e in rt.events if e.action == "downgrade"]
    assert len(downs) == 3  # every chunk fell back
    assert all(e.backend == "vmap" for e in downs)


def test_partial_failure_mid_run_is_bitwise():
    """Only the FIRST chunk loses its worker; later chunks run on the
    primary.  The concatenated result must still equal the no-failure
    run bitwise (deterministic replicate order)."""
    healthy = TaskRuntime("vmap", chunk=3).map(_double, _XS, _C)
    flaky = FailingExecutor(fail_first=1)
    rt = TaskRuntime(flaky, chunk=3)
    out = rt.map(_double, _XS, _C)
    np.testing.assert_array_equal(np.asarray(healthy["y"]),
                                  np.asarray(out["y"]))
    assert sum(e.action == "downgrade" for e in rt.events) == 1


def test_retry_events_carry_triggering_exception():
    """Every re-attempt is a distinct "retry" event recording the
    backend that failed and the exception that triggered the fallback
    (satellite of the observability PR: recoveries must be auditable)."""
    rt = TaskRuntime(FailingExecutor(), chunk=3)
    rt.map(_double, _XS, _C)
    retries = [e for e in rt.events if e.action == "retry"]
    downs = [e for e in rt.events if e.action == "downgrade"]
    assert len(retries) == 3  # one per failed chunk attempt
    assert len(retries) == len(downs)  # each retry produced a downgrade
    assert all(e.backend == "failing" for e in retries)
    assert all("synthetic worker loss" in e.detail for e in retries)
    assert [e.chunk_index for e in retries] == [0, 1, 2]


def test_exhausted_ladder_emits_no_retry_event():
    """With no retry budget there is no re-attempt, hence no "retry"
    event — the failure propagates instead."""
    rt = TaskRuntime(FailingExecutor(), max_retries=0)
    with pytest.raises(RuntimeError, match="synthetic"):
        rt.map(_double, _XS, _C)
    assert not [e for e in rt.events if e.action == "retry"]


def test_exhausted_ladder_reraises():
    rt = TaskRuntime(FailingExecutor(), max_retries=0)
    with pytest.raises(RuntimeError, match="synthetic"):
        rt.map(_double, _XS, _C)


def test_downgrade_table_is_a_ladder():
    assert DOWNGRADE["shard_map"] == "vmap"
    assert DOWNGRADE["vmap"] == "serial"
    assert DOWNGRADE["serial"] is None


# ---------------------------------------------------------------------------
# Nested parallelism
# ---------------------------------------------------------------------------

def test_map_product_matches_nested_loops():
    def cell(xo, xi, c):
        return xo * xi + c

    xo = jnp.arange(3, dtype=jnp.float32) + 1
    xi = jnp.arange(4, dtype=jnp.float32)
    out = TaskRuntime("vmap").map_product(cell, xo, xi, _C)
    ref = xo[:, None] * xi[None, :] + _C
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_map_product_chunked_bitwise():
    def cell(xo, xi, c):
        return {"v": xo["a"] * xi + c}

    xo = {"a": jnp.arange(5, dtype=jnp.float32)}
    xi = jnp.arange(6, dtype=jnp.float32)
    full = TaskRuntime("vmap").map_product(cell, xo, xi, _C)
    chunked = TaskRuntime("vmap", chunk=7).map_product(cell, xo, xi, _C)
    np.testing.assert_array_equal(np.asarray(full["v"]),
                                  np.asarray(chunked["v"]))
    assert chunked["v"].shape == (5, 6)


def test_map_product_empty_axis():
    def cell(xo, xi):
        return xo * xi

    out = TaskRuntime("vmap").map_product(
        cell, jnp.zeros((0,), jnp.float32), jnp.arange(4.0))
    assert out.shape == (0, 4)


def test_map_product_empty_inner_axis():
    """Zero-length INNER axis: the flattened product axis is empty, so
    the zero-replicate path must reshape back to (b_outer, 0, ...)."""
    def cell(xo, xi):
        return {"v": xo * xi, "s": xo + xi}

    out = TaskRuntime("vmap").map_product(
        cell, jnp.arange(3.0), jnp.zeros((0,), jnp.float32))
    assert out["v"].shape == (3, 0)
    assert out["s"].shape == (3, 0)
    assert out["v"].dtype == jnp.float32


def test_map_product_both_axes_empty():
    def cell(xo, xi):
        return xo * xi

    out = TaskRuntime("vmap").map_product(
        cell, jnp.zeros((0,), jnp.float32), jnp.zeros((0,), jnp.float32))
    assert out.shape == (0, 0)


# ---------------------------------------------------------------------------
# Integration: bootstrap replicates through the runtime
# ---------------------------------------------------------------------------

# the canonical shapes of test_inference.py, where the replicate-
# invariance contract (serial == vmap bitwise) is asserted to hold —
# chunked scheduling inherits exactly that contract, chunk by chunk
_N, _P, _K = 3000, 8, 4


@pytest.fixture(scope="module")
def ctx(key):
    d = make_causal_data(jax.random.PRNGKey(42), _N, _P, effect=1.5)
    est = DML(CausalConfig(n_folds=_K))
    return est.fit(d.y, d.t, d.X, key=key).fit_ctx


def _boot(ctx, **kw):
    return dml_bootstrap(
        ctx.nuis_y, ctx.nuis_t, n_folds=_K, XW=ctx.XW, y=ctx.y, t=ctx.t,
        phi=ctx.phi, key=jax.random.PRNGKey(11), n_replicates=7, **kw)


def test_bootstrap_chunked_bitwise(ctx):
    full = _boot(ctx, executor="vmap")
    chunked = _boot(ctx, executor="vmap", chunk=3)
    np.testing.assert_array_equal(np.asarray(full.replicates),
                                  np.asarray(chunked.replicates))


def test_bootstrap_downgrade_bitwise(ctx):
    full = _boot(ctx, executor="vmap", chunk=3)
    flaky = _boot(ctx, executor=FailingExecutor(fail_first=1), chunk=3)
    np.testing.assert_array_equal(np.asarray(full.replicates),
                                  np.asarray(flaky.replicates))


def test_bootstrap_memory_budget_chunks_and_is_exact(ctx):
    full = _boot(ctx, executor="vmap")
    # ~2-replicate budget from the probed model, forced through the
    # public path by passing the budget into dml_bootstrap
    fn = make_dml_replicate_fn(ctx.nuis_y, ctx.nuis_t, 3)
    keys = replicate_keys(jax.random.PRNGKey(11), 7)
    model = memory_model(fn, keys, (ctx.XW, ctx.y, ctx.t, ctx.phi), 7)
    assert model is not None and model.slope > 0
    budget = int(model.base + 2.5 * model.slope)
    small = _boot(ctx, executor="vmap", memory_budget=budget)
    np.testing.assert_array_equal(np.asarray(full.replicates),
                                  np.asarray(small.replicates))


def test_as_runtime_passthrough():
    rt = TaskRuntime("serial")
    assert as_runtime(rt) is rt
    assert as_runtime("vmap").name == "vmap"
    assert TaskRuntime("serial").name == "serial"
