"""Streaming sufficient-statistics engine (repro.core.moments): the
bit-identity contract between the chunked and whole blocked strategies
at the KERNEL level, legacy-form equivalence at row_block=0, and the
no-dense-moment-matrix memory claim of the chunked final stage.

Estimator-level row_block invariance and executor bit-identity moved to
the cross-estimator conformance suite (tests/test_conformance.py over
tests/conformance.py's registry)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import moments
from repro.core.final_stage import cate_basis, fit_final_stage
from repro.data.causal_dgp import make_causal_data


def _rows(key, n, p):
    ks = jax.random.split(key, 6)
    X = jax.random.normal(ks[0], (n, p))
    y = jax.random.normal(ks[1], (n,))
    w = jax.random.exponential(ks[2], (n,))
    folds = jax.random.randint(ks[3], (n,), 0, 4)
    Wk = jax.random.exponential(ks[4], (4, n))
    t = jax.random.bernoulli(ks[5], 0.5, (n,)).astype(jnp.float32)
    return X, y, w, folds, Wk, t


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# n deliberately NOT divisible by most block sizes: the zero-row padding
# must be an exact no-op in every accumulator.
@pytest.mark.parametrize("n,row_block", [
    (777, 128), (777, 100), (512, 256), (640, 640),
])
def test_weighted_gram_chunked_equals_whole(key, n, row_block):
    X, y, w, *_ = _rows(key, n, 7)
    out_c = moments.weighted_gram(X, w, intercept=True, append=y,
                                  row_block=row_block, strategy="chunked")
    out_w = moments.weighted_gram(X, w, intercept=True, append=y,
                                  row_block=row_block, strategy="whole")
    _assert_trees_equal(out_c, out_w)


@pytest.mark.parametrize("row_block", [128, 100])
def test_weighted_gram_chunked_equals_whole_jitted(key, row_block):
    """Bit-identity must survive XLA fusion, not just eager dispatch."""
    X, y, w, *_ = _rows(key, 777, 7)

    def run(strategy):
        return jax.jit(lambda X_, y_, w_: moments.weighted_gram(
            X_, w_, intercept=True, append=y_, row_block=row_block,
            strategy=strategy))(X, y, w)

    _assert_trees_equal(run("chunked"), run("whole"))


def test_fold_gram_chunked_equals_whole(key):
    X, y, _, folds, *_ = _rows(key, 1000, 9)
    out_c = moments.fold_gram(X, folds, 4, intercept=True, append=y,
                              row_block=192, strategy="chunked")
    out_w = moments.fold_gram(X, folds, 4, intercept=True, append=y,
                              row_block=192, strategy="whole")
    _assert_trees_equal(out_c, out_w)
    # padded fold ids one-hot to the zero row: counts stay exact
    np.testing.assert_array_equal(
        np.asarray(out_c[1]), np.bincount(np.asarray(folds), minlength=4))


def test_fold_weighted_gram_chunked_equals_whole(key):
    X, y, _, _, Wk, _ = _rows(key, 900, 6)
    out_c = moments.fold_weighted_gram(X, Wk, intercept=True, append=y,
                                       row_block=256, strategy="chunked")
    out_w = moments.fold_weighted_gram(X, Wk, intercept=True, append=y,
                                       row_block=256, strategy="whole")
    _assert_trees_equal(out_c, out_w)


def test_residual_moments_and_meat_chunked_equals_whole(key):
    n = 1100
    X, y, w, _, _, t = _rows(key, n, 5)
    my = 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (n,))
    mt = jnp.clip(jax.random.uniform(jax.random.fold_in(key, 2), (n,)),
                  0.1, 0.9)
    phi = cate_basis(X, 3)
    a = moments.residual_moments(y, t, my, mt, phi, row_block=256,
                                 strategy="chunked")
    b = moments.residual_moments(y, t, my, mt, phi, row_block=256,
                                 strategy="whole")
    _assert_trees_equal(a, b)
    theta = jnp.asarray([1.0, -0.5, 0.2])
    m_c = moments.residual_meat(y, t, my, mt, phi, theta, w=w,
                                row_block=256, strategy="chunked")
    m_w = moments.residual_meat(y, t, my, mt, phi, theta, w=w,
                                row_block=256, strategy="whole")
    _assert_trees_equal(m_c, m_w)
    rw_c = moments.residual_weighted_gram(y - my, t - mt, phi, w,
                                          row_block=256,
                                          strategy="chunked")
    rw_w = moments.residual_weighted_gram(y - my, t - mt, phi, w,
                                          row_block=256, strategy="whole")
    _assert_trees_equal(rw_c, rw_w)


def test_row_block_zero_is_legacy_forms(key):
    """row_block=0 must be byte-for-byte the legacy whole-array einsums
    (this anchors serial == vmap bit-identity in repro.inference)."""
    X, y, w, _, Wk, _ = _rows(key, 500, 6)
    f32 = jnp.float32
    Xa = jnp.concatenate([X.astype(f32), jnp.ones((500, 1), f32)], axis=1)
    Z = jnp.concatenate([Xa, y.astype(f32)[:, None]], axis=1)
    G, n_eff = moments.weighted_gram(X, w, intercept=True, append=y)
    np.testing.assert_array_equal(
        np.asarray(G), np.asarray(jnp.einsum("ni,n,nj->ij", Z,
                                             w.astype(f32), Z)))
    np.testing.assert_array_equal(np.asarray(n_eff),
                                  np.asarray(w.astype(f32).sum()))
    Gk, n_k = moments.fold_weighted_gram(X, Wk, intercept=True, append=y)
    np.testing.assert_array_equal(
        np.asarray(Gk), np.asarray(jnp.einsum("ni,kn,nj->kij", Z,
                                              Wk.astype(f32), Z)))
    np.testing.assert_array_equal(np.asarray(n_k),
                                  np.asarray(Wk.astype(f32).sum(axis=1)))


def test_final_stage_chunked_equals_whole_bitwise(key):
    n = 2048
    d = make_causal_data(jax.random.PRNGKey(7), n, 6, effect=1.0)
    my = 0.2 * d.y
    mt = jnp.full((n,), 0.5, jnp.float32)
    phi = cate_basis(d.X, 2)
    fc = fit_final_stage(d.y, d.t, my, mt, phi, row_block=256,
                         strategy="chunked")
    fw = fit_final_stage(d.y, d.t, my, mt, phi, row_block=256,
                         strategy="whole")
    np.testing.assert_array_equal(np.asarray(fc.theta), np.asarray(fw.theta))
    np.testing.assert_array_equal(np.asarray(fc.cov), np.asarray(fw.cov))


def test_jackknife_segmented_matches_direct_weighted_fit(key):
    """The LOO-identity jackknife (G_total - G_fold) must agree with
    re-solving each delete-fold weighted moment directly."""
    from repro.core.crossfit import fold_ids
    from repro.inference import delete_fold_jackknife
    from repro.inference.numerics import weighted_theta
    n, k = 2000, 4
    d = make_causal_data(jax.random.PRNGKey(13), n, 6, effect=1.0)
    my = 0.1 * d.y
    mt = jnp.full((n,), 0.5, jnp.float32)
    folds = fold_ids(key, n, k)
    phi = cate_basis(d.X, 2)
    jk = delete_fold_jackknife(d.y, d.t, my, mt, folds, phi, k)
    ry = d.y - my
    rt = d.t - mt
    direct = jnp.stack([
        weighted_theta(ry, rt, phi,
                       (folds != j).astype(jnp.float32),
                       with_se=False)[0]
        for j in range(k)])
    np.testing.assert_allclose(np.asarray(jk.replicates),
                               np.asarray(direct), rtol=1e-4, atol=1e-5)
    # row-blocked segmented pass agrees too
    jk_rb = delete_fold_jackknife(d.y, d.t, my, mt, folds, phi, k,
                                  row_block=300)
    np.testing.assert_allclose(np.asarray(jk_rb.replicates),
                               np.asarray(jk.replicates),
                               rtol=1e-4, atol=1e-5)


def test_final_stage_chunked_has_no_dense_moment_matrix():
    """Acceptance: the chunked final stage never materializes the dense
    (n, p_phi) moment matrix — verified on the post-optimization HLO
    via launch.hlo_cost's peak-temp check."""
    from repro.launch.hlo_cost import peak_temp_bytes
    n, p_phi = 8192, 4
    f32 = jnp.float32
    args = [jax.ShapeDtypeStruct((n,), f32)] * 4 + [
        jax.ShapeDtypeStruct((n, p_phi), f32)]

    def lower(row_block):
        def f(y, t, my, mt, phi):
            fs = fit_final_stage(y, t, my, mt, phi, row_block=row_block)
            return fs.theta, fs.cov
        return jax.jit(f).lower(*args).compile().as_text()

    dense_z_bytes = n * p_phi * 4
    peak_chunked = peak_temp_bytes(lower(512))
    peak_whole = peak_temp_bytes(lower(0))
    assert peak_chunked < dense_z_bytes, (peak_chunked, dense_z_bytes)
    assert peak_whole >= dense_z_bytes, (peak_whole, dense_z_bytes)


def test_crossfit_engines_route_through_executor(key):
    """crossfit dispatch accepts Executor instances and names — fold
    fits share the Executor protocol with trials and replicates."""
    from repro.core.crossfit import crossfit
    from repro.core.nuisance import make_logistic, make_ridge
    from repro.inference import SerialExecutor
    d = make_causal_data(jax.random.PRNGKey(17), 1200, 5, effect=1.0)
    ny, nt = make_ridge(1e-3), make_logistic(1e-3, 8)
    cf_v = crossfit(ny, nt, key, d.X, d.y, d.t, 3, engine="parallel")
    cf_e = crossfit(ny, nt, key, d.X, d.y, d.t, 3,
                    engine=SerialExecutor())
    np.testing.assert_allclose(np.asarray(cf_v.oof_y),
                               np.asarray(cf_e.oof_y), rtol=1e-5,
                               atol=1e-5)


def test_halving_trial_closure_is_stable():
    """The _JitCache fix: the same (task, hidden, steps) rung must hand
    the executor the SAME closure object (a fresh lambda per rung used
    to re-trace every rung)."""
    from repro.core.tuning import _halving_trial_fn
    a = _halving_trial_fn("reg", (16,), 30)
    b = _halving_trial_fn("reg", (16,), 30)
    assert a is b
    assert _halving_trial_fn("reg", (16,), 60) is not a
