"""Optimizer substrate: AdamW math, clipping, schedules, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig
from repro.optim.adamw import adamw_init, adamw_update, clip_by_global_norm
from repro.optim.compression import (ErrorFeedback, compress_decompress,
    compressed_psum_mean)
from repro.optim.schedule import cosine_schedule, linear_schedule


def test_adamw_minimizes_quadratic(key):
    w = {"x": jax.random.normal(key, (16,))}
    cfg = TrainConfig(learning_rate=0.1, weight_decay=0.0, grad_clip=1e9)
    opt = adamw_init(w)
    loss = lambda p: 0.5 * jnp.sum(p["x"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(w)
        w, opt, _ = adamw_update(g, opt, w, jnp.float32(0.1), cfg)
    assert float(loss(w)) < 1e-4


def test_weight_decay_is_decoupled(key):
    """With zero gradients, params shrink by exactly lr*wd*p."""
    w = {"x": jnp.ones((4,))}
    cfg = TrainConfig(learning_rate=0.0, weight_decay=0.1, grad_clip=1e9)
    opt = adamw_init(w)
    g = {"x": jnp.zeros((4,))}
    w2, _, _ = adamw_update(g, opt, w, jnp.float32(0.5), cfg)
    np.testing.assert_allclose(np.asarray(w2["x"]),
                               1.0 - 0.5 * 0.1 * 1.0, rtol=1e-6)


def test_clip_by_global_norm(key):
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(10 * 9 + 10 * 16))
    from repro.optim.adamw import global_norm
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_schedules():
    total, warm, peak = 100, 10, 1.0
    for sched in (cosine_schedule, linear_schedule):
        v0 = float(sched(jnp.int32(0), peak=peak, warmup=warm, total=total))
        v_w = float(sched(jnp.int32(warm), peak=peak, warmup=warm,
                          total=total))
        v_end = float(sched(jnp.int32(total), peak=peak, warmup=warm,
                            total=total))
        assert v0 == pytest.approx(0.0, abs=1e-6)
        assert v_w == pytest.approx(peak, rel=1e-3)
        assert v_end < 0.2 * peak


@pytest.mark.parametrize("method", ["bf16", "int8"])
def test_compress_roundtrip_error_bounded(key, method):
    g = jax.random.normal(key, (1024,))
    rec = compress_decompress(g, method)
    rel = float(jnp.linalg.norm(rec - g) / jnp.linalg.norm(g))
    assert rel < (0.01 if method == "bf16" else 0.02)


def test_compressed_psum_with_error_feedback(key):
    """Inside vmap-as-axis, compressed mean-reduction + EF: the residual
    carries the quantization error so the bias vanishes over steps."""
    n_dev = 4
    gs = jax.random.normal(key, (n_dev, 256))

    def red(g, r):
        out, ef = compressed_psum_mean(
            {"g": g}, "dev", "int8", ErrorFeedback(residual={"g": r}))
        return out["g"], ef.residual["g"]

    out, res = jax.vmap(red, axis_name="dev", in_axes=(0, 0))(
        gs, jnp.zeros_like(gs))
    # all devices agree, approximately equal to the true mean
    np.testing.assert_allclose(out[0], out[1], rtol=1e-6)
    rel = float(jnp.linalg.norm(out[0] - gs.mean(0)) /
                jnp.linalg.norm(gs.mean(0)))
    assert rel < 0.05
    # error feedback residual holds the quantization error (nonzero)
    assert float(jnp.abs(res).max()) > 0
    # EF guarantee: the CUMULATIVE average of T compressed reductions
    # converges to the true mean (error stays O(1/T), not O(1))
    total = out[0]
    for _ in range(4):
        out, res = jax.vmap(red, axis_name="dev", in_axes=(0, 0))(gs, res)
        total = total + out[0]
    rel_cum = float(jnp.linalg.norm(total / 5 - gs.mean(0)) /
                    jnp.linalg.norm(gs.mean(0)))
    assert rel_cum < rel
