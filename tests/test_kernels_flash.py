"""Flash-attention kernel: Pallas (interpret=True) vs the pure-jnp
oracle, swept over shapes/dtypes/GQA ratios/causality (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import kernel as fa_kernel
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.models import attention as attn_mod


def _mk(key, B, Sq, Sk, H, KV, D, dtype):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, H, Sq, D), jnp.float32).astype(dtype)
    k = jax.random.normal(kk, (B, KV, Sk, D), jnp.float32).astype(dtype)
    v = jax.random.normal(kv, (B, KV, Sk, D), jnp.float32).astype(dtype)
    return q, k, v


SHAPES = [
    # B, Sq, Sk, H, KV, D, block_q, block_k
    (1, 128, 128, 2, 2, 32, 64, 64),
    (2, 128, 128, 4, 2, 64, 128, 128),
    (1, 256, 256, 4, 1, 64, 128, 64),   # MQA
    (2, 64, 64, 8, 8, 16, 32, 32),      # MHA small head
    (1, 128, 256, 2, 2, 32, 64, 128),   # rectangular (non-causal only)
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", SHAPES)
def test_flash_matches_ref(key, shape, dtype):
    B, Sq, Sk, H, KV, D, bq, bk = shape
    causal = Sq == Sk
    q, k, v = _mk(key, B, Sq, Sk, H, KV, D, dtype)
    ref = fa_ref.attention_ref(q, k, v, causal=causal)
    out = fa_kernel.flash_attention_pallas(q, k, v, causal=causal,
                                           block_q=bq, block_k=bk,
                                           interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_softcap(key):
    q, k, v = _mk(key, 1, 128, 128, 2, 2, 32, jnp.float32)
    ref = fa_ref.attention_ref(q, k, v, causal=True, softcap=30.0)
    out = fa_kernel.flash_attention_pallas(q, k, v, causal=True,
                                           softcap=30.0, block_q=64,
                                           block_k=64, interpret=True)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_ops_layout_roundtrip(key):
    """ops.flash_attention takes model layout (B,S,H,D)."""
    B, S, H, KV, D = 2, 128, 4, 2, 32
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, D))
    k = jax.random.normal(kk, (B, S, KV, D))
    v = jax.random.normal(kv, (B, S, KV, D))
    out_ref = fa_ops.flash_attention(q, k, v, causal=True, backend="ref")
    out_int = fa_ops.flash_attention(q, k, v, causal=True,
                                     backend="interpret", block_q=64,
                                     block_k=64)
    np.testing.assert_allclose(out_int, out_ref, rtol=2e-5, atol=2e-5)
    # and both agree with the model-side dense sdpa
    sdpa = attn_mod._sdpa(q, k, v, causal=True)
    np.testing.assert_allclose(out_ref, sdpa, rtol=2e-5, atol=2e-5)


def test_chunked_xla_matches_kernel_schedule(key):
    """The pure-XLA chunked path and the Pallas kernel implement the
    same online-softmax math."""
    B, S, H, KV, D = 1, 256, 2, 2, 32
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, D))
    k = jax.random.normal(kk, (B, S, KV, D))
    v = jax.random.normal(kv, (B, S, KV, D))
    a = attn_mod._chunked_attn(q, k, v, causal=True, chunk=64)
    b = fa_ops.flash_attention(q, k, v, causal=True, backend="interpret",
                               block_q=64, block_k=64)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)
