"""Distributed tuning (paper C2): the (trial x fold) population sweep
picks the statistically right penalty; successive halving converges."""
import jax
import jax.numpy as jnp
import pytest

from repro.config import CausalConfig
from repro.core.tuning import (successive_halving, tune_penalty,
                               tuned_nuisances)


def test_tune_penalty_prefers_strong_reg_when_noisy(key):
    """p >~ n with pure-noise targets: heavier ridge must win."""
    n, p = 120, 100
    ks = jax.random.split(key, 2)
    X = jax.random.normal(ks[0], (n, p))
    y = jax.random.normal(ks[1], (n,))
    lams = jnp.asarray([1e-5, 1e-3, 10.0], jnp.float32)
    res = tune_penalty("reg", lams, X, y, n_folds=4, key=key)
    assert res.best_value == 10.0
    assert res.scores.shape == (3,)


def test_tune_penalty_prefers_weak_reg_when_clean(key):
    n, p = 2000, 10
    ks = jax.random.split(key, 3)
    X = jax.random.normal(ks[0], (n, p))
    beta = jax.random.normal(ks[1], (p,))
    y = X @ beta + 0.01 * jax.random.normal(ks[2], (n,))
    lams = jnp.asarray([1e-5, 100.0], jnp.float32)
    res = tune_penalty("reg", lams, X, y, n_folds=4, key=key)
    assert res.best_value == pytest.approx(1e-5)


def test_tune_penalty_clf(key):
    n, p = 1500, 6
    ks = jax.random.split(key, 2)
    X = jax.random.normal(ks[0], (n, p))
    t = jax.random.bernoulli(ks[1], jax.nn.sigmoid(2 * X[:, 0]))
    lams = jnp.asarray([1e-4, 1e-2, 1.0], jnp.float32)
    res = tune_penalty("clf", lams, X, t.astype(jnp.float32), n_folds=3,
                       key=key)
    assert res.best_score < 0.69  # beats the chance log-loss ln 2
    assert res.best_value < 1.0


def test_successive_halving_converges(key):
    n, p = 600, 5
    ks = jax.random.split(key, 3)
    X = jax.random.normal(ks[0], (n, p))
    y = X @ jax.random.normal(ks[1], (p,))
    lrs = jnp.asarray([1e-6, 1e-3, 3e-3], jnp.float32)  # 1e-6 can't learn
    res = successive_halving("reg", lrs, X, y, n_folds=2, base_steps=30,
                             rungs=2, hidden=(16,), key=key)
    assert res.best_lr != pytest.approx(1e-6)
    assert len(res.history) >= 1
    assert len(res.history[0]["kept"]) <= 2  # halved


def test_tuned_nuisances_plug_into_dml(key):
    from repro.core.dml import DML
    from repro.data.causal_dgp import make_causal_data
    data = make_causal_data(jax.random.PRNGKey(1), 4000, 10, effect=1.0)
    cfg = CausalConfig(n_folds=3)
    ny, nt = tuned_nuisances(cfg, data.X, data.y, data.t, key)
    res = DML(cfg, nuisance_y=ny, nuisance_t=nt).fit(data.y, data.t,
                                                     data.X, key=key)
    assert abs(res.ate - 1.0) < 0.12
