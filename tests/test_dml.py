"""Estimator faithfulness (the paper's §5.1 demo + §2 theory): ATE/CATE
recovery on the dowhy-style DGP, parallel == sequential engines, W
controls, and tuned nuisances."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import CausalConfig
from repro.core.dml import DML
from repro.core.nuisance import make_mlp
from repro.data.causal_dgp import make_causal_data, paper_demo_data

N, P = 8000, 20


@pytest.fixture(scope="module")
def data():
    return make_causal_data(jax.random.PRNGKey(42), N, P, effect=1.5)


def test_ate_recovery_parallel(data, key):
    cfg = CausalConfig(n_folds=5, engine="parallel")
    res = DML(cfg).fit(data.y, data.t, data.X, key=key)
    assert abs(res.ate - data.true_ate) < 3 * float(res.stderr[0]) + 0.05
    assert res.diagnostics.ortho_moment < 1e-3


def test_parallel_equals_sequential(data, key):
    """C1 is an execution-strategy change, not a statistical one."""
    r1 = DML(CausalConfig(n_folds=5, engine="parallel")).fit(
        data.y, data.t, data.X, key=key)
    r2 = DML(CausalConfig(n_folds=5, engine="sequential")).fit(
        data.y, data.t, data.X, key=key)
    np.testing.assert_allclose(r1.theta, r2.theta, rtol=1e-4, atol=1e-5)


def test_cate_recovery_heterogeneous(key):
    data = make_causal_data(jax.random.PRNGKey(7), N, P,
                            heterogeneous=True, effect=1.0)
    cfg = CausalConfig(n_folds=5, cate_features=2, engine="parallel")
    res = DML(cfg).fit(data.y, data.t, data.X, key=key)
    rmse = float(jnp.sqrt(jnp.mean((res.cate(data.X) - data.true_cate) ** 2)))
    assert rmse < 0.15
    # theta ~ [1.0, 0.5] (effect = 1 + 0.5 x0)
    np.testing.assert_allclose(res.theta, [1.0, 0.5], atol=0.12)


def test_paper_demo_listing(key):
    """The exact §5.1 code-listing DGP: y=(1+.5 x0)T + x0 + eps."""
    data = paper_demo_data(jax.random.PRNGKey(0), n=20_000, p=50)
    cfg = CausalConfig(n_folds=5, cate_features=2, engine="parallel")
    res = DML(cfg).fit(data.y, data.t, data.X, key=key)
    assert abs(res.ate_of(data.X) - float(data.true_cate.mean())) < 0.08


def test_w_controls_are_used(key):
    """Confounding lives in W only: omitting W biases the estimate,
    including it recovers the truth."""
    data = make_causal_data(jax.random.PRNGKey(3), N, P, effect=1.0,
                            confounding_strength=2.0)
    W, X = data.X[:, :10], data.X[:, 10:]  # confounders are in cols < 10
    cfg = CausalConfig(n_folds=5, engine="parallel")
    biased = DML(cfg).fit(data.y, data.t, X, key=key)
    adjusted = DML(cfg).fit(data.y, data.t, X, W=W, key=key)
    assert abs(adjusted.ate - 1.0) < abs(biased.ate - 1.0)
    assert abs(adjusted.ate - 1.0) < 0.1


@pytest.mark.slow
def test_mlp_nuisances(key):
    """Nonlinear confounding needs a nonlinear nuisance."""
    n = 4000
    ks = jax.random.split(jax.random.PRNGKey(11), 4)
    X = jax.random.normal(ks[0], (n, 5))
    g = jnp.sin(2 * X[:, 0]) + X[:, 1] ** 2
    prop = jax.nn.sigmoid(g - 1.0)
    t = jax.random.bernoulli(ks[1], prop).astype(jnp.float32)
    y = 1.0 * t + g + 0.3 * jax.random.normal(ks[2], (n,))
    cfg = CausalConfig(n_folds=4, engine="parallel")
    nuis_y = make_mlp("reg", hidden=(64,), steps=300, lr=3e-3)
    nuis_t = make_mlp("clf", hidden=(64,), steps=300, lr=3e-3)
    res = DML(cfg, nuisance_y=nuis_y, nuisance_t=nuis_t).fit(y, t, X,
                                                             key=key)
    linear = DML(cfg).fit(y, t, X, key=key)
    assert abs(res.ate - 1.0) < abs(linear.ate - 1.0) + 0.02
    assert abs(res.ate - 1.0) < 0.15


def test_continuous_treatment(key):
    data = make_causal_data(jax.random.PRNGKey(5), N, P, effect=0.7,
                            discrete_treatment=False)
    cfg = CausalConfig(n_folds=5, discrete_treatment=False,
                       nuisance_t="ridge", engine="parallel")
    res = DML(cfg).fit(data.y, data.t, data.X, key=key)
    assert abs(res.ate - 0.7) < 0.05


def test_summary_renders(data, key):
    res = DML(CausalConfig(n_folds=3)).fit(data.y, data.t, data.X, key=key)
    s = res.summary()
    assert "DML result" in s and "overlap" in s


def test_loo_engine_matches_parallel(data, key):
    """Beyond-paper leave-one-out-Gram engine: identical estimates (ridge
    exact by identity; logistic MM converges to the same optimum)."""
    r1 = DML(CausalConfig(n_folds=5, engine="parallel")).fit(
        data.y, data.t, data.X, key=key)
    r2 = DML(CausalConfig(n_folds=5, engine="parallel_loo")).fit(
        data.y, data.t, data.X, key=key)
    assert abs(r1.ate - r2.ate) < 2e-3
    np.testing.assert_allclose(r1.theta, r2.theta, atol=2e-3)
