"""repro.inference: executor equivalence (serial == vmap bitwise at
the legacy canonical shape), jackknife-vs-IF stderr agreement, and the
estimator-facing interval API.  Cross-estimator bit-identity and
row_block conformance live in tests/test_conformance.py; nominal CI
coverage lives in tests/test_oracle_properties.py (slow tier)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import CausalConfig
from repro.core.dml import DML
from repro.core.drlearner import DRLearner
from repro.data.causal_dgp import make_causal_data
from repro.inference import (SerialExecutor, ShardMapExecutor,
                             VmapExecutor, delete_fold_jackknife,
                             dml_bootstrap, make_executor)

N, P, K = 3000, 8, 4


@pytest.fixture(scope="module")
def data():
    return make_causal_data(jax.random.PRNGKey(42), N, P, effect=1.5)


@pytest.fixture(scope="module")
def fitted(data):
    cfg = CausalConfig(n_folds=K, n_bootstrap=32)
    return DML(cfg).fit(data.y, data.t, data.X, key=jax.random.PRNGKey(0))


def _boot(ctx, executor, scheme="pairs", B=6):
    return dml_bootstrap(ctx.nuis_y, ctx.nuis_t, n_folds=K, XW=ctx.XW,
                         y=ctx.y, t=ctx.t, phi=ctx.phi,
                         key=jax.random.PRNGKey(5), n_replicates=B,
                         scheme=scheme, executor=executor)


@pytest.mark.parametrize("scheme", ["pairs", "multiplier"])
def test_serial_vmap_bit_identical_legacy_shape(fitted, scheme):
    """The PR-1 engine-equivalence anchor: per-replicate estimates from
    the loop baseline and the batched program are IDENTICAL at the
    legacy whole-array p_phi=1 canonical shape (bit-identity of the
    row_block=0 forms is shape-dependent; the shape-robust row-blocked
    contract is certified per estimator in tests/test_conformance.py)."""
    ctx = fitted.fit_ctx
    r_ser = _boot(ctx, "serial", scheme=scheme)
    r_vec = _boot(ctx, "vmap", scheme=scheme)
    np.testing.assert_array_equal(np.asarray(r_ser.replicates),
                                  np.asarray(r_vec.replicates))
    np.testing.assert_array_equal(np.asarray(r_ser.replicate_se),
                                  np.asarray(r_vec.replicate_se))


def test_shard_map_matches_vmap(fitted):
    """Replicate axis sharded over the (1-device here) data mesh axis:
    same program, same bits — including the non-divisible-B padding."""
    ctx = fitted.fit_ctx
    r_vec = _boot(ctx, "vmap", B=5)
    r_shm = _boot(ctx, "shard_map", B=5)
    np.testing.assert_array_equal(np.asarray(r_vec.replicates),
                                  np.asarray(r_shm.replicates))


def test_vmap_microbatch_bit_identical(fitted):
    """Chunked vmap (bounded-memory mode for industrial n) returns the
    same bits as the full-batch program."""
    ctx = fitted.fit_ctx
    r_full = _boot(ctx, VmapExecutor(), B=7)
    r_chunk = _boot(ctx, VmapExecutor(microbatch=3), B=7)
    np.testing.assert_array_equal(np.asarray(r_full.replicates),
                                  np.asarray(r_chunk.replicates))


def test_replicates_replay_from_base_key(fitted):
    """Lineage: replicate b depends only on fold_in(base, b), so a
    3-replicate run is a prefix of a 6-replicate run."""
    ctx = fitted.fit_ctx
    r6 = _boot(ctx, "vmap", B=6)
    r3 = _boot(ctx, "vmap", B=3)
    np.testing.assert_array_equal(np.asarray(r3.replicates),
                                  np.asarray(r6.replicates)[:3])


def test_jackknife_agrees_with_if_stderr():
    """Delete-fold jackknife se vs the influence-function (HC0 sandwich)
    se computed in estimands/final_stage: same asymptotic target."""
    d = make_causal_data(jax.random.PRNGKey(3), 8000, 10, effect=1.0)
    res = DML(CausalConfig(n_folds=5)).fit(d.y, d.t, d.X,
                                           key=jax.random.PRNGKey(0))
    jk = res.inference(method="jackknife")
    if_se = float(res.stderr[0])
    jk_se = float(jk.se[0])
    assert 0.4 * if_se < jk_se < 2.5 * if_se, (jk_se, if_se)


def test_jackknife_reuses_fold_states(fitted):
    """Direct call on the crossfit artifacts (no refit whatsoever)."""
    cf = fitted.crossfit
    ctx = fitted.fit_ctx
    jk = delete_fold_jackknife(ctx.y, ctx.t, cf.oof_y, cf.oof_t,
                               cf.folds, ctx.phi, K)
    assert jk.replicates.shape == (K, ctx.phi.shape[1])
    assert np.isfinite(np.asarray(jk.se)).all()


def test_ate_interval_api(data, fitted):
    lo, hi = fitted.ate_interval()
    assert lo < fitted.ate < hi
    assert np.isfinite([lo, hi]).all()
    # width shrinks with alpha
    lo2, hi2 = fitted.ate_interval(alpha=0.5)
    assert (hi2 - lo2) < (hi - lo)
    # normal + studentized kinds work
    for kind in ("normal", "studentized"):
        lo3, hi3 = fitted.ate_interval(kind=kind)
        assert lo3 < hi3


def test_cate_interval_api(data, fitted):
    lo, hi = fitted.cate_interval(data.X[:7])
    assert lo.shape == (7,) and hi.shape == (7,)
    assert bool((lo < hi).all())


def test_interval_default_config_is_b200():
    """Acceptance: plain DML.fit(...).ate_interval() draws B=200
    bootstrap replicates through the vmap executor by default."""
    cfg = CausalConfig()
    assert cfg.inference == "bootstrap"
    assert cfg.n_bootstrap == 200
    assert cfg.inference_executor == "vmap"


def test_inference_none_falls_back_to_sandwich(data):
    cfg = CausalConfig(n_folds=3, inference="none")
    res = DML(cfg).fit(data.y, data.t, data.X, key=jax.random.PRNGKey(0))
    lo, hi = res.ate_interval()
    clo, chi = res.conf_int()
    assert lo == pytest.approx(float(clo[0]))
    assert hi == pytest.approx(float(chi[0]))
    blo, bhi = res.cate_interval(data.X[:3])
    assert bool((blo < bhi).all())


def test_dr_learner_interval(data):
    cfg = CausalConfig(n_folds=3, n_bootstrap=24)
    res = DRLearner(cfg).fit(data.y, data.t, data.X,
                             key=jax.random.PRNGKey(0))
    lo, hi = res.ate_interval()
    assert lo < hi
    assert abs((lo + hi) / 2 - res.ate) < 0.2
    blo, bhi = res.cate_interval(data.X[:4])
    assert blo.shape == (4,)


def test_dr_interval_centers_on_ate_with_heterogeneous_basis():
    """The ATE CI must cover res.ate (= mean pseudo-outcome) even when
    the CATE basis is heterogeneous and covariates are NOT centered —
    theta[0] is then the effect at x=0, far from the ATE."""
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    n = 3000
    X = 5.0 + jax.random.normal(ks[0], (n, 3))   # non-centered
    prop = jax.nn.sigmoid(0.3 * (X[:, 0] - 5.0))
    t = jax.random.bernoulli(ks[1], prop).astype(jnp.float32)
    tau = 1.0 + 0.5 * X[:, 0]
    y = tau * t + X[:, 0] + 0.5 * jax.random.normal(ks[2], (n,))
    cfg = CausalConfig(n_folds=3, cate_features=2, n_bootstrap=32)
    res = DRLearner(cfg).fit(y, t, X, key=ks[3])
    lo, hi = res.ate_interval()
    assert abs(res.ate - float(tau.mean())) < 0.3
    assert lo <= res.ate <= hi, (lo, res.ate, hi)


def test_dr_inference_none_is_respected(data):
    """inference='none' must not silently launch a bootstrap."""
    cfg = CausalConfig(n_folds=3, inference="none")
    res = DRLearner(cfg).fit(data.y, data.t, data.X,
                             key=jax.random.PRNGKey(0))
    lo, hi = res.ate_interval()      # analytic normal CI, no refits
    assert lo < res.ate < hi
    with pytest.raises(ValueError):
        res.cate_interval(data.X[:2])
    with pytest.raises(ValueError):
        res.inference()


def test_inference_cache_ignores_alpha(fitted):
    """Replicates are alpha-independent: a new level must re-quantile
    the cached draws, not re-run B re-estimations."""
    r1 = fitted.inference(n_bootstrap=8)
    r2 = fitted.inference(n_bootstrap=8, alpha=0.2)
    assert r1 is r2


def test_mlp_nuisance_bootstrap_runs(data):
    """Non-linear nuisances take the generic vmapped-fit fallback."""
    from repro.core.nuisance import make_mlp
    from repro.inference import dml_bootstrap as boot
    ny = make_mlp("reg", hidden=(8,), steps=10, lr=1e-2)
    nt = make_mlp("clf", hidden=(8,), steps=10, lr=1e-2)
    phi = jnp.ones((N, 1), jnp.float32)
    r = boot(ny, nt, n_folds=3, XW=data.X, y=data.y, t=data.t, phi=phi,
             key=jax.random.PRNGKey(2), n_replicates=3, with_se=False)
    assert r.replicates.shape == (3, 1)
    assert np.isfinite(np.asarray(r.replicates)).all()


def test_make_executor_factory():
    assert isinstance(make_executor("serial"), SerialExecutor)
    assert isinstance(make_executor("vmap"), VmapExecutor)
    assert isinstance(make_executor("shard_map"), ShardMapExecutor)
    exe = VmapExecutor()
    assert make_executor(exe) is exe
    with pytest.raises(ValueError):
        make_executor("ray")


def test_executor_maps_pytrees():
    exe = make_executor("vmap")
    xs = {"a": jnp.arange(4.0), "b": jnp.ones((4, 2))}
    out = exe.map(lambda x: {"s": x["a"] + x["b"].sum()}, xs)
    np.testing.assert_allclose(np.asarray(out["s"]),
                               np.asarray(jnp.arange(4.0) + 2.0))


def test_executor_passthrough_args():
    """Extra map args ride along un-mapped (compiled-program inputs, not
    baked constants) on every backend."""
    data = jnp.arange(6.0)
    for name in ("serial", "vmap", "shard_map"):
        exe = make_executor(name)
        out = exe.map(lambda i, d: d[i] * 2.0,
                      jnp.arange(3, dtype=jnp.int32), data)
        np.testing.assert_allclose(np.asarray(out), [0.0, 2.0, 4.0],
                                   err_msg=name)


def test_refutation_executor_equivalence(data):
    """Refuters route their replicate loops through the same Executor:
    serial and vmap dispatch give identical replicate ATEs."""
    from repro.core import refutation
    est = DML(CausalConfig(n_folds=3))
    kw = dict(original_ate=1.5, n_reps=2, key=jax.random.PRNGKey(11))
    for refuter in (refutation.placebo_treatment,
                    refutation.random_common_cause,
                    refutation.data_subset):
        r_ser = refuter(est, data.y, data.t, data.X, executor="serial",
                        **kw)
        r_vec = refuter(est, data.y, data.t, data.X, executor="vmap",
                        **kw)
        assert r_ser.refuted_ates == r_vec.refuted_ates, refuter.__name__


def test_tuning_executor_equivalence(key):
    """tune_penalty through serial vs vmap executors: same scores."""
    from repro.core.tuning import tune_penalty
    n, p = 500, 6
    ks = jax.random.split(key, 2)
    X = jax.random.normal(ks[0], (n, p))
    y = X @ jax.random.normal(ks[1], (p,))
    lams = jnp.asarray([1e-4, 1e-2, 1.0], jnp.float32)
    r_vec = tune_penalty("reg", lams, X, y, n_folds=3, key=key,
                         executor="vmap")
    r_ser = tune_penalty("reg", lams, X, y, n_folds=3, key=key,
                         executor="serial")
    assert r_vec.best_index == r_ser.best_index
    # tune_penalty rides the legacy LAPACK-solve nuisances, so serial
    # vs batched agree to float32 noise, not bitwise
    np.testing.assert_allclose(np.asarray(r_vec.scores),
                               np.asarray(r_ser.scores),
                               rtol=1e-4, atol=1e-9)
