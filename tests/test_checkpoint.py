"""Checkpoint manager: atomic roundtrip, async, retention, elastic
re-shard restore, and exact training-resume lineage."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _state(key):
    return {"params": {"w": jax.random.normal(key, (8, 4)),
                       "b": jnp.zeros((4,))},
            "step": jnp.int32(7)}


def test_roundtrip(tmp_path, key):
    mgr = CheckpointManager(str(tmp_path))
    st = _state(key)
    mgr.save(10, st, metric=1.5)
    template = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), st)
    restored, meta = mgr.restore(template)
    assert meta["step"] == 10 and meta["metric"] == 1.5
    for a, b in zip(jax.tree_util.tree_leaves(st),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_wait(tmp_path, key):
    mgr = CheckpointManager(str(tmp_path))
    st = _state(key)
    mgr.save_async(3, st)
    mgr.wait()
    assert mgr.latest_step() == 3


def test_retention_keeps_latest_and_best(tmp_path, key):
    mgr = CheckpointManager(str(tmp_path), keep_latest=2, keep_best=1)
    st = _state(key)
    for step, metric in [(1, 0.5), (2, 5.0), (3, 4.0), (4, 3.0)]:
        mgr.save(step, st, metric=metric)
    steps = sorted(s for s, _ in mgr._steps())
    assert steps == [1, 3, 4]  # 3,4 newest; 1 is best-metric


def test_shape_mismatch_fails_loudly(tmp_path, key):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state(key))
    bad_template = {"params": {"w": jax.ShapeDtypeStruct((9, 4), jnp.float32),
                               "b": jax.ShapeDtypeStruct((4,), jnp.float32)},
                    "step": jax.ShapeDtypeStruct((), jnp.int32)}
    with pytest.raises(ValueError, match="shape mismatch"):
        mgr.restore(bad_template)


def test_elastic_restore_new_sharding(tmp_path, key):
    """Restore under a different mesh's shardings (1-device 'new mesh' —
    the mechanism is identical at 512 chips: device_put under the target
    NamedSharding)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path))
    st = _state(key)
    mgr.save(1, st)
    mesh = Mesh(np.array(jax.devices()).reshape(1, 1), ("data", "model"))
    sh = NamedSharding(mesh, P("data", None))
    template = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), st)
    shardings = {"params": {"w": sh, "b": NamedSharding(mesh, P(None))},
                 "step": NamedSharding(mesh, P())}
    restored, _ = mgr.restore(template, shardings=shardings)
    assert restored["params"]["w"].sharding == sh
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(st["params"]["w"]))


def test_training_resume_is_exact(tmp_path):
    """Checkpoint at step k, restart, continue: identical losses to an
    uninterrupted run (the deterministic-lineage guarantee)."""
    from repro.config import TrainConfig
    from repro.configs import get_config
    from repro.data.lm_data import lm_batch
    from repro.launch.train import make_train_step
    from repro.optim.adamw import adamw_init

    cfg = get_config("whisper-tiny-smoke")
    from repro.models.model import build_model
    import dataclasses
    cfg = dataclasses.replace(cfg, encoder_layers=1, num_layers=1)
    model = build_model(cfg)
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=2, total_steps=8)
    key = jax.random.PRNGKey(0)

    def batch_at(s):
        b = lm_batch(jax.random.fold_in(key, s), 2, 16, cfg.vocab_size)
        b["frames"] = 0.1 * jax.random.normal(
            jax.random.fold_in(key, 10_000 + s),
            (2, cfg.max_source_positions, cfg.d_model), cfg.compute_dtype)
        return b

    step_fn = jax.jit(make_train_step(model, tcfg))

    params = model.init(key)
    opt = adamw_init(params)
    losses = []
    mgr = CheckpointManager(str(tmp_path))
    for s in range(6):
        params, opt, m = step_fn(params, opt, batch_at(s))
        losses.append(float(m["loss"]))
        if s == 2:
            mgr.save(s + 1, {"params": params, "opt": opt})

    # restart from step 3
    template = {"params": model.abstract_params(),
                "opt": jax.eval_shape(lambda p: adamw_init(p),
                                      model.abstract_params())}
    restored, meta = mgr.restore(template)
    params2, opt2 = restored["params"], restored["opt"]
    for s in range(meta["step"], 6):
        params2, opt2, m2 = step_fn(params2, opt2, batch_at(s))
        np.testing.assert_allclose(float(m2["loss"]), losses[s], rtol=1e-5)
