"""Certification of the fused segment-Gram kernel family
(repro.kernels.seg_gram) behind ``row_block_strategy="pallas"``.

Two tiers of guarantees:

  tolerance  every moment form the moments engine routes to seg_gram
             agrees with the chunked reference (<= ~1e-4 on raw Grams;
             fp32 reassociation), for ALL lowerings: the one-hot
             oracle, the XLA scatter path, and the Pallas kernel in
             interpret mode (same block decomposition the mosaic
             compiler sees on TPU).
  exact      the structural contracts are bitwise: padded tail rows
             are no-ops, w=0 masks a row exactly like zeroing its
             data, empty segments produce exactly-zero Gram slabs and
             integer-zero counts, and power-of-two weights scale the
             Gram exactly.

Estimator-wide parity (every registry estimator, point estimates)
lives in tests/test_conformance.py::test_pallas_strategy_parity.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import moments
from repro.kernels.residual_gram import ops as rg_ops
from repro.kernels.seg_gram import ops as sg_ops
from repro.kernels.seg_gram import ref as sg_ref

BACKENDS = ("ref", "scatter", "interpret")
_N, _P, _K = 700, 3, 4          # non-divisible into the row block
_RB = 256


@pytest.fixture(scope="module")
def arrs():
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 8)
    return dict(
        y=jax.random.normal(ks[0], (_N,)),
        t=(jax.random.uniform(ks[1], (_N,)) < 0.5).astype(jnp.float32),
        my=0.1 * jax.random.normal(ks[2], (_N,)),
        mt=jnp.full((_N,), 0.5, jnp.float32),
        rz=jax.random.normal(ks[3], (_N,)),
        phi=jax.random.normal(ks[4], (_N, _P)),
        w=jax.random.exponential(ks[5], (_N,)),
        folds=jax.random.randint(ks[6], (_N,), 0, _K),
        theta=jnp.arange(1.0, _P + 1),
        X=jax.random.normal(ks[7], (_N, 5)),
    )


def _close(a, b, msg="", atol=2e-4, rtol=2e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=rtol, atol=atol, err_msg=msg)


# ---------------------------------------------------------------------------
# Tolerance tier: every strategy="pallas" route in the moments engine
# against its chunked reference, per lowering.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_moments_forms_parity(arrs, backend):
    a = arrs
    kw = dict(row_block=_RB)
    with sg_ops.force_backend(backend):
        pairs = [
            ("weighted_gram",
             moments.weighted_gram(a["X"], a["w"], intercept=True,
                                   strategy="chunked", **kw),
             moments.weighted_gram(a["X"], a["w"], intercept=True,
                                   strategy="pallas", **kw)),
            ("fold_gram",
             moments.fold_gram(a["X"], a["folds"], _K, intercept=True,
                               append=a["y"], strategy="chunked", **kw),
             moments.fold_gram(a["X"], a["folds"], _K, intercept=True,
                               append=a["y"], strategy="pallas", **kw)),
            ("residual_moments",
             moments.residual_moments(a["y"], a["t"], a["my"], a["mt"],
                                      a["phi"], strategy="chunked", **kw),
             moments.residual_moments(a["y"], a["t"], a["my"], a["mt"],
                                      a["phi"], strategy="pallas", **kw)),
            ("residual_weighted_gram",
             moments.residual_weighted_gram(a["y"], a["t"], a["phi"],
                                            a["w"], strategy="chunked",
                                            **kw),
             moments.residual_weighted_gram(a["y"], a["t"], a["phi"],
                                            a["w"], strategy="pallas",
                                            **kw)),
            ("residual_meat",
             moments.residual_meat(a["y"], a["t"], a["my"], a["mt"],
                                   a["phi"], a["theta"], w=a["w"],
                                   strategy="chunked", **kw),
             moments.residual_meat(a["y"], a["t"], a["my"], a["mt"],
                                   a["phi"], a["theta"], w=a["w"],
                                   strategy="pallas", **kw)),
            ("iv_gram",
             moments.iv_gram(a["y"], a["t"], a["rz"], a["phi"], a["w"],
                             strategy="chunked", **kw),
             moments.iv_gram(a["y"], a["t"], a["rz"], a["phi"], a["w"],
                             strategy="pallas", **kw)),
            ("iv_meat",
             moments.iv_meat(a["y"], a["t"], a["rz"], a["phi"],
                             a["theta"], w=a["w"], strategy="chunked",
                             **kw),
             moments.iv_meat(a["y"], a["t"], a["rz"], a["phi"],
                             a["theta"], w=a["w"], strategy="pallas",
                             **kw)),
            ("fold_iv_gram",
             moments.fold_iv_gram(a["y"], a["t"], a["rz"], a["phi"],
                                  a["folds"], _K, strategy="chunked",
                                  **kw),
             moments.fold_iv_gram(a["y"], a["t"], a["rz"], a["phi"],
                                  a["folds"], _K, strategy="pallas",
                                  **kw)),
        ]
    for name, ref, got in pairs:
        ref = ref if isinstance(ref, tuple) else (ref,)
        got = got if isinstance(got, tuple) else (got,)
        for i, (r, g) in enumerate(zip(ref, got)):
            _close(g, r, f"{name}[{i}] {backend}")


@pytest.mark.parametrize("backend", BACKENDS)
def test_counts_strategy_independent(arrs, backend):
    """Counts/n_eff are plain sums computed outside the kernels: exact
    integers, bitwise-equal to the chunked one-hot column sums."""
    a = arrs
    _, c_ref = moments.fold_gram(a["X"], a["folds"], _K, row_block=_RB,
                                 strategy="chunked")
    with sg_ops.force_backend(backend):
        _, c = moments.fold_gram(a["X"], a["folds"], _K, row_block=_RB,
                                 strategy="pallas")
    np.testing.assert_array_equal(np.asarray(c_ref), np.asarray(c))


def test_pallas_requires_blocked_path(arrs):
    """row_block=0 keeps the legacy whole-array form byte-for-byte —
    the pallas strategy only engages on the blocked path."""
    a = arrs
    r0 = moments.residual_moments(a["y"], a["t"], a["my"], a["mt"],
                                  a["phi"], row_block=0)
    rp = moments.residual_moments(a["y"], a["t"], a["my"], a["mt"],
                                  a["phi"], row_block=0,
                                  strategy="pallas")
    np.testing.assert_array_equal(np.asarray(r0[0]), np.asarray(rp[0]))
    np.testing.assert_array_equal(np.asarray(r0[1]), np.asarray(rp[1]))


@pytest.mark.parametrize("backend", BACKENDS)
def test_fused_forms_take_no_fallback(arrs, backend):
    """``fold_weighted_gram`` and ``weighted_gram_and_vec`` lower
    through fused seg_gram builders: tolerance parity with the chunked
    reference, n_eff bitwise, and — the load-bearing assertion — the
    ``seg_gram.fallback[<form>]`` counters stay at ZERO.  Before the
    fused builders landed, both forms silently laddered pallas→chunked
    on every trace; this pins the fusion so it cannot regress."""
    from repro.obs.metrics import default_registry

    a = arrs
    Wk = jax.random.exponential(jax.random.PRNGKey(9), (_K, _N))
    ref_fw = moments.fold_weighted_gram(a["X"], Wk, intercept=True,
                                        row_block=_RB, strategy="chunked")
    ref_gv = moments.weighted_gram_and_vec(a["X"], a["w"], a["y"],
                                           intercept=True, row_block=_RB,
                                           strategy="chunked")
    with sg_ops.force_backend(backend):
        got_fw = moments.fold_weighted_gram(a["X"], Wk, intercept=True,
                                            row_block=_RB,
                                            strategy="pallas")
        got_gv = moments.weighted_gram_and_vec(a["X"], a["w"], a["y"],
                                               intercept=True,
                                               row_block=_RB,
                                               strategy="pallas")
    _close(got_fw[0], ref_fw[0], f"fold_weighted_gram {backend}")
    np.testing.assert_array_equal(np.asarray(ref_fw[1]),
                                  np.asarray(got_fw[1]))  # n_eff bitwise
    _close(got_gv[0], ref_gv[0], f"gram_and_vec.G {backend}")
    _close(got_gv[1], ref_gv[1], f"gram_and_vec.u {backend}")
    np.testing.assert_array_equal(np.asarray(ref_gv[2]),
                                  np.asarray(got_gv[2]))
    counters = default_registry().snapshot()["counters"]
    fallbacks = {k: v for k, v in counters.items()
                 if k.startswith("seg_gram.fallback[") and v}
    assert not fallbacks, f"fused forms took the fallback rung: {fallbacks}"


def test_fallback_ladder_counts_unfused_form(arrs):
    """Every registry moment form is fused now, but the counted
    pallas→chunked rung in ``blocked_reduce`` stays for future unfused
    forms: a direct call under strategy="pallas" yields the chunked
    bits exactly AND bumps ``seg_gram.fallback[<form>]`` — the
    ladder's observability contract."""
    from repro.core.moments import blocked_reduce
    from repro.obs.metrics import default_registry

    a = arrs

    def block(Xb, wb):
        return (wb[:, None].astype(jnp.float32) * Xb).T @ Xb

    ref = blocked_reduce(block, (a["X"], a["w"]), row_block=_RB,
                         strategy="chunked")
    got = blocked_reduce(block, (a["X"], a["w"]), row_block=_RB,
                         strategy="pallas", form="custom_form")
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))
    counters = default_registry().snapshot()["counters"]
    assert counters.get("seg_gram.fallback[custom_form]", 0) >= 1


# ---------------------------------------------------------------------------
# Exact tier: the structural bitwise contracts.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["scatter", "interpret"])
def test_padded_rows_exact_noop(arrs, backend):
    """Manually appending pad rows (zero data, seg=-1, w=0) changes
    NOTHING, bitwise — the contract the internal tail-padding relies
    on (no n % block_n divisibility requirement).  Scatter and the
    kernel only: the one-hot oracle's einsum retiles with n, so its
    padding invariance is tolerance-level, not bitwise."""
    a = arrs
    pad = 56  # 700 + 56 = 756, still non-divisible by 256
    U = a["phi"]
    V = jnp.concatenate([a["phi"], a["y"][:, None]], axis=1)
    seg = a["folds"]
    w = a["w"]
    Up = jnp.pad(U, ((0, pad), (0, 0)))
    Vp = jnp.pad(V, ((0, pad), (0, 0)))
    segp = jnp.pad(seg, (0, pad), constant_values=-1)
    wp = jnp.pad(w, (0, pad))
    g = sg_ops.segment_outer(U, V, seg, _K, w=w, row_block=_RB,
                             backend=backend)
    gp = sg_ops.segment_outer(Up, Vp, segp, _K, w=wp, row_block=_RB,
                              backend=backend)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(gp),
                                  err_msg=backend)


@pytest.mark.parametrize("backend", BACKENDS)
def test_zero_weight_equals_zero_data(arrs, backend):
    """Masking a row with w=0 is bitwise the same as zeroing its data
    (builders are row-linear and map zero rows to zero L/R rows)."""
    a = arrs
    mask = (jnp.arange(_N) % 3 != 0).astype(jnp.float32)
    g_w = sg_ops.residual_gram(a["y"], a["t"], a["my"], a["mt"],
                               a["phi"], w=mask, row_block=_RB,
                               backend=backend)
    z = mask
    g_z = sg_ops.residual_gram(a["y"] * z, a["t"] * z, a["my"] * z,
                               a["mt"] * z, a["phi"] * z[:, None],
                               row_block=_RB, backend=backend)
    np.testing.assert_array_equal(np.asarray(g_w[0]), np.asarray(g_z[0]),
                                  err_msg=backend)
    np.testing.assert_array_equal(np.asarray(g_w[1]), np.asarray(g_z[1]),
                                  err_msg=backend)


@pytest.mark.parametrize("backend", BACKENDS)
def test_empty_segment_exact_zero(arrs, backend):
    """A segment no row maps to yields an exactly-zero Gram slab and
    an integer-zero count — no NaN, no epsilon."""
    a = arrs
    seg = jnp.where(a["folds"] == 2, 1, a["folds"])  # segment 2 empty
    g = sg_ops.segment_outer(a["phi"], a["phi"], seg, _K,
                             row_block=_RB, backend=backend)
    assert np.all(np.asarray(g[2]) == 0.0), backend
    counts = sg_ops.segment_counts(seg, _K)
    assert float(counts[2]) == 0.0


@pytest.mark.parametrize("backend", BACKENDS)
def test_power_of_two_weights_exact(arrs, backend):
    """w = 2 everywhere scales the Gram EXACTLY by 2 (power-of-two
    scaling is exact in fp32) — pins where the weight is applied."""
    a = arrs
    g1 = sg_ops.segment_outer(a["phi"], a["phi"], a["folds"], _K,
                              row_block=_RB, backend=backend)
    g2 = sg_ops.segment_outer(a["phi"], a["phi"], a["folds"], _K,
                              w=jnp.full((_N,), 2.0), row_block=_RB,
                              backend=backend)
    np.testing.assert_array_equal(2.0 * np.asarray(g1), np.asarray(g2),
                                  err_msg=backend)


def test_blocked_scatter_matches_whole(arrs):
    """The bounded-memory blocked scatter (lax.scan of per-block
    segment_sums) agrees with the one-shot scatter."""
    a = arrs
    whole = sg_ops.segment_outer(a["phi"], a["phi"], a["folds"], _K,
                                 w=a["w"], row_block=0,
                                 backend="scatter")
    blocked = sg_ops.segment_outer(a["phi"], a["phi"], a["folds"], _K,
                                   w=a["w"], row_block=_RB,
                                   backend="scatter")
    _close(blocked, whole, "blocked scatter", atol=1e-4)


# ---------------------------------------------------------------------------
# The historical residual_gram entry point now routes through seg_gram
# (one fused-Gram implementation in the repo).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["ref", "interpret"])
def test_residual_gram_entry_point_parity(arrs, backend):
    a = arrs
    G_ref, b_ref = moments.residual_moments(a["y"], a["t"], a["my"],
                                            a["mt"], a["phi"],
                                            row_block=_RB,
                                            strategy="chunked")
    G, b = rg_ops.residual_gram(a["y"], a["t"], a["my"], a["mt"],
                                a["phi"], backend=backend)
    _close(G, G_ref, f"residual_gram G {backend}")
    _close(b, b_ref, f"residual_gram b {backend}")


def test_residual_gram_non_divisible_n():
    """The old hard ``assert n % block_n == 0`` is gone: the wrapper
    zero-pads the row tail (an exact no-op, certified above)."""
    key = jax.random.PRNGKey(11)
    ks = jax.random.split(key, 5)
    n, p = 333, 2  # 333 % 512 != 0 and n < block_n
    y, t, my, mt = (jax.random.normal(k, (n,)) for k in ks[:4])
    phi = jax.random.normal(ks[4], (n, p))
    G, b = rg_ops.residual_gram(y, t, my, mt, phi, backend="interpret")
    G_ref, b_ref = moments.residual_moments(y, t, my, mt, phi)
    _close(G, G_ref, "non-divisible G", atol=1e-4)
    _close(b, b_ref, "non-divisible b", atol=1e-4)


# ---------------------------------------------------------------------------
# End-to-end: the segmented sweep under strategy="pallas".
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["scatter", "interpret"])
def test_segmented_sweep_pallas_parity(backend):
    from repro.config import CausalConfig
    from repro.data.causal_dgp import make_causal_data
    from repro.sweep.segmented import segmented_dml_sweep

    key = jax.random.PRNGKey(0)
    n, E = 400, 5
    data = make_causal_data(jax.random.fold_in(key, 1), n, 4, effect=1.0)
    sids = jax.random.randint(jax.random.fold_in(key, 2), (n,), 0, E)
    cfg_c = CausalConfig(n_folds=3, inference="none", row_block=128,
                         row_block_strategy="chunked")
    cfg_p = dataclasses.replace(cfg_c, row_block_strategy="pallas")
    r_c = segmented_dml_sweep(cfg_c, data.X, data.y, data.t, sids, E, key)
    with sg_ops.force_backend(backend):
        r_p = segmented_dml_sweep(cfg_p, data.X, data.y, data.t, sids,
                                  E, key)
    for k in ("theta", "se", "ate"):
        _close(r_p[k], r_c[k], f"sweep.{k} {backend}", atol=1e-5,
               rtol=1e-5)


def test_builder_zero_rows_are_zero():
    """The builder contract the padding relies on: all-zero input rows
    produce all-zero L and R rows, for every builder."""
    z1 = jnp.zeros((4, 1))
    z3 = jnp.zeros((4, 3))
    theta = jnp.ones((1, 3))
    cases = [
        (sg_ref.build_pair, [z3, z3]),
        (sg_ref.build_design, [z3]),
        (sg_ref.build_residual, [z1, z1, z1, z1, z3]),
        (sg_ref.build_residual_direct, [z1, z1, z3]),
        (sg_ref.build_iv, [z1, z1, z1, z3]),
        (sg_ref.build_residual_meat, [z1, z1, z1, z1, z3, theta]),
        (sg_ref.build_iv_meat, [z1, z1, z1, z3, theta]),
    ]
    for builder, args in cases:
        L, R = builder(*args)
        assert np.all(np.asarray(L) == 0.0), builder.__name__
        assert np.all(np.asarray(R) == 0.0), builder.__name__
