"""repro.sweep certification: the segment-parallel panel against the
loop of single fits it replaces.

Contracts:
  * cells mode is BITWISE identical to ``serial_loop`` (a Python loop
    of masked single-estimator fits) at the canonical row-blocked
    conformance shapes, for EVERY sweepable registry estimator;
  * runtime-chunked scheduling of the cell axis changes nothing — the
    chunked and whole-batch panels are exactly equal;
  * zero-row segments produce flagged (ok=False) finite cells and do
    not perturb any other cell;
  * one failing column does not poison the panel (per-column fault
    isolation), and the surviving columns stay bit-exact;
  * shared-nuisance reuse (columns differing only in final stage) is
    bitwise the per-cell fit with the group's key lineage;
  * the segmented one-pass path equals a gathered per-segment
    LOO-kernel reference to float tolerance (it shares one fold draw
    across cells — a different execution of the same estimator, like
    engine="parallel_loo").
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import CausalConfig
from repro.core.registry import ROW_BLOCK, get_spec
from repro.data.causal_dgp import make_causal_data, make_iv_data
from repro.sweep import SweepSpec, serial_loop, sweep
from repro.sweep.segmented import segmented_dml_sweep

N, E = 1100, 5
_KEY = jax.random.PRNGKey(3)
_CFG = CausalConfig(n_folds=3, inference="none", row_block=ROW_BLOCK)

SWEEPABLE = ("dml", "drlearner", "s_learner", "t_learner", "x_learner",
             "orthoiv", "driv")


@pytest.fixture(scope="module")
def data():
    return make_causal_data(jax.random.PRNGKey(42), N, 6, effect=1.2)


@pytest.fixture(scope="module")
def iv_data():
    return make_iv_data(jax.random.PRNGKey(42), N, 6, effect=1.2,
                        compliance=0.75)


@pytest.fixture(scope="module")
def sids():
    return jax.random.randint(jax.random.PRNGKey(9), (N,), 0, E)


def _kw(name, data, iv_data, sids):
    d = iv_data if get_spec(name).needs_instrument else data
    kw = dict(X=d.X, y=d.y, t=d.t, segment_ids=sids, key=_KEY)
    if get_spec(name).needs_instrument:
        kw["z"] = d.z
    return kw


@pytest.mark.parametrize("name", SWEEPABLE)
def test_panel_equals_serial_loop_bitwise(name, data, iv_data, sids):
    """The acceptance contract: the batched panel IS the loop of single
    fits, bit for bit, at the canonical row-blocked shapes."""
    kw = _kw(name, data, iv_data, sids)
    spec = SweepSpec(n_segments=E, columns=((name, _CFG),))
    panel = sweep(spec, executor="vmap", **kw)
    loop = serial_loop(name, _CFG, n_segments=E, **kw)
    col = panel.columns[0]
    assert not col.failed
    np.testing.assert_array_equal(np.asarray(col.thetas),
                                  np.asarray(loop["theta"]), err_msg=name)
    np.testing.assert_array_equal(np.asarray(col.ates),
                                  np.asarray(loop["ate"]), err_msg=name)
    if col.ses is not None and "se" in loop:
        np.testing.assert_array_equal(np.asarray(col.ses),
                                      np.asarray(loop["se"]),
                                      err_msg=name)
    assert bool(col.ok(panel.counts).all())


def test_chunked_equals_whole_panel(data, sids):
    """Runtime-chunked scheduling of the cell axis (sweep_chunk) is an
    execution detail: exactly equal to the whole-batch panel."""
    kw = dict(X=data.X, y=data.y, t=data.t, segment_ids=sids, key=_KEY)
    whole = sweep(SweepSpec(n_segments=E, columns=(("dml", _CFG),)),
                  executor="vmap", **kw)
    cfg_c = dataclasses.replace(_CFG, sweep_chunk=2)
    chunked = sweep(SweepSpec(n_segments=E, columns=(("dml", cfg_c),)),
                    executor="vmap", **kw)
    assert any(ev.startswith("chunk") for ev in chunked.columns[0].events)
    np.testing.assert_array_equal(np.asarray(whole.columns[0].thetas),
                                  np.asarray(chunked.columns[0].thetas))
    np.testing.assert_array_equal(np.asarray(whole.columns[0].ses),
                                  np.asarray(chunked.columns[0].ses))


@pytest.mark.parametrize("name", ("dml", "t_learner"))
def test_zero_row_segment(name, data, sids):
    """A segment with no rows yields a flagged finite cell; every
    populated cell keeps its exact estimate."""
    sids0 = jnp.where(sids == 2, 1, sids)       # segment 2 emptied
    kw = dict(X=data.X, y=data.y, t=data.t, segment_ids=sids0, key=_KEY)
    panel = sweep(SweepSpec(n_segments=E, columns=((name, _CFG),)),
                  executor="vmap", **kw)
    col = panel.columns[0]
    ok = np.asarray(col.ok(panel.counts))
    assert int(panel.counts[2]) == 0 and not ok[2]
    assert ok[[0, 1, 3, 4]].all()
    assert np.isfinite(np.asarray(col.thetas)).all()
    loop = serial_loop(name, _CFG, n_segments=E, **kw)
    np.testing.assert_array_equal(np.asarray(col.thetas)[ok],
                                  np.asarray(loop["theta"])[ok])


def test_fault_isolation(data, sids):
    """A column that cannot even build (unknown nuisance) is recorded
    as failed; its neighbors keep bit-exact estimates."""
    bad = dataclasses.replace(_CFG, nuisance_y="nope")
    spec = SweepSpec(n_segments=E,
                     columns=(("dml", bad), ("dml", _CFG)))
    kw = dict(X=data.X, y=data.y, t=data.t, segment_ids=sids, key=_KEY)
    panel = sweep(spec, executor="vmap", **kw)
    assert panel.columns[0].failed
    assert "nope" in panel.columns[0].error
    assert not panel.columns[1].failed
    loop = serial_loop("dml", _CFG, n_segments=E, col_index=1, **kw)
    np.testing.assert_array_equal(np.asarray(panel.columns[1].thetas),
                                  np.asarray(loop["theta"]))
    assert panel.failures() == ((0, panel.columns[0].error),)
    # NaN column in the table, not an exception
    table = np.asarray(panel.ate_table())
    assert np.isnan(table[:, 0]).all() and np.isfinite(table[:, 1]).all()


def test_missing_instrument_isolated(data, sids):
    """An IV column without z fails alone; the DML column survives."""
    spec = SweepSpec(n_segments=E,
                     columns=(("orthoiv", _CFG), ("dml", _CFG)))
    panel = sweep(spec, X=data.X, y=data.y, t=data.t, segment_ids=sids,
                  key=_KEY, executor="vmap")
    assert panel.columns[0].failed and "instrument" in panel.columns[0].error
    assert not panel.columns[1].failed


def test_shared_nuisance_reuse_bitwise(data, sids):
    """Columns differing only in final stage share one residual pass —
    and still equal the per-cell single fits (group key lineage) bit
    for bit."""
    cfg2 = dataclasses.replace(_CFG, cate_features=2)
    spec = SweepSpec(n_segments=E,
                     columns=(("dml", _CFG), ("dml", cfg2)))
    kw = dict(X=data.X, y=data.y, t=data.t, segment_ids=sids, key=_KEY)
    panel = sweep(spec, executor="vmap", reuse=True, **kw)
    assert [c.shared_nuisance for c in panel.columns] == [False, True]
    assert panel.columns[1].key_index == 0
    for col, cfg in zip(panel.columns, (_CFG, cfg2)):
        loop = serial_loop("dml", cfg, n_segments=E, col_index=0, **kw)
        np.testing.assert_array_equal(np.asarray(col.thetas),
                                      np.asarray(loop["theta"]))
    # and reuse=False reproduces the plain per-column panel
    plain = sweep(spec, executor="vmap", reuse=False, **kw)
    assert not any(c.shared_nuisance for c in plain.columns)


def test_shared_group_member_failure_isolated(data, sids, monkeypatch):
    """One member of a shared-nuisance group failing (here: its CI
    dispatch) must not discard its siblings' computed columns — the
    shared residual pass alone is group-fatal."""
    import repro.sweep.engine as eng

    def boom(*args, **kwargs):
        raise RuntimeError("synthetic CI failure")

    monkeypatch.setattr(eng, "_column_ci", boom)
    cfg2 = dataclasses.replace(_CFG, cate_features=2,
                               inference="bootstrap", n_bootstrap=4)
    spec = SweepSpec(n_segments=E, columns=(("dml", _CFG), ("dml", cfg2)))
    panel = sweep(spec, X=data.X, y=data.y, t=data.t, segment_ids=sids,
                  key=_KEY, executor="vmap", reuse=True)
    assert not panel.columns[0].failed
    assert panel.columns[1].failed
    assert "synthetic" in panel.columns[1].error
    loop = serial_loop("dml", _CFG, X=data.X, y=data.y, t=data.t,
                       segment_ids=sids, n_segments=E, key=_KEY,
                       col_index=0)
    np.testing.assert_array_equal(np.asarray(panel.columns[0].thetas),
                                  np.asarray(loop["theta"]))


def test_sweep_bootstrap_ci(data, sids):
    """(cell × replicate) draws through map_product: per-cell CIs with
    ordered finite bounds and the full replicate tensor attached."""
    cfg = dataclasses.replace(_CFG, inference="bootstrap", n_bootstrap=8)
    panel = sweep(SweepSpec(n_segments=E, columns=(("dml", cfg),)),
                  X=data.X, y=data.y, t=data.t, segment_ids=sids,
                  key=_KEY, executor="vmap")
    col = panel.columns[0]
    assert col.replicates.shape == (E, 8, 1)
    assert col.ci_lo.shape == (E,) and col.ci_hi.shape == (E,)
    assert np.isfinite(np.asarray(col.ci_lo)).all()
    assert bool((col.ci_lo < col.ci_hi).all())


def test_segmented_matches_gathered_loo_reference(data):
    """The one-pass segmented path = per-segment gathered fits with the
    SAME shared folds and the SAME LOO/MM kernels, to float tolerance
    (different summation order only)."""
    from repro.core.crossfit import _oof_select, fold_ids
    from repro.core.final_stage import cate_basis
    from repro.core.nuisance import logistic_fit_folds, ridge_fit_folds
    from repro.inference.numerics import det_solve

    e_seg, k = 3, 3
    cfg = CausalConfig(n_folds=k)
    sids3 = jax.random.randint(jax.random.PRNGKey(11), (N,), 0, e_seg)
    key = jax.random.PRNGKey(7)
    out = segmented_dml_sweep(cfg, data.X, data.y, data.t, sids3, e_seg,
                              key)
    folds = fold_ids(key, N, k)
    f32 = jnp.float32

    def aug(x):
        return jnp.concatenate([x, jnp.ones((x.shape[0], 1), x.dtype)],
                               axis=1)

    for s in range(e_seg):
        m = np.asarray(sids3) == s
        xs, ys = data.X[m], data.y[m]
        ts, fs = data.t[m], folds[m]
        sty = ridge_fit_folds(cfg.ridge_lambda, xs, ys, fs, k)
        my = _oof_select(jnp.einsum("kp,np->kn", sty["beta"],
                                    aug(xs.astype(f32))), fs)
        stt = logistic_fit_folds(cfg.ridge_lambda, 2 * cfg.newton_iters,
                                 xs, ts.astype(f32), fs, k)
        mt = _oof_select(jax.nn.sigmoid(
            jnp.einsum("kp,np->kn", stt["beta"], aug(xs.astype(f32)))),
            fs)
        ry, rt = ys.astype(f32) - my, ts.astype(f32) - mt
        phi = cate_basis(xs, cfg.cate_features)
        z = rt[:, None] * phi
        mm = jnp.concatenate([z, ry[:, None]], axis=1)
        g = mm.T @ mm
        p = phi.shape[1]
        a = g[:p, :p] + 1e-8 * xs.shape[0] * jnp.eye(p)
        ref = det_solve(a, g[:p, p])
        np.testing.assert_allclose(np.asarray(out["theta"][s]),
                                   np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_segmented_mode_through_engine(data, sids):
    """mode='segmented' routes DML columns onto the one-pass kernels
    (tagged in events) and recovers the effect on every segment."""
    panel = sweep(SweepSpec(n_segments=E, columns=(("dml", _CFG),)),
                  X=data.X, y=data.y, t=data.t, segment_ids=sids,
                  key=_KEY, mode="segmented")
    col = panel.columns[0]
    assert col.events == ("segmented",)
    assert np.isfinite(np.asarray(col.thetas)).all()
    assert np.abs(np.asarray(col.ates) - 1.2).max() < 0.6  # ~220 rows/seg
    # unsupported configs fall back to cells (still bit-exact vs loop)
    mlp_cfg = dataclasses.replace(_CFG, nuisance_y="mlp", mlp_steps=5,
                                  mlp_hidden=(8,))
    panel2 = sweep(SweepSpec(n_segments=E, columns=(("dml", mlp_cfg),)),
                   X=data.X, y=data.y, t=data.t, segment_ids=sids,
                   key=_KEY, mode="segmented", executor="vmap")
    assert panel2.columns[0].events != ("segmented",)
    assert np.isfinite(np.asarray(panel2.columns[0].thetas)).all()


def test_spec_validation():
    with pytest.raises(ValueError):
        SweepSpec(n_segments=0, columns=(("dml", _CFG),))
    with pytest.raises(ValueError):
        SweepSpec(n_segments=4, columns=())
    spec = SweepSpec.grid(4, estimators=("dml", "drlearner"),
                          configs=(_CFG,))
    assert spec.n_cells == 8 and len(spec.columns) == 2


def test_unknown_estimator_is_isolated(data, sids):
    panel = sweep(SweepSpec(n_segments=E, columns=(("nope", _CFG),)),
                  X=data.X, y=data.y, t=data.t, segment_ids=sids,
                  key=_KEY)
    assert panel.columns[0].failed
    assert "nope" in panel.columns[0].error


def test_panel_summary(data, sids):
    cfg = dataclasses.replace(_CFG, segment_key="cohort")
    spec = SweepSpec.grid(E, estimators=("dml",), configs=(cfg,))
    panel = sweep(spec, X=data.X, y=data.y, t=data.t, segment_ids=sids,
                  key=_KEY, executor="vmap")
    s = panel.summary()
    assert "cohort" in s and f"{E} segments" in s
    assert panel.ate_table().shape == (E, 1)
    assert panel.ok().shape == (E, 1)
