"""GLA scan kernel: chunked ref vs token-by-token naive oracle vs Pallas
interpret, both recurrence modes (mamba2 'post', rwkv6 'bonus')."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssm_scan import kernel as gla_kernel
from repro.kernels.ssm_scan import ops as gla_ops
from repro.kernels.ssm_scan import ref as gla_ref


def _mk(key, B, H, T, Dk, Dv, decay_lo=0.05):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, H, T, Dk))
    k = jax.random.normal(ks[1], (B, H, T, Dk))
    v = jax.random.normal(ks[2], (B, H, T, Dv))
    # per-step decay within the stability contract (w >= e^-3.49 ~ 0.03)
    w = decay_lo + (1 - decay_lo) * jax.random.uniform(ks[3], (B, H, T, Dk))
    u = jax.random.normal(ks[4], (H, Dk)) * 0.5
    return q, k, v, w, u


SHAPES = [
    # B, H, T, Dk, Dv, chunk
    (2, 2, 64, 16, 16, 16),
    (1, 4, 128, 32, 64, 16),
    (2, 1, 96, 8, 8, 16),     # T not multiple of 32
    (1, 2, 64, 64, 64, 32),
]


@pytest.mark.parametrize("mode", ["post", "bonus"])
@pytest.mark.parametrize("shape", SHAPES)
def test_chunked_ref_vs_naive(key, shape, mode):
    B, H, T, Dk, Dv, chunk = shape
    q, k, v, w, u = _mk(key, B, H, T, Dk, Dv)
    uu = None if mode == "post" else u
    o_ref, s_ref = gla_ref.gla_chunked_ref(q, k, v, w, uu, chunk=chunk)
    o_naive, s_naive = gla_ref.gla_naive(q, k, v, w, uu)
    np.testing.assert_allclose(o_ref, o_naive, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s_ref, s_naive, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("mode", ["post", "bonus"])
@pytest.mark.parametrize("shape", SHAPES[:2])
def test_pallas_interpret_vs_ref(key, shape, mode):
    B, H, T, Dk, Dv, chunk = shape
    q, k, v, w, u = _mk(key, B, H, T, Dk, Dv)
    uu = None if mode == "post" else u
    o_ref, s_ref = gla_ref.gla_chunked_ref(q, k, v, w, uu, chunk=chunk)
    o_pal, s_pal = gla_kernel.gla_pallas(q, k, v, w, uu, chunk=chunk,
                                         interpret=True)
    np.testing.assert_allclose(o_pal, o_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s_pal, s_ref, rtol=2e-4, atol=2e-4)


def test_strong_decay_is_finite(key):
    """Decay at the clamp boundary must not overflow the chunked form."""
    B, H, T, Dk, Dv = 1, 2, 64, 16, 16
    q, k, v, w, u = _mk(key, B, H, T, Dk, Dv)
    w = jnp.full_like(w, float(np.exp(-gla_ref.MAX_LOG_DECAY)))
    o, s = gla_ref.gla_chunked_ref(q, k, v, w, None, chunk=16)
    assert jnp.isfinite(o).all() and jnp.isfinite(s).all()
    o2, s2 = gla_ref.gla_naive(q, k, v, w, None)
    np.testing.assert_allclose(o, o2, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("mode", ["post", "bonus"])
def test_decode_step_extends_prefill(key, mode):
    """Running T steps of gla_step == the scan's final state/output."""
    B, H, T, Dk, Dv = 1, 2, 32, 8, 8
    q, k, v, w, u = _mk(key, B, H, T, Dk, Dv)
    uu = None if mode == "post" else u
    o_scan, s_scan = gla_ref.gla_chunked_ref(q, k, v, w, uu, chunk=16)
    s = jnp.zeros((B, H, Dk, Dv))
    outs = []
    for t in range(T):
        s, o = gla_ops.gla_decode_step(s, q[:, :, t], k[:, :, t],
                                       v[:, :, t], w[:, :, t], uu)
        outs.append(o)
    o_seq = jnp.stack(outs, axis=2)
    np.testing.assert_allclose(o_seq, o_scan, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s, s_scan, rtol=2e-4, atol=2e-4)


def test_initial_state_carries(key):
    """Chunked scan with an initial state == naive with the same state."""
    B, H, T, Dk, Dv = 1, 1, 32, 8, 8
    q, k, v, w, u = _mk(key, B, H, T, Dk, Dv)
    s0 = jax.random.normal(jax.random.fold_in(key, 9), (B, H, Dk, Dv))
    o1, s1 = gla_ref.gla_chunked_ref(q, k, v, w, None, chunk=16,
                                     initial_state=s0)
    o2, s2 = gla_ref.gla_naive(q, k, v, w, None, initial_state=s0)
    np.testing.assert_allclose(o1, o2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s1, s2, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# SSD mode (head-shared q/k, scalar decay)
# ---------------------------------------------------------------------------

def _mk_ssd(key, B, H, T, N, P):
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, T, N))
    k = jax.random.normal(ks[1], (B, T, N))
    v = jax.random.normal(ks[2], (B, H, T, P))
    a = 0.05 + 0.95 * jax.random.uniform(ks[3], (B, H, T))
    return q, k, v, a


@pytest.mark.parametrize("shape", [
    (2, 3, 64, 16, 16, 32), (1, 4, 128, 64, 64, 64), (2, 1, 96, 8, 8, 32),
])
def test_ssd_chunked_vs_naive(key, shape):
    B, H, T, N, P, chunk = shape
    q, k, v, a = _mk_ssd(key, B, H, T, N, P)
    o1, s1 = gla_ref.ssd_chunked_ref(q, k, v, a, chunk=chunk)
    o2, s2 = gla_ref.ssd_naive(q, k, v, a)
    np.testing.assert_allclose(o1, o2, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(s1, s2, rtol=3e-4, atol=3e-4)


def test_ssd_strong_decay_any_magnitude(key):
    """Unlike the per-channel GLA path, SSD's L-matrix form is stable for
    ARBITRARY decay (no clamp contract needed)."""
    q, k, v, a = _mk_ssd(key, 1, 2, 64, 16, 16)
    a = jnp.full_like(a, 1e-20)  # brutal decay
    o, s = gla_ref.ssd_chunked_ref(q, k, v, a, chunk=32)
    assert jnp.isfinite(o).all() and jnp.isfinite(s).all()
    o2, s2 = gla_ref.ssd_naive(q, k, v, a)
    np.testing.assert_allclose(o, o2, rtol=3e-4, atol=3e-4)


def test_ssd_pallas_interpret_vs_ref(key):
    B, H, T, N, P, chunk = 2, 3, 128, 32, 64, 32
    q, k, v, a = _mk_ssd(key, B, H, T, N, P)
    o_ref, s_ref = gla_ref.ssd_chunked_ref(q, k, v, a, chunk=chunk)
    o_pal, s_pal = gla_kernel.ssd_pallas(q, k, v, a, chunk=chunk,
                                         interpret=True)
    np.testing.assert_allclose(o_pal, o_ref, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(s_pal, s_ref, rtol=3e-4, atol=3e-4)


def test_ssd_decode_step_extends(key):
    B, H, T, N, P = 1, 2, 32, 8, 8
    q, k, v, a = _mk_ssd(key, B, H, T, N, P)
    o_scan, s_scan = gla_ref.ssd_chunked_ref(q, k, v, a, chunk=16)
    s = jnp.zeros((B, H, N, P))
    outs = []
    for t in range(T):
        s, o = gla_ops.ssd_decode_step(s, q[:, t], k[:, t], v[:, :, t],
                                       a[:, :, t])
        outs.append(o)
    np.testing.assert_allclose(jnp.stack(outs, 2), o_scan, rtol=3e-4,
                               atol=3e-4)
    np.testing.assert_allclose(s, s_scan, rtol=3e-4, atol=3e-4)
