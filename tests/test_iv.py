"""Orthogonal-IV family (repro.core.iv): LATE recovery on the
compliance DGP (the acceptance bar: within 2 standard errors), naive-DML
bias as the control, DRIV agreement, CATE recovery, weak-instrument
screening, replicate inference, and the IV dry-run cell."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import CausalConfig
from repro.core.dml import DML
from repro.core.iv import DRIV, OrthoIV
from repro.data.causal_dgp import make_iv_data

N, P = 8000, 10


@pytest.fixture(scope="module")
def data():
    return make_iv_data(jax.random.PRNGKey(42), N, P, effect=1.5,
                        compliance=0.7)


@pytest.fixture(scope="module")
def fitted(data):
    cfg = CausalConfig(n_folds=5, n_bootstrap=32)
    return OrthoIV(cfg).fit(data.y, data.t, data.z, data.X,
                            key=jax.random.PRNGKey(0))


def test_orthoiv_recovers_late_within_2se(data, fitted):
    """The acceptance criterion: the known LATE within 2 stderr."""
    assert abs(fitted.late - data.true_late) < 2 * float(fitted.stderr[0])
    assert not fitted.diagnostics.weak_instrument
    assert fitted.diagnostics.ortho_moment < 1e-3


def test_naive_dml_is_biased_iv_is_not(data, fitted, key):
    """The reason the IV family exists: the DGP's unobserved confounder
    drives noncompliers' treatment, so DML (no instrument) lands far
    from the truth while OrthoIV straddles it."""
    cfg = CausalConfig(n_folds=5, inference="none")
    naive = DML(cfg).fit(data.y, data.t, data.X, key=key)
    iv_err = abs(fitted.late - data.true_late)
    naive_err = abs(naive.ate - data.true_late)
    assert naive_err > 0.15          # materially confounded
    assert iv_err < 0.5 * naive_err  # and the instrument removes it


def test_driv_agrees_with_orthoiv(data, fitted, key):
    cfg = CausalConfig(n_folds=5, inference="none")
    dr = DRIV(cfg).fit(data.y, data.t, data.z, data.X, key=key)
    assert abs(dr.late - data.true_late) < 2.5 * dr.stderr + 0.05
    assert abs(dr.late - fitted.late) < 0.1
    # the preliminary estimate is the constant OrthoIV solve
    assert abs(dr.theta_pre - fitted.late) < 0.05


def test_iv_cate_recovery_heterogeneous(key):
    d = make_iv_data(jax.random.PRNGKey(7), N, P, effect=1.0,
                     heterogeneous=True, compliance=0.8)
    cfg = CausalConfig(n_folds=5, cate_features=2, inference="none")
    res = OrthoIV(cfg).fit(d.y, d.t, d.z, d.X, key=key)
    # theta ~ [1.0, 0.5] (effect = 1 + 0.5 x0), IV noise is real
    np.testing.assert_allclose(np.asarray(res.theta), [1.0, 0.5],
                               atol=0.2)
    rmse = float(jnp.sqrt(jnp.mean(
        (res.cate(d.X) - d.true_cate) ** 2)))
    assert rmse < 0.25


def test_continuous_instrument(key):
    d = make_iv_data(jax.random.PRNGKey(5), N, P, effect=0.8,
                     discrete_instrument=False, compliance=0.9)
    cfg = CausalConfig(n_folds=5, discrete_instrument=False,
                       discrete_treatment=False, nuisance_t="ridge",
                       inference="none")
    res = OrthoIV(cfg).fit(d.y, d.t, d.z, d.X, key=key)
    assert abs(res.ate - 0.8) < 0.1
    assert not res.diagnostics.weak_instrument


def test_weak_instrument_is_flagged(key):
    """Near-zero compliance -> no first stage -> the F screen fires."""
    d = make_iv_data(jax.random.PRNGKey(11), 3000, 6, effect=1.0,
                     compliance=0.02)
    cfg = CausalConfig(n_folds=3, inference="none")
    res = OrthoIV(cfg).fit(d.y, d.t, d.z, d.X, key=key)
    assert res.diagnostics.weak_instrument
    from repro.core.refutation import weak_instrument
    rep = weak_instrument(res)
    assert not rep.passed
    assert "FAIL" in rep.row()


def test_weak_instrument_report_on_strong_design(fitted):
    from repro.core.refutation import weak_instrument
    rep = weak_instrument(fitted)
    assert rep.passed
    assert rep.f_stat > 100.0


def test_placebo_instrument_executor_equivalence(data, key):
    from repro.core.refutation import placebo_instrument
    est = OrthoIV(CausalConfig(n_folds=3, inference="none"))
    kw = dict(original_ate=1.5, n_reps=2, key=jax.random.PRNGKey(19))
    r_ser = placebo_instrument(est, data.y, data.t, data.z, data.X,
                               executor="serial", **kw)
    r_vec = placebo_instrument(est, data.y, data.t, data.z, data.X,
                               executor="vmap", **kw)
    assert r_ser.refuted_ates == r_vec.refuted_ates
    assert r_ser.name == "placebo_instrument"


def test_iv_bootstrap_interval_api(data, fitted):
    lo, hi = fitted.late_interval()
    assert lo < fitted.late < hi
    assert np.isfinite([lo, hi]).all()
    lo2, hi2 = fitted.ate_interval(alpha=0.5)
    assert (hi2 - lo2) < (hi - lo)
    blo, bhi = fitted.cate_interval(data.X[:5])
    assert blo.shape == (5,) and bool((blo < bhi).all())


def test_iv_jackknife_agrees_with_if_stderr(fitted):
    jk = fitted.inference(method="jackknife")
    if_se = float(fitted.stderr[0])
    jk_se = float(jk.se[0])
    assert 0.3 * if_se < jk_se < 3.0 * if_se, (jk_se, if_se)


def test_iv_jackknife_matches_direct_delete_fold(key):
    """LOO-identity jackknife (one segmented instrumented Gram) vs
    re-solving each delete-fold weighted IV moment directly."""
    from repro.core.crossfit import fold_ids
    from repro.core.final_stage import cate_basis
    from repro.inference import delete_fold_jackknife_iv
    from repro.inference.numerics import weighted_iv_theta
    n, k = 2000, 4
    d = make_iv_data(jax.random.PRNGKey(13), n, 6, effect=1.0,
                     compliance=0.75)
    my = 0.1 * d.y
    mt = jnp.full((n,), 0.5, jnp.float32)
    mz = jnp.full((n,), 0.5, jnp.float32)
    folds = fold_ids(key, n, k)
    phi = cate_basis(d.X, 2)
    jk = delete_fold_jackknife_iv(d.y, d.t, d.z, my, mt, mz, folds, phi,
                                  k)
    ry, rt, rz = d.y - my, d.t - mt, d.z - mz
    direct = jnp.stack([
        weighted_iv_theta(ry, rt, rz, phi,
                          (folds != j).astype(jnp.float32),
                          with_se=False)[0]
        for j in range(k)])
    np.testing.assert_allclose(np.asarray(jk.replicates),
                               np.asarray(direct), rtol=1e-4, atol=1e-5)
    jk_rb = delete_fold_jackknife_iv(d.y, d.t, d.z, my, mt, mz, folds,
                                     phi, k, row_block=300)
    np.testing.assert_allclose(np.asarray(jk_rb.replicates),
                               np.asarray(jk.replicates), rtol=1e-4,
                               atol=1e-5)


def test_iv_inference_cache_ignores_alpha(fitted):
    r1 = fitted.inference(n_bootstrap=8)
    r2 = fitted.inference(n_bootstrap=8, alpha=0.2)
    assert r1 is r2


def test_iv_inference_none_falls_back_to_sandwich(data):
    cfg = CausalConfig(n_folds=3, inference="none")
    res = OrthoIV(cfg).fit(data.y, data.t, data.z, data.X,
                           key=jax.random.PRNGKey(0))
    lo, hi = res.ate_interval()
    clo, chi = res.conf_int()
    assert lo == pytest.approx(float(clo[0]))
    assert hi == pytest.approx(float(chi[0]))


def test_driv_interval_centers_on_late(data, key):
    cfg = CausalConfig(n_folds=3, n_bootstrap=24)
    res = DRIV(cfg).fit(data.y, data.t, data.z, data.X, key=key)
    lo, hi = res.late_interval()
    assert lo <= res.late <= hi
    blo, bhi = res.cate_interval(data.X[:4])
    assert blo.shape == (4,)


def test_tuned_iv_nuisances(data, key):
    from repro.core.tuning import tuned_iv_nuisances
    cfg = CausalConfig(n_folds=3, inference="none")
    ny, nt, nz = tuned_iv_nuisances(cfg, data.X[:2000], data.y[:2000],
                                    data.t[:2000], data.z[:2000], key)
    assert ny.name == "ridge" and nt.name == "logistic"
    assert nz.name == "logistic"
    res = OrthoIV(cfg, nuisance_y=ny, nuisance_t=nt,
                  nuisance_z=nz).fit(data.y, data.t, data.z, data.X,
                                     key=key)
    assert abs(res.late - data.true_late) < 0.2


def test_iv_summary_renders(fitted):
    s = fitted.summary()
    assert "OrthoIV result" in s and "first-stage F" in s


def test_iv_cell_lowers():
    """The IV workload lowers against a mesh exactly like the DML cell
    (smoke shape; the 256-chip version runs in the dry-run tier)."""
    from jax.sharding import Mesh
    from repro.launch.dml_cell import lower_iv_cell
    from repro.configs.iv_synthetic import IV_CAUSAL
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    lowered = lower_iv_cell(mesh, IV_CAUSAL, n=512, p=8)
    txt = lowered.as_text()
    assert "func" in txt or len(txt) > 0


def test_iv_data_ground_truth_properties():
    """DGP invariants: complier fraction ~ compliance, exclusion (Z
    enters Y only through T), and the Wald estimand equals the LATE."""
    # instrument_strength=0 -> Z ~ Bern(1/2) independent of X, so the
    # UNCONDITIONAL Wald ratio is the LATE (with X-driven assignment
    # only the X-conditional moment is; that's what OrthoIV solves)
    d = make_iv_data(jax.random.PRNGKey(3), 50_000, 4, effect=2.0,
                     compliance=0.6, instrument_strength=0.0)
    assert abs(float(d.complier.mean()) - 0.6) < 0.02
    # population Wald check: E[Y|Z=1]-E[Y|Z=0] / E[T|Z=1]-E[T|Z=0]
    z = np.asarray(d.z)
    y = np.asarray(d.y)
    t = np.asarray(d.t)
    wald = ((y[z == 1].mean() - y[z == 0].mean())
            / (t[z == 1].mean() - t[z == 0].mean()))
    assert abs(wald - d.true_late) < 0.15
