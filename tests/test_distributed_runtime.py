"""Certification of the row-sharded data-mesh path
(repro.runtime.distributed) against the single-process chunked
baseline it accelerates.

Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the
CI tier1-dist leg) for a real 8-shard mesh; on a plain 1-device host
the mesh degrades to (1, 1) and every contract still holds on the
same code path.

Contracts:
  * ``reduction="ordered"`` is BITWISE: every registry estimator's
    full fit under ``use_data_mesh`` equals the single-process chunked
    fit at the canonical conformance shapes, and the blocked moments
    entry points match at several row_blocks including non-divisible
    row counts (the padded-block path);
  * ``init``-seeded reductions replay the same left fold —
    ``MomentStore.ingest`` sharded ≡ serial bitwise on aligned blocks;
  * ``reduction="psum"`` is tolerance-grade (documented, not bitwise);
  * a lost shard downgrades through the runtime ladder to the
    single-host rung with the SAME bits (default retry budget), and
    with a zero retry budget costs exactly one sweep column — resume
    through the checkpoint recomputes only that column;
  * the job API (submit / poll / subscribe) streams one event per
    column and returns the same panel ``sweep`` would.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.config import CausalConfig
from repro.core import moments
from repro.core.registry import ROW_BLOCK, SPEC_IDS, SPECS, tree_arrays
from repro.runtime import (
    JobManager,
    dist_reduce,
    inject_shard_failure,
    make_data_mesh,
    use_data_mesh,
)
from repro.store import MomentStore
from repro.sweep import SweepSpec, sweep

N = 1100  # the conformance row count: non-divisible into ROW_BLOCK
_FIT_KEY = jax.random.PRNGKey(0)
_DATA_KEY = jax.random.PRNGKey(42)
_data_cache = {}


def _data(spec):
    if spec.make_data not in _data_cache:
        _data_cache[spec.make_data] = spec.make_data(_DATA_KEY)
    return _data_cache[spec.make_data]


def _assert_trees_equal(a, b, msg=""):
    la, lb = tree_arrays(a), tree_arrays(b)
    assert len(la) == len(lb), msg
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=msg)


@pytest.fixture(scope="module")
def dm():
    return make_data_mesh()


@pytest.fixture(scope="module")
def arrs():
    ks = jax.random.split(jax.random.PRNGKey(7), 6)
    return dict(
        X=jax.random.normal(ks[0], (N, 5)),
        w=jax.random.exponential(ks[1], (N,)).astype(jnp.float32),
        folds=jax.random.randint(ks[2], (N,), 0, 4),
        ry=jax.random.normal(ks[3], (N,)),
        rt=jax.random.normal(ks[4], (N,)),
        rz=jax.random.normal(ks[5], (N,)),
    )


def test_mesh_shape_adapts_to_devices(dm):
    """The default mesh spans every visible device — 8 under the
    forced-8 CI leg, (1, 1) on a plain host — and says so in its
    label."""
    assert dm.n_shards == jax.device_count()
    assert dm.label.endswith(":ordered")
    with pytest.raises(ValueError):
        make_data_mesh(reduction="median")
    with pytest.raises(ValueError):
        dist_reduce(lambda x: x.sum(0), [jnp.ones((8, 2))], row_block=4)


# ---------------------------------------------------------------------------
# The tentpole certificate: registry-wide bitwise identity.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", SPECS, ids=SPEC_IDS)
def test_registry_fit_sharded_bitwise(spec, dm):
    """EVERY registry estimator: the full fit with the data mesh active
    is bit-for-bit the single-process chunked fit at the canonical
    row-blocked shapes."""
    data = _data(spec)
    cfg = dataclasses.replace(spec.base_cfg, row_block=ROW_BLOCK,
                              row_block_strategy="chunked")
    r_single = spec.fit(data, cfg, _FIT_KEY)
    with use_data_mesh(dm):
        r_dist = spec.fit(data, cfg, _FIT_KEY)
    _assert_trees_equal(r_single, r_dist, spec.name)


@pytest.mark.parametrize("rb", [256, 128, 64])
def test_moments_ordered_bitwise(arrs, dm, rb):
    """The blocked moments entry points at several row_blocks (N=1100
    never divides evenly — the padded-tail-and-extra-blocks path):
    sharded ordered reduction ≡ chunked, bitwise."""
    a = arrs
    ref_wg = moments.weighted_gram(a["X"], a["w"], intercept=True,
                                   row_block=rb, strategy="chunked")
    ref_fg = moments.fold_gram(a["X"], a["folds"], 4, intercept=True,
                               row_block=rb, strategy="chunked")
    ref_iv = moments.iv_gram(a["ry"], a["rt"], a["rz"], a["X"], a["w"],
                             row_block=rb, strategy="chunked")
    with use_data_mesh(dm):
        got_wg = moments.weighted_gram(a["X"], a["w"], intercept=True,
                                       row_block=rb, strategy="chunked")
        got_fg = moments.fold_gram(a["X"], a["folds"], 4, intercept=True,
                                   row_block=rb, strategy="chunked")
        got_iv = moments.iv_gram(a["ry"], a["rt"], a["rz"], a["X"],
                                 a["w"], row_block=rb, strategy="chunked")
    _assert_trees_equal(ref_wg, got_wg, f"weighted_gram rb={rb}")
    _assert_trees_equal(ref_fg, got_fg, f"fold_gram rb={rb}")
    _assert_trees_equal(ref_iv, got_iv, f"iv_gram rb={rb}")


def test_dist_reduce_init_seeded_bitwise(arrs, dm):
    """``init`` seeds the ordered fold exactly like blocked_reduce —
    the store-ingest hook."""
    a = arrs

    def block(Xb, wb):
        return (wb[:, None].astype(jnp.float32) * Xb).T @ Xb

    seed = jnp.full((5, 5), 0.25, jnp.float32)
    ref = moments.blocked_reduce(block, (a["X"], a["w"]), row_block=128,
                                 strategy="chunked", init=seed)
    got = dist_reduce(block, (a["X"], a["w"]), row_block=128, dm=dm,
                      init=seed)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


def test_psum_mode_tolerance(arrs, dm):
    """The wire-efficient psum mode reassociates — tolerance-grade
    against chunked, by design."""
    a = arrs

    def block(Xb, wb):
        return (wb[:, None].astype(jnp.float32) * Xb).T @ Xb

    ref = moments.blocked_reduce(block, (a["X"], a["w"]), row_block=128,
                                 strategy="chunked")
    got = dist_reduce(block, (a["X"], a["w"]), row_block=128, dm=dm,
                      reduction="psum")
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                               rtol=2e-5, atol=2e-4)


# ---------------------------------------------------------------------------
# Fault tolerance: lost shards.
# ---------------------------------------------------------------------------

def _sweep_kw(n_segments=3):
    d = _data(SPECS[0])
    sids = jax.random.randint(jax.random.PRNGKey(9), (N,), 0, n_segments)
    return dict(X=d.X, y=d.y, t=d.t, segment_ids=sids, key=_FIT_KEY)


_CFG = CausalConfig(n_folds=3, inference="none", row_block=ROW_BLOCK)


def test_lost_shard_downgrades_to_single_host_bitwise(dm):
    """Default retry budget: a shard lost at trace time drops the chunk
    to the plain single-host rung — SAME bits as the no-mesh run, with
    the downgrade recorded on the column's events."""
    kw = _sweep_kw()
    spec = SweepSpec(n_segments=3, columns=(("dml", _CFG),))
    plain = sweep(spec, **kw).columns[0]
    inject_shard_failure(1)
    try:
        col = sweep(spec, data_mesh=dm, **kw).columns[0]
    finally:
        inject_shard_failure(0)
    assert not col.failed
    assert any(ev.startswith("downgrade:") for ev in col.events), col.events
    np.testing.assert_array_equal(np.asarray(plain.thetas),
                                  np.asarray(col.thetas))
    np.testing.assert_array_equal(np.asarray(plain.ates),
                                  np.asarray(col.ates))


def test_lost_shard_costs_one_column_and_resumes(tmp_path, dm):
    """Zero retry budget on the struck column: the loss is isolated to
    that column (its group neighbor lands bitwise), and re-running the
    sweep against the same checkpoint directory recomputes ONLY the
    lost column."""
    kw = _sweep_kw()
    cfg_fragile = dataclasses.replace(_CFG, runtime_max_retries=0)
    spec = SweepSpec(n_segments=3, columns=(("dml", cfg_fragile),
                                            ("drlearner", _CFG)))
    plain = sweep(spec, **kw)
    mgr = CheckpointManager(str(tmp_path / "ckpt"))

    inject_shard_failure(1)
    try:
        struck = sweep(spec, data_mesh=dm, checkpoint=mgr, **kw)
    finally:
        inject_shard_failure(0)
    assert struck.columns[0].failed
    assert "injected shard failure" in struck.columns[0].error
    assert not struck.columns[1].failed  # at most ONE column lost
    np.testing.assert_array_equal(np.asarray(plain.columns[1].thetas),
                                  np.asarray(struck.columns[1].thetas))

    # resume: the surviving column restores from disk, the lost one
    # recomputes (errored checkpoints never restore) and now succeeds
    recovered = sweep(spec, data_mesh=dm, checkpoint=mgr, **kw)
    assert not recovered.columns[0].failed
    assert "restored" not in recovered.columns[0].events
    assert "restored" in recovered.columns[1].events
    np.testing.assert_array_equal(np.asarray(plain.columns[0].thetas),
                                  np.asarray(recovered.columns[0].thetas))
    np.testing.assert_array_equal(np.asarray(plain.columns[1].thetas),
                                  np.asarray(recovered.columns[1].thetas))


def test_elastic_sweep_helper(tmp_path, dm):
    """launch.elastic.elastic_sweep: one call = checkpointed sweep; the
    second call restores every column bitwise without recomputing."""
    from repro.launch.elastic import elastic_sweep, sweep_checkpoint_manager

    kw = _sweep_kw()
    spec = SweepSpec(n_segments=3, columns=(("dml", _CFG),))
    mgr = sweep_checkpoint_manager(str(tmp_path / "ck"), spec)
    assert mgr.keep_latest >= len(spec.columns) + 1

    first = elastic_sweep(spec, directory=str(tmp_path / "es"),
                          data_mesh=dm, **kw)
    second = elastic_sweep(spec, directory=str(tmp_path / "es"),
                           data_mesh=dm, **kw)
    assert "restored" in second.columns[0].events
    np.testing.assert_array_equal(np.asarray(first.columns[0].thetas),
                                  np.asarray(second.columns[0].thetas))


# ---------------------------------------------------------------------------
# The sharded store.
# ---------------------------------------------------------------------------

def test_store_ingest_sharded_bitwise(dm):
    """``MomentStore.ingest`` with a data mesh: accumulators AND the
    refreshed panel are bitwise the serial store's after the same
    aligned ingests (the init-seeded ordered fold)."""
    n_blk = 2 * ROW_BLOCK
    d = _data(SPECS[0])
    sids = jax.random.randint(jax.random.PRNGKey(9), (N,), 0, 3)
    cfg = dataclasses.replace(_CFG, nuisance_t="ridge",
                              discrete_treatment=False, cate_features=1)
    spec = SweepSpec(n_segments=3, columns=(("dml", cfg),))
    serial = MomentStore(spec, n_features=d.X.shape[1], key=_FIT_KEY)
    shard = MomentStore(spec, n_features=d.X.shape[1], key=_FIT_KEY,
                        data_mesh=dm)
    for lo in (0, n_blk):  # two ingests, both on row_block boundaries
        blk = dict(X=d.X[lo:lo + n_blk], y=d.y[lo:lo + n_blk],
                   t=d.t[lo:lo + n_blk],
                   segment_ids=sids[lo:lo + n_blk])
        serial.ingest(**blk)
        shard.ingest(**blk)
    for c1, c2 in zip(serial._cols, shard._cols):
        _assert_trees_equal(c1.state, c2.state, "accumulators")
    p1, p2 = serial.refresh(), shard.refresh()
    for c1, c2 in zip(p1.columns, p2.columns):
        assert not (c1.failed or c2.failed)
        np.testing.assert_array_equal(np.asarray(c1.thetas),
                                      np.asarray(c2.thetas))


# ---------------------------------------------------------------------------
# The job API.
# ---------------------------------------------------------------------------

def test_job_submit_blocking_matches_sweep(dm):
    """``block=True``: deterministic inline run — same panel bits as a
    direct ``sweep`` call, one "column" event per column, bracketed by
    submitted/done."""
    kw = _sweep_kw()
    spec = SweepSpec(n_segments=3, columns=(("dml", _CFG),))
    direct = sweep(spec, data_mesh=dm, **kw)
    jm = JobManager()
    job = jm.submit(spec, block=True, data_mesh=dm, **kw)
    st = job.status()
    assert st["status"] == "done"
    assert st["columns_done"] == 1 and st["columns_failed"] == 0
    actions = [e.action for e in job.events_since(0)]
    assert actions == ["submitted", "column", "done"]
    panel = job.result()
    np.testing.assert_array_equal(np.asarray(direct.columns[0].thetas),
                                  np.asarray(panel.columns[0].thetas))


def test_job_background_subscribe(dm):
    """A threaded job: ``subscribe`` yields every event in order and
    terminates when the job settles; ``wait`` unblocks."""
    kw = _sweep_kw()
    spec = SweepSpec(n_segments=2, columns=(("dml", _CFG),))
    jm = JobManager()
    job = jm.submit(spec, data_mesh=dm, **kw)
    events = list(job.subscribe())
    assert job.wait(timeout=60)
    assert [e.action for e in events] == ["submitted", "column", "done"]
    assert job.result(timeout=5) is not None
    assert jm.status(job.job_id)["status"] == "done"


@pytest.mark.slow
def test_two_process_smoke_best_effort():
    """The real ``jax.distributed`` two-process launcher: PASS where
    the platform supports multi-process CPU collectives, pytest-SKIP
    where it doesn't (e.g. 0.4.x CPU: "Multiprocess computations
    aren't implemented") — never a hard failure for a platform gap."""
    from repro.launch.dist_smoke import run_smoke

    verdict = run_smoke(timeout=150)
    assert verdict != "FAIL", "two-process result diverged from reference"
    if verdict != "OK":
        pytest.skip(verdict)


def test_job_failure_surfaces():
    """A sweep that cannot even start marks the job failed; ``result``
    re-raises."""
    kw = _sweep_kw()
    spec = SweepSpec(n_segments=3, columns=(("dml", _CFG),))
    jm = JobManager()
    job = jm.submit(spec, block=True, mode="no_such_mode", **kw)
    assert job.status()["status"] == "failed"
    with pytest.raises(Exception):
        job.result()
