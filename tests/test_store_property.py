"""Property-based certification (hypothesis) of the store's fixed-order
block-fold contract: ANY partition of the rows into ingest blocks on
``row_block`` boundaries — including empty blocks and the degenerate
single-block partition — yields bitwise-identical accumulators and a
bitwise-identical refreshed panel at the canonical row-blocked shapes.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import numpy as np  # noqa: E402

import jax  # noqa: E402

from repro.config import CausalConfig  # noqa: E402
from repro.data.causal_dgp import make_causal_data  # noqa: E402
from repro.store import MomentStore  # noqa: E402
from repro.sweep.spec import SweepSpec  # noqa: E402

N, E, P, R = 1024, 3, 4, 256
_CFG = CausalConfig(n_folds=2, inference="none", row_block=R,
                    nuisance_t="ridge", discrete_treatment=False)
_SPEC = SweepSpec(n_segments=E, columns=(("dml", _CFG),))
_KEY = jax.random.PRNGKey(5)

_DATA = make_causal_data(jax.random.PRNGKey(21), N, P, effect=1.2,
                         discrete_treatment=False)
_SIDS = jax.random.randint(jax.random.PRNGKey(2), (N,), 0, E)


def _build(bounds):
    store = MomentStore(_SPEC, n_features=P, key=_KEY)
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        store.ingest(X=_DATA.X[lo:hi], y=_DATA.y[lo:hi], t=_DATA.t[lo:hi],
                     segment_ids=_SIDS[lo:hi])
    return store


_FULL = _build([0, N])
_FULL_PANEL = _FULL.refresh()
_FULL_STATE = {k: np.asarray(v)
               for k, v in jax.tree_util.tree_flatten_with_path(
                   _FULL.state_dict())[0]}


# partitions: sorted R-aligned cut points, possibly repeated (repeats
# are zero-row ingest blocks — the empty-block edge case); the empty
# cut list is the single-block partition.
_cuts = st.lists(st.integers(min_value=1, max_value=N // R - 1),
                 min_size=0, max_size=6).map(
                     lambda ks: sorted(R * k for k in ks))


@settings(max_examples=12, deadline=None)
@given(_cuts)
def test_any_aligned_partition_is_bitwise(cuts):
    store = _build([0] + cuts + [N])
    assert store.aligned
    flat = jax.tree_util.tree_flatten_with_path(store.state_dict())[0]
    for path, leaf in flat:
        np.testing.assert_array_equal(np.asarray(leaf), _FULL_STATE[path])
    panel = store.refresh()
    col, ref = panel.columns[0], _FULL_PANEL.columns[0]
    np.testing.assert_array_equal(np.asarray(col.thetas),
                                  np.asarray(ref.thetas))
    np.testing.assert_array_equal(np.asarray(col.ses), np.asarray(ref.ses))
    np.testing.assert_array_equal(np.asarray(panel.counts),
                                  np.asarray(_FULL_PANEL.counts))
