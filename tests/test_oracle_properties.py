"""Oracle property tests: hypothesis strategies draw linear-Gaussian
(and IV compliance) DGPs whose ATE/LATE is known in closed form, and
every estimator must recover the truth — DML and OrthoIV calibrated
against their OWN reported stderr (the oracle property: the point
estimate lands within a few of its claimed standard errors of the
closed-form estimand, whatever the drawn effect/confounding).

The nominal-coverage Monte-Carlo grid (slow tier, nightly) checks the
bootstrap CIs of both families at the 90% level over seeded studies.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.config import CausalConfig  # noqa: E402
from repro.core.dml import DML  # noqa: E402
from repro.core.drlearner import DRLearner  # noqa: E402
from repro.core.iv import OrthoIV  # noqa: E402
from repro.core.metalearners import t_learner  # noqa: E402
from repro.data.causal_dgp import make_causal_data, make_iv_data  # noqa: E402

SETTINGS = dict(max_examples=8, deadline=None)


@settings(**SETTINGS)
@given(effect=st.floats(-2.0, 2.0), conf=st.floats(0.0, 1.5),
       seed=st.integers(0, 99))
def test_dml_recovers_linear_gaussian_ate(effect, conf, seed):
    """Continuous-treatment partially-linear DGP: the DML estimand IS
    the drawn effect, exactly."""
    d = make_causal_data(jax.random.PRNGKey(seed), 2500, 5,
                         effect=effect, confounding_strength=conf,
                         discrete_treatment=False)
    cfg = CausalConfig(n_folds=3, discrete_treatment=False,
                       nuisance_t="ridge", inference="none")
    res = DML(cfg).fit(d.y, d.t, d.X, key=jax.random.PRNGKey(seed + 1))
    se = float(res.stderr[0])
    assert abs(res.ate - effect) < 5 * se + 0.02, (res.ate, effect, se)


@settings(**SETTINGS)
@given(effect=st.floats(-1.5, 2.0), compliance=st.floats(0.4, 0.9),
       seed=st.integers(0, 99))
def test_orthoiv_recovers_late(effect, compliance, seed):
    """Binary-instrument compliance DGP: complier status independent of
    X, so the LATE equals the drawn effect in closed form — and the
    unobserved confounder guarantees the naive estimand differs."""
    d = make_iv_data(jax.random.PRNGKey(seed), 3000, 5, effect=effect,
                     compliance=compliance)
    cfg = CausalConfig(n_folds=3, inference="none")
    res = OrthoIV(cfg).fit(d.y, d.t, d.z, d.X,
                           key=jax.random.PRNGKey(seed + 1))
    se = float(res.stderr[0])
    assert abs(res.late - d.true_late) < 5 * se + 0.05, \
        (res.late, d.true_late, se)
    assert not res.diagnostics.weak_instrument


@settings(**SETTINGS)
@given(effect=st.floats(-1.5, 1.5), seed=st.integers(0, 99))
def test_dr_and_tlearner_recover_ate(effect, seed):
    d = make_causal_data(jax.random.PRNGKey(seed), 3000, 5,
                         effect=effect)
    key = jax.random.PRNGKey(seed + 1)
    dr = DRLearner(CausalConfig(n_folds=3, inference="none")).fit(
        d.y, d.t, d.X, key=key)
    assert abs(dr.ate - effect) < 5 * dr.stderr + 0.1
    tl = t_learner(d.y, d.t, d.X, key=key)
    assert abs(tl.ate - effect) < 0.25


@settings(**SETTINGS)
@given(seed=st.integers(0, 999), n=st.sampled_from([800, 1100]),
       rb=st.sampled_from([128, 257]))
def test_iv_gram_blocked_strategies_bitwise_equal(seed, n, rb):
    """The moments contract as a property: chunked ≡ whole for ANY
    drawn data and any (divisible or not) block size."""
    from repro.core import moments
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    ry = jax.random.normal(ks[0], (n,))
    rt = jax.random.normal(ks[1], (n,))
    rz = jax.random.normal(ks[2], (n,))
    phi = jax.random.normal(ks[3], (n, 2))
    w = jax.random.exponential(ks[4], (n,))
    a = moments.iv_gram(ry, rt, rz, phi, w, row_block=rb,
                        strategy="chunked")
    b = moments.iv_gram(ry, rt, rz, phi, w, row_block=rb,
                        strategy="whole")
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    m_a = moments.iv_meat(ry, rt, rz, phi, jnp.asarray([1.0, -0.5]),
                          w=w, row_block=rb, strategy="chunked")
    m_b = moments.iv_meat(ry, rt, rz, phi, jnp.asarray([1.0, -0.5]),
                          w=w, row_block=rb, strategy="whole")
    np.testing.assert_array_equal(np.asarray(m_a), np.asarray(m_b))


# ---------------------------------------------------------------------------
# Nominal CI coverage (slow tier -> nightly): seeded Monte-Carlo grid.
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_dml_bootstrap_ci_nominal_coverage():
    """90% percentile CI over 12 independent studies: exact binomial
    12/12 at nominal .90 has p≈.28; >=8 is a loose floor."""
    covered = 0
    trials = 12
    for s in range(trials):
        d = make_causal_data(jax.random.PRNGKey(100 + s), 1500, 4,
                             effect=1.0)
        cfg = CausalConfig(n_folds=3, n_bootstrap=48, alpha=0.10)
        res = DML(cfg).fit(d.y, d.t, d.X,
                           key=jax.random.PRNGKey(1000 + s))
        lo, hi = res.ate_interval()
        covered += int(lo <= 1.0 <= hi)
    assert covered >= 8, f"DML coverage {covered}/{trials} at nominal .90"


@pytest.mark.slow
def test_orthoiv_bootstrap_ci_nominal_coverage():
    """IV CIs need more data/replicates to calibrate than DML's (the
    2SLS ratio is noisier): at n=2500/compliance=.8/B=64 the measured
    grid covers 11/12 at nominal .90; >=8 is the same loose floor."""
    covered = 0
    trials = 12
    for s in range(trials):
        d = make_iv_data(jax.random.PRNGKey(200 + s), 2500, 4,
                         effect=1.0, compliance=0.8)
        cfg = CausalConfig(n_folds=3, n_bootstrap=64, alpha=0.10)
        res = OrthoIV(cfg).fit(d.y, d.t, d.z, d.X,
                               key=jax.random.PRNGKey(2000 + s))
        lo, hi = res.late_interval()
        covered += int(lo <= d.true_late <= hi)
    assert covered >= 8, f"IV coverage {covered}/{trials} at nominal .90"
