"""Cross-estimator conformance harness: the estimator REGISTRY.

Every estimator in the catalogue (DML, DRLearner, the S/T/X
metalearners, OrthoIV, DRIV) registers an ``EstimatorSpec`` here, and
``tests/test_conformance.py`` runs ONE parametrized suite over the
registry — replacing the per-module copy-pasted variants that used to
live in test_dml.py / test_inference.py / test_moments.py:

  * serial ≡ vmap executor bit-identity per bootstrap replicate, at
    each estimator's canonical bit-identity shape (bit-identity is
    shape-dependent — XLA retiles the n-contraction under fusion — so
    the contract is pinned at canonical shapes: whole-array for the
    p_phi = 1 DML legacy path, row-blocked for everything wider and
    for the IV family, whose moments always carry the scan's fusion
    barrier at the canonical shape);
  * row_block invariance: chunked ≡ whole blocked evaluation of the
    SAME row_block is exactly equal (including non-divisible n), and
    row_block = 0 vs R agrees to float-reassociation tolerance;
  * config round-trip: dataclasses.asdict -> CausalConfig(**d)
    reproduces the config AND a bit-identical fit;
  * truth recovery: every estimator lands near its DGP's known
    ATE/LATE (a loose sanity floor; the tight statistical assertions
    live in the per-estimator test modules and the oracle suite).

This module is deliberately NOT named test_*: pytest collects only
``test_conformance.py``, which imports SPECS from here.  Adding an
estimator = appending one spec; the whole certification suite applies
automatically.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.config import CausalConfig
from repro.core.dml import DML
from repro.core.drlearner import DRLearner
from repro.core.iv import DRIV, OrthoIV
from repro.core.metalearners import s_learner, t_learner, x_learner
from repro.core.nuisance import make_logistic, make_ridge
from repro.data.causal_dgp import make_causal_data, make_iv_data

# Non-divisible on purpose: n % ROW_BLOCK != 0, so the zero-row padding
# of the blocked decomposition is exercised by every chunked≡whole
# assertion.
N_CONF = 1100
ROW_BLOCK = 256
EFFECT = 1.2


@dataclasses.dataclass(frozen=True)
class EstimatorSpec:
    """One estimator's registration with the conformance suite.

    fit(data, cfg, key)   -> pytree of jnp arrays (the full estimate)
    point(tree)           -> float ATE/LATE read off that pytree
    boot(data, cfg, key, executor, B) -> InferenceResult, or None when
                          the estimator has no replicate inference
                          (metalearners)
    boot_cfg              the canonical bit-identity config for the
                          serial ≡ vmap check (None -> skip)
    rb_tol                |theta(rb=0) - theta(rb=R)| tolerance for the
                          cross-setting invariance check
    """

    name: str
    make_data: Callable[[jax.Array], Any]
    fit: Callable[[Any, CausalConfig, jax.Array], Any]
    point: Callable[[Any], float]
    truth: Callable[[Any], float]
    base_cfg: CausalConfig
    boot: Optional[Callable[..., Any]] = None
    boot_cfg: Optional[CausalConfig] = None
    truth_tol: float = 0.25
    rb_tol: float = 2e-3


def _conf_data(key):
    return make_causal_data(key, N_CONF, 6, effect=EFFECT)


def _conf_iv_data(key):
    return make_iv_data(key, N_CONF, 6, effect=EFFECT, compliance=0.75)


def _boot_via_inference(fit):
    """Estimators whose result exposes .inference(): one adapter."""

    def boot(data, cfg, key, executor, n_replicates):
        res = fit(data, cfg, key)
        return res.inference(executor=executor,
                             n_bootstrap=n_replicates)

    return boot


# -- DML --------------------------------------------------------------------

def _fit_dml(data, cfg, key):
    return DML(cfg).fit(data.y, data.t, data.X, key=key)


# -- DRLearner --------------------------------------------------------------

def _fit_dr(data, cfg, key):
    return DRLearner(cfg).fit(data.y, data.t, data.X, key=key)


# -- metalearners (nuisances built from the cfg so row_block/strategy
#    thread through; no replicate inference) -------------------------------

def _meta_nuisances(cfg):
    reg = make_ridge(cfg.ridge_lambda, row_block=cfg.row_block,
                     strategy=cfg.row_block_strategy)
    clf = make_logistic(cfg.ridge_lambda, cfg.newton_iters,
                        row_block=cfg.row_block,
                        strategy=cfg.row_block_strategy)
    return reg, clf


def _fit_s(data, cfg, key):
    reg, _ = _meta_nuisances(cfg)
    return s_learner(data.y, data.t, data.X, nuisance=reg, key=key)


def _fit_t(data, cfg, key):
    reg, _ = _meta_nuisances(cfg)
    return t_learner(data.y, data.t, data.X, nuisance=reg, key=key)


def _fit_x(data, cfg, key):
    reg, clf = _meta_nuisances(cfg)
    return x_learner(data.y, data.t, data.X, nuisance=reg,
                     propensity=clf, key=key)


# -- orthogonal-IV family ---------------------------------------------------

def _fit_orthoiv(data, cfg, key):
    return OrthoIV(cfg).fit(data.y, data.t, data.z, data.X, key=key)


def _fit_driv(data, cfg, key):
    return DRIV(cfg).fit(data.y, data.t, data.z, data.X, key=key)


_CFG = CausalConfig(n_folds=3, inference="none")
_CFG_BOOT_RB = CausalConfig(n_folds=3, n_bootstrap=4,
                            row_block=ROW_BLOCK)

SPECS = (
    EstimatorSpec(
        name="dml",
        make_data=_conf_data,
        fit=_fit_dml,
        point=lambda r: r.ate,
        truth=lambda d: d.true_ate,
        base_cfg=_CFG,
        boot=_boot_via_inference(_fit_dml),
        # the uniform conformance contract certifies the row-blocked
        # path (its lax.scan is a fusion barrier, so the invariant
        # einsum vocabulary survives batching at any shape); the
        # legacy whole-array p_phi=1 contract stays pinned at its
        # PR-1 canonical shape in tests/test_inference.py
        boot_cfg=_CFG_BOOT_RB,
    ),
    EstimatorSpec(
        name="dml_p2_rb",
        make_data=_conf_data,
        fit=_fit_dml,
        point=lambda r: r.ate,
        truth=lambda d: d.true_ate,
        base_cfg=dataclasses.replace(_CFG, cate_features=2),
        boot=_boot_via_inference(_fit_dml),
        # wider bases hold bit-identity on the row-blocked path only
        boot_cfg=dataclasses.replace(_CFG_BOOT_RB, cate_features=2),
        truth_tol=0.4,   # theta[0] is the x=0 effect under this basis
    ),
    EstimatorSpec(
        name="dml_loo",
        make_data=_conf_data,
        fit=_fit_dml,
        point=lambda r: r.ate,
        truth=lambda d: d.true_ate,
        base_cfg=dataclasses.replace(_CFG, engine="parallel_loo"),
    ),
    EstimatorSpec(
        name="drlearner",
        make_data=_conf_data,
        fit=_fit_dr,
        point=lambda r: r.ate,
        truth=lambda d: d.true_ate,
        base_cfg=_CFG,
        boot=_boot_via_inference(_fit_dr),
        boot_cfg=_CFG_BOOT_RB,
    ),
    EstimatorSpec(
        name="s_learner",
        make_data=_conf_data,
        fit=_fit_s,
        point=lambda r: r.ate,
        truth=lambda d: d.true_ate,
        base_cfg=_CFG,
    ),
    EstimatorSpec(
        name="t_learner",
        make_data=_conf_data,
        fit=_fit_t,
        point=lambda r: r.ate,
        truth=lambda d: d.true_ate,
        base_cfg=_CFG,
    ),
    EstimatorSpec(
        name="x_learner",
        make_data=_conf_data,
        fit=_fit_x,
        point=lambda r: r.ate,
        truth=lambda d: d.true_ate,
        base_cfg=_CFG,
    ),
    EstimatorSpec(
        name="orthoiv",
        make_data=_conf_iv_data,
        fit=_fit_orthoiv,
        point=lambda r: r.late,
        truth=lambda d: d.true_late,
        base_cfg=_CFG,
        boot=_boot_via_inference(_fit_orthoiv),
        boot_cfg=_CFG_BOOT_RB,
        truth_tol=0.35,  # IV variance at n=1100 is honest-to-goodness wide
    ),
    EstimatorSpec(
        name="orthoiv_p2_rb",
        make_data=_conf_iv_data,
        fit=_fit_orthoiv,
        point=lambda r: r.late,
        truth=lambda d: d.true_late,
        base_cfg=dataclasses.replace(_CFG, cate_features=2),
        boot=_boot_via_inference(_fit_orthoiv),
        boot_cfg=dataclasses.replace(_CFG_BOOT_RB, cate_features=2),
        truth_tol=0.5,
    ),
    EstimatorSpec(
        name="driv",
        make_data=_conf_iv_data,
        fit=_fit_driv,
        point=lambda r: r.late,
        truth=lambda d: d.true_late,
        base_cfg=_CFG,
        boot=_boot_via_inference(_fit_driv),
        boot_cfg=_CFG_BOOT_RB,
        truth_tol=0.35,
    ),
)

SPEC_IDS = tuple(s.name for s in SPECS)


def _to_tree(obj):
    """Recursively open dataclass results into plain dicts (skipping
    caches, configs and fit contexts) so tree_leaves reaches every
    nested array — results are NOT registered pytrees."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {f.name: _to_tree(getattr(obj, f.name))
                for f in dataclasses.fields(obj)
                if not f.name.startswith("_")
                and f.name not in ("cfg", "fit_ctx")}
    return obj


def tree_arrays(tree) -> tuple:
    """The floating jnp-array leaves of an estimator result, for
    exact-equality comparison across execution strategies."""
    return tuple(leaf for leaf in jax.tree_util.tree_leaves(_to_tree(tree))
                 if isinstance(leaf, (jax.Array, jnp.ndarray))
                 and jnp.issubdtype(leaf.dtype, jnp.floating))
