"""Integration: the end-to-end training driver learns the synthetic
stream, and the batched server produces the same tokens as an unbatched
greedy reference."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.config import ParallelConfig, TrainConfig
from repro.configs import get_config
from repro.data.lm_data import lm_batch
from repro.launch.serve import BatchServer, Request
from repro.launch.train import make_train_step
from repro.models.model import build_model
from repro.optim.adamw import adamw_init

# full-zoo / serving loops: the long tier (PR CI runs -m 'not slow')
pytestmark = pytest.mark.slow


def test_training_reduces_loss(key):
    cfg = dataclasses.replace(get_config("granite-3-2b-smoke"),
                              num_layers=2, vocab_size=97)
    model = build_model(cfg)
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=5, total_steps=150)
    step_fn = jax.jit(make_train_step(model, tcfg))
    params = model.init(key)
    opt = adamw_init(params)
    losses = []
    for s in range(150):
        batch = lm_batch(jax.random.fold_in(key, s), 8, 32, cfg.vocab_size)
        params, opt, m = step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
    # the 97-token bigram permutation needs ~50k tokens to crack; at
    # 256 tokens/step we assert a solid descent, not convergence
    assert losses[-1] < losses[0] - 0.4, (losses[0], losses[-1])


def test_microbatched_step_matches_full_batch(key):
    """Gradient accumulation is numerically the same step."""
    cfg = dataclasses.replace(get_config("granite-3-2b-smoke"),
                              num_layers=1, vocab_size=97)
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=1, total_steps=10)
    m1 = build_model(cfg, ParallelConfig(microbatch=1))
    m4 = build_model(cfg, ParallelConfig(microbatch=4))
    params = m1.init(key)
    opt = adamw_init(params)
    batch = lm_batch(key, 8, 32, cfg.vocab_size)
    p1, _, met1 = jax.jit(make_train_step(m1, tcfg))(params, opt, batch)
    p4, _, met4 = jax.jit(make_train_step(m4, tcfg))(params, opt, batch)
    np.testing.assert_allclose(float(met1["loss"]), float(met4["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_batch_server_matches_manual_greedy(key):
    cfg = get_config("granite-3-2b-smoke")
    model = build_model(cfg)
    params = model.init(key)
    server = BatchServer(model, params, max_seq=64)
    prompts = [jax.random.randint(jax.random.fold_in(key, i), (8,), 0,
                                  cfg.vocab_size) for i in range(2)]
    # same-length prompts: wave batching must equal per-request greedy
    outs = server.serve_wave([Request(p, max_new_tokens=5) for p in prompts])
    for i, p in enumerate(prompts):
        solo = server.serve_wave([Request(p, max_new_tokens=5)])
        assert outs[i].tokens == solo[0].tokens, i


def test_compressed_training_still_learns(key):
    cfg = dataclasses.replace(get_config("granite-3-2b-smoke"),
                              num_layers=1, vocab_size=97)
    model = build_model(cfg, ParallelConfig(gradient_compression="int8"))
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=5, total_steps=120)
    step_fn = jax.jit(make_train_step(model, tcfg))
    params = model.init(key)
    opt = adamw_init(params)
    losses = []
    for s in range(120):
        batch = lm_batch(jax.random.fold_in(key, s), 8, 32, cfg.vocab_size)
        params, opt, m = step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3
