"""repro.store certification: incremental ingest against the full
rebuild it replaces.

Contracts:
  * ``ingest(block1); ingest(block2); refresh()`` is BITWISE identical
    to one ingest of the concatenated rows at the canonical row-blocked
    shapes (every split on a ``row_block`` boundary), for EVERY
    store-supported registry estimator — accumulators and panel alike;
  * every registry estimator outside the ``store_supported`` gate
    fault-isolates as a failed column with the gate's reason, without
    poisoning supported neighbors;
  * the refreshed estimates match a float64 dense reference computed
    from the store's own fold assignment (tolerance — the store is a
    different execution of the same estimator, like the segmented
    sweep);
  * empty ingests are exact no-ops; fold assignment is streaming-stable;
    misaligned ingests flip ``store.aligned``;
  * strategy="pallas" ingest is bitwise partition-invariant within the
    scatter lowering and tolerance-equal to chunked;
  * versioned snapshots through ``checkpoint.CheckpointManager`` roll
    back to bit-identical panels;
  * ingest/refresh emit obs spans and metrics, and tracing changes no
    bits.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.config import CausalConfig
from repro.core.registry import ROW_BLOCK, SPECS, get_spec
from repro.data.causal_dgp import make_causal_data, make_iv_data
from repro.obs.trace import Tracer
from repro.store import MomentStore, store_supported
from repro.sweep.spec import SweepSpec

N, E, P = 1100, 5, 6
_SKEY = jax.random.PRNGKey(11)

ALL_ESTIMATORS = tuple(s.name for s in SPECS)
SUPPORTED = ("dml", "dml_p2_rb", "dml_loo", "orthoiv", "orthoiv_p2_rb")
UNSUPPORTED = tuple(n for n in ALL_ESTIMATORS if n not in SUPPORTED)


def _cfg(name: str) -> CausalConfig:
    """The canonical store config: all-ridge nuisances, continuous
    treatment, blocked rows (the bitwise-contract regime)."""
    return CausalConfig(
        n_folds=3, inference="none", row_block=ROW_BLOCK,
        nuisance_t="ridge", nuisance_z="ridge", discrete_treatment=False,
        cate_features=2 if "p2" in name else 1)


@pytest.fixture(scope="module")
def data():
    return make_causal_data(jax.random.PRNGKey(42), N, P, effect=1.2,
                            discrete_treatment=False)


@pytest.fixture(scope="module")
def iv_data():
    return make_iv_data(jax.random.PRNGKey(42), N, P, effect=1.2,
                        compliance=0.75)


@pytest.fixture(scope="module")
def sids():
    return jax.random.randint(jax.random.PRNGKey(9), (N,), 0, E)


def _arrays(name, data, iv_data, sids):
    d = iv_data if get_spec(name).needs_instrument else data
    kw = dict(X=d.X, y=d.y, t=d.t, segment_ids=sids)
    if get_spec(name).needs_instrument:
        kw["z"] = d.z
    return kw


def _sliced(kw, lo, hi):
    return {k: v[lo:hi] for k, v in kw.items()}


def _ingest_partition(spec, kw, cuts, key=_SKEY, tracer=None):
    """Build a store and ingest ``kw`` split at row indices ``cuts``."""
    store = MomentStore(spec, n_features=P, key=key, tracer=tracer)
    bounds = [0] + list(cuts) + [kw["X"].shape[0]]
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        store.ingest(**_sliced(kw, lo, hi))
    return store


def _assert_panels_equal(pa, pb):
    for ca, cb in zip(pa.columns, pb.columns):
        assert ca.error == cb.error
        if ca.error is None:
            np.testing.assert_array_equal(np.asarray(ca.thetas),
                                          np.asarray(cb.thetas))
            np.testing.assert_array_equal(np.asarray(ca.ses),
                                          np.asarray(cb.ses))
            np.testing.assert_array_equal(np.asarray(ca.ates),
                                          np.asarray(cb.ates))
    np.testing.assert_array_equal(np.asarray(pa.counts),
                                  np.asarray(pb.counts))


def _assert_states_equal(sa, sb):
    fa, fb = sa.state_dict(), sb.state_dict()
    assert set(fa) == set(fb)
    for k in fa:
        la = jax.tree_util.tree_leaves(fa[k])
        lb = jax.tree_util.tree_leaves(fb[k])
        for a, b in zip(la, lb):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# The bitwise ingest contract, certified for every registry estimator.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", SUPPORTED)
def test_ingest_partition_bitwise(name, data, iv_data, sids):
    kw = _arrays(name, data, iv_data, sids)
    spec = SweepSpec(n_segments=E, columns=((name, _cfg(name)),))
    full = _ingest_partition(spec, kw, ())
    # splits on ROW_BLOCK boundaries — the canonical row-blocked shapes
    inc = _ingest_partition(spec, kw, (2 * ROW_BLOCK,))
    _assert_states_equal(full, inc)
    _assert_panels_equal(full.refresh(), inc.refresh())
    assert full.aligned and inc.aligned
    # a three-way split, including an uneven final remainder block
    inc3 = _ingest_partition(spec, kw, (ROW_BLOCK, 3 * ROW_BLOCK))
    _assert_states_equal(full, inc3)
    _assert_panels_equal(full.refresh(), inc3.refresh())


@pytest.mark.parametrize("name", UNSUPPORTED)
def test_unsupported_estimators_gated(name, data, iv_data, sids):
    ok, reason = store_supported(get_spec(name), _cfg(name))
    assert not ok and "store" in reason
    # the failed column fault-isolates; the supported neighbor is intact
    spec = SweepSpec(n_segments=E,
                     columns=(("dml", _cfg("dml")), (name, _cfg(name))))
    kw = _arrays(name, data, iv_data, sids)
    if "z" not in kw:  # always carry z so instrumented neighbors load
        kw["z"] = iv_data.z
    store = _ingest_partition(spec, kw, (2 * ROW_BLOCK,))
    panel = store.refresh()
    assert panel.columns[1].failed and "store" in panel.columns[1].error
    assert panel.columns[0].error is None
    assert bool(panel.columns[0].ok(panel.counts).all())
    ref = _ingest_partition(
        SweepSpec(n_segments=E, columns=(("dml", _cfg("dml")),)), kw, ())
    np.testing.assert_array_equal(np.asarray(panel.columns[0].thetas),
                                  np.asarray(ref.refresh().columns[0].thetas))


def test_logistic_config_gated():
    cfg = CausalConfig(n_folds=3, inference="none")  # default: logistic t
    ok, reason = store_supported(get_spec("dml"), cfg)
    assert not ok and "store" in reason


# ---------------------------------------------------------------------------
# Edge cases: empty blocks, misalignment, fold stability.
# ---------------------------------------------------------------------------

def test_empty_ingest_is_exact_noop(data, sids):
    spec = SweepSpec(n_segments=E, columns=(("dml", _cfg("dml")),))
    kw = _arrays("dml", data, None, sids)
    a = _ingest_partition(spec, kw, ())
    b = MomentStore(spec, n_features=P, key=_SKEY)
    b.ingest(**_sliced(kw, 0, 0))                       # leading empty
    b.ingest(**_sliced(kw, 0, 2 * ROW_BLOCK))
    b.ingest(**_sliced(kw, N, N))                       # interior empty
    b.ingest(**_sliced(kw, 2 * ROW_BLOCK, N))
    b.ingest(**_sliced(kw, 0, 0))                       # trailing empty
    _assert_states_equal(a, b)
    _assert_panels_equal(a.refresh(), b.refresh())
    assert b.n_ingests == 5 and b.version == 5 and b.n_total == N


def test_misaligned_ingest_flags_tolerance_regime(data, sids):
    spec = SweepSpec(n_segments=E, columns=(("dml", _cfg("dml")),))
    kw = _arrays("dml", data, None, sids)
    s = _ingest_partition(spec, kw, (300,))  # not a ROW_BLOCK multiple
    assert not s.aligned
    assert s.column_aligned == (False,)
    # still numerically the same estimator
    full = _ingest_partition(spec, kw, ())
    np.testing.assert_allclose(
        np.asarray(s.refresh().columns[0].thetas),
        np.asarray(full.refresh().columns[0].thetas),
        rtol=2e-4, atol=2e-4)


def test_alignment_is_per_column(tmp_path, data, sids):
    # one misaligned ingest into ONE column must not downgrade the
    # whole store's reported regime: a column whose row_block divides
    # every ingest boundary stays bitwise-certified next to a
    # misaligned neighbor
    cfg_a = _cfg("dml")                                   # rb = ROW_BLOCK
    cfg_b = dataclasses.replace(_cfg("dml"), row_block=3 * ROW_BLOCK // 4)
    spec = SweepSpec(n_segments=E,
                     columns=(("dml", cfg_a), ("dml", cfg_b)))
    kw = _arrays("dml", data, None, sids)
    # split at 2*ROW_BLOCK: a boundary for cfg_a, misaligned for cfg_b
    s = _ingest_partition(spec, kw, (2 * ROW_BLOCK,))
    assert s.column_aligned == (True, False)
    assert not s.aligned                     # rollup reports any-degraded
    panel = s.refresh()
    assert panel.columns[0].aligned is True
    assert panel.columns[1].aligned is False
    assert "misaligned" in panel.summary()
    # the aligned column keeps the bitwise contract vs a one-shot build
    full = _ingest_partition(spec, kw, ())
    assert full.column_aligned == (True, True)
    np.testing.assert_array_equal(
        np.asarray(panel.columns[0].thetas),
        np.asarray(full.refresh().columns[0].thetas))
    # the misaligned neighbor is tolerance-equal, as before
    np.testing.assert_allclose(
        np.asarray(panel.columns[1].thetas),
        np.asarray(full.refresh().columns[1].thetas),
        rtol=2e-4, atol=2e-4)
    # per-column flags survive a save/restore round-trip via extras
    manager = CheckpointManager(str(tmp_path), keep_latest=4)
    s.save(manager)
    meta_extra = manager.restore(s.state_dict())[1]["extra"]
    assert meta_extra["column_aligned"] == [True, False]
    assert meta_extra["aligned"] is False
    restored = MomentStore(spec, n_features=P, key=_SKEY)
    restored.restore(manager)
    assert restored.column_aligned == (True, False)
    # unsupported columns report None (no alignment regime to certify)
    spec_u = SweepSpec(n_segments=E, columns=(
        ("dml", cfg_a), ("drlearner", _cfg("drlearner"))))
    u = _ingest_partition(spec_u, kw, ())
    assert u.column_aligned == (True, None)
    assert u.aligned


def test_fold_assignment_streaming_stable(data, sids):
    spec = SweepSpec(n_segments=E, columns=(("dml", _cfg("dml")),))
    store = MomentStore(spec, n_features=P, key=_SKEY)
    whole = np.asarray(store.fold_assignment(0, 0, N))
    head = np.asarray(store.fold_assignment(0, 0, 512))
    tail = np.asarray(store.fold_assignment(0, 512, N - 512))
    np.testing.assert_array_equal(whole, np.concatenate([head, tail]))
    k = _cfg("dml").n_folds
    assert set(np.unique(whole)) <= set(range(k))
    # every fold is populated at this n (sanity on the keyed draw)
    assert len(np.unique(whole)) == k


def test_zero_row_segment_flagged_not_crashed(data):
    sids0 = jnp.zeros((N,), jnp.int32)  # all rows in segment 0
    spec = SweepSpec(n_segments=3, columns=(("dml", _cfg("dml")),))
    store = MomentStore(spec, n_features=P, key=_SKEY)
    store.ingest(X=data.X, y=data.y, t=data.t, segment_ids=sids0)
    panel = store.refresh()
    col = panel.columns[0]
    assert np.isfinite(np.asarray(col.thetas)).all()
    ok = np.asarray(col.ok(panel.counts))
    assert ok[0] and not ok[1] and not ok[2]


# ---------------------------------------------------------------------------
# Tolerance certification against a float64 dense reference.
# ---------------------------------------------------------------------------

def _dense_reference(name, cfg, kw, folds):
    """Float64 single-pass reference on the store's fold assignment."""
    X = np.asarray(kw["X"], np.float64)
    y = np.asarray(kw["y"], np.float64)
    t = np.asarray(kw["t"], np.float64)
    z = np.asarray(kw["z"], np.float64) if "z" in kw else None
    sids = np.asarray(kw["segment_ids"])
    folds = np.asarray(folds)
    n, p = X.shape
    k, lam = cfg.n_folds, cfg.ridge_lambda
    xa = np.concatenate([X, np.ones((n, 1))], axis=1)
    pf = 1 if cfg.cate_features <= 1 else cfg.cate_features
    phi = (np.ones((n, 1)) if pf == 1 else
           np.concatenate([np.ones((n, 1)), X[:, :pf - 1]], axis=1))
    thetas = []
    iv = get_spec(name).needs_instrument
    for s in range(E):
        inseg = sids == s
        ry, rt = np.zeros(n), np.zeros(n)
        rz = np.zeros(n)
        for f in range(k):
            own = inseg & (folds == f)
            comp = inseg & (folds != f)
            nc = max(comp.sum(), 1)
            A = xa[comp].T @ xa[comp] / nc + lam * np.eye(p + 1)
            for target, out in ((y, ry), (t, rt)) + (
                    ((z, rz),) if iv else ()):
                beta = np.linalg.solve(A, xa[comp].T @ target[comp] / nc)
                out[own] = target[own] - xa[own] @ beta
        nseg = max(inseg.sum(), 1)
        zt = rt[inseg, None] * phi[inseg]
        if iv:
            zz = rz[inseg, None] * phi[inseg]
            a = zz.T @ zt + 1e-8 * nseg * np.eye(pf)
            thetas.append(np.linalg.solve(a, zz.T @ ry[inseg]))
        else:
            a = zt.T @ zt + 1e-8 * nseg * np.eye(pf)
            thetas.append(np.linalg.solve(a, zt.T @ ry[inseg]))
    return np.stack(thetas)


@pytest.mark.parametrize("name", ("dml", "dml_p2_rb", "orthoiv"))
def test_refresh_matches_dense_reference(name, data, iv_data, sids):
    cfg = _cfg(name)
    kw = _arrays(name, data, iv_data, sids)
    spec = SweepSpec(n_segments=E, columns=((name, cfg),))
    store = _ingest_partition(spec, kw, (2 * ROW_BLOCK,))
    got = np.asarray(store.refresh().columns[0].thetas)
    want = _dense_reference(name, cfg, kw, store.fold_assignment(0, 0, N))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_dml_recovers_effect(data, sids):
    spec = SweepSpec(n_segments=E, columns=(("dml", _cfg("dml")),))
    store = _ingest_partition(spec, _arrays("dml", data, None, sids), ())
    ates = np.asarray(store.refresh().columns[0].ates)
    assert np.all(np.abs(ates - data.true_ate) < 0.2)


# ---------------------------------------------------------------------------
# Pallas-strategy ingest (fused segment-outer kernels).
# ---------------------------------------------------------------------------

def test_pallas_ingest_partition_bitwise_and_tolerance(data, sids):
    from repro.kernels.seg_gram.ops import force_backend

    cfgp = CausalConfig(
        n_folds=3, inference="none", row_block=ROW_BLOCK,
        nuisance_t="ridge", discrete_treatment=False,
        row_block_strategy="pallas")
    spec = SweepSpec(n_segments=E, columns=(("dml", cfgp),))
    kw = _arrays("dml", data, None, sids)
    with force_backend("scatter"):
        full = _ingest_partition(spec, kw, ())
        inc = _ingest_partition(spec, kw, (2 * ROW_BLOCK,))
        _assert_states_equal(full, inc)
        _assert_panels_equal(full.refresh(), inc.refresh())
        theta_p = np.asarray(full.refresh().columns[0].thetas)
    chunked = _ingest_partition(
        SweepSpec(n_segments=E, columns=(("dml", _cfg("dml")),)), kw, ())
    np.testing.assert_allclose(
        theta_p, np.asarray(chunked.refresh().columns[0].thetas),
        rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Versioned snapshots (checkpoint/) — hot-swap and rollback.
# ---------------------------------------------------------------------------

def test_checkpoint_rollback_bitwise(tmp_path, data, sids):
    spec = SweepSpec(n_segments=E, columns=(("dml", _cfg("dml")),))
    kw = _arrays("dml", data, None, sids)
    manager = CheckpointManager(str(tmp_path), keep_latest=8)
    store = MomentStore(spec, n_features=P, key=_SKEY)
    store.ingest(**_sliced(kw, 0, 2 * ROW_BLOCK))
    v1 = store.save(manager)
    p1 = store.refresh()
    store.ingest(**_sliced(kw, 2 * ROW_BLOCK, N))
    v2 = store.save(manager)
    p2 = store.refresh()
    assert manager.latest_step() == v2 and v2 > v1
    assert not np.array_equal(np.asarray(p1.columns[0].thetas),
                              np.asarray(p2.columns[0].thetas))
    store.restore(manager, step=v1)  # rollback
    assert store.version == v1 and store.n_total == 2 * ROW_BLOCK
    _assert_panels_equal(store.refresh(), p1)
    store.restore(manager)  # hot-swap forward to latest
    _assert_panels_equal(store.refresh(), p2)
    # ingest continues correctly after a rollback round-trip
    store.restore(manager, step=v1)
    store.ingest(**_sliced(kw, 2 * ROW_BLOCK, N))
    _assert_panels_equal(store.refresh(), p2)


def test_checkpoint_provenance_mismatch_raises(tmp_path, data, sids):
    kw = _arrays("dml", data, None, sids)
    manager = CheckpointManager(str(tmp_path), keep_latest=8)
    a = MomentStore(SweepSpec(n_segments=E, columns=(("dml", _cfg("dml")),)),
                    n_features=P, key=_SKEY)
    a.ingest(**kw)
    a.save(manager)
    b = MomentStore(
        SweepSpec(n_segments=E, columns=(("dml_loo", _cfg("dml_loo")),)),
        n_features=P, key=_SKEY)
    with pytest.raises(ValueError, match="columns"):
        b.restore(manager)


# ---------------------------------------------------------------------------
# Observability: spans, metrics, and no-bit-perturbation.
# ---------------------------------------------------------------------------

def test_obs_spans_and_metrics(data, sids):
    spec = SweepSpec(n_segments=E, columns=(("dml", _cfg("dml")),))
    kw = _arrays("dml", data, None, sids)
    tracer = Tracer()
    traced = _ingest_partition(spec, kw, (2 * ROW_BLOCK,), tracer=tracer)
    p_traced = traced.refresh()
    names = [s.name for s in tracer.spans]
    assert names.count("store.ingest") == 2
    assert "store.refresh" in names
    snap = tracer.metrics.snapshot()["counters"]
    assert snap["store.ingests"] == 2
    assert snap["store.ingest.rows"] == N
    assert snap["store.refreshes"] == 1
    plain = _ingest_partition(spec, kw, (2 * ROW_BLOCK,))
    _assert_panels_equal(p_traced, plain.refresh())


def test_fallback_rung_counter():
    # fold_weighted_gram gained a fused builder (PR 10) so it no longer
    # exercises the pallas->chunked rung; a direct blocked_reduce with a
    # form seg_gram has no builder for still must count per-form.
    from repro.core import moments
    from repro.obs.metrics import default_registry

    c = default_registry().counter("seg_gram.fallback[store_custom_form]")
    before = c.value
    X = jnp.ones((64, 3), jnp.float32)
    moments.blocked_reduce(
        lambda xb: xb.T @ xb,
        (X,),
        row_block=16,
        strategy="pallas",
        form="store_custom_form",
    )
    assert c.value == before + 1
