"""Trip-count-aware HLO cost model vs XLA ground truth."""
import jax
import jax.numpy as jnp

from repro.launch import hlo_cost


def _compile(f, *shapes):
    return jax.jit(f).lower(*shapes).compile()


def test_scan_trip_count_multiplies_flops():
    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=10)
        return h

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = _compile(f, x, w)
    t = hlo_cost.analyze(c.as_text())
    expect = 2 * 128 * 256 * 256 * 10  # 10 matmuls
    assert t.unknown_trip_counts == 0
    assert abs(t.flops - expect) / expect < 0.02


def test_matches_xla_on_straightline():
    def g(x, w):
        h = x
        for _ in range(4):
            h = jnp.tanh(h @ w)
        return h

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = _compile(g, x, w)
    t = hlo_cost.analyze(c.as_text())
    xla = c.cost_analysis()
    if isinstance(xla, list):  # newer jax returns [dict]
        xla = xla[0]
    assert abs(t.flops - xla["flops"]) / xla["flops"] < 0.02


def test_nested_scan_trip_counts():
    def f(x, w):
        def outer(h, _):
            def inner(h2, _):
                return h2 @ w, None
            h2, _ = jax.lax.scan(inner, h, None, length=3)
            return h2, None
        h, _ = jax.lax.scan(outer, x, None, length=5)
        return h

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = _compile(f, x, w)
    t = hlo_cost.analyze(c.as_text())
    expect = 2 * 32 * 64 * 64 * 15  # 5 x 3 matmuls
    assert abs(t.flops - expect) / expect < 0.05


def test_dynamic_slice_charged_at_slice_size():
    def f(stack):
        def body(h, i):
            return h + jax.lax.dynamic_index_in_dim(
                stack, i, axis=0, keepdims=False), None
        h, _ = jax.lax.scan(body, jnp.zeros((256, 256)),
                            jnp.arange(100, dtype=jnp.int32))
        return h

    stack = jax.ShapeDtypeStruct((100, 256, 256), jnp.float32)
    c = _compile(f, stack)
    t = hlo_cost.analyze(c.as_text())
    # each of the 100 iterations touches ~3 slices' worth of bytes, not
    # the 26 MB stack; total must be far below 100 x full-stack
    full_stack_each = 100 * 100 * 256 * 256 * 4
    assert t.bytes < 0.1 * full_stack_each


def test_shape_parsing():
    assert hlo_cost._size_bytes("f32[8,16]{1,0}") == 512
    assert hlo_cost._size_bytes("bf16[4]") == 8
    assert hlo_cost._size_bytes("pred[2,2]") == 4
    assert hlo_cost._size_bytes("(f32[8], bf16[8])") == 48
    assert hlo_cost._numel("f32[3,5]") == 15
