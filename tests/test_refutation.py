"""NEXUS validation suite: refuters behave as designed."""
import jax
import pytest

from repro.config import CausalConfig
from repro.core import refutation
from repro.core.dml import DML
from repro.data.causal_dgp import make_causal_data


@pytest.fixture(scope="module")
def setup():
    data = make_causal_data(jax.random.PRNGKey(21), 6000, 10, effect=2.0)
    cfg = CausalConfig(n_folds=3, engine="parallel")
    est = DML(cfg)
    base = est.fit(data.y, data.t, data.X, key=jax.random.PRNGKey(0))
    return data, est, base


def test_placebo_collapses_to_zero(setup):
    data, est, base = setup
    rep = refutation.placebo_treatment(est, data.y, data.t, data.X,
                                       original_ate=base.ate, n_reps=2)
    assert abs(rep.mean) < 0.2 * abs(base.ate)
    assert rep.passed


def test_random_common_cause_stable(setup):
    data, est, base = setup
    rep = refutation.random_common_cause(est, data.y, data.t, data.X,
                                         original_ate=base.ate, n_reps=2)
    assert abs(rep.mean - base.ate) < 0.1 * abs(base.ate)
    assert rep.passed


def test_subset_stable(setup):
    data, est, base = setup
    rep = refutation.data_subset(est, data.y, data.t, data.X,
                                 original_ate=base.ate, n_reps=2)
    assert rep.passed


@pytest.mark.slow
def test_run_all_report(setup):
    data, _, _ = setup
    reports = refutation.run_all(CausalConfig(n_folds=3), data.y, data.t,
                                 data.X)
    assert len(reports) == 3
    for r in reports:
        assert r.row()
        assert r.passed, r.row()
