"""repro.serve_effects certification: the online serving layer.

Contracts:
  * batched wave scoring (pad-and-mask, any wave shape in the ladder)
    is BITWISE identical to per-request unbatched scoring, and padded
    slots are certified no-ops (flagged zeros that cannot perturb real
    rows);
  * every request scores against exactly ONE panel version — a
    hot-swap between waves changes the served estimates without
    dropping or mixing in-flight waves, and rollback re-installs the
    previous version bit-for-bit;
  * the ingest → refresh → save → serve edge: a server loads panel
    versions from ``MomentStore`` checkpoints (provenance-checked) and
    swaps between them;
  * failed (``ok=False``) cells and out-of-range segment ids return
    flagged responses, never NaN;
  * edge cases: empty wave, single request, queue overflow
    backpressure;
  * observability is per-server (never the process-global registry):
    latency/occupancy histograms fill, waves emit obs spans, and
    tracing changes no bits.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.config import CausalConfig
from repro.core.registry import ROW_BLOCK
from repro.data.causal_dgp import make_causal_data
from repro.obs.metrics import MetricsRegistry, default_registry
from repro.obs.trace import Tracer
from repro.serve_effects import (
    EffectServer,
    QueueFull,
    ServingPanel,
    panel_from_checkpoint,
    score_single,
)
from repro.store import MomentStore
from repro.sweep.spec import SweepSpec

N, E, P = 1100, 5, 6
_SKEY = jax.random.PRNGKey(11)


def _cfg() -> CausalConfig:
    return CausalConfig(
        n_folds=3, inference="none", row_block=ROW_BLOCK,
        nuisance_t="ridge", discrete_treatment=False, cate_features=2)


@pytest.fixture(scope="module")
def data():
    return make_causal_data(jax.random.PRNGKey(42), N, P, effect=1.2,
                            discrete_treatment=False)


@pytest.fixture(scope="module")
def sids():
    return jax.random.randint(jax.random.PRNGKey(9), (N,), 0, E)


@pytest.fixture(scope="module")
def spec():
    return SweepSpec(n_segments=E, columns=(("dml", _cfg()),))


@pytest.fixture(scope="module")
def store(spec, data, sids):
    s = MomentStore(spec, n_features=P, key=_SKEY)
    s.ingest(X=data.X, y=data.y, t=data.t, segment_ids=sids)
    return s


@pytest.fixture(scope="module")
def panel(store):
    return ServingPanel.from_effect_panel(
        store.refresh(), n_features=P, version=store.version)


def _server(panel, **kw):
    kw.setdefault("wave_sizes", (4, 16))
    kw.setdefault("max_queue", 64)
    return EffectServer(panel, **kw)


# ---------------------------------------------------------------------------
# Bitwise: batched-with-padding ≡ unbatched, padded slots are no-ops.
# ---------------------------------------------------------------------------

def test_batched_scoring_bitwise_unbatched(panel, data, sids):
    srv = _server(panel)
    X = np.asarray(data.X[:5])                # 5 real rows -> wave of 16
    ids = np.asarray(sids[:5])
    responses = srv.score(X, ids)
    for i, r in enumerate(responses):
        ref = jax.block_until_ready(
            score_single(panel, X[i], int(ids[i]), srv._z))
        assert r.cate == float(ref["cate"])
        assert r.lo == float(ref["lo"]) and r.hi == float(ref["hi"])
        assert r.se == float(ref["se"]) and r.ok == bool(ref["ok"])
        assert r.version == panel.version


def test_wave_shape_invariance_bitwise(panel, data, sids):
    # the same request served through different wave shapes (different
    # jit programs, different padding) produces identical bits
    X, ids = np.asarray(data.X[:3]), np.asarray(sids[:3])
    small = _server(panel, wave_sizes=(4,)).score(X, ids)
    large = _server(panel, wave_sizes=(16,)).score(X, ids)
    ones = _server(panel, wave_sizes=(1,)).score(X, ids)
    for a, b, c in zip(small, large, ones):
        assert (a.cate, a.lo, a.hi, a.se, a.ok) \
            == (b.cate, b.lo, b.hi, b.se, b.ok) \
            == (c.cate, c.lo, c.hi, c.se, c.ok)


def test_padded_slots_are_flagged_noops(panel, data):
    from repro.serve_effects.scoring import score_batch

    X = np.zeros((8, P), np.float32)
    X[0] = np.asarray(data.X[0])
    sids_wave = np.full((8,), -1, np.int32)   # 7 padded slots
    sids_wave[0] = 2
    out = {k: np.asarray(v) for k, v in jax.block_until_ready(
        score_batch(panel, X, sids_wave, 1.96)).items()}
    assert not out["ok"][1:].any()
    np.testing.assert_array_equal(out["cate"][1:], 0.0)
    np.testing.assert_array_equal(out["lo"][1:], 0.0)
    # and garbage in the padded slots cannot perturb the real row
    X2 = X.copy()
    X2[1:] = 1e30
    out2 = {k: np.asarray(v) for k, v in jax.block_until_ready(
        score_batch(panel, X2, sids_wave, 1.96)).items()}
    for k in ("cate", "lo", "hi", "se", "ok"):
        assert out[k][0] == out2[k][0]


# ---------------------------------------------------------------------------
# Edge cases: empty wave, single request, backpressure.
# ---------------------------------------------------------------------------

def test_empty_wave_is_noop(panel):
    srv = _server(panel)
    assert srv.step() == []
    assert srv.drain() == []
    assert srv.snapshot()["counters"].get("serve.waves", 0) == 0


def test_single_request(panel, data):
    srv = _server(panel)
    t = srv.submit(np.asarray(data.X[0]), 1)
    assert not t.done and srv.queue_depth == 1
    (served,) = srv.step()
    assert served is t and t.done and srv.queue_depth == 0
    assert np.isfinite(t.response.cate)
    assert t.response.lo <= t.response.cate <= t.response.hi
    assert t.response.latency_s > 0


def test_queue_overflow_backpressure(panel, data):
    srv = _server(panel, wave_sizes=(4,), max_queue=8)
    x = np.asarray(data.X[0])
    for _ in range(8):
        srv.submit(x, 0)
    with pytest.raises(QueueFull):
        srv.submit(x, 0)
    assert srv.snapshot()["counters"]["serve.rejected"] == 1
    # draining relieves the backpressure; nothing admitted was dropped
    served = srv.drain()
    assert len(served) == 8 and all(t.done for t in served)
    srv.submit(x, 0)


def test_bad_request_shape_rejected(panel):
    srv = _server(panel)
    with pytest.raises(ValueError, match="request x"):
        srv.submit(np.zeros((P + 1,), np.float32), 0)


# ---------------------------------------------------------------------------
# Flagged responses: failed cells, out-of-range segments — never NaN.
# ---------------------------------------------------------------------------

def test_failed_cell_returns_flagged_response(data):
    sids0 = jnp.zeros((N,), jnp.int32)        # segments 1, 2 have no rows
    spec0 = SweepSpec(n_segments=3, columns=(("dml", _cfg()),))
    s = MomentStore(spec0, n_features=P, key=_SKEY)
    s.ingest(X=data.X, y=data.y, t=data.t, segment_ids=sids0)
    sp = ServingPanel.from_effect_panel(s.refresh(), n_features=P,
                                        version=s.version)
    srv = _server(sp)
    good, bad = srv.score(np.asarray(data.X[:2]), np.asarray([0, 1]))
    assert good.ok and np.isfinite(good.cate)
    assert not bad.ok
    assert (bad.cate, bad.lo, bad.hi, bad.se) == (0.0, 0.0, 0.0, 0.0)


def test_out_of_range_segment_flagged(panel, data):
    srv = _server(panel)
    lo, hi = srv.score(np.asarray(data.X[:2]), np.asarray([-3, E + 7]))
    for r in (lo, hi):
        assert not r.ok and r.cate == 0.0 and not np.isnan(r.cate)


def test_failed_column_rejected_at_prepare(store):
    panel = store.refresh()
    bad = SweepSpec(n_segments=E, columns=(("drlearner", _cfg()),))
    s = MomentStore(bad, n_features=P, key=_SKEY)  # unsupported -> failed
    with pytest.raises(ValueError, match="failed"):
        ServingPanel.from_effect_panel(s.refresh(), n_features=P)
    assert panel.columns[0].error is None  # sanity: the good one serves


# ---------------------------------------------------------------------------
# Hot-swap: one version per wave, checkpoint wiring, rollback.
# ---------------------------------------------------------------------------

def test_hot_swap_one_version_per_wave_never_mixed(spec, data, sids):
    s = MomentStore(spec, n_features=P, key=_SKEY)
    s.ingest(X=data.X[:512], y=data.y[:512], t=data.t[:512],
             segment_ids=sids[:512])
    p1 = ServingPanel.from_effect_panel(s.refresh(), n_features=P,
                                        version=s.version)
    s.ingest(X=data.X[512:], y=data.y[512:], t=data.t[512:],
             segment_ids=sids[512:])
    p2 = ServingPanel.from_effect_panel(s.refresh(), n_features=P,
                                        version=s.version)
    srv = _server(p1, wave_sizes=(4,), max_queue=64)
    x = np.asarray(data.X[0])
    tickets = [srv.submit(x, 1) for _ in range(8)]  # two waves queued
    wave1 = srv.step()
    srv.swap(p2)          # arrives while wave 2's requests sit queued
    wave2 = srv.step()
    v1 = {t.response.version for t in wave1}
    v2 = {t.response.version for t in wave2}
    assert v1 == {p1.version} and v2 == {p2.version}
    assert len(wave1) + len(wave2) == len(tickets)  # nothing dropped
    assert all(t.done for t in tickets)
    # the swap changed the served estimate for an identical request
    assert wave1[0].response.cate != wave2[0].response.cate


def test_hot_swap_from_store_checkpoints(tmp_path, spec, data, sids):
    manager = CheckpointManager(str(tmp_path), keep_latest=8)
    s = MomentStore(spec, n_features=P, key=_SKEY)
    s.ingest(X=data.X[:512], y=data.y[:512], t=data.t[:512],
             segment_ids=sids[:512])
    v1 = s.save(manager)
    s.ingest(X=data.X[512:], y=data.y[512:], t=data.t[512:],
             segment_ids=sids[512:])
    v2 = s.save(manager)

    p1 = panel_from_checkpoint(manager, spec, P, key=_SKEY, step=v1)
    srv = _server(p1)
    x, sid = np.asarray(data.X[3]), 2
    r1 = srv.score(x[None], [sid])[0]
    assert r1.version == v1

    latest = panel_from_checkpoint(manager, spec, P, key=_SKEY)  # = v2
    srv.swap(latest)
    r2 = srv.score(x[None], [sid])[0]
    assert r2.version == v2 and r2.cate != r1.cate

    rolled = srv.rollback()
    assert rolled.version == v1
    r3 = srv.score(x[None], [sid])[0]
    assert r3.version == v1 and r3.cate == r1.cate  # bitwise round-trip
    assert srv.snapshot()["counters"]["serve.swaps"] == 1
    assert srv.snapshot()["counters"]["serve.rollbacks"] == 1


def test_checkpoint_provenance_enforced(tmp_path, spec, data, sids):
    manager = CheckpointManager(str(tmp_path), keep_latest=8)
    s = MomentStore(spec, n_features=P, key=_SKEY)
    s.ingest(X=data.X, y=data.y, t=data.t, segment_ids=sids)
    s.save(manager)
    other = SweepSpec(n_segments=E, columns=(("dml_loo", _cfg()),))
    with pytest.raises(ValueError, match="columns"):
        panel_from_checkpoint(manager, other, P, key=_SKEY)


def test_rollback_without_history_raises(panel):
    with pytest.raises(RuntimeError, match="roll back"):
        _server(panel).rollback()


# ---------------------------------------------------------------------------
# Observability: per-server registry, histograms, spans, no perturbation.
# ---------------------------------------------------------------------------

def test_metrics_are_per_server_never_global(panel, data, sids):
    a, b = _server(panel), _server(panel)
    a.score(np.asarray(data.X[:6]), np.asarray(sids[:6]))
    snap_a, snap_b = a.snapshot(), b.snapshot()
    assert snap_a["counters"]["serve.requests"] == 6
    assert "serve.requests" not in snap_b["counters"]
    assert "serve.requests" not in default_registry().snapshot()["counters"]
    hist = snap_a["histograms"]["serve.request_seconds"]
    assert hist["count"] == 6 and hist["p99"] >= hist["p50"] > 0
    occ = snap_a["histograms"]["serve.batch_occupancy"]
    assert 0 < occ["max"] <= 1.0
    # an injected registry is used as-is
    reg = MetricsRegistry()
    c = _server(panel, registry=reg)
    c.score(np.asarray(data.X[:1]), np.asarray(sids[:1]))
    assert reg.snapshot()["counters"]["serve.requests"] == 1


def test_wave_spans_and_bit_identity_under_tracing(panel, data, sids):
    X, ids = np.asarray(data.X[:9]), np.asarray(sids[:9])
    tracer = Tracer()
    traced = _server(panel, tracer=tracer).score(X, ids)
    plain = _server(panel).score(X, ids)
    waves = [s for s in tracer.spans if s.name == "serve.wave"]
    assert waves and waves[0].attrs["version"] == panel.version
    assert sum(s.attrs["fill"] for s in waves) == 9
    for a, b in zip(traced, plain):
        assert (a.cate, a.lo, a.hi, a.se, a.ok) \
            == (b.cate, b.lo, b.hi, b.se, b.ok)
