"""Sharding policy: every (arch x shape x mesh) cell's parameter and
input specs must divide evenly — the fast (no-lowering) half of the
multi-pod dry-run, covering all 40 cells x 2 meshes on one CPU device."""
import pytest
from jax.sharding import PartitionSpec as P

from repro.config import SHAPES
from repro.configs import ARCH_IDS
from repro.launch.cells import batch_pspecs, cache_pspecs, make_cell
from repro.distributed.sharding import logical_to_spec, param_specs

AXIS_SIZE = {"data": 16, "model": 16, "pod": 2}


def _check_divisible(shape, spec, where):
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for dim, p in zip(shape, parts):
        if p is None:
            continue
        axes = p if isinstance(p, tuple) else (p,)
        n = 1
        for a in axes:
            n *= AXIS_SIZE[a]
        assert dim % n == 0, f"{where}: dim {dim} not divisible by {n} ({spec})"


@pytest.mark.parametrize("multi_pod", [False, True])
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_all_cells_shardable(arch, multi_pod):
    for shape in SHAPES:
        cell = make_cell(arch, shape.name, multi_pod=multi_pod)
        model = cell.model()
        ok, _ = model.supports_shape(shape)
        if not ok:
            continue
        # parameters
        schema = model.schema()
        specs = param_specs(schema, cell.rules)
        import jax.tree_util as jtu
        defs = jtu.tree_leaves(
            schema, is_leaf=lambda x: hasattr(x, "axes"))
        spec_leaves = jtu.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        for d, s in zip(defs, spec_leaves):
            _check_divisible(d.shape, s, f"{cell.name} param")
        # inputs
        inputs = model.input_specs(shape)
        if shape.kind in ("train", "prefill"):
            ps = batch_pspecs(cell)
            for k, v in inputs.items():
                _check_divisible(v.shape, ps[k], f"{cell.name} input {k}")
        else:
            cache_sp = cache_pspecs(cell, inputs["cache"])
            cl = jtu.tree_leaves(inputs["cache"])
            sl = jtu.tree_leaves(cache_sp,
                                 is_leaf=lambda x: isinstance(x, P))
            for leaf, s in zip(cl, sl):
                _check_divisible(leaf.shape, s, f"{cell.name} cache")


def test_dedup_under_sequence_parallel():
    cell = make_cell("granite-3-2b", "train_4k")
    # logits: seq must NOT claim "model" (vocab owns it)
    spec = logical_to_spec(("batch", "logits_seq", "vocab"), cell.rules)
    assert spec == P("data", None, "model")
    # residual stream: seq DOES claim model (SP)
    spec = logical_to_spec(("batch", "seq", "embed_act"), cell.rules)
    assert spec == P("data", "model", None)


def test_head_indivisible_archs_fall_back():
    """yi (56 heads) cannot TP over 16: heads replicated, q seq-sharded."""
    cell = make_cell("yi-34b", "train_4k")
    assert cell.rules.get("heads") is None
    assert cell.rules.get("attn_seq") == "model"
    # granite (32 heads) does TP its heads
    cell2 = make_cell("granite-3-2b", "train_4k")
    assert cell2.rules.get("heads") == "model"
    assert cell2.rules.get("attn_seq") is None


def test_moe_expert_parallel_over_dp():
    cell = make_cell("deepseek-v3-671b", "train_4k")
    assert cell.rules.get("experts") == "data"
    cell_mp = make_cell("deepseek-v3-671b", "decode_32k", multi_pod=True)
    assert cell_mp.rules.get("experts") == ("pod", "data")


def test_long_context_cache_spec():
    cell = make_cell("zamba2-1.2b", "long_500k")
    model = cell.model()
    inputs = model.input_specs(cell.shape)
    sp = cache_pspecs(cell, inputs["cache"])
    # attention KV seq sharded over the DP axis (batch=1 frees it)
    assert sp["attn"]["k"][2] == "data"
