# Tests run on the host's single CPU device — the 512-placeholder-device
# XLA flag belongs to launch/dryrun.py ONLY and must never be set here.
import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    """Release compiled executables between test modules.

    Every XLA executable holds live memory mappings; across the full
    suite the process otherwise accumulates past the kernel's default
    ``vm.max_map_count`` (65530) and a late compile segfaults inside
    XLA.  Cross-module cache hits are rare (each module compiles its
    own shapes), so this costs little and bounds the map count.  The
    bit-identity contracts are all certified within one module, never
    across a cache clear."""
    yield
    jax.clear_caches()


@pytest.fixture(autouse=True)
def _reset_default_metrics_registry():
    """Fresh process-global metrics registry per test.

    ``obs.metrics.default_registry()`` is a process-wide singleton
    (trace-time instrumentation can't thread a handle); without a reset
    any two tests touching a same-name counter couple through test
    order.  Counts within one test remain visible — instrumentation
    re-resolves ``default_registry()`` on every increment."""
    from repro.obs.metrics import reset_default_registry

    reset_default_registry()
    yield


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


def assert_no_nan(tree, where=""):
    import jax.numpy as jnp
    for leaf in jax.tree_util.tree_leaves(tree):
        assert jnp.isfinite(leaf).all(), f"non-finite values {where}"
