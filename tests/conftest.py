# Tests run on the host's single CPU device — the 512-placeholder-device
# XLA flag belongs to launch/dryrun.py ONLY and must never be set here.
import jax
import pytest


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


def assert_no_nan(tree, where=""):
    import jax.numpy as jnp
    for leaf in jax.tree_util.tree_leaves(tree):
        assert jnp.isfinite(leaf).all(), f"non-finite values {where}"
