"""End-to-end LM training driver (deliverable b): trains a ~100M-param
granite-family model on the synthetic bigram stream for a few hundred
steps with async checkpointing, then demonstrates an ELASTIC restart
(restore + exact-replay continuation).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses
import os
import tempfile

import jax

from repro.config import ParallelConfig, TrainConfig
from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data.lm_data import bigram_ce_floor, lm_batch
from repro.data.pipeline import ShardedFeed, batch_sharding
from repro.launch.elastic import elastic_restore
from repro.launch.mesh import make_host_mesh
from repro.launch.train import TrainState, train_loop
from repro.models.model import build_model
from repro.distributed.sharding import default_rules

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=16)
ap.add_argument("--seq", type=int, default=128)
args = ap.parse_args()

# ~100M params: granite family, narrowed
cfg = dataclasses.replace(
    get_config("granite-3-2b"),
    num_layers=6, d_model=512, num_heads=8, num_kv_heads=4, head_dim=64,
    d_ff=1536, vocab_size=8192, max_position_embeddings=2048)
print(f"model: {cfg.param_count()/1e6:.0f}M params "
      f"(CE floor ≈ {bigram_ce_floor(cfg.vocab_size):.2f} nats)")

mesh = make_host_mesh()
rules = default_rules(fsdp=False)
model = build_model(cfg, ParallelConfig(fsdp=False), rules)
tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=args.steps // 10,
                   total_steps=args.steps)

key = jax.random.PRNGKey(0)
ckpt_dir = os.path.join(tempfile.gettempdir(), "repro_train_lm_ckpt")
manager = CheckpointManager(ckpt_dir, keep_latest=2)

feed = ShardedFeed(
    lambda s: lm_batch(jax.random.fold_in(key, s), args.batch, args.seq,
                       cfg.vocab_size),
    sharding=batch_sharding(mesh))

with jax.set_mesh(mesh):
    state = train_loop(model, tcfg, feed, manager=manager,
                       ckpt_every=max(args.steps // 3, 50), log_every=25)
feed.close()

# ---- elastic restart demo: restore the latest checkpoint onto the
# (possibly different) mesh and continue for a few steps -----------------
print("\nelastic restart: restoring latest checkpoint ...")
restored, meta = elastic_restore(manager, model, rules, mesh)
resume = meta["step"]
print(f"restored step {resume}; continuing 10 more steps")
feed2 = ShardedFeed(
    lambda s: lm_batch(jax.random.fold_in(key, s), args.batch, args.seq,
                       cfg.vocab_size),
    sharding=batch_sharding(mesh), start_step=resume)
tcfg2 = dataclasses.replace(tcfg, total_steps=resume + 10)
with jax.set_mesh(mesh):
    train_loop(model, tcfg2, feed2, log_every=5,
               state=TrainState(params=restored["params"],
                                opt=restored["opt"], step=resume))
feed2.close()
print("done.")
