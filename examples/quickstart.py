"""Quickstart — the paper's §5.1 listing, translated to NEXUS-JAX.

The original (EconML + Ray):

    est_ray = DML_Ray(model_y=RandomForestRegressor(),
                      model_t=RandomForestClassifier(),
                      model_final=StatsModelsLinearRegression(...),
                      discrete_treatment=True, cv=5)
    est_ray.fit(y, T, X=X, W=None)

Here: the same 5-fold cross-fit DML with the fold-parallel engine (the
SPMD translation of Ray tasks), MXU-native nuisances, and the NEXUS
validation suite.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.config import CausalConfig
from repro.core.dml import DML
from repro.core.refutation import run_all
from repro.data.causal_dgp import paper_demo_data

key = jax.random.PRNGKey(123)

# the paper's synthetic data: y = (1 + .5 x0) T + x0 + eps, T ~ B(expit(x0))
print("generating synthetic data (n=100k, p=100) ...")
data = paper_demo_data(key, n=100_000, p=100)

cfg = CausalConfig(
    n_folds=5,                 # cv=5
    nuisance_y="ridge",        # model_y (MXU-native; see DESIGN.md §9)
    nuisance_t="logistic",     # model_t
    cate_features=2,           # theta(x) = b0 + b1 * x0  (the true CATE)
    discrete_treatment=True,
    engine="parallel",         # the paper's contribution (C1)
    inference="jackknife",     # near-free CI at this n (reuses fold
)                              # fits); bootstrap demo: inference_demo.py

est = DML(cfg)
res = est.fit(data.y, data.t, data.X, key=key)
print(res.summary())
print(f"\ntrue ATE = {float(data.true_cate.mean()):.4f}   "
      f"estimated ATE = {res.ate_of(data.X):.4f}")

# replicate-based CI via the repro.inference executor (jackknife here:
# k delete-fold re-solves of the final stage, no nuisance refits)
lo, hi = res.ate_interval()
print(f"{cfg.inference} {100 * (1 - cfg.alpha):.0f}% CI for theta0: "
      f"[{lo:+.4f}, {hi:+.4f}]")

print("\nNEXUS validation suite (refutation tests):")
for report in run_all(cfg, data.y, data.t, data.X, key=key):
    print(" ", report.row())
