"""Batched serving demo (NEXUS deployment path): prefill + lock-step
continuous decode of a wave of requests against a smoke model.

    PYTHONPATH=src python examples/serve_demo.py [--arch granite-3-2b]
"""
import argparse
import time

import jax

from repro.configs import get_config
from repro.launch.serve import BatchServer, Request
from repro.models.model import build_model

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="granite-3-2b")
ap.add_argument("--requests", type=int, default=4)
ap.add_argument("--new-tokens", type=int, default=12)
args = ap.parse_args()

key = jax.random.PRNGKey(0)
cfg = get_config(args.arch + "-smoke")
model = build_model(cfg)
params = model.init(key)
server = BatchServer(model, params, max_seq=128)

prompts = [jax.random.randint(jax.random.fold_in(key, i), (16,), 0,
                              cfg.vocab_size) for i in range(args.requests)]
reqs = [Request(p, max_new_tokens=args.new_tokens) for p in prompts]

t0 = time.time()
outs = server.serve_wave(reqs)
dt = time.time() - t0
total = sum(len(o.tokens) for o in outs)
print(f"served {args.requests} requests, {total} tokens "
      f"in {dt:.2f}s ({total/dt:.1f} tok/s on this host)")
for i, o in enumerate(outs):
    print(f"  req{i}: {o.tokens}")
