"""Segment-parallel sweep demo: estimate one effect PER user segment —
the paper's many-cohorts workload — as batched programs, then compare
against the practitioner's groupby loop.

Run: PYTHONPATH=src python examples/sweep_demo.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.sweep_synthetic import SWEEP
from repro.data.causal_dgp import make_causal_data
from repro.sweep import SweepSpec, serial_loop, sweep

N, P, E = 16_384, 10, 16


def main():
    key = jax.random.PRNGKey(0)
    data = make_causal_data(key, N, P, effect=1.0, heterogeneous=True)
    # synthetic cohort assignment (in production: a user-segment column)
    sids = jax.random.randint(jax.random.fold_in(key, 1), (N,), 0, E)

    cfg = dataclasses.replace(SWEEP, n_folds=3, row_block=1024)
    cfg_ci = dataclasses.replace(cfg, inference="bootstrap", n_bootstrap=32)

    # two columns: a fast point sweep + a bootstrap-CI sweep — the CI
    # column's (cell x replicate) axes run through runtime.map_product
    spec = SweepSpec(n_segments=E, columns=(("dml", cfg), ("dml", cfg_ci)),
                     segment_key=SWEEP.segment_key)

    t0 = time.perf_counter()
    panel = sweep(spec, X=data.X, y=data.y, t=data.t, segment_ids=sids,
                  key=key, executor="vmap")
    jax.block_until_ready(panel.columns[0].thetas)
    print(f"batched panel ({spec.n_cells} cells): "
          f"{time.perf_counter() - t0:.2f}s")
    print(panel.summary())

    # per-segment ATEs with bootstrap CIs
    ci = panel.columns[1]
    print("\nper-segment ATE [bootstrap 95% CI]:")
    for s in range(E):
        print(f"  segment {s:2d} (n={int(panel.counts[s]):5d}): "
              f"{float(ci.ates[s]):+.3f} "
              f"[{float(ci.ci_lo[s]):+.3f}, {float(ci.ci_hi[s]):+.3f}]")

    # the loop the panel replaces — and certifies against, bitwise
    t0 = time.perf_counter()
    loop = serial_loop("dml", cfg, X=data.X, y=data.y, t=data.t,
                       segment_ids=sids, n_segments=E, key=key)
    jax.block_until_ready(loop["theta"])
    t_loop = time.perf_counter() - t0
    same = np.array_equal(np.asarray(panel.columns[0].thetas),
                          np.asarray(loop["theta"]))
    print(f"\nserial loop of {E} single fits: {t_loop:.2f}s; "
          f"panel == loop bitwise: {same}")

    # the one-pass segmented execution (shared fold draw, LOO kernels)
    t0 = time.perf_counter()
    seg = sweep(SweepSpec(n_segments=E, columns=(("dml", cfg),)),
                X=data.X, y=data.y, t=data.t, segment_ids=sids, key=key,
                mode="segmented")
    jax.block_until_ready(seg.columns[0].thetas)
    print(f"segmented one-pass sweep: {time.perf_counter() - t0:.2f}s "
          f"(mean |Δ| vs cells "
          f"{float(jnp.abs(seg.columns[0].ates - panel.columns[0].ates).mean()):.3f} "
          f"— a different fold draw, same estimator)")


if __name__ == "__main__":
    main()
