"""Effect-store demo: five days of arriving data, refreshed two ways —
re-fitting the whole panel from scratch every day (the practitioner's
baseline) vs folding ONLY the new rows into a persistent MomentStore
and re-solving from moments.  At these row-blocked shapes the two are
bitwise identical, day after day.

Run: PYTHONPATH=src python examples/store_demo.py
"""
import tempfile
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.config import CausalConfig
from repro.data.causal_dgp import make_causal_data
from repro.store import MomentStore
from repro.sweep.spec import SweepSpec

N_DAY, DAYS, P, E = 4096, 5, 10, 8


def main():
    key = jax.random.PRNGKey(0)
    total = N_DAY * DAYS
    data = make_causal_data(key, total, P, effect=1.0,
                            discrete_treatment=False)
    sids = jax.random.randint(jax.random.fold_in(key, 1), (total,), 0, E)

    cfg = CausalConfig(n_folds=3, inference="none", row_block=1024,
                       nuisance_t="ridge", discrete_treatment=False)
    spec = SweepSpec(n_segments=E, columns=(("dml", cfg),))

    def day(d):
        lo, hi = d * N_DAY, (d + 1) * N_DAY
        return dict(X=data.X[lo:hi], y=data.y[lo:hi], t=data.t[lo:hi],
                    segment_ids=sids[lo:hi])

    store = MomentStore(spec, n_features=P, key=key)
    ckpt = CheckpointManager(tempfile.mkdtemp(prefix="store_demo_"))

    print(f"{DAYS} days x {N_DAY} rows/day, {E} segments, "
          f"row_block={cfg.row_block}\n")
    print("day   rows_seen  ingest+refresh   full_refit   speedup  bitwise")
    for d in range(DAYS):
        # incremental: fold ONLY today's rows into the standing store
        t0 = time.perf_counter()
        store.ingest(**day(d))
        panel = store.refresh()
        jax.block_until_ready(panel.columns[0].thetas)
        t_inc = time.perf_counter() - t0
        store.save(ckpt)  # versioned snapshot (hot-swap/rollback)

        # baseline: rebuild from scratch over ALL rows seen so far
        t0 = time.perf_counter()
        refit = MomentStore(spec, n_features=P, key=key)
        hi = (d + 1) * N_DAY
        refit.ingest(X=data.X[:hi], y=data.y[:hi], t=data.t[:hi],
                     segment_ids=sids[:hi])
        full = refit.refresh()
        jax.block_until_ready(full.columns[0].thetas)
        t_full = time.perf_counter() - t0

        same = np.array_equal(np.asarray(panel.columns[0].thetas),
                              np.asarray(full.columns[0].thetas))
        print(f"  {d}   {store.n_total:9d}  {t_inc:12.2f}s  "
              f"{t_full:9.2f}s  {t_full / t_inc:6.2f}x  {same}")

    print(f"\nstore at version {store.version} "
          f"(checkpoints: {ckpt.latest_step()} latest)")
    col = store.refresh().columns[0]
    print("per-segment ATE after day 5:",
          np.array2string(np.asarray(col.ates), precision=3))
    print("(full-refit timings include each day's from-scratch jit; the "
          "standing store compiles once and its ingest cost scales with "
          "the new block, not the history)")


if __name__ == "__main__":
    main()
